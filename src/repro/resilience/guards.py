"""On-device step health monitor + graceful wire degradation state machine.

The paper's thesis is that quantization error is a *metric*; the DPS
controllers use it to steer bit-widths, and this module uses the same
measurements to detect failure.  A :class:`GuardState` pytree rides
:class:`~repro.core.qtrain.TrainState` through the compiled step and folds
the step's numeric signals into a small int32 "health word":

    bit 0  loss came back NaN/Inf
    bit 1  raw local gradients carried NaN/Inf (counted PRE-encode: the
           int8 wire codec clips NaN silently, so post-wire values look
           healthy — detection must happen on the raw tree)
    bit 2  a wire domain's overflow-rate EWMA crossed the storm threshold
    bit 3  the decoded gradient norm spiked vs its EWMA (how a corrupted
           wire payload — e.g. a bit-flipped int8 buffer — manifests:
           every decoded element gains a large power-of-two offset)
    bit 4  a wire domain's FL is pinned at its effective cap (railed
           controller; monitor-only)
    bit 5  a wire domain's IL ratcheted up repeatedly (monitor-only)
    bit 6  at least one wire domain is running the fp32 fallback
    bit 7  this step's update was skipped (params/opt/DPS held)

Everything is computed from values the step already materializes (loss,
wire-leg ``QuantStats``, the DPS registry) plus one extra ``psum`` of a
per-rank nonfinite count — zero additional host syncs; the health word is
drained with the existing deferred log-point metrics.

Degradation: when a wire domain trips (overflow storm, NaN gradients, or a
gradient-norm spike), ``degraded[d]`` latches to 1 and the NEXT step's
collective for that domain runs the fp32 fallback branch of a
``lax.cond`` — both branches live in the one compiled step (the serve
page-table trick: behavior changes through traced inputs, never through
recompilation).  After ``cooldown`` consecutive clean steps the int8 wire
re-arms.  On the trip itself the update is skipped (the fault already
happened this step) and the compute ``grads`` domain widens by one IL bit
(:func:`widen_on_trip` — the widening scheme of ``dps._clamp_fmt``).

Guard decisions NEVER feed from post-fallback values: the overflow signal
is tagged ``guard_sink`` for the precision-flow verifier, whose
``PF-GUARD-TAINT`` rule proves it derives from ``wire_stats`` taint.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import dps as dps_lib
from repro.core import tagging
from repro.core.fixed_point import QuantStats

HEALTH_LOSS_NONFINITE = 1
HEALTH_GRADS_NONFINITE = 2
HEALTH_OVERFLOW_STORM = 4
HEALTH_GRAD_SPIKE = 8
HEALTH_FL_RAIL = 16
HEALTH_IL_RATCHET = 32
HEALTH_DEGRADED = 64
HEALTH_SKIPPED = 128

_HEALTH_NAMES = (
    (HEALTH_LOSS_NONFINITE, "loss-nonfinite"),
    (HEALTH_GRADS_NONFINITE, "grads-nonfinite"),
    (HEALTH_OVERFLOW_STORM, "overflow-storm"),
    (HEALTH_GRAD_SPIKE, "grad-spike"),
    (HEALTH_FL_RAIL, "fl-rail"),
    (HEALTH_IL_RATCHET, "il-ratchet"),
    (HEALTH_DEGRADED, "degraded"),
    (HEALTH_SKIPPED, "skipped"),
)


def health_flags(word: int) -> Tuple[str, ...]:
    """Decode a drained health word into its event names (host-side)."""
    return tuple(name for bit, name in _HEALTH_NAMES if int(word) & bit)


@dataclasses.dataclass(frozen=True)
class GuardConfig:
    """Static thresholds of the health monitor (hashable: jit closure).

    The defaults are deliberately far from healthy-training territory so
    that guards are TRANSPARENT when nothing is wrong: wire overflow
    rates under the flexpoint controllers sit in the low percent range
    (storm trips at a 25% EWMA / 75% instantaneous rate), and a 16x
    gradient-norm jump over its EWMA does not occur in converging runs.
    """

    overflow_beta: float = 0.9     # EWMA decay of per-domain overflow rate
    overflow_trip: float = 0.25    # EWMA level that declares a storm
    overflow_trip_hi: float = 0.75 # instantaneous rate that declares one
    spike_ratio: float = 16.0      # gnorm > ratio * EWMA -> corrupted sync
    norm_beta: float = 0.9         # EWMA decay of the gradient norm
    rail_window: int = 8           # consecutive steps before a rail bit
    rail_overflow: float = 0.05    # FL-at-cap counts as railed only while
                                   # the domain also clips > this rate (a
                                   # flexpoint wire format sits at its FL
                                   # cap by construction — pinned AND
                                   # overflowing is the conflicted state)
    cooldown: int = 16             # clean steps before int8 re-arms
    widen_on_trip: bool = True     # +1 IL on the compute grads domain


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class GuardState:
    """Per-run health state (replicated scalars / tiny [D] vectors).

    ``D`` = number of wire domains in the precision plan, in plan order
    (:func:`wire_domains`); D = 0 runs the monitor without a degradation
    target (loss/grad guards + skip gate still apply).
    """

    health: jax.Array         # i32, last step's health word
    trips: jax.Array          # i32, cumulative degradation trips
    skipped: jax.Array        # i32, cumulative skipped updates
    degraded: jax.Array       # i32[D], 1 = fp32 fallback next step
    cooldown: jax.Array       # i32[D], clean steps left before re-arm
    overflow_ewma: jax.Array  # f32[D]
    gnorm_ewma: jax.Array     # f32, EWMA of the decoded gradient norm
    fl_rail: jax.Array        # i32[D], consecutive steps FL at its cap
    il_ratchet: jax.Array     # i32[D], consecutive steps IL moved up
    prev_il: jax.Array        # i32[D], last step's (max) IL per domain


def wire_domains(plan) -> Tuple[str, ...]:
    """The plan's wire domains, in plan order — the [D] axis of
    :class:`GuardState`."""
    return tuple(n for n, spec in plan.domains if spec.wire)


def init_guard_state(plan) -> GuardState:
    d = len(wire_domains(plan))
    # every field gets its OWN freshly-allocated array: the launch path
    # donates the train state into the jitted step, and two leaves
    # sharing one device buffer is an XLA donation error ("attempt to
    # donate the same buffer twice")
    zi = lambda: jnp.zeros((d,), jnp.int32)
    ils = []
    for n in wire_domains(plan):
        spec = plan.spec(n)
        st = spec.make().init(spec.state_shape())
        ils.append(jnp.max(st.il).astype(jnp.int32))
    return GuardState(
        health=jnp.zeros((), jnp.int32),
        trips=jnp.zeros((), jnp.int32),
        skipped=jnp.zeros((), jnp.int32),
        degraded=zi(), cooldown=zi(),
        overflow_ewma=jnp.zeros((d,), jnp.float32),
        gnorm_ewma=jnp.zeros((), jnp.float32),
        fl_rail=zi(), il_ratchet=zi(),
        prev_il=(jnp.stack(ils) if ils else zi()))


def guard_restore_defaults(plan, prefix: str = ".guard") -> dict:
    """Checkpoint back-compat defaults for the ``TrainState.guard`` subtree
    (same contract as ``qtrain.dps_restore_defaults``)."""
    from repro.checkpoint import flatten_tree  # deferred: io imports core
    return {f"{prefix}/{k}": v
            for k, v in flatten_tree(init_guard_state(plan)).items()}


def _collapse_stats(ws: QuantStats) -> jax.Array:
    """Global overflow rate of a (possibly [G]-shaped) wire-stats leg."""
    return jnp.sum(ws.overflow) / jnp.maximum(jnp.sum(ws.count), 1.0)


def domain_overflow(plan, wire_legs: dict) -> jax.Array:
    """f32[D] instantaneous overflow rates, one per wire domain.

    ``wire_legs`` maps domain name -> that leg's psum'ed wire
    :class:`QuantStats` (absent legs read 0 — e.g. the params leg of a
    degraded step, or a fp32 fallback branch whose stats are zeros).  The
    result is tagged ``guard_sink`` so the flow verifier can prove the
    degradation decision derives from wire-stats taint (PF-GUARD-TAINT),
    not from post-fallback values.
    """
    rates = []
    for n in wire_domains(plan):
        ws = wire_legs.get(n)
        if ws is None:
            # leg not engaged this config (e.g. wire_params without ZeRO):
            # a plain zero, deliberately NOT tagged — PF-GUARD-TAINT
            # audits engaged legs only
            rates.append(jnp.float32(0.0))
        else:
            rates.append(tagging.tag(_collapse_stats(ws), "guard_sink",
                                     domain=n))
    return (jnp.stack(rates) if rates else jnp.zeros((0,), jnp.float32))


def _rail_signals(plan, prev_il, new_dps):
    """Per-wire-domain rail signals from the updated DPS registry.

    Returns ``(il, fl_at_cap, il_up)``: the (max-over-groups) IL, whether
    any group's FL sits at its effective cap (``min(fl_max, max_total -
    il)`` — the same clamp ``dps._clamp_fmt`` applies), and whether the IL
    moved up vs the previous step.
    """
    ils, caps, ups = [], [], []
    for d, n in enumerate(wire_domains(plan)):
        spec = plan.spec(n)
        st = new_dps[n]
        h = spec.hyper
        il = jnp.asarray(st.il, jnp.int32)
        fl = jnp.asarray(st.fl, jnp.int32)
        cap = jnp.minimum(jnp.int32(h.fl_max), jnp.int32(h.max_total) - il)
        ils.append(jnp.max(il))
        caps.append(jnp.any(fl >= cap))
        ups.append(jnp.max(il) > prev_il[d])
    if not ils:
        z = jnp.zeros((0,), jnp.int32)
        return z, jnp.zeros((0,), jnp.bool_), jnp.zeros((0,), jnp.bool_)
    return jnp.stack(ils), jnp.stack(caps), jnp.stack(ups)


def update_guard(gcfg: GuardConfig, plan, guard: GuardState, *,
                 loss, grads_bad, gnorm, wire_ov, new_dps,
                 grads_domain_idx: int = 0):
    """Fold this step's signals into the next :class:`GuardState`.

    All inputs are replicated on-device values the step already computed:
    ``loss`` (scalar), ``grads_bad`` (psum'ed nonfinite count of the RAW
    local gradients), ``gnorm`` (norm of the decoded/averaged gradients),
    ``wire_ov`` (f32[D] from :func:`domain_overflow`), ``new_dps`` (the
    registry AFTER the controller update).  ``grads_domain_idx`` is the
    [D]-index of the domain that carries the gradient wire (NaN/spike
    trips land there).

    Returns ``(new_guard, ok, trip_any)``: ``ok`` (bool scalar) gates the
    params/opt/DPS update (False = hold the previous values — the "skip"
    response), ``trip_any`` is the rising-edge degradation trip this step
    (feeds :func:`widen_on_trip`).
    """
    d = guard.degraded.shape[0]
    loss_bad = ~jnp.isfinite(loss)
    g_bad = jnp.asarray(grads_bad) > 0
    # EWMA warmup: no spike before the norm EWMA has a value, and never
    # feed a nonfinite norm into it.
    g_ok = jnp.isfinite(gnorm)
    spike = g_ok & (guard.gnorm_ewma > 0) & (
        gnorm > gcfg.spike_ratio * guard.gnorm_ewma)
    ov = jnp.where(jnp.isfinite(wire_ov), wire_ov, 1.0)
    ov_ewma = (gcfg.overflow_beta * guard.overflow_ewma
               + (1.0 - gcfg.overflow_beta) * ov)
    storm = (ov_ewma > gcfg.overflow_trip) | (ov > gcfg.overflow_trip_hi)

    # per-domain trip: its own storm, plus gradient-path corruption
    # (NaN grads / norm spike / NaN loss) charged to the gradient wire
    grad_fault = loss_bad | g_bad | spike
    if d:
        charge = jnp.zeros((d,), jnp.bool_).at[grads_domain_idx].set(
            grad_fault)
        trip = storm | charge
    else:
        trip = storm
    rising = trip & (guard.degraded == 0)
    trip_any = jnp.any(rising) if d else jnp.zeros((), jnp.bool_)

    clean = ~trip
    cooldown = jnp.where(
        trip, jnp.int32(gcfg.cooldown),
        jnp.maximum(guard.cooldown - jnp.where(
            (guard.degraded > 0) & clean, 1, 0), 0))
    degraded = jnp.where(trip, 1,
                         jnp.where((guard.degraded > 0) & (cooldown > 0),
                                   guard.degraded, 0)).astype(jnp.int32)

    il, fl_cap, il_up = _rail_signals(plan, guard.prev_il, new_dps)
    # FL-at-cap alone is steady state for flexpoint wire formats (il + fl
    # == wire bits by construction); railed = pinned AND still clipping.
    fl_rail = jnp.where(fl_cap & (ov > gcfg.rail_overflow),
                        guard.fl_rail + 1, 0).astype(jnp.int32)
    il_ratchet = jnp.where(il_up, guard.il_ratchet + 1, 0).astype(jnp.int32)
    railed = jnp.any(fl_rail >= gcfg.rail_window) if d else False
    ratchety = jnp.any(il_ratchet >= gcfg.rail_window) if d else False

    ok = ~(loss_bad | g_bad | spike)
    bit = lambda cond, b: jnp.where(cond, jnp.int32(b), 0)
    health = (bit(loss_bad, HEALTH_LOSS_NONFINITE)
              | bit(g_bad, HEALTH_GRADS_NONFINITE)
              | bit(jnp.any(storm) if d else False, HEALTH_OVERFLOW_STORM)
              | bit(spike, HEALTH_GRAD_SPIKE)
              | bit(railed, HEALTH_FL_RAIL)
              | bit(ratchety, HEALTH_IL_RATCHET)
              | bit(jnp.any(degraded > 0) if d else False, HEALTH_DEGRADED)
              | bit(~ok, HEALTH_SKIPPED))

    new_guard = GuardState(
        health=health.astype(jnp.int32),
        trips=guard.trips + trip_any.astype(jnp.int32),
        skipped=guard.skipped + (~ok).astype(jnp.int32),
        degraded=degraded, cooldown=cooldown,
        overflow_ewma=ov_ewma.astype(jnp.float32),
        gnorm_ewma=jnp.where(ok & g_ok,
                             gcfg.norm_beta * guard.gnorm_ewma
                             + (1.0 - gcfg.norm_beta) * gnorm,
                             guard.gnorm_ewma).astype(jnp.float32),
        fl_rail=fl_rail, il_ratchet=il_ratchet, prev_il=il)
    return new_guard, ok, trip_any


def widen_on_trip(plan, dps, trip_any, domain: str = "grads"):
    """One IL bit of extra headroom on the compute ``domain`` when a trip
    fired this step — the reactive half of Courbariaux-style overflow
    scaling, applied through the same ``_clamp_fmt`` the controllers use
    so caps and the exactness span hold."""
    if domain not in plan:
        return dps
    spec = plan.spec(domain)
    st = dps[domain]
    il, fl = dps_lib._clamp_fmt(
        jnp.asarray(st.il) + jnp.where(trip_any, 1, 0),
        jnp.asarray(st.fl), spec.hyper)
    widened = dataclasses.replace(st, il=il, fl=fl)
    return type(dps)({n: (widened if n == domain else dps[n])
                      for n in dps.names()})


def nonfinite_count(tree) -> jax.Array:
    """f32 count of NaN/Inf elements across a pytree (rank-local; psum it
    inside shard_map bodies)."""
    leaves = jax.tree_util.tree_leaves(tree)
    if not leaves:
        return jnp.zeros((), jnp.float32)
    return sum(jnp.sum((~jnp.isfinite(l.astype(jnp.float32))).astype(
        jnp.float32)) for l in leaves)


def global_norm(tree) -> jax.Array:
    """f32 L2 norm of a pytree (the spike detector's input)."""
    leaves = jax.tree_util.tree_leaves(tree)
    if not leaves:
        return jnp.zeros((), jnp.float32)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))
