"""repro.resilience — numeric health guards, graceful wire degradation,
loss-spike rollback, and the fault-injection harness that proves them.

See README.md in this directory for the failure-mode -> detector ->
response -> recovery table.
"""

from repro.resilience import guards  # noqa: F401
from repro.resilience.guards import (  # noqa: F401
    GuardConfig, GuardState, HEALTH_LOSS_NONFINITE, HEALTH_GRADS_NONFINITE,
    HEALTH_OVERFLOW_STORM, HEALTH_GRAD_SPIKE, HEALTH_FL_RAIL,
    HEALTH_IL_RATCHET, HEALTH_DEGRADED, HEALTH_SKIPPED, domain_overflow,
    global_norm, health_flags, init_guard_state, nonfinite_count,
    update_guard, wire_domains, widen_on_trip)
from repro.resilience.inject import (  # noqa: F401
    FaultPlan, apply_grad_faults, corrupt_checkpoint, payload_fault_fn)
