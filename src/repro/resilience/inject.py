"""Deterministic fault injection — every guard is proven by firing it.

A :class:`FaultPlan` is static config compiled INTO the step (the traced
comparison is against the step counter, so the same compiled step runs
faulted and clean steps — no recompile at the fault boundary):

    nan_grads_at       poison the raw local gradients with NaN at one step
    overflow_storm_at  scale the raw gradients by ``storm_scale`` for
                       ``storm_steps`` consecutive steps — every element
                       blows past the wire radix, driving the overflow-
                       rate EWMA over the storm threshold
    wire_flip_at       XOR ``0x40`` into the int8 dispatch-leg payload of
                       the gradient all-reduce at one step (transport
                       corruption: every decoded element gains a large
                       power-of-two offset, which the gradient-norm spike
                       guard catches)

Host-side faults (not part of the compiled step):

    corrupt_checkpoint  truncate or bit-flip a saved ``arrays.npz`` —
                        the digest verification in ``checkpoint.ckpt``
                        must skip the directory on resume
    SIGTERM             ``launch.train --sigterm-at N`` raises the real
                        signal mid-run; the preemption handler must
                        checkpoint and exit 0

All injections are deterministic in the step counter so recovery tests
can assert detection within a bounded number of steps.
"""

from __future__ import annotations

import dataclasses
import os
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Static fault schedule (hashable; lives in the jit closure).

    ``-1`` disables a fault.  Steps are compared against the step counter
    the driver passes into the compiled step.
    """

    nan_grads_at: int = -1
    overflow_storm_at: int = -1
    storm_steps: int = 4
    storm_scale: float = float(2 ** 18)
    wire_flip_at: int = -1

    def any_grad_fault(self) -> bool:
        return self.nan_grads_at >= 0 or self.overflow_storm_at >= 0


def apply_grad_faults(faults: Optional[FaultPlan], grads, step):
    """Inject the scheduled gradient faults into the raw local tree.

    Applied immediately after the backward pass and BEFORE any stats or
    wire encode, so detection sees exactly what a real numeric fault
    would produce.  Identity when ``faults`` is None or schedules no
    gradient fault (static decision: the clean step's jaxpr is
    unchanged).
    """
    if faults is None or not faults.any_grad_fault():
        return grads
    step = jnp.asarray(step, jnp.int32)
    if faults.nan_grads_at >= 0:
        hit = step == faults.nan_grads_at
        grads = jax.tree.map(
            lambda g: jnp.where(hit, jnp.full_like(g, jnp.nan), g), grads)
    if faults.overflow_storm_at >= 0:
        at = faults.overflow_storm_at
        hit = (step >= at) & (step < at + faults.storm_steps)
        scale = jnp.where(hit, jnp.asarray(faults.storm_scale,
                                           jnp.float32), 1.0)
        grads = jax.tree.map(
            lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads)
    return grads


def payload_fault_fn(faults: Optional[FaultPlan], step):
    """The int8 wire-payload corruption hook for
    :func:`repro.dist.collectives.dps_allreduce_mean_tree`.

    Returns ``None`` (no hook, jaxpr unchanged) unless a flip is
    scheduled; otherwise a callable applied to the encoded dispatch-leg
    buffer.  XOR with ``0x40`` flips bit 6 of every payload byte — a
    dense, sign-preserving corruption that decodes to a ±2^(6-FL) offset
    on every element, reliably detectable through the gradient-norm spike
    guard yet finite (the NaN guard must NOT fire: the wire cannot carry
    NaN, which is exactly why transport corruption needs its own
    detector).
    """
    if faults is None or faults.wire_flip_at < 0:
        return None
    step = jnp.asarray(step, jnp.int32)

    def flip(buf):
        return jnp.where(step == faults.wire_flip_at,
                         jax.lax.bitwise_xor(buf, jnp.int8(0x40)), buf)
    return flip


def corrupt_checkpoint(ckpt_dir: str, step: int, mode: str = "truncate"):
    """Corrupt a saved checkpoint in place (host-side fault).

    ``mode="truncate"`` chops ``arrays.npz`` to half its bytes (a torn
    write that survived the atomic rename — e.g. a dying disk);
    ``mode="bitflip"`` flips one bit in one array's payload and rewrites
    the npz as a VALID zip — ``np.load`` succeeds and the zip CRC is
    clean, so only the manifest SHA-256 digests can catch it (silent
    bit-rot, the corruption class checksums-at-rest exist for).
    """
    path = os.path.join(ckpt_dir, f"step_{step:08d}", "arrays.npz")
    if mode == "truncate":
        with open(path, "rb") as f:
            raw = f.read()
        with open(path, "wb") as f:
            f.write(raw[:max(len(raw) // 2, 1)])
    elif mode == "bitflip":
        with np.load(path) as data:
            arrays = {k: np.array(data[k]) for k in data.files}
        key = max(arrays, key=lambda k: arrays[k].nbytes)
        buf = arrays[key].view(np.uint8).reshape(-1)
        buf[len(buf) // 2] ^= 0x10
        np.savez(path, **arrays)
    else:
        raise ValueError(f"unknown corruption mode {mode!r}")
    return path
