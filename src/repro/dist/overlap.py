"""Backward-overlapped bucketed wire: per-bucket compressed all-reduces.

The monolithic tree collective (:func:`~repro.dist.collectives.
dps_allreduce_mean_tree`) encodes every gradient leaf into ONE int8
buffer and ships it through one ``all_to_all``/``all_gather`` pair — so
the whole backward must finish before a single wire byte moves, and the
encode → collective → decode chain sits on the critical path end to end.

This module splits the gradient tree into DDP-style **buckets** and runs
one compressed collective pair per bucket, in the order the backward
materializes gradients (last layer first).  Each bucket's collective
depends only on that bucket's leaves, so:

* on backends with asynchronous collective dispatch, bucket k's wire
  legs overlap bucket k+1's backward compute and decode — the classic
  DDP overlap schedule (the per-bucket dependency chains are
  independent; XLA's latency-hiding scheduler is free to interleave
  them);
* on any backend, each bucket's encode/reduce/decode runs over a small
  working set instead of the whole flattened tree (cache locality), and
  per-bucket :class:`~repro.dist.collectives.GroupLayout`\\ s resolve a
  size-aware quantum per bucket, so grouped-layout padding shrinks from
  "every leaf padded against the global layout" to "every leaf padded
  against its bucket";
* the int8 wire buffers are per-bucket jit temporaries: XLA double
  buffers them (bucket k's buffer is dead — and its allocation reusable
  — by the time bucket k+2 encodes), instead of holding one tree-sized
  wire buffer live across the whole sync.

Determinism and bit-exactness contract (pinned by tests/test_overlap.py):

* ``BucketPlan`` is static Python — buckets are contiguous runs of leaf
  indices, emitted in REVERSE flatten order (the backward's
  materialization order), every leaf exactly once.
* Leg-1 rounding keys are derived from the GLOBAL leaf index
  (``fold_in(k1, g)``), exactly like the monolithic tree collective, so
  dispatch-leg wire bytes and the returned per-leaf stats are
  bit-identical to the monolithic path under both rounding modes.
* Under ``mode="nearest"`` the decoded bucketed mean is **bit-exact**
  vs the monolithic collective: encode/decode are elementwise
  deterministic and the receive-leg sums run in identical rank order,
  so chunk geometry cannot change a single ulp.  Under stochastic
  rounding only the gather leg differs (its bits are element-indexed
  relative to the layout, which is now per-bucket); each leg still
  quantizes with < one grid step of unbiased error.

Every bucket is wrapped in ``wire_bucket`` trace-time tags (see
:mod:`repro.core.tagging`): ``stage="ready"`` on each raw leaf the
moment the bucket is handed to the wire, ``stage="mean"`` on the decoded
mean.  The precision-flow verifier's PF-BUCKET rules
(:mod:`repro.analysis.flow`) prove from the jaxpr that every ready
bucket is encoded exactly once and decoded before the optimizer consumes
it.  ``bucket_ready_tap`` additionally plants a ``stage="grad"``
landmark inside the *backward* itself (a custom-vjp identity on the
parameters), marking where each bucket's gradients materialize — the
readiness point the overlap schedule keys on.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import tagging
from repro.core.fixed_point import (FixedPointFormat, QuantStats,
                                    ROUND_STOCHASTIC)
from repro.dist.collectives import (_aligned_allreduce_mean, _group_layout,
                                    _resolve_backend, _resolve_quantum,
                                    _validate_capacity, _wire_reduce,
                                    group_layout, resolve_domain_format,
                                    wire_decode, wire_encode)

# Default bucket granularity, in elements.  Small enough that a LeNet-
# scale tree still splits into a few buckets (so the schedule is
# exercised at test scale), large enough that per-bucket collective
# launch overhead stays negligible for multi-MiB layers — DDP's 25 MB
# fp32 default is ~6.5M elements; revisit when a single transformer
# block exceeds this by orders of magnitude.
DEFAULT_BUCKET_ELEMS = 1 << 16


@dataclasses.dataclass(frozen=True)
class BucketPlan:
    """Static assignment of gradient-tree leaves to wire buckets.

    ``buckets[b]`` is the ascending, contiguous run of global leaf
    indices (flatten order) that bucket ``b`` syncs; buckets are listed
    in **ready order** — reverse flatten order, because the backward
    materializes the last layer's gradients first.  All fields are
    Python ints: the plan is part of the jit closure, never traced.
    """

    sizes: Tuple[int, ...]              # per-leaf element counts
    buckets: Tuple[Tuple[int, ...], ...]  # ready-order leaf-index runs
    target: int                         # requested elements per bucket

    def __post_init__(self):
        n = len(self.sizes)
        if not self.buckets and n:
            raise ValueError("empty bucket list for a non-empty tree")
        flat = [g for b in self.buckets for g in b]
        if sorted(flat) != list(range(n)):
            raise ValueError(
                f"buckets {self.buckets} are not a partition of the "
                f"{n} leaves: every leaf must appear exactly once")
        stop = n
        for b, run in enumerate(self.buckets):
            if not run:
                raise ValueError(f"bucket {b} is empty")
            if list(run) != list(range(run[0], run[0] + len(run))):
                raise ValueError(
                    f"bucket {b} = {run} is not a contiguous ascending "
                    "run of leaf indices")
            if run[-1] != stop - 1:
                raise ValueError(
                    f"buckets must cover leaves in reverse flatten order "
                    f"(the backward's ready order): bucket {b} ends at "
                    f"leaf {run[-1]}, expected {stop - 1}")
            stop = run[0]

    @property
    def n_buckets(self) -> int:
        return len(self.buckets)

    @property
    def n_leaves(self) -> int:
        return len(self.sizes)

    def bucket_of(self, leaf: int) -> int:
        """The bucket index owning global leaf ``leaf``."""
        for b, run in enumerate(self.buckets):
            if run[0] <= leaf <= run[-1]:
                return b
        raise IndexError(f"leaf {leaf} not in any bucket")

    def bucket_elems(self, b: int) -> int:
        return sum(self.sizes[g] for g in self.buckets[b])


def plan_buckets(sizes, target_elems: int = DEFAULT_BUCKET_ELEMS
                 ) -> BucketPlan:
    """Greedy reverse-order bucketing: walk leaves from the LAST flatten
    index down (the order the backward produces gradients), open a new
    bucket whenever the current one already holds ``target_elems``
    elements.  Every bucket gets at least one leaf, so a single leaf
    larger than the target becomes its own bucket rather than stalling
    the schedule."""
    sizes = tuple(int(s) for s in sizes)
    if target_elems < 1:
        raise ValueError(f"target_elems must be >= 1, got {target_elems}")
    buckets, run, acc = [], [], 0
    for g in range(len(sizes) - 1, -1, -1):
        if run and acc + sizes[g] > target_elems:
            buckets.append(tuple(reversed(run)))
            run, acc = [], 0
        run.append(g)
        acc += sizes[g]
    if run:
        buckets.append(tuple(reversed(run)))
    return BucketPlan(sizes=sizes, buckets=tuple(buckets),
                      target=int(target_elems))


# -------------------------------------------------- gradient-readiness taps

@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3))
def bucket_ready_tap(x, bucket: int, leaf: int, n_buckets: int):
    """Identity on the forward; on the backward, tags the cotangent —
    the leaf's gradient, at the exact point the backward materializes
    it — with a ``wire_bucket`` ``stage="grad"`` landmark.  The tag is
    the :data:`~repro.core.tagging.dps_tag` identity primitive: it
    lowers to nothing, so the tap is free at runtime; it exists so the
    readiness order is *visible in the jaxpr* (the per-bucket collective
    chains hang off these points) and checkable by the flow verifier."""
    return x


def _tap_fwd(x, bucket, leaf, n_buckets):
    return x, None


def _tap_bwd(bucket, leaf, n_buckets, _, cot):
    return (tagging.tag(cot, "wire_bucket", stage="grad", bucket=bucket,
                        leaf=leaf, n=n_buckets),)


bucket_ready_tap.defvjp(_tap_fwd, _tap_bwd)


def tap_params(params, plan: BucketPlan):
    """Wrap every param leaf in its bucket's readiness tap (identity
    forward; gradient-materialization landmark backward).  Apply to the
    parameters entering the loss so each grad leaf is born tagged."""
    leaves, treedef = jax.tree_util.tree_flatten(params)
    if len(leaves) != plan.n_leaves:
        raise ValueError(
            f"param tree has {len(leaves)} leaves but the bucket plan "
            f"covers {plan.n_leaves}")
    out = [bucket_ready_tap(l, plan.bucket_of(g), g, plan.n_buckets)
           for g, l in enumerate(leaves)]
    return jax.tree_util.tree_unflatten(treedef, out)


# ------------------------------------------------- the bucketed collective

def bucketed_allreduce_mean_tree(tree, formats, axis_name, key,
                                 *, mode: str = ROUND_STOCHASTIC,
                                 backend: str = "auto",
                                 domain: str = "wire_grads",
                                 quantum: Optional[int] = None,
                                 plan: Optional[BucketPlan] = None,
                                 target_elems: int = DEFAULT_BUCKET_ELEMS):
    """Bucketed :func:`~repro.dist.collectives.dps_allreduce_mean_tree`:
    one compressed ``all_to_all``/``all_gather`` pair **per bucket**, in
    backward ready order, instead of one monolithic pair for the tree.

    Same contract as the monolithic collective — ``(mean_tree, stats)``,
    every leaf cast back to its own dtype, stats ``[G]``-stacked in leaf
    order for grouped formats or merged in leaf order for a scalar
    format, dispatch-leg stats covering exactly this rank's |tree|
    elements — and bit-identical wire bytes / stats on the dispatch leg
    (leg-1 rounding keys are global-leaf-indexed in both).  Under
    ``mode="nearest"`` the decoded mean is bit-exact vs the monolithic
    path; see the module docstring for the stochastic gather-leg caveat.

    ``plan=None`` derives :func:`plan_buckets` over the leaf sizes with
    ``target_elems``; a caller-supplied plan must match the tree's leaf
    sizes (the qtrain readiness taps and this collective must agree on
    the bucket → leaf mapping).
    """
    fmt = resolve_domain_format(formats, domain)
    _validate_capacity(fmt)
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    if not leaves:
        return tree, QuantStats.zero(fmt.il.shape)
    grouped = fmt.il.ndim != 0
    if grouped and fmt.il.shape[0] != len(leaves):
        raise ValueError(
            f"[G]-shaped tree formats are one ⟨IL, FL⟩ per leaf: the table "
            f"has {fmt.il.shape[0]} rows, the tree {len(leaves)} leaves")
    sizes = tuple(l.size for l in leaves)
    if plan is None:
        plan = plan_buckets(sizes, target_elems)
    elif plan.sizes != sizes:
        raise ValueError(
            f"bucket plan was built for leaf sizes {plan.sizes} but the "
            f"tree has {sizes}; scheduler and collective must share one "
            "plan")
    n = jax.lax.axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    k1, k2 = jax.random.split(jax.random.fold_in(key, idx))
    # leg-2 bits are element-indexed (see _aligned_allreduce_mean), so the
    # grouped gather leg needs a rank-invariant stream — same fold as the
    # monolithic path, further folded per bucket.
    k2s = jax.random.fold_in(key, 0x4C454732)                # "LEG2"
    be = _resolve_backend(backend)
    B = plan.n_buckets

    out = [None] * len(leaves)
    leaf_stats = [None] * len(leaves)

    with tagging.domain(domain):
        for b, run in enumerate(plan.buckets):
            bleaves = [
                tagging.tag(leaves[g], "wire_bucket", stage="ready",
                            bucket=b, leaf=g, n=B)
                for g in run]
            bsizes = tuple(sizes[g] for g in run)
            if grouped:
                lo, hi = run[0], run[-1] + 1
                fmt_b = FixedPointFormat(fmt.il[lo:hi], fmt.fl[lo:hi])
                q = _resolve_quantum(quantum, sum(bsizes), len(run), be)
                layout = group_layout(bsizes, n_chunks=n, quantum=q)

                def encode_leg1(tg_all, mask, _run=run, _bl=bleaves,
                                _fmt=fmt_b, _lay=layout):
                    buf = jnp.zeros((_lay.total,), jnp.int8)
                    for j, g in enumerate(_run):
                        fmt_g = FixedPointFormat(_fmt.il[j], _fmt.fl[j])
                        w, s = wire_encode(
                            _bl[j].reshape(-1), fmt_g,
                            key=jax.random.fold_in(k1, g), mode=mode,
                            backend=be)
                        buf = jax.lax.dynamic_update_slice(
                            buf, w, (_lay.offsets[j],))
                        leaf_stats[g] = s
                    per = [leaf_stats[g] for g in _run]
                    return buf, jax.tree.map(lambda *xs: jnp.stack(xs),
                                             *per)

                mean_al, _ = _aligned_allreduce_mean(
                    None, fmt_b, layout, axis_name, k1,
                    jax.random.fold_in(k2s, b), mode=mode, backend=be,
                    encode_leg1=encode_leg1)
                mean_al = tagging.tag(mean_al, "wire_bucket", stage="mean",
                                      bucket=b, n=B)
                for j, g in enumerate(run):
                    sl = jax.lax.dynamic_slice(
                        mean_al, (layout.offsets[j],), (sizes[g],))
                    out[g] = sl.reshape(leaves[g].shape).astype(
                        leaves[g].dtype)
            else:
                size_b = sum(bsizes)
                chunk, _ = _group_layout(size_b, n)
                offsets = tuple(int(o)
                                for o in np.cumsum((0,) + bsizes[:-1]))
                total = chunk * n
                q = _resolve_quantum(quantum, size_b, 1, be)
                buf = jnp.zeros((total,), jnp.int8)
                for j, g in enumerate(run):
                    w, s = wire_encode(bleaves[j].reshape(-1), fmt,
                                       key=jax.random.fold_in(k1, g),
                                       mode=mode, backend=be)
                    buf = jax.lax.dynamic_update_slice(buf, w, (offsets[j],))
                    leaf_stats[g] = s
                payload = tagging.tag(buf.reshape(n, chunk), "wire_payload",
                                      leg="dispatch")
                wire = jax.lax.all_to_all(payload, axis_name, split_axis=0,
                                          concat_axis=0, tiled=True)
                part = _wire_reduce(wire, fmt, None, backend=be, quantum=q)
                wire2, _ = wire_encode(part, fmt,
                                       key=jax.random.fold_in(k2, b),
                                       mode=mode, compute_stats=False,
                                       backend=be)
                wire2 = tagging.tag(wire2, "wire_payload", leg="gather")
                full = jax.lax.all_gather(wire2, axis_name, axis=0,
                                          tiled=True)
                for j, g in enumerate(run):
                    dec = wire_decode(
                        jax.lax.dynamic_slice(full, (offsets[j],),
                                              (sizes[g],)), fmt)
                    dec = tagging.tag(dec, "wire_bucket", stage="mean",
                                      bucket=b, n=B)
                    out[g] = dec.reshape(leaves[g].shape).astype(
                        leaves[g].dtype)

        # reassemble stats in GLOBAL leaf order — the same stack/merge
        # order as the monolithic tree collective, so the controller
        # stream is bit-identical to the un-bucketed path.
        if grouped:
            stats = jax.tree.map(lambda *xs: jnp.stack(xs), *leaf_stats)
        else:
            stats = leaf_stats[0]
            for s in leaf_stats[1:]:
                stats = stats.merge(s)
        stats = tagging.tag_tree(stats, "wire_stats")

    return jax.tree_util.tree_unflatten(treedef, out), stats
