"""Backward-overlapped bucketed wire: per-bucket compressed all-reduces.

The monolithic tree collective (:func:`~repro.dist.collectives.
dps_allreduce_mean_tree`) encodes every gradient leaf into ONE int8
buffer and ships it through one ``all_to_all``/``all_gather`` pair — so
the whole backward must finish before a single wire byte moves, and the
encode → collective → decode chain sits on the critical path end to end.

This module splits the gradient tree into DDP-style **buckets** and runs
one compressed collective pair per bucket, in the order the backward
materializes gradients (last layer first).  Each bucket's collective
depends only on that bucket's leaves, so:

* on backends with asynchronous collective dispatch, bucket k's wire
  legs overlap bucket k+1's backward compute and decode — the classic
  DDP overlap schedule (the per-bucket dependency chains are
  independent; XLA's latency-hiding scheduler is free to interleave
  them);
* on any backend, each bucket's encode/reduce/decode runs over a small
  working set instead of the whole flattened tree (cache locality), and
  per-bucket :class:`~repro.dist.collectives.GroupLayout`\\ s resolve a
  size-aware quantum per bucket, so grouped-layout padding shrinks from
  "every leaf padded against the global layout" to "every leaf padded
  against its bucket";
* the int8 wire buffers are per-bucket jit temporaries: XLA double
  buffers them (bucket k's buffer is dead — and its allocation reusable
  — by the time bucket k+2 encodes), instead of holding one tree-sized
  wire buffer live across the whole sync.

Determinism and bit-exactness contract (pinned by tests/test_overlap.py):

* ``BucketPlan`` is static Python — buckets are contiguous runs of leaf
  indices, emitted in REVERSE flatten order (the backward's
  materialization order), every leaf exactly once.
* Leg-1 rounding keys are derived from the GLOBAL leaf index
  (``fold_in(k1, g)``), exactly like the monolithic tree collective, so
  dispatch-leg wire bytes and the returned per-leaf stats are
  bit-identical to the monolithic path under both rounding modes.
* Gather-leg rounding bits are ALSO keyed by global leaf index
  (:func:`~repro.dist.collectives._leg2_bits` with the bucket's first
  leaf as ``group_offset``), so the decoded bucketed mean is
  **bit-exact** vs the monolithic collective under BOTH rounding
  modes: encode/decode are elementwise deterministic, the receive-leg
  sums run in identical rank order, and every rounding-bit draw is a
  function of (leaf, element offset) alone — chunk and bucket geometry
  cannot change a single ulp (pinned by
  tests/test_overlap.py::test_bucketed_bitexact_both_modes).

Every bucket is wrapped in ``wire_bucket`` trace-time tags (see
:mod:`repro.core.tagging`): ``stage="ready"`` on each raw leaf the
moment the bucket is handed to the wire, ``stage="mean"`` on the decoded
mean.  The precision-flow verifier's PF-BUCKET rules
(:mod:`repro.analysis.flow`) prove from the jaxpr that every ready
bucket is encoded exactly once and decoded before the optimizer consumes
it.  ``bucket_ready_tap`` additionally plants a ``stage="grad"``
landmark inside the *backward* itself (a custom-vjp identity on the
parameters), marking where each bucket's gradients materialize — the
readiness point the overlap schedule keys on.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import tagging
from repro.core.fixed_point import (FixedPointFormat, QuantStats,
                                    ROUND_STOCHASTIC)
from repro.dist.collectives import (_aligned_allreduce_mean,
                                    _aligned_rs_snap, _decode_aligned,
                                    _encode_aligned, _group_layout,
                                    _leg2_bits, _pad_reshape,
                                    _resolve_backend, _resolve_quantum,
                                    _validate_capacity, _wire_reduce,
                                    group_layout, resolve_domain_format,
                                    wire_decode, wire_encode)

# Default bucket granularity, in elements.  Small enough that a LeNet-
# scale tree still splits into a few buckets (so the schedule is
# exercised at test scale), large enough that per-bucket collective
# launch overhead stays negligible for multi-MiB layers — DDP's 25 MB
# fp32 default is ~6.5M elements; revisit when a single transformer
# block exceeds this by orders of magnitude.
DEFAULT_BUCKET_ELEMS = 1 << 16


@dataclasses.dataclass(frozen=True)
class BucketPlan:
    """Static assignment of gradient-tree leaves to wire buckets.

    ``buckets[b]`` is the ascending, contiguous run of global leaf
    indices (flatten order) that bucket ``b`` syncs; buckets are listed
    in **ready order** — reverse flatten order, because the backward
    materializes the last layer's gradients first.  All fields are
    Python ints: the plan is part of the jit closure, never traced.
    """

    sizes: Tuple[int, ...]              # per-leaf element counts
    buckets: Tuple[Tuple[int, ...], ...]  # ready-order leaf-index runs
    target: int                         # requested elements per bucket

    def __post_init__(self):
        n = len(self.sizes)
        if not self.buckets and n:
            raise ValueError("empty bucket list for a non-empty tree")
        flat = [g for b in self.buckets for g in b]
        if sorted(flat) != list(range(n)):
            raise ValueError(
                f"buckets {self.buckets} are not a partition of the "
                f"{n} leaves: every leaf must appear exactly once")
        stop = n
        for b, run in enumerate(self.buckets):
            if not run:
                raise ValueError(f"bucket {b} is empty")
            if list(run) != list(range(run[0], run[0] + len(run))):
                raise ValueError(
                    f"bucket {b} = {run} is not a contiguous ascending "
                    "run of leaf indices")
            if run[-1] != stop - 1:
                raise ValueError(
                    f"buckets must cover leaves in reverse flatten order "
                    f"(the backward's ready order): bucket {b} ends at "
                    f"leaf {run[-1]}, expected {stop - 1}")
            stop = run[0]

    @property
    def n_buckets(self) -> int:
        return len(self.buckets)

    @property
    def n_leaves(self) -> int:
        return len(self.sizes)

    def bucket_of(self, leaf: int) -> int:
        """The bucket index owning global leaf ``leaf``."""
        for b, run in enumerate(self.buckets):
            if run[0] <= leaf <= run[-1]:
                return b
        raise IndexError(f"leaf {leaf} not in any bucket")

    def bucket_elems(self, b: int) -> int:
        return sum(self.sizes[g] for g in self.buckets[b])


def plan_buckets(sizes, target_elems: int = DEFAULT_BUCKET_ELEMS
                 ) -> BucketPlan:
    """Greedy reverse-order bucketing: walk leaves from the LAST flatten
    index down (the order the backward produces gradients), open a new
    bucket whenever the current one already holds ``target_elems``
    elements.  Every bucket gets at least one leaf, so a single leaf
    larger than the target becomes its own bucket rather than stalling
    the schedule."""
    sizes = tuple(int(s) for s in sizes)
    if target_elems < 1:
        raise ValueError(f"target_elems must be >= 1, got {target_elems}")
    buckets, run, acc = [], [], 0
    for g in range(len(sizes) - 1, -1, -1):
        if run and acc + sizes[g] > target_elems:
            buckets.append(tuple(reversed(run)))
            run, acc = [], 0
        run.append(g)
        acc += sizes[g]
    if run:
        buckets.append(tuple(reversed(run)))
    return BucketPlan(sizes=sizes, buckets=tuple(buckets),
                      target=int(target_elems))


# -------------------------------------------------- gradient-readiness taps

@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3))
def bucket_ready_tap(x, bucket: int, leaf: int, n_buckets: int):
    """Identity on the forward; on the backward, tags the cotangent —
    the leaf's gradient, at the exact point the backward materializes
    it — with a ``wire_bucket`` ``stage="grad"`` landmark.  The tag is
    the :data:`~repro.core.tagging.dps_tag` identity primitive: it
    lowers to nothing, so the tap is free at runtime; it exists so the
    readiness order is *visible in the jaxpr* (the per-bucket collective
    chains hang off these points) and checkable by the flow verifier."""
    return x


def _tap_fwd(x, bucket, leaf, n_buckets):
    return x, None


def _tap_bwd(bucket, leaf, n_buckets, _, cot):
    return (tagging.tag(cot, "wire_bucket", stage="grad", bucket=bucket,
                        leaf=leaf, n=n_buckets),)


bucket_ready_tap.defvjp(_tap_fwd, _tap_bwd)


def tap_params(params, plan: BucketPlan):
    """Wrap every param leaf in its bucket's readiness tap (identity
    forward; gradient-materialization landmark backward).  Apply to the
    parameters entering the loss so each grad leaf is born tagged."""
    leaves, treedef = jax.tree_util.tree_flatten(params)
    if len(leaves) != plan.n_leaves:
        raise ValueError(
            f"param tree has {len(leaves)} leaves but the bucket plan "
            f"covers {plan.n_leaves}")
    out = [bucket_ready_tap(l, plan.bucket_of(g), g, plan.n_buckets)
           for g, l in enumerate(leaves)]
    return jax.tree_util.tree_unflatten(treedef, out)


# ------------------------------------------------- the bucketed collective

def bucketed_allreduce_mean_tree(tree, formats, axis_name, key,
                                 *, mode: str = ROUND_STOCHASTIC,
                                 backend: str = "auto",
                                 domain: str = "wire_grads",
                                 quantum: Optional[int] = None,
                                 plan: Optional[BucketPlan] = None,
                                 target_elems: int = DEFAULT_BUCKET_ELEMS):
    """Bucketed :func:`~repro.dist.collectives.dps_allreduce_mean_tree`:
    one compressed ``all_to_all``/``all_gather`` pair **per bucket**, in
    backward ready order, instead of one monolithic pair for the tree.

    Same contract as the monolithic collective — ``(mean_tree, stats)``,
    every leaf cast back to its own dtype, stats ``[G]``-stacked in leaf
    order for grouped formats or merged in leaf order for a scalar
    format, dispatch-leg stats covering exactly this rank's |tree|
    elements — and bit-identical wire bytes / stats on the dispatch leg
    (leg-1 rounding keys are global-leaf-indexed in both).  The decoded
    mean is bit-exact vs the monolithic path under BOTH rounding modes:
    gather-leg bits are global-leaf-indexed too (see the module
    docstring).

    ``plan=None`` derives :func:`plan_buckets` over the leaf sizes with
    ``target_elems``; a caller-supplied plan must match the tree's leaf
    sizes (the qtrain readiness taps and this collective must agree on
    the bucket → leaf mapping).
    """
    fmt = resolve_domain_format(formats, domain)
    _validate_capacity(fmt)
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    if not leaves:
        return tree, QuantStats.zero(fmt.il.shape)
    grouped = fmt.il.ndim != 0
    if grouped and fmt.il.shape[0] != len(leaves):
        raise ValueError(
            f"[G]-shaped tree formats are one ⟨IL, FL⟩ per leaf: the table "
            f"has {fmt.il.shape[0]} rows, the tree {len(leaves)} leaves")
    sizes = tuple(l.size for l in leaves)
    if plan is None:
        plan = plan_buckets(sizes, target_elems)
    elif plan.sizes != sizes:
        raise ValueError(
            f"bucket plan was built for leaf sizes {plan.sizes} but the "
            f"tree has {sizes}; scheduler and collective must share one "
            "plan")
    n = jax.lax.axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    k1, k2 = jax.random.split(jax.random.fold_in(key, idx))
    del k2  # gather-leg bits come from the rank-invariant k2s stream
    # leg-2 bits are element-indexed and keyed by GLOBAL leaf index
    # (collectives._leg2_bits): the same rank-invariant fold as the
    # monolithic path, with each bucket passing its first leaf's global
    # index as group_offset — so every leaf draws the exact bits the
    # monolithic layout would, and bucketing is invisible under
    # stochastic rounding.
    k2s = jax.random.fold_in(key, 0x4C454732)                # "LEG2"
    be = _resolve_backend(backend)
    B = plan.n_buckets

    out = [None] * len(leaves)
    leaf_stats = [None] * len(leaves)

    with tagging.domain(domain):
        for b, run in enumerate(plan.buckets):
            bleaves = [
                tagging.tag(leaves[g], "wire_bucket", stage="ready",
                            bucket=b, leaf=g, n=B)
                for g in run]
            bsizes = tuple(sizes[g] for g in run)
            if grouped:
                lo, hi = run[0], run[-1] + 1
                fmt_b = FixedPointFormat(fmt.il[lo:hi], fmt.fl[lo:hi])
                q = _resolve_quantum(quantum, sum(bsizes), len(run), be)
                layout = group_layout(bsizes, n_chunks=n, quantum=q)

                def encode_leg1(tg_all, mask, _run=run, _bl=bleaves,
                                _fmt=fmt_b, _lay=layout):
                    buf = jnp.zeros((_lay.total,), jnp.int8)
                    for j, g in enumerate(_run):
                        fmt_g = FixedPointFormat(_fmt.il[j], _fmt.fl[j])
                        w, s = wire_encode(
                            _bl[j].reshape(-1), fmt_g,
                            key=jax.random.fold_in(k1, g), mode=mode,
                            backend=be)
                        buf = jax.lax.dynamic_update_slice(
                            buf, w, (_lay.offsets[j],))
                        leaf_stats[g] = s
                    per = [leaf_stats[g] for g in _run]
                    return buf, jax.tree.map(lambda *xs: jnp.stack(xs),
                                             *per)

                mean_al, _ = _aligned_allreduce_mean(
                    None, fmt_b, layout, axis_name, k1, k2s,
                    mode=mode, backend=be, group_offset=lo,
                    encode_leg1=encode_leg1)
                mean_al = tagging.tag(mean_al, "wire_bucket", stage="mean",
                                      bucket=b, n=B)
                for j, g in enumerate(run):
                    sl = jax.lax.dynamic_slice(
                        mean_al, (layout.offsets[j],), (sizes[g],))
                    out[g] = sl.reshape(leaves[g].shape).astype(
                        leaves[g].dtype)
            else:
                size_b = sum(bsizes)
                chunk, _ = _group_layout(size_b, n)
                offsets = tuple(int(o)
                                for o in np.cumsum((0,) + bsizes[:-1]))
                total = chunk * n
                q = _resolve_quantum(quantum, size_b, 1, be)
                buf = jnp.zeros((total,), jnp.int8)
                for j, g in enumerate(run):
                    w, s = wire_encode(bleaves[j].reshape(-1), fmt,
                                       key=jax.random.fold_in(k1, g),
                                       mode=mode, backend=be)
                    buf = jax.lax.dynamic_update_slice(buf, w, (offsets[j],))
                    leaf_stats[g] = s
                payload = tagging.tag(buf.reshape(n, chunk), "wire_payload",
                                      leg="dispatch")
                wire = jax.lax.all_to_all(payload, axis_name, split_axis=0,
                                          concat_axis=0, tiled=True)
                part = _wire_reduce(wire, fmt, None, backend=be, quantum=q)
                if mode == ROUND_STOCHASTIC:
                    bits2 = jax.lax.dynamic_slice(
                        _pad_reshape(_leg2_bits(k2s, bsizes, run[0]),
                                     total - size_b, (total,)),
                        (idx * chunk,), (chunk,))
                else:
                    bits2 = None
                wire2, _ = wire_encode(part, fmt, bits=bits2,
                                       mode=mode, compute_stats=False,
                                       backend=be)
                wire2 = tagging.tag(wire2, "wire_payload", leg="gather")
                full = jax.lax.all_gather(wire2, axis_name, axis=0,
                                          tiled=True)
                for j, g in enumerate(run):
                    dec = wire_decode(
                        jax.lax.dynamic_slice(full, (offsets[j],),
                                              (sizes[g],)), fmt)
                    dec = tagging.tag(dec, "wire_bucket", stage="mean",
                                      bucket=b, n=B)
                    out[g] = dec.reshape(leaves[g].shape).astype(
                        leaves[g].dtype)

        # reassemble stats in GLOBAL leaf order — the same stack/merge
        # order as the monolithic tree collective, so the controller
        # stream is bit-identical to the un-bucketed path.
        if grouped:
            stats = jax.tree.map(lambda *xs: jnp.stack(xs), *leaf_stats)
        else:
            stats = leaf_stats[0]
            for s in leaf_stats[1:]:
                stats = stats.merge(s)
        stats = tagging.tag_tree(stats, "wire_stats")

    return jax.tree_util.tree_unflatten(treedef, out), stats


# ------------------------------------------- the sharded (ZeRO-1) halves

def _bucket_format(fmt: FixedPointFormat, lo: int, gb: int,
                   grouped: bool) -> FixedPointFormat:
    """Bucket rows ``[lo, lo + gb)`` of a per-leaf ``[G]`` format table —
    or a scalar format broadcast to ``gb`` identical rows, so the aligned
    codec (which resolves per-tile formats from a row table) runs the
    scalar grid unchanged."""
    if grouped:
        return FixedPointFormat(fmt.il[lo:lo + gb], fmt.fl[lo:lo + gb])
    return FixedPointFormat(
        jnp.broadcast_to(jnp.asarray(fmt.il), (gb,)),
        jnp.broadcast_to(jnp.asarray(fmt.fl), (gb,)))


def _check_partitioner(part, n: int, n_leaves: int, fmt: FixedPointFormat,
                       backend: str, what: str):
    be = _resolve_backend(backend)
    if be != part.backend:
        raise ValueError(
            f"{what}: partitioner layout was built for the "
            f"{part.backend!r} codec backend but the collective resolved "
            f"{be!r}; build the GroupAlignedPartitioner with the backend "
            "the step runs")
    if n != part.n_shards:
        raise ValueError(
            f"{what}: partitioner has n_shards={part.n_shards} but the "
            f"mesh axis has {n} ranks")
    if len(part.shapes) != n_leaves:
        raise ValueError(
            f"{what}: partitioner covers {len(part.shapes)} leaves, "
            f"got {n_leaves}")
    if fmt.il.ndim != 0 and fmt.il.shape[0] != n_leaves:
        raise ValueError(
            f"[G]-shaped formats are one ⟨IL, FL⟩ per leaf: the table has "
            f"{fmt.il.shape[0]} rows, the tree {n_leaves} leaves")
    return be


def zero_bucketed_reduce_scatter(tree, formats, axis_name, key, *, part,
                                 mode: str = ROUND_STOCHASTIC,
                                 backend: str = "auto",
                                 domain: str = "wire_grads",
                                 tag_buckets: bool = False):
    """Compressed gradient reduce-scatter onto a group-aligned ZeRO shard.

    The sharded first half of :func:`bucketed_allreduce_mean_tree`: one
    int8 ``all_to_all`` per bucket of ``part`` (a
    :class:`repro.dist.sharding.GroupAlignedPartitioner`), walked in
    backward-ready order (reverse flatten order), each followed by the
    fused decode-reduce of the owned chunk and a LOCAL wire-grid snap
    (:func:`~repro.dist.collectives._aligned_rs_snap`) — the re-encode +
    decode the all-reduce's gather leg would have applied, minus the
    gather.  Rank r therefore holds values bit-identical to its chunk of
    the replicated collective's decoded mean, under both rounding modes
    (every rounding-bit draw is keyed by global leaf index; see the
    module docstring), which is what makes ZeRO + per-layer wire +
    overlap bit-exact with the replicated per-layer step.

    ``formats`` may be scalar (one wire grid everywhere) or per-leaf
    ``[G]``-shaped; stats come back in the same shape, assembled in
    global leaf order exactly like the replicated collectives.

    ``tag_buckets=True`` wraps every bucket in the ``wire_bucket``
    ready/mean trace tags the PF-BUCKET verifier rules consume — turn it
    on exactly when the gradients carry :func:`bucket_ready_tap`
    landmarks (the overlapped step), whose plan must list this
    partitioner's buckets in reverse order.

    Returns ``(gshard fp32 [part.shard_size], stats)``; ``gshard`` is
    this rank's concatenated per-bucket chunks of the snapped mean —
    ``part.shard(part.flatten(mean_tree), rank)`` of the replicated
    result.  Must run inside ``shard_map``; ``key`` may be identical
    across ranks.
    """
    fmt = resolve_domain_format(formats, domain)
    _validate_capacity(fmt)
    leaves, _ = jax.tree_util.tree_flatten(tree)
    n = jax.lax.axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    be = _check_partitioner(part, n, len(leaves), fmt, backend,
                            "zero_bucketed_reduce_scatter")
    grouped = fmt.il.ndim != 0
    k1, _ = jax.random.split(jax.random.fold_in(key, idx))
    k2s = jax.random.fold_in(key, 0x4C454732)                # "LEG2"
    B = part.n_buckets

    chunks = [None] * B
    leaf_stats = [None] * len(leaves)
    with tagging.domain(domain):
        for rb in range(B):          # ready order = reverse flatten order
            pb = B - 1 - rb
            run = part.buckets[pb]
            lay = part.layouts[pb]
            lo, gb = run[0], len(run)
            fmt_b = _bucket_format(fmt, lo, gb, grouped)
            bleaves = [
                tagging.tag(leaves[g], "wire_bucket", stage="ready",
                            bucket=rb, leaf=g, n=B) if tag_buckets
                else leaves[g]
                for g in run]

            def encode_leg1(tg_all, mask, _run=run, _bl=bleaves,
                            _fmt=fmt_b, _lay=lay):
                buf = jnp.zeros((_lay.total,), jnp.int8)
                for j, g in enumerate(_run):
                    fmt_g = FixedPointFormat(_fmt.il[j], _fmt.fl[j])
                    w, s = wire_encode(
                        _bl[j].reshape(-1), fmt_g,
                        key=jax.random.fold_in(k1, g), mode=mode,
                        backend=be)
                    buf = jax.lax.dynamic_update_slice(
                        buf, w, (_lay.offsets[j],))
                    leaf_stats[g] = s
                per = [leaf_stats[g] for g in _run]
                return buf, jax.tree.map(lambda *xs: jnp.stack(xs), *per)

            _, wire2, _, my_tg = _aligned_rs_snap(
                None, fmt_b, lay, axis_name, k1, k2s, mode=mode,
                backend=be, group_offset=lo, encode_leg1=encode_leg1)
            dec = _decode_aligned(wire2, fmt_b, my_tg, lay.quantum)
            if tag_buckets:
                dec = tagging.tag(dec, "wire_bucket", stage="mean",
                                  bucket=rb, n=B)
            chunks[pb] = dec

        # stats in GLOBAL leaf order, same as the replicated collectives
        if grouped:
            stats = jax.tree.map(lambda *xs: jnp.stack(xs), *leaf_stats)
        else:
            stats = leaf_stats[0]
            for s in leaf_stats[1:]:
                stats = stats.merge(s)
        stats = tagging.tag_tree(stats, "wire_stats")

    gshard = chunks[0] if B == 1 else jnp.concatenate(chunks)
    return gshard, stats


def zero_allgather_params(shard: jax.Array, formats, axis_name, key, *,
                          part, mode: str = ROUND_STOCHASTIC,
                          backend: str = "auto",
                          domain: str = "wire_params"):
    """Compressed parameter all-gather from group-aligned ZeRO shards.

    The sharded return leg: each rank encodes its ``[part.shard_size]``
    slice of the updated flat parameter vector bucket-segment by
    bucket-segment with the aligned codec (per-tile formats from the
    bucket's row table, alignment padding masked out of the stats),
    ships ONE concatenated int8 ``all_gather``, and decodes the full
    group-aligned buffer.  ``formats`` may be scalar or per-leaf
    ``[G]``-shaped (``wire_params`` rows in leaf order).

    Returns ``(flat fp32 [part.padded_size], stats)``: ``flat`` is the
    decoded aligned parameter buffer (``part.unflatten`` restores the
    tree), ``stats`` cover this rank's encode of its shard elements
    (``psum_stats`` counts each global element exactly once).  Must run
    inside ``shard_map``; ``key`` may be identical across ranks.
    """
    fmt = resolve_domain_format(formats, domain)
    _validate_capacity(fmt)
    n = jax.lax.axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    be = _check_partitioner(part, n, len(part.shapes), fmt, backend,
                            "zero_allgather_params")
    grouped = fmt.il.ndim != 0
    # gather-leg-style element-indexed bits (rank-invariant stream keyed
    # by global leaf index): rank r's draws depend only on which elements
    # it owns, not on r itself
    kps = jax.random.fold_in(key, 0x57504C47)                # "WPLG"

    wire_chunks, stat_rows = [], []
    with tagging.domain(domain):
        for pb in range(part.n_buckets):
            run = part.buckets[pb]
            lay = part.layouts[pb]
            lo, gb = run[0], len(run)
            fmt_b = _bucket_format(fmt, lo, gb, grouped)
            tg_all = jnp.asarray(lay.tile_groups())
            tpc = lay.chunk // lay.quantum
            my_tg = jax.lax.dynamic_slice(tg_all, (idx * tpc,), (tpc,))
            my_mask = jax.lax.dynamic_slice(
                jnp.asarray(lay.mask()), (idx * lay.chunk,), (lay.chunk,))
            soff = part.shard_offset(pb)
            seg = jax.lax.slice(shard, (soff,), (soff + lay.chunk,))
            if mode == ROUND_STOCHASTIC:
                bits = jax.lax.dynamic_slice(
                    lay.align(_leg2_bits(kps, lay.group_sizes, lo)),
                    (idx * lay.chunk,), (lay.chunk,))
            else:
                bits = None
            w, s = _encode_aligned(seg, fmt_b, my_tg, my_mask, bits=bits,
                                   mode=mode, backend=be,
                                   quantum=lay.quantum)
            wire_chunks.append(w)
            stat_rows.append(s)

        wire = (wire_chunks[0] if len(wire_chunks) == 1
                else jnp.concatenate(wire_chunks))
        wire = tagging.tag(wire, "wire_payload", leg="gather")
        gathered = jax.lax.all_gather(wire, axis_name, axis=0, tiled=True)
        gathered = gathered.reshape(n, part.shard_size)

        segs = []
        for pb in range(part.n_buckets):
            run = part.buckets[pb]
            lay = part.layouts[pb]
            lo, gb = run[0], len(run)
            soff = part.shard_offset(pb)
            seg_full = gathered[:, soff:soff + lay.chunk].reshape(
                n * lay.chunk)
            segs.append(_decode_aligned(
                seg_full, _bucket_format(fmt, lo, gb, grouped),
                jnp.asarray(lay.tile_groups()), lay.quantum))
        flat = segs[0] if len(segs) == 1 else jnp.concatenate(segs)

        rows = (stat_rows[0] if len(stat_rows) == 1
                else jax.tree.map(lambda *xs: jnp.concatenate(xs),
                                  *stat_rows))
        if grouped:
            stats = rows
        else:
            # scalar wire_params domain: collapse the per-leaf rows
            stats = QuantStats(
                count=rows.count.sum(), nonzero=rows.nonzero.sum(),
                overflow=rows.overflow.sum(),
                abs_err_sum=rows.abs_err_sum.sum(),
                rel_err_sum=rows.rel_err_sum.sum(),
                abs_sum=rows.abs_sum.sum(), max_abs=rows.max_abs.max())
        stats = tagging.tag_tree(stats, "wire_stats")
    return flat, stats
