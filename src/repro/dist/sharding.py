"""Logical-axis sharding: rules, mesh context, and constraint helpers.

Model code never names mesh axes.  It names *logical* axes — "batch",
"tp", "fsdp", "expert", ... — and this module resolves them against the
mesh the current run built (or resolves them to nothing on one device).
Resolution applies the **divisibility fallback**: a logical axis binds a
mesh axis only when the tensor dimension divides the mesh-axis size;
otherwise the dimension stays replicated.  A mesh axis is never used for
two dimensions of the same tensor.

The binding between a concrete :class:`jax.sharding.Mesh` and a
:class:`LogicalRules` instance is a dynamic context (:func:`axis_rules`):

    with mesh, axis_rules(mesh, LogicalRules()):
        jitted = jax.jit(step, in_shardings=..., out_shardings=...)
        ...

Inside the context, :func:`logical_constraint` emits
``with_sharding_constraint``; outside any context it is the identity, so
single-device smoke paths trace the exact same model code.
"""

from __future__ import annotations

import contextlib
import dataclasses
import math
import threading
from typing import Any, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# One candidate assignment: a single mesh axis or a tuple of mesh axes that
# shard a dimension jointly (e.g. batch over ("pod", "data")).
Axis = Union[str, Tuple[str, ...]]

# Logical-axis -> mesh-axis candidates, tried in order.  First candidate
# whose axes (a) all exist in the mesh, (b) are not already taken by another
# dimension of the same tensor, and (c) whose combined size divides the
# tensor dimension, wins.  Logical names absent from this table ("embed",
# "seq", "kv_seq", "head_dim", "layers", ...) always replicate.
DEFAULT_RULES: Tuple[Tuple[str, Tuple[Axis, ...]], ...] = (
    ("batch", (("pod", "data"), "data")),
    ("batch2d", (("pod", "data", "model"), ("data", "model"))),
    ("fsdp", (("pod", "data"), "data")),
    ("tp", ("model",)),
    ("tp_seq", ("model",)),
    ("heads", ("model",)),
    ("kv", ("model",)),
    ("expert", ("model",)),
    ("vocab_out", ("model",)),
)


def _axis_sizes(mesh) -> dict:
    # not mesh.shape: sharding-rules tests duck-type the mesh with only
    # ``axis_names`` and ``devices.shape``
    return dict(zip(mesh.axis_names, mesh.devices.shape))


@dataclasses.dataclass(frozen=True)
class LogicalRules:
    """Logical-axis resolution table with divisibility fallback."""

    rules: Tuple[Tuple[str, Tuple[Axis, ...]], ...] = DEFAULT_RULES

    def candidates(self, logical: str) -> Tuple[Axis, ...]:
        for name, cands in self.rules:
            if name == logical:
                return cands
        return ()

    def _resolve(self, logical: Optional[str], dim: int, sizes: dict,
                 taken: set) -> Optional[Axis]:
        if logical is None:
            return None
        for cand in self.candidates(logical):
            axes = (cand,) if isinstance(cand, str) else tuple(cand)
            if any(a not in sizes or a in taken for a in axes):
                continue
            n = math.prod(sizes[a] for a in axes)
            if n <= 1 or dim % n:
                continue
            taken.update(axes)
            return cand
        return None

    def resolve_dim(self, logical: Optional[str], dim: int, mesh,
                    taken: set) -> Optional[Axis]:
        """Resolve one tensor dimension to a mesh axis (or ``None``).

        ``taken`` is mutated: axes consumed here are unavailable for the
        remaining dimensions of the same tensor.
        """
        return self._resolve(logical, dim, _axis_sizes(mesh), taken)

    def spec(self, logical: Sequence[Optional[str]], shape: Sequence[int],
             mesh) -> P:
        """PartitionSpec for a whole tensor (one shared ``taken`` set)."""
        assert len(logical) == len(shape), (tuple(logical), tuple(shape))
        sizes, taken = _axis_sizes(mesh), set()
        return P(*[self._resolve(name, dim, sizes, taken)
                   for name, dim in zip(logical, shape)])


def _is_axes_leaf(x) -> bool:
    """A logical-axes annotation: None or a tuple of str/None entries."""
    return x is None or (isinstance(x, tuple)
                         and all(e is None or isinstance(e, str) for e in x))


def tree_specs(logical, struct, mesh: Mesh, rules: LogicalRules):
    """Resolve a pytree of logical-axes tuples against ``struct``'s shapes.

    ``logical`` mirrors ``struct`` with each array leaf replaced by its
    logical-axes tuple (see ``models.common.logical_tree``).  Returns the
    same tree of :class:`NamedSharding`.
    """
    return jax.tree.map(
        lambda log, s: NamedSharding(mesh, rules.spec(log, s.shape, mesh)),
        logical, struct, is_leaf=_is_axes_leaf)


# ---------------------------------------------------------------------------
# ZeRO-1 parameter partitioning.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ZeroPartitioner:
    """Padded 1-D layout that shards *any* param tree across N ranks.

    :class:`LogicalRules` can only bind "fsdp" to the data axis when a
    tensor dimension divides the mesh-axis size — everything else stays
    replicated.  ZeRO-1 sidesteps the divisibility gap entirely: the whole
    tree is flattened (leaf order = ``tree_flatten`` order) into one fp32
    vector, zero-padded to a multiple of ``n_shards``, and sharded as equal
    contiguous slices.  Non-divisible leaves, scalars, and leaves smaller
    than the axis all shard, because slice boundaries ignore leaf
    boundaries.

    The layout is the contract between the three ZeRO pieces:

    * ``flatten(grads)`` feeds
      :func:`repro.dist.collectives.dps_reduce_scatter_mean`, whose
      per-rank chunk is exactly ``shard(flatten(x), rank)`` of the mean;
    * the optimizer steps one ``[shard_size]`` slice per rank
      (``SGD.update_shard`` / ``AdamW.update_shard``);
    * :func:`repro.dist.collectives.dps_allgather_params` (or a plain
      ``all_gather``) reassembles the flat vector, and ``unflatten``
      restores shapes and dtypes.

    Padding is always zero: zero gradients and zero parameters produce zero
    optimizer updates, so the pad region stays zero for SGD/AdamW and
    round-trips exactly.
    """

    treedef: Any
    shapes: Tuple[Tuple[int, ...], ...]
    dtypes: Tuple[Any, ...]
    n_shards: int

    @staticmethod
    def create(tree, n_shards: int) -> "ZeroPartitioner":
        """Build from a concrete or abstract (ShapeDtypeStruct) tree."""
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        if not leaves:
            raise ValueError("ZeroPartitioner needs a non-empty tree")
        return ZeroPartitioner(
            treedef=treedef,
            shapes=tuple(tuple(l.shape) for l in leaves),
            dtypes=tuple(l.dtype for l in leaves),
            n_shards=int(n_shards))

    @property
    def size(self) -> int:
        """Unpadded element count of the flattened tree."""
        return sum(math.prod(s) for s in self.shapes)

    @property
    def shard_size(self) -> int:
        return -(-self.size // self.n_shards)

    @property
    def padded_size(self) -> int:
        return self.shard_size * self.n_shards

    def flatten(self, tree) -> jax.Array:
        """Tree -> fp32 ``[padded_size]`` (zero-padded, tree_flatten order)."""
        leaves = jax.tree_util.tree_leaves(tree)
        flat = jnp.concatenate(
            [l.reshape(-1).astype(jnp.float32) for l in leaves])
        return jnp.pad(flat, (0, self.padded_size - self.size))

    def unflatten(self, flat: jax.Array):
        """``[padded_size]`` (or ``[size]``) -> tree with original
        shapes/dtypes; the pad region is dropped."""
        out, off = [], 0
        for shape, dtype in zip(self.shapes, self.dtypes):
            n = math.prod(shape)
            out.append(flat[off:off + n].reshape(shape).astype(dtype))
            off += n
        return jax.tree_util.tree_unflatten(self.treedef, out)

    def shard(self, flat: jax.Array, index) -> jax.Array:
        """Rank ``index``'s ``[shard_size]`` slice (``index`` may be traced,
        e.g. ``lax.axis_index`` inside ``shard_map``)."""
        return jax.lax.dynamic_slice(
            flat, (index * self.shard_size,), (self.shard_size,))


@dataclasses.dataclass(frozen=True)
class GroupAlignedPartitioner:
    """ZeRO-1 flat layout whose leaf slots are padded to the wire quantum.

    :class:`ZeroPartitioner` packs leaves back to back, so rank-chunk
    boundaries straddle leaves and the flat vector cannot carry per-leaf
    ⟨IL, FL⟩ wire formats — the reason per-layer wire and the overlapped
    bucketed pipeline used to be rejected under ZeRO.  This layout keeps
    the same contract (flatten / shard / optimizer-steps-a-slice /
    unflatten, zero padding everywhere) but reuses
    :class:`repro.dist.collectives.GroupLayout`'s alignment arithmetic:

    * leaves are grouped into ``buckets`` — contiguous runs of leaf
      indices in ``tree_flatten`` order (one run covering every leaf when
      the overlapped pipeline is off);
    * within a bucket every leaf slot is padded up to the bucket's wire
      ``quantum``, and the bucket total is padded so each of the
      ``n_shards`` rank chunks is itself a whole number of quanta
      (``GroupLayout.chunk``).  Chunk boundaries therefore never straddle
      a group, and each aligned tile maps to exactly one leaf
      (``GroupLayout.tile_groups``);
    * a rank's shard is the concatenation of its per-bucket chunks, so
      the sharded half-collectives can run the grouped aligned codec
      bucket-by-bucket in backward-ready order while the optimizer still
      sees one flat ``[shard_size]`` slice.

    Every field is a static Python value, so the partitioner is safe to
    build from abstract trees (``jax.eval_shape``) and to close over in
    jitted code.  Padding is zero and stays zero through SGD/AdamW (zero
    grad + zero param -> zero update), exactly as in
    :class:`ZeroPartitioner`.
    """

    treedef: Any
    shapes: Tuple[Tuple[int, ...], ...]
    dtypes: Tuple[Any, ...]
    n_shards: int
    backend: str
    buckets: Tuple[Tuple[int, ...], ...]
    layouts: Tuple[Any, ...]   # one collectives.GroupLayout per bucket

    @staticmethod
    def create(tree, n_shards: int, *, backend: str = "auto",
               quantum: Optional[int] = None,
               buckets: Optional[Sequence[Sequence[int]]] = None
               ) -> "GroupAlignedPartitioner":
        """Build from a concrete or abstract tree.

        ``buckets`` is a sequence of contiguous leaf-index runs (any
        order; stored sorted into flatten order) — pass the runs of a
        :class:`repro.dist.overlap.BucketPlan` to align the layout with
        the overlapped pipeline, or leave ``None`` for one bucket over
        the whole tree.  Each bucket resolves its own quantum (same
        derivation as the bucketed collective), unless ``quantum`` pins
        one globally.
        """
        from repro.dist.collectives import (_resolve_backend,
                                            _resolve_quantum, group_layout)
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        if not leaves:
            raise ValueError("GroupAlignedPartitioner needs a non-empty tree")
        sizes = [math.prod(tuple(l.shape)) or 1 for l in leaves]
        if buckets is None:
            runs = (tuple(range(len(leaves))),)
        else:
            runs = tuple(tuple(int(i) for i in r) for r in
                         sorted(buckets, key=lambda r: r[0]))
            flat_idx = [i for r in runs for i in r]
            if flat_idx != list(range(len(leaves))):
                raise ValueError(
                    "buckets must partition the leaves into contiguous "
                    f"ascending runs, got {runs}")
        be = _resolve_backend(backend)
        layouts = []
        for run in runs:
            b_sizes = tuple(sizes[i] for i in run)
            q = _resolve_quantum(quantum, sum(b_sizes), len(run), be)
            layouts.append(group_layout(b_sizes, n_chunks=n_shards,
                                        quantum=q))
        return GroupAlignedPartitioner(
            treedef=treedef,
            shapes=tuple(tuple(l.shape) for l in leaves),
            dtypes=tuple(l.dtype for l in leaves),
            n_shards=int(n_shards), backend=be,
            buckets=runs, layouts=tuple(layouts))

    # --- static geometry -------------------------------------------------

    @property
    def n_buckets(self) -> int:
        return len(self.buckets)

    @property
    def size(self) -> int:
        """Unpadded element count of the flattened tree."""
        return sum(math.prod(s) or 1 for s in self.shapes)

    @property
    def padded_size(self) -> int:
        """Flat-buffer length: sum of aligned bucket totals."""
        return sum(l.total for l in self.layouts)

    @property
    def shard_size(self) -> int:
        """Per-rank slice length: sum of aligned bucket chunks."""
        return sum(l.chunk for l in self.layouts)

    def bucket_offset(self, b: int) -> int:
        """Flat-buffer offset of bucket ``b``."""
        return sum(l.total for l in self.layouts[:b])

    def shard_offset(self, b: int) -> int:
        """Offset of bucket ``b``'s chunk within a rank's shard."""
        return sum(l.chunk for l in self.layouts[:b])

    def leaf_range(self, b: int) -> Tuple[int, int]:
        """Global leaf-index range ``[lo, hi)`` of bucket ``b`` — the
        slice of a per-leaf ``[G]`` format table this bucket consumes."""
        run = self.buckets[b]
        return run[0], run[-1] + 1

    def leaf_offset(self, g: int) -> int:
        """Flat-buffer offset of leaf ``g``'s aligned slot."""
        for b, run in enumerate(self.buckets):
            if g in run:
                return self.bucket_offset(b) + self.layouts[b].offsets[
                    run.index(g)]
        raise IndexError(g)

    # --- layout transforms ----------------------------------------------

    def flatten(self, tree) -> jax.Array:
        """Tree -> fp32 ``[padded_size]``: each leaf in its aligned slot,
        zeros everywhere else (slot tails and chunk pads)."""
        leaves = jax.tree_util.tree_leaves(tree)
        flat = jnp.zeros((self.padded_size,), jnp.float32)
        for b, run in enumerate(self.buckets):
            off = self.bucket_offset(b)
            lay = self.layouts[b]
            for j, g in enumerate(run):
                leaf = leaves[g].reshape(-1).astype(jnp.float32)
                flat = jax.lax.dynamic_update_slice(
                    flat, leaf, (off + lay.offsets[j],))
        return flat

    def unflatten(self, flat: jax.Array):
        """``[padded_size]`` -> tree with original shapes/dtypes; slot
        tails and chunk pads are dropped."""
        out = []
        for b, run in enumerate(self.buckets):
            off = self.bucket_offset(b)
            lay = self.layouts[b]
            for j, g in enumerate(run):
                n = math.prod(self.shapes[g]) or 1
                o = off + lay.offsets[j]
                out.append(flat[o:o + n].reshape(self.shapes[g])
                           .astype(self.dtypes[g]))
        return jax.tree_util.tree_unflatten(self.treedef, out)

    def shard(self, flat: jax.Array, index) -> jax.Array:
        """Rank ``index``'s ``[shard_size]`` slice: the concatenation of
        its per-bucket chunks (``index`` may be traced)."""
        parts = []
        for b, lay in enumerate(self.layouts):
            off = self.bucket_offset(b)
            parts.append(jax.lax.dynamic_slice(
                flat, (off + index * lay.chunk,), (lay.chunk,)))
        return parts[0] if len(parts) == 1 else jnp.concatenate(parts)

    def assemble(self, gathered: jax.Array) -> jax.Array:
        """``all_gather`` of shards (``[n_shards, shard_size]``) -> the
        flat ``[padded_size]`` buffer (inverse of per-rank :meth:`shard`)."""
        segs = []
        for b, lay in enumerate(self.layouts):
            s = self.shard_offset(b)
            segs.append(gathered[:, s:s + lay.chunk].reshape(
                self.n_shards * lay.chunk))
        return segs[0] if len(segs) == 1 else jnp.concatenate(segs)


# ---------------------------------------------------------------------------
# Mesh + rules context.
# ---------------------------------------------------------------------------

class _Ctx(threading.local):
    def __init__(self):
        self.stack = []


_CTX = _Ctx()


@contextlib.contextmanager
def axis_rules(mesh: Mesh, rules: LogicalRules):
    """Bind ``(mesh, rules)`` for :func:`logical_constraint` et al."""
    _CTX.stack.append((mesh, rules))
    try:
        yield mesh, rules
    finally:
        _CTX.stack.pop()


def current_mesh_rules() -> Tuple[Optional[Mesh], Optional[LogicalRules]]:
    """The innermost ``axis_rules`` binding, or ``(None, None)``."""
    if _CTX.stack:
        return _CTX.stack[-1]
    return None, None


def model_axis_size() -> int:
    """Size of the mesh's "model" axis in the current context (1 outside)."""
    mesh, _ = current_mesh_rules()
    if mesh is None:
        return 1
    return int(_axis_sizes(mesh).get("model", 1))


def logical_constraint(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """``with_sharding_constraint`` via logical names; identity off-mesh."""
    mesh, rules = current_mesh_rules()
    if mesh is None:
        return x
    spec = rules.spec(logical, x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
