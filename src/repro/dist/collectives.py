"""Compressed collectives: the paper's quantizer on the gradient wire.

A fixed-point format ⟨IL, FL⟩ with IL + FL ≤ 8 puts every grid integer in
[-128, 127], so a quantized payload travels the interconnect as **int8**
instead of fp32 — 4× fewer bytes on the wire for the two collective legs
of an all-reduce.  Stochastic rounding (Gupta et al., 2015) keeps both
legs unbiased, and the same :class:`QuantStats` the DPS controllers
consume fall out of the encode for free, so a training loop can feed each
leg's wire-quantization error straight into that leg's dedicated *wire
precision domain* (``wire_grads`` / ``wire_params`` in the
:class:`~repro.core.dps.PrecisionPlan` registry; see
``QuantConfig.grad_allreduce_bits`` in :mod:`repro.core.qtrain`).  Every
collective below takes the whole registry-format mapping and resolves its
own leg's ⟨IL, FL⟩ (:func:`resolve_domain_format`).

Codec backends: on TPU the encode runs as the fused Pallas
``dps_quant_wire`` kernel (one read-x/write-wire HBM pass, stats ride in
SMEM); elsewhere it runs as plain jnp ops.  ``backend="auto"`` picks per
``jax.default_backend()``; both backends are bit-exact against
``repro.kernels.ref.dps_quant_wire_ref``.

Formats may be **per-group**: an ⟨IL, FL⟩ of shape ``[G]`` splits the
flattened tensor into G contiguous chunks (per-layer groups — the grads
DPS controller state is the natural producer) and returns ``[G]``-shaped
:class:`QuantStats`.  A scalar format (the default) is the global case.

All collective functions here are written for ``shard_map`` bodies: they
take an ``axis_name`` and use raw ``lax`` collectives.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fixed_point import (FixedPointFormat, QuantStats,
                                    ROUND_NEAREST, ROUND_STOCHASTIC, exp2_int,
                                    wire_quantize)

# int8 wire capacity: IL + FL beyond this saturates grid integers.
WIRE_BITS = 8


def wire_format(fmt: FixedPointFormat, wire_bits: int = WIRE_BITS
                ) -> FixedPointFormat:
    """Derive a wire ⟨IL, FL⟩ from a (wider) compute format.

    Keeps the radix position — IL, the overflow guard — and spends the
    remaining ``wire_bits`` on fraction: ``⟨min(IL, wire_bits - 1),
    wire_bits - IL⟩``.

    NOTE: the training loop no longer derives its wire formats this way —
    each wire leg's ⟨IL, FL⟩ now comes from a dedicated precision domain
    (``wire_grads`` / ``wire_params``) in the :class:`PrecisionPlan`
    registry, because a controller that moves IL in response to wire
    overflow moves the wire radix with it, and under hair-trigger
    ``r_max`` that ratchet destabilizes training (dist/README.md).  The
    helper remains for deriving *static* wire formats in tools and tests.
    """
    if not 2 <= wire_bits <= WIRE_BITS:
        raise ValueError(f"wire_bits must be in [2, {WIRE_BITS}] for an int8 "
                         f"payload, got {wire_bits}")
    il = jnp.clip(jnp.asarray(fmt.il, jnp.int32), 1, wire_bits - 1)
    return FixedPointFormat(il, (wire_bits - il).astype(jnp.int32))


def resolve_domain_format(formats, domain: str) -> FixedPointFormat:
    """One collective leg's ⟨IL, FL⟩ from a precision-domain registry.

    ``formats`` is either the ``{domain: FixedPointFormat}`` mapping
    produced by ``qtrain.bundle_formats`` — the leg picks out its own
    domain — or a bare :class:`FixedPointFormat`, used as-is (the
    pre-registry calling convention, kept for benchmarks and direct
    codec tests).
    """
    if isinstance(formats, FixedPointFormat):
        return formats
    try:
        fmt = formats[domain]
    except (KeyError, IndexError, TypeError):
        have = sorted(formats) if hasattr(formats, "keys") else type(formats)
        raise KeyError(
            f"no {domain!r} format in the registry mapping (have {have}); "
            "declare the wire domain in the PrecisionPlan or pass a "
            "FixedPointFormat directly") from None
    if not isinstance(fmt, FixedPointFormat):
        raise TypeError(f"registry entry {domain!r} is {type(fmt)}, "
                        "expected FixedPointFormat")
    return fmt


def _concrete_ilfl(fmt: FixedPointFormat):
    """(il, fl) as numpy when statically known, else None (traced)."""
    if isinstance(fmt.il, jax.core.Tracer) or isinstance(fmt.fl, jax.core.Tracer):
        return None
    return np.asarray(fmt.il), np.asarray(fmt.fl)


def _validate_capacity(fmt: FixedPointFormat):
    """Raise eagerly on statically over-wide formats (IL + FL > 8).

    Traced formats can't be rejected at trace time; for those the encode
    saturates at ±127 and counts the saturated elements into
    ``QuantStats.overflow`` so the controller sees the wire clipping.
    """
    conc = _concrete_ilfl(fmt)
    if conc is None:
        return
    il, fl = conc
    total = il.astype(np.int64) + fl.astype(np.int64)
    if np.any(total > WIRE_BITS):
        raise ValueError(
            f"⟨IL, FL⟩ = ⟨{il}, {fl}⟩ exceeds the int8 wire: IL + FL = "
            f"{total} > {WIRE_BITS}.  Grid integers would saturate at ±127; "
            f"derive a wire format with wire_format(fmt) instead.")


def _group_layout(size: int, groups: int) -> Tuple[int, int]:
    """(chunk, pad) splitting ``size`` elements into ``groups`` chunks."""
    chunk = -(-size // groups)
    return chunk, groups * chunk - size


def _resolve_backend(backend: str) -> str:
    if backend == "auto":
        return "kernel" if jax.default_backend() == "tpu" else "jnp"
    if backend not in ("kernel", "jnp"):
        raise ValueError(f"unknown wire codec backend {backend!r}; "
                         "expected 'auto', 'kernel' or 'jnp'")
    return backend


def wire_encode(x: jax.Array, fmt: FixedPointFormat, *,
                key: Optional[jax.Array] = None,
                bits: Optional[jax.Array] = None,
                mode: str = ROUND_STOCHASTIC,
                compute_stats: bool = True,
                backend: str = "auto",
                ) -> Tuple[jax.Array, Optional[QuantStats]]:
    """Quantize ``x`` onto the ⟨IL, FL⟩ grid and emit int8 grid integers.

    Statically over-wide formats (IL + FL > 8) raise eagerly; traced
    formats saturate at ±127 with the saturated count folded into
    ``stats.overflow``.  ``bits`` (uint32, x.size elements) supplies the
    rounding noise deterministically; ``key`` draws it.

    Per-group formats (``fmt.il.shape == [G]``): the flattened ``x`` is
    split into G contiguous chunks of ``ceil(x.size / G)`` elements (the
    last chunk may be short) and chunk g is encoded with ⟨IL[g], FL[g]⟩;
    stats come back with shape ``[G]``.  The round-trip is element-exact
    with G independent global-format calls on the chunks (given the same
    ``bits`` slices).  Grouped encode always uses the jnp codec — the
    fused kernel takes one SMEM-prefetched format per call.

    ``backend``: "auto" (fused Pallas kernel on TPU, jnp elsewhere),
    "kernel", or "jnp".  Both are bit-exact against
    ``repro.kernels.ref.dps_quant_wire_ref``.

    Returns ``(wire int8 with x's shape, stats)``.
    """
    if mode not in (ROUND_STOCHASTIC, ROUND_NEAREST):
        # reject here so both backends fail identically (the kernel path
        # folds mode into a boolean and would otherwise silently round
        # to nearest)
        raise ValueError(f"unknown rounding mode {mode!r}")
    _validate_capacity(fmt)
    if fmt.il.ndim == 0:
        if _resolve_backend(backend) == "kernel":
            from repro.kernels import ops
            stochastic = mode == ROUND_STOCHASTIC
            b = bits.reshape(-1) if bits is not None else None
            wire, stats = ops.dps_quantize_wire(x, fmt, key=key, bits=b,
                                                stochastic=stochastic)
            return wire, (stats if compute_stats else None)
        if bits is not None:
            bits = bits.reshape(x.shape)
        return wire_quantize(x, fmt, mode=mode, key=key, bits=bits,
                             compute_stats=compute_stats)

    # --- per-group path (jnp codec) ---
    if fmt.il.ndim != 1:
        raise ValueError(f"per-group formats must be rank-1 [G], got shape "
                         f"{fmt.il.shape}")
    groups = fmt.il.shape[0]
    n = x.size
    chunk, pad = _group_layout(n, groups)
    if bits is None and mode == ROUND_STOCHASTIC:
        if key is None:
            raise ValueError("stochastic rounding needs `bits` or `key`")
        bits = jax.random.bits(key, shape=(n,), dtype=jnp.uint32)
    xg = jnp.pad(x.reshape(-1), (0, pad)).reshape(groups, chunk)
    bg = (jnp.pad(bits.reshape(-1), (0, pad)).reshape(groups, chunk)
          if bits is not None else None)
    mask = jnp.pad(jnp.ones((n,), jnp.float32), (0, pad)).reshape(groups, chunk)
    wire, stats = wire_quantize(xg, fmt, mode=mode, bits=bg,
                                compute_stats=compute_stats, mask=mask)
    return wire.reshape(-1)[:n].reshape(x.shape), stats


def wire_decode(wire: jax.Array, fmt: FixedPointFormat,
                dtype=jnp.float32) -> jax.Array:
    """Grid integers (int8) back to values: ``wire * 2^-FL``.

    Accepts the same scalar or ``[G]``-shaped formats as
    :func:`wire_encode` (grouped decode uses the matching contiguous-chunk
    layout over the flattened payload).
    """
    if fmt.il.ndim == 0:
        return (wire.astype(jnp.float32) * exp2_int(-fmt.fl)).astype(dtype)
    groups = fmt.il.shape[0]
    n = wire.size
    chunk, pad = _group_layout(n, groups)
    wg = jnp.pad(wire.reshape(-1), (0, pad)).reshape(groups, chunk)
    dec = wg.astype(jnp.float32) * exp2_int(-fmt.fl)[:, None]
    return dec.reshape(-1)[:n].reshape(wire.shape).astype(dtype)


def psum_stats(stats: QuantStats, axis_name) -> QuantStats:
    """Combine per-rank :class:`QuantStats` across ``axis_name``.

    Sums psum; ``max_abs`` pmaxes — matching ``QuantStats.merge``."""
    summed = jax.lax.psum((stats.count, stats.nonzero, stats.overflow,
                           stats.abs_err_sum, stats.rel_err_sum,
                           stats.abs_sum), axis_name)
    return QuantStats(*summed, max_abs=jax.lax.pmax(stats.max_abs, axis_name))


def dps_allreduce_mean(x: jax.Array, formats, axis_name,
                       key: jax.Array, *, mode: str = ROUND_STOCHASTIC,
                       backend: str = "auto", domain: str = "wire_grads",
                       ) -> Tuple[jax.Array, QuantStats]:
    """Mean of per-rank ``x`` over ``axis_name`` with an int8 wire format.

    Reduce-scatter / all-gather decomposition, both legs compressed:

      1. each rank quantizes its full local tensor to the ⟨IL, FL⟩ grid and
         ships int8 grid integers through a tiled ``all_to_all`` — rank j
         ends up owning every rank's j-th chunk (reduce-scatter leg);
      2. the owner sums its chunks in fp32, divides by the axis size,
         re-quantizes the mean chunk and ``all_gather``s int8 back out.

    Total wire bytes ≈ 2·|x|·1 B vs 2·|x|·4 B for an fp32 ring all-reduce.
    With stochastic rounding each leg's error is < one grid step (2^-FL),
    so the result is within two grid steps of the exact mean and unbiased.

    ``backend`` selects the wire codec (see :func:`wire_encode`);
    ``formats``/``domain`` resolve the leg's ⟨IL, FL⟩ out of a
    precision-domain registry mapping (:func:`resolve_domain_format`).

    Returns ``(mean, stats)``; ``stats`` describe this rank's dispatch-leg
    quantization of the |x| local elements (so ``psum_stats(stats, axis)``
    counts each global element exactly once) and belong to the wire
    domain's controller.  Must run inside ``shard_map``; ``key`` may be
    identical across ranks (it is decorrelated with ``axis_index`` here).
    """
    fmt = resolve_domain_format(formats, domain)
    if fmt.il.ndim != 0:
        # the two legs chunk the flattened tensor per-rank, which does not
        # line up with the [G] contiguous-group layout; group-aligned
        # chunking is a ROADMAP item.
        raise ValueError("dps_allreduce_mean takes a global (scalar) format;"
                         " per-group formats are encode/decode-only for now")
    n = jax.lax.axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    k1, k2 = jax.random.split(jax.random.fold_in(key, idx))

    shape, size = x.shape, x.size
    chunk, pad = _group_layout(size, n)

    # leg 1: quantize the local tensor (stats cover exactly these elements),
    # pad the int8 wire, and scatter chunk j to rank j.
    wire, stats = wire_encode(x.reshape(-1), fmt, key=k1, mode=mode,
                              backend=backend)
    wire = jnp.pad(wire, (0, pad)).reshape(n, chunk)
    wire = jax.lax.all_to_all(wire, axis_name, split_axis=0, concat_axis=0,
                              tiled=True)                       # (n, chunk)
    part = wire_decode(wire, fmt).sum(axis=0) / n               # (chunk,)

    # leg 2: re-quantize the owned mean chunk, gather int8 everywhere.
    wire2, _ = wire_encode(part, fmt, key=k2, mode=mode,
                           compute_stats=False, backend=backend)
    full = jax.lax.all_gather(wire2, axis_name, axis=0, tiled=True)
    mean = wire_decode(full, fmt, x.dtype)[:size].reshape(shape)
    return mean, stats


def dps_reduce_scatter_mean(x: jax.Array, formats, axis_name,
                            key: jax.Array, *, mode: str = ROUND_STOCHASTIC,
                            backend: str = "auto",
                            domain: str = "wire_grads",
                            ) -> Tuple[jax.Array, QuantStats]:
    """Reduce-scatter mean over ``axis_name`` with the int8 wire on the
    scatter leg — the ZeRO half-collective.

    Each rank quantizes its *full* local tensor onto the ⟨IL, FL⟩ grid and
    ships int8 grid integers through a tiled ``all_to_all``, so rank j ends
    up holding every rank's j-th chunk; the owner decodes, sums in fp32 and
    divides by the axis size.  This is exactly leg 1 of
    :func:`dps_allreduce_mean` — but where the all-reduce immediately
    re-quantizes and gathers the mean back out, ZeRO-1 keeps it **sharded**
    so each rank can run its slice of the optimizer locally
    (:func:`dps_allgather_params` is the return leg, applied to the updated
    parameter shard instead of the gradient mean).

    Wire bytes ≈ |x|·1 B per rank vs |x|·4 B for an fp32 reduce-scatter;
    stochastic rounding keeps the leg unbiased with error < one grid step
    (2^-FL) on every element of the mean.

    Returns ``(shard, stats)``: ``shard`` is this rank's chunk of the
    flattened, zero-padded mean — shape ``[ceil(x.size / n)]``, the padded
    1-D layout of :class:`repro.dist.sharding.ZeroPartitioner` — and
    ``stats`` cover this rank's dispatch-leg encode of its |x| local
    elements (``psum_stats(stats, axis)`` counts each global element exactly
    once).  Must run inside ``shard_map``; ``key`` may be identical across
    ranks (it is decorrelated with ``axis_index`` here).
    ``formats``/``domain``: see :func:`resolve_domain_format`.
    """
    fmt = resolve_domain_format(formats, domain)
    if fmt.il.ndim != 0:
        raise ValueError("dps_reduce_scatter_mean takes a global (scalar) "
                         "format; per-group formats are encode/decode-only "
                         "for now")
    n = jax.lax.axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    chunk, pad = _group_layout(x.size, n)

    wire, stats = wire_encode(x.reshape(-1), fmt,
                              key=jax.random.fold_in(key, idx), mode=mode,
                              backend=backend)
    wire = jnp.pad(wire, (0, pad)).reshape(n, chunk)
    wire = jax.lax.all_to_all(wire, axis_name, split_axis=0, concat_axis=0,
                              tiled=True)                       # (n, chunk)
    shard = wire_decode(wire, fmt).sum(axis=0) / n              # (chunk,)
    return shard, stats


def dps_allgather_params(shard: jax.Array, formats, axis_name,
                         key: jax.Array, *, mode: str = ROUND_STOCHASTIC,
                         backend: str = "auto", domain: str = "wire_params",
                         ) -> Tuple[jax.Array, QuantStats]:
    """All-gather per-rank parameter shards with an int8 wire — the ZeRO
    return leg.

    Each rank quantizes its updated shard (the slice of the flattened
    parameter vector it just stepped locally) onto the ⟨IL, FL⟩ grid, ships
    int8 grid integers through a tiled ``all_gather``, and every rank
    decodes the concatenation.  Wire bytes ≈ |shard|·1 B per rank vs
    |shard|·4 B fp32.  Note the decode quantizes the *parameters* onto the
    wire grid — the leg reads the registry's ``wire_params`` domain
    (:func:`resolve_domain_format`), whose controller tracks the weight
    range from the stats returned here, so wire clipping and rounding
    error steer next step's wire ⟨IL, FL⟩ without touching the compute
    weights controller.

    Returns ``(full, stats)``: ``full`` is the flat ``[n · shard.size]``
    gathered vector (identical on every rank), ``stats`` cover this rank's
    encode of its |shard| elements (``psum_stats`` → every global element
    counted exactly once).  Must run inside ``shard_map``; ``key`` may be
    identical across ranks.
    """
    fmt = resolve_domain_format(formats, domain)
    if fmt.il.ndim != 0:
        raise ValueError("dps_allgather_params takes a global (scalar) "
                         "format; per-group formats are encode/decode-only "
                         "for now")
    idx = jax.lax.axis_index(axis_name)
    wire, stats = wire_encode(shard.reshape(-1), fmt,
                              key=jax.random.fold_in(key, idx), mode=mode,
                              backend=backend)
    full = jax.lax.all_gather(wire, axis_name, axis=0, tiled=True)
    return wire_decode(full, fmt), stats


def dps_allreduce_mean_tree(tree, formats, axis_name,
                            key: jax.Array, *, mode: str = ROUND_STOCHASTIC,
                            backend: str = "auto",
                            domain: str = "wire_grads"):
    """:func:`dps_allreduce_mean` over a whole pytree in ONE collective pair.

    Leaves are flattened and concatenated into a single fp32 buffer before
    the collective, so the per-step gradient sync costs one all_to_all +
    one all_gather regardless of how many (possibly tiny) leaves the tree
    has — not 2·L launches each padded to the axis size.  Returns
    ``(mean_tree, stats)`` with every leaf cast back to its own dtype.
    ``formats``/``domain``: see :func:`resolve_domain_format`.
    """
    fmt = resolve_domain_format(formats, domain)
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    if not leaves:
        return tree, QuantStats.zero(fmt.il.shape)
    flat = jnp.concatenate([l.reshape(-1).astype(jnp.float32)
                            for l in leaves])
    mean, stats = dps_allreduce_mean(flat, fmt, axis_name, key, mode=mode,
                                     backend=backend)
    out, off = [], 0
    for leaf in leaves:
        out.append(mean[off:off + leaf.size].reshape(leaf.shape)
                   .astype(leaf.dtype))
        off += leaf.size
    return jax.tree_util.tree_unflatten(treedef, out), stats
