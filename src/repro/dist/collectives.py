"""Compressed collectives: the paper's quantizer on the gradient wire.

A fixed-point format ⟨IL, FL⟩ with IL + FL ≤ 8 puts every grid integer in
[-128, 127], so a quantized payload travels the interconnect as **int8**
instead of fp32 — 4× fewer bytes on the wire for the two collective legs
of an all-reduce.  Stochastic rounding (Gupta et al., 2015) keeps both
legs unbiased, and the same :class:`QuantStats` the DPS controllers
consume fall out of the encode for free, so a training loop can feed its
wire-quantization error straight into the paper's precision controller.

All functions here are written for ``shard_map`` bodies: they take an
``axis_name`` and use raw ``lax`` collectives.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.fixed_point import (FixedPointFormat, QuantStats,
                                    ROUND_STOCHASTIC, exp2_int, quantize)


def wire_encode(x: jax.Array, fmt: FixedPointFormat, *,
                key: Optional[jax.Array] = None,
                bits: Optional[jax.Array] = None,
                mode: str = ROUND_STOCHASTIC,
                compute_stats: bool = True
                ) -> Tuple[jax.Array, Optional[QuantStats]]:
    """Quantize ``x`` onto the ⟨IL, FL⟩ grid and emit int8 grid integers.

    The caller must ensure ``IL + FL <= 8`` (grid integers outside int8
    would wrap).  Returns ``(wire int8, stats)`` where stats measure the
    quantization event exactly as :func:`repro.core.fixed_point.quantize`.
    """
    q, stats = quantize(x, fmt, mode=mode, key=key, bits=bits,
                        compute_stats=compute_stats)
    # q is on the grid: q * 2^FL is an exact integer in fp32.  The clip
    # turns an over-wide (IL + FL > 8) format — fmt is traced, so it can't
    # be rejected statically — into bounded saturation instead of leaving
    # the float->int8 convert to wrap backend-dependently.
    wire = jnp.clip(jnp.round(q.astype(jnp.float32) * exp2_int(fmt.fl)),
                    -128, 127)
    return wire.astype(jnp.int8), stats


def wire_decode(wire: jax.Array, fmt: FixedPointFormat,
                dtype=jnp.float32) -> jax.Array:
    """Grid integers (int8) back to values: ``wire * 2^-FL``."""
    return (wire.astype(jnp.float32) * exp2_int(-fmt.fl)).astype(dtype)


def psum_stats(stats: QuantStats, axis_name) -> QuantStats:
    """Combine per-rank :class:`QuantStats` across ``axis_name``.

    Sums psum; ``max_abs`` pmaxes — matching ``QuantStats.merge``."""
    summed = jax.lax.psum((stats.count, stats.nonzero, stats.overflow,
                           stats.abs_err_sum, stats.rel_err_sum,
                           stats.abs_sum), axis_name)
    return QuantStats(*summed, max_abs=jax.lax.pmax(stats.max_abs, axis_name))


def dps_allreduce_mean(x: jax.Array, fmt: FixedPointFormat, axis_name,
                       key: jax.Array, *, mode: str = ROUND_STOCHASTIC
                       ) -> Tuple[jax.Array, QuantStats]:
    """Mean of per-rank ``x`` over ``axis_name`` with an int8 wire format.

    Reduce-scatter / all-gather decomposition, both legs compressed:

      1. each rank quantizes its full local tensor to the ⟨IL, FL⟩ grid and
         ships int8 grid integers through a tiled ``all_to_all`` — rank j
         ends up owning every rank's j-th chunk (reduce-scatter leg);
      2. the owner sums its chunks in fp32, divides by the axis size,
         re-quantizes the mean chunk and ``all_gather``s int8 back out.

    Total wire bytes ≈ 2·|x|·1 B vs 2·|x|·4 B for an fp32 ring all-reduce.
    With stochastic rounding each leg's error is < one grid step (2^-FL),
    so the result is within two grid steps of the exact mean and unbiased.

    Returns ``(mean, stats)``; ``stats`` describe this rank's dispatch-leg
    quantization of the |x| local elements (so ``psum_stats(stats, axis)``
    counts each global element exactly once).  Must run inside
    ``shard_map``; ``key`` may be identical across ranks (it is decorrelated
    with ``axis_index`` here).
    """
    n = jax.lax.axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    k1, k2 = jax.random.split(jax.random.fold_in(key, idx))

    shape, size = x.shape, x.size
    chunk = -(-size // n)
    pad = n * chunk - size

    # leg 1: quantize the local tensor (stats cover exactly these elements),
    # pad the int8 wire, and scatter chunk j to rank j.
    wire, stats = wire_encode(x.reshape(-1), fmt, key=k1, mode=mode)
    wire = jnp.pad(wire, (0, pad)).reshape(n, chunk)
    wire = jax.lax.all_to_all(wire, axis_name, split_axis=0, concat_axis=0,
                              tiled=True)                       # (n, chunk)
    part = wire_decode(wire, fmt).sum(axis=0) / n               # (chunk,)

    # leg 2: re-quantize the owned mean chunk, gather int8 everywhere.
    wire2, _ = wire_encode(part, fmt, key=k2, mode=mode,
                           compute_stats=False)
    full = jax.lax.all_gather(wire2, axis_name, axis=0, tiled=True)
    mean = wire_decode(full, fmt, x.dtype)[:size].reshape(shape)
    return mean, stats
