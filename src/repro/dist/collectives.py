"""Compressed collectives: the paper's quantizer on the gradient wire.

A fixed-point format ⟨IL, FL⟩ with IL + FL ≤ 8 puts every grid integer in
[-128, 127], so a quantized payload travels the interconnect as **int8**
instead of fp32 — 4× fewer bytes on the wire for the two collective legs
of an all-reduce.  Stochastic rounding (Gupta et al., 2015) keeps both
legs unbiased, and the same :class:`QuantStats` the DPS controllers
consume fall out of the encode for free, so a training loop can feed each
leg's wire-quantization error straight into that leg's dedicated *wire
precision domain* (``wire_grads`` / ``wire_params`` in the
:class:`~repro.core.dps.PrecisionPlan` registry; see
``QuantConfig.grad_allreduce_bits`` in :mod:`repro.core.qtrain`).  Every
collective below takes the whole registry-format mapping and resolves its
own leg's ⟨IL, FL⟩ (:func:`resolve_domain_format`).

Codec backends: on TPU the encode runs as the fused Pallas
``dps_quant_wire`` kernel (one read-x/write-wire HBM pass, stats ride in
SMEM); elsewhere it runs as plain jnp ops.  ``backend="auto"`` picks per
``jax.default_backend()``; both backends are bit-exact against
``repro.kernels.ref.dps_quant_wire_ref``.

Formats may be **per-group**: an ⟨IL, FL⟩ of shape ``[G]`` splits the
flattened tensor into G contiguous chunks — equal ``ceil(size / G)``
chunks by default, or explicit per-layer ``group_sizes`` (the grads DPS
controller's per-leaf state is the natural producer) — and returns
``[G]``-shaped :class:`QuantStats`.  A scalar format (the default) is the
global case.  The collectives run ``[G]`` formats through BOTH legs at
kernel speed via the **group-aligned layout** (:class:`GroupLayout`):
every group zero-padded to a multiple of the kernel's tile ``quantum``,
the whole buffer padded to rank-divisible tile-aligned chunks, so one
fused kernel launch encodes all G formats (``[G, 2]`` SMEM table) and
the receive leg's fused ``dps_wire_reduce`` decodes + means the int8
payload without an fp32 ``(n, chunk)`` intermediate in HBM.

All collective functions here are written for ``shard_map`` bodies: they
take an ``axis_name`` and use raw ``lax`` collectives.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import tagging
from repro.core.fixed_point import (FixedPointFormat, QuantStats,
                                    ROUND_NEAREST, ROUND_STOCHASTIC, exp2_int,
                                    wire_quantize)

# int8 wire capacity: IL + FL beyond this saturates grid integers.
WIRE_BITS = 8

# Elements per grouped-kernel grid tile: the group-aligned layout pads every
# group to a multiple of this (and rank chunks to tile multiples), so a tile
# never straddles groups.  Must be a multiple of
# ``repro.kernels.dps_quant.MIN_GROUP_QUANTUM`` (= 32·128, the minimum int8
# TPU tile); bigger quanta trade padding overhead for fewer grid steps —
# benchmarks pass a larger one for multi-MiB tensors.
WIRE_GROUP_QUANTUM = 4096

# The jnp codec has no (32, 128) tile constraint — its layout granularity
# only needs the int8 lane width, so tiny models can run much finer grouped
# layouts without the kernel backend's per-group padding floor.
WIRE_JNP_TILE = 128


def default_wire_quantum(size: int, groups: int, backend: str) -> int:
    """Size-aware grouped-wire quantum: ``ceil(size / G)`` rounded up to
    the backend's int8 tile, capped at :data:`WIRE_GROUP_QUANTUM`.

    The ``kernel`` backend's grid tile must stay a multiple of the
    (32, 128) minimum int8 TPU tile (= ``WIRE_GROUP_QUANTUM``), so it
    always resolves the classic 4096.  The ``jnp`` backend only needs
    lane-width (:data:`WIRE_JNP_TILE`) alignment, so a tiny model's
    per-group padding shrinks from 4096·G to ~``size`` elements.  The
    per-element collective results are layout-invariant (rounding bits are
    drawn per *element*, receive-leg sums are exact in the fp32 mantissa),
    so the two backends stay bit-identical even when they resolve
    different quanta.
    """
    tile = WIRE_GROUP_QUANTUM if backend == "kernel" else WIRE_JNP_TILE
    target = -(-max(size, 1) // max(groups, 1))
    return min(WIRE_GROUP_QUANTUM, max(tile, -(-target // tile) * tile))


def _resolve_quantum(quantum: Optional[int], size: int, groups: int,
                     backend: str) -> int:
    """An explicit ``quantum=`` wins; ``None`` derives the size-aware
    default for the resolved backend."""
    if quantum is not None:
        return int(quantum)
    return default_wire_quantum(size, groups, backend)


def wire_format(fmt: FixedPointFormat, wire_bits: int = WIRE_BITS
                ) -> FixedPointFormat:
    """Derive a wire ⟨IL, FL⟩ from a (wider) compute format.

    Keeps the radix position — IL, the overflow guard — and spends the
    remaining ``wire_bits`` on fraction: ``⟨min(IL, wire_bits - 1),
    wire_bits - IL⟩``.

    NOTE: the training loop no longer derives its wire formats this way —
    each wire leg's ⟨IL, FL⟩ now comes from a dedicated precision domain
    (``wire_grads`` / ``wire_params``) in the :class:`PrecisionPlan`
    registry, because a controller that moves IL in response to wire
    overflow moves the wire radix with it, and under hair-trigger
    ``r_max`` that ratchet destabilizes training (dist/README.md).  The
    helper remains for deriving *static* wire formats in tools and tests.
    """
    if not 2 <= wire_bits <= WIRE_BITS:
        raise ValueError(f"wire_bits must be in [2, {WIRE_BITS}] for an int8 "
                         f"payload, got {wire_bits}")
    il = jnp.clip(jnp.asarray(fmt.il, jnp.int32), 1, wire_bits - 1)
    return FixedPointFormat(il, (wire_bits - il).astype(jnp.int32))


def resolve_domain_format(formats, domain: str) -> FixedPointFormat:
    """One collective leg's ⟨IL, FL⟩ from a precision-domain registry.

    ``formats`` is either the ``{domain: FixedPointFormat}`` mapping
    produced by ``qtrain.bundle_formats`` — the leg picks out its own
    domain — or a bare :class:`FixedPointFormat`, used as-is (the
    pre-registry calling convention, kept for benchmarks and direct
    codec tests).
    """
    if isinstance(formats, FixedPointFormat):
        return formats
    try:
        fmt = formats[domain]
    except (KeyError, IndexError, TypeError):
        have = sorted(formats) if hasattr(formats, "keys") else type(formats)
        raise KeyError(
            f"no {domain!r} format in the registry mapping (have {have}); "
            "declare the wire domain in the PrecisionPlan or pass a "
            "FixedPointFormat directly") from None
    if not isinstance(fmt, FixedPointFormat):
        raise TypeError(f"registry entry {domain!r} is {type(fmt)}, "
                        "expected FixedPointFormat")
    return fmt


def _concrete_ilfl(fmt: FixedPointFormat):
    """(il, fl) as numpy when statically known, else None (traced)."""
    if isinstance(fmt.il, jax.core.Tracer) or isinstance(fmt.fl, jax.core.Tracer):
        return None
    return np.asarray(fmt.il), np.asarray(fmt.fl)


def _validate_capacity(fmt: FixedPointFormat):
    """Raise eagerly on statically over-wide formats (IL + FL > 8).

    Traced formats can't be rejected at trace time; for those the encode
    saturates at ±127 and counts the saturated elements into
    ``QuantStats.overflow`` so the controller sees the wire clipping.
    """
    conc = _concrete_ilfl(fmt)
    if conc is None:
        return
    il, fl = conc
    total = il.astype(np.int64) + fl.astype(np.int64)
    if np.any(total > WIRE_BITS):
        raise ValueError(
            f"⟨IL, FL⟩ = ⟨{il}, {fl}⟩ exceeds the int8 wire: IL + FL = "
            f"{total} > {WIRE_BITS}.  Grid integers would saturate at ±127; "
            f"derive a wire format with wire_format(fmt) instead.")


def _group_layout(size: int, groups: int) -> Tuple[int, int]:
    """(chunk, pad) splitting ``size`` elements into ``groups`` chunks."""
    chunk = -(-size // groups)
    return chunk, groups * chunk - size


def _equal_group_sizes(size: int, groups: int) -> Tuple[int, ...]:
    """The default [G] split: equal ``ceil(size / G)`` contiguous chunks
    (the last possibly short or empty)."""
    chunk = -(-size // groups)
    return tuple(max(0, min(chunk, size - g * chunk)) for g in range(groups))


@dataclasses.dataclass(frozen=True)
class GroupLayout:
    """Static group-aligned flat layout shared by kernels and collectives.

    Group ``g``'s payload occupies ``[offsets[g], offsets[g] +
    group_sizes[g])`` of the aligned buffer; the slot is padded to a
    multiple of ``quantum`` (one grouped-kernel grid tile), so a tile
    never straddles groups.  The buffer is then padded to ``n_chunks``
    equal, tile-aligned ``chunk``-element rank chunks (``total = n_chunks
    · chunk``), so an ``all_to_all``/``all_gather`` boundary always falls
    on a tile boundary and every tile's format is resolvable from the
    ``[G, 2]`` table through :meth:`tile_groups`.  All fields are Python
    ints — the layout is part of the jit closure, never traced.
    """

    group_sizes: Tuple[int, ...]
    quantum: int
    n_chunks: int
    padded: Tuple[int, ...]
    offsets: Tuple[int, ...]
    chunk: int
    total: int

    @property
    def size(self) -> int:
        return sum(self.group_sizes)

    @property
    def tiles(self) -> int:
        return self.total // self.quantum

    @property
    def is_exact(self) -> bool:
        """True when every group already sits at its aligned offset and no
        tail padding exists — align/dealign are then identities (layer
        sizes that are quantum multiples, the common big-model case)."""
        return self.total == self.size and all(
            p == s for p, s in zip(self.padded, self.group_sizes))

    def tile_groups(self) -> np.ndarray:
        """int32 ``[tiles]`` tile → group row (tail padding reads row 0,
        which the mask keeps out of wire bytes and statistics)."""
        out = np.zeros((self.tiles,), np.int32)
        for g, (off, pad) in enumerate(zip(self.offsets, self.padded)):
            out[off // self.quantum:(off + pad) // self.quantum] = g
        return out

    def mask(self) -> np.ndarray:
        """float32 ``[total]`` validity (1 on payload, 0 on padding)."""
        out = np.zeros((self.total,), np.float32)
        for g, (off, size) in enumerate(zip(self.offsets, self.group_sizes)):
            out[off:off + size] = 1.0
        return out

    def align(self, flat: jax.Array) -> jax.Array:
        """Contiguous ``[size]`` payload → aligned ``[total]`` buffer
        (padding zero-filled; the no-op copy is skipped when the layout
        is already exact)."""
        if self.is_exact:
            return flat
        out = jnp.zeros((self.total,), flat.dtype)
        off_in = 0
        for off, size in zip(self.offsets, self.group_sizes):
            if size:
                out = jax.lax.dynamic_update_slice(
                    out, flat[off_in:off_in + size], (off,))
            off_in += size
        return out

    def dealign(self, aligned: jax.Array) -> jax.Array:
        """Aligned ``[total]`` buffer → contiguous ``[size]`` payload."""
        if self.is_exact:
            return aligned
        parts = [aligned[off:off + size]
                 for off, size in zip(self.offsets, self.group_sizes) if size]
        return parts[0] if len(parts) == 1 else jnp.concatenate(parts)


def group_layout(group_sizes, n_chunks: int = 1,
                 quantum: int = WIRE_GROUP_QUANTUM) -> GroupLayout:
    """Build the group-aligned layout for ``group_sizes`` payload groups
    split across ``n_chunks`` ranks."""
    sizes = tuple(int(s) for s in group_sizes)
    if any(s < 0 for s in sizes):
        raise ValueError(f"negative group size in {sizes}")
    padded = tuple(-(-s // quantum) * quantum for s in sizes)
    offsets, off = [], 0
    for p in padded:
        offsets.append(off)
        off += p
    chunk = quantum * -(-off // (n_chunks * quantum)) if off else quantum
    return GroupLayout(group_sizes=sizes, quantum=quantum, n_chunks=n_chunks,
                       padded=padded, offsets=tuple(offsets), chunk=chunk,
                       total=chunk * n_chunks)


def _group_ids(group_sizes) -> np.ndarray:
    """int32 per-element group id for a contiguous (unaligned) split."""
    return np.repeat(np.arange(len(group_sizes), dtype=np.int32),
                     np.asarray(group_sizes, np.int64))


def _check_group_sizes(fmt: FixedPointFormat, group_sizes, total: int,
                       what: str = "x.size"):
    """``group_sizes`` (when given) must have one entry per format-table
    row and sum to the payload size — a mismatched table would otherwise
    be consumed silently with wrong formats (or, on the kernel path, read
    past the [G, 2] SMEM table)."""
    if group_sizes is None:
        return
    groups = fmt.il.shape[0]
    if len(group_sizes) != groups or sum(group_sizes) != total:
        raise ValueError(
            f"group_sizes {tuple(group_sizes)} must have {groups} entries "
            f"(one per format-table row) summing to {what} = {total}")


def _resolve_backend(backend: str) -> str:
    if backend == "auto":
        return "kernel" if jax.default_backend() == "tpu" else "jnp"
    if backend not in ("kernel", "jnp"):
        raise ValueError(f"unknown wire codec backend {backend!r}; "
                         "expected 'auto', 'kernel' or 'jnp'")
    return backend


def _segment_stats(s: QuantStats, ids, groups: int) -> QuantStats:
    """Per-tile/per-element QuantStats → ``[G]`` rows via segment reduce."""
    seg = lambda v: jax.ops.segment_sum(v, ids, num_segments=groups)
    return QuantStats(
        count=seg(s.count), nonzero=seg(s.nonzero), overflow=seg(s.overflow),
        abs_err_sum=seg(s.abs_err_sum), rel_err_sum=seg(s.rel_err_sum),
        abs_sum=seg(s.abs_sum),
        max_abs=jnp.maximum(
            jax.ops.segment_max(s.max_abs, ids, num_segments=groups), 0.0))


def _encode_aligned(x_al: jax.Array, fmt: FixedPointFormat, tile_group,
                    mask, *, bits=None, key=None, mode: str,
                    backend: str, quantum: int, compute_stats: bool = True):
    """Grouped wire encode of a group-aligned ``[total]`` buffer.

    One fused kernel launch on the ``kernel`` backend (``[G, 2]`` SMEM
    table, ``[G, N_STATS]`` accumulator); per-tile ``wire_quantize`` plus
    a segment reduction on ``jnp`` — bit-exact wire bytes either way.
    Returns ``(wire int8 [total], [G]-shaped stats | None)``.
    """
    stochastic = mode == ROUND_STOCHASTIC
    if stochastic and bits is None:
        if key is None:
            raise ValueError("stochastic rounding needs `bits` or `key`")
        bits = jax.random.bits(key, shape=(x_al.size,), dtype=jnp.uint32)
    x_al = tagging.tag(x_al, "encode_in", stochastic=stochastic)
    if stochastic:
        bits = tagging.tag(bits, "sr_bits")
    if backend == "kernel":
        from repro.kernels import ops
        return ops.dps_quantize_wire_grouped(
            x_al, fmt, tile_group,
            bits=bits if stochastic else None, mask=mask,
            stochastic=stochastic, quantum=quantum,
            compute_stats=compute_stats)
    tiles = x_al.size // quantum
    tg = jnp.asarray(tile_group, jnp.int32)
    fmt_t = FixedPointFormat(fmt.il[tg], fmt.fl[tg])
    wire, s = wire_quantize(
        x_al.reshape(tiles, quantum), fmt_t, mode=mode,
        bits=bits.reshape(tiles, quantum) if bits is not None else None,
        compute_stats=compute_stats,
        mask=mask.reshape(tiles, quantum) if mask is not None else None)
    stats = (_segment_stats(s, tg, fmt.il.shape[0]) if compute_stats
             else None)
    return wire.reshape(-1), stats


def _wire_reduce(wire: jax.Array, fmt: FixedPointFormat, tile_group,
                 *, backend: str, quantum: int) -> jax.Array:
    """Receive leg: ``(n, chunk)`` int8 → fp32 ``[chunk]`` mean.

    The ``kernel`` backend runs the fused ``dps_wire_reduce`` (no fp32
    ``(n, chunk)`` intermediate in HBM); ``jnp`` decodes per tile and
    means.  Every decoded value is an exact fp32 multiple of its group's
    ``2^-FL`` and the sums stay inside the fp32 mantissa, so both
    backends produce bit-identical means.
    """
    n = wire.shape[0]
    if backend == "kernel":
        from repro.kernels import ops
        return ops.dps_wire_reduce(wire, fmt, tile_group, quantum=quantum)
    if fmt.il.ndim == 0:
        return wire_decode(wire, fmt).sum(axis=0) / n
    tiles = wire.shape[1] // quantum
    inv = exp2_int(-fmt.fl)[jnp.asarray(tile_group, jnp.int32)]
    dec = wire.reshape(n, tiles, quantum).astype(jnp.float32) * inv[None, :,
                                                                    None]
    return (dec.sum(axis=0) / n).reshape(-1)


def _decode_aligned(wire_al: jax.Array, fmt: FixedPointFormat, tile_group,
                    quantum: int, dtype=jnp.float32) -> jax.Array:
    """Aligned ``[total]`` int8 → values, per-tile FL from the table."""
    tiles = wire_al.size // quantum
    inv = exp2_int(-fmt.fl)[jnp.asarray(tile_group, jnp.int32)]
    dec = wire_al.reshape(tiles, quantum).astype(jnp.float32) * inv[:, None]
    return tagging.tag(dec.reshape(-1).astype(dtype), "decode_out")


def _encode_elementwise(x: jax.Array, fmt: FixedPointFormat, elem_group,
                        *, bits=None, key=None, mode: str,
                        compute_stats: bool = True):
    """Grouped encode with per-ELEMENT group ids (no alignment assumed).

    The layout-agnostic jnp path for unequal ``group_sizes`` and for
    collectives whose chunk layout is owned by the caller (the ZeRO
    halves): formats are gathered per element, stats segment-reduce into
    ``[G]`` rows.  Wire bytes are bit-identical to the aligned kernel
    path (same elementwise math, same rounding bits per element).  The
    per-element stat terms exist only as fusion inputs to the segment
    reductions under jit (XLA fuses the elementwise producers into the
    scatter-adds); this is the correctness-grade grouped path — the hot
    paths run :func:`_encode_aligned`'s tile-granular reduction instead.
    """
    gid = jnp.asarray(elem_group, jnp.int32)
    fmt_e = FixedPointFormat(fmt.il[gid], fmt.fl[gid])
    if mode == ROUND_STOCHASTIC and bits is None:
        if key is None:
            raise ValueError("stochastic rounding needs `bits` or `key`")
        bits = jax.random.bits(key, shape=(x.size,), dtype=jnp.uint32)
    x = tagging.tag(x, "encode_in", stochastic=mode == ROUND_STOCHASTIC)
    if bits is not None:
        bits = tagging.tag(bits, "sr_bits")
    wire, s = wire_quantize(x.reshape(-1), fmt_e, mode=mode,
                            bits=bits.reshape(-1) if bits is not None
                            else None,
                            compute_stats=compute_stats)
    stats = (_segment_stats(s, gid, fmt.il.shape[0]) if compute_stats
             else None)
    return wire, stats


def wire_encode(x: jax.Array, fmt: FixedPointFormat, *,
                key: Optional[jax.Array] = None,
                bits: Optional[jax.Array] = None,
                mode: str = ROUND_STOCHASTIC,
                compute_stats: bool = True,
                backend: str = "auto",
                group_sizes: Optional[Tuple[int, ...]] = None,
                ) -> Tuple[jax.Array, Optional[QuantStats]]:
    """Quantize ``x`` onto the ⟨IL, FL⟩ grid and emit int8 grid integers.

    Statically over-wide formats (IL + FL > 8) raise eagerly; traced
    formats saturate at ±127 with the saturated count folded into
    ``stats.overflow``.  ``bits`` (uint32, x.size elements) supplies the
    rounding noise deterministically; ``key`` draws it.

    Per-group formats (``fmt.il.shape == [G]``): the flattened ``x`` is
    split into G contiguous chunks — equal ``ceil(x.size / G)`` chunks by
    default (the last possibly short), or explicit per-layer
    ``group_sizes`` (must sum to ``x.size``) — and chunk g is encoded
    with ⟨IL[g], FL[g]⟩; stats come back with shape ``[G]``.  The
    round-trip is element-exact with G independent global-format calls on
    the chunks (given the same ``bits`` slices).  On the ``kernel``
    backend the grouped encode is ONE fused launch: the payload is
    scattered into the group-aligned layout (:class:`GroupLayout`), the
    kernel resolves each tile's format from the ``[G, 2]`` SMEM table,
    and the wire comes back in ``x``'s own layout.

    ``backend``: "auto" (fused Pallas kernel on TPU, jnp elsewhere),
    "kernel", or "jnp".  Both are bit-exact against
    ``repro.kernels.ref.dps_quant_wire_ref``.

    Returns ``(wire int8 with x's shape, stats)``.
    """
    if mode not in (ROUND_STOCHASTIC, ROUND_NEAREST):
        # reject here so both backends fail identically (the kernel path
        # folds mode into a boolean and would otherwise silently round
        # to nearest)
        raise ValueError(f"unknown rounding mode {mode!r}")
    _validate_capacity(fmt)
    x = tagging.tag(x, "encode_in", stochastic=mode == ROUND_STOCHASTIC)
    if bits is not None:
        bits = tagging.tag(bits, "sr_bits")
    if fmt.il.ndim == 0:
        if group_sizes is not None:
            raise ValueError("group_sizes needs a [G]-shaped format")
        if _resolve_backend(backend) == "kernel":
            from repro.kernels import ops
            stochastic = mode == ROUND_STOCHASTIC
            b = bits.reshape(-1) if bits is not None else None
            wire, stats = ops.dps_quantize_wire(x, fmt, key=key, bits=b,
                                                stochastic=stochastic)
            return wire, (stats if compute_stats else None)
        if bits is not None:
            bits = bits.reshape(x.shape)
        return wire_quantize(x, fmt, mode=mode, key=key, bits=bits,
                             compute_stats=compute_stats)

    # --- per-group path ---
    if fmt.il.ndim != 1:
        raise ValueError(f"per-group formats must be rank-1 [G], got shape "
                         f"{fmt.il.shape}")
    groups = fmt.il.shape[0]
    n = x.size
    if group_sizes is not None:
        group_sizes = tuple(int(s) for s in group_sizes)
        _check_group_sizes(fmt, group_sizes, n)
    if bits is None and mode == ROUND_STOCHASTIC:
        if key is None:
            raise ValueError("stochastic rounding needs `bits` or `key`")
        bits = tagging.tag(
            jax.random.bits(key, shape=(n,), dtype=jnp.uint32), "sr_bits")

    if _resolve_backend(backend) == "kernel":
        # one fused launch over the group-aligned layout; bits travel with
        # their elements, so the wire is bit-identical to the jnp path.
        layout = group_layout(group_sizes or _equal_group_sizes(n, groups))
        wire_al, stats = _encode_aligned(
            layout.align(x.reshape(-1)), fmt, jnp.asarray(layout.tile_groups()),
            jnp.asarray(layout.mask()),
            bits=layout.align(bits) if bits is not None else None,
            mode=mode, backend="kernel", quantum=layout.quantum,
            compute_stats=compute_stats)
        return layout.dealign(wire_al).reshape(x.shape), stats

    if group_sizes is not None:
        wire, stats = _encode_elementwise(x, fmt, _group_ids(group_sizes),
                                          bits=bits, mode=mode,
                                          compute_stats=compute_stats)
        return wire.reshape(x.shape), stats

    chunk, pad = _group_layout(n, groups)
    xg = _pad_reshape(x.reshape(-1), pad, (groups, chunk))
    bg = (_pad_reshape(bits.reshape(-1), pad, (groups, chunk))
          if bits is not None else None)
    mask = (None if not pad else
            _pad_reshape(jnp.ones((n,), jnp.float32), pad, (groups, chunk)))
    wire, stats = wire_quantize(xg, fmt, mode=mode, bits=bg,
                                compute_stats=compute_stats, mask=mask)
    return wire.reshape(-1)[:n].reshape(x.shape), stats


def _pad_reshape(v: jax.Array, pad: int, shape) -> jax.Array:
    """Tail-pad + reshape, skipping the no-op pad copy when ``pad == 0``."""
    return (v if not pad else jnp.pad(v, (0, pad))).reshape(shape)


def wire_decode(wire: jax.Array, fmt: FixedPointFormat,
                dtype=jnp.float32,
                group_sizes: Optional[Tuple[int, ...]] = None) -> jax.Array:
    """Grid integers (int8) back to values: ``wire * 2^-FL``.

    Accepts the same scalar or ``[G]``-shaped formats (and the same
    ``group_sizes`` split) as :func:`wire_encode` over the flattened
    payload.
    """
    if fmt.il.ndim == 0:
        dec = (wire.astype(jnp.float32) * exp2_int(-fmt.fl)).astype(dtype)
        return tagging.tag(dec, "decode_out")
    groups = fmt.il.shape[0]
    n = wire.size
    if group_sizes is not None:
        gid = jnp.asarray(_group_ids(group_sizes), jnp.int32)
        dec = wire.reshape(-1).astype(jnp.float32) * exp2_int(-fmt.fl)[gid]
        return tagging.tag(dec.reshape(wire.shape).astype(dtype), "decode_out")
    chunk, pad = _group_layout(n, groups)
    wg = _pad_reshape(wire.reshape(-1), pad, (groups, chunk))
    dec = wg.astype(jnp.float32) * exp2_int(-fmt.fl)[:, None]
    return tagging.tag(dec.reshape(-1)[:n].reshape(wire.shape).astype(dtype),
                       "decode_out")


def psum_stats(stats: QuantStats, axis_name) -> QuantStats:
    """Combine per-rank :class:`QuantStats` across ``axis_name``.

    Sums psum; ``max_abs`` pmaxes — matching ``QuantStats.merge``."""
    summed = jax.lax.psum((stats.count, stats.nonzero, stats.overflow,
                           stats.abs_err_sum, stats.rel_err_sum,
                           stats.abs_sum), axis_name)
    return QuantStats(*summed, max_abs=jax.lax.pmax(stats.max_abs, axis_name))


def dps_allreduce_mean(x: jax.Array, formats, axis_name,
                       key: jax.Array, *, mode: str = ROUND_STOCHASTIC,
                       backend: str = "auto", domain: str = "wire_grads",
                       group_sizes: Optional[Tuple[int, ...]] = None,
                       quantum: Optional[int] = None,
                       ) -> Tuple[jax.Array, QuantStats]:
    """Mean of per-rank ``x`` over ``axis_name`` with an int8 wire format.

    Reduce-scatter / all-gather decomposition, both legs compressed:

      1. each rank quantizes its full local tensor to the ⟨IL, FL⟩ grid and
         ships int8 grid integers through a tiled ``all_to_all`` — rank j
         ends up owning every rank's j-th chunk (reduce-scatter leg);
      2. the owner sums its chunks in fp32, divides by the axis size,
         re-quantizes the mean chunk and ``all_gather``s int8 back out.

    Total wire bytes ≈ 2·|x|·1 B vs 2·|x|·4 B for an fp32 ring all-reduce.
    With stochastic rounding each leg's error is < one grid step (2^-FL),
    so the result is within two grid steps of the exact mean and unbiased.

    A ``[G]``-shaped format runs one ⟨IL, FL⟩ per contiguous group
    (``group_sizes``, default equal chunks) through BOTH legs: the payload
    travels in the group-aligned layout (:class:`GroupLayout`, tile
    ``quantum``-aligned groups and rank chunks), so on the ``kernel``
    backend leg 1 is one grouped-kernel launch, the receive leg is the
    fused ``dps_wire_reduce`` (the fp32 ``(n, chunk)`` intermediate never
    touches HBM), and leg 2 re-encodes each owner's chunk with the
    per-tile formats.  Stats come back ``[G]``-shaped.

    ``backend`` selects the wire codec (see :func:`wire_encode`);
    ``formats``/``domain`` resolve the leg's ⟨IL, FL⟩ out of a
    precision-domain registry mapping (:func:`resolve_domain_format`).

    Returns ``(mean, stats)``; ``stats`` describe this rank's dispatch-leg
    quantization of the |x| local elements (so ``psum_stats(stats, axis)``
    counts each global element exactly once) and belong to the wire
    domain's controller.  Must run inside ``shard_map``; ``key`` may be
    identical across ranks (it is decorrelated with ``axis_index`` here).

    ``quantum=None`` (the default) derives the grouped layout's tile size
    per :func:`default_wire_quantum` — size-aware on the jnp backend, the
    kernel tile minimum on TPU; the result is layout-invariant either way.
    """
    fmt = resolve_domain_format(formats, domain)
    _validate_capacity(fmt)
    n = jax.lax.axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    k1, k2 = jax.random.split(jax.random.fold_in(key, idx))
    be = _resolve_backend(backend)
    shape, size = x.shape, x.size
    groups = fmt.il.shape[0] if fmt.il.ndim else 1
    q = _resolve_quantum(quantum, size, groups, be)

    with tagging.domain(domain):
        if fmt.il.ndim != 0:
            _check_group_sizes(fmt, group_sizes, size)
            layout = group_layout(group_sizes
                                  or _equal_group_sizes(size, groups),
                                  n_chunks=n, quantum=q)
            # leg-2 bits are element-indexed, so every rank must derive
            # the same stream (see _aligned_allreduce_mean): a rank-
            # invariant fold distinct from every leg-1 fold_in(key, idx)
            k2s = jax.random.fold_in(key, 0x4C454732)        # "LEG2"
            mean_al, stats = _aligned_allreduce_mean(
                layout.align(x.reshape(-1).astype(jnp.float32)), fmt, layout,
                axis_name, jax.random.fold_in(key, idx), k2s,
                mode=mode, backend=be)
            stats = tagging.tag_tree(stats, "wire_stats")
            return (layout.dealign(mean_al).reshape(shape).astype(x.dtype),
                    stats)

        chunk, pad = _group_layout(size, n)

        # leg 1: quantize the local tensor (stats cover exactly these
        # elements), pad the int8 wire, and scatter chunk j to rank j.
        wire, stats = wire_encode(x.reshape(-1), fmt, key=k1, mode=mode,
                                  backend=be)
        wire = _pad_reshape(wire, pad, (n, chunk))
        wire = tagging.tag(wire, "wire_payload", leg="dispatch")
        wire = jax.lax.all_to_all(wire, axis_name, split_axis=0,
                                  concat_axis=0, tiled=True)    # (n, chunk)
        # receive: fused int8 decode-reduce on the kernel backend — the
        # decoded fp32 (n, chunk) intermediate never exists in HBM.
        part = _wire_reduce(wire, fmt, None, backend=be, quantum=q)

        # leg 2: re-quantize the owned mean chunk, gather int8 everywhere.
        wire2, _ = wire_encode(part, fmt, key=k2, mode=mode,
                               compute_stats=False, backend=be)
        wire2 = tagging.tag(wire2, "wire_payload", leg="gather")
        full = jax.lax.all_gather(wire2, axis_name, axis=0, tiled=True)
        mean = wire_decode(full, fmt, x.dtype)[:size].reshape(shape)
        return mean, tagging.tag_tree(stats, "wire_stats")


def _leg2_bits(k2, group_sizes, group_offset: int = 0) -> jax.Array:
    """Rank-invariant gather-leg rounding bits, keyed by GLOBAL group index.

    Element e of group ``group_offset + g`` always draws the same uint32 —
    no matter which layout (monolithic, per-bucket, sharded) carries the
    group — because each group gets its own ``fold_in(k2, global_g)``
    stream, mirroring the dispatch leg's per-leaf ``fold_in(k1, g)``
    draws.  This is what makes the bucketed pipeline and the sharded ZeRO
    halves bit-exact with the monolithic collective under stochastic
    rounding.  Returns the contiguous ``[sum(group_sizes)]`` stream.
    """
    streams = [jax.random.bits(jax.random.fold_in(k2, group_offset + g),
                               shape=(s,), dtype=jnp.uint32)
               for g, s in enumerate(group_sizes) if s]
    return streams[0] if len(streams) == 1 else jnp.concatenate(streams)


def _aligned_rs_snap(x_al, fmt: FixedPointFormat,
                     layout: GroupLayout, axis_name, k1, k2,
                     *, mode: str, backend: str, group_offset: int = 0,
                     encode_leg1=None):
    """Compressed reduce-scatter + wire-grid snap of an aligned buffer.

    The first half of :func:`_aligned_allreduce_mean`, usable on its own
    as the ZeRO-1 gradient half: dispatch-leg encode, tiled
    ``all_to_all``, fused decode-reduce of the owned chunk, then a LOCAL
    re-encode of the mean chunk onto the wire grid (no collective — the
    int8 ``wire2`` only travels if the caller gathers it).  Because the
    all-reduce decodes exactly this ``wire2`` after its gather, a sharded
    consumer that decodes ``wire2`` locally sees bit-identical values to
    its chunk of the gathered mean — the property that makes ZeRO +
    per-layer wire bit-exact with the replicated step.

    ``encode_leg1(tile_groups, mask) -> (wire_al, stats)`` overrides the
    dispatch-leg encode (the tree collectives encode leaf-by-leaf into a
    preallocated buffer instead of scattering an fp32 copy); the default
    runs :func:`_encode_aligned` on ``x_al``.

    Rounding bits on both legs are drawn per **element** and keyed by
    global group index — leg 1 via the caller's per-leaf ``fold_in(k1,
    g)`` draws (or one ``[layout.size]`` stream in the default encode),
    leg 2 via :func:`_leg2_bits` with ``group_offset`` naming the first
    group's global index — so the per-element result is invariant to the
    layout's quantum, rank-chunk and bucket geometry (receive-leg sums
    are exact in the fp32 mantissa), and the two backends stay
    bit-identical even when they resolve different default quanta.
    ``k2`` must be identical on every rank (element → bits, not rank →
    bits); ``k1`` may be per-rank (leg 1 encodes rank-local data).

    Returns ``(part fp32 [chunk] raw mean, wire2 int8 [chunk], stats,
    my_tg)``.
    """
    n = jax.lax.axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    tg_all = jnp.asarray(layout.tile_groups())
    mask = jnp.asarray(layout.mask())
    stochastic = mode == ROUND_STOCHASTIC
    if encode_leg1 is None:
        bits1 = (layout.align(jax.random.bits(k1, shape=(layout.size,),
                                              dtype=jnp.uint32))
                 if stochastic else None)
        wire_al, stats = _encode_aligned(
            x_al, fmt, tg_all, mask, bits=bits1, mode=mode, backend=backend,
            quantum=layout.quantum)
    else:
        wire_al, stats = encode_leg1(tg_all, mask)

    payload = tagging.tag(wire_al.reshape(n, layout.chunk), "wire_payload",
                          leg="dispatch")
    wire = jax.lax.all_to_all(payload, axis_name,
                              split_axis=0, concat_axis=0, tiled=True)
    # this rank's chunk covers tiles [idx·tpc, (idx+1)·tpc) of the layout
    tpc = layout.chunk // layout.quantum
    my_tg = jax.lax.dynamic_slice(tg_all, (idx * tpc,), (tpc,))
    part = _wire_reduce(wire, fmt, my_tg, backend=backend,
                        quantum=layout.quantum)           # (chunk,) fp32

    # leg 2: per-tile re-encode of the owned mean chunk (stats not needed;
    # alignment padding is zero and encodes to zero bytes)
    if stochastic:
        bits2 = jax.lax.dynamic_slice(
            layout.align(_leg2_bits(k2, layout.group_sizes, group_offset)),
            (idx * layout.chunk,), (layout.chunk,))
    else:
        bits2 = None
    wire2, _ = _encode_aligned(part, fmt, my_tg, None, bits=bits2,
                               mode=mode, backend=backend,
                               quantum=layout.quantum, compute_stats=False)
    return part, wire2, stats, my_tg


def _aligned_allreduce_mean(x_al: jax.Array, fmt: FixedPointFormat,
                            layout: GroupLayout, axis_name, k1, k2,
                            *, mode: str, backend: str,
                            group_offset: int = 0, encode_leg1=None):
    """Both compressed legs over a group-aligned ``[total]`` fp32 buffer.

    :func:`_aligned_rs_snap` (dispatch, reduce, wire-grid re-encode of
    the owned mean chunk) followed by the int8 ``all_gather`` of the
    re-encoded chunks and the per-tile decode.  Returns ``(mean_al fp32
    [total], [G] stats)``; see :func:`_aligned_rs_snap` for the
    element-indexed rounding-bit contract.
    """
    _, wire2, stats, _ = _aligned_rs_snap(
        x_al, fmt, layout, axis_name, k1, k2, mode=mode, backend=backend,
        group_offset=group_offset, encode_leg1=encode_leg1)
    wire2 = tagging.tag(wire2, "wire_payload", leg="gather")
    full = jax.lax.all_gather(wire2, axis_name, axis=0, tiled=True)
    return _decode_aligned(full, fmt, jnp.asarray(layout.tile_groups()),
                           layout.quantum), stats


def dps_reduce_scatter_mean(x: jax.Array, formats, axis_name,
                            key: jax.Array, *, mode: str = ROUND_STOCHASTIC,
                            backend: str = "auto",
                            domain: str = "wire_grads",
                            group_sizes: Optional[Tuple[int, ...]] = None,
                            quantum: Optional[int] = None,
                            ) -> Tuple[jax.Array, QuantStats]:
    """Reduce-scatter mean over ``axis_name`` with the int8 wire on the
    scatter leg — the ZeRO half-collective.

    Each rank quantizes its *full* local tensor onto the ⟨IL, FL⟩ grid and
    ships int8 grid integers through a tiled ``all_to_all``, so rank j ends
    up holding every rank's j-th chunk; the owner decodes, sums in fp32 and
    divides by the axis size.  This is exactly leg 1 of
    :func:`dps_allreduce_mean` — but where the all-reduce immediately
    re-quantizes and gathers the mean back out, ZeRO-1 keeps it **sharded**
    so each rank can run its slice of the optimizer locally
    (:func:`dps_allgather_params` is the return leg, applied to the updated
    parameter shard instead of the gradient mean).

    Wire bytes ≈ |x|·1 B per rank vs |x|·4 B for an fp32 reduce-scatter;
    stochastic rounding keeps the leg unbiased with error < one grid step
    (2^-FL) on every element of the mean.

    A ``[G]``-shaped format splits the flattened ``x`` into contiguous
    groups (``group_sizes``, default equal chunks) and returns ``[G]``
    stats.  The chunk layout here is the CALLER's contract (the
    ``ZeroPartitioner`` flat slices), so the grouped codec runs
    per-element formats on the jnp path — group boundaries need not align
    with rank chunks.  The train step's grouped ZeRO path does NOT come
    through here: it runs the group-aligned
    :class:`repro.dist.sharding.GroupAlignedPartitioner` layout through
    :func:`repro.dist.overlap.zero_bucketed_reduce_scatter` (kernel-grade
    aligned codec, per-bucket collectives); this per-element form remains
    for callers that own their own chunk layout.

    Returns ``(shard, stats)``: ``shard`` is this rank's chunk of the
    flattened, zero-padded mean — shape ``[ceil(x.size / n)]``, the padded
    1-D layout of :class:`repro.dist.sharding.ZeroPartitioner` — and
    ``stats`` cover this rank's dispatch-leg encode of its |x| local
    elements (``psum_stats(stats, axis)`` counts each global element exactly
    once).  Must run inside ``shard_map``; ``key`` may be identical across
    ranks (it is decorrelated with ``axis_index`` here).
    ``formats``/``domain``: see :func:`resolve_domain_format`.
    ``quantum=None`` derives the receive-leg tile per
    :func:`default_wire_quantum`.
    """
    fmt = resolve_domain_format(formats, domain)
    _validate_capacity(fmt)
    n = jax.lax.axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    be = _resolve_backend(backend)
    chunk, pad = _group_layout(x.size, n)
    groups = fmt.il.shape[0] if fmt.il.ndim else 1
    q = _resolve_quantum(quantum, x.size, groups, be)

    with tagging.domain(domain):
        if fmt.il.ndim != 0:
            if backend == "kernel":
                raise ValueError(
                    "dps_reduce_scatter_mean runs [G]-shaped formats with "
                    "the per-element jnp codec (the shard layout is the "
                    "caller's ZeroPartitioner contract, so group boundaries "
                    "cannot be tile-aligned); an explicit backend='kernel' "
                    "request cannot be honored here — use backend='auto', "
                    "or dps_allreduce_mean for the group-aligned kernel "
                    "schedule")
            _check_group_sizes(fmt, group_sizes, x.size)
            gid = _group_ids(group_sizes
                             or _equal_group_sizes(x.size, fmt.il.shape[0]))
            wire, stats = _encode_elementwise(
                x.reshape(-1), fmt, gid, key=jax.random.fold_in(key, idx),
                mode=mode)
            wire = _pad_reshape(wire, pad, (n, chunk))
            wire = tagging.tag(wire, "wire_payload", leg="dispatch")
            wire = jax.lax.all_to_all(wire, axis_name, split_axis=0,
                                      concat_axis=0, tiled=True)
            # decode with the formats of THIS rank's chunk positions
            gid_pad = np.pad(gid, (0, pad))
            my_gid = jax.lax.dynamic_slice(jnp.asarray(gid_pad),
                                           (idx * chunk,), (chunk,))
            inv = exp2_int(-fmt.fl)[my_gid]
            shard = (wire.astype(jnp.float32) * inv[None, :]).sum(axis=0) / n
            return shard, tagging.tag_tree(stats, "wire_stats")

        wire, stats = wire_encode(x.reshape(-1), fmt,
                                  key=jax.random.fold_in(key, idx),
                                  mode=mode, backend=be)
        wire = _pad_reshape(wire, pad, (n, chunk))
        wire = tagging.tag(wire, "wire_payload", leg="dispatch")
        wire = jax.lax.all_to_all(wire, axis_name, split_axis=0,
                                  concat_axis=0, tiled=True)     # (n, chunk)
        # fused decode-reduce on the kernel backend (no fp32 (n, chunk)
        # in HBM)
        shard = _wire_reduce(wire, fmt, None, backend=be, quantum=q)
        return shard, tagging.tag_tree(stats, "wire_stats")


def dps_allgather_params(shard: jax.Array, formats, axis_name,
                         key: jax.Array, *, mode: str = ROUND_STOCHASTIC,
                         backend: str = "auto", domain: str = "wire_params",
                         group_sizes: Optional[Tuple[int, ...]] = None,
                         ) -> Tuple[jax.Array, QuantStats]:
    """All-gather per-rank parameter shards with an int8 wire — the ZeRO
    return leg.

    Each rank quantizes its updated shard (the slice of the flattened
    parameter vector it just stepped locally) onto the ⟨IL, FL⟩ grid, ships
    int8 grid integers through a tiled ``all_gather``, and every rank
    decodes the concatenation.  Wire bytes ≈ |shard|·1 B per rank vs
    |shard|·4 B fp32.  Note the decode quantizes the *parameters* onto the
    wire grid — the leg reads the registry's ``wire_params`` domain
    (:func:`resolve_domain_format`), whose controller tracks the weight
    range from the stats returned here, so wire clipping and rounding
    error steer next step's wire ⟨IL, FL⟩ without touching the compute
    weights controller.

    A ``[G]``-shaped format partitions the GATHERED ``[n · shard.size]``
    vector into contiguous groups (``group_sizes``, default equal
    chunks): each rank encodes its shard with the formats of its own
    positions and every rank decodes the concatenation group-wise.  The
    shard layout is the caller's contract, so the grouped codec runs
    per-element formats (jnp path) — no alignment assumed.  (The train
    step's grouped ZeRO return leg runs the group-aligned layout through
    :func:`repro.dist.overlap.zero_allgather_params` instead.)

    Returns ``(full, stats)``: ``full`` is the flat ``[n · shard.size]``
    gathered vector (identical on every rank), ``stats`` cover this rank's
    encode of its |shard| elements (``psum_stats`` → every global element
    counted exactly once).  Must run inside ``shard_map``; ``key`` may be
    identical across ranks.
    """
    fmt = resolve_domain_format(formats, domain)
    _validate_capacity(fmt)
    n = jax.lax.axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    with tagging.domain(domain):
        if fmt.il.ndim != 0:
            if backend == "kernel":
                raise ValueError(
                    "dps_allgather_params runs [G]-shaped formats with the "
                    "per-element jnp codec (the shard layout is the "
                    "caller's contract, so group boundaries cannot be "
                    "tile-aligned); an explicit backend='kernel' request "
                    "cannot be honored here — use backend='auto'")
            total = n * shard.size
            _check_group_sizes(fmt, group_sizes, total,
                               what="the gathered vector size")
            gid = _group_ids(group_sizes
                             or _equal_group_sizes(total, fmt.il.shape[0]))
            my_gid = jax.lax.dynamic_slice(jnp.asarray(gid),
                                           (idx * shard.size,),
                                           (shard.size,))
            wire, stats = _encode_elementwise(
                shard.reshape(-1), fmt, my_gid,
                key=jax.random.fold_in(key, idx), mode=mode)
            wire = tagging.tag(wire, "wire_payload", leg="gather")
            full = jax.lax.all_gather(wire, axis_name, axis=0, tiled=True)
            dec = tagging.tag(
                full.astype(jnp.float32)
                * exp2_int(-fmt.fl)[jnp.asarray(gid)], "decode_out")
            return dec, tagging.tag_tree(stats, "wire_stats")
        wire, stats = wire_encode(shard.reshape(-1), fmt,
                                  key=jax.random.fold_in(key, idx),
                                  mode=mode, backend=backend)
        wire = tagging.tag(wire, "wire_payload", leg="gather")
        full = jax.lax.all_gather(wire, axis_name, axis=0, tiled=True)
        return wire_decode(full, fmt), tagging.tag_tree(stats, "wire_stats")


def dps_allreduce_mean_tree(tree, formats, axis_name,
                            key: jax.Array, *, mode: str = ROUND_STOCHASTIC,
                            backend: str = "auto",
                            domain: str = "wire_grads",
                            quantum: Optional[int] = None,
                            payload_fault=None):
    """:func:`dps_allreduce_mean` over a whole pytree in ONE collective pair.

    Each leaf is encoded straight into its slot of ONE preallocated int8
    wire buffer (``dynamic_update_slice``; the old fp32
    flatten-and-concatenate pass over the whole tree is gone — the only
    tree-sized intermediate is the 4×-smaller int8 buffer), so the
    per-step gradient sync costs one all_to_all + one all_gather
    regardless of how many (possibly tiny) leaves the tree has — not 2·L
    launches each padded to the axis size.  The mean comes back leaf by
    leaf (int8 slice → decode → leaf dtype): the fp32 mean never exists
    as a flat tree-sized buffer either.

    A ``[G]``-shaped format (G = leaf count) runs ONE ⟨IL, FL⟩ PER LEAF:
    leaf g encodes into a :class:`GroupLayout`-aligned slot with
    ⟨IL[g], FL[g]⟩, both collective legs run group-aligned (fused grouped
    kernel + ``dps_wire_reduce`` on the ``kernel`` backend), and stats
    come back ``[G]``-shaped — per-layer wire formats at full kernel
    speed, one HBM pass per leg.

    Returns ``(mean_tree, stats)`` with every leaf cast back to its own
    dtype.  ``formats``/``domain``: see :func:`resolve_domain_format`.
    ``quantum=None`` derives the per-leaf slot alignment per
    :func:`default_wire_quantum` (size-aware on jnp, kernel tile on TPU).

    ``payload_fault`` is the fault-injection hook of
    ``repro.resilience.inject``: a callable applied to the encoded int8
    dispatch-leg buffer right before it enters the collective (simulating
    transport corruption), or None (the default — the jaxpr is
    unchanged).  Test harness only; the guards it exists to prove live in
    ``repro.resilience.guards``.
    """
    fmt = resolve_domain_format(formats, domain)
    _validate_capacity(fmt)
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    if not leaves:
        return tree, QuantStats.zero(fmt.il.shape)
    grouped = fmt.il.ndim != 0
    if grouped and fmt.il.shape[0] != len(leaves):
        raise ValueError(
            f"[G]-shaped tree formats are one ⟨IL, FL⟩ per leaf: the table "
            f"has {fmt.il.shape[0]} rows, the tree {len(leaves)} leaves")
    n = jax.lax.axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    k1, k2 = jax.random.split(jax.random.fold_in(key, idx))
    be = _resolve_backend(backend)
    sizes = tuple(l.size for l in leaves)
    q = _resolve_quantum(quantum, sum(sizes),
                         len(leaves) if grouped else 1, be)

    if grouped:
        layout = group_layout(sizes, n_chunks=n, quantum=q)
        offsets, total = layout.offsets, layout.total
    else:
        # one format decodes everywhere, so exact packing (tail pad only,
        # no per-leaf alignment — plain offsets, not a GroupLayout, whose
        # invariants are tile-aligned) keeps the wire payload minimal.
        layout = None
        size = sum(sizes)
        chunk, _ = _group_layout(size, n)
        offsets = tuple(int(o) for o in np.cumsum((0,) + sizes[:-1]))
        total = chunk * n

    def encode_leg1(tg_all, mask):
        """Leaf-by-leaf encode into the preallocated int8 wire buffer."""
        buf = jnp.zeros((total,), jnp.int8)
        per_leaf = []
        for g, leaf in enumerate(leaves):
            fmt_g = (FixedPointFormat(fmt.il[g], fmt.fl[g]) if grouped
                     else fmt)
            w, s = wire_encode(leaf.reshape(-1), fmt_g,
                               key=jax.random.fold_in(k1, g), mode=mode,
                               backend=be)
            buf = jax.lax.dynamic_update_slice(buf, w, (offsets[g],))
            per_leaf.append(s)
        if grouped:
            stats = jax.tree.map(lambda *xs: jnp.stack(xs), *per_leaf)
        else:
            stats = per_leaf[0]
            for s in per_leaf[1:]:
                stats = stats.merge(s)
        if payload_fault is not None:
            buf = payload_fault(buf)
        return buf, stats

    with tagging.domain(domain):
        if grouped:
            # leg-2 bits are element-indexed (see _aligned_allreduce_mean):
            # every rank must derive the same stream
            k2s = jax.random.fold_in(key, 0x4C454732)        # "LEG2"
            mean_al, stats = _aligned_allreduce_mean(
                None, fmt, layout, axis_name, k1, k2s, mode=mode,
                backend=be, encode_leg1=encode_leg1)
            full = mean_al
            decode = lambda g, flat: flat  # already decoded per tile
        else:
            buf, stats = encode_leg1(None, None)
            payload = tagging.tag(buf.reshape(n, chunk), "wire_payload",
                                  leg="dispatch")
            wire = jax.lax.all_to_all(payload, axis_name,
                                      split_axis=0, concat_axis=0,
                                      tiled=True)
            part = _wire_reduce(wire, fmt, None, backend=be, quantum=q)
            # gather-leg bits keyed by global leaf index (rank-invariant
            # k2s stream, same contract as _aligned_rs_snap) so the
            # bucketed and sharded schedules stay bit-exact with this
            # monolithic one under stochastic rounding
            if mode == ROUND_STOCHASTIC:
                k2s = jax.random.fold_in(key, 0x4C454732)    # "LEG2"
                bits2 = jax.lax.dynamic_slice(
                    _pad_reshape(_leg2_bits(k2s, sizes), total - sum(sizes),
                                 (total,)),
                    (idx * chunk,), (chunk,))
            else:
                bits2 = None
            wire2, _ = wire_encode(part, fmt, bits=bits2, mode=mode,
                                   compute_stats=False, backend=be)
            wire2 = tagging.tag(wire2, "wire_payload", leg="gather")
            full = jax.lax.all_gather(wire2, axis_name, axis=0, tiled=True)
            decode = lambda g, sl: wire_decode(sl, fmt)
        stats = tagging.tag_tree(stats, "wire_stats")

    out = []
    for g, leaf in enumerate(leaves):
        sl = jax.lax.dynamic_slice(full, (offsets[g],), (leaf.size,))
        out.append(decode(g, sl).reshape(leaf.shape).astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, out), stats
