"""Distribution subsystem: logical sharding rules + compressed collectives.

``repro.dist.sharding`` binds a mesh and :class:`LogicalRules` into a
context so model code can express placement as *logical* axis names
("batch", "tp", "fsdp", ...) that resolve against whatever mesh the run
builds — or no-op entirely on a single device.

``repro.dist.collectives`` moves gradient/statistics payloads over the
mesh with the paper's fixed-point quantizer applied to the wire format
(int8 instead of fp32 — see :func:`dps_allreduce_mean`).
"""

from repro.dist.sharding import (LogicalRules, axis_rules, current_mesh_rules,
                                 logical_constraint, model_axis_size,
                                 tree_specs)
from repro.dist.collectives import (dps_allreduce_mean, psum_stats,
                                    wire_decode, wire_encode)

__all__ = [
    "LogicalRules", "axis_rules", "current_mesh_rules", "logical_constraint",
    "model_axis_size", "tree_specs",
    "dps_allreduce_mean", "psum_stats", "wire_decode", "wire_encode",
]
