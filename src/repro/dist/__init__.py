"""Distribution subsystem: logical sharding rules + compressed collectives.

Sharding (``repro.dist.sharding``)
----------------------------------
Binds a mesh and :class:`LogicalRules` into a context so model code can
express placement as *logical* axis names ("batch", "tp", "fsdp", ...)
that resolve against whatever mesh the run builds — or no-op entirely on
a single device.

The int8 wire format (``repro.dist.collectives``)
-------------------------------------------------
Gradient payloads travel the interconnect as **grid integers**: a value
``x`` quantized onto the paper's ⟨IL, FL⟩ fixed-point grid is shipped as
``round(x · 2^FL)`` in one int8 byte (IL + FL ≤ 8 keeps every grid
integer in [-128, 127]; statically wider formats are rejected eagerly,
traced ones saturate with the clipped count folded into
``QuantStats.overflow``).  The receiver decodes with ``wire · 2^-FL``.

:func:`dps_allreduce_mean` is the collective built on that codec: a
reduce-scatter (tiled ``all_to_all``) plus ``all_gather``, **both legs
int8** — ≈ 2·|x| wire bytes against ≈ 8·|x| for an fp32 ring all-reduce.
Stochastic rounding keeps each leg unbiased and under one grid step of
error, so the result lands within **two grid steps (2·2^-FL)** of the
exact mean.  Encoding runs through the fused Pallas ``dps_quant_wire``
kernel on TPU (one read-x/write-wire HBM pass, stats in SMEM) and plain
jnp ops elsewhere; formats may be per-group (⟨IL, FL⟩ of shape [G] over
contiguous chunks of the flattened tensor).

:func:`dps_reduce_scatter_mean` / :func:`dps_allgather_params` split the
same schedule into ZeRO-1's two halves: the scatter leg leaves the mean
**sharded** (one flat chunk per rank, the
:class:`~repro.dist.sharding.ZeroPartitioner` padded layout) so each rank
steps its slice of the optimizer locally, and the gather leg ships the
updated parameter shards back — both int8.  See ``dist/README.md`` for
when each schedule engages.

Training integration — ``QuantConfig.grad_allreduce_bits``
----------------------------------------------------------
The knob that turns the codec into the gradient hot path::

    from repro.core import qtrain
    from repro.optim import SGDConfig, make_optimizer

    mesh = jax.make_mesh((jax.device_count(),), ("data",))
    qcfg = qtrain.QuantConfig(grad_allreduce_bits=8)
    step = qtrain.make_train_step(loss_fn, make_optimizer(SGDConfig()),
                                  qcfg, mesh=mesh)
    state, metrics = jax.jit(step)(state, batch)   # metrics["E_wire"], ...

The forward/backward runs per data shard under ``shard_map`` and the
parameter-gradient mean is computed by :func:`dps_allreduce_mean` with
the ⟨IL, FL⟩ of the registry's dedicated **wire_grads** precision domain
(every collective leg picks its own domain's format out of the
``qtrain.bundle_formats`` mapping — see :func:`resolve_domain_format`).
The dispatch-leg :class:`QuantStats` feed that wire domain's controller
(default "flexpoint": max-abs-driven radix placement), so wire clipping
moves the *wire* radix rather than ratcheting the compute controllers'
IL — the instability the registry redesign fixed, see dist/README.md.
Single-device meshes degrade to the identity all-reduce; the CLI
spelling is ``repro.launch.train --grad-allreduce-bits 8``.
"""

from repro.dist.sharding import (LogicalRules, ZeroPartitioner, axis_rules,
                                 current_mesh_rules, logical_constraint,
                                 model_axis_size, tree_specs)
from repro.dist.collectives import (dps_allgather_params, dps_allreduce_mean,
                                    dps_allreduce_mean_tree,
                                    dps_reduce_scatter_mean, psum_stats,
                                    resolve_domain_format, wire_decode,
                                    wire_encode, wire_format)

__all__ = [
    "LogicalRules", "ZeroPartitioner", "axis_rules", "current_mesh_rules",
    "logical_constraint", "model_axis_size", "tree_specs",
    "dps_allgather_params", "dps_allreduce_mean", "dps_allreduce_mean_tree",
    "dps_reduce_scatter_mean", "psum_stats", "resolve_domain_format",
    "wire_decode", "wire_encode", "wire_format",
]
