"""Whisper-style encoder-decoder (audio family).

The conv frontend is a STUB per the assignment: ``frames`` arrive as
precomputed (B, enc_seq, d_model) frame embeddings.  Encoder is non-causal
self-attention; decoder is causal self-attention + cross-attention over the
encoder output.  Sinusoidal positions on both stacks (whisper's learned
decoder table tops out at 448 positions — the assigned 32k decode shapes
need absolute positions beyond that, so both stacks use sinusoids; noted in
DESIGN.md).

Decode keeps two caches per layer: the growing self-attention KV and the
fixed cross-attention KV computed once at prefill.
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.fixed_point import QuantStats
from repro.dist.sharding import logical_constraint
from repro.models import attention as attn_lib
from repro.models import mlp as mlp_lib
from repro.models.common import (ParamDef, embed_defs, embed_lookup,
                                 fused_unembed_xent, layer_norm, softmax_xent,
                                 unembed)
from repro.models.transformer import _dtype, stack_defs


def sinusoid(S: int, D: int) -> jax.Array:
    return sinusoid_at(jnp.arange(S, dtype=jnp.int32), D)


def sinusoid_at(pos: jax.Array, D: int) -> jax.Array:
    """Sinusoidal embedding rows at integer positions ``pos`` (any shape)."""
    dim = jnp.arange(D // 2, dtype=jnp.float32)
    ang = pos[..., None].astype(jnp.float32) / jnp.power(10000.0, 2 * dim / D)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _ln_defs(d):
    return {"s": ParamDef((d,), (None,), init="ones", dtype=jnp.float32),
            "b": ParamDef((d,), (None,), init="zeros", dtype=jnp.float32)}


def _enc_layer_defs(cfg: ModelConfig):
    dt = _dtype(cfg)
    return {
        "ln1": _ln_defs(cfg.d_model),
        "attn": attn_lib.gqa_defs(cfg, dt),
        "ln2": _ln_defs(cfg.d_model),
        "mlp": mlp_lib.mlp_defs(cfg.d_model, cfg.d_ff, cfg.gated_mlp, dt),
    }


def _dec_layer_defs(cfg: ModelConfig):
    dt = _dtype(cfg)
    return {
        "ln1": _ln_defs(cfg.d_model),
        "self_attn": attn_lib.gqa_defs(cfg, dt),
        "lnx": _ln_defs(cfg.d_model),
        "cross_attn": attn_lib.gqa_defs(cfg, dt),
        "ln2": _ln_defs(cfg.d_model),
        "mlp": mlp_lib.mlp_defs(cfg.d_model, cfg.d_ff, cfg.gated_mlp, dt),
    }


def model_defs(cfg: ModelConfig) -> Dict[str, Any]:
    dt = _dtype(cfg)
    return {
        "embed": embed_defs(cfg.vocab, cfg.d_model, tie=cfg.tie_embed, dtype=dt),
        "enc_layers": stack_defs(cfg.n_enc_layers, _enc_layer_defs(cfg)),
        "enc_norm": _ln_defs(cfg.d_model),
        "dec_layers": stack_defs(cfg.n_layers, _dec_layer_defs(cfg)),
        "dec_norm": _ln_defs(cfg.d_model),
    }


def _ln(x, p):
    return layer_norm(x, p["s"], p["b"])


def encode(cfg: ModelConfig, params, frames: jax.Array, qctx=None):
    """frames (B, enc_seq, D) — stubbed frontend output."""
    x = frames.astype(_dtype(cfg)) + sinusoid(
        frames.shape[1], cfg.d_model).astype(_dtype(cfg))
    x = logical_constraint(x, "batch", "seq", "embed")
    positions = jnp.arange(frames.shape[1], dtype=jnp.int32)[None, :]

    def body(carry, xs):
        h, stats_acc = carry
        p, idx = xs
        a, _ = attn_lib.gqa_apply(cfg, p["attn"], _ln(h, p["ln1"]),
                                  positions=positions, mode="train",
                                  causal=False)
        h = h + a
        h = h + mlp_lib.mlp_apply(cfg, p["mlp"], _ln(h, p["ln2"]))
        stats = QuantStats.zero()
        if qctx is not None:
            h, stats = qctx.tap(h, idx)
            stats = stats if stats is not None else QuantStats.zero()
        return (h, stats_acc.merge(stats)), None

    if cfg.remat in ("full", "dots"):
        pol = (jax.checkpoint_policies.nothing_saveable if cfg.remat == "full"
               else jax.checkpoint_policies.checkpoint_dots)
        body = jax.checkpoint(body, policy=pol)
    idxs = jnp.arange(cfg.n_enc_layers, dtype=jnp.uint32) + 50_000
    (x, stats), _ = jax.lax.scan(body, (x, QuantStats.zero()),
                                 (params["enc_layers"], idxs),
                                 unroll=cfg.probe_unroll)
    return _ln(x, params["enc_norm"]), stats


def _decoder(cfg: ModelConfig, params, x, enc_out, *, mode, cache, cache_pos,
             qctx):
    positions = (cache_pos[:, None] if mode == "decode"
                 else jnp.arange(x.shape[1], dtype=jnp.int32)[None, :])

    def body(carry, xs):
        h, stats_acc = carry
        p, idx, self_cache, cross_cache = xs
        a, new_self = attn_lib.gqa_apply(
            cfg, p["self_attn"], _ln(h, p["ln1"]), positions=positions,
            mode=mode, cache=self_cache, cache_pos=cache_pos)
        h = h + a
        if mode == "decode":
            c, _ = attn_lib.gqa_apply(
                cfg, p["cross_attn"], _ln(h, p["lnx"]), positions=positions,
                mode="decode_static", cache=cross_cache)
            new_cross = cross_cache
        else:
            c, new_cross = attn_lib.gqa_apply(
                cfg, p["cross_attn"], _ln(h, p["lnx"]), positions=positions,
                mode="prefill" if mode == "prefill" else "train",
                kv_x=enc_out, causal=False)
        h = h + c
        h = h + mlp_lib.mlp_apply(cfg, p["mlp"], _ln(h, p["ln2"]))
        stats = QuantStats.zero()
        if qctx is not None:
            h, stats = qctx.tap(h, idx)
            stats = stats if stats is not None else QuantStats.zero()
        return (h, stats_acc.merge(stats)), (new_self, new_cross)

    if cfg.remat in ("full", "dots"):
        pol = (jax.checkpoint_policies.nothing_saveable if cfg.remat == "full"
               else jax.checkpoint_policies.checkpoint_dots)
        body = jax.checkpoint(body, policy=pol)

    idxs = jnp.arange(cfg.n_layers, dtype=jnp.uint32)
    if cache is None:
        B = x.shape[0]
        cache = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                             cache_struct(cfg, B, 0))
    (x, stats), new_cache = jax.lax.scan(
        body, (x, QuantStats.zero()),
        (params["dec_layers"], idxs, cache["self"], cache["cross"]),
        unroll=cfg.probe_unroll)
    if mode == "train":
        new_cache = None
    else:
        new_cache = {"self": new_cache[0], "cross": new_cache[1]}
    return _ln(x, params["dec_norm"]), new_cache, stats


def cache_struct(cfg: ModelConfig, batch: int, max_seq: int):
    L = cfg.n_layers
    dt = jnp.int8 if cfg.kv_cache_bits == 8 else _dtype(cfg)
    kv_self = (L, batch, max_seq, cfg.n_kv_heads, cfg.head_dim)
    kv_cross = (L, batch, cfg.enc_seq if max_seq else 0, cfg.n_kv_heads,
                cfg.head_dim)
    return {
        "self": (jax.ShapeDtypeStruct(kv_self, dt),
                 jax.ShapeDtypeStruct(kv_self, dt)),
        "cross": (jax.ShapeDtypeStruct(kv_cross, dt),
                  jax.ShapeDtypeStruct(kv_cross, dt)),
    }


def cache_logical(cfg: ModelConfig):
    sp = ("layers", "batch", "kv_seq", "kv", "head_dim")
    return {"self": (sp, sp), "cross": (sp, sp)}


def init_cache(cfg: ModelConfig, batch: int, max_seq: int):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        cache_struct(cfg, batch, max_seq))


def forward(cfg: ModelConfig, params, tokens, *, frames=None, qctx=None,
            mode="train", cache=None, cache_pos=None, enc_out=None,
            vision_embeds=None, hidden_only=False):
    """Returns (logits, new_cache, aux, stats).  ``frames`` required unless
    decoding (cross KV already cached)."""
    stats = QuantStats.zero()
    if mode != "decode":
        enc_out, enc_stats = encode(cfg, params, frames, qctx)
        stats = stats.merge(enc_stats)
    x = embed_lookup(params["embed"]["tok"], tokens).astype(_dtype(cfg))
    if mode == "decode":
        x = x + sinusoid_at(cache_pos, cfg.d_model)[:, None, :].astype(x.dtype)
    else:
        x = x + sinusoid(x.shape[1], cfg.d_model)[None].astype(x.dtype)

    x, new_cache, dec_stats = _decoder(cfg, params, x, enc_out, mode=mode,
                                       cache=cache, cache_pos=cache_pos,
                                       qctx=qctx)
    stats = stats.merge(dec_stats)
    if hidden_only:
        return x, new_cache, jnp.zeros((), jnp.float32), stats
    if mode == "prefill":
        x = x[:, -1:]
    logits = unembed(x, params["embed"], cfg.vocab)
    return logits, new_cache, jnp.zeros((), jnp.float32), stats


def loss_fn(cfg: ModelConfig):
    def fn(params, batch, qctx=None):
        tokens = batch["tokens"]
        hidden, _, _, stats = forward(cfg, params, tokens[:, :-1],
                                      frames=batch["frames"], qctx=qctx,
                                      hidden_only=True)
        loss = fused_unembed_xent(hidden, params["embed"], cfg.vocab,
                                  tokens[:, 1:], batch.get("loss_mask"),
                                  unroll=cfg.probe_unroll)
        return loss, {"act_stats": stats}
    return fn


def prefill(cfg: ModelConfig, params, tokens, max_seq: int, *, frames=None,
            qctx=None, vision_embeds=None):
    logits, cache, _, _ = forward(cfg, params, tokens, frames=frames,
                                  qctx=qctx, mode="prefill")
    S = tokens.shape[1]
    cache["self"] = jax.tree.map(
        lambda c: jnp.pad(c, ((0, 0), (0, 0), (0, max_seq - S), (0, 0), (0, 0))),
        cache["self"])
    pos = jnp.full((tokens.shape[0],), S, jnp.int32)
    return logits[:, -1], cache, pos


def decode_step(cfg: ModelConfig, params, tokens, cache, pos, qctx=None):
    logits, new_cache, _, _ = forward(cfg, params, tokens, qctx=qctx,
                                      mode="decode", cache=cache, cache_pos=pos)
    return logits[:, -1], new_cache


def count_params(cfg: ModelConfig) -> float:
    from repro.models.mlp import count_mlp_params
    attn = attn_lib.count_gqa_params(cfg)
    mlp = count_mlp_params(cfg.d_model, cfg.d_ff, cfg.gated_mlp)
    enc = cfg.n_enc_layers * (4 * cfg.d_model + attn + mlp)
    dec = cfg.n_layers * (6 * cfg.d_model + 2 * attn + mlp)
    total = enc + dec + 4 * cfg.d_model
    total += cfg.vocab * cfg.d_model * (1 if cfg.tie_embed else 2)
    return float(total)
