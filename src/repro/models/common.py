"""Shared model substrate: param defs, norms, RoPE, activations, embeddings.

Parameters are declared once as :class:`ParamDef` (shape + logical sharding
axes + initializer) so a single declaration drives materialization
(``init_params``), sharding resolution (``logical_tree``) and the dry-run's
``ShapeDtypeStruct`` stand-ins (``abstract_params``) — the MaxText pattern,
kept small.

Everything here is pure jnp; quantization taps arrive through the ``qctx``
objects from :mod:`repro.core.qtrain`.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.dist.sharding import logical_constraint


@dataclasses.dataclass(frozen=True)
class ParamDef:
    """One parameter: shape, per-dim logical axes, init spec."""

    shape: Tuple[int, ...]
    logical: Tuple[Optional[str], ...]
    init: str = "normal"        # normal | zeros | ones | embed
    scale: float = 1.0          # stddev multiplier (normal) / fan-in override
    dtype: Any = jnp.float32

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


def _init_one(key, d: ParamDef) -> jax.Array:
    if d.init == "zeros":
        return jnp.zeros(d.shape, d.dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, d.dtype)
    if d.init == "embed":
        return (jax.random.normal(key, d.shape, jnp.float32) * d.scale).astype(d.dtype)
    if d.init == "normal":
        # truncated-normal fan-in scaling over the last dim's fan-in
        fan_in = d.shape[-2] if len(d.shape) >= 2 else d.shape[-1]
        std = d.scale / math.sqrt(max(fan_in, 1))
        w = jax.random.truncated_normal(key, -2.0, 2.0, d.shape, jnp.float32) * std
        return w.astype(d.dtype)
    raise ValueError(f"unknown init {d.init!r}")


def is_def(x) -> bool:
    return isinstance(x, ParamDef)


def init_params(key, defs) -> Any:
    """Materialize a pytree of ParamDef into arrays (deterministic per path)."""
    leaves, treedef = jax.tree_util.tree_flatten(defs, is_leaf=is_def)
    keys = jax.random.split(key, len(leaves))
    vals = [_init_one(k, d) for k, d in zip(keys, leaves)]
    return jax.tree_util.tree_unflatten(treedef, vals)


def abstract_params(defs) -> Any:
    """ShapeDtypeStruct stand-ins (dry-run: no allocation)."""
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype), defs, is_leaf=is_def)


def logical_tree(defs) -> Any:
    """Pytree of logical-axis tuples, same structure as the params."""
    return jax.tree.map(lambda d: d.logical, defs, is_leaf=is_def)


# ---------------------------------------------------------------------------
# Normalization / activations (fp32 islands — see policy.py).
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return out.astype(x.dtype)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    out = out * scale.astype(jnp.float32) + bias.astype(jnp.float32)
    return out.astype(x.dtype)


def act_fn(name: str, x: jax.Array) -> jax.Array:
    if name == "silu":
        return jax.nn.silu(x)
    if name == "gelu":
        return jax.nn.gelu(x, approximate=True)
    if name == "relu2":       # squared ReLU (nemotron-4)
        r = jax.nn.relu(x)
        return r * r
    if name == "relu":
        return jax.nn.relu(x)
    raise ValueError(f"unknown activation {name!r}")


# ---------------------------------------------------------------------------
# Rotary position embeddings.
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float = 10000.0) -> jax.Array:
    """Inverse frequencies, shape (head_dim // 2,) fp32."""
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0
               ) -> jax.Array:
    """Rotate ``x``: (..., S, H, D) with positions (..., S) broadcastable.

    Pairing convention: (x[..., :D/2], x[..., D/2:]) rotated jointly —
    llama-style "rotate_half".
    """
    d = x.shape[-1]
    inv = rope_freqs(d, theta)                           # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., S, D/2)
    cos = jnp.cos(ang)[..., None, :]                     # (..., S, 1, D/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding / unembedding.
# ---------------------------------------------------------------------------

def padded_vocab(vocab: int, multiple: int = 512) -> int:
    """Vocab padded for clean model-axis sharding (92553 → 92672 etc.).

    The pad columns are masked to -1e30 in :func:`unembed`, so they carry
    zero probability and zero gradient signal — loss/accuracy match the
    unpadded model exactly."""
    return -(-vocab // multiple) * multiple


def embed_defs(vocab: int, d_model: int, tie: bool = True,
               dtype=jnp.float32) -> Dict[str, ParamDef]:
    vp = padded_vocab(vocab)
    defs = {"tok": ParamDef((vp, d_model), ("vocab_out", "embed"),
                            init="embed", scale=0.02, dtype=dtype)}
    if not tie:
        defs["unembed"] = ParamDef((d_model, vp), ("embed", "vocab_out"),
                                   init="normal", dtype=dtype)
    return defs


def embed_lookup(emb: jax.Array, tokens: jax.Array,
                 seq_axis: Optional[str] = "tp_seq") -> jax.Array:
    x = jnp.take(emb, tokens, axis=0)
    return logical_constraint(x, "batch", seq_axis, "embed")


def unembed(x: jax.Array, params: Dict[str, jax.Array],
            vocab: int) -> jax.Array:
    """Project hidden states to logits (fp32); mask vocab-padding columns."""
    if "unembed" in params:
        w = params["unembed"]
        logits = jnp.einsum("bsd,dv->bsv", x.astype(jnp.float32),
                            w.astype(jnp.float32))
    else:
        logits = jnp.einsum("bsd,vd->bsv", x.astype(jnp.float32),
                            params["tok"].astype(jnp.float32))
    vp = logits.shape[-1]
    if vp != vocab:
        pad_mask = jnp.arange(vp) >= vocab
        logits = jnp.where(pad_mask, -1e30, logits)
    return logical_constraint(logits, "batch", "seq", "vocab_out")


def softmax_xent(logits: jax.Array, labels: jax.Array,
                 mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean next-token cross-entropy in fp32."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def fused_unembed_xent(x: jax.Array, params: Dict[str, jax.Array], vocab: int,
                       labels: jax.Array, mask: Optional[jax.Array] = None,
                       chunk: int = 512, unroll: bool = False) -> jax.Array:
    """Unembed + cross-entropy fused over sequence chunks.

    The (B, S, V) fp32 logits tensor of a 256k-vocab model is several GB per
    device and its cotangent doubles that; scanning ``chunk`` positions at a
    time (body checkpointed) keeps the live footprint at
    (B, chunk, V_shard) while producing the identical mean loss."""
    B, S, D = x.shape
    if mask is None:
        mask = jnp.ones((B, S), jnp.float32)
    chunk = min(chunk, S)
    nb = -(-S // chunk)
    pad = nb * chunk - S
    xp = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    lp = jnp.pad(labels, ((0, 0), (0, pad)))
    mp = jnp.pad(mask.astype(jnp.float32), ((0, 0), (0, pad)))
    xb = jnp.moveaxis(xp.reshape(B, nb, chunk, D), 1, 0)
    lb = jnp.moveaxis(lp.reshape(B, nb, chunk), 1, 0)
    mb = jnp.moveaxis(mp.reshape(B, nb, chunk), 1, 0)

    def body(carry, xs):
        nll_sum, cnt = carry
        xc, lc, mc = xs
        logits = unembed(xc, params, vocab)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        nll_sum = nll_sum + jnp.sum((logz - gold) * mc)
        return (nll_sum, cnt + jnp.sum(mc)), None

    body = jax.checkpoint(body)
    (nll, cnt), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (xb, lb, mb), unroll=unroll)
    return nll / jnp.maximum(cnt, 1.0)
