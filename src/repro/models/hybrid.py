"""Zamba2-style hybrid: Mamba2 backbone + one SHARED attention block.

``cfg.n_layers`` Mamba2 blocks; after every ``cfg.hybrid_period`` of them the
single shared transformer block (attention + MLP, one parameter set) is
applied — Zamba's weight-sharing trick.  Each of the
``n_layers // hybrid_period`` invocations keeps its own KV cache.

Decode stays sub-quadratic: SSM state is O(1) and the shared-attention
caches are the only seq_len-sized state, so ``long_500k`` runs here.
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.fixed_point import QuantStats
from repro.models import ssm as ssm_lib
from repro.dist.sharding import logical_constraint
from repro.models.common import (ParamDef, embed_defs, embed_lookup,
                                 fused_unembed_xent, rms_norm, softmax_xent,
                                 unembed)
from repro.models.transformer import (_block, _dtype, layer_defs as
                                      attn_block_defs, stack_defs)


def n_shared_invocations(cfg: ModelConfig) -> int:
    return cfg.n_layers // cfg.hybrid_period


def _split(cfg: ModelConfig):
    """(n_groups, group_size, remainder) of the mamba stack."""
    g = n_shared_invocations(cfg)
    k = cfg.hybrid_period
    return g, k, cfg.n_layers - g * k


def model_defs(cfg: ModelConfig) -> Dict[str, Any]:
    dt = _dtype(cfg)
    mamba_layer = {
        "norm": ParamDef((cfg.d_model,), (None,), init="ones", dtype=jnp.float32),
        "ssm": ssm_lib.ssm_defs(cfg, dt),
    }
    return {
        "embed": embed_defs(cfg.vocab, cfg.d_model, tie=cfg.tie_embed, dtype=dt),
        "mamba": stack_defs(cfg.n_layers, mamba_layer),
        "shared": attn_block_defs(cfg),       # ONE shared attn+MLP block
        "final_norm": ParamDef((cfg.d_model,), (None,), init="ones",
                               dtype=jnp.float32),
    }


def cache_struct(cfg: ModelConfig, batch: int, max_seq: int):
    L, G = cfg.n_layers, n_shared_invocations(cfg)
    H, P = ssm_lib.n_ssm_heads(cfg), cfg.ssm_head_dim
    cc = ssm_lib.conv_channels(cfg)
    dt = jnp.int8 if cfg.kv_cache_bits == 8 else _dtype(cfg)
    kv = (G, batch, max_seq, cfg.n_kv_heads, cfg.head_dim)
    return {
        "ssm": (jax.ShapeDtypeStruct((L, batch, H, P, cfg.ssm_state), jnp.float32),
                jax.ShapeDtypeStruct((L, batch, cfg.ssm_conv - 1, cc), jnp.float32)),
        "attn": (jax.ShapeDtypeStruct(kv, dt), jax.ShapeDtypeStruct(kv, dt)),
    }


def cache_logical(cfg: ModelConfig):
    sp = ("layers", "batch", "kv_seq", "kv", "head_dim")
    return {
        "ssm": (("layers", "batch", "heads", None, None),
                ("layers", "batch", None, "tp")),
        "attn": (sp, sp),
    }


def init_cache(cfg: ModelConfig, batch: int, max_seq: int):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        cache_struct(cfg, batch, max_seq))


def _mamba_scan(cfg, layers, caches, x, idx0, *, mode, qctx):
    """Scan a stacked slice of mamba layers.  Returns (x, caches, stats)."""

    def body(carry, xs):
        h, stats_acc = carry
        p, idx, layer_cache = xs
        out, new_cache = ssm_lib.ssm_apply(
            cfg, p["ssm"], rms_norm(h, p["norm"]), mode=mode, cache=layer_cache)
        h = h + out
        stats = QuantStats.zero()
        if qctx is not None:
            h, stats = qctx.tap(h, idx)
            stats = stats if stats is not None else QuantStats.zero()
        h = logical_constraint(h, "batch", "tp_seq", "embed")  # SP carry
        return (h, stats_acc.merge(stats)), new_cache

    if cfg.remat in ("full", "dots"):
        pol = (jax.checkpoint_policies.nothing_saveable if cfg.remat == "full"
               else jax.checkpoint_policies.checkpoint_dots)
        body = jax.checkpoint(body, policy=pol)

    n = jax.tree_util.tree_leaves(layers)[0].shape[0]
    idxs = idx0 + jnp.arange(n, dtype=jnp.uint32)
    (x, stats), new_caches = jax.lax.scan(body, (x, QuantStats.zero()),
                                          (layers, idxs, caches),
                                          unroll=cfg.probe_unroll)
    return x, new_caches, stats


def forward(cfg: ModelConfig, params, tokens, *, qctx=None, mode="train",
            cache=None, cache_pos=None, vision_embeds=None,
            hidden_only=False):
    x = embed_lookup(params["embed"]["tok"], tokens).astype(_dtype(cfg))
    B, S, _ = x.shape
    G, K, rem = _split(cfg)
    if cache is None:
        cache = init_cache(cfg, B, 0)

    if mode == "decode":
        positions = cache_pos[:, None]
    else:
        positions = jnp.arange(S, dtype=jnp.int32)[None, :]

    # split the mamba stack into G groups of K plus a remainder
    grouped = jax.tree.map(lambda a: a[:G * K].reshape((G, K) + a.shape[1:]),
                           params["mamba"])
    tail = jax.tree.map(lambda a: a[G * K:], params["mamba"])
    ssm_g = jax.tree.map(lambda a: a[:G * K].reshape((G, K) + a.shape[1:]),
                         cache["ssm"])
    ssm_t = jax.tree.map(lambda a: a[G * K:], cache["ssm"])

    stats_total = QuantStats.zero()
    aux = jnp.zeros((), jnp.float32)

    def group_body(carry, xs):
        h, stats_acc = carry
        gp, g_idx, g_ssm, g_attn = xs
        h, new_ssm, stats = _mamba_scan(cfg, gp, g_ssm, h, g_idx * K,
                                        mode=mode, qctx=qctx)
        h, new_attn, aux_l, stats2 = _block(
            cfg, params["shared"], h, positions=positions, mode=mode,
            cache=g_attn, cache_pos=cache_pos, qctx=qctx,
            layer_idx=jnp.uint32(10_000) + g_idx)
        return (h, stats_acc.merge(stats).merge(stats2)), (new_ssm, new_attn)

    if cfg.remat in ("full", "dots"):
        # the OUTER group scan must be remat'd too, or its per-group
        # residuals (13 × multi-GB) dominate train-step memory
        pol = (jax.checkpoint_policies.nothing_saveable if cfg.remat == "full"
               else jax.checkpoint_policies.checkpoint_dots)
        group_body = jax.checkpoint(group_body, policy=pol)

    g_idxs = jnp.arange(G, dtype=jnp.uint32)
    (x, stats_total), (new_ssm_g, new_attn) = jax.lax.scan(
        group_body, (x, stats_total), (grouped, g_idxs, ssm_g, cache["attn"]),
        unroll=cfg.probe_unroll)

    if rem:
        x, new_ssm_t, stats = _mamba_scan(cfg, tail, ssm_t, x, G * K,
                                          mode=mode, qctx=qctx)
        stats_total = stats_total.merge(stats)
    else:
        new_ssm_t = ssm_t

    new_cache = None
    if mode in ("prefill", "decode"):
        flat = jax.tree.map(
            lambda g, t: jnp.concatenate(
                [g.reshape((G * K,) + g.shape[2:]), t]), new_ssm_g, new_ssm_t)
        new_cache = {"ssm": flat, "attn": new_attn}

    x = rms_norm(x, params["final_norm"])
    if hidden_only:
        return x, new_cache, aux, stats_total
    if mode == "prefill":
        x = x[:, -1:]
    logits = unembed(x, params["embed"], cfg.vocab)
    return logits, new_cache, aux, stats_total


def loss_fn(cfg: ModelConfig):
    def fn(params, batch, qctx=None):
        tokens = batch["tokens"]
        hidden, _, _, stats = forward(cfg, params, tokens[:, :-1], qctx=qctx,
                                      hidden_only=True)
        loss = fused_unembed_xent(hidden, params["embed"], cfg.vocab,
                                  tokens[:, 1:], batch.get("loss_mask"),
                                  unroll=cfg.probe_unroll)
        return loss, {"act_stats": stats}
    return fn


def prefill(cfg: ModelConfig, params, tokens, max_seq: int, *, qctx=None,
            vision_embeds=None):
    logits, cache, _, _ = forward(cfg, params, tokens, qctx=qctx, mode="prefill")
    S = tokens.shape[1]
    pad = max_seq - S
    cache["attn"] = jax.tree.map(
        lambda c: jnp.pad(c, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))),
        cache["attn"])
    pos = jnp.full((tokens.shape[0],), S, jnp.int32)
    return logits[:, -1], cache, pos


def decode_step(cfg: ModelConfig, params, tokens, cache, pos, qctx=None):
    logits, new_cache, _, _ = forward(cfg, params, tokens, qctx=qctx,
                                      mode="decode", cache=cache, cache_pos=pos)
    return logits[:, -1], new_cache


def count_params(cfg: ModelConfig) -> float:
    from repro.models import attention as attn_lib
    from repro.models.mlp import count_mlp_params
    mamba = cfg.n_layers * (cfg.d_model + ssm_lib.count_ssm_params(cfg))
    shared = (2 * cfg.d_model + attn_lib.count_gqa_params(cfg)
              + count_mlp_params(cfg.d_model, cfg.d_ff, cfg.gated_mlp))
    total = mamba + shared + cfg.d_model
    total += cfg.vocab * cfg.d_model * (1 if cfg.tie_embed else 2)
    return float(total)
