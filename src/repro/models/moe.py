"""Mixture-of-Experts: top-k routing with two dispatch strategies.

``moe_apply`` picks, statically at trace time, between:

  * **einsum dispatch** — GShard-style one-hot dispatch/combine einsums,
    O(T·E·C) memory.  Used for decode (T = batch), smoke tests, and any
    un-meshed run.  No collectives of its own; XLA shards the einsums.

  * **all-to-all dispatch** (``shard_map``) — the production path.  Tokens
    are sharded over (pod·data) × model (sequence-parallel residual);
    each rank computes its local top-k, packs per-expert capacity buffers,
    and two ``lax.all_to_all``s over the model axis move tokens to their
    expert's owner and back (expert parallelism).  Capacity-bounded and
    dropping, with the Switch-style load-balance auxiliary loss.

Router logits/probs stay fp32 and are excluded from DPS quantization
(see ``repro.core.policy``): reordering top-k under rounding noise
destabilizes expert assignment.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.dist.sharding import current_mesh_rules, logical_constraint
from repro.models.common import ParamDef, act_fn
from repro.models.mlp import mlp_apply, mlp_defs


def moe_defs(cfg: ModelConfig, dtype) -> Dict[str, ParamDef]:
    D, E, F = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    defs = {
        # router stays fp32 (policy fp32 island)
        "router": ParamDef((D, E), (None, None), dtype=jnp.float32),
        "w_in": ParamDef((E, D, F), ("expert", "fsdp", None), dtype=dtype),
        "w_gate": ParamDef((E, D, F), ("expert", "fsdp", None), dtype=dtype),
        "w_out": ParamDef((E, F, D), ("expert", None, "fsdp"), dtype=dtype),
    }
    if cfg.n_shared_experts:
        defs["shared"] = mlp_defs(D, cfg.n_shared_experts * F, True, dtype)
    return defs


def _router(cfg: ModelConfig, router_w: jax.Array, x: jax.Array):
    """fp32 top-k routing.  x: (T, D) -> (weights (T,K), idx (T,K), probs)."""
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32),
                        router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_i = jax.lax.top_k(probs, cfg.top_k)
    top_w = top_w / jnp.maximum(jnp.sum(top_w, -1, keepdims=True), 1e-9)
    return top_w, top_i, probs


def _aux_fp(cfg: ModelConfig, probs: jax.Array, top_i: jax.Array):
    """Load-balance ingredients: f_e (dispatch fraction) and p̄_e (mean
    router prob).  Kept separate so sharded callers can average them across
    ranks BEFORE the (nonlinear) product."""
    E = cfg.n_experts
    f = jnp.mean(jax.nn.one_hot(top_i, E, dtype=jnp.float32), axis=(0, 1))
    p = jnp.mean(probs, axis=0)
    return f, p


def _aux_loss(cfg: ModelConfig, probs: jax.Array, top_i: jax.Array):
    """Switch load-balance loss: E * Σ_e f_e · p̄_e."""
    f, p = _aux_fp(cfg, probs, top_i)
    return cfg.n_experts * jnp.sum(f * p) * cfg.top_k


_A2A_IL, _A2A_FL = 4, 4       # int8 wire grid: range ±8, step 1/16


def _a2a_pack(x: jax.Array) -> jax.Array:
    span = float(1 << (_A2A_IL - 1 + _A2A_FL))
    y = jnp.clip(x.astype(jnp.float32) * (1 << _A2A_FL), -span, span - 1)
    return jnp.round(y).astype(jnp.int8)


def _a2a_unpack(q: jax.Array, dtype) -> jax.Array:
    return (q.astype(jnp.float32) * (1.0 / (1 << _A2A_FL))).astype(dtype)


def _capacity(n_tokens: int, cfg: ModelConfig) -> int:
    c = math.ceil(n_tokens * cfg.top_k / cfg.n_experts * cfg.capacity_factor)
    return max(8, -(-c // 8) * 8)   # static, 8-aligned


# ---------------------------------------------------------------------------
# Path 1: one-hot einsum dispatch (small T / no mesh).
# ---------------------------------------------------------------------------

def _moe_einsum(cfg: ModelConfig, p, x2: jax.Array):
    T, D = x2.shape
    E, C = cfg.n_experts, _capacity(T, cfg)
    top_w, top_i, probs = _router(cfg, p["router"], x2)

    # position of each (token, k) inside its expert's capacity buffer
    onehot = jax.nn.one_hot(top_i, E, dtype=jnp.int32)          # (T, K, E)
    flat = onehot.reshape(T * cfg.top_k, E)
    pos = jnp.cumsum(flat, axis=0) - flat                        # (T*K, E)
    pos = jnp.sum(pos * flat, axis=-1).reshape(T, cfg.top_k)     # (T, K)
    keep = pos < C
    disp = (jax.nn.one_hot(top_i, E, dtype=x2.dtype)[..., :, None]
            * jax.nn.one_hot(pos, C, dtype=x2.dtype)[..., None, :]
            * keep[..., None, None].astype(x2.dtype))            # (T,K,E,C)
    comb = disp * top_w[..., None, None].astype(x2.dtype)
    disp = jnp.sum(disp, axis=1)                                 # (T, E, C)
    comb = jnp.sum(comb, axis=1)

    buf = jnp.einsum("tec,td->ecd", disp, x2)                    # (E, C, D)
    h = jnp.einsum("ecd,edf->ecf", buf, p["w_in"])
    g = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])
    h = act_fn(cfg.act, g) * h
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["w_out"])
    out = jnp.einsum("tec,ecd->td", comb, out_buf)
    return out, _aux_loss(cfg, probs, top_i)


# ---------------------------------------------------------------------------
# Path 2: shard_map + all_to_all expert parallelism (production).
# ---------------------------------------------------------------------------

def _moe_a2a_local(cfg: ModelConfig, mesh_axes, batch_axes, x_l, router_w,
                   w_in, w_gate, w_out):
    """Per-rank body under shard_map.

    x_l: (B_l, S_l, D) local tokens.  Expert weights are local shards
    (E_l, D, F).  Two all_to_alls over the "model" axis implement
    dispatch/combine.
    """
    B_l, S_l, D = x_l.shape
    T_l = B_l * S_l
    x2 = x_l.reshape(T_l, D)
    E = cfg.n_experts
    m = jax.lax.axis_size("model")
    E_l = E // m
    C = _capacity(T_l, cfg)

    top_w, top_i, probs = _router(cfg, router_w, x2)
    f, pbar = _aux_fp(cfg, probs, top_i)
    f = jax.lax.pmean(f, mesh_axes)          # average BEFORE the product:
    pbar = jax.lax.pmean(pbar, mesh_axes)    # Σ f̄·p̄ ≠ mean(Σ f·p)
    aux = cfg.n_experts * jnp.sum(f * pbar) * cfg.top_k

    # slot assignment (token-major priority, drop beyond capacity)
    onehot = jax.nn.one_hot(top_i, E, dtype=jnp.int32)            # (T,K,E)
    flat = onehot.reshape(T_l * cfg.top_k, E)
    pos = (jnp.cumsum(flat, axis=0) - flat)
    pos = jnp.sum(pos * flat, axis=-1)                            # (T*K,)
    eidx = top_i.reshape(-1)
    keep = pos < C
    slot = jnp.where(keep, eidx * C + pos, E * C)                 # drop row

    # pack local capacity buffers (E*C+1 rows; last row swallows drops)
    buf = jnp.zeros((E * C + 1, D), x2.dtype)
    tok_rows = jnp.repeat(x2, cfg.top_k, axis=0)                  # (T*K, D)
    buf = buf.at[slot].add(tok_rows)
    buf = buf[:-1].reshape(E, C, D)

    # dispatch: every rank sends each expert-owner its C-slot block.
    # With moe_a2a_bits == 8 the payload is snapped to the DPS ⟨4,4⟩ grid
    # and moved as int8 — the paper's quantizer on the expert-parallel wire
    # (2× all-to-all bytes vs bf16; error bounded by one grid step).
    wire_int8 = cfg.moe_a2a_bits == 8
    if wire_int8:
        buf = _a2a_pack(buf)
    buf = jax.lax.all_to_all(buf, "model", split_axis=0, concat_axis=1,
                             tiled=True)                          # (E_l, m*C, D)
    if wire_int8:
        buf = _a2a_unpack(buf, x_l.dtype)
    h = jnp.einsum("ecd,edf->ecf", buf, w_in)
    g = jnp.einsum("ecd,edf->ecf", buf, w_gate)
    h = act_fn(cfg.act, g) * h
    out_buf = jnp.einsum("ecf,efd->ecd", h, w_out)                # (E_l, m*C, D)
    if wire_int8:
        out_buf = _a2a_pack(out_buf)
    out_buf = jax.lax.all_to_all(out_buf, "model", split_axis=1,
                                 concat_axis=0, tiled=True)       # (E, C, D)
    if wire_int8:
        out_buf = _a2a_unpack(out_buf, x_l.dtype)

    # combine: gather each token's k slots, weight, sum
    out_rows = jnp.concatenate(
        [out_buf.reshape(E * C, D), jnp.zeros((1, D), x2.dtype)])
    gathered = out_rows[slot].reshape(T_l, cfg.top_k, D)
    w = (top_w * keep.reshape(T_l, cfg.top_k)).astype(x2.dtype)
    out = jnp.einsum("tk,tkd->td", w, gathered)
    return out.reshape(B_l, S_l, D), aux


def _moe_a2a(cfg: ModelConfig, p, x: jax.Array, mesh):
    names = mesh.axis_names
    batch_axes = tuple(a for a in ("pod", "data") if a in names)
    mesh_axes = tuple(a for a in names)
    body = partial(_moe_a2a_local, cfg, mesh_axes, batch_axes)
    out, aux = jax.shard_map(
        body, mesh=mesh,
        in_specs=(P(batch_axes or None, "model", None),   # x: batch × seq(SP)
                  P(None, None),                           # router replicated
                  P("model", None, None), P("model", None, None),
                  P("model", None, None)),
        out_specs=(P(batch_axes or None, "model", None), P()),
        check_vma=False,
    )(x, p["router"], p["w_in"], p["w_gate"], p["w_out"])
    return out, aux


# ---------------------------------------------------------------------------
# Entry point.
# ---------------------------------------------------------------------------

def moe_apply(cfg: ModelConfig, p: Dict[str, jax.Array], x: jax.Array
              ) -> Tuple[jax.Array, jax.Array]:
    """Returns (out (B,S,D), aux_loss scalar)."""
    B, S, D = x.shape
    mesh, _ = current_mesh_rules()
    use_a2a = False
    if mesh is not None and "model" in mesh.axis_names:
        m = dict(zip(mesh.axis_names, mesh.devices.shape))["model"]
        bsz = math.prod(s for a, s in zip(mesh.axis_names, mesh.devices.shape)
                        if a in ("pod", "data"))
        use_a2a = (m > 1 and S % m == 0 and B % max(bsz, 1) == 0
                   and cfg.n_experts % m == 0)
    if use_a2a:
        out, aux = _moe_a2a(cfg, p, x, mesh)
    else:
        out2, aux = _moe_einsum(cfg, p, x.reshape(B * S, D))
        out = out2.reshape(B, S, D)

    if cfg.n_shared_experts:
        out = out + mlp_apply(cfg, p["shared"], x)
    return logical_constraint(out, "batch", "tp_seq", "embed"), aux


def count_moe_params(cfg: ModelConfig) -> int:
    D, E, F = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    n = D * E + E * D * F * 3
    if cfg.n_shared_experts:
        n += 3 * D * cfg.n_shared_experts * F
    return n


def count_moe_active_params(cfg: ModelConfig) -> int:
    D, F = cfg.d_model, cfg.moe_d_ff
    n = D * cfg.n_experts + cfg.top_k * D * F * 3
    if cfg.n_shared_experts:
        n += 3 * D * cfg.n_shared_experts * F
    return n
