"""LeNet (Caffe variant) — the paper's evaluation network (§4).

conv(5×5, 20) → maxpool2 → conv(5×5, 50) → maxpool2 → fc(500) + ReLU →
fc(10).  Activations are tapped (quantize + stats) after every layer, as in
the paper's custom Caffe rounding layers; the last-layer logit gradient is
quantized analytically in the loss so Alg. 1's "Calculate E and R for last
layer Gradients" is reproduced exactly.
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.core import fixed_point as fxp
from repro.core.fixed_point import QuantStats
from repro.models.common import ParamDef, init_params


def model_defs() -> Dict[str, Any]:
    return {
        "conv1_w": ParamDef((5, 5, 1, 20), (None, None, None, None), scale=1.0),
        "conv1_b": ParamDef((20,), (None,), init="zeros"),
        "conv2_w": ParamDef((5, 5, 20, 50), (None, None, None, None), scale=1.0),
        "conv2_b": ParamDef((50,), (None,), init="zeros"),
        "fc1_w": ParamDef((4 * 4 * 50, 500), ("fsdp", "tp")),
        "fc1_b": ParamDef((500,), (None,), init="zeros"),
        "fc2_w": ParamDef((500, 10), (None, None)),
        "fc2_b": ParamDef((10,), (None,), init="zeros"),
    }


def _conv(x, w, b):
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + b


def _pool(x):
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, (1, 2, 2, 1),
                                 (1, 2, 2, 1), "VALID")


def forward(params, images: jax.Array, qctx=None):
    """images (B, 28, 28, 1) -> (logits (B, 10), act_stats, last_stats).

    ``last_stats`` is the final (logit) tap alone — Alg. 1 line 13
    ("Calculate E and R for last layer Activations")."""
    stats = QuantStats.zero()
    last = QuantStats.zero()

    def tap(x, salt):
        nonlocal stats, last
        if qctx is None:
            return x
        q, s = qctx.tap(x, salt)
        if s is not None:
            stats = stats.merge(s)
            last = s
        return q

    x = tap(_pool(_conv(images, params["conv1_w"], params["conv1_b"])), "c1")
    x = tap(_pool(_conv(x, params["conv2_w"], params["conv2_b"])), "c2")
    x = x.reshape(x.shape[0], -1)
    x = tap(jax.nn.relu(x @ params["fc1_w"] + params["fc1_b"]), "f1")
    logits = x @ params["fc2_w"] + params["fc2_b"]
    logits = tap(logits, "f2")
    return logits, stats, last


def loss_fn(params, batch, qctx=None):
    logits, act_stats, last_stats = forward(params, batch["images"], qctx)
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32))
    loss = -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))
    aux = {"act_stats": act_stats, "last_act_stats": last_stats,
           "acc": jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))}

    # paper Alg. 1 line 20: E and R of the LAST LAYER gradient.  dL/dlogits
    # has the closed form (softmax - onehot)/B; quantize it for stats only.
    if qctx is not None and qctx.collect_stats:
        p = jax.nn.softmax(logits.astype(jnp.float32))
        dlogits = (p - jax.nn.one_hot(labels, 10)) / logits.shape[0]
        dlogits = jax.lax.stop_gradient(dlogits)
        _, gstats = fxp.quantize(dlogits, qctx.grads_fmt, mode=qctx.rounding,
                                 key=jax.random.fold_in(qctx.key, 0xD106))
        aux["dlogits_stats"] = gstats
    return loss, aux


def init(key):
    return init_params(key, model_defs())
