"""Attention-free Mamba2 LM (the ``ssm`` family; mamba2-1.3b).

Embed → L × [pre-norm residual SSD block] → final norm → unembed.
Decode state is O(1) per token, so the ``long_500k`` cell runs here.
"""

from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.fixed_point import QuantStats
from repro.models import ssm as ssm_lib
from repro.dist.sharding import logical_constraint
from repro.models.common import (ParamDef, embed_defs, embed_lookup,
                                 fused_unembed_xent, rms_norm, softmax_xent,
                                 unembed)
from repro.models.transformer import stack_defs, _dtype


def model_defs(cfg: ModelConfig) -> Dict[str, Any]:
    dt = _dtype(cfg)
    layer = {
        "norm": ParamDef((cfg.d_model,), (None,), init="ones", dtype=jnp.float32),
        "ssm": ssm_lib.ssm_defs(cfg, dt),
    }
    return {
        "embed": embed_defs(cfg.vocab, cfg.d_model, tie=cfg.tie_embed, dtype=dt),
        "layers": stack_defs(cfg.n_layers, layer),
        "final_norm": ParamDef((cfg.d_model,), (None,), init="ones",
                               dtype=jnp.float32),
    }


def cache_struct(cfg: ModelConfig, batch: int, max_seq: int):
    """SSM decode cache: (state, conv_tail) per layer — O(1) in seq_len."""
    L = cfg.n_layers
    H, P = ssm_lib.n_ssm_heads(cfg), cfg.ssm_head_dim
    cc = ssm_lib.conv_channels(cfg)
    return (
        jax.ShapeDtypeStruct((L, batch, H, P, cfg.ssm_state), jnp.float32),
        jax.ShapeDtypeStruct((L, batch, cfg.ssm_conv - 1, cc), jnp.float32),
    )


def cache_logical(cfg: ModelConfig):
    return (("layers", "batch", "heads", None, None),
            ("layers", "batch", None, "tp"))


def init_cache(cfg: ModelConfig, batch: int, max_seq: int = 0):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        cache_struct(cfg, batch, max_seq))


def _run_stack(cfg, layers, x, *, mode, cache, qctx):
    def body(carry, xs):
        h, stats_acc = carry
        p, idx, layer_cache = xs
        out, new_cache = ssm_lib.ssm_apply(
            cfg, p["ssm"], rms_norm(h, p["norm"]), mode=mode,
            cache=layer_cache)
        h = h + out
        stats = QuantStats.zero()
        if qctx is not None:
            h, stats = qctx.tap(h, idx)
            stats = stats if stats is not None else QuantStats.zero()
        # sequence-parallel carry: the layer-scan residual is the backward
        # pass's dominant saved tensor; sharding it on the model axis divides
        # that footprint by the TP degree (SSM internals re-gather as needed)
        h = logical_constraint(h, "batch", "tp_seq", "embed")
        return (h, stats_acc.merge(stats)), new_cache

    if cfg.remat == "full":
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    elif cfg.remat == "dots":
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.checkpoint_dots)

    idxs = jnp.arange(cfg.n_layers, dtype=jnp.uint32)
    (x, stats), new_cache = jax.lax.scan(body, (x, QuantStats.zero()),
                                         (layers, idxs, cache),
                                         unroll=cfg.probe_unroll)
    if mode == "train":
        new_cache = None
    return x, new_cache, stats


def forward(cfg: ModelConfig, params, tokens, *, qctx=None, mode="train",
            cache=None, cache_pos=None, vision_embeds=None,
            hidden_only=False):
    x = embed_lookup(params["embed"]["tok"], tokens, seq_axis=None).astype(_dtype(cfg))
    B = x.shape[0]
    if cache is None:
        cache = init_cache(cfg, B)
    x, new_cache, stats = _run_stack(cfg, params["layers"], x, mode=mode,
                                     cache=cache, qctx=qctx)
    x = rms_norm(x, params["final_norm"])
    if hidden_only:
        return x, new_cache, jnp.zeros((), jnp.float32), stats
    if mode == "prefill":
        x = x[:, -1:]
    logits = unembed(x, params["embed"], cfg.vocab)
    return logits, new_cache, jnp.zeros((), jnp.float32), stats


def loss_fn(cfg: ModelConfig):
    def fn(params, batch, qctx=None):
        tokens = batch["tokens"]
        hidden, _, _, stats = forward(cfg, params, tokens[:, :-1], qctx=qctx,
                                      hidden_only=True)
        loss = fused_unembed_xent(hidden, params["embed"], cfg.vocab,
                                  tokens[:, 1:], batch.get("loss_mask"),
                                  unroll=cfg.probe_unroll)
        return loss, {"act_stats": stats}
    return fn


def prefill(cfg: ModelConfig, params, tokens, max_seq: int, *, qctx=None,
            vision_embeds=None):
    logits, cache, _, _ = forward(cfg, params, tokens, qctx=qctx,
                                  mode="prefill")
    B = tokens.shape[0]
    pos = jnp.full((B,), tokens.shape[1], jnp.int32)
    return logits[:, -1], cache, pos


def decode_step(cfg: ModelConfig, params, tokens, cache, pos, qctx=None):
    logits, new_cache, _, _ = forward(cfg, params, tokens, qctx=qctx,
                                      mode="decode", cache=cache,
                                      cache_pos=pos)
    return logits[:, -1], new_cache


def count_params(cfg: ModelConfig) -> float:
    per_layer = cfg.d_model + ssm_lib.count_ssm_params(cfg)
    total = cfg.n_layers * per_layer + cfg.d_model
    total += cfg.vocab * cfg.d_model * (1 if cfg.tie_embed else 2)
    return float(total)
