"""Mamba2 (SSD — state-space duality) block, chunked-scan formulation.

Implements the SSD algorithm of Dao & Gu '24 (arXiv:2405.21060): the
sequence is split into chunks of ``ssm_chunk``; within a chunk the output is
a masked (decay-weighted) attention-like matmul, across chunks a small
recurrence carries the (H, P, N) state.  Train/prefill cost is
O(S·Q·(P+N)) — sub-quadratic in S — and decode is an O(1) state update,
which is why the ssm/hybrid archs own the ``long_500k`` cell.

Numerics: the recurrent state, per-step decays, A_log and dt_bias stay fp32
(policy carve-out — fixed-point emulation of a 500k-step recurrence
underflows at 2^-FL; the paper's §5 anticipates exactly this failure mode).
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist.sharding import logical_constraint
from repro.models.common import ParamDef, rms_norm


def d_inner(cfg: ModelConfig) -> int:
    return cfg.ssm_expand * cfg.d_model


def n_ssm_heads(cfg: ModelConfig) -> int:
    return d_inner(cfg) // cfg.ssm_head_dim


def conv_channels(cfg: ModelConfig) -> int:
    return d_inner(cfg) + 2 * cfg.ssm_state


def ssm_defs(cfg: ModelConfig, dtype) -> Dict[str, ParamDef]:
    D, N = cfg.d_model, cfg.ssm_state
    di, H = d_inner(cfg), n_ssm_heads(cfg)
    cc = conv_channels(cfg)
    return {
        # in_proj emits [z, x, B, C, dt]
        "w_in": ParamDef((D, 2 * di + 2 * N + H), ("fsdp", "tp"), dtype=dtype),
        "conv_w": ParamDef((cfg.ssm_conv, cc), (None, "tp"), scale=1.0, dtype=dtype),
        "conv_b": ParamDef((cc,), ("tp",), init="zeros", dtype=dtype),
        "a_log": ParamDef((H,), (None,), init="zeros", dtype=jnp.float32),
        "dt_bias": ParamDef((H,), (None,), init="zeros", dtype=jnp.float32),
        "d_skip": ParamDef((H,), (None,), init="ones", dtype=jnp.float32),
        "norm_scale": ParamDef((di,), ("tp",), init="ones", dtype=jnp.float32),
        "w_out": ParamDef((di, D), ("tp", "fsdp"), dtype=dtype),
    }


def _split_proj(cfg: ModelConfig, zxbcdt: jax.Array):
    di, N, H = d_inner(cfg), cfg.ssm_state, n_ssm_heads(cfg)
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di:di + di + 2 * N]
    dt = zxbcdt[..., di + di + 2 * N:]
    return z, xbc, dt


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array,
                 state: Optional[jax.Array] = None):
    """Depthwise causal conv over seq.  xbc (B,S,C), w (K,C).

    With ``state`` (B, K-1, C) — decode path — prepends the cached tail and
    returns the updated tail."""
    K = w.shape[0]
    if state is not None:
        full = jnp.concatenate([state.astype(xbc.dtype), xbc], axis=1)
    else:
        full = jnp.pad(xbc, ((0, 0), (K - 1, 0), (0, 0)))
    windows = jnp.stack([full[:, i:i + xbc.shape[1]] for i in range(K)], 0)
    out = jnp.einsum("kbsc,kc->bsc", windows, w) + b
    new_state = full[:, -(K - 1):] if K > 1 else None
    return jax.nn.silu(out), new_state


def _decays(cfg: ModelConfig, dt_raw: jax.Array, a_log: jax.Array,
            dt_bias: jax.Array):
    """Per-(step, head) dt and log-decay, fp32.  dt_raw (..., H)."""
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + dt_bias)
    a = -jnp.exp(a_log.astype(jnp.float32))
    log_decay = dt * a                      # <= 0
    return dt, log_decay


def ssd_scan(cfg: ModelConfig, x: jax.Array, b_mat: jax.Array, c_mat: jax.Array,
             dt: jax.Array, log_decay: jax.Array,
             h0: Optional[jax.Array] = None):
    """Chunked SSD.  x (B,S,H,P); b,c (B,S,N); dt/log_decay (B,S,H) fp32.

    Returns (y (B,S,H,P), h_final (B,H,P,N) fp32)."""
    B, S, H, Pd = x.shape
    N = b_mat.shape[-1]
    Q = min(cfg.ssm_chunk, S)
    S_orig = S
    if S % Q:
        # pad to the chunk grid: zero x/B/C (no state contribution) and zero
        # log_decay (decay factor 1 — final state unaffected)
        pad = Q - S % Q
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        b_mat = jnp.pad(b_mat, ((0, 0), (0, pad), (0, 0)))
        c_mat = jnp.pad(c_mat, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        log_decay = jnp.pad(log_decay, ((0, 0), (0, pad), (0, 0)))
        S = S + pad
    nc = S // Q

    xr = (x * dt[..., None].astype(x.dtype)).reshape(B, nc, Q, H, Pd)
    br = b_mat.reshape(B, nc, Q, N)
    cr = c_mat.reshape(B, nc, Q, N)
    ld = log_decay.reshape(B, nc, Q, H)
    # heads shard on the model axis (B/C are head-shared and stay replicated);
    # the O(Q²·H) intra-chunk tensors below are the SSD memory hot spot
    xr = logical_constraint(xr, "batch", None, None, "heads", None)
    ld = logical_constraint(ld, "batch", None, None, "heads")
    cum = jnp.cumsum(ld, axis=2)                        # (B,nc,Q,H)
    total = cum[:, :, -1]                               # (B,nc,H)

    # --- intra-chunk (quadratic in Q only) ---
    cb = jnp.einsum("bcqn,bckn->bcqk", cr.astype(jnp.float32),
                    br.astype(jnp.float32))
    rel = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,nc,Q,Q,H) t-s
    tri = jnp.tril(jnp.ones((Q, Q), jnp.float32))
    decay_m = jnp.exp(rel) * tri[None, None, :, :, None]
    m = cb[..., None] * decay_m                          # (B,nc,Q,Q,H)
    y_intra = jnp.einsum("bcqkh,bckhp->bcqhp", m, xr.astype(jnp.float32))

    # --- chunk states ---
    w_state = jnp.exp(total[:, :, None, :] - cum)        # (B,nc,Q,H)
    s_chunk = jnp.einsum("bckn,bckh,bckhp->bchpn", br.astype(jnp.float32),
                         w_state, xr.astype(jnp.float32))

    # --- inter-chunk recurrence ---
    if h0 is None:
        h0 = jnp.zeros((B, H, Pd, N), jnp.float32)

    def step(h, inp):
        s_c, tot = inp                                   # (B,H,P,N), (B,H)
        y_prev_state = h                                 # state before chunk
        h_next = jnp.exp(tot)[..., None, None] * h + s_c
        return h_next, y_prev_state

    (h_final, h_prevs) = jax.lax.scan(
        step, h0, (jnp.moveaxis(s_chunk, 1, 0), jnp.moveaxis(total, 1, 0)))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)                # (B,nc,H,P,N)

    y_inter = jnp.einsum("bcqn,bcqh,bchpn->bcqhp", cr.astype(jnp.float32),
                         jnp.exp(cum), h_prevs)
    y = (y_intra + y_inter).reshape(B, S, H, Pd)[:, :S_orig]
    return y.astype(x.dtype), h_final


def ssm_apply(cfg: ModelConfig, p: Dict[str, jax.Array], x: jax.Array, *,
              mode: str = "train",
              cache: Optional[Tuple[jax.Array, jax.Array]] = None):
    """Mamba2 mixer.  cache = (ssm_state (B,H,P,N) fp32, conv_tail (B,K-1,C)).

    Returns (out (B,S,D), new_cache)."""
    B, S, D = x.shape
    di, N, H, Pd = d_inner(cfg), cfg.ssm_state, n_ssm_heads(cfg), cfg.ssm_head_dim

    zxbcdt = jnp.einsum("bsd,dk->bsk", x, p["w_in"])
    z, xbc, dt_raw = _split_proj(cfg, zxbcdt)
    zxbcdt = logical_constraint(zxbcdt, "batch", "seq", "tp")

    conv_state = cache[1] if cache is not None else None
    xbc, new_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_state)
    xi = xbc[..., :di].reshape(B, S, H, Pd)
    b_mat = xbc[..., di:di + N]
    c_mat = xbc[..., di + N:]

    dt, log_decay = _decays(cfg, dt_raw, p["a_log"], p["dt_bias"])

    if mode == "decode":
        # O(1) recurrence: h = exp(dt·A)·h + dt·B⊗x  (S == 1)
        h = cache[0]
        a = jnp.exp(log_decay[:, 0])                     # (B,H)
        xu = (xi[:, 0].astype(jnp.float32) * dt[:, 0][..., None])
        h_new = (a[..., None, None] * h
                 + jnp.einsum("bhp,bn->bhpn", xu, b_mat[:, 0].astype(jnp.float32)))
        y = jnp.einsum("bn,bhpn->bhp", c_mat[:, 0].astype(jnp.float32), h_new)
        y = y[:, None].astype(x.dtype)                   # (B,1,H,P)
        new_cache = (h_new, new_conv)
    else:
        h0 = cache[0] if cache is not None else None
        y, h_final = ssd_scan(cfg, xi, b_mat, c_mat, dt, log_decay, h0)
        new_cache = (h_final, new_conv) if mode == "prefill" else None

    y = y + p["d_skip"].astype(y.dtype)[None, None, :, None] * xi
    y = y.reshape(B, S, di)
    y = rms_norm(y * jax.nn.silu(z), p["norm_scale"])
    out = jnp.einsum("bsk,kd->bsd", y, p["w_out"])
    return logical_constraint(out, "batch", "seq", "embed"), new_cache


def count_ssm_params(cfg: ModelConfig) -> int:
    D, N = cfg.d_model, cfg.ssm_state
    di, H = d_inner(cfg), n_ssm_heads(cfg)
    cc = conv_channels(cfg)
    return (D * (2 * di + 2 * N + H) + cfg.ssm_conv * cc + cc
            + 3 * H + di + di * D)
