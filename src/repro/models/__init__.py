"""Model zoo registry: family name -> module with the uniform model API.

Every family module exposes:
  model_defs(cfg)                 -> pytree of ParamDef
  forward(cfg, params, tokens, **kw) -> (logits, new_cache, aux_loss, stats)
  loss_fn(cfg)                    -> (params, batch, qctx) -> (loss, aux)
  prefill(cfg, params, tokens, max_seq, **kw) -> (logits, cache, pos)
  decode_step(cfg, params, tokens, cache, pos) -> (logits, new_cache)
  cache_struct / cache_logical / init_cache
  count_params(cfg) [+ count_active_params for MoE]
"""

from __future__ import annotations


def registry(family: str):
    from repro.models import encdec, hybrid, mamba, transformer
    mods = {
        "dense": transformer,
        "moe": transformer,
        "vlm": transformer,
        "ssm": mamba,
        "hybrid": hybrid,
        "encdec": encdec,
    }
    if family not in mods:
        raise ValueError(f"unknown model family {family!r}")
    return mods[family]
