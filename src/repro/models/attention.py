"""Attention: grouped-query (GQA/MQA/MHA) and multi-head latent (MLA).

Call patterns (used by the drivers):
  * ``mode="train"``    — full causal self-attention, no cache.
  * ``mode="prefill"``  — causal, returns the populated KV cache.
  * ``mode="decode"``   — one new token against a cache of ``max_seq``.
  * ``mode="decode_static"`` — fixed cross-attention cache (enc-dec).

Memory strategy (the dry-run's per-device HBM budget is 16 GB):
  * train/prefill attention runs **blockwise with online softmax** (a
    flash-attention schedule expressed in lax.scan — the TPU-native
    adaptation of the quadratic-scores GPU layer; see DESIGN §3) whenever
    S_q·S_k is large, so per-device score memory is O(S_q · block) instead
    of O(S²);
  * the head-vs-sequence parallelism decision is made statically per arch:
    if n_heads divides the model axis, heads shard (Megatron-TP); otherwise
    queries shard over sequence (sequence-parallel attention) and the small
    K/V are replicated on the model axis.

MLA implements the *absorbed* decode form — scores are taken directly
against the compressed latent cache, so decode HBM traffic per token is
O(kv_lora + rope) instead of O(heads × head_dim).
"""

from __future__ import annotations

import math
from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist.sharding import logical_constraint, model_axis_size
from repro.models.common import ParamDef, apply_rope

NEG_INF = -1e30
FLASH_THRESHOLD = 4096 * 2048          # S_q · S_k above which we go blockwise
FLASH_BLOCK = 1024

# int8 KV cache (cfg.kv_cache_bits == 8): values snap to the DPS ⟨3,5⟩ grid
# (range ±4, step 1/32) and live in HBM as grid integers — the paper's
# quantizer applied to serving state; halves cache bytes vs bf16.
_KV_IL, _KV_FL = 3, 5


def _kv_pack(x: jax.Array) -> jax.Array:
    span = float(1 << (_KV_IL - 1 + _KV_FL))
    y = jnp.clip(x.astype(jnp.float32) * (1 << _KV_FL), -span, span - 1)
    return jnp.round(y).astype(jnp.int8)


def _cache_read(c: jax.Array, dtype) -> jax.Array:
    if c.dtype == jnp.int8:
        return (c.astype(jnp.float32) * (1.0 / (1 << _KV_FL))).astype(dtype)
    return c.astype(dtype)


def _cache_write(x: jax.Array, cache_dtype) -> jax.Array:
    return _kv_pack(x) if cache_dtype == jnp.int8 else x.astype(cache_dtype)


def gqa_defs(cfg: ModelConfig, dtype) -> Dict[str, ParamDef]:
    D, H, KV, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    defs = {
        "wq": ParamDef((D, H * Dh), ("fsdp", "tp"), dtype=dtype),
        "wk": ParamDef((D, KV * Dh), ("fsdp", "tp"), dtype=dtype),
        "wv": ParamDef((D, KV * Dh), ("fsdp", "tp"), dtype=dtype),
        "wo": ParamDef((H * Dh, D), ("tp", "fsdp"), dtype=dtype),
    }
    if cfg.attn_bias:
        defs["bq"] = ParamDef((H * Dh,), ("tp",), init="zeros", dtype=dtype)
        defs["bk"] = ParamDef((KV * Dh,), ("tp",), init="zeros", dtype=dtype)
        defs["bv"] = ParamDef((KV * Dh,), ("tp",), init="zeros", dtype=dtype)
        defs["bo"] = ParamDef((D,), (None,), init="zeros", dtype=dtype)
    return defs


# ---------------------------------------------------------------------------
# Core attention math (q/k/v with FUSED head dim: (B, S, H, Dh)).
# ---------------------------------------------------------------------------

def _attn_full(q, k, v, *, causal: bool, scale: float):
    """Materialized-scores attention for small S_q·S_k."""
    s = jnp.einsum("bqhd,bshd->bhqs", q, k,
                   preferred_element_type=jnp.float32) * scale
    if causal:
        Sq, Sk = q.shape[1], k.shape[1]
        qi = jnp.arange(Sq)[:, None] + (Sk - Sq)
        kj = jnp.arange(Sk)[None, :]
        s = s + jnp.where(kj <= qi, 0.0, NEG_INF)
    p = jax.nn.softmax(s.astype(jnp.float32), axis=-1).astype(v.dtype)
    return jnp.einsum("bhqs,bshd->bqhd", p, v)


def _attn_flash(q, k, v, *, causal: bool, scale: float,
                block: int = FLASH_BLOCK, unroll: bool = False):
    """Blockwise online-softmax attention: lax.scan over K/V blocks.

    Per-step score footprint is (B, H, S_q, block); the scan body is
    checkpointed so backward recomputes blocks instead of storing them.
    ``v`` may have a different head width than q/k (MLA: qk 192, v 128)."""
    B, Sq, H, Dh = q.shape
    Dv = v.shape[-1]
    Sk = k.shape[1]
    block = min(block, Sk)
    nb = -(-Sk // block)
    pad = nb * block - Sk
    kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = jnp.moveaxis(kp.reshape(B, nb, block, H, Dh), 1, 0)
    vb = jnp.moveaxis(vp.reshape(B, nb, block, H, Dv), 1, 0)
    j0s = jnp.arange(nb) * block

    qi = jnp.arange(Sq) + (Sk - Sq)                  # global query positions
    qf = q

    def body(carry, xs):
        m, l, acc = carry
        k_blk, v_blk, j0 = xs
        s = jnp.einsum("bqhd,bjhd->bhqj", qf, k_blk,
                       preferred_element_type=jnp.float32) * scale
        kj = j0 + jnp.arange(block)
        valid = (kj[None, :] < Sk)
        if causal:
            valid = valid & (kj[None, :] <= qi[:, None])
        valid = valid[None, None]                      # (1,1,Sq,block)
        s = jnp.where(valid, s, NEG_INF)
        bm = jnp.max(s, axis=-1)                       # (B,H,Sq)
        new_m = jnp.maximum(m, bm)
        p = jnp.exp(s - new_m[..., None]) * valid      # masked exp
        corr = jnp.exp(m - new_m)
        l = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bhqj,bjhd->bhqd", p.astype(v.dtype), v_blk,
                        preferred_element_type=jnp.float32)
        acc = acc * corr[..., None] + pv
        return (new_m, l, acc), None

    body = jax.checkpoint(body)
    m0 = jnp.full((B, H, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, Sq), jnp.float32)
    a0 = jnp.zeros((B, H, Sq, Dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kb, vb, j0s),
                                  unroll=unroll)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return jnp.moveaxis(out, 1, 2).astype(q.dtype)     # (B,Sq,H,Dh)


def sdpa(q, k, v, *, causal: bool, scale: float, unroll: bool = False):
    if q.shape[1] * k.shape[1] > FLASH_THRESHOLD:
        return _attn_flash(q, k, v, causal=causal, scale=scale, unroll=unroll)
    return _attn_full(q, k, v, causal=causal, scale=scale)


def _heads_on_model(n_heads: int) -> bool:
    m = model_axis_size()
    return m > 1 and n_heads % m == 0


def _constrain_qkv(q, k, v, n_heads, batch2d: bool = False):
    """Static parallelism decision: shard heads if divisible; else either
    shard the query sequence (K/V replicated on the model axis) or — with
    ``batch2d`` — shard the BATCH over (data × model) so attention is fully
    local and no K/V replication happens (§Perf hillclimb #7)."""
    if _heads_on_model(n_heads):
        q = logical_constraint(q, "batch", None, "heads", None)
        k = logical_constraint(k, "batch", None, "heads", None)
        v = logical_constraint(v, "batch", None, "heads", None)
    elif batch2d:
        q = logical_constraint(q, "batch2d", None, None, None)
        k = logical_constraint(k, "batch2d", None, None, None)
        v = logical_constraint(v, "batch2d", None, None, None)
    else:
        q = logical_constraint(q, "batch", "tp_seq", None, None)
        k = logical_constraint(k, "batch", None, None, None)
        v = logical_constraint(v, "batch", None, None, None)
    return q, k, v


# ---------------------------------------------------------------------------
# GQA.
# ---------------------------------------------------------------------------

def gqa_apply(cfg: ModelConfig, p: Dict[str, jax.Array], x: jax.Array,
              *, positions: jax.Array, mode: str = "train",
              cache: Optional[Tuple[jax.Array, jax.Array]] = None,
              cache_pos=None, kv_x: Optional[jax.Array] = None,
              causal: bool = True, paged_ptab: Optional[jax.Array] = None,
              paged_backend: str = "auto"):
    """Grouped-query attention.  ``kv_x`` switches to cross-attention.

    ``cache`` = (k, v) each (B, max_seq, KV, Dh); decode writes the new
    token at ``cache_pos`` and attends over [0, cache_pos].

    ``paged_ptab`` (serving, ``mode="decode"`` only) switches to the paged
    KV pool: ``cache`` is then this layer's ``(k_pages, v_pages, k_fmt,
    v_fmt)`` slice — (n_pages, page, KV, Dh) pools plus (n_pages, 2)
    per-page ⟨IL, FL⟩ rows — and ``paged_ptab`` the (B, P) page table
    (see repro.serve).  Returns ``(out, new_cache)``."""
    B, Sq, D = x.shape
    H, KV, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    G = H // KV
    src = x if kv_x is None else kv_x
    Sk = src.shape[1]

    q = jnp.einsum("bsd,dh->bsh", x, p["wq"])
    k = jnp.einsum("bsd,dh->bsh", src, p["wk"])
    v = jnp.einsum("bsd,dh->bsh", src, p["wv"])
    if cfg.attn_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, Sq, H, Dh)
    k = k.reshape(B, Sk, KV, Dh)
    v = v.reshape(B, Sk, KV, Dh)

    if kv_x is None and cfg.rope_theta > 0:
        kv_pos = positions if mode != "decode" else cache_pos[..., None]
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, kv_pos, cfg.rope_theta)

    scale = 1.0 / math.sqrt(Dh)
    new_cache = None

    if mode == "decode_static":
        ck, cv = cache                                  # (B, S, KV, Dh)
        out = _decode_attn(q.reshape(B, Sq, KV, G, Dh), ck, cv, None, scale)
    elif mode == "decode" and paged_ptab is not None:
        out, new_cache = _paged_decode(cache, q, k, v, cache_pos, paged_ptab,
                                       paged_backend, scale)
    elif mode == "decode":
        ck, cv = cache
        upd = lambda c, new: jax.vmap(
            lambda cb, nb, pb: jax.lax.dynamic_update_slice_in_dim(
                cb, nb, pb, 0))(c, _cache_write(new, c.dtype), cache_pos)
        ck = upd(ck, k)
        cv = upd(cv, v)
        new_cache = (ck, cv)
        S = ck.shape[1]
        valid = jnp.arange(S)[None, :] <= cache_pos[:, None]    # (B, S)
        out = _decode_attn(q.reshape(B, Sq, KV, G, Dh), ck, cv, valid, scale)
    else:
        if mode == "prefill":
            cdt = jnp.int8 if cfg.kv_cache_bits == 8 else k.dtype
            new_cache = (_cache_write(k, cdt), _cache_write(v, cdt))
        # repeat K/V heads to H (per-device slice only when heads shard)
        kr = jnp.repeat(k, G, axis=2)
        vr = jnp.repeat(v, G, axis=2)
        q, kr, vr = _constrain_qkv(q, kr, vr, H, cfg.attn_batch2d)
        out = sdpa(q, kr, vr, causal=causal and kv_x is None, scale=scale,
                   unroll=cfg.probe_unroll)

    out = out.reshape(B, Sq, H * Dh)
    out = jnp.einsum("bsh,hd->bsd", out, p["wo"])
    if cfg.attn_bias:
        out = out + p["bo"]
    return logical_constraint(out, "batch", "tp_seq", "embed"), new_cache


def _paged_decode(cache, q, k, v, cache_pos, ptab, backend, scale):
    """Serving decode against the paged KV pool (repro.serve).

    Writes the new token's K/V into its page — quantized onto the page's
    own ⟨IL, FL⟩ grid when the pool is int8 — then runs the fused
    dequantizing paged attention over the page table.  Positions ≥
    ``cache_pos[b] + 1`` are masked inside the kernel, so page-table
    entries past a row's last page (the serve layer's trash page) never
    reach the output.
    """
    from repro.core import fixed_point as fxp
    from repro.core import tagging
    from repro.kernels import paged_attn

    k_pg, v_pg, k_fmt, v_fmt = cache
    _, ps, KV, Dh = k_pg.shape
    B = q.shape[0]
    int8 = k_pg.dtype == jnp.int8
    bits = 8 if int8 else 0

    slot = cache_pos // ps
    phys = jnp.take_along_axis(ptab, slot[:, None], axis=1)[:, 0]   # (B,)
    off = cache_pos % ps

    def write(pool, fmt_tab, new):
        new = new[:, 0].astype(jnp.float32)                # (B, KV, Dh)
        if int8:
            rows = fmt_tab[phys]                           # (B, 2) [IL, FL]
            fmt = fxp.FixedPointFormat(rows[:, 0], rows[:, 1])
            vals, _ = fxp.wire_quantize(new.reshape(B, KV * Dh), fmt,
                                        mode=fxp.ROUND_NEAREST,
                                        compute_stats=False)
        else:
            vals = new.reshape(B, KV * Dh)
        vals = tagging.tag(vals, "kv_page", domain="kv_cache",
                           stage="write", bits=bits)
        return pool.at[phys, off].set(
            vals.reshape(B, KV, Dh).astype(pool.dtype))

    k_pg = write(k_pg, k_fmt, k)
    v_pg = write(v_pg, v_fmt, v)

    flt = jnp.stack([k_fmt[:, 1], v_fmt[:, 1]], axis=1)    # (n_pages, 2) FLs
    k_read = tagging.tag(k_pg, "kv_page", domain="kv_cache",
                         stage="read", bits=bits)
    v_read = tagging.tag(v_pg, "kv_page", domain="kv_cache",
                         stage="read", bits=bits)
    out = paged_attn.paged_decode_attn(
        q[:, 0].astype(jnp.float32), k_read, v_read, flt, ptab,
        cache_pos + 1, scale=scale, backend=backend)
    return out[:, None].astype(q.dtype), (k_pg, v_pg, k_fmt, v_fmt)


def _decode_attn(q, ck, cv, valid, scale):
    """Grouped decode attention: q (B,Sq,KV,G,Dh) over cache (B,S,KV,Dh)."""
    s = jnp.einsum("bqkgd,bskd->bkgqs", q, _cache_read(ck, q.dtype),
                   preferred_element_type=jnp.float32) * scale
    if valid is not None:
        s = s + jnp.where(valid, 0.0, NEG_INF)[:, None, None, None, :]
    p = jax.nn.softmax(s.astype(jnp.float32), axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, _cache_read(cv, q.dtype))
    B, Sq = out.shape[0], out.shape[1]
    return out.reshape(B, Sq, -1, out.shape[-1])


# ---------------------------------------------------------------------------
# MLA — DeepSeek-V2 multi-head latent attention.
# ---------------------------------------------------------------------------

def mla_defs(cfg: ModelConfig, dtype) -> Dict[str, ParamDef]:
    D, H = cfg.d_model, cfg.n_heads
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    return {
        "wq_a": ParamDef((D, qr), ("fsdp", None), dtype=dtype),
        "q_norm": ParamDef((qr,), (None,), init="ones", dtype=jnp.float32),
        "wq_b": ParamDef((qr, H * (dn + dr)), (None, "tp"), dtype=dtype),
        "wkv_a": ParamDef((D, kvr + dr), ("fsdp", None), dtype=dtype),
        "kv_norm": ParamDef((kvr,), (None,), init="ones", dtype=jnp.float32),
        # decoupled up-projections so decode can absorb them:
        "w_uk": ParamDef((kvr, H, dn), (None, "tp", None), dtype=dtype),
        "w_uv": ParamDef((kvr, H, dv), (None, "tp", None), dtype=dtype),
        "wo": ParamDef((H * dv, D), ("tp", "fsdp"), dtype=dtype),
    }


def _mla_qkr(cfg, p, x, positions):
    """Shared query path + latent/k_rope projection for all modes."""
    from repro.models.common import rms_norm
    B, S, _ = x.shape
    H, dn, dr = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim
    q_lat = rms_norm(jnp.einsum("bsd,dr->bsr", x, p["wq_a"]), p["q_norm"])
    q = jnp.einsum("bsr,rh->bsh", q_lat, p["wq_b"]).reshape(B, S, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    kv = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"])
    c_kv = rms_norm(kv[..., :cfg.kv_lora_rank], p["kv_norm"])
    k_rope = kv[..., cfg.kv_lora_rank:]
    k_rope = apply_rope(k_rope[:, :, None, :], positions,
                        cfg.rope_theta)[:, :, 0, :]
    c_kv = logical_constraint(c_kv, "batch", None, None)
    return q_nope, q_rope, c_kv, k_rope


def mla_apply(cfg: ModelConfig, p: Dict[str, jax.Array], x: jax.Array, *,
              positions: jax.Array, mode: str = "train",
              cache: Optional[Tuple[jax.Array, jax.Array]] = None,
              cache_pos=None):
    """MLA attention.  Cache = (c_kv (B, S, kvr), k_rope (B, S, dr))."""
    B, Sq, D = x.shape
    H = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    scale = 1.0 / math.sqrt(dn + dr)

    q_nope, q_rope, c_kv, k_rope = _mla_qkr(cfg, p, x, positions)

    new_cache = None
    if mode == "decode":
        cc, cr = cache
        upd = lambda c, new: jax.vmap(
            lambda cb, nb, pb: jax.lax.dynamic_update_slice_in_dim(
                cb, nb, pb, 0))(c, _cache_write(new, c.dtype), cache_pos)
        cc = upd(cc, c_kv)
        cr = upd(cr, k_rope)
        new_cache = (cc, cr)
        # absorbed decode: project q into latent space, score vs the latent
        q_c = jnp.einsum("bthd,rhd->bthr", q_nope, p["w_uk"])      # (B,1,H,kvr)
        scores = (jnp.einsum("bthr,bsr->bhts", q_c, _cache_read(cc, q_c.dtype),
                             preferred_element_type=jnp.float32)
                  + jnp.einsum("bthe,bse->bhts", q_rope,
                               _cache_read(cr, q_rope.dtype),
                               preferred_element_type=jnp.float32)) * scale
        S = cc.shape[1]
        valid = jnp.arange(S)[None, :] <= cache_pos[:, None]
        scores = scores + jnp.where(valid, 0.0, NEG_INF)[:, None, None, :]
        probs = jax.nn.softmax(scores.astype(jnp.float32), -1).astype(x.dtype)
        ctx = jnp.einsum("bhts,bsr->bthr", probs, _cache_read(cc, probs.dtype))
        out = jnp.einsum("bthr,rhd->bthd", ctx, p["w_uv"])          # (B,1,H,dv)
    else:
        if mode == "prefill":
            cdt = jnp.int8 if cfg.kv_cache_bits == 8 else c_kv.dtype
            new_cache = (_cache_write(c_kv, cdt), _cache_write(k_rope, cdt))
        # expanded form: per-head K (nope) and V from the latent; rope parts
        # concatenated so one flash call covers both score terms
        k_nope = jnp.einsum("bsr,rhd->bshd", c_kv, p["w_uk"])
        v = jnp.einsum("bsr,rhd->bshd", c_kv, p["w_uv"])
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                      (B, Sq, H, dr))], axis=-1)
        q, k, v = _constrain_qkv(q, k, v, H, cfg.attn_batch2d)
        out = sdpa(q, k, v, causal=True, scale=scale, unroll=cfg.probe_unroll)

    out = out.reshape(B, Sq, H * dv)
    out = jnp.einsum("bsh,hd->bsd", out, p["wo"])
    return logical_constraint(out, "batch", "tp_seq", "embed"), new_cache


def count_gqa_params(cfg: ModelConfig) -> int:
    D, H, KV, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    n = D * H * Dh * 2 + D * KV * Dh * 2
    if cfg.attn_bias:
        n += H * Dh + 2 * KV * Dh + D
    return n


def count_mla_params(cfg: ModelConfig) -> int:
    D, H = cfg.d_model, cfg.n_heads
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    return (D * qr + qr * H * (dn + dr) + D * (kvr + dr)
            + kvr * H * dn + kvr * H * dv + H * dv * D)
