"""Decoder-only transformer LM: dense, MoE, MLA and VLM-backbone variants.

One module covers llama3.2 / mistral-large / nemotron-4 / gemma (dense),
qwen3-moe / deepseek-v2 (MoE, the latter with MLA), and internvl2 (VLM —
patch embeddings from the stubbed vision frontend are prepended to the
token sequence).

Structure notes:
  * the layer stack runs under ``jax.lax.scan`` over stacked per-layer
    params — HLO size and compile time are O(1) in depth;
  * each scan body is ``jax.checkpoint``-wrapped per ``cfg.remat``;
  * DPS activation taps (``qctx.tap``) fire on the residual stream after
    every block; their stats ride the scan carry and merge globally;
  * decode threads the per-layer KV cache through scan xs/ys.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.fixed_point import QuantStats
from repro.dist.sharding import logical_constraint
from repro.models import attention as attn_lib
from repro.models import mlp as mlp_lib
from repro.models import moe as moe_lib
from repro.models.common import (ParamDef, embed_defs, embed_lookup,
                                 fused_unembed_xent, init_params, rms_norm,
                                 layer_norm, softmax_xent, unembed)


def _dtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def stack_defs(n: int, defs):
    """Prepend a stacked ``layers`` dim to every ParamDef in a tree."""
    return jax.tree.map(
        lambda d: ParamDef((n,) + d.shape, ("layers",) + d.logical,
                           init=d.init, scale=d.scale, dtype=d.dtype),
        defs, is_leaf=lambda x: isinstance(x, ParamDef))


def layer_defs(cfg: ModelConfig) -> Dict[str, Any]:
    dt = _dtype(cfg)
    defs: Dict[str, Any] = {
        "norm1": ParamDef((cfg.d_model,), (None,), init="ones",
                          dtype=jnp.float32),
        "norm2": ParamDef((cfg.d_model,), (None,), init="ones",
                          dtype=jnp.float32),
    }
    if cfg.norm == "layer":
        defs["norm1_b"] = ParamDef((cfg.d_model,), (None,), init="zeros",
                                   dtype=jnp.float32)
        defs["norm2_b"] = ParamDef((cfg.d_model,), (None,), init="zeros",
                                   dtype=jnp.float32)
    defs["attn"] = (attn_lib.mla_defs(cfg, dt) if cfg.mla
                    else attn_lib.gqa_defs(cfg, dt))
    if cfg.n_experts:
        defs["moe"] = moe_lib.moe_defs(cfg, dt)
    else:
        defs["mlp"] = mlp_lib.mlp_defs(cfg.d_model, cfg.d_ff, cfg.gated_mlp, dt)
    return defs


def model_defs(cfg: ModelConfig) -> Dict[str, Any]:
    dt = _dtype(cfg)
    return {
        "embed": embed_defs(cfg.vocab, cfg.d_model, tie=cfg.tie_embed, dtype=dt),
        "layers": stack_defs(cfg.n_layers, layer_defs(cfg)),
        "final_norm": ParamDef((cfg.d_model,), (None,), init="ones",
                               dtype=jnp.float32),
    }


def _norm(cfg, x, scale, bias=None):
    if cfg.norm == "layer":
        return layer_norm(x, scale, bias)
    return rms_norm(x, scale)


def _block(cfg: ModelConfig, p, x, *, positions, mode, cache, cache_pos,
           qctx, layer_idx, paged_ptab=None, paged_backend="auto"):
    """One transformer block.  Returns (x, new_cache, aux_loss, stats)."""
    h = _norm(cfg, x, p["norm1"], p.get("norm1_b"))
    if cfg.mla:
        a_out, new_cache = attn_lib.mla_apply(
            cfg, p["attn"], h, positions=positions, mode=mode, cache=cache,
            cache_pos=cache_pos)
    else:
        a_out, new_cache = attn_lib.gqa_apply(
            cfg, p["attn"], h, positions=positions, mode=mode, cache=cache,
            cache_pos=cache_pos, paged_ptab=paged_ptab,
            paged_backend=paged_backend)
    x = x + a_out

    h = _norm(cfg, x, p["norm2"], p.get("norm2_b"))
    aux_loss = jnp.zeros((), jnp.float32)
    if cfg.n_experts:
        m_out, aux_loss = moe_lib.moe_apply(cfg, p["moe"], h)
    else:
        m_out = mlp_lib.mlp_apply(cfg, p["mlp"], h)
    x = x + m_out

    stats = QuantStats.zero()
    if qctx is not None:
        x, stats = qctx.tap(x, layer_idx)
        if stats is None:
            stats = QuantStats.zero()
    return x, new_cache, aux_loss, stats


def _run_stack(cfg: ModelConfig, layers, x, *, positions, mode="train",
               cache=None, cache_pos=None, qctx=None, paged_ptab=None,
               paged_backend="auto"):
    """Scan the layer stack.  Returns (x, new_cache, aux_loss, stats)."""

    def body(carry, xs):
        h, aux_acc, stats_acc = carry
        p, idx, layer_cache = xs
        h, new_cache, aux, stats = _block(
            cfg, p, h, positions=positions, mode=mode, cache=layer_cache,
            cache_pos=cache_pos, qctx=qctx, layer_idx=idx,
            paged_ptab=paged_ptab, paged_backend=paged_backend)
        return (h, aux_acc + aux, stats_acc.merge(stats)), new_cache

    if cfg.remat == "full":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    elif cfg.remat == "dots":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.checkpoint_dots)

    idxs = jnp.arange(cfg.n_layers, dtype=jnp.uint32)
    carry0 = (x, jnp.zeros((), jnp.float32), QuantStats.zero())
    (x, aux_loss, stats), new_cache = jax.lax.scan(
        body, carry0, (layers, idxs, cache), unroll=cfg.probe_unroll)
    if mode == "train":
        new_cache = None
    return x, new_cache, aux_loss, stats


def forward(cfg: ModelConfig, params, tokens: jax.Array, *,
            vision_embeds: Optional[jax.Array] = None, qctx=None,
            mode: str = "train", cache=None, cache_pos=None,
            hidden_only: bool = False, paged_ptab=None,
            paged_backend: str = "auto"):
    """Returns (logits | hidden, new_cache, aux_loss, act_stats).

    ``mode="prefill"`` unembeds the LAST position only (the serving loop
    needs just the next-token logits; a full-vocab (B, S, V) projection at
    32k prompt length is multiple GB of fp32 per device for nothing).
    ``hidden_only=True`` skips unembedding — the loss fuses it chunkwise."""
    x = embed_lookup(params["embed"]["tok"], tokens).astype(_dtype(cfg))
    if vision_embeds is not None:
        x = jnp.concatenate([vision_embeds.astype(x.dtype), x], axis=1)
        x = logical_constraint(x, "batch", "tp_seq", "embed")

    B, S, _ = x.shape
    if mode == "decode":
        positions = cache_pos[:, None]                      # (B, 1)
    else:
        positions = jnp.arange(S, dtype=jnp.int32)[None, :]

    if cache is None:
        # scan requires an xs pytree; use per-layer None via zeros-shaped dummy
        cache = _dummy_cache(cfg, B)

    x, new_cache, aux_loss, stats = _run_stack(
        cfg, params["layers"], x, positions=positions, mode=mode,
        cache=cache, cache_pos=cache_pos, qctx=qctx, paged_ptab=paged_ptab,
        paged_backend=paged_backend)

    x = _norm(cfg, x, params["final_norm"])
    if hidden_only:
        return x, new_cache, aux_loss, stats
    if mode == "prefill":
        x = x[:, -1:]
    logits = unembed(x, params["embed"], cfg.vocab)
    return logits, new_cache, aux_loss, stats


def _dummy_cache(cfg: ModelConfig, batch: int):
    """Zero-length cache placeholder so scan xs always has the same tree."""
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        cache_struct(cfg, batch, 0))


def cache_struct(cfg: ModelConfig, batch: int, max_seq: int):
    """ShapeDtypeStructs of the decode cache (stacked over layers)."""
    L = cfg.n_layers
    dt = jnp.int8 if cfg.kv_cache_bits == 8 else _dtype(cfg)
    if cfg.mla:
        return (
            jax.ShapeDtypeStruct((L, batch, max_seq, cfg.kv_lora_rank), dt),
            jax.ShapeDtypeStruct((L, batch, max_seq, cfg.qk_rope_dim), dt),
        )
    shp = (L, batch, max_seq, cfg.n_kv_heads, cfg.head_dim)
    return (jax.ShapeDtypeStruct(shp, dt), jax.ShapeDtypeStruct(shp, dt))


def cache_logical(cfg: ModelConfig):
    if cfg.mla:
        return (("layers", "batch", "kv_seq", None),
                ("layers", "batch", "kv_seq", None))
    sp = ("layers", "batch", "kv_seq", "kv", "head_dim")
    return (sp, sp)


def init_cache(cfg: ModelConfig, batch: int, max_seq: int):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        cache_struct(cfg, batch, max_seq))


def loss_fn(cfg: ModelConfig):
    """(params, batch, qctx) -> (loss, aux) for qtrain.make_train_step."""

    def fn(params, batch, qctx=None):
        tokens = batch["tokens"]
        hidden, _, aux_loss, stats = forward(
            cfg, params, tokens[:, :-1],
            vision_embeds=batch.get("vision_embeds"), qctx=qctx,
            hidden_only=True)
        labels = tokens[:, 1:]
        if "vision_embeds" in batch and batch["vision_embeds"] is not None:
            nv = batch["vision_embeds"].shape[1]
            hidden = hidden[:, nv:]
        loss = fused_unembed_xent(hidden, params["embed"], cfg.vocab, labels,
                                  batch.get("loss_mask"),
                                  unroll=cfg.probe_unroll)
        loss = loss + cfg.router_aux_coef * aux_loss
        return loss, {"act_stats": stats, "aux_loss": aux_loss}

    return fn


def prefill(cfg: ModelConfig, params, tokens: jax.Array, max_seq: int, *,
            vision_embeds=None, qctx=None):
    """Run the prompt, return (last_logits, cache padded to max_seq, pos)."""
    logits, cache, _, _ = forward(cfg, params, tokens,
                                  vision_embeds=vision_embeds, qctx=qctx,
                                  mode="prefill")
    S = cache[0].shape[2]
    pad = max_seq - S
    cache = jax.tree.map(
        lambda c: jnp.pad(c, ((0, 0), (0, 0), (0, pad)) + ((0, 0),) * (c.ndim - 3)),
        cache)
    B = tokens.shape[0]
    pos = jnp.full((B,), S, jnp.int32)
    return logits[:, -1], cache, pos


def decode_step(cfg: ModelConfig, params, tokens: jax.Array, cache, pos,
                qctx=None):
    """One token per row.  tokens (B, 1); pos (B,) write positions.

    Returns (logits (B, vocab), new_cache)."""
    logits, new_cache, _, _ = forward(cfg, params, tokens, qctx=qctx,
                                      mode="decode", cache=cache,
                                      cache_pos=pos)
    return logits[:, -1], new_cache


def decode_step_paged(cfg: ModelConfig, params, tokens: jax.Array, cache,
                      ptab: jax.Array, pos: jax.Array, *,
                      backend: str = "auto", qctx=None):
    """One token per row against the paged KV pool (repro.serve).

    ``cache``: the serve layer's per-layer ``(k_pages, v_pages, k_fmt,
    v_fmt)`` stacked over layers (leading dim L — scan xs/ys, exactly like
    the contiguous cache).  ``ptab`` (B, P) int32 logical→physical page
    table shared by every layer; ``pos`` (B,) absolute write positions.
    Returns (logits (B, vocab), new_cache)."""
    logits, new_cache, _, _ = forward(cfg, params, tokens, qctx=qctx,
                                      mode="decode", cache=cache,
                                      cache_pos=pos, paged_ptab=ptab,
                                      paged_backend=backend)
    return logits[:, -1], new_cache


def count_params(cfg: ModelConfig) -> float:
    per_layer = 2 * cfg.d_model
    per_layer += (attn_lib.count_mla_params(cfg) if cfg.mla
                  else attn_lib.count_gqa_params(cfg))
    if cfg.n_experts:
        per_layer += moe_lib.count_moe_params(cfg)
    else:
        per_layer += mlp_lib.count_mlp_params(cfg.d_model, cfg.d_ff,
                                              cfg.gated_mlp)
    total = cfg.n_layers * per_layer + cfg.d_model
    total += cfg.vocab * cfg.d_model * (1 if cfg.tie_embed else 2)
    return float(total)


def count_active_params(cfg: ModelConfig) -> float:
    if not cfg.n_experts:
        return count_params(cfg)
    per_layer = 2 * cfg.d_model
    per_layer += (attn_lib.count_mla_params(cfg) if cfg.mla
                  else attn_lib.count_gqa_params(cfg))
    per_layer += moe_lib.count_moe_active_params(cfg)
    total = cfg.n_layers * per_layer + cfg.d_model
    total += cfg.vocab * cfg.d_model * (1 if cfg.tie_embed else 2)
    return float(total)
