"""Feed-forward blocks: gated (SwiGLU/GeGLU) and plain (squared-ReLU)."""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist.sharding import logical_constraint
from repro.models.common import ParamDef, act_fn


def mlp_defs(d_model: int, d_ff: int, gated: bool, dtype) -> Dict[str, ParamDef]:
    defs = {
        "w_in": ParamDef((d_model, d_ff), ("fsdp", "tp"), dtype=dtype),
        "w_out": ParamDef((d_ff, d_model), ("tp", "fsdp"), dtype=dtype),
    }
    if gated:
        defs["w_gate"] = ParamDef((d_model, d_ff), ("fsdp", "tp"), dtype=dtype)
    return defs


def mlp_apply(cfg: ModelConfig, p: Dict[str, jax.Array], x: jax.Array,
              act: str | None = None) -> jax.Array:
    act = act or cfg.act
    h = jnp.einsum("bsd,df->bsf", x, p["w_in"])
    if "w_gate" in p:
        g = jnp.einsum("bsd,df->bsf", x, p["w_gate"])
        h = act_fn(act, g) * h
    else:
        h = act_fn(act, h)
    h = logical_constraint(h, "batch", "seq", "tp")
    out = jnp.einsum("bsf,fd->bsd", h, p["w_out"])
    return logical_constraint(out, "batch", "tp_seq", "embed")


def count_mlp_params(d_model: int, d_ff: int, gated: bool) -> int:
    return d_model * d_ff * (3 if gated else 2)
