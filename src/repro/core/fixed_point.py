"""Dynamic fixed-point ⟨IL, FL⟩ emulation with fused quantization statistics.

This is the paper's numerical substrate (§2.1).  A fixed-point format is a
pair of bit-widths ``⟨IL, FL⟩``: IL integer bits (including sign) and FL
fractional bits.  The representable grid is ``k · 2^-FL`` for integers
``k ∈ [-2^(IL-1+FL), 2^(IL-1+FL) - 1]``.

Key property for a *dynamic* precision scheme inside ``jit``: IL and FL are
**traced int32 scalars**, never Python ints, so the controller can change
them every training step without triggering recompilation.  All scale factors
are derived with ``exp2`` on traced values.

Exactness: emulation math runs in float32.  Grid integers are exact in
float32 iff ``IL - 1 + FL <= 24`` (fp32 mantissa); controllers clamp widths
to honour this, and tests assert bit-exactness in that regime.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

# Fraction-of-a-unit resolution used for stochastic rounding: uniform samples
# are exact multiples of 2^-24, matching fp32 mantissa resolution.
_U_BITS = 24
_U_SCALE = 1.0 / (1 << _U_BITS)

ROUND_NEAREST = "nearest"
ROUND_STOCHASTIC = "stochastic"


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class FixedPointFormat:
    """A (possibly batched) dynamic fixed-point format.

    ``il``/``fl`` are int32 arrays (scalars for global granularity, shape
    ``[G]`` for per-group granularity).  They are pytree leaves: traced under
    ``jit``, checkpointable, donate-able.
    """

    il: jax.Array
    fl: jax.Array

    @staticmethod
    def create(il: int, fl: int) -> "FixedPointFormat":
        return FixedPointFormat(jnp.asarray(il, jnp.int32), jnp.asarray(fl, jnp.int32))

    def total_bits(self) -> jax.Array:
        return self.il + self.fl


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class QuantStats:
    """Sufficient statistics of one quantization event.

    All fields are sums/counts (or max for ``max_abs``) so they combine
    across tensors, layers, and mesh shards (``psum`` for sums, ``pmax`` for
    the max) without bias.
    """

    count: jax.Array          # f32, number of elements
    nonzero: jax.Array        # f32, elements with |x| > 0 (for relative mean)
    overflow: jax.Array       # f32, elements clipped at the range boundary
    abs_err_sum: jax.Array    # f32, Σ |q - clip(x)| (rounding error only)
    rel_err_sum: jax.Array    # f32, Σ |q - clip(x)| / |clip(x)| over nonzero
    abs_sum: jax.Array        # f32, Σ |clip(x)|
    max_abs: jax.Array        # f32, max |x| (pre-clip; FlexPoint-style predictor)

    @staticmethod
    def zero(shape=()) -> "QuantStats":
        z = jnp.zeros(shape, jnp.float32)
        return QuantStats(z, z, z, z, z, z, z)

    def merge(self, other: "QuantStats") -> "QuantStats":
        return QuantStats(
            self.count + other.count,
            self.nonzero + other.nonzero,
            self.overflow + other.overflow,
            self.abs_err_sum + other.abs_err_sum,
            self.rel_err_sum + other.rel_err_sum,
            self.abs_sum + other.abs_sum,
            jnp.maximum(self.max_abs, other.max_abs),
        )

    # --- derived metrics (paper §2.2) ---
    def overflow_rate(self) -> jax.Array:
        """R: fraction of values that clipped — drives IL."""
        return self.overflow / jnp.maximum(self.count, 1.0)

    def quant_error(self, metric: str = "relative_mean") -> jax.Array:
        """E: average quantization error percentage — drives FL.

        ``relative_mean``: mean over nonzero elements of |q-x|/|x| (the
        paper's "average quantization error percentage"; saturates at 1.0 for
        round-to-zero events, which the paper identifies as the FL driver).
        ``ratio``: Σ|q-x| / Σ|x| (aggregate alternative, less sensitive to
        tiny-magnitude elements).
        """
        if metric == "relative_mean":
            return self.rel_err_sum / jnp.maximum(self.nonzero, 1.0)
        elif metric == "ratio":
            return self.abs_err_sum / jnp.maximum(self.abs_sum, 1e-30)
        raise ValueError(f"unknown error metric {metric!r}")


def merge_stats(*stats: QuantStats) -> QuantStats:
    out = stats[0]
    for s in stats[1:]:
        out = out.merge(s)
    return out


def exp2_int(n: jax.Array) -> jax.Array:
    """Bit-exact ``2.0 ** n`` for int32 ``n`` in [-126, 127].

    ``jnp.exp2`` is NOT bit-exact on all backends (this container's CPU
    backend returns ``exp2(13.0) == 8192.0039``), which would knock every
    quantized value off the ⟨IL, FL⟩ grid.  Constructing the float32 from
    its exponent bits is exact by definition.
    """
    n = jnp.clip(jnp.asarray(n, jnp.int32), -126, 127)
    return jax.lax.bitcast_convert_type((n + 127) << 23, jnp.float32)


def grid_bounds(fmt: FixedPointFormat):
    """Scale factors and integer-grid bounds for a format (traced-safe)."""
    scale = exp2_int(fmt.fl)             # x -> grid units
    inv_scale = exp2_int(-fmt.fl)        # grid units -> x
    span = exp2_int(fmt.il - 1 + fmt.fl)
    qmax = span - 1.0                    # largest grid integer
    qmin = -span                         # smallest grid integer
    return scale, inv_scale, qmin, qmax


def _uniform_from_bits(bits: jax.Array) -> jax.Array:
    """uint32 random bits -> exact fp32 uniforms in [0, 1) at 2^-24 grid."""
    return (bits >> (32 - _U_BITS)).astype(jnp.float32) * _U_SCALE


def _grid_round(x: jax.Array, fmt_b: FixedPointFormat, mode: str,
                bits: Optional[jax.Array], key: Optional[jax.Array]):
    """Shared grid-rounding core of :func:`quantize` / :func:`wire_quantize`.

    Returns ``(xf, over_range, yc, q_int, inv_scale)`` where ``q_int`` is
    the rounded grid integer clipped to the ⟨IL, FL⟩ range and ``yc`` the
    range-clipped value in grid units.  One implementation of the paper's
    Eq. (1)/(2) keeps the emulation and the wire codec bit-identical.
    """
    xf = x.astype(jnp.float32)
    scale, inv_scale, qmin, qmax = grid_bounds(fmt_b)

    y = xf * scale
    over_range = (y > qmax) | (y < qmin)
    yc = jnp.clip(y, qmin, qmax)

    if mode == ROUND_STOCHASTIC:
        if bits is None:
            if key is None:
                raise ValueError("stochastic rounding needs `bits` or `key`")
            bits = jax.random.bits(key, shape=x.shape, dtype=jnp.uint32)
        u = _uniform_from_bits(bits)
        q_int = jnp.floor(yc + u)
    elif mode == ROUND_NEAREST:
        q_int = jnp.floor(yc + 0.5)
    else:
        raise ValueError(f"unknown rounding mode {mode!r}")
    # floor(qmax + u) can exceed qmax when u -> 1 only if yc == qmax exactly
    # and u == 1 (excluded); the extra clip guards fp edge cases for free.
    q_int = jnp.clip(q_int, qmin, qmax)
    return xf, over_range, yc, q_int, inv_scale


def quantize(
    x: jax.Array,
    fmt: FixedPointFormat,
    *,
    mode: str = ROUND_STOCHASTIC,
    bits: Optional[jax.Array] = None,
    key: Optional[jax.Array] = None,
    compute_stats: bool = True,
):
    """Quantize ``x`` onto the ⟨IL, FL⟩ grid.  Returns ``(q, stats | None)``.

    ``mode='stochastic'`` implements the paper's Eq. (2): unbiased rounding,
    E[q] = clip(x).  Supply either ``bits`` (uint32, same shape as x — the
    deterministic, kernel-matching path) or ``key`` (bits drawn internally).
    ``mode='nearest'`` implements Eq. (1) (round half away from floor, i.e.
    floor(y + 0.5)).

    The returned ``q`` has x's dtype; internal math is fp32.  Stats measure
    *rounding* error against the range-clipped reference (overflow is
    reported separately via the overflow count, mirroring Alg. 2's split of
    responsibilities: R -> IL, E -> FL).
    """
    orig_dtype = x.dtype
    xf, over, yc, q_int, inv_scale = _grid_round(x, fmt, mode, bits, key)
    q = q_int * inv_scale

    stats = None
    if compute_stats:
        x_ref = yc * inv_scale           # range-clipped reference value
        abs_err = jnp.abs(q - x_ref)
        abs_ref = jnp.abs(x_ref)
        nz = abs_ref > 0.0
        rel = jnp.where(nz, abs_err / jnp.where(nz, abs_ref, 1.0), 0.0)
        stats = QuantStats(
            count=jnp.asarray(x.size, jnp.float32),
            nonzero=jnp.sum(nz.astype(jnp.float32)),
            overflow=jnp.sum(over.astype(jnp.float32)),
            abs_err_sum=jnp.sum(abs_err),
            rel_err_sum=jnp.sum(rel),
            abs_sum=jnp.sum(abs_ref),
            max_abs=jnp.max(jnp.abs(xf)) if x.size else jnp.float32(0),
        )
    return q.astype(orig_dtype), stats


# Capacity of the int8 wire payload used by repro.dist.collectives: grid
# integers outside [-128, 127] saturate (and are counted as overflow).
WIRE_QMIN = -128.0
WIRE_QMAX = 127.0


def wire_quantize(
    x: jax.Array,
    fmt: FixedPointFormat,
    *,
    mode: str = ROUND_STOCHASTIC,
    bits: Optional[jax.Array] = None,
    key: Optional[jax.Array] = None,
    compute_stats: bool = True,
    mask: Optional[jax.Array] = None,
):
    """Quantize ``x`` onto the ⟨IL, FL⟩ grid and emit int8 *grid integers*.

    The wire payload is ``round(q · 2^FL)`` saturated at int8 capacity
    ``[-128, 127]``.  For IL + FL ≤ 8 the grid fits the wire exactly and
    the result is bit-identical to :func:`quantize` followed by the
    integer conversion; for over-wide formats the saturated elements are
    counted into ``stats.overflow`` and the reported rounding error is
    measured against the *decoded wire value*, so a controller consuming
    these stats sees wire clipping as what it is — overflow.

    Per-group formats: when ``fmt.il``/``fmt.fl`` have shape ``[G]`` (or
    any non-scalar shape), the leading ``fmt.il.ndim`` dims of ``x`` must
    equal ``fmt.il.shape``; stats reduce over the remaining trailing dims,
    so every stats leaf comes out with shape ``fmt.il.shape``.

    ``mask`` (same shape as x, 1/0) excludes padding from the statistics
    and zeroes the corresponding wire bytes.

    Returns ``(wire int8 with x's shape, stats | None)``.
    """
    nd = fmt.il.ndim
    if x.ndim < nd or x.shape[:nd] != fmt.il.shape:
        raise ValueError(
            f"per-group format {fmt.il.shape} needs x leading dims to match, "
            f"got x shape {x.shape}")
    bshape = fmt.il.shape + (1,) * (x.ndim - nd)
    fmt_b = FixedPointFormat(fmt.il.reshape(bshape), fmt.fl.reshape(bshape))
    axes = tuple(range(nd, x.ndim))

    m = jnp.ones(x.shape, jnp.float32) if mask is None else mask.astype(jnp.float32)
    xf, over_range, yc, q_int, inv_scale = _grid_round(x, fmt_b, mode, bits, key)
    sat = jnp.clip(q_int, WIRE_QMIN, WIRE_QMAX)
    wire = (sat * m).astype(jnp.int8)

    stats = None
    if compute_stats:
        over = ((over_range | (q_int != sat)).astype(jnp.float32)) * m
        x_ref = yc * inv_scale              # range-clipped reference value
        dec = sat * inv_scale               # what the receiver will decode
        abs_err = jnp.abs(dec - x_ref) * m
        abs_ref = jnp.abs(x_ref) * m
        nz = (abs_ref > 0.0).astype(jnp.float32)
        rel = jnp.where(abs_ref > 0.0,
                        abs_err / jnp.where(abs_ref > 0.0, abs_ref, 1.0), 0.0)
        stats = QuantStats(
            count=jnp.sum(m, axis=axes),
            nonzero=jnp.sum(nz, axis=axes),
            overflow=jnp.sum(over, axis=axes),
            abs_err_sum=jnp.sum(abs_err, axis=axes),
            rel_err_sum=jnp.sum(rel, axis=axes),
            abs_sum=jnp.sum(abs_ref, axis=axes),
            max_abs=(jnp.max(jnp.abs(xf) * m, axis=axes) if x.size
                     else jnp.zeros(fmt.il.shape, jnp.float32)),
        )
    return wire, stats


def quantize_tree(tree, fmt: FixedPointFormat, *, mode: str = ROUND_STOCHASTIC,
                  key: Optional[jax.Array] = None, predicate=None):
    """Quantize every leaf of a pytree with one shared format.

    ``predicate(path, leaf) -> bool`` selects which leaves are quantized
    (see ``repro.core.policy``).  Returns ``(tree_q, merged QuantStats)``.
    Per-leaf RNG derives from ``key`` by leaf index (stable ordering).

    Leaves are SERIALIZED with ``optimization_barrier``: each quantization
    event's temporaries (the u32 random-bits tensor + fp32 working copies,
    ~6× the leaf in bytes) are live one leaf at a time instead of
    concurrently.  The buffer-assignment dump of the 236B-MoE train step
    showed ~19 GiB of co-scheduled quantization temporaries without this;
    with the chain the peak is one leaf's worth.  (A reshape-into-chunks
    variant is NOT usable here: flattening a sharded leaf makes XLA gather
    the full logical tensor on every device.)
    """
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out, stats = [], QuantStats.zero()
    dep = jnp.zeros((), jnp.float32)
    for i, (path, leaf) in enumerate(leaves):
        if predicate is not None and not predicate(path, leaf):
            out.append(leaf)
            continue
        leaf_d, _ = jax.lax.optimization_barrier((leaf, dep))
        k = jax.random.fold_in(key, i) if key is not None else None
        q, s = _quantize_leaf(leaf_d, fmt, mode, k)
        out.append(q)
        stats = stats.merge(s)
        dep = s.count
    return jax.tree_util.tree_unflatten(treedef, [v for v in out]), stats


def _quantize_leaf(leaf: jax.Array, fmt: FixedPointFormat, mode: str, key):
    """Quantize one tree leaf with bounded temporaries.

    Layer-stacked weights (ndim ≥ 3, leading dim = layers, never sharded)
    are processed per-layer under ``lax.map``: the u32 random-bits tensor
    and the fp32 working copies are then one layer-slice each instead of
    one full-stack each (~7× leaf bytes — the dominant train-step
    temporary at 100B+ scale).  ``lax.map`` over the UNSHARDED leading axis
    keeps every slice's sharding; flattening a sharded leaf instead would
    all-gather it (measured: 2.6 TB temp on the 236B MoE).
    """
    if leaf.ndim >= 3 and leaf.shape[0] > 4 and leaf.size > (1 << 22):
        keys = (jax.random.split(key, leaf.shape[0]) if key is not None
                else jnp.zeros((leaf.shape[0], 2), jnp.uint32))

        def body(xs):
            sl, k = xs
            return quantize(sl, fmt, mode=mode,
                            key=k if key is not None else None)

        q, s = jax.lax.map(body, (leaf, keys))
        return q, QuantStats(
            count=jnp.sum(s.count), nonzero=jnp.sum(s.nonzero),
            overflow=jnp.sum(s.overflow), abs_err_sum=jnp.sum(s.abs_err_sum),
            rel_err_sum=jnp.sum(s.rel_err_sum), abs_sum=jnp.sum(s.abs_sum),
            max_abs=jnp.max(s.max_abs))
    return quantize(leaf, fmt, mode=mode, key=key)
