"""Quantized-training plumbing: taps, per-attribute DPS bundles, train-state.

Wires the paper's Algorithm 1 into an arbitrary JAX model:

  forward pass   — activations pass through :func:`act_tap` (quantize + stats
                   on the way down, gradient quantization on the way back up
                   via ``custom_vjp``),
  backward pass  — parameter gradients are quantized before the optimizer;
                   the loss's own logit-gradient (the paper's "last layer
                   gradients") is quantized with stats,
  weight update  — updated weights are re-snapped to the weight grid
                   (stochastic rounding makes tiny updates survive in
                   expectation, the property Gupta et al. identified),
  scale_precision — one controller per attribute consumes the step's merged
                   stats and emits the next step's ⟨IL, FL⟩.

Everything here is shape-polymorphic and mesh-agnostic: stats are plain
``jnp`` reductions, so under ``pjit`` they come out globally reduced, and the
⟨IL, FL⟩ state is replicated.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import dps as dps_lib
from repro.core import fixed_point as fxp
from repro.core.fixed_point import FixedPointFormat, QuantStats
from repro.core.policy import QuantPolicy

ATTRS = ("weights", "acts", "grads")


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """Static configuration of the quantized-training scheme."""

    enabled: bool = True
    controller: str = "paper"
    rounding: str = fxp.ROUND_STOCHASTIC
    policy: QuantPolicy = QuantPolicy()
    # one hyper per attribute; the paper runs one Alg.-2 instance each for
    # weights, activations and gradients (global granularity).
    hyper_weights: dps_lib.DPSHyper = dps_lib.DPSHyper()
    hyper_acts: dps_lib.DPSHyper = dps_lib.DPSHyper()
    hyper_grads: dps_lib.DPSHyper = dps_lib.DPSHyper(il_init=8, fl_init=16)
    stat_scope: str = "global"          # "global" | "last_layer"
    master_weights: bool = False        # keep an fp copy (beyond-paper)
    # Opt-in compressed gradient synchronization: when set (8 to start),
    # parameter gradients are averaged across the data axis by an explicit
    # shard_map'ed int8-wire ``dps_allreduce_mean`` instead of GSPMD's
    # implicit fp32 psum, and the wire-leg QuantStats merge into the grads
    # DPS stats — so wire quantization error steers ⟨IL, FL⟩.  Needs
    # ``make_train_step(..., mesh=...)``; degrades to the identity on
    # single-device meshes.
    grad_allreduce_bits: Optional[int] = None

    def controllers(self):
        mk = dps_lib.make_controller
        return {
            "weights": mk(self.controller, self.hyper_weights),
            "acts": mk(self.controller, self.hyper_acts),
            "grads": mk(self.controller, self.hyper_grads),
        }


def init_dps_bundle(qcfg: QuantConfig) -> Dict[str, Any]:
    """Initial DPS controller states, one per attribute."""
    return {k: c.init() for k, c in qcfg.controllers().items()}


def bundle_formats(qcfg: QuantConfig, bundle) -> Dict[str, FixedPointFormat]:
    ctrls = qcfg.controllers()
    return {k: ctrls[k].fmt(bundle[k]) for k in ATTRS}


def update_dps_bundle(qcfg: QuantConfig, bundle, stats: Dict[str, QuantStats],
                      aux=None) -> Dict[str, Any]:
    ctrls = qcfg.controllers()
    return {k: ctrls[k].update(bundle[k], stats[k], aux) for k in ATTRS}


# ---------------------------------------------------------------------------
# Activation tap: quantize forward, quantize the cotangent backward.
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class QCtx:
    """Per-step quantization context handed to model code.

    ``None`` (the default ``QCtx.off()``-less path) disables taps entirely —
    model code guards with ``if qctx is not None``.
    """

    acts_fmt: FixedPointFormat
    grads_fmt: FixedPointFormat
    key: jax.Array
    rounding: str = dataclasses.field(metadata=dict(static=True))
    collect_stats: bool = dataclasses.field(metadata=dict(static=True))

    def tap(self, x: jax.Array, salt):
        """Quantize activation ``x``; returns ``(q, QuantStats)``.

        ``salt`` decorrelates rounding noise across call sites; inside a
        scanned stack pass the per-layer key/index.
        """
        kf = jax.random.fold_in(self.key, _salt_to_int(salt))
        kb = jax.random.fold_in(kf, 0x9E3779B9)
        q, stats = _qtap(self.rounding, x, self.acts_fmt, self.grads_fmt, kf, kb)
        if not self.collect_stats:
            stats = None
        return q, stats


def _salt_to_int(salt) -> jax.Array:
    if isinstance(salt, str):
        import zlib
        return jnp.uint32(zlib.crc32(salt.encode()))  # stable across processes
    return jnp.asarray(salt, jnp.uint32)


from functools import partial


@partial(jax.custom_vjp, nondiff_argnums=(0,))
def _qtap(mode, x, a_fmt, g_fmt, kf, kb):
    q, stats = fxp.quantize(x, a_fmt, mode=mode, key=kf, compute_stats=True)
    return q, stats


def _qtap_fwd(mode, x, a_fmt, g_fmt, kf, kb):
    out = _qtap(mode, x, a_fmt, g_fmt, kf, kb)
    return out, (g_fmt, kb)


def _qtap_bwd(mode, res, cot):
    g_fmt, kb = res
    gq, _ = fxp.quantize(cot[0], g_fmt, mode=mode, key=kb, compute_stats=False)
    return (gq, None, None, None, None)


_qtap.defvjp(_qtap_fwd, _qtap_bwd)


# ---------------------------------------------------------------------------
# Weight / gradient tree quantization.
# ---------------------------------------------------------------------------

def quantize_params(params, fmt: FixedPointFormat, qcfg: QuantConfig, key):
    """Snap the parameter tree to the weight grid. Returns (qparams, stats)."""
    if not qcfg.enabled or not qcfg.policy.quantize_weights:
        return params, QuantStats.zero()
    return fxp.quantize_tree(params, fmt, mode=qcfg.rounding, key=key,
                             predicate=qcfg.policy.param_predicate())


def quantize_grads(grads, fmt: FixedPointFormat, qcfg: QuantConfig, key):
    """Quantize parameter gradients before the optimizer step."""
    if not qcfg.enabled or not qcfg.policy.quantize_grads:
        return grads, QuantStats.zero()
    return fxp.quantize_tree(grads, fmt, mode=qcfg.rounding, key=key,
                             predicate=qcfg.policy.param_predicate())


# ---------------------------------------------------------------------------
# Train state + generic quantized train step.
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class TrainState:
    step: jax.Array
    params: Any
    opt_state: Any
    dps: Any                 # {attr: controller state}
    rng: jax.Array
    # rolling telemetry (replicated scalars) for logging/benchmarks:
    last_loss: jax.Array

    @staticmethod
    def create(params, opt_state, qcfg: QuantConfig, rng) -> "TrainState":
        return TrainState(
            step=jnp.zeros((), jnp.int32),
            params=params,
            opt_state=opt_state,
            dps=init_dps_bundle(qcfg),
            rng=rng,
            last_loss=jnp.zeros((), jnp.float32),
        )


def make_train_step(loss_fn, optimizer, qcfg: QuantConfig,
                    accum_steps: int = 1, mesh=None, data_axis: str = "data"):
    """Build a quantized SGD/AdamW train step around ``loss_fn``.

    ``loss_fn(params, batch, qctx) -> (loss, aux)`` where ``aux`` is a dict
    that may contain ``"act_stats"`` (merged QuantStats from taps) and
    ``"dlogits_stats"`` (last-layer gradient stats, see models).  The
    returned step is pure: ``step(state, batch) -> (state, metrics)``.

    ``accum_steps > 1`` splits the global batch into microbatches scanned
    sequentially with fp32 gradient accumulation — the standard way to fit
    the large train cells in per-device HBM (activation memory scales with
    the microbatch, gradients are one extra params-sized buffer).

    ``qcfg.grad_allreduce_bits`` + ``mesh``: the forward/backward runs
    inside a ``shard_map`` over ``data_axis`` (params replicated, batch
    split) and parameter gradients are averaged by the int8-wire
    :func:`repro.dist.collectives.dps_allreduce_mean` — ~4× fewer gradient
    wire bytes than the implicit fp32 psum.  The wire format is derived
    from the grads controller's ⟨IL, FL⟩ (:func:`wire_format`), and the
    dispatch-leg QuantStats merge into the grads stats the DPS bundle
    update consumes.  The path engages only on pure data-parallel meshes
    (every non-``data_axis`` mesh axis of size 1): JAX 0.4's partial-manual
    ``shard_map`` (``auto=``) miscompiles the mixed GSPMD/manual case, so
    tensor-parallel meshes fall back to the implicit psum with a warning.
    On a single-device mesh (or ``mesh=None``) the path degrades to the
    identity all-reduce: the step is bit-identical to the uncompressed one.
    """
    ctrls = qcfg.controllers()
    rounding = getattr(ctrls["weights"], "rounding", qcfg.rounding)

    wire_bits = qcfg.grad_allreduce_bits
    if wire_bits is not None and not 2 <= wire_bits <= 8:
        raise ValueError(f"grad_allreduce_bits={wire_bits}: the wire payload "
                         "is int8, so only 2..8 grid bits are supported")
    axis_sizes = (dict(zip(mesh.axis_names, mesh.devices.shape))
                  if mesh is not None else {})
    n_data = int(axis_sizes.get(data_axis, 1))
    wire_sync = wire_bits is not None and n_data > 1
    if wire_sync and any(s > 1 for a, s in axis_sizes.items()
                         if a != data_axis):
        warnings.warn(
            "grad_allreduce_bits needs a pure data-parallel mesh (all "
            f"non-'{data_axis}' axes of size 1); got {axis_sizes}. Falling "
            "back to the implicit fp32 gradient all-reduce.")
        wire_sync = False
    if wire_sync:
        from repro.dist import collectives  # deferred: dist imports core

    def _grads(qparams, batch, fmts, k_a, microbatch_idx):
        qctx = None
        if qcfg.enabled and qcfg.policy.quantize_acts:
            qctx = QCtx(acts_fmt=fmts["acts"], grads_fmt=fmts["grads"],
                        key=jax.random.fold_in(k_a, microbatch_idx),
                        rounding=rounding, collect_stats=True)
        return jax.value_and_grad(loss_fn, has_aux=True)(qparams, batch, qctx)

    def _accum_grads(qparams, batch, fmts, k_a):
        if accum_steps == 1:
            return _grads(qparams, batch, fmts, k_a, 0)
        micro = jax.tree.map(
            lambda x: x.reshape((accum_steps, x.shape[0] // accum_steps)
                                + x.shape[1:]), batch)

        def body(carry, xs):
            loss_acc, g_acc, stats_acc, idx = carry
            (loss, aux), g = _grads(qparams, xs, fmts, k_a, idx)
            g_acc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32),
                                 g_acc, g)
            stats_acc = stats_acc.merge(aux.get("act_stats",
                                                QuantStats.zero()))
            return (loss_acc + loss, g_acc, stats_acc, idx + 1), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), qparams)
        (loss, g, stats, _), _ = jax.lax.scan(
            body, (jnp.zeros((), jnp.float32), g0, QuantStats.zero(),
                   jnp.zeros((), jnp.uint32)), micro,
            length=accum_steps)
        n = float(accum_steps)
        grads = jax.tree.map(lambda x, p: (x / n).astype(p.dtype), g, qparams)
        return (loss / n, {"act_stats": stats}), grads

    def _wire_synced_grads(qparams, batch, fmts, k_a, k_r):
        """Per-shard fwd/bwd + compressed gradient mean over ``data_axis``.

        Runs the whole gradient computation inside a full-manual
        ``shard_map``: each data shard sees its slice of the batch,
        computes local gradients, and the tree-wide
        ``dps_allreduce_mean`` replaces the implicit psum.  Scalars
        (loss, acc) come back pmean'ed and QuantStats psum'ed, so the
        caller sees the same global quantities as the GSPMD path.
        """
        def body(qparams, batch, fmts, k_a, k_r):
            rank = jax.lax.axis_index(data_axis)
            wfmt = collectives.wire_format(fmts["grads"], wire_bits)
            (loss, aux), grads = _accum_grads(
                qparams, batch, fmts, jax.random.fold_in(k_a, rank))
            grads, wstats = collectives.dps_allreduce_mean_tree(
                grads, wfmt, data_axis, k_r, mode=rounding)
            wstats = collectives.psum_stats(wstats, data_axis)
            loss = jax.lax.pmean(loss, data_axis)
            aux = {k: (collectives.psum_stats(v, data_axis)
                       if isinstance(v, QuantStats)
                       else jax.lax.pmean(v, data_axis))
                   for k, v in aux.items()}
            return (loss, aux), grads, wstats

        fn = jax.shard_map(body, mesh=mesh,
                           in_specs=(P(), P(data_axis), P(), P(), P()),
                           out_specs=(P(), P(), P()), check_vma=False)
        return fn(qparams, batch, fmts, k_a, k_r)

    def train_step(state: TrainState, batch):
        key = jax.random.fold_in(state.rng, state.step)
        k_w, k_g, k_a = jax.random.split(key, 3)
        fmts = bundle_formats(qcfg, state.dps)

        # -- forward/backward in the quantized regime (Alg. 1 lines 9-20) --
        qparams, w_stats = quantize_params(state.params, fmts["weights"], qcfg, k_w)
        if wire_sync:
            # the wire path derives its own RNG stream instead of widening
            # the step's key split, so the default path stays bit-identical
            # to a step built without a mesh.
            k_r = jax.random.fold_in(key, 0x57495245)  # "WIRE"
            (loss, aux), grads, wire_stats = _wire_synced_grads(
                qparams, batch, fmts, k_a, k_r)
        else:
            (loss, aux), grads = _accum_grads(qparams, batch, fmts, k_a)
            wire_stats = None

        grads, g_stats = quantize_grads(grads, fmts["grads"], qcfg, k_g)
        if "dlogits_stats" in aux and qcfg.stat_scope == "last_layer":
            g_stats = aux["dlogits_stats"]
        elif "dlogits_stats" in aux:
            g_stats = g_stats.merge(aux["dlogits_stats"])
        if wire_stats is not None:
            # wire error feeds the grads controller: a too-coarse wire grid
            # raises E (-> FL up), wire clipping raises R (-> IL up).
            g_stats = g_stats.merge(wire_stats)
        if qcfg.stat_scope == "last_layer" and "last_act_stats" in aux:
            a_stats = aux["last_act_stats"]
        else:
            a_stats = aux.get("act_stats", QuantStats.zero())

        # -- update + re-snap weights to the grid (Alg. 1 lines 18-19) --
        updates, opt_state = optimizer.update(grads, state.opt_state,
                                              state.params, count=state.step)
        new_params = jax.tree.map(lambda p, u: p + u, state.params, updates)
        if qcfg.enabled and qcfg.policy.quantize_weights and not qcfg.master_weights:
            new_params, w_stats2 = quantize_params(
                new_params, fmts["weights"], qcfg, jax.random.fold_in(k_w, 1))
            w_stats = w_stats.merge(w_stats2)

        # -- scale_precision (Alg. 2, one controller per attribute) --
        stats = {"weights": w_stats, "acts": a_stats, "grads": g_stats}
        new_dps = update_dps_bundle(qcfg, state.dps, stats, {"loss": loss})

        metrics = {
            "loss": loss,
            "il_w": fmts["weights"].il, "fl_w": fmts["weights"].fl,
            "il_a": fmts["acts"].il, "fl_a": fmts["acts"].fl,
            "il_g": fmts["grads"].il, "fl_g": fmts["grads"].fl,
            "E_w": w_stats.quant_error(), "R_w": w_stats.overflow_rate(),
            "E_a": a_stats.quant_error(), "R_a": a_stats.overflow_rate(),
            "E_g": g_stats.quant_error(), "R_g": g_stats.overflow_rate(),
        }
        if wire_stats is not None:
            metrics["E_wire"] = wire_stats.quant_error()
            metrics["R_wire"] = wire_stats.overflow_rate()
        new_state = TrainState(
            step=state.step + 1, params=new_params, opt_state=opt_state,
            dps=new_dps, rng=state.rng, last_loss=loss.astype(jnp.float32))
        return new_state, metrics

    # introspection for drivers/tests: did the compressed path engage?
    train_step.wire_sync_active = wire_sync
    return train_step
