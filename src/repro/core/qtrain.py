"""Quantized-training plumbing: taps, precision-domain registry, train-state.

Wires the paper's Algorithm 1 into an arbitrary JAX model:

  forward pass   — activations pass through :func:`act_tap` (quantize + stats
                   on the way down, gradient quantization on the way back up
                   via ``custom_vjp``),
  backward pass  — parameter gradients are quantized before the optimizer;
                   the loss's own logit-gradient (the paper's "last layer
                   gradients") is quantized with stats,
  weight update  — updated weights are re-snapped to the weight grid
                   (stochastic rounding makes tiny updates survive in
                   expectation, the property Gupta et al. identified),
  scale_precision — one controller per **precision domain** consumes the
                   step's merged stats and emits the next step's ⟨IL, FL⟩.

Precision domains generalize the paper's fixed weights/acts/grads triple: a
:class:`~repro.core.dps.PrecisionPlan` (``QuantConfig.plan()``) declares a
named registry of ``{domain: controller kind, hyper, stats routing, group
count}`` that builds the pytree :class:`~repro.core.dps.DpsBundle` threaded
through :class:`TrainState`.  The standard plan carries the three compute
domains plus dedicated **wire domains** when compressed gradient sync is on:

  ``wire_grads``   — owns the int8 format of the gradient all-reduce /
                     reduce-scatter leg, fed by that leg's wire QuantStats
                     (default controller "flexpoint": max-abs-driven radix,
                     Köster et al.);
  ``wire_params``  — owns the ZeRO-1 parameter all-gather leg's format,
                     fed by the params-leg wire stats.

Wire stats feed *only* their wire domain — never the compute controllers.
Deriving the wire grid from the grads controller's IL (the pre-registry
``wire_format``-of-the-compute-format scheme) let a few clipped wire
elements ratchet IL up, coarsen the ⟨IL, 8−IL⟩ wire grid, and rail the
compute FL at its cap chasing irreducible wire error (the instability
pinned — now as a stability guarantee — by
``tests/test_train_allreduce.py``).

Everything here is shape-polymorphic and mesh-agnostic: stats are plain
``jnp`` reductions, so under ``pjit`` they come out globally reduced, and the
⟨IL, FL⟩ state is replicated.
"""

from __future__ import annotations

import dataclasses
import math
import warnings
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import dps as dps_lib
from repro.core import fixed_point as fxp
from repro.core.dps import DpsBundle, DomainSpec, PrecisionPlan
from repro.core.fixed_point import FixedPointFormat, QuantStats
from repro.core.policy import QuantPolicy


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """Static configuration of the quantized-training scheme."""

    enabled: bool = True
    controller: str = "paper"
    rounding: str = fxp.ROUND_STOCHASTIC
    policy: QuantPolicy = QuantPolicy()
    # one hyper per compute domain; the paper runs one Alg.-2 instance each
    # for weights, activations and gradients (global granularity).
    hyper_weights: dps_lib.DPSHyper = dps_lib.DPSHyper()
    hyper_acts: dps_lib.DPSHyper = dps_lib.DPSHyper()
    hyper_grads: dps_lib.DPSHyper = dps_lib.DPSHyper(il_init=8, fl_init=16)
    stat_scope: str = "global"          # "global" | "last_layer"
    master_weights: bool = False        # keep an fp copy (beyond-paper)
    # Wire precision domains: with compressed gradient sync on, each int8
    # collective leg runs its own controller — "wire_grads" for the gradient
    # scatter/all-reduce leg, "wire_params" for the ZeRO parameter all-gather
    # leg — instead of deriving ⟨IL, 8−IL⟩ from a compute controller (the
    # ratchet failure documented in dist/README.md).  "flexpoint" places the
    # wire radix just above the observed max |x| at a fixed wire width, so
    # stray clipped elements cannot ratchet the grid coarser.
    wire_controller: str = "flexpoint"
    hyper_wire_grads: Optional[dps_lib.DPSHyper] = None   # None -> derived
    hyper_wire_params: Optional[dps_lib.DPSHyper] = None  # None -> derived
    # Measured wire slack: derive each wire domain's radix headroom from
    # its own measured abs_sum/nonzero tail quantile instead of the
    # hand-tuned per-tensor-class constants (dps.wire_hyper(auto_slack=
    # True)).  Only affects the DERIVED wire hypers — an explicit
    # hyper_wire_* wins.
    wire_auto_slack: bool = False
    # Per-LAYER wire formats: 0 = one global wire ⟨IL, FL⟩ (scalar state);
    # G > 0 gives the ``wire_grads`` domain a [G] controller state — one
    # ⟨IL, FL⟩ per gradient-tree leaf, fed group-wise by the collective's
    # [G] wire stats and handed to the group-aligned collectives as the
    # [G, 2] kernel format table.  G must equal the grad tree's leaf count
    # when the compressed sync engages (``make_train_step`` checks);
    # ``with_per_layer_wire`` derives it from a params tree.  Under
    # ``zero_opt_shards`` the flat optimizer layout switches to the
    # group-aligned :class:`~repro.dist.sharding.GroupAlignedPartitioner`
    # (leaf slots padded to the wire quantum), so per-leaf boundaries —
    # and with them the per-leaf ⟨IL, FL⟩ — survive the flatten and both
    # sharded wire legs run the grouped codec.  ``wire_params`` mirrors
    # the group count: one params-leg format per leaf too.
    wire_grads_groups: int = 0
    # Full custom registry: overrides the standard five-domain plan built
    # from the fields above.
    precision_plan: Optional[PrecisionPlan] = None
    # Opt-in compressed gradient synchronization: when set (8 to start),
    # parameter gradients are averaged across the data axis by an explicit
    # shard_map'ed int8-wire ``dps_allreduce_mean`` instead of GSPMD's
    # implicit fp32 psum, and the wire-leg QuantStats merge into the grads
    # DPS stats — so wire quantization error steers ⟨IL, FL⟩.  Needs
    # ``make_train_step(..., mesh=...)``; degrades to the identity on
    # single-device meshes.
    grad_allreduce_bits: Optional[int] = None
    # Backward-overlapped bucketed wire (repro.dist.overlap): with the
    # compressed sync engaged, split the gradient tree into DDP-style
    # buckets (contiguous leaf runs in backward ready order — last layer
    # first) and run one compressed collective pair per bucket instead of
    # one monolithic pair for the tree.  Each bucket's wire legs depend
    # only on its own leaves, so collective dispatch can overlap the
    # remaining backward, working sets stay bucket-sized, and per-bucket
    # GroupLayouts shrink grouped-padding overhead.  Gradient-readiness
    # taps (custom-vjp identities on the params) mark each bucket's
    # materialization point in the backward jaxpr; the precision-flow
    # verifier's PF-BUCKET rules prove every bucket is encoded exactly
    # once and decoded before the optimizer consumes it.  No effect
    # without ``grad_allreduce_bits``.  Composes with
    # ``zero_opt_shards``: the group-aligned ZeRO layout materializes
    # each bucket as a contiguous run of aligned leaf slots, so the
    # sharded path runs one int8 reduce-scatter per bucket in the same
    # backward-ready order (the all-gather return leg stays monolithic —
    # it has no readiness structure to exploit).
    wire_overlap: bool = False
    wire_bucket_elems: int = 0          # 0 -> overlap.DEFAULT_BUCKET_ELEMS
    # Numeric health guards (repro.resilience): a GuardConfig arms the
    # on-device step health monitor — loss/gradient NaN detection with a
    # skip gate, per-wire-domain overflow-storm EWMAs, gradient-norm
    # spike detection, controller rail bits — and the graceful
    # degradation state machine that swaps a tripped wire domain's int8
    # collective for its fp32 fallback through a traced flag (both
    # branches live in the one compiled step; int8 re-arms after a
    # cooldown of clean steps).  None (the default) leaves the step's
    # jaxpr untouched; with guards armed and no fault the trajectory is
    # bit-exact with the unguarded step (see tests/test_resilience.py).
    guards: Optional[Any] = None
    # ZeRO-1: shard the optimizer state across the data axis into this many
    # slices (must equal the mesh's data-axis size when it engages).  The
    # param tree is flattened into a padded 1-D layout so non-divisible
    # leaves still shard — the plain ZeroPartitioner normally, or the
    # group-aligned :class:`~repro.dist.sharding.GroupAlignedPartitioner`
    # when per-layer wire formats or ``wire_overlap`` engage (see
    # :func:`zero_partitioner`); each rank steps its slice locally and
    # the updated parameter shards are all-gathered back.  Combined
    # with ``grad_allreduce_bits``, both collective legs (reduce-scatter of
    # grads, all-gather of params) ride the int8 wire.  Optimizer state is
    # created with :func:`zero_opt_state` instead of ``optimizer.init``.
    # Engages on pure data-parallel meshes only (same JAX partial-manual
    # shard_map constraint as the compressed all-reduce); degrades to the
    # replicated step on a single device or without a mesh.
    zero_opt_shards: Optional[int] = None

    def plan(self) -> PrecisionPlan:
        """The precision-domain registry this config trains under.

        The standard plan: one domain per compute attribute (same controller
        kind, per-domain hyper), plus ``wire_grads`` whenever
        ``grad_allreduce_bits`` is set and ``wire_params`` when ZeRO-1 can
        additionally put the parameter all-gather on the wire.  A custom
        ``precision_plan`` replaces all of it.
        """
        if self.precision_plan is not None:
            return self.precision_plan
        domains = [
            ("weights", DomainSpec(self.controller, self.hyper_weights)),
            ("acts", DomainSpec(self.controller, self.hyper_acts)),
            ("grads", DomainSpec(self.controller, self.hyper_grads)),
        ]
        wb = self.grad_allreduce_bits
        if wb is not None:
            # default radix placement mirrors the tensor class (see
            # dps.wire_hyper): gradients start wide (±2^5 covers typical
            # init grads) and track the bulk two octaves under the max
            # (slack -2: clip the rare tail, keep grid resolution);
            # parameters are O(1), concentrated, and bias under clipping,
            # so their radix covers the max with headroom (slack +1).
            # wire_grads_groups > 0 turns the domain per-layer: a [G]
            # controller state driving the [G, 2] kernel format table.
            domains.append(("wire_grads", DomainSpec(
                self.wire_controller,
                self.hyper_wire_grads
                or dps_lib.wire_hyper(wb, il_init=6, slack=-2.0,
                                      auto_slack=self.wire_auto_slack),
                groups=self.wire_grads_groups, wire=True)))
            if self.zero_opt_shards is not None:
                # wire_params mirrors the grads domain's granularity: the
                # group-aligned layout keeps leaf boundaries, so per-layer
                # wire runs one params-leg ⟨IL, FL⟩ per leaf as well.
                domains.append(("wire_params", DomainSpec(
                    self.wire_controller,
                    self.hyper_wire_params
                    or dps_lib.wire_hyper(wb, il_init=2, slack=1.0,
                                          auto_slack=self.wire_auto_slack),
                    groups=self.wire_grads_groups, wire=True)))
        return PrecisionPlan(tuple(domains))

    def with_per_layer_wire(self, params) -> "QuantConfig":
        """This config with one ``wire_grads`` format per leaf of
        ``params`` (a concrete or abstract tree) — the per-layer wire
        regime the group-aligned collectives run at kernel speed.  A
        no-op unless ``grad_allreduce_bits`` is set."""
        if self.grad_allreduce_bits is None or self.precision_plan is not None:
            return self
        return dataclasses.replace(
            self, wire_grads_groups=len(jax.tree_util.tree_leaves(params)))


def init_dps_bundle(qcfg: QuantConfig) -> DpsBundle:
    """Initial DPS registry: one controller state per declared domain."""
    return qcfg.plan().init()


def bundle_formats(qcfg: QuantConfig, bundle: DpsBundle
                   ) -> Dict[str, FixedPointFormat]:
    """Per-domain ⟨IL, FL⟩ for this step, keyed by domain name."""
    return qcfg.plan().formats(bundle)


def update_dps_bundle(qcfg: QuantConfig, bundle: DpsBundle,
                      streams: Dict[str, QuantStats], aux=None) -> DpsBundle:
    """scale_precision over the registry: each domain consumes the stats
    stream its spec routes to (absent streams read as zero stats)."""
    return qcfg.plan().update(bundle, streams, aux)


def dps_restore_defaults(qcfg: QuantConfig, prefix: str = ".dps") -> dict:
    """Checkpoint back-compat defaults: a fresh DPS registry, flattened to
    the checkpoint's ``".dps/<domain>/.<field>"`` key paths (the leading
    dots are how ``GetAttrKey`` stringifies — ``TrainState`` is a
    registered dataclass, so its checkpoint keys carry them).

    Pass as ``ckpt.restore(..., defaults=...)`` so a run configured with
    wire domains resumes from a legacy checkpoint that only carries the
    three-key compute bundle — the missing domains initialize fresh while
    everything present in the checkpoint restores normally.
    """
    from repro.checkpoint import flatten_tree  # deferred: io imports core
    return {f"{prefix}/{k}": v
            for k, v in flatten_tree(init_dps_bundle(qcfg)).items()}


def guard_restore_defaults(qcfg: QuantConfig, prefix: str = ".guard") -> dict:
    """Checkpoint back-compat defaults for the guard subtree: a run with
    ``qcfg.guards`` armed resumes from a checkpoint written without guards
    (the missing :class:`~repro.resilience.GuardState` initializes fresh).
    Empty when guards are off."""
    if qcfg.guards is None:
        return {}
    from repro.resilience import guards as guards_lib  # deferred
    return guards_lib.guard_restore_defaults(qcfg.plan(), prefix)


# ---------------------------------------------------------------------------
# Activation tap: quantize forward, quantize the cotangent backward.
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class QCtx:
    """Per-step quantization context handed to model code.

    ``None`` (the default ``QCtx.off()``-less path) disables taps entirely —
    model code guards with ``if qctx is not None``.
    """

    acts_fmt: FixedPointFormat
    grads_fmt: FixedPointFormat
    key: jax.Array
    rounding: str = dataclasses.field(metadata=dict(static=True))
    collect_stats: bool = dataclasses.field(metadata=dict(static=True))

    def tap(self, x: jax.Array, salt):
        """Quantize activation ``x``; returns ``(q, QuantStats)``.

        ``salt`` decorrelates rounding noise across call sites; inside a
        scanned stack pass the per-layer key/index.
        """
        kf = jax.random.fold_in(self.key, _salt_to_int(salt))
        kb = jax.random.fold_in(kf, 0x9E3779B9)
        q, stats = _qtap(self.rounding, x, self.acts_fmt, self.grads_fmt, kf, kb)
        if not self.collect_stats:
            stats = None
        return q, stats


def _salt_to_int(salt) -> jax.Array:
    if isinstance(salt, str):
        import zlib
        return jnp.uint32(zlib.crc32(salt.encode()))  # stable across processes
    return jnp.asarray(salt, jnp.uint32)


from functools import partial


@partial(jax.custom_vjp, nondiff_argnums=(0,))
def _qtap(mode, x, a_fmt, g_fmt, kf, kb):
    q, stats = fxp.quantize(x, a_fmt, mode=mode, key=kf, compute_stats=True)
    return q, stats


def _qtap_fwd(mode, x, a_fmt, g_fmt, kf, kb):
    out = _qtap(mode, x, a_fmt, g_fmt, kf, kb)
    return out, (g_fmt, kb)


def _qtap_bwd(mode, res, cot):
    g_fmt, kb = res
    gq, _ = fxp.quantize(cot[0], g_fmt, mode=mode, key=kb, compute_stats=False)
    return (gq, None, None, None, None)


_qtap.defvjp(_qtap_fwd, _qtap_bwd)


# ---------------------------------------------------------------------------
# Weight / gradient tree quantization.
# ---------------------------------------------------------------------------

def quantize_params(params, fmt: FixedPointFormat, qcfg: QuantConfig, key):
    """Snap the parameter tree to the weight grid. Returns (qparams, stats)."""
    if not qcfg.enabled or not qcfg.policy.quantizes("weights"):
        return params, QuantStats.zero()
    return fxp.quantize_tree(params, fmt, mode=qcfg.rounding, key=key,
                             predicate=qcfg.policy.param_predicate())


def quantize_grads(grads, fmt: FixedPointFormat, qcfg: QuantConfig, key):
    """Quantize parameter gradients before the optimizer step."""
    if not qcfg.enabled or not qcfg.policy.quantizes("grads"):
        return grads, QuantStats.zero()
    return fxp.quantize_tree(grads, fmt, mode=qcfg.rounding, key=key,
                             predicate=qcfg.policy.param_predicate())


# ---------------------------------------------------------------------------
# Train state + generic quantized train step.
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class TrainState:
    step: jax.Array
    params: Any
    opt_state: Any
    dps: Any                 # {attr: controller state}
    rng: jax.Array
    # rolling telemetry (replicated scalars) for logging/benchmarks:
    last_loss: jax.Array
    # health-guard state (repro.resilience.GuardState) when
    # ``qcfg.guards`` is armed; None keeps the legacy six-field pytree
    # (an empty subtree — old checkpoints restore without defaults).
    guard: Any = None

    @staticmethod
    def create(params, opt_state, qcfg: QuantConfig, rng) -> "TrainState":
        guard = None
        if qcfg.guards is not None:
            from repro.resilience import guards as guards_lib  # deferred
            guard = guards_lib.init_guard_state(qcfg.plan())
        return TrainState(
            step=jnp.zeros((), jnp.int32),
            params=params,
            opt_state=opt_state,
            dps=init_dps_bundle(qcfg),
            rng=rng,
            last_loss=jnp.zeros((), jnp.float32),
            guard=guard,
        )


def _mesh_axis_sizes(mesh) -> Dict[str, int]:
    return (dict(zip(mesh.axis_names, mesh.devices.shape))
            if mesh is not None else {})


def zero_opt_engaged(qcfg: QuantConfig, mesh, data_axis: str = "data") -> bool:
    """Does the ZeRO-1 sharded-optimizer path engage for (qcfg, mesh)?

    Mirrors :func:`make_train_step`'s own checks so launch code and specs
    can size/shard the optimizer state consistently with the step that will
    actually run: requires ``zero_opt_shards`` set AND equal to the mesh's
    ``data_axis`` size (larger than 1), and a pure data-parallel mesh
    (every other axis of size 1 — the partial-manual shard_map
    constraint).  Any mismatch means the step warns and falls back to the
    replicated optimizer state, so this returns False for it too.
    """
    if qcfg.zero_opt_shards is None:
        return False
    sizes = _mesh_axis_sizes(mesh)
    n_data = int(sizes.get(data_axis, 1))
    if n_data <= 1 or qcfg.zero_opt_shards != n_data:
        return False
    return not any(s > 1 for a, s in sizes.items() if a != data_axis)


def wire_sync_engaged(qcfg: QuantConfig, mesh,
                      data_axis: str = "data") -> bool:
    """Does the compressed gradient all-reduce engage for (qcfg, mesh)?

    Mirrors :func:`make_train_step`'s own checks (the same pure
    data-parallel constraint as :func:`zero_opt_engaged`) so launch and
    analysis code can predict — without building the step — whether the
    ``wire_grads`` domain will actually put payload on the wire.
    """
    if qcfg.grad_allreduce_bits is None:
        return False
    sizes = _mesh_axis_sizes(mesh)
    if int(sizes.get(data_axis, 1)) <= 1:
        return False
    return not any(s > 1 for a, s in sizes.items() if a != data_axis)


def wire_params_engaged(qcfg: QuantConfig, params, mesh,
                        data_axis: str = "data") -> bool:
    """Does the ZeRO-1 parameter all-gather ride the int8 wire?

    The flat wire legs can't honor per-leaf carve-outs, so the params-side
    wire only engages when the quantization policy covers EVERY param leaf
    and no fp master copy is promised (the same static decision
    :func:`make_train_step` makes — see its ``full_quant``).  ``params``
    may be a concrete or abstract (ShapeDtypeStruct) tree.  When this is
    False under an engaged ZeRO + compressed-sync config, the updated
    params are gathered in fp32 by design.
    """
    if not (zero_opt_engaged(qcfg, mesh, data_axis)
            and wire_sync_engaged(qcfg, mesh, data_axis)):
        return False
    if qcfg.master_weights:
        return False
    pred = qcfg.policy.param_predicate()
    return all(pred(path, leaf) for path, leaf in
               jax.tree_util.tree_flatten_with_path(params)[0])


def zero_partitioner(qcfg: QuantConfig, params, n_shards: int):
    """The flat ZeRO-1 layout this config shards its optimizer state over.

    The plain :class:`~repro.dist.sharding.ZeroPartitioner` (minimal
    divisibility padding, leaf boundaries erased) unless the compressed
    sync runs a layout that must keep leaf boundaries — per-layer
    ``wire_grads`` groups or the overlapped bucketed wire — in which case
    the :class:`~repro.dist.sharding.GroupAlignedPartitioner` pads every
    leaf slot to the wire quantum so rank chunks and collective
    boundaries never straddle a leaf and per-leaf ⟨IL, FL⟩ survive the
    flatten.  With ``wire_overlap`` the aligned layout is additionally
    bucketed by :func:`repro.dist.overlap.plan_buckets` (same plan as the
    readiness taps) so each bucket is a contiguous aligned slot run.

    ``params`` may be concrete or abstract.  The decision is mesh-free on
    purpose: it must agree between :func:`zero_opt_state` (called at init,
    often before the mesh exists) and the step body, and every input to it
    is static config.
    """
    from repro.dist.sharding import (  # deferred: dist imports core
        GroupAlignedPartitioner, ZeroPartitioner)
    plan = qcfg.plan()
    groups = plan.spec("wire_grads").groups if "wire_grads" in plan else 0
    aligned = (qcfg.grad_allreduce_bits is not None
               and (groups > 0 or qcfg.wire_overlap))
    if not aligned:
        return ZeroPartitioner.create(params, n_shards)
    buckets = None
    if qcfg.wire_overlap:
        from repro.dist import overlap as overlap_lib
        sizes = tuple(int(math.prod(tuple(l.shape))) or 1
                      for l in jax.tree_util.tree_leaves(params))
        bplan = overlap_lib.plan_buckets(
            sizes, qcfg.wire_bucket_elems or overlap_lib.DEFAULT_BUCKET_ELEMS)
        # BucketPlan lists buckets in backward-ready (reverse flatten)
        # order; the partitioner wants flatten order.
        buckets = tuple(sorted(bplan.buckets, key=lambda r: r[0]))
    return GroupAlignedPartitioner.create(params, n_shards, buckets=buckets)


def zero_opt_state(optimizer, params, n_shards: int,
                   qcfg: Optional[QuantConfig] = None):
    """ZeRO-1 optimizer state: one flat padded vector per state tensor.

    Returns ``optimizer.init_shard`` over the flat ZeRO layout — a GLOBAL
    ``[padded_size]`` array per state leaf, meant to be placed with
    ``NamedSharding(mesh, P("data"))`` so each rank holds ``1/n_shards``
    of it (see ``launch.specs.train_state_shardings``).

    Pass the run's ``qcfg`` so the layout matches the step that will
    consume the state: per-layer wire formats and the overlapped wire run
    the group-aligned layout, whose padded size differs from the plain
    ZeroPartitioner's (see :func:`zero_partitioner`).  ``qcfg=None`` keeps
    the legacy plain layout.
    """
    from repro.dist.sharding import ZeroPartitioner  # deferred: dist imports core
    part = (zero_partitioner(qcfg, params, n_shards) if qcfg is not None
            else ZeroPartitioner.create(params, n_shards))
    flat = jax.eval_shape(lambda t: part.flatten(t), params)
    return optimizer.init_shard(flat)


def make_train_step(loss_fn, optimizer, qcfg: QuantConfig,
                    accum_steps: int = 1, mesh=None, data_axis: str = "data",
                    faults=None):
    """Build a quantized SGD/AdamW train step around ``loss_fn``.

    ``loss_fn(params, batch, qctx) -> (loss, aux)`` where ``aux`` is a dict
    that may contain ``"act_stats"`` (merged QuantStats from taps) and
    ``"dlogits_stats"`` (last-layer gradient stats, see models).  The
    returned step is pure: ``step(state, batch) -> (state, metrics)``.

    ``accum_steps > 1`` splits the global batch into microbatches scanned
    sequentially with fp32 gradient accumulation — the standard way to fit
    the large train cells in per-device HBM (activation memory scales with
    the microbatch, gradients are one extra params-sized buffer).

    ``qcfg.grad_allreduce_bits`` + ``mesh``: the forward/backward runs
    inside a ``shard_map`` over ``data_axis`` (params replicated, batch
    split) and parameter gradients are averaged by the int8-wire
    :func:`repro.dist.collectives.dps_allreduce_mean` — ~4× fewer gradient
    wire bytes than the implicit fp32 psum.  The wire ⟨IL, FL⟩ comes from
    the registry's dedicated ``wire_grads`` domain, and the dispatch-leg
    QuantStats feed that domain's controller (and only it — compute
    controllers never see wire events).  The path engages only on pure
    data-parallel meshes (every non-``data_axis`` mesh axis of size 1):
    JAX 0.4's partial-manual ``shard_map`` (``auto=``) miscompiles the
    mixed GSPMD/manual case, so tensor-parallel meshes fall back to the
    implicit psum with a warning.  On a single-device mesh (or
    ``mesh=None``) the path degrades to the identity all-reduce: the step
    is bit-identical to the uncompressed one.

    ``qcfg.zero_opt_shards`` + ``mesh``: ZeRO-1.  The optimizer state lives
    as flat ``P(data_axis)``-sharded slices of the ZeroPartitioner layout
    (1/n of the replicated bytes per device) and the optimizer steps one
    slice per rank inside the shard_map.  Without ``grad_allreduce_bits``
    the gradients come from the ordinary (implicit-psum) backward pass and
    the update legs are exact, so the step is **bit-exact** with the
    replicated one — fp32 state, ``clip_norm`` off (the cross-shard norm
    psum sums in a different order than the per-leaf norm), and optimizer
    scalars whose products are f32-exact (e.g. power-of-two
    lr/momentum/weight_decay; otherwise layout-dependent FMA contraction
    may drift the state by 1 ULP/step, see ``SGD._leaf``); with it, one
    fused shard_map body runs
    per-shard fwd/bwd → int8 ``dps_reduce_scatter_mean`` → local optimizer
    → int8 ``dps_allgather_params``, the grads-leg wire stats feed the
    ``wire_grads`` domain and the params-leg wire stats feed the
    ``wire_params`` domain.  Same pure-data-parallel constraint and
    single-device degradation as above.

    Per-layer wire formats (``wire_grads_groups > 0``) and the overlapped
    bucketed wire (``wire_overlap``) COMPOSE with ZeRO-1: the flat layout
    switches to the group-aligned partitioner (:func:`zero_partitioner`),
    whose aligned leaf slots keep per-leaf ⟨IL, FL⟩ through the flatten,
    and the fused body becomes readiness-tapped fwd/bwd → grouped int8
    ``zero_bucketed_reduce_scatter`` (one collective per bucket, backward-
    ready order) → local optimizer over aligned slices → grouped int8
    ``zero_allgather_params``.  At ``bits=None``-equivalent settings and
    under nearest rounding the decoded updates are bit-exact vs the
    replicated per-layer step (and under stochastic rounding too: every
    wire rounding-bit draw is keyed by global leaf index, see
    ``repro.dist.overlap``).  Mismatched ``zero_opt_shards`` vs the mesh
    warns and falls back to the replicated state — the same policy as
    every other engagement mismatch; only impossible configs raise.
    """
    plan = qcfg.plan()
    rounding = getattr(plan.controller("weights"), "rounding", qcfg.rounding)
    grad_domain = getattr(optimizer, "grad_domain", "grads")
    if grad_domain not in plan:
        raise ValueError(
            f"{type(optimizer).__name__}.grad_domain = {grad_domain!r} names "
            f"no precision domain in the plan ({plan.names}); the optimizer-"
            "input gradient quantization needs its format from the registry")

    wire_bits = qcfg.grad_allreduce_bits
    if wire_bits is not None and not 2 <= wire_bits <= 8:
        raise ValueError(f"grad_allreduce_bits={wire_bits}: the wire payload "
                         "is int8, so only 2..8 grid bits are supported")
    axis_sizes = _mesh_axis_sizes(mesh)
    n_data = int(axis_sizes.get(data_axis, 1))
    wire_sync = wire_bits is not None and n_data > 1
    if wire_sync and any(s > 1 for a, s in axis_sizes.items()
                         if a != data_axis):
        warnings.warn(
            "grad_allreduce_bits needs a pure data-parallel mesh (all "
            f"non-'{data_axis}' axes of size 1); got {axis_sizes}. Falling "
            "back to the implicit fp32 gradient all-reduce.")
        wire_sync = False

    # Engagement policy (uniform): a config/mesh MISMATCH — the requested
    # path simply cannot engage on this mesh — warns and falls back to the
    # equivalent uncompressed/replicated step; an IMPOSSIBLE config — one
    # no mesh could satisfy — raises.  The chosen paths are surfaced as
    # ``train_step.{wire_sync,zero_opt,wire_overlap,zero_groupaligned}_
    # active`` attributes.
    zero_opt = qcfg.zero_opt_shards is not None and n_data > 1
    if zero_opt and any(s > 1 for a, s in axis_sizes.items()
                        if a != data_axis):
        warnings.warn(
            "zero_opt_shards needs a pure data-parallel mesh (all "
            f"non-'{data_axis}' axes of size 1); got {axis_sizes}. Falling "
            "back to the replicated optimizer state.")
        zero_opt = False
    if zero_opt and qcfg.zero_opt_shards != n_data:
        warnings.warn(
            f"zero_opt_shards={qcfg.zero_opt_shards} does not match the "
            f"mesh's '{data_axis}' axis size ({n_data}); the optimizer "
            "state shards over that axis. Falling back to the replicated "
            "optimizer state.")
        zero_opt = False
    if zero_opt and not hasattr(optimizer, "update_shard"):
        raise TypeError(f"{type(optimizer).__name__} has no shard-local "
                        "update_shard/init_shard interface; ZeRO-1 needs it")
    if wire_sync and "wire_grads" not in plan:
        raise ValueError(
            "grad_allreduce_bits engages the compressed gradient sync but "
            f"the precision plan ({plan.names}) declares no 'wire_grads' "
            "domain to govern the wire format")
    wire_groups = plan.spec("wire_grads").groups if "wire_grads" in plan else 0
    if wire_sync and zero_opt and "wire_params" not in plan:
        raise ValueError(
            "zero_opt_shards + grad_allreduce_bits put the parameter "
            f"all-gather on the int8 wire, but the precision plan "
            f"({plan.names}) declares no 'wire_params' domain")
    wire_overlap = bool(qcfg.wire_overlap) and wire_sync
    # per-layer wire formats and the overlapped bucketed wire keep leaf
    # boundaries through the flatten via the group-aligned layout
    # (zero_partitioner); the sharded legs then run the grouped codec.
    zero_aligned = zero_opt and wire_sync and (wire_groups > 0
                                               or wire_overlap)
    if wire_sync or zero_opt:
        from repro.dist import collectives  # deferred: dist imports core
    if wire_overlap or zero_aligned:
        from repro.dist import overlap as overlap_lib
        bucket_elems = (qcfg.wire_bucket_elems
                        or overlap_lib.DEFAULT_BUCKET_ELEMS)

    # Health guards + fault injection (repro.resilience).  Both are
    # static decisions: guards/faults off leaves every body below — and
    # with it the compiled step — exactly as it was.  ``sig`` extends the
    # shard_map bodies with the extra signal plumbing (degrade flags in,
    # nonfinite count / sharded grad norm out).
    guards_on = qcfg.guards is not None
    if guards_on or faults is not None:
        from repro import resilience as rsl  # deferred: resilience imports core
    sig = guards_on or faults is not None
    wire_names = ()
    gidx = pidx = 0
    if guards_on:
        wire_names = rsl.wire_domains(plan)
        gidx = (wire_names.index("wire_grads")
                if "wire_grads" in wire_names else 0)
        pidx = (wire_names.index("wire_params")
                if "wire_params" in wire_names else 0)
    if (faults is not None and faults.wire_flip_at >= 0
            and not (wire_sync and not wire_overlap and not zero_opt)):
        raise ValueError(
            "FaultPlan.wire_flip_at targets the monolithic tree "
            "all-reduce payload; it needs an engaged compressed sync "
            "without wire_overlap or zero_opt_shards")

    def _grads(qparams, batch, fmts, k_a, microbatch_idx, tap=None):
        qctx = None
        if qcfg.enabled and qcfg.policy.quantizes("acts"):
            qctx = QCtx(acts_fmt=fmts["acts"], grads_fmt=fmts["grads"],
                        key=jax.random.fold_in(k_a, microbatch_idx),
                        rounding=rounding, collect_stats=True)
        # the readiness tap must sit INSIDE the differentiated function:
        # its custom-vjp backward tags each param leaf's cotangent at the
        # point the backward materializes it (repro.dist.overlap).
        fn = (loss_fn if tap is None
              else lambda p, b, c: loss_fn(tap(p), b, c))
        return jax.value_and_grad(fn, has_aux=True)(qparams, batch, qctx)

    def _accum_grads(qparams, batch, fmts, k_a, tap=None):
        if accum_steps == 1:
            return _grads(qparams, batch, fmts, k_a, 0, tap)
        micro = jax.tree.map(
            lambda x: x.reshape((accum_steps, x.shape[0] // accum_steps)
                                + x.shape[1:]), batch)

        def body(carry, xs):
            loss_acc, g_acc, stats_acc, idx = carry
            (loss, aux), g = _grads(qparams, xs, fmts, k_a, idx, tap)
            g_acc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32),
                                 g_acc, g)
            stats_acc = stats_acc.merge(aux.get("act_stats",
                                                QuantStats.zero()))
            return (loss_acc + loss, g_acc, stats_acc, idx + 1), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), qparams)
        (loss, g, stats, _), _ = jax.lax.scan(
            body, (jnp.zeros((), jnp.float32), g0, QuantStats.zero(),
                   jnp.zeros((), jnp.uint32)), micro,
            length=accum_steps)
        n = float(accum_steps)
        grads = jax.tree.map(lambda x, p: (x / n).astype(p.dtype), g, qparams)
        return (loss / n, {"act_stats": stats}), grads

    def _raw_grad_stats(grads, fmts, k_g, rank):
        """Compute-grid gradient stats measured on the RAW local gradients.

        In the wire-synced paths the optimizer-input ``quantize_grads``
        downstream sees gradients that already sit on the (coarser) wire
        grid, so its stats report near-zero error — fed to the grads
        controller they would starve it, ratchet the compute FL down, and
        coarsen the backward-tap grid until training destabilizes
        (observed on LeNet/MNIST-tiny).  The grads domain therefore
        consumes this stats-only measurement of the compute grid against
        the pre-wire gradients — the same quantization event the
        replicated path scores — while the gradient *values* flow through
        the wire untouched.
        """
        if not (qcfg.enabled and qcfg.policy.quantizes("grads")):
            return QuantStats.zero()
        _, st = quantize_grads(grads, fmts[grad_domain], qcfg,
                               jax.random.fold_in(k_g, rank))
        return st

    def _wire_synced_grads(qparams, batch, fmts, k_a, k_g, k_r,
                           deg_g=None, count=None):
        """Per-shard fwd/bwd + compressed gradient mean over ``data_axis``.

        Runs the whole gradient computation inside a full-manual
        ``shard_map``: each data shard sees its slice of the batch,
        computes local gradients, and the tree-wide
        ``dps_allreduce_mean`` replaces the implicit psum.  Scalars
        (loss, acc) come back pmean'ed and QuantStats psum'ed, so the
        caller sees the same global quantities as the GSPMD path.

        With ``wire_overlap`` the monolithic tree collective becomes the
        bucketed schedule (repro.dist.overlap): readiness taps on the
        params mark each bucket's gradients as the backward materializes
        them, and one compressed collective pair runs per bucket in that
        order — bit-exact vs the monolithic path under nearest rounding,
        identical dispatch-leg stats under both modes.

        Guards armed: ``deg_g`` (replicated i32 from last step's
        GuardState) selects between the int8 wire and a per-leaf fp32
        ``pmean`` fallback through ``lax.cond`` — the predicate is
        replicated, so every rank takes the same branch and the
        collectives inside stay congruent — and the body additionally
        returns the psum'ed nonfinite count of the RAW local gradients
        (the wire codec clips NaN silently, so detection must precede
        the encode).
        """
        def body(qparams, batch, fmts, k_a, k_g, k_r, *extra):
            deg_g = count = None
            if sig:
                deg_g, count = extra
            rank = jax.lax.axis_index(data_axis)
            tap = bplan = None
            if wire_overlap:
                bplan = overlap_lib.plan_buckets(
                    tuple(l.size
                          for l in jax.tree_util.tree_leaves(qparams)),
                    bucket_elems)
                tap = lambda p: overlap_lib.tap_params(p, bplan)
            (loss, aux), grads = _accum_grads(
                qparams, batch, fmts, jax.random.fold_in(k_a, rank), tap)
            if faults is not None:
                grads = rsl.apply_grad_faults(faults, grads, count)
            if wire_groups:
                n_leaves = len(jax.tree_util.tree_leaves(grads))
                if n_leaves != wire_groups:
                    raise ValueError(
                        f"wire_grads_groups={wire_groups} but the gradient "
                        f"tree has {n_leaves} leaves; per-layer wire formats "
                        "need one group per leaf (derive the config with "
                        "QuantConfig.with_per_layer_wire(params))")
            g_raw = _raw_grad_stats(grads, fmts, k_g, rank)
            bad = (jax.lax.psum(rsl.nonfinite_count(grads), data_axis)
                   if guards_on else None)

            def wire_leg(grads):
                if wire_overlap:
                    return overlap_lib.bucketed_allreduce_mean_tree(
                        grads, fmts, data_axis, k_r, mode=rounding,
                        domain="wire_grads", plan=bplan)
                if faults is not None:
                    return collectives.dps_allreduce_mean_tree(
                        grads, fmts, data_axis, k_r, mode=rounding,
                        domain="wire_grads",
                        payload_fault=rsl.payload_fault_fn(faults, count))
                return collectives.dps_allreduce_mean_tree(
                    grads, fmts, data_axis, k_r, mode=rounding,
                    domain="wire_grads")

            if guards_on:
                def f32_leg(grads):
                    # graceful degradation: exact per-leaf mean, zero wire
                    # stats (the guard must never feed from post-fallback
                    # values — see resilience.guards)
                    g = jax.tree.map(lambda x: jax.lax.pmean(x, data_axis),
                                     grads)
                    return g, QuantStats.zero(fmts["wire_grads"].il.shape)
                grads, wstats = jax.lax.cond(deg_g > 0, f32_leg, wire_leg,
                                             grads)
            else:
                grads, wstats = wire_leg(grads)
            wstats = collectives.psum_stats(wstats, data_axis)
            g_raw = collectives.psum_stats(g_raw, data_axis)
            loss = jax.lax.pmean(loss, data_axis)
            aux = {k: (collectives.psum_stats(v, data_axis)
                       if isinstance(v, QuantStats)
                       else jax.lax.pmean(v, data_axis))
                   for k, v in aux.items()}
            out = ((loss, aux), grads, wstats, g_raw)
            return out + (bad,) if guards_on else out

        n_in = 8 if sig else 6
        n_out = 5 if guards_on else 4
        fn = jax.shard_map(
            body, mesh=mesh,
            in_specs=(P(), P(data_axis)) + (P(),) * (n_in - 2),
            out_specs=(P(),) * n_out, check_vma=False)
        args = (qparams, batch, fmts, k_a, k_g, k_r)
        if sig:
            args += (deg_g, count)
        return fn(*args)

    def _zero_wire_step(part, full_quant, qparams, pflat, opt_state, batch,
                        fmts, count, k_a, k_g, k_r, deg_g=None, deg_p=None):
        """Fused ZeRO-1 step body: per-shard fwd/bwd, int8 reduce-scatter of
        the flat gradients, shard-local optimizer, all-gather of the
        updated parameter shards.

        ``full_quant`` (static) says every param leaf passes the policy's
        ``param_predicate``: the flat layout erases leaf identity, so the
        params all-gather rides the int8 wire — and the optimizer-input
        gradient quantization applies to the flat slice — only when no
        leaf is policy-excluded and no fp master copy is promised;
        otherwise the params leg gathers fp32 (gradient wire compression
        still applies to every leaf, exactly like ``dps_allreduce_mean``).

        Returns ``((loss, aux), new_flat_params, new_opt_state, g_wire,
        p_wire, g_stats)`` where ``g_wire``/``p_wire`` are the psum'ed
        QuantStats of the two wire legs (gradients / parameters) and
        ``g_stats`` the compute-grid gradient stats measured on the raw
        local gradients (see ``_raw_grad_stats``).

        Guards armed: ``deg_g``/``deg_p`` select — per wire domain,
        through ``lax.cond`` on the replicated flags — the fp32 fallback
        for the matching leg: an exact ``psum_scatter``/n of the flat
        gradients (same rank-major chunk order as ``part.shard``) and
        the fp32 tiled all-gather; the body additionally returns the
        psum'ed raw-gradient nonfinite count and the global squared norm
        of the decoded gradient shards (the spike detector's input).
        """
        def body(qparams, pflat, opt_local, batch, fmts, count, k_a, k_g,
                 k_r, *extra):
            deg_g = deg_p = None
            if sig:
                deg_g, deg_p = extra
            rank = jax.lax.axis_index(data_axis)
            k1, k2 = jax.random.split(k_r)
            (loss, aux), grads = _accum_grads(
                qparams, batch, fmts, jax.random.fold_in(k_a, rank))
            if faults is not None:
                grads = rsl.apply_grad_faults(faults, grads, count)
            g_stats = _raw_grad_stats(grads, fmts, k_g, rank)
            bad = (jax.lax.psum(rsl.nonfinite_count(grads), data_axis)
                   if guards_on else None)
            gflat = part.flatten(grads)

            def wire_rs(gflat):
                return collectives.dps_reduce_scatter_mean(
                    gflat, fmts, data_axis, k1, mode=rounding,
                    domain="wire_grads")

            if guards_on:
                def f32_rs(gflat):
                    sc = jax.lax.psum_scatter(gflat, data_axis,
                                              scatter_dimension=0,
                                              tiled=True)
                    return (sc / n_data,
                            QuantStats.zero(fmts["wire_grads"].il.shape))
                gshard, g_wire = jax.lax.cond(deg_g > 0, f32_rs, wire_rs,
                                              gflat)
            else:
                gshard, g_wire = wire_rs(gflat)
            if full_quant and qcfg.enabled and qcfg.policy.quantizes("grads"):
                # optimizer-input gradient quantization (Alg. 1), on this
                # rank's slice with the step's own rounding mode (matching
                # the replicated quantize_grads); stats-wise the event is
                # degenerate — the shard already sits on the wire grid —
                # so the controller stream is g_stats above, not this.
                gshard, _ = fxp.quantize(
                    gshard, fmts[grad_domain], mode=qcfg.rounding,
                    key=jax.random.fold_in(k_g, 0x524157 + rank))
            g2 = (jax.lax.psum(jnp.sum(jnp.square(
                gshard.astype(jnp.float32))), data_axis)
                if guards_on else None)
            pshard = part.shard(pflat, rank)
            upd, new_opt = optimizer.update_shard(gshard, opt_local, pshard,
                                                  count, axis_name=data_axis)
            if full_quant:
                def wire_ag(x):
                    return collectives.dps_allgather_params(
                        x, fmts, data_axis, k2, mode=rounding,
                        domain="wire_params")
                if guards_on:
                    def f32_ag(x):
                        return (jax.lax.all_gather(x, data_axis, axis=0,
                                                   tiled=True),
                                QuantStats.zero(
                                    fmts["wire_params"].il.shape))
                    new_flat, p_wire = jax.lax.cond(deg_p > 0, f32_ag,
                                                    wire_ag, pshard + upd)
                else:
                    new_flat, p_wire = wire_ag(pshard + upd)
            else:
                new_flat = jax.lax.all_gather(pshard + upd, data_axis,
                                              axis=0, tiled=True)
                p_wire = QuantStats.zero()
            g_wire = collectives.psum_stats(g_wire, data_axis)
            p_wire = collectives.psum_stats(p_wire, data_axis)
            g_stats = collectives.psum_stats(g_stats, data_axis)
            loss = jax.lax.pmean(loss, data_axis)
            aux = {k: (collectives.psum_stats(v, data_axis)
                       if isinstance(v, QuantStats)
                       else jax.lax.pmean(v, data_axis))
                   for k, v in aux.items()}
            out = ((loss, aux), new_flat, new_opt, g_wire, p_wire, g_stats)
            return out + (bad, g2) if guards_on else out

        n_in = 11 if sig else 9
        base_out = ((P(), P()), P(), P(data_axis), P(), P(), P())
        fn = jax.shard_map(
            body, mesh=mesh,
            in_specs=(P(), P(), P(data_axis), P(data_axis), P(), P(), P(),
                      P(), P()) + (P(),) * (n_in - 9),
            out_specs=base_out + ((P(), P()) if guards_on else ()),
            check_vma=False)
        args = (qparams, pflat, opt_state, batch, fmts, count, k_a, k_g,
                k_r)
        if sig:
            args += (deg_g, deg_p)
        return fn(*args)

    def _zero_aligned_wire_step(part, full_quant, qparams, pflat, opt_state,
                                batch, fmts, count, k_a, k_g, k_r,
                                deg_g=None, deg_p=None):
        """Group-aligned fused ZeRO-1 step: per-shard fwd/bwd, grouped int8
        reduce-scatter per bucket (backward-ready order when the overlap
        engages), shard-local optimizer over aligned slices, grouped int8
        (or fp32) all-gather of the updated parameter shards.

        The sharded twin of ``_zero_wire_step`` for the
        GroupAlignedPartitioner layout: per-leaf ⟨IL, FL⟩ from the [G]
        ``wire_grads``/``wire_params`` tables ride both legs, and with
        ``wire_overlap`` the gradients carry readiness taps so each
        bucket's reduce-scatter dispatches as the backward materializes
        it.  Same return contract as ``_zero_wire_step``, including the
        guard extensions (``deg_g``/``deg_p`` fallback conds, raw
        nonfinite count, sharded grad-norm signal).
        """
        def body(qparams, pflat, opt_local, batch, fmts, count, k_a, k_g,
                 k_r, *extra):
            deg_g = deg_p = None
            if sig:
                deg_g, deg_p = extra
            rank = jax.lax.axis_index(data_axis)
            tap = None
            if wire_overlap:
                bplan = overlap_lib.plan_buckets(
                    tuple(l.size
                          for l in jax.tree_util.tree_leaves(qparams)),
                    bucket_elems)
                tap = lambda p: overlap_lib.tap_params(p, bplan)
            (loss, aux), grads = _accum_grads(
                qparams, batch, fmts, jax.random.fold_in(k_a, rank), tap)
            if faults is not None:
                grads = rsl.apply_grad_faults(faults, grads, count)
            if wire_groups:
                n_leaves = len(jax.tree_util.tree_leaves(grads))
                if n_leaves != wire_groups:
                    raise ValueError(
                        f"wire_grads_groups={wire_groups} but the gradient "
                        f"tree has {n_leaves} leaves; per-layer wire formats "
                        "need one group per leaf (derive the config with "
                        "QuantConfig.with_per_layer_wire(params))")
            g_stats = _raw_grad_stats(grads, fmts, k_g, rank)
            bad = (jax.lax.psum(rsl.nonfinite_count(grads), data_axis)
                   if guards_on else None)

            # k_r goes to BOTH legs verbatim — the same key the replicated
            # tree collective consumes, so every leg-1 draw (split(fold_in(
            # k_r, idx))) and leg-2 draw (fold_in(k_r, LEG2)) matches the
            # replicated per-layer step bit for bit; the params leg derives
            # its own disjoint stream (fold_in(k_r, WPLG)) internally.
            def wire_rs(grads):
                return overlap_lib.zero_bucketed_reduce_scatter(
                    grads, fmts, data_axis, k_r, part=part, mode=rounding,
                    domain="wire_grads", tag_buckets=wire_overlap)

            if guards_on:
                def f32_rs(grads):
                    # exact fallback over the same aligned flat layout:
                    # psum_scatter's rank-major chunks match part.shard
                    sc = jax.lax.psum_scatter(part.flatten(grads),
                                              data_axis,
                                              scatter_dimension=0,
                                              tiled=True)
                    return (sc / n_data,
                            QuantStats.zero(fmts["wire_grads"].il.shape))
                gshard, g_wire = jax.lax.cond(deg_g > 0, f32_rs, wire_rs,
                                              grads)
            else:
                gshard, g_wire = wire_rs(grads)
            if full_quant and qcfg.enabled and qcfg.policy.quantizes("grads"):
                # optimizer-input gradient quantization on this rank's
                # slice (same contract as _zero_wire_step)
                gshard, _ = fxp.quantize(
                    gshard, fmts[grad_domain], mode=qcfg.rounding,
                    key=jax.random.fold_in(k_g, 0x524157 + rank))
            g2 = (jax.lax.psum(jnp.sum(jnp.square(
                gshard.astype(jnp.float32))), data_axis)
                if guards_on else None)
            pshard = part.shard(pflat, rank)
            upd, new_opt = optimizer.update_shard(gshard, opt_local, pshard,
                                                  count, axis_name=data_axis)

            def f32_gather(x):
                # fp32 return leg; the aligned layout is bucket-major, so
                # the rank-major gather goes through part.assemble
                gathered = jax.lax.all_gather(x, data_axis, axis=0,
                                              tiled=False)
                return (part.assemble(gathered),
                        QuantStats.zero(fmts["wire_params"].il.shape))

            if full_quant:
                def wire_ag(x):
                    return overlap_lib.zero_allgather_params(
                        x, fmts, data_axis, k_r, part=part,
                        mode=rounding, domain="wire_params")
                if guards_on:
                    new_flat, p_wire = jax.lax.cond(deg_p > 0, f32_gather,
                                                    wire_ag, pshard + upd)
                else:
                    new_flat, p_wire = wire_ag(pshard + upd)
            else:
                new_flat, p_wire = f32_gather(pshard + upd)
            g_wire = collectives.psum_stats(g_wire, data_axis)
            p_wire = collectives.psum_stats(p_wire, data_axis)
            g_stats = collectives.psum_stats(g_stats, data_axis)
            loss = jax.lax.pmean(loss, data_axis)
            aux = {k: (collectives.psum_stats(v, data_axis)
                       if isinstance(v, QuantStats)
                       else jax.lax.pmean(v, data_axis))
                   for k, v in aux.items()}
            out = ((loss, aux), new_flat, new_opt, g_wire, p_wire, g_stats)
            return out + (bad, g2) if guards_on else out

        n_in = 11 if sig else 9
        base_out = ((P(), P()), P(), P(data_axis), P(), P(), P())
        fn = jax.shard_map(
            body, mesh=mesh,
            in_specs=(P(), P(), P(data_axis), P(data_axis), P(), P(), P(),
                      P(), P()) + (P(),) * (n_in - 9),
            out_specs=base_out + ((P(), P()) if guards_on else ()),
            check_vma=False)
        args = (qparams, pflat, opt_state, batch, fmts, count, k_a, k_g,
                k_r)
        if sig:
            args += (deg_g, deg_p)
        return fn(*args)

    def _zero_plain_opt(part, gflat, pflat, opt_state, count):
        """ZeRO-1 optimizer leg without wire compression: slice the (already
        averaged, replicated) flat gradients, step the local shard, and
        all-gather the updated parameter shards in fp32.  Every leg is an
        exact copy, so the reassembled parameters are bit-identical to the
        replicated optimizer step whenever the shard-local optimizer math
        is (see ``make_train_step``'s ZeRO note on FMA contraction)."""
        def body(gflat, pflat, opt_local, count):
            rank = jax.lax.axis_index(data_axis)
            upd, new_opt = optimizer.update_shard(
                part.shard(gflat, rank), opt_local, part.shard(pflat, rank),
                count, axis_name=data_axis)
            new_flat = jax.lax.all_gather(part.shard(pflat, rank) + upd,
                                          data_axis, axis=0, tiled=True)
            return new_flat, new_opt

        fn = jax.shard_map(body, mesh=mesh,
                           in_specs=(P(), P(), P(data_axis), P()),
                           out_specs=(P(), P(data_axis)), check_vma=False)
        return fn(gflat, pflat, opt_state, count)

    def train_step(state: TrainState, batch):
        key = jax.random.fold_in(state.rng, state.step)
        k_w, k_g, k_a = jax.random.split(key, 3)
        fmts = bundle_formats(qcfg, state.dps)

        # -- forward/backward in the quantized regime (Alg. 1 lines 9-20) --
        qparams, w_stats = quantize_params(state.params, fmts["weights"], qcfg, k_w)
        g_wire = p_wire = wire_stats = None
        bad_count = gnorm = None
        deg_g = deg_p = jnp.zeros((), jnp.int32)
        if guards_on:
            if state.guard is None:
                raise ValueError(
                    "qcfg.guards is armed but TrainState.guard is None; "
                    "build the state with TrainState.create(..., qcfg, ...) "
                    "or restore with qtrain.guard_restore_defaults")
            # LAST step's degradation flags drive THIS step's collective
            # branch — a traced input, so fallback and wire live in the
            # same compiled step (no recompile at the trip boundary).
            if wire_names:
                deg_g = state.guard.degraded[gidx]
                if "wire_params" in wire_names:
                    deg_p = state.guard.degraded[pidx]
        if zero_opt:
            # ZeRO-1: the optimizer steps flat P(data)-sharded slices of the
            # flat layout (plain or group-aligned, see zero_partitioner),
            # then the updated parameter shards are gathered back into the
            # (replicated) tree.
            part = zero_partitioner(qcfg, state.params, n_data)
            pflat = part.flatten(state.params)
            if wire_sync:
                # the flat wire legs can't honor per-leaf carve-outs: only
                # engage them on the params/optimizer side when the policy
                # would quantize every leaf anyway and no fp master copy
                # is promised (static decision, uniform across steps).
                full_quant = wire_params_engaged(qcfg, state.params, mesh,
                                                 data_axis)
                if not full_quant:
                    warnings.warn(
                        "zero_opt_shards + grad_allreduce_bits: the policy "
                        "excludes some param leaves (or master_weights is "
                        "set), and the flat ZeRO layout cannot skip them "
                        "per-leaf — gathering updated params in fp32 and "
                        "skipping the flat optimizer-input gradient "
                        "quantization (the gradient wire stays int8).")
                k_r = jax.random.fold_in(key, 0x57495245)  # "WIRE"
                step_fn = (_zero_aligned_wire_step if zero_aligned
                           else _zero_wire_step)
                res = step_fn(part, full_quant, qparams, pflat,
                              state.opt_state, batch, fmts, state.step,
                              k_a, k_g, k_r,
                              *((deg_g, deg_p) if sig else ()))
                (loss, aux), new_flat, opt_state, g_wire, p_wire, g_stats \
                    = res[:6]
                if guards_on:
                    bad_count, g2 = res[6:]
                    gnorm = jnp.sqrt(g2)
                wire_stats = g_wire.merge(p_wire)
            else:
                # exact legs: grads from the ordinary (implicit-psum)
                # backward pass, slice + step + fp32 gather — bit-exact
                # with the replicated optimizer step.
                (loss, aux), grads = _accum_grads(qparams, batch, fmts, k_a)
                if faults is not None:
                    grads = rsl.apply_grad_faults(faults, grads, state.step)
                if guards_on:
                    bad_count = rsl.nonfinite_count(grads)
                    gnorm = rsl.global_norm(grads)
                grads, g_stats = quantize_grads(grads, fmts[grad_domain],
                                                qcfg, k_g)
                new_flat, opt_state = _zero_plain_opt(
                    part, part.flatten(grads), pflat, state.opt_state,
                    state.step)
            new_params = part.unflatten(new_flat)
        else:
            if wire_sync:
                # the wire path derives its own RNG stream instead of
                # widening the step's key split, so the default path stays
                # bit-identical to a step built without a mesh.
                k_r = jax.random.fold_in(key, 0x57495245)  # "WIRE"
                res = _wire_synced_grads(
                    qparams, batch, fmts, k_a, k_g, k_r,
                    *((deg_g, state.step) if sig else ()))
                if guards_on:
                    (loss, aux), grads, wire_stats, g_raw, bad_count = res
                    # spike detection reads the DECODED mean — transport
                    # corruption (a flipped payload) only exists there
                    gnorm = rsl.global_norm(grads)
                else:
                    (loss, aux), grads, wire_stats, g_raw = res
                # the optimizer-input snap still applies (Alg. 1), but the
                # controller stream is the raw-gradient measurement — the
                # mean already sits on the wire grid, so this event's own
                # stats are degenerate (see _raw_grad_stats).
                grads, _ = quantize_grads(grads, fmts[grad_domain], qcfg,
                                          k_g)
                g_stats = g_raw
            else:
                (loss, aux), grads = _accum_grads(qparams, batch, fmts, k_a)
                if faults is not None:
                    grads = rsl.apply_grad_faults(faults, grads, state.step)
                if guards_on:
                    bad_count = rsl.nonfinite_count(grads)
                    gnorm = rsl.global_norm(grads)
                grads, g_stats = quantize_grads(grads, fmts[grad_domain],
                                                qcfg, k_g)
            # -- update (Alg. 1 line 18) --
            updates, opt_state = optimizer.update(grads, state.opt_state,
                                                  state.params,
                                                  count=state.step)
            new_params = jax.tree.map(lambda p, u: p + u, state.params,
                                      updates)

        if "dlogits_stats" in aux and qcfg.stat_scope == "last_layer":
            g_stats = aux["dlogits_stats"]
        elif "dlogits_stats" in aux:
            g_stats = g_stats.merge(aux["dlogits_stats"])
        if qcfg.stat_scope == "last_layer" and "last_act_stats" in aux:
            a_stats = aux["last_act_stats"]
        else:
            a_stats = aux.get("act_stats", QuantStats.zero())

        # -- re-snap weights to the grid (Alg. 1 line 19) --
        if (qcfg.enabled and qcfg.policy.quantizes("weights")
                and not qcfg.master_weights):
            new_params, w_stats2 = quantize_params(
                new_params, fmts["weights"], qcfg, jax.random.fold_in(k_w, 1))
            w_stats = w_stats.merge(w_stats2)

        # -- scale_precision (Alg. 2, one controller per domain) --
        # Each wire leg feeds its own wire domain, never a compute
        # controller: a clipped wire element must move the *wire* radix,
        # not ratchet the compute IL (see module docstring).
        streams = {"weights": w_stats, "acts": a_stats, "grads": g_stats}
        if wire_stats is not None:
            if zero_opt:
                streams["wire_grads"] = g_wire
                streams["wire_params"] = p_wire
            else:
                streams["wire_grads"] = wire_stats
        new_dps = update_dps_bundle(qcfg, state.dps, streams, {"loss": loss})

        # -- health guards: fold this step's signals, gate the update --
        new_guard = state.guard
        if guards_on:
            wire_legs = {}
            if wire_stats is not None:
                wire_legs = ({"wire_grads": g_wire, "wire_params": p_wire}
                             if zero_opt else {"wire_grads": wire_stats})
            new_guard, g_ok, trip_any = rsl.update_guard(
                qcfg.guards, plan, state.guard, loss=loss,
                grads_bad=bad_count, gnorm=gnorm,
                wire_ov=rsl.guards.domain_overflow(plan, wire_legs),
                new_dps=new_dps, grads_domain_idx=gidx)
            # the skip gate: a poisoned step must not reach the params,
            # optimizer state, or controllers.  jnp.where is an exact
            # select, so with g_ok True (no fault) every value passes
            # through bit-identical — the guard-transparency contract.
            keep = lambda new, old: jax.tree.map(
                lambda a, b: jnp.where(g_ok, a, b), new, old)
            new_params = keep(new_params, state.params)
            opt_state = keep(opt_state, state.opt_state)
            new_dps = keep(new_dps, state.dps)
            if qcfg.guards.widen_on_trip:
                # reactive headroom: one extra IL bit on the compute
                # grads domain the step a trip fires (dps._clamp_fmt
                # keeps caps and the exactness span)
                new_dps = rsl.widen_on_trip(plan, new_dps, trip_any)

        # -- telemetry: ⟨IL, FL⟩ + E/R per domain (scalarized for [G];
        # grouped domains also report the per-group spread so per-layer
        # wire formats are visible in the train log) --
        short = {"weights": "w", "acts": "a", "grads": "g"}
        metrics = {"loss": loss}
        for name, spec in plan.domains:
            fmt, tag = fmts[name], short.get(name, name)
            scalar = (lambda x: x) if not spec.groups else jnp.mean
            metrics[f"il_{tag}"] = scalar(fmt.il)
            metrics[f"fl_{tag}"] = scalar(fmt.fl)
            if spec.groups:
                metrics[f"il_{tag}_min"] = jnp.min(fmt.il)
                metrics[f"il_{tag}_max"] = jnp.max(fmt.il)
                metrics[f"fl_{tag}_min"] = jnp.min(fmt.fl)
                metrics[f"fl_{tag}_max"] = jnp.max(fmt.fl)
            st = streams.get(spec.stream(name))
            if st is not None:
                metrics[f"E_{tag}"] = scalar(st.quant_error())
                metrics[f"R_{tag}"] = scalar(st.overflow_rate())
        if wire_stats is not None:
            ws = wire_stats
            if ws.count.ndim:          # [G] per-layer stats -> global view
                ws = QuantStats(*(jnp.sum(f) for f in
                                  (ws.count, ws.nonzero, ws.overflow,
                                   ws.abs_err_sum, ws.rel_err_sum,
                                   ws.abs_sum)),
                                max_abs=jnp.max(ws.max_abs))
            metrics["E_wire"] = ws.quant_error()
            metrics["R_wire"] = ws.overflow_rate()
        if guards_on:
            # the health word + counters ride the ordinary metrics dict,
            # so they drain at the driver's log points with everything
            # else — no extra host sync (the PR 7 deferred-fetch pattern)
            metrics["health"] = new_guard.health
            metrics["skipped"] = new_guard.skipped
            metrics["trips"] = new_guard.trips
            metrics["degraded"] = (jnp.max(new_guard.degraded)
                                   if wire_names
                                   else jnp.zeros((), jnp.int32))
        new_state = TrainState(
            step=state.step + 1, params=new_params, opt_state=opt_state,
            dps=new_dps, rng=state.rng, last_loss=loss.astype(jnp.float32),
            guard=new_guard)
        return new_state, metrics

    # introspection for drivers/tests: did the compressed paths engage?
    train_step.wire_sync_active = wire_sync
    train_step.zero_opt_active = zero_opt
    train_step.wire_overlap_active = wire_overlap
    train_step.zero_groupaligned_active = zero_aligned
    train_step.guards_active = guards_on
    return train_step
