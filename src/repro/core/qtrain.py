"""Quantized-training plumbing: taps, per-attribute DPS bundles, train-state.

Wires the paper's Algorithm 1 into an arbitrary JAX model:

  forward pass   — activations pass through :func:`act_tap` (quantize + stats
                   on the way down, gradient quantization on the way back up
                   via ``custom_vjp``),
  backward pass  — parameter gradients are quantized before the optimizer;
                   the loss's own logit-gradient (the paper's "last layer
                   gradients") is quantized with stats,
  weight update  — updated weights are re-snapped to the weight grid
                   (stochastic rounding makes tiny updates survive in
                   expectation, the property Gupta et al. identified),
  scale_precision — one controller per attribute consumes the step's merged
                   stats and emits the next step's ⟨IL, FL⟩.

Everything here is shape-polymorphic and mesh-agnostic: stats are plain
``jnp`` reductions, so under ``pjit`` they come out globally reduced, and the
⟨IL, FL⟩ state is replicated.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import dps as dps_lib
from repro.core import fixed_point as fxp
from repro.core.fixed_point import FixedPointFormat, QuantStats
from repro.core.policy import QuantPolicy

ATTRS = ("weights", "acts", "grads")


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """Static configuration of the quantized-training scheme."""

    enabled: bool = True
    controller: str = "paper"
    rounding: str = fxp.ROUND_STOCHASTIC
    policy: QuantPolicy = QuantPolicy()
    # one hyper per attribute; the paper runs one Alg.-2 instance each for
    # weights, activations and gradients (global granularity).
    hyper_weights: dps_lib.DPSHyper = dps_lib.DPSHyper()
    hyper_acts: dps_lib.DPSHyper = dps_lib.DPSHyper()
    hyper_grads: dps_lib.DPSHyper = dps_lib.DPSHyper(il_init=8, fl_init=16)
    stat_scope: str = "global"          # "global" | "last_layer"
    master_weights: bool = False        # keep an fp copy (beyond-paper)
    # Opt-in compressed gradient synchronization: when set (8 to start),
    # parameter gradients are averaged across the data axis by an explicit
    # shard_map'ed int8-wire ``dps_allreduce_mean`` instead of GSPMD's
    # implicit fp32 psum, and the wire-leg QuantStats merge into the grads
    # DPS stats — so wire quantization error steers ⟨IL, FL⟩.  Needs
    # ``make_train_step(..., mesh=...)``; degrades to the identity on
    # single-device meshes.
    grad_allreduce_bits: Optional[int] = None
    # ZeRO-1: shard the optimizer state across the data axis into this many
    # slices (must equal the mesh's data-axis size when it engages).  The
    # param tree is flattened into the padded 1-D ZeroPartitioner layout so
    # non-divisible leaves still shard; each rank steps its slice locally
    # and the updated parameter shards are all-gathered back.  Combined
    # with ``grad_allreduce_bits``, both collective legs (reduce-scatter of
    # grads, all-gather of params) ride the int8 wire.  Optimizer state is
    # created with :func:`zero_opt_state` instead of ``optimizer.init``.
    # Engages on pure data-parallel meshes only (same JAX partial-manual
    # shard_map constraint as the compressed all-reduce); degrades to the
    # replicated step on a single device or without a mesh.
    zero_opt_shards: Optional[int] = None

    def controllers(self):
        mk = dps_lib.make_controller
        return {
            "weights": mk(self.controller, self.hyper_weights),
            "acts": mk(self.controller, self.hyper_acts),
            "grads": mk(self.controller, self.hyper_grads),
        }


def init_dps_bundle(qcfg: QuantConfig) -> Dict[str, Any]:
    """Initial DPS controller states, one per attribute."""
    return {k: c.init() for k, c in qcfg.controllers().items()}


def bundle_formats(qcfg: QuantConfig, bundle) -> Dict[str, FixedPointFormat]:
    ctrls = qcfg.controllers()
    return {k: ctrls[k].fmt(bundle[k]) for k in ATTRS}


def update_dps_bundle(qcfg: QuantConfig, bundle, stats: Dict[str, QuantStats],
                      aux=None) -> Dict[str, Any]:
    ctrls = qcfg.controllers()
    return {k: ctrls[k].update(bundle[k], stats[k], aux) for k in ATTRS}


# ---------------------------------------------------------------------------
# Activation tap: quantize forward, quantize the cotangent backward.
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class QCtx:
    """Per-step quantization context handed to model code.

    ``None`` (the default ``QCtx.off()``-less path) disables taps entirely —
    model code guards with ``if qctx is not None``.
    """

    acts_fmt: FixedPointFormat
    grads_fmt: FixedPointFormat
    key: jax.Array
    rounding: str = dataclasses.field(metadata=dict(static=True))
    collect_stats: bool = dataclasses.field(metadata=dict(static=True))

    def tap(self, x: jax.Array, salt):
        """Quantize activation ``x``; returns ``(q, QuantStats)``.

        ``salt`` decorrelates rounding noise across call sites; inside a
        scanned stack pass the per-layer key/index.
        """
        kf = jax.random.fold_in(self.key, _salt_to_int(salt))
        kb = jax.random.fold_in(kf, 0x9E3779B9)
        q, stats = _qtap(self.rounding, x, self.acts_fmt, self.grads_fmt, kf, kb)
        if not self.collect_stats:
            stats = None
        return q, stats


def _salt_to_int(salt) -> jax.Array:
    if isinstance(salt, str):
        import zlib
        return jnp.uint32(zlib.crc32(salt.encode()))  # stable across processes
    return jnp.asarray(salt, jnp.uint32)


from functools import partial


@partial(jax.custom_vjp, nondiff_argnums=(0,))
def _qtap(mode, x, a_fmt, g_fmt, kf, kb):
    q, stats = fxp.quantize(x, a_fmt, mode=mode, key=kf, compute_stats=True)
    return q, stats


def _qtap_fwd(mode, x, a_fmt, g_fmt, kf, kb):
    out = _qtap(mode, x, a_fmt, g_fmt, kf, kb)
    return out, (g_fmt, kb)


def _qtap_bwd(mode, res, cot):
    g_fmt, kb = res
    gq, _ = fxp.quantize(cot[0], g_fmt, mode=mode, key=kb, compute_stats=False)
    return (gq, None, None, None, None)


_qtap.defvjp(_qtap_fwd, _qtap_bwd)


# ---------------------------------------------------------------------------
# Weight / gradient tree quantization.
# ---------------------------------------------------------------------------

def quantize_params(params, fmt: FixedPointFormat, qcfg: QuantConfig, key):
    """Snap the parameter tree to the weight grid. Returns (qparams, stats)."""
    if not qcfg.enabled or not qcfg.policy.quantize_weights:
        return params, QuantStats.zero()
    return fxp.quantize_tree(params, fmt, mode=qcfg.rounding, key=key,
                             predicate=qcfg.policy.param_predicate())


def quantize_grads(grads, fmt: FixedPointFormat, qcfg: QuantConfig, key):
    """Quantize parameter gradients before the optimizer step."""
    if not qcfg.enabled or not qcfg.policy.quantize_grads:
        return grads, QuantStats.zero()
    return fxp.quantize_tree(grads, fmt, mode=qcfg.rounding, key=key,
                             predicate=qcfg.policy.param_predicate())


# ---------------------------------------------------------------------------
# Train state + generic quantized train step.
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class TrainState:
    step: jax.Array
    params: Any
    opt_state: Any
    dps: Any                 # {attr: controller state}
    rng: jax.Array
    # rolling telemetry (replicated scalars) for logging/benchmarks:
    last_loss: jax.Array

    @staticmethod
    def create(params, opt_state, qcfg: QuantConfig, rng) -> "TrainState":
        return TrainState(
            step=jnp.zeros((), jnp.int32),
            params=params,
            opt_state=opt_state,
            dps=init_dps_bundle(qcfg),
            rng=rng,
            last_loss=jnp.zeros((), jnp.float32),
        )


def _mesh_axis_sizes(mesh) -> Dict[str, int]:
    return (dict(zip(mesh.axis_names, mesh.devices.shape))
            if mesh is not None else {})


def zero_opt_engaged(qcfg: QuantConfig, mesh, data_axis: str = "data") -> bool:
    """Does the ZeRO-1 sharded-optimizer path engage for (qcfg, mesh)?

    Mirrors :func:`make_train_step`'s own checks so launch code and specs
    can size/shard the optimizer state consistently with the step that will
    actually run: requires ``zero_opt_shards`` set, a mesh whose
    ``data_axis`` is larger than 1, and a pure data-parallel mesh (every
    other axis of size 1 — the partial-manual shard_map constraint).
    """
    if qcfg.zero_opt_shards is None:
        return False
    sizes = _mesh_axis_sizes(mesh)
    if int(sizes.get(data_axis, 1)) <= 1:
        return False
    return not any(s > 1 for a, s in sizes.items() if a != data_axis)


def zero_opt_state(optimizer, params, n_shards: int):
    """ZeRO-1 optimizer state: one flat padded vector per state tensor.

    Returns ``optimizer.init_shard`` over the :class:`ZeroPartitioner`
    flat layout — a GLOBAL ``[padded_size]`` array per state leaf, meant to
    be placed with ``NamedSharding(mesh, P("data"))`` so each rank holds
    ``1/n_shards`` of it (see ``launch.specs.train_state_shardings``).
    """
    from repro.dist.sharding import ZeroPartitioner  # deferred: dist imports core
    part = ZeroPartitioner.create(params, n_shards)
    flat = jax.eval_shape(lambda t: part.flatten(t), params)
    return optimizer.init_shard(flat)


def make_train_step(loss_fn, optimizer, qcfg: QuantConfig,
                    accum_steps: int = 1, mesh=None, data_axis: str = "data"):
    """Build a quantized SGD/AdamW train step around ``loss_fn``.

    ``loss_fn(params, batch, qctx) -> (loss, aux)`` where ``aux`` is a dict
    that may contain ``"act_stats"`` (merged QuantStats from taps) and
    ``"dlogits_stats"`` (last-layer gradient stats, see models).  The
    returned step is pure: ``step(state, batch) -> (state, metrics)``.

    ``accum_steps > 1`` splits the global batch into microbatches scanned
    sequentially with fp32 gradient accumulation — the standard way to fit
    the large train cells in per-device HBM (activation memory scales with
    the microbatch, gradients are one extra params-sized buffer).

    ``qcfg.grad_allreduce_bits`` + ``mesh``: the forward/backward runs
    inside a ``shard_map`` over ``data_axis`` (params replicated, batch
    split) and parameter gradients are averaged by the int8-wire
    :func:`repro.dist.collectives.dps_allreduce_mean` — ~4× fewer gradient
    wire bytes than the implicit fp32 psum.  The wire format is derived
    from the grads controller's ⟨IL, FL⟩ (:func:`wire_format`), and the
    dispatch-leg QuantStats merge into the grads stats the DPS bundle
    update consumes.  The path engages only on pure data-parallel meshes
    (every non-``data_axis`` mesh axis of size 1): JAX 0.4's partial-manual
    ``shard_map`` (``auto=``) miscompiles the mixed GSPMD/manual case, so
    tensor-parallel meshes fall back to the implicit psum with a warning.
    On a single-device mesh (or ``mesh=None``) the path degrades to the
    identity all-reduce: the step is bit-identical to the uncompressed one.

    ``qcfg.zero_opt_shards`` + ``mesh``: ZeRO-1.  The optimizer state lives
    as flat ``P(data_axis)``-sharded slices of the ZeroPartitioner layout
    (1/n of the replicated bytes per device) and the optimizer steps one
    slice per rank inside the shard_map.  Without ``grad_allreduce_bits``
    the gradients come from the ordinary (implicit-psum) backward pass and
    the update legs are exact, so the step is **bit-exact** with the
    replicated one — fp32 state, ``clip_norm`` off (the cross-shard norm
    psum sums in a different order than the per-leaf norm), and optimizer
    scalars whose products are f32-exact (e.g. power-of-two
    lr/momentum/weight_decay; otherwise layout-dependent FMA contraction
    may drift the state by 1 ULP/step, see ``SGD._leaf``); with it, one
    fused shard_map body runs
    per-shard fwd/bwd → int8 ``dps_reduce_scatter_mean`` → local optimizer
    → int8 ``dps_allgather_params``, the grads-leg wire stats feed the
    grads controller and the params-leg wire stats feed the weights
    controller.  Same pure-data-parallel constraint and single-device
    degradation as above.
    """
    ctrls = qcfg.controllers()
    rounding = getattr(ctrls["weights"], "rounding", qcfg.rounding)

    wire_bits = qcfg.grad_allreduce_bits
    if wire_bits is not None and not 2 <= wire_bits <= 8:
        raise ValueError(f"grad_allreduce_bits={wire_bits}: the wire payload "
                         "is int8, so only 2..8 grid bits are supported")
    axis_sizes = _mesh_axis_sizes(mesh)
    n_data = int(axis_sizes.get(data_axis, 1))
    wire_sync = wire_bits is not None and n_data > 1
    if wire_sync and any(s > 1 for a, s in axis_sizes.items()
                         if a != data_axis):
        warnings.warn(
            "grad_allreduce_bits needs a pure data-parallel mesh (all "
            f"non-'{data_axis}' axes of size 1); got {axis_sizes}. Falling "
            "back to the implicit fp32 gradient all-reduce.")
        wire_sync = False

    zero_opt = qcfg.zero_opt_shards is not None and n_data > 1
    if zero_opt and not zero_opt_engaged(qcfg, mesh, data_axis):
        warnings.warn(
            "zero_opt_shards needs a pure data-parallel mesh (all "
            f"non-'{data_axis}' axes of size 1); got {axis_sizes}. Falling "
            "back to the replicated optimizer state.")
        zero_opt = False
    if zero_opt and qcfg.zero_opt_shards != n_data:
        raise ValueError(
            f"zero_opt_shards={qcfg.zero_opt_shards} must equal the mesh's "
            f"'{data_axis}' axis size ({n_data}): the optimizer state shards "
            "over that axis")
    if zero_opt and not hasattr(optimizer, "update_shard"):
        raise TypeError(f"{type(optimizer).__name__} has no shard-local "
                        "update_shard/init_shard interface; ZeRO-1 needs it")
    if wire_sync or zero_opt:
        from repro.dist import collectives  # deferred: dist imports core
    if zero_opt:
        from repro.dist.sharding import ZeroPartitioner

    def _grads(qparams, batch, fmts, k_a, microbatch_idx):
        qctx = None
        if qcfg.enabled and qcfg.policy.quantize_acts:
            qctx = QCtx(acts_fmt=fmts["acts"], grads_fmt=fmts["grads"],
                        key=jax.random.fold_in(k_a, microbatch_idx),
                        rounding=rounding, collect_stats=True)
        return jax.value_and_grad(loss_fn, has_aux=True)(qparams, batch, qctx)

    def _accum_grads(qparams, batch, fmts, k_a):
        if accum_steps == 1:
            return _grads(qparams, batch, fmts, k_a, 0)
        micro = jax.tree.map(
            lambda x: x.reshape((accum_steps, x.shape[0] // accum_steps)
                                + x.shape[1:]), batch)

        def body(carry, xs):
            loss_acc, g_acc, stats_acc, idx = carry
            (loss, aux), g = _grads(qparams, xs, fmts, k_a, idx)
            g_acc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32),
                                 g_acc, g)
            stats_acc = stats_acc.merge(aux.get("act_stats",
                                                QuantStats.zero()))
            return (loss_acc + loss, g_acc, stats_acc, idx + 1), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), qparams)
        (loss, g, stats, _), _ = jax.lax.scan(
            body, (jnp.zeros((), jnp.float32), g0, QuantStats.zero(),
                   jnp.zeros((), jnp.uint32)), micro,
            length=accum_steps)
        n = float(accum_steps)
        grads = jax.tree.map(lambda x, p: (x / n).astype(p.dtype), g, qparams)
        return (loss / n, {"act_stats": stats}), grads

    def _wire_synced_grads(qparams, batch, fmts, k_a, k_r):
        """Per-shard fwd/bwd + compressed gradient mean over ``data_axis``.

        Runs the whole gradient computation inside a full-manual
        ``shard_map``: each data shard sees its slice of the batch,
        computes local gradients, and the tree-wide
        ``dps_allreduce_mean`` replaces the implicit psum.  Scalars
        (loss, acc) come back pmean'ed and QuantStats psum'ed, so the
        caller sees the same global quantities as the GSPMD path.
        """
        def body(qparams, batch, fmts, k_a, k_r):
            rank = jax.lax.axis_index(data_axis)
            wfmt = collectives.wire_format(fmts["grads"], wire_bits)
            (loss, aux), grads = _accum_grads(
                qparams, batch, fmts, jax.random.fold_in(k_a, rank))
            grads, wstats = collectives.dps_allreduce_mean_tree(
                grads, wfmt, data_axis, k_r, mode=rounding)
            wstats = collectives.psum_stats(wstats, data_axis)
            loss = jax.lax.pmean(loss, data_axis)
            aux = {k: (collectives.psum_stats(v, data_axis)
                       if isinstance(v, QuantStats)
                       else jax.lax.pmean(v, data_axis))
                   for k, v in aux.items()}
            return (loss, aux), grads, wstats

        fn = jax.shard_map(body, mesh=mesh,
                           in_specs=(P(), P(data_axis), P(), P(), P()),
                           out_specs=(P(), P(), P()), check_vma=False)
        return fn(qparams, batch, fmts, k_a, k_r)

    def _zero_wire_step(part, full_quant, qparams, pflat, opt_state, batch,
                        fmts, count, k_a, k_g, k_r):
        """Fused ZeRO-1 step body: per-shard fwd/bwd, int8 reduce-scatter of
        the flat gradients, shard-local optimizer, all-gather of the
        updated parameter shards.

        ``full_quant`` (static) says every param leaf passes the policy's
        ``param_predicate``: the flat layout erases leaf identity, so the
        params all-gather rides the int8 wire — and the optimizer-input
        gradient quantization applies to the flat slice — only when no
        leaf is policy-excluded and no fp master copy is promised;
        otherwise the params leg gathers fp32 (gradient wire compression
        still applies to every leaf, exactly like ``dps_allreduce_mean``).

        Returns ``((loss, aux), new_flat_params, new_opt_state, g_wire,
        p_wire, g_stats)`` where ``g_wire``/``p_wire`` are the psum'ed
        QuantStats of the two wire legs (gradients / parameters) and
        ``g_stats`` the optimizer-input gradient quantization stats.
        """
        def body(qparams, pflat, opt_local, batch, fmts, count, k_a, k_g, k_r):
            rank = jax.lax.axis_index(data_axis)
            gfmt = collectives.wire_format(fmts["grads"], wire_bits)
            wfmt = collectives.wire_format(fmts["weights"], wire_bits)
            k1, k2 = jax.random.split(k_r)
            (loss, aux), grads = _accum_grads(
                qparams, batch, fmts, jax.random.fold_in(k_a, rank))
            gshard, g_wire = collectives.dps_reduce_scatter_mean(
                part.flatten(grads), gfmt, data_axis, k1, mode=rounding)
            if full_quant and qcfg.enabled and qcfg.policy.quantize_grads:
                # optimizer-input gradient quantization (Alg. 1), on this
                # rank's slice with the step's own rounding mode (matching
                # the replicated quantize_grads); the pad region quantizes
                # zeros exactly so the stats only gain pad counts, never
                # error.
                gshard, g_stats = fxp.quantize(
                    gshard, fmts["grads"], mode=qcfg.rounding,
                    key=jax.random.fold_in(k_g, rank))
            else:
                g_stats = QuantStats.zero()
            pshard = part.shard(pflat, rank)
            upd, new_opt = optimizer.update_shard(gshard, opt_local, pshard,
                                                  count, axis_name=data_axis)
            if full_quant:
                new_flat, p_wire = collectives.dps_allgather_params(
                    pshard + upd, wfmt, data_axis, k2, mode=rounding)
            else:
                new_flat = jax.lax.all_gather(pshard + upd, data_axis,
                                              axis=0, tiled=True)
                p_wire = QuantStats.zero()
            g_wire = collectives.psum_stats(g_wire, data_axis)
            p_wire = collectives.psum_stats(p_wire, data_axis)
            g_stats = collectives.psum_stats(g_stats, data_axis)
            loss = jax.lax.pmean(loss, data_axis)
            aux = {k: (collectives.psum_stats(v, data_axis)
                       if isinstance(v, QuantStats)
                       else jax.lax.pmean(v, data_axis))
                   for k, v in aux.items()}
            return (loss, aux), new_flat, new_opt, g_wire, p_wire, g_stats

        fn = jax.shard_map(
            body, mesh=mesh,
            in_specs=(P(), P(), P(data_axis), P(data_axis), P(), P(), P(),
                      P(), P()),
            out_specs=((P(), P()), P(), P(data_axis), P(), P(), P()),
            check_vma=False)
        return fn(qparams, pflat, opt_state, batch, fmts, count, k_a, k_g,
                  k_r)

    def _zero_plain_opt(part, gflat, pflat, opt_state, count):
        """ZeRO-1 optimizer leg without wire compression: slice the (already
        averaged, replicated) flat gradients, step the local shard, and
        all-gather the updated parameter shards in fp32.  Every leg is an
        exact copy, so the reassembled parameters are bit-identical to the
        replicated optimizer step whenever the shard-local optimizer math
        is (see ``make_train_step``'s ZeRO note on FMA contraction)."""
        def body(gflat, pflat, opt_local, count):
            rank = jax.lax.axis_index(data_axis)
            upd, new_opt = optimizer.update_shard(
                part.shard(gflat, rank), opt_local, part.shard(pflat, rank),
                count, axis_name=data_axis)
            new_flat = jax.lax.all_gather(part.shard(pflat, rank) + upd,
                                          data_axis, axis=0, tiled=True)
            return new_flat, new_opt

        fn = jax.shard_map(body, mesh=mesh,
                           in_specs=(P(), P(), P(data_axis), P()),
                           out_specs=(P(), P(data_axis)), check_vma=False)
        return fn(gflat, pflat, opt_state, count)

    def train_step(state: TrainState, batch):
        key = jax.random.fold_in(state.rng, state.step)
        k_w, k_g, k_a = jax.random.split(key, 3)
        fmts = bundle_formats(qcfg, state.dps)

        # -- forward/backward in the quantized regime (Alg. 1 lines 9-20) --
        qparams, w_stats = quantize_params(state.params, fmts["weights"], qcfg, k_w)
        g_wire = p_wire = wire_stats = None
        if zero_opt:
            # ZeRO-1: the optimizer steps flat P(data)-sharded slices of the
            # ZeroPartitioner layout, then the updated parameter shards are
            # gathered back into the (replicated) tree.
            part = ZeroPartitioner.create(state.params, n_data)
            pflat = part.flatten(state.params)
            if wire_sync:
                # the flat wire legs can't honor per-leaf carve-outs: only
                # engage them on the params/optimizer side when the policy
                # would quantize every leaf anyway and no fp master copy
                # is promised (static decision, uniform across steps).
                pred = qcfg.policy.param_predicate()
                full_quant = (not qcfg.master_weights and all(
                    pred(path, leaf) for path, leaf in
                    jax.tree_util.tree_flatten_with_path(state.params)[0]))
                if not full_quant:
                    warnings.warn(
                        "zero_opt_shards + grad_allreduce_bits: the policy "
                        "excludes some param leaves (or master_weights is "
                        "set), and the flat ZeRO layout cannot skip them "
                        "per-leaf — gathering updated params in fp32 and "
                        "skipping the flat optimizer-input gradient "
                        "quantization (the gradient wire stays int8).")
                k_r = jax.random.fold_in(key, 0x57495245)  # "WIRE"
                (loss, aux), new_flat, opt_state, g_wire, p_wire, g_stats = \
                    _zero_wire_step(part, full_quant, qparams, pflat,
                                    state.opt_state, batch, fmts, state.step,
                                    k_a, k_g, k_r)
                wire_stats = g_wire.merge(p_wire)
            else:
                # exact legs: grads from the ordinary (implicit-psum)
                # backward pass, slice + step + fp32 gather — bit-exact
                # with the replicated optimizer step.
                (loss, aux), grads = _accum_grads(qparams, batch, fmts, k_a)
                grads, g_stats = quantize_grads(grads, fmts["grads"], qcfg,
                                                k_g)
                new_flat, opt_state = _zero_plain_opt(
                    part, part.flatten(grads), pflat, state.opt_state,
                    state.step)
            new_params = part.unflatten(new_flat)
        else:
            if wire_sync:
                # the wire path derives its own RNG stream instead of
                # widening the step's key split, so the default path stays
                # bit-identical to a step built without a mesh.
                k_r = jax.random.fold_in(key, 0x57495245)  # "WIRE"
                (loss, aux), grads, wire_stats = _wire_synced_grads(
                    qparams, batch, fmts, k_a, k_r)
            else:
                (loss, aux), grads = _accum_grads(qparams, batch, fmts, k_a)
            grads, g_stats = quantize_grads(grads, fmts["grads"], qcfg, k_g)
            # -- update (Alg. 1 line 18) --
            updates, opt_state = optimizer.update(grads, state.opt_state,
                                                  state.params,
                                                  count=state.step)
            new_params = jax.tree.map(lambda p, u: p + u, state.params,
                                      updates)

        if "dlogits_stats" in aux and qcfg.stat_scope == "last_layer":
            g_stats = aux["dlogits_stats"]
        elif "dlogits_stats" in aux:
            g_stats = g_stats.merge(aux["dlogits_stats"])
        if wire_stats is not None:
            # wire error feeds the controllers: a too-coarse wire grid
            # raises E (-> FL up), wire clipping raises R (-> IL up).
            if zero_opt:
                # grads leg steers the grads controller; the params
                # all-gather leg quantizes *weights*, so it steers the
                # weights controller instead.
                g_stats = g_stats.merge(g_wire)
                w_stats = w_stats.merge(p_wire)
            else:
                g_stats = g_stats.merge(wire_stats)
        if qcfg.stat_scope == "last_layer" and "last_act_stats" in aux:
            a_stats = aux["last_act_stats"]
        else:
            a_stats = aux.get("act_stats", QuantStats.zero())

        # -- re-snap weights to the grid (Alg. 1 line 19) --
        if qcfg.enabled and qcfg.policy.quantize_weights and not qcfg.master_weights:
            new_params, w_stats2 = quantize_params(
                new_params, fmts["weights"], qcfg, jax.random.fold_in(k_w, 1))
            w_stats = w_stats.merge(w_stats2)

        # -- scale_precision (Alg. 2, one controller per attribute) --
        stats = {"weights": w_stats, "acts": a_stats, "grads": g_stats}
        new_dps = update_dps_bundle(qcfg, state.dps, stats, {"loss": loss})

        metrics = {
            "loss": loss,
            "il_w": fmts["weights"].il, "fl_w": fmts["weights"].fl,
            "il_a": fmts["acts"].il, "fl_a": fmts["acts"].fl,
            "il_g": fmts["grads"].il, "fl_g": fmts["grads"].fl,
            "E_w": w_stats.quant_error(), "R_w": w_stats.overflow_rate(),
            "E_a": a_stats.quant_error(), "R_a": a_stats.overflow_rate(),
            "E_g": g_stats.quant_error(), "R_g": g_stats.overflow_rate(),
        }
        if wire_stats is not None:
            metrics["E_wire"] = wire_stats.quant_error()
            metrics["R_wire"] = wire_stats.overflow_rate()
        new_state = TrainState(
            step=state.step + 1, params=new_params, opt_state=opt_state,
            dps=new_dps, rng=state.rng, last_loss=loss.astype(jnp.float32))
        return new_state, metrics

    # introspection for drivers/tests: did the compressed paths engage?
    train_step.wire_sync_active = wire_sync
    train_step.zero_opt_active = zero_opt
    return train_step
