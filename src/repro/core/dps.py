"""Dynamic precision scaling controllers.

The paper's contribution (Algorithm 2) plus the baselines it compares
against (Table 1).  Every controller is a pure, jit-safe state machine:

    state  = controller.init()                      # pytree (checkpointable)
    state  = controller.update(state, stats, aux)   # once per train step
    fmt    = controller.fmt(state)                  # FixedPointFormat to use

``stats`` is the merged :class:`~repro.core.fixed_point.QuantStats` of the
**precision domain** this controller governs, and ``aux`` carries scalar
training signals (currently the loss, for the convergence-based Na &
Mukhopadhyay baseline).  Domains are declared by a :class:`PrecisionPlan`
(domain name -> :class:`DomainSpec`) which builds the named
:class:`DpsBundle` registry the train step threads through time: the
paper's three compute attributes (``weights`` / ``acts`` / ``grads``) plus
dedicated **wire domains** (``wire_grads`` / ``wire_params``) that own the
int8 collective legs' formats — see :mod:`repro.core.qtrain`.

All updates are branchless ``lax``/``jnp`` arithmetic on traced int32 state,
so precision changes never recompile the train step.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import tagging
from repro.core.fixed_point import FixedPointFormat, QuantStats

# fp32-mantissa exactness bound for the emulation grid: IL - 1 + FL <= 24.
_EXACT_SPAN = 24


@dataclasses.dataclass(frozen=True)
class DPSHyper:
    """Static controller hyper-parameters (hashable; part of jit closure).

    Defaults follow the paper's evaluation (§4): thresholds
    ``E_max = R_max = 0.01% = 1e-4``, updated once per iteration.
    """

    r_max: float = 1e-4
    e_max: float = 1e-4
    il_min: int = 2
    il_max: int = 16
    fl_min: int = 0
    fl_max: int = 23
    il_init: int = 8
    fl_init: int = 12
    step: int = 1                      # unit bit step `s`
    total_bits: int = 16               # fixed-width schemes (Courbariaux/FlexPoint)
    max_total: int = 32                # dynamic-width cap (IL+FL)
    error_metric: str = "relative_mean"
    # Na & Mukhopadhyay convergence baseline:
    na_ml: int = 24                    # maximum bit-width `ml`
    na_tl_init: int = 8                # initial target bit-width `tl`
    na_window: int = 100               # loss-stagnation window (EMA horizon)
    na_eps: float = 1e-3               # relative improvement threshold
    # FlexPoint-like predictive scheme:
    flex_decay: float = 0.9
    flex_slack: float = 1.0            # extra headroom bits on predicted max
    # measured-slack mode (wire domains): place the radix at the r_max
    # tail quantile of the measured magnitude distribution instead of a
    # hand-tuned 2^flex_slack over the max — see FlexpointController and
    # wire_hyper(auto_slack=True)
    flex_auto_slack: bool = False


def _clamp_fmt(il: jax.Array, fl: jax.Array, h: DPSHyper):
    il = jnp.clip(il, h.il_min, h.il_max)
    fl = jnp.clip(fl, h.fl_min, h.fl_max)
    # keep the emulation grid exact in fp32 and respect the width cap:
    # shrink FL first (the paper's own bias: FL exists to stop round-to-zero,
    # IL to stop overflow; overflow is the catastrophic failure mode).
    fl = jnp.minimum(fl, _EXACT_SPAN + 1 - il)
    fl = jnp.minimum(fl, h.max_total - il)
    return il.astype(jnp.int32), fl.astype(jnp.int32)


# ---------------------------------------------------------------------------
# Paper controller — Algorithm 2.
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PaperState:
    il: jax.Array
    fl: jax.Array


class PaperController:
    """Overflow- and quantization-error-based scaling (the paper's Alg. 2).

        if R > R_max: IL += s  else IL -= s
        if E > E_max: FL += s  else FL -= s

    Aggressive by design: width shrinks on *every* step where the metrics sit
    below threshold (§2.2 "attempts to reduce the bit-width whenever ...").
    """

    name = "paper"

    def __init__(self, hyper: DPSHyper = DPSHyper()):
        self.h = hyper

    def init(self, shape=()) -> PaperState:
        return PaperState(
            il=jnp.full(shape, self.h.il_init, jnp.int32),
            fl=jnp.full(shape, self.h.fl_init, jnp.int32),
        )

    def fmt(self, state: PaperState) -> FixedPointFormat:
        return FixedPointFormat(state.il, state.fl)

    def update(self, state: PaperState, stats: QuantStats, aux=None) -> PaperState:
        h = self.h
        r = stats.overflow_rate()
        e = stats.quant_error(h.error_metric)
        il = state.il + jnp.where(r > h.r_max, h.step, -h.step)
        fl = state.fl + jnp.where(e > h.e_max, h.step, -h.step)
        il, fl = _clamp_fmt(il, fl, h)
        return PaperState(il, fl)


# ---------------------------------------------------------------------------
# Courbariaux et al. '14 — fixed width, dynamic radix, overflow-driven.
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class CourbariauxState:
    il: jax.Array
    fl: jax.Array


class CourbariauxController:
    """Greedy overflow-rate scheme with IL + FL = total_bits (§3).

    if R > R_max:        radix right (IL+1, FL-1)
    elif 2R <= R_max:    radix left  (IL-1, FL+1)   # headroom
    else:                unchanged
    """

    name = "courbariaux"

    def __init__(self, hyper: DPSHyper = DPSHyper()):
        self.h = hyper

    def init(self, shape=()) -> CourbariauxState:
        n = self.h.total_bits
        il0 = min(max(self.h.il_init, self.h.il_min), n - 1)
        return CourbariauxState(
            il=jnp.full(shape, il0, jnp.int32),
            fl=jnp.full(shape, n - il0, jnp.int32),
        )

    def fmt(self, state: CourbariauxState) -> FixedPointFormat:
        return FixedPointFormat(state.il, state.fl)

    def update(self, state: CourbariauxState, stats: QuantStats, aux=None):
        h = self.h
        r = stats.overflow_rate()
        delta = jnp.where(r > h.r_max, 1, jnp.where(2.0 * r <= h.r_max, -1, 0))
        il = jnp.clip(state.il + delta, h.il_min, h.total_bits - h.fl_min)
        fl = h.total_bits - il
        return CourbariauxState(il.astype(jnp.int32), fl.astype(jnp.int32))


# ---------------------------------------------------------------------------
# Na & Mukhopadhyay '16 — convergence-based, dynamic width (round-to-nearest).
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class NaState:
    tl: jax.Array          # current target bit-width
    il: jax.Array
    fl: jax.Array
    loss_ema: jax.Array    # slow EMA of training loss
    best_ema: jax.Array    # best (lowest) EMA seen since last width bump
    stall: jax.Array       # consecutive non-improving steps


class NaController:
    """Width grows by `s` whenever training stalls or overflows (§3).

    IL tracks overflow like the fixed-width schemes; FL = tl - IL.  Rounding
    is round-to-nearest in the original — the training loop consults
    ``controller.rounding`` to pick the mode.
    """

    name = "na_mukhopadhyay"
    rounding = "nearest"

    def __init__(self, hyper: DPSHyper = DPSHyper()):
        self.h = hyper

    def init(self, shape=()) -> NaState:
        tl0 = self.h.na_tl_init
        il0 = max(self.h.il_min, tl0 // 2)
        return NaState(
            tl=jnp.full(shape, tl0, jnp.int32),
            il=jnp.full(shape, il0, jnp.int32),
            fl=jnp.full(shape, tl0 - il0, jnp.int32),
            loss_ema=jnp.full(shape, jnp.inf, jnp.float32),
            best_ema=jnp.full(shape, jnp.inf, jnp.float32),
            stall=jnp.zeros(shape, jnp.int32),
        )

    def fmt(self, state: NaState) -> FixedPointFormat:
        return FixedPointFormat(state.il, state.fl)

    def update(self, state: NaState, stats: QuantStats, aux=None) -> NaState:
        h = self.h
        loss = jnp.asarray(aux["loss"], jnp.float32) if aux else jnp.float32(0)
        beta = 1.0 - 1.0 / h.na_window
        ema = jnp.where(jnp.isinf(state.loss_ema), loss,
                        beta * state.loss_ema + (1 - beta) * loss)
        improved = ema < state.best_ema * (1.0 - h.na_eps)
        stall = jnp.where(improved, 0, state.stall + 1)
        stagnant = stall >= h.na_window
        overflowing = stats.overflow_rate() > h.r_max
        bump = stagnant | overflowing
        tl = jnp.clip(state.tl + jnp.where(bump, h.step, 0), h.na_tl_init, h.na_ml)
        # radix placement from overflow, width from convergence:
        il = jnp.clip(state.il + jnp.where(overflowing, 1, 0), h.il_min, tl - h.fl_min)
        fl = tl - il
        return NaState(
            tl=tl.astype(jnp.int32), il=il.astype(jnp.int32), fl=fl.astype(jnp.int32),
            loss_ema=ema,
            best_ema=jnp.where(improved, ema, jnp.where(bump, ema, state.best_ema)),
            stall=jnp.where(bump, 0, stall).astype(jnp.int32),
        )


# ---------------------------------------------------------------------------
# Gupta et al. '15 — static format (no scaling).
# ---------------------------------------------------------------------------

class StaticController:
    """Fixed ⟨IL, FL⟩ for the whole run (Gupta et al.; also the paper's
    "fixed 13-bit" divergence demonstration)."""

    name = "static"

    def __init__(self, hyper: DPSHyper = DPSHyper()):
        self.h = hyper

    def init(self, shape=()) -> PaperState:
        return PaperState(
            il=jnp.full(shape, self.h.il_init, jnp.int32),
            fl=jnp.full(shape, self.h.fl_init, jnp.int32),
        )

    def fmt(self, state: PaperState) -> FixedPointFormat:
        return FixedPointFormat(state.il, state.fl)

    def update(self, state: PaperState, stats: QuantStats, aux=None) -> PaperState:
        return state


# ---------------------------------------------------------------------------
# FlexPoint-like — fixed width, predictive max-value radix (Köster et al.).
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class FlexState:
    il: jax.Array
    fl: jax.Array
    max_ema: jax.Array


class FlexpointController:
    """Predict next-step max |x| from an EMA, place the radix just above it.

    Width is fixed at ``total_bits`` (Flexpoint uses a 16-bit mantissa with a
    shared exponent; the shared exponent maps onto our IL choice).
    """

    name = "flexpoint"

    def __init__(self, hyper: DPSHyper = DPSHyper()):
        self.h = hyper

    def init(self, shape=()) -> FlexState:
        n = self.h.total_bits
        il0 = min(max(self.h.il_init, self.h.il_min), n)
        return FlexState(
            il=jnp.full(shape, il0, jnp.int32),
            fl=jnp.full(shape, n - il0, jnp.int32),
            max_ema=jnp.zeros(shape, jnp.float32),
        )

    def fmt(self, state: FlexState) -> FixedPointFormat:
        return FixedPointFormat(state.il, state.fl)

    def update(self, state: FlexState, stats: QuantStats, aux=None) -> FlexState:
        h = self.h
        m = jnp.maximum(h.flex_decay * state.max_ema,
                        stats.max_abs.astype(jnp.float32))
        pred = m * (2.0 ** h.flex_slack)
        if h.flex_auto_slack:
            # Measured slack: the per-element mean |x| over nonzero
            # elements estimates the bulk scale b of the magnitude
            # distribution; for a Laplace(0, b) tail the r_max quantile
            # sits at b·ln(1/r_max), so placing the radix there clips an
            # expected r_max fraction — the measured version of the
            # hand-tuned negative gradient slack (see wire_hyper), and
            # it tracks each stream (per-group rows included) instead of
            # one per-tensor-class constant.  Never place above the max
            # component (nothing out there to cover), and fall back to
            # the static slack on steps where the stream carried no
            # stats (e.g. wire domains before the sync first engages).
            bulk = stats.abs_sum / jnp.maximum(stats.nonzero, 1.0)
            cover = bulk * jnp.float32(jnp.log(1.0 / h.r_max))
            pred = jnp.where(stats.nonzero > 0.0,
                             jnp.minimum(m, cover), pred)
        # smallest IL whose signed range covers pred: 2^(IL-1) > pred
        il = jnp.ceil(jnp.log2(jnp.maximum(pred, 1e-30))).astype(jnp.int32) + 1
        il = jnp.clip(il, h.il_min, h.total_bits - h.fl_min)
        fl = h.total_bits - il
        return FlexState(il.astype(jnp.int32), fl.astype(jnp.int32), m)


CONTROLLERS = {
    c.name: c
    for c in (PaperController, CourbariauxController, NaController,
              StaticController, FlexpointController)
}


def make_controller(name: str, hyper: Optional[DPSHyper] = None):
    if name not in CONTROLLERS:
        raise ValueError(f"unknown DPS controller {name!r}; have {sorted(CONTROLLERS)}")
    return CONTROLLERS[name](hyper or DPSHyper())


def wire_hyper(wire_bits: int, il_init: int, slack: float = 1.0,
               auto_slack: bool = False) -> DPSHyper:
    """Hyper-parameters for a *wire* precision domain.

    The wire payload is int8 grid integers, so every width knob is capped at
    ``wire_bits``: fixed-width controllers (flexpoint / courbariaux) run at
    ``total_bits = wire_bits``, and dynamic-width controllers (paper) are
    clamped by ``max_total = wire_bits`` so a wire-domain format can never
    statically exceed the int8 capacity.

    ``slack`` is the flexpoint headroom exponent (radix placed to cover
    ``max|x| · 2^slack``).  At 8 bits the budget is too narrow to span a
    heavy-tailed tensor, so the right placement depends on the tensor
    class: *gradients* want a **negative** slack — the bulk carries the
    learning signal and the rare tail tolerates clipping (mild gradient
    clipping), so spending the grid on the bulk beats covering the max
    (measured on LeNet/MNIST-tiny: covering max|g| leaves most gradient
    elements under one grid step and destabilizes training) — while
    *parameters* are concentrated near their max and biased by clipping,
    so they want the classic positive headroom.

    Under a per-layer wire domain (``groups = G``) the same hyper governs
    every row: each layer's controller places its own radix from its own
    ``max|g|`` stream, so the slack is per-tensor-class while the radix is
    per-layer — the spread across rows is the measured octave spread of
    the per-layer gradient ranges.

    ``auto_slack=True`` replaces the hand-tuned constant with a measured
    placement: the flexpoint controller derives the radix from the wire
    stream's own ``abs_sum``/``nonzero`` (the bulk scale) at the ``r_max``
    tail quantile, so each domain — and each group row under a per-layer
    wire — tunes its own effective slack every step instead of inheriting
    one per-tensor-class constant.  ``slack`` remains the fallback until
    the stream first carries stats.
    """
    il0 = min(max(il_init, 1), wire_bits)
    return DPSHyper(il_min=1, il_max=wire_bits, fl_min=0,
                    fl_max=max(wire_bits - 1, 1), il_init=il0,
                    fl_init=wire_bits - il0, total_bits=wire_bits,
                    max_total=wire_bits, flex_slack=slack,
                    flex_auto_slack=auto_slack)


# ---------------------------------------------------------------------------
# Precision domains: declarative plan -> named controller-state registry.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DomainSpec:
    """One precision domain: controller kind, hyper, stats routing, groups.

    ``stats`` names the :class:`QuantStats` stream that feeds this domain's
    controller (empty = the domain's own name).  ``groups`` > 0 declares a
    per-group ``[G]`` controller state — one ⟨IL, FL⟩ per group, the
    ``[G, 2]`` format table the group-aligned collectives and the grouped
    Pallas wire kernel consume (see :mod:`repro.dist.collectives`); a
    ``[G]`` stats stream updates each group's row independently (the
    per-layer wire regime: ``QuantConfig.with_per_layer_wire``), while a
    scalar stream broadcasts.  0 is the global scalar case.  Hashable, so
    a plan can sit in a jit closure.

    ``wire`` declares this domain as a *wire* domain: its controller is
    allowed (expected) to consume wire-leg ``QuantStats``.  The
    precision-flow verifier (``repro.analysis.flow``) flags wire stats
    reaching a ``wire=False`` controller — the stats-starvation bug class
    ``qtrain._raw_grad_stats`` exists to prevent.
    """

    controller: str = "paper"
    hyper: DPSHyper = DPSHyper()
    stats: str = ""
    groups: int = 0
    wire: bool = False

    def make(self):
        return make_controller(self.controller, self.hyper)

    def state_shape(self) -> tuple:
        return (self.groups,) if self.groups else ()

    def stream(self, name: str) -> str:
        return self.stats or name


@jax.tree_util.register_pytree_with_keys_class
class DpsBundle:
    """Named per-domain controller states — the DPS registry's pytree.

    Behaves like an ordered, immutable mapping ``{domain: controller state}``
    and flattens with the domain names as keys, so checkpoints address
    leaves as ``dps/<domain>/<field>`` (the legacy three-key dict layout is
    a structural subset — see ``checkpoint.ckpt``).
    """

    def __init__(self, states):
        self._states = dict(states)

    def __getitem__(self, name):
        return self._states[name]

    def __contains__(self, name):
        return name in self._states

    def __iter__(self):
        return iter(self._states)

    def __len__(self):
        return len(self._states)

    def __repr__(self):
        return f"DpsBundle({list(self._states)})"

    def names(self):
        return tuple(self._states)

    def items(self):
        return self._states.items()

    def tree_flatten_with_keys(self):
        names = tuple(self._states)
        return ([(jax.tree_util.DictKey(n), self._states[n]) for n in names],
                names)

    @classmethod
    def tree_unflatten(cls, names, children):
        return cls(zip(names, children))


# The standard training domains.  ``weights``/``acts``/``grads`` are the
# paper's three compute attributes; ``wire_grads``/``wire_params`` govern the
# int8 collective legs (gradient all-reduce / reduce-scatter, ZeRO parameter
# all-gather) when compressed gradient sync is on.
COMPUTE_DOMAINS = ("weights", "acts", "grads")
WIRE_DOMAINS = ("wire_grads", "wire_params")


@dataclasses.dataclass(frozen=True)
class PrecisionPlan:
    """Declarative registry: domain name -> :class:`DomainSpec`.

    Builds and drives a :class:`DpsBundle`:

        plan   = PrecisionPlan.of(weights=DomainSpec(...), ...)
        bundle = plan.init()                      # DpsBundle (pytree)
        fmts   = plan.formats(bundle)             # {domain: FixedPointFormat}
        bundle = plan.update(bundle, streams, aux)

    ``streams`` is a ``{stream name: QuantStats}`` dict; each domain consumes
    the stream its spec routes to (its own name by default) and sees zero
    stats when that stream is absent this step — so a plan may carry domains
    (e.g. wire domains on a single-device run) that only engage sometimes.
    Hashable and static: a plan never changes shape under jit.
    """

    domains: Tuple[Tuple[str, DomainSpec], ...]

    def __post_init__(self):
        names = [n for n, _ in self.domains]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate precision domains in {names}")
        for n, spec in self.domains:
            if spec.controller not in CONTROLLERS:
                raise ValueError(f"domain {n!r}: unknown controller "
                                 f"{spec.controller!r}; have "
                                 f"{sorted(CONTROLLERS)}")
            if spec.groups < 0:
                raise ValueError(f"domain {n!r}: groups must be >= 0")

    @staticmethod
    def of(**domains: DomainSpec) -> "PrecisionPlan":
        return PrecisionPlan(tuple(domains.items()))

    @property
    def names(self) -> Tuple[str, ...]:
        return tuple(n for n, _ in self.domains)

    def spec(self, name: str) -> DomainSpec:
        for n, s in self.domains:
            if n == name:
                return s
        raise KeyError(f"no precision domain {name!r}; have {self.names}")

    def __contains__(self, name: str) -> bool:
        return any(n == name for n, _ in self.domains)

    def controller(self, name: str):
        return self.spec(name).make()

    def init(self) -> DpsBundle:
        return DpsBundle((n, s.make().init(s.state_shape()))
                         for n, s in self.domains)

    def formats(self, bundle: DpsBundle):
        return {n: s.make().fmt(bundle[n]) for n, s in self.domains}

    def update(self, bundle: DpsBundle, streams, aux=None) -> DpsBundle:
        out = {}
        for n, s in self.domains:
            st = streams.get(s.stream(n))
            shape = s.state_shape()
            if st is None:
                st = QuantStats.zero(shape)
            elif tuple(st.count.shape) != shape:
                if st.count.ndim == 0:
                    # a scalar stream feeding a per-group domain drives
                    # every group with the same global statistics
                    st = jax.tree.map(
                        lambda x: jnp.broadcast_to(x, shape), st)
                else:
                    # anything else would silently reshape the domain's
                    # controller state (breaking the static-structure
                    # invariant jit/checkpoints rely on) or die in
                    # controller arithmetic with an opaque broadcast error
                    raise ValueError(
                        f"domain {n!r} (groups={s.groups}) consumes stream "
                        f"{s.stream(n)!r} whose stats have shape "
                        f"{tuple(st.count.shape)}; a routed stream must be "
                        "scalar or match the domain's group count")
            # declare the consumption site for the precision-flow verifier:
            # this stream is about to drive domain ``n``'s controller
            st = tagging.tag_tree(st, "stats_sink", domain=n, wire=s.wire,
                                  stream=s.stream(n))
            out[n] = s.make().update(bundle[n], st, aux)
        return DpsBundle(out)
