"""Trace-time markers for the precision-flow verifier.

``dps_tag`` is an **identity primitive**: at runtime it is a no-op (the
MLIR lowering forwards its operand, so nothing reaches the compiled HLO),
but it survives into the jaxpr, where ``repro.analysis.flow`` reads its
parameters to learn — from *declarations, not guesses* — where quantized
values enter and leave the wire pipeline:

    kind="encode_in"     the fp32 value about to be wire-quantized
    kind="decode_out"    the fp32 value a wire decode just produced
    kind="wire_payload"  the int8 buffer about to enter a collective
    kind="wire_stats"    QuantStats fields a wire leg measured
    kind="sr_bits"       the uniform-bits operand of a stochastic encode
    kind="stats_sink"    a stream a controller is about to consume
    kind="wire_bucket"   a bucketed-wire landmark (repro.dist.overlap):
                         stage="grad" where a bucket's gradient leaf
                         materializes in the backward, stage="ready" on
                         the raw leaf handed to the wire, stage="mean"
                         on the decoded bucket mean — with bucket=b,
                         n=<bucket count> (and leaf=g for per-leaf
                         stages)

Each tag carries the precision ``domain`` it belongs to (taken from the
ambient :func:`domain` context when not given explicitly) plus arbitrary
hashable metadata.  The analyzer taint-propagates from these markers; see
``src/repro/analysis/README.md`` for the rules built on them.

The primitive is registered with identity JVP/transpose/batching rules so
tagged values differentiate and vmap exactly like untagged ones, and the
abstract eval is the identity, so tracing semantics are unchanged.
"""

from __future__ import annotations

import contextlib
from typing import Any, Iterator, Optional, Tuple

import jax
from jax import core as jax_core
from jax.interpreters import ad, batching, mlir

TAG_PRIMITIVE_NAME = "dps_tag"

dps_tag_p = jax_core.Primitive(TAG_PRIMITIVE_NAME)
dps_tag_p.def_impl(lambda x, **params: x)
dps_tag_p.def_abstract_eval(lambda x, **params: x)

# lowering: forward the operand — the tag never reaches HLO
mlir.register_lowering(dps_tag_p, lambda ctx, x, **params: [x])

# vmap: the tag applies to the batched value unchanged
batching.defvectorized(dps_tag_p)

# JVP: the tangent of a tagged value is the (untagged) tangent; the tag
# is a statement about the primal's role in the wire pipeline.
ad.defjvp(dps_tag_p, lambda g, x, **params: g)
ad.primitive_transposes[dps_tag_p] = lambda ct, x, **params: [ct]


# ---------------------------------------------------------------------------
# Ambient domain context: collectives enter ``with tagging.domain(name)``
# so every tag below them resolves its precision domain without threading
# the name through each helper.
# ---------------------------------------------------------------------------

_DOMAIN_STACK: list = []


@contextlib.contextmanager
def domain(name: str) -> Iterator[None]:
    """Trace-time context: tags bound inside resolve ``domain=name``."""
    _DOMAIN_STACK.append(name)
    try:
        yield
    finally:
        _DOMAIN_STACK.pop()


def current_domain() -> Optional[str]:
    return _DOMAIN_STACK[-1] if _DOMAIN_STACK else None


def _freeze_meta(meta: dict) -> Tuple[Tuple[str, Any], ...]:
    frozen = tuple(sorted(meta.items()))
    for _, v in frozen:
        hash(v)   # params live in the jaxpr: hashable only
    return frozen


def tag(x, kind: str, **meta):
    """Mark ``x`` with ``kind`` for the precision-flow analyzer.

    Identity at runtime.  ``domain`` defaults to the ambient
    :func:`domain` context; any extra keyword metadata must be hashable
    (it is stored as jaxpr equation parameters).
    """
    meta.setdefault("domain", current_domain())
    return dps_tag_p.bind(x, kind=kind, meta=_freeze_meta(meta))


def tag_tree(tree, kind: str, **meta):
    """:func:`tag` every array leaf of a pytree."""
    return jax.tree.map(lambda leaf: tag(leaf, kind, **meta), tree)


def tag_params(eqn_params: dict) -> Optional[dict]:
    """Decode a jaxpr equation's tag parameters, or None if ``eqn_params``
    is not from a ``dps_tag`` equation.  Returns {"kind": ..., **meta}."""
    if "kind" not in eqn_params or "meta" not in eqn_params:
        return None
    out = {"kind": eqn_params["kind"]}
    out.update(dict(eqn_params["meta"]))
    return out
