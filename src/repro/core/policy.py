"""Quantization policy: which tensors the DPS quantizers touch.

The paper quantizes weights, biases, activations and gradients (Alg. 1).
At LM scale a handful of numerically sensitive islands must stay in float —
each is the same kind of carve-out the paper itself makes for gradients
("requires the most precision in order for training to converge"):

  * norm scales / biases        — O(d) params, scale-sensitive
  * router weights & logits     — quantizing routing probabilities reorders
                                  top-k and destabilizes expert assignment
  * SSM recurrent islands       — A_log, dt_bias, and the recurrent state:
                                  fixed-point state underflows at 2^-FL over
                                  4k-512k step recurrences (paper §5 predicts
                                  exactly this failure: smallest value 2^-FL)
  * RoPE tables / positions     — deterministic constants

Everything else — projections, embeddings, MoE expert weights, conv stems —
is quantized.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Sequence

DEFAULT_EXCLUDE: tuple = (
    r"norm", r"ln_", r"_scale$", r"router", r"gate_w$", r"a_log", r"dt_bias",
    r"rope", r"pos_emb",
)


@dataclasses.dataclass(frozen=True)
class QuantPolicy:
    """Name-pattern based tensor selection (static; hashable)."""

    quantize_weights: bool = True
    quantize_acts: bool = True
    quantize_grads: bool = True
    exclude: Sequence[str] = DEFAULT_EXCLUDE

    def quantizes(self, domain: str) -> bool:
        """Does the policy quantize this precision domain's tensors?

        The three compute domains map onto their enable flags.  Wire domains
        are always true: the int8 wire is a transport codec whose engagement
        is decided by ``QuantConfig.grad_allreduce_bits`` (and, for the flat
        ZeRO params leg, by the per-leaf carve-outs via
        ``param_predicate``) — not by the numerics policy.
        """
        return {"weights": self.quantize_weights,
                "acts": self.quantize_acts,
                "grads": self.quantize_grads}.get(domain, True)

    def param_predicate(self):
        pats = [re.compile(p) for p in self.exclude]

        def pred(path, leaf) -> bool:
            if not self.quantize_weights:
                return False
            name = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
            return not any(p.search(name) for p in pats)

        return pred
