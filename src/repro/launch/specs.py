"""ShapeDtypeStruct stand-ins + shardings for every (arch × shape) cell.

``input_specs`` returns weak-type-correct, shardable stand-ins for every
model input — the dry-run lowers against these, so no tensor is ever
allocated.  ``*_shardings`` resolve the logical axes of every train-state /
batch / cache leaf against a concrete mesh via the divisibility-fallback
rules.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core import qtrain
from repro.dist.sharding import LogicalRules, tree_specs
from repro.models import registry
from repro.models.common import abstract_params, logical_tree


def _ns(mesh, rules, logical, shape):
    return NamedSharding(mesh, rules.spec(logical, shape, mesh))


# ---------------------------------------------------------------------------
# Batches.
# ---------------------------------------------------------------------------

def train_batch_specs(cfg: ModelConfig, shape: ShapeConfig):
    """ShapeDtypeStructs of one global training batch."""
    B, S = shape.batch, shape.seq
    batch: Dict[str, Any] = {}
    if cfg.family == "vlm":
        nt = S - cfg.n_patches
        batch["tokens"] = jax.ShapeDtypeStruct((B, nt + 1), jnp.int32)
        batch["vision_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.n_patches, cfg.d_model), jnp.bfloat16)
    elif cfg.family == "encdec":
        batch["tokens"] = jax.ShapeDtypeStruct((B, S + 1), jnp.int32)
        batch["frames"] = jax.ShapeDtypeStruct(
            (B, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
    else:
        batch["tokens"] = jax.ShapeDtypeStruct((B, S + 1), jnp.int32)
    return batch


def train_batch_shardings(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                          rules: LogicalRules):
    specs = train_batch_specs(cfg, shape)
    out = {}
    for k, v in specs.items():
        logical = (("batch",) + (None,) * (len(v.shape) - 1))
        out[k] = _ns(mesh, rules, logical, v.shape)
    return out


# ---------------------------------------------------------------------------
# Train state.
# ---------------------------------------------------------------------------

def param_shardings(cfg: ModelConfig, mesh: Mesh, rules: LogicalRules):
    mod = registry(cfg.family)
    defs = mod.model_defs(cfg)
    return tree_specs(logical_tree(defs), abstract_params(defs), mesh, rules)


def opt_state_shardings(optimizer, p_shards):
    from repro.optim.optimizers import SGD, AdamW
    if isinstance(optimizer, SGD):
        return {"mu": p_shards}
    if isinstance(optimizer, AdamW):
        return {"m": p_shards, "v": p_shards}
    raise TypeError(type(optimizer))


def _abstract_opt_state(aparams, optimizer, qcfg: qtrain.QuantConfig,
                        mesh: Optional[Mesh]):
    """Optimizer-state template in whichever layout the step will use.

    Mirrors :func:`repro.core.qtrain.zero_opt_engaged`: when the ZeRO-1
    path engages, the state is the flat padded
    :func:`~repro.core.qtrain.zero_opt_state` layout (meant to shard
    ``P("data")``); otherwise the ordinary per-leaf ``optimizer.init``
    tree.  Keeping this decision in one place prevents a layout mismatch
    between the checkpoint template, the shardings, and the step.
    """
    if qtrain.zero_opt_engaged(qcfg, mesh):
        # qcfg rides along so the flat layout matches the step's: per-layer
        # wire formats / wire_overlap switch it to the group-aligned
        # partitioner, whose padded size differs from the plain one.
        return jax.eval_shape(
            lambda p: qtrain.zero_opt_state(optimizer, p,
                                            qcfg.zero_opt_shards,
                                            qcfg=qcfg), aparams)
    return jax.eval_shape(optimizer.init, aparams)


def train_state_shardings(cfg: ModelConfig, mesh: Mesh, rules: LogicalRules,
                          optimizer, qcfg: qtrain.QuantConfig):
    repl = NamedSharding(mesh, P())
    p_shards = param_shardings(cfg, mesh, rules)
    if qtrain.zero_opt_engaged(qcfg, mesh):
        # ZeRO-1: every optimizer-state leaf is one flat padded vector
        # sharded over the data axis — 1/n of the replicated bytes per
        # device, the point of the scheme.
        data_sh = NamedSharding(mesh, P("data"))
        aparams = abstract_params(registry(cfg.family).model_defs(cfg))
        opt_shards = jax.tree.map(
            lambda _: data_sh,
            _abstract_opt_state(aparams, optimizer, qcfg, mesh))
    else:
        opt_shards = opt_state_shardings(optimizer, p_shards)
    # the DPS registry (DpsBundle over the plan's domains, wire domains
    # included when declared) is replicated scalar state on every device
    dps_template = qtrain.init_dps_bundle(qcfg)
    dps_shards = jax.tree.map(lambda _: repl, dps_template)
    # guard state (repro.resilience) is replicated scalars / tiny [D]
    # vectors, exactly like the DPS registry
    guard = None
    if qcfg.guards is not None:
        from repro.resilience import guards as guards_lib
        guard = jax.tree.map(lambda _: repl,
                             guards_lib.init_guard_state(qcfg.plan()))
    return qtrain.TrainState(
        step=repl, params=p_shards, opt_state=opt_shards,
        dps=dps_shards, rng=repl, last_loss=repl, guard=guard)


def abstract_train_state(cfg: ModelConfig, optimizer, qcfg: qtrain.QuantConfig,
                         mesh: Optional[Mesh] = None):
    """ShapeDtypeStruct TrainState (dry-run: no allocation).

    ``mesh`` matters only under ``qcfg.zero_opt_shards``: the optimizer
    state template switches to the flat ZeRO layout exactly when the step
    built against this mesh will (see :func:`_abstract_opt_state`).
    """
    mod = registry(cfg.family)
    defs = mod.model_defs(cfg)
    aparams = abstract_params(defs)
    opt_state = _abstract_opt_state(aparams, optimizer, qcfg, mesh)
    dps = jax.eval_shape(lambda: qtrain.init_dps_bundle(qcfg))
    rng = jax.eval_shape(lambda: jax.random.key(0))
    guard = None
    if qcfg.guards is not None:
        from repro.resilience import guards as guards_lib
        guard = jax.eval_shape(
            lambda: guards_lib.init_guard_state(qcfg.plan()))
    return qtrain.TrainState(
        step=jax.ShapeDtypeStruct((), jnp.int32),
        params=aparams, opt_state=opt_state, dps=dps, rng=rng,
        last_loss=jax.ShapeDtypeStruct((), jnp.float32), guard=guard)


# ---------------------------------------------------------------------------
# Decode / prefill.
# ---------------------------------------------------------------------------

def decode_specs(cfg: ModelConfig, shape: ShapeConfig):
    """(tokens, cache, pos) stand-ins for one serve_step."""
    B, S = shape.batch, shape.seq
    mod = registry(cfg.family)
    return {
        "tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32),
        "cache": mod.cache_struct(cfg, B, S),
        "pos": jax.ShapeDtypeStruct((B,), jnp.int32),
    }


def decode_shardings(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                     rules: LogicalRules):
    B, S = shape.batch, shape.seq
    mod = registry(cfg.family)
    struct = mod.cache_struct(cfg, B, S)
    logical = mod.cache_logical(cfg)
    cache_shards = tree_specs(logical, struct, mesh, rules)
    return {
        "tokens": _ns(mesh, rules, ("batch", None), (B, 1)),
        "cache": cache_shards,
        "pos": _ns(mesh, rules, ("batch",), (B,)),
    }


def prefill_specs(cfg: ModelConfig, shape: ShapeConfig):
    B, S = shape.batch, shape.seq
    out: Dict[str, Any] = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    if cfg.family == "vlm":
        out["tokens"] = jax.ShapeDtypeStruct((B, S - cfg.n_patches), jnp.int32)
        out["vision_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.n_patches, cfg.d_model), jnp.bfloat16)
    if cfg.family == "encdec":
        out["frames"] = jax.ShapeDtypeStruct(
            (B, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
    return out


def prefill_shardings(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                      rules: LogicalRules):
    specs = prefill_specs(cfg, shape)
    return {k: _ns(mesh, rules, ("batch",) + (None,) * (len(v.shape) - 1),
                   v.shape)
            for k, v in specs.items()}


# ---------------------------------------------------------------------------
# Step builders.
# ---------------------------------------------------------------------------

def per_layer_wire_qcfg(cfg: ModelConfig,
                        qcfg: qtrain.QuantConfig) -> qtrain.QuantConfig:
    """``qcfg`` with one ``wire_grads`` ⟨IL, FL⟩ per parameter leaf of this
    arch — the group count derives from the abstract param tree, so launch
    code can finalize the config before any tensor exists.  A no-op unless
    the compressed gradient sync is configured."""
    return qcfg.with_per_layer_wire(
        abstract_params(registry(cfg.family).model_defs(cfg)))


def wire_bucket_plan(cfg: ModelConfig, qcfg: qtrain.QuantConfig):
    """The :class:`repro.dist.overlap.BucketPlan` a ``wire_overlap`` train
    step would bucket this arch's gradients under, derived from the
    abstract param tree (no tensor exists yet) — the same derivation
    :func:`repro.core.qtrain.make_train_step` performs, so launch code and
    the dry-run report the geometry the step actually runs.  ``None``
    unless the overlapped wire is configured."""
    if not (qcfg.wire_overlap and qcfg.grad_allreduce_bits is not None):
        return None
    from repro.dist import overlap as overlap_lib
    aparams = abstract_params(registry(cfg.family).model_defs(cfg))
    sizes = tuple(l.size for l in jax.tree_util.tree_leaves(aparams))
    return overlap_lib.plan_buckets(
        sizes, qcfg.wire_bucket_elems or overlap_lib.DEFAULT_BUCKET_ELEMS)


def build_train_step(cfg: ModelConfig, qcfg: qtrain.QuantConfig, optimizer,
                     accum_steps: Optional[int] = None,
                     mesh: Optional[Mesh] = None, faults=None):
    """Train step for one arch.  ``mesh`` is only needed when
    ``qcfg.grad_allreduce_bits`` is set: the compressed gradient all-reduce
    runs as an explicit ``shard_map`` over the mesh's data axis (see
    :func:`repro.core.qtrain.make_train_step`).  ``faults`` is a
    :class:`repro.resilience.FaultPlan` compiled into the step (test
    harness; None leaves the step untouched)."""
    mod = registry(cfg.family)
    accum = cfg.train_accum if accum_steps is None else accum_steps
    return qtrain.make_train_step(mod.loss_fn(cfg), optimizer, qcfg,
                                  accum_steps=accum, mesh=mesh,
                                  faults=faults)


def build_decode_step(cfg: ModelConfig):
    mod = registry(cfg.family)

    def serve_step(params, tokens, cache, pos):
        logits, new_cache = mod.decode_step(cfg, params, tokens, cache, pos)
        # greedy next token + advanced positions: the serving loop's fixpoint
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return next_tok, new_cache, pos + 1

    return serve_step


def build_prefill_step(cfg: ModelConfig, max_seq: int):
    mod = registry(cfg.family)

    def prefill_step(params, **inputs):
        return mod.prefill(cfg, params, inputs.pop("tokens"), max_seq,
                           **inputs)

    return prefill_step
