import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this driver
  1. builds the production mesh (16×16 single-pod / 2×16×16 multi-pod),
  2. resolves every sharding (params, optimizer state, batch, KV caches)
     through the logical rules,
  3. ``jax.jit(step).lower(...).compile()``s against ShapeDtypeStruct
     stand-ins — no tensor is allocated,
  4. records ``memory_analysis()`` (fits-on-chip proof),
     ``cost_analysis()`` (FLOPs / bytes) and the collective schedule parsed
     from the optimized HLO,
  5. compiles shallow unrolled probes (1-layer / 2-layer) to undo XLA's
     count-the-while-body-once accounting for scanned layer stacks
     (DESIGN §6), and
  6. writes one JSON per cell under results/dryrun/.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3_2_3b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""

import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import (ARCH_NAMES, SHAPES, ModelConfig, ShapeConfig,
                                applicable_shapes, get_config)
from repro.core import qtrain
from repro.dist.sharding import LogicalRules, axis_rules
from repro.launch import specs as specs_lib
from repro.launch.mesh import make_production_mesh
from repro.optim import SGDConfig, make_optimizer

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")

# HLO collective accounting lives in hlo_stats (shared with benchmarks and
# the multi-device tests); re-exported here for historical importers.
from repro.launch.hlo_stats import (COLLECTIVE_OPS,  # noqa: E402
                                    collective_bytes, wire_bytes_summary)


def _mesh_and_rules(multi_pod: bool):
    mesh = make_production_mesh(multi_pod=multi_pod)
    return mesh, LogicalRules()


def _qcfg(grad_allreduce_bits=None, zero_opt_shards=None,
          wire_controller="flexpoint", wire_overlap=False) -> qtrain.QuantConfig:
    return qtrain.QuantConfig(enabled=True, controller="paper",
                              grad_allreduce_bits=grad_allreduce_bits,
                              zero_opt_shards=zero_opt_shards,
                              wire_controller=wire_controller,
                              wire_overlap=wire_overlap)


def _optimizer():
    return make_optimizer(SGDConfig())


def _train_qcfg(cfg, mesh, grad_allreduce_bits=None, zero_opt=False,
                wire_controller="flexpoint",
                wire_groups="global",
                wire_overlap=False) -> qtrain.QuantConfig:
    """The QuantConfig a train cell compiles under — single source for the
    compile itself and the per-cell ``precision_domains`` report."""
    zero_shards = None
    if zero_opt:
        zero_shards = int(dict(zip(mesh.axis_names,
                                   mesh.devices.shape)).get("data", 1))
    qcfg = _qcfg(grad_allreduce_bits, zero_shards, wire_controller,
                 wire_overlap)
    if wire_groups == "per-layer":
        # composes with ZeRO: the group-aligned flat layout keeps leaf
        # boundaries, so per-leaf wire formats survive the flatten
        qcfg = specs_lib.per_layer_wire_qcfg(cfg, qcfg)
    return qcfg


def _abstract_params(cfg: ModelConfig):
    from repro.models import registry
    from repro.models.common import abstract_params
    return abstract_params(registry(cfg.family).model_defs(cfg))


def _engaged_domains(cfg: ModelConfig, qcfg: qtrain.QuantConfig,
                     mesh) -> Tuple[str, ...]:
    """The wire domains the compiled step will actually serve on this
    mesh (a declared domain can compile on a mesh where the sync is
    skipped — production meshes have a model axis > 1)."""
    engaged = []
    if qtrain.wire_sync_engaged(qcfg, mesh):
        engaged.append("wire_grads")
    if qtrain.zero_opt_engaged(qcfg, mesh):
        engaged.append("wire_grads")
        if qtrain.wire_params_engaged(qcfg, _abstract_params(cfg), mesh):
            engaged.append("wire_params")
    return tuple(dict.fromkeys(engaged))


def _audit_wire(cfg: ModelConfig, qcfg: qtrain.QuantConfig, mesh,
                hlo: str, engaged: Tuple[str, ...]) -> Dict[str, Any]:
    """Prove the declared wire domains against the compiled HLO
    (``repro.analysis.hlo_audit``) and FAIL the dry run on drift — a
    domain the config declares, the mesh engages, but the HLO never
    serves used to slip through as a silently-fp32 cell."""
    from repro.analysis import hlo_audit

    n_params = sum(l.size for l in jax.tree_util.tree_leaves(
        _abstract_params(cfg)))
    two_leg = True
    declared_f32 = 0.0
    if qtrain.zero_opt_engaged(qcfg, mesh) and "wire_params" not in engaged:
        # the policy excludes leaves: fp32 param gather is the declared
        # behavior (see qtrain.wire_params_engaged) — one s8 leg remains
        two_leg = False
        declared_f32 = 4.0 * n_params * 1.25
    claims = hlo_audit.AuditClaims(
        engaged=engaged, two_leg=two_leg, grouped=False,
        f32_declared_bytes=declared_f32,
        n_wire_elems=n_params if engaged else None)
    report = hlo_audit.audit_hlo(hlo, claims, name=f"{cfg.name}/wire")
    if not report.ok:
        raise RuntimeError(
            "wire audit failed — declared precision domains drifted from "
            "the compiled HLO:\n" + "\n".join(
                str(v) for v in report.violations))
    return {"engaged": list(engaged),
            "rules_checked": sorted(report.checked),
            "violations": []}


def _compile_train(cfg: ModelConfig, shape: ShapeConfig, mesh, rules,
                   grad_allreduce_bits=None, zero_opt=False,
                   wire_controller="flexpoint", wire_groups="global",
                   wire_overlap=False):
    qcfg = _train_qcfg(cfg, mesh, grad_allreduce_bits, zero_opt,
                       wire_controller, wire_groups, wire_overlap)
    opt = _optimizer()
    # On the production meshes (model axis > 1) the compressed all-reduce
    # and ZeRO-1 fall back (with a warning) to the implicit psum /
    # replicated optimizer state — qtrain only engages the shard_map paths
    # on pure data-parallel meshes.  abstract_train_state makes the same
    # call, so the opt-state layout always matches the step.
    step = specs_lib.build_train_step(cfg, qcfg, opt, mesh=mesh)
    state_sh = specs_lib.train_state_shardings(cfg, mesh, rules, opt, qcfg)
    batch_sh = specs_lib.train_batch_shardings(cfg, shape, mesh, rules)
    astate = specs_lib.abstract_train_state(cfg, opt, qcfg, mesh=mesh)
    abatch = specs_lib.train_batch_specs(cfg, shape)
    repl = NamedSharding(mesh, P())

    with mesh, axis_rules(mesh, rules):
        jitted = jax.jit(step,
                         in_shardings=(state_sh, batch_sh),
                         out_shardings=(state_sh, None),
                         donate_argnums=(0,))
        lowered = jitted.lower(astate, abatch)
        compiled = lowered.compile()
    return lowered, compiled


def _compile_decode(cfg: ModelConfig, shape: ShapeConfig, mesh, rules):
    step = specs_lib.build_decode_step(cfg)
    d_specs = specs_lib.decode_specs(cfg, shape)
    d_sh = specs_lib.decode_shardings(cfg, shape, mesh, rules)
    p_sh = specs_lib.param_shardings(cfg, mesh, rules)
    from repro.models import registry
    from repro.models.common import abstract_params
    aparams = abstract_params(registry(cfg.family).model_defs(cfg))

    with mesh, axis_rules(mesh, rules):
        jitted = jax.jit(step,
                         in_shardings=(p_sh, d_sh["tokens"], d_sh["cache"],
                                       d_sh["pos"]),
                         out_shardings=(d_sh["tokens"], d_sh["cache"],
                                        d_sh["pos"]),
                         donate_argnums=(2,))
        lowered = jitted.lower(aparams, d_specs["tokens"], d_specs["cache"],
                               d_specs["pos"])
        compiled = lowered.compile()
    return lowered, compiled


def _compile_prefill(cfg: ModelConfig, shape: ShapeConfig, mesh, rules):
    step = specs_lib.build_prefill_step(cfg, max_seq=shape.seq)
    p_sh = specs_lib.param_shardings(cfg, mesh, rules)
    in_sh = specs_lib.prefill_shardings(cfg, shape, mesh, rules)
    in_specs = specs_lib.prefill_specs(cfg, shape)
    from repro.models import registry
    from repro.models.common import abstract_params
    aparams = abstract_params(registry(cfg.family).model_defs(cfg))

    with mesh, axis_rules(mesh, rules):
        jitted = jax.jit(lambda params, inputs: step(params, **inputs),
                         in_shardings=(p_sh, in_sh))
        lowered = jitted.lower(aparams, in_specs)
        compiled = lowered.compile()
    return lowered, compiled


KIND_COMPILERS = {"train": _compile_train, "prefill": _compile_prefill,
                  "decode": _compile_decode}


def _probe_variants(cfg: ModelConfig):
    """Shallow configs for the scan-body FLOP correction.

    Returns (variants, reconstruct) where ``variants`` is a dict
    name -> cfg and ``reconstruct(probe_stats) -> full_stats_fn`` combines
    them linearly into the full-depth estimate."""
    P = dict(probe_unroll=True, train_accum=1)
    if cfg.family == "hybrid":
        k = cfg.hybrid_period
        g, rem = cfg.n_layers // k, cfg.n_layers % k
        v = {"g1": dataclasses.replace(cfg, n_layers=k, **P),
             "g2": dataclasses.replace(cfg, n_layers=2 * k, **P),
             "g1r": dataclasses.replace(cfg, n_layers=k + 1, **P)}

        def rec(p):
            per_group = p["g2"] - p["g1"]
            per_mamba = p["g1r"] - p["g1"]
            const = p["g1"] - per_group
            return const + g * per_group + rem * per_mamba
        return v, rec
    if cfg.family == "encdec":
        v = {"d1e1": dataclasses.replace(cfg, n_layers=1, n_enc_layers=1, **P),
             "d2e1": dataclasses.replace(cfg, n_layers=2, n_enc_layers=1, **P),
             "d1e2": dataclasses.replace(cfg, n_layers=1, n_enc_layers=2, **P)}

        def rec(p):
            per_d = p["d2e1"] - p["d1e1"]
            per_e = p["d1e2"] - p["d1e1"]
            const = p["d1e1"] - per_d - per_e
            return const + cfg.n_layers * per_d + cfg.n_enc_layers * per_e
        return v, rec
    v = {"l1": dataclasses.replace(cfg, n_layers=1, **P),
         "l2": dataclasses.replace(cfg, n_layers=2, **P)}

    def rec(p):
        per = p["l2"] - p["l1"]
        return p["l1"] - per + cfg.n_layers * per
    return v, rec


def _extract(compiled) -> Dict[str, Any]:
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):   # older jaxlibs: one dict per device
        cost = cost[0] if cost else {}
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    out = {
        "flops": float(cost.get("flops", -1.0)),
        "bytes_accessed": float(cost.get("bytes accessed", -1.0)),
        "collective_bytes": {k: v for k, v in coll.items() if k != "counts"},
        "collective_counts": coll["counts"],
        # ring-model wire bytes split int8 vs fp32 — the accounting the
        # compressed schedules (--grad-allreduce-bits / --zero-opt) move
        "collective_wire_bytes": wire_bytes_summary(hlo),
    }
    for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                 "output_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes"):
        val = getattr(mem, attr, None)
        if val is not None:
            out[attr] = int(val)
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             probes: bool = True, overrides: Dict[str, Any] = None,
             grad_allreduce_bits: int = None,
             zero_opt: bool = False,
             wire_controller: str = "flexpoint",
             wire_groups: str = "global",
             wire_overlap: bool = False) -> Dict[str, Any]:
    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    mesh, rules = _mesh_and_rules(multi_pod)
    compile_fn = KIND_COMPILERS[shape.kind]
    if shape.kind == "train" and (grad_allreduce_bits is not None or zero_opt):
        import functools
        compile_fn = functools.partial(
            _compile_train, grad_allreduce_bits=grad_allreduce_bits,
            zero_opt=zero_opt, wire_controller=wire_controller,
            wire_groups=wire_groups, wire_overlap=wire_overlap)

    t0 = time.time()
    lowered, compiled = compile_fn(cfg, shape, mesh, rules)
    stats = _extract(compiled)
    stats["compile_seconds"] = round(time.time() - t0, 1)
    stats["mesh"] = "multi" if multi_pod else "single"
    stats["n_devices"] = mesh.devices.size
    stats["arch"], stats["shape"], stats["kind"] = arch, shape_name, shape.kind
    if shape.kind == "train":
        # the precision-domain registry this cell trains under (wire
        # domains appear exactly when the compressed sync would engage;
        # per-layer wire domains report their group count = leaf count);
        # _train_qcfg is the same derivation _compile_train compiled with
        qcfg = _train_qcfg(cfg, mesh, grad_allreduce_bits, zero_opt,
                           wire_controller, wire_groups, wire_overlap)
        plan = qcfg.plan()
        engaged = _engaged_domains(cfg, qcfg, mesh)
        stats["precision_domains"] = {
            n: {"controller": s.controller, "groups": s.groups,
                "stats": s.stream(n), "wire": s.wire,
                "engaged": not s.wire or n in engaged}
            for n, s in plan.domains}
        stats["wire_audit"] = _audit_wire(cfg, qcfg, mesh,
                                          compiled.as_text(), engaged)
        bplan = specs_lib.wire_bucket_plan(cfg, qcfg)
        if bplan is not None:
            stats["wire_buckets"] = {
                "n_buckets": bplan.n_buckets,
                "n_leaves": bplan.n_leaves,
                "target_elems": bplan.target,
                "bucket_elems": [bplan.bucket_elems(b)
                                 for b in range(bplan.n_buckets)],
                "engaged": "wire_grads" in engaged,
            }

    if probes:
        variants, rec = _probe_variants(cfg)
        probe_stats: Dict[str, Dict[str, float]] = {}
        for name, vcfg in variants.items():
            _, c = compile_fn(vcfg, shape, mesh, rules)
            e = _extract(c)
            probe_stats[name] = {
                "flops": e["flops"], "bytes_accessed": e["bytes_accessed"],
                **{f"cb_{k}": v for k, v in e["collective_bytes"].items()},
            }
        keys = next(iter(probe_stats.values())).keys()
        corrected = {}
        for key in keys:
            corrected[key] = rec({n: probe_stats[n][key]
                                  for n in probe_stats})
        stats["corrected"] = corrected
        stats["probes"] = probe_stats
    return stats


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-probes", action="store_true")
    ap.add_argument("--grad-allreduce-bits", type=int, default=None,
                    help="compile train cells with the compressed int8 "
                         "gradient all-reduce requested (engages on pure "
                         "data-parallel meshes; falls back with a warning "
                         "when the mesh has a model axis)")
    ap.add_argument("--zero-opt", action="store_true",
                    help="compile train cells with ZeRO-1 sharded optimizer "
                         "state requested (same pure-data-parallel "
                         "engagement rule as --grad-allreduce-bits)")
    ap.add_argument("--wire-controller", default="flexpoint",
                    help="controller kind for the wire precision domains "
                         "(wire_grads/wire_params) of compressed train "
                         "cells")
    ap.add_argument("--wire-overlap", choices=("on", "off"), default="off",
                    help="compile compressed train cells with the "
                         "backward-overlapped bucketed wire "
                         "(repro.dist.overlap) instead of the monolithic "
                         "collective; same engagement rule as "
                         "--grad-allreduce-bits")
    ap.add_argument("--wire-groups", choices=("per-layer", "global"),
                    default="global",
                    help="wire_grads granularity for compressed train "
                         "cells: 'per-layer' declares one ⟨IL, FL⟩ per "
                         "param leaf ([G] controller state, reported in "
                         "precision_domains)")
    ap.add_argument("--out", default=RESULTS_DIR)
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    cells = []
    archs = ARCH_NAMES if (args.all or args.arch is None) else [args.arch]
    for arch in archs:
        cfg = get_config(arch)
        shapes = ([args.shape] if args.shape
                  else applicable_shapes(cfg))
        for sh in shapes:
            if sh not in applicable_shapes(cfg):
                print(f"SKIP {arch} × {sh}: not applicable "
                      f"(see DESIGN §Arch-applicability)")
                continue
            meshes = {"single": [False], "multi": [True],
                      "both": [False, True]}[args.mesh]
            for mp in meshes:
                cells.append((arch, sh, mp))

    failures = []
    for arch, sh, mp in cells:
        tag = f"{arch}__{sh}__{'multi' if mp else 'single'}"
        out_path = os.path.join(args.out, tag + ".json")
        print(f"=== {tag} ===", flush=True)
        try:
            # probes (FLOP correction) only for the single-pod roofline
            # table; the multi-pod pass proves the "pod" axis shards
            stats = run_cell(arch, sh, mp,
                             probes=not args.no_probes and not mp,
                             grad_allreduce_bits=args.grad_allreduce_bits,
                             zero_opt=args.zero_opt,
                             wire_controller=args.wire_controller,
                             wire_groups=args.wire_groups,
                             wire_overlap=args.wire_overlap == "on")
            with open(out_path, "w") as f:
                json.dump(stats, f, indent=1)
            print(f"  ok: flops={stats['flops']:.3e} "
                  f"bytes={stats['bytes_accessed']:.3e} "
                  f"temp={stats.get('temp_size_in_bytes', 0)/2**30:.2f}GiB "
                  f"({stats['compile_seconds']}s)", flush=True)
        except Exception as e:
            failures.append((tag, repr(e)))
            traceback.print_exc()
    print(f"\n{len(cells) - len(failures)}/{len(cells)} cells compiled")
    for tag, err in failures:
        print(f"FAIL {tag}: {err[:200]}")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
