"""Production mesh construction (functions only — importing this module
never touches jax device state; jax locks the device count on first init).

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the "pod" axis carries
data parallelism across the inter-pod DCI links (collectives on it are the
most expensive — see EXPERIMENTS §Roofline).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    return jax.make_mesh(tuple(shape), tuple(axes))


def make_host_mesh():
    """Whatever devices exist, as a 1-D data mesh (tests / smoke)."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",))
