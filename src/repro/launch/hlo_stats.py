"""Optimized-HLO collective accounting shared by dryrun, benchmarks, tests.

Two views of the same parse:

* :func:`collective_bytes` — per-collective-type max-operand bytes (the
  dry-run's historical metric; kept for the roofline JSON schema).
* :func:`collective_wire_bytes` — per-(op, dtype) **wire** bytes under the
  ring-transfer model: an all-reduce moves ~2× its payload over the
  interconnect (reduce-scatter + all-gather phases), the other collectives
  ~1×.  This is the honest way to compare an fp32 gradient all-reduce
  against the compressed int8 two-leg path (all-to-all + all-gather), and
  what the ``grad_allreduce_bits`` regression test asserts on.
"""

from __future__ import annotations

import re
from typing import Dict

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([\d,]*)\]")
_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
                "u8": 1, "pred": 1, "s64": 8, "u64": 8, "f64": 8, "s16": 2,
                "u16": 2}

# interconnect traversals per payload byte under the ring model
_RING_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _collective_instructions(hlo_text: str):
    """Yield ``(op, [(dtype, bytes), ...])`` per collective instruction."""
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.match(r"%?[\w.\-]+\s*=\s*(.*)", s)
        if not m:
            continue
        rest = m.group(1)
        op = None
        for cand in COLLECTIVE_OPS:
            if re.search(rf"\b{cand}(-start|-done)?\(", rest):
                op = cand
                break
        if op is None or f"{op}-done" in rest:
            continue
        sizes = [(d, _shape_bytes(d, dims))
                 for d, dims in _SHAPE_RE.findall(rest)]
        if sizes:
            yield op, sizes


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-collective-type bytes from optimized HLO (max operand/result
    shape per instruction — the ring-transfer approximation)."""
    out = {k: 0.0 for k in COLLECTIVE_OPS}
    counts = {k: 0 for k in COLLECTIVE_OPS}
    for op, sizes in _collective_instructions(hlo_text):
        out[op] += max(b for _, b in sizes)
        counts[op] += 1
    out["counts"] = counts
    return out


def collective_wire_bytes(hlo_text: str) -> Dict[str, object]:
    """Ring-model wire bytes per (op, dtype) plus totals.

    Returns ``{"by_op_dtype": {op: {dtype: bytes}}, "total": float,
    "by_dtype": {dtype: bytes}}`` where every instruction contributes
    ``ring_factor(op) × max-shape bytes`` under its max-shape dtype.
    """
    by_op: Dict[str, Dict[str, float]] = {}
    by_dtype: Dict[str, float] = {}
    total = 0.0
    for op, sizes in _collective_instructions(hlo_text):
        dtype, nbytes = max(sizes, key=lambda t: t[1])
        wire = _RING_FACTOR[op] * nbytes
        by_op.setdefault(op, {})
        by_op[op][dtype] = by_op[op].get(dtype, 0.0) + wire
        by_dtype[dtype] = by_dtype.get(dtype, 0.0) + wire
        total += wire
    return {"by_op_dtype": by_op, "by_dtype": by_dtype, "total": total}
