"""Optimized-HLO collective accounting shared by dryrun, benchmarks, tests.

Two views of the same parse:

* :func:`collective_bytes` — per-collective-type max-operand bytes (the
  dry-run's historical metric; kept for the roofline JSON schema).
* :func:`collective_wire_bytes` — per-(op, dtype) **wire** bytes under the
  ring-transfer model: an all-reduce moves ~2× its payload over the
  interconnect (reduce-scatter + all-gather phases), the other collectives
  ~1×.  This is the honest way to compare an fp32 gradient all-reduce
  against the compressed int8 two-leg path (all-to-all + all-gather), and
  what the ``grad_allreduce_bits`` regression test asserts on.

Every byte count here flows through ONE instruction-walker
(:func:`_instructions`): each consumer names the opcodes it cares about
and interprets the parsed shapes; there is a single place that decides
what an "instruction line" is.  ``repro.analysis.hlo_audit`` builds its
rule engine on the same walker.
"""

from __future__ import annotations

import re
from typing import Dict, Iterable, Iterator, List, NamedTuple, Tuple

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([\d,]*)\]")
_ASSIGN_RE = re.compile(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*)")
_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
                "u8": 1, "pred": 1, "s64": 8, "u64": 8, "f64": 8, "s16": 2,
                "u16": 2, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
                "f8e4m3fnuz": 1, "f8e5m2fnuz": 1, "f8e4m3b11fnuz": 1,
                "c64": 8, "c128": 16}

# interconnect traversals per payload byte under the ring model
_RING_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    try:
        return n * _DTYPE_BYTES[dtype]
    except KeyError:
        raise ValueError(
            f"unknown HLO dtype {dtype!r} in shape {dtype}[{dims}] — add "
            f"it to repro.launch.hlo_stats._DTYPE_BYTES (guessing a byte "
            f"width would silently corrupt the wire accounting)") from None


class Instruction(NamedTuple):
    """One parsed assignment line whose opcode matched the walker filter.

    ``shapes`` holds every ``(dtype, bytes)`` on the line (result AND any
    spelled-out operand shapes); ``result_shapes`` only those left of the
    opcode token (the result side — a tuple result contributes one entry
    per element).
    """

    op: str
    shapes: Tuple[Tuple[str, int], ...]
    result_shapes: Tuple[Tuple[str, int], ...]
    line: str


def _instructions(hlo_text: str, op_names: Iterable[str]
                  ) -> Iterator[Instruction]:
    """The ONE instruction-walker: yield every assignment whose opcode is
    in ``op_names``.

    Matches ``name = ... <op>(...)`` (``ROOT``-prefixed too) including
    inside fusion/while/branch computation bodies; ``<op>-start`` variants
    count, ``<op>-done`` completions are skipped (their payload was
    already counted at the ``-start``).
    """
    pats = [(op, re.compile(rf"\b{re.escape(op)}(-start|-done)?\("))
            for op in op_names]
    for line in hlo_text.splitlines():
        s = line.strip()
        m = _ASSIGN_RE.match(s)
        if not m:
            continue
        rest = m.group(1)
        for op, pat in pats:
            tok = pat.search(rest)
            if tok is None:
                continue
            if f"{op}-done" in rest:
                break
            shapes = tuple((d, _shape_bytes(d, dims))
                           for d, dims in _SHAPE_RE.findall(rest))
            result = tuple(
                (d, _shape_bytes(d, dims))
                for d, dims in _SHAPE_RE.findall(rest[:tok.start()]))
            yield Instruction(op, shapes, result, s)
            break


def _collective_instructions(hlo_text: str):
    """Yield ``(op, dtype, payload_bytes)`` per collective instruction.

    Payload = max shape on the instruction (covers the full-tensor side of
    an all-reduce / all-gather / reduce-scatter) — except ``all-to-all``,
    whose CPU lowering decomposes into a tuple of per-rank chunks
    ``(s8[1,c], ...×n) all-to-all(...)``; there the payload is the *sum*
    of the result-tuple shapes (equal to the single-array form's full
    shape), not one chunk.
    """
    for ins in _instructions(hlo_text, COLLECTIVE_OPS):
        if not ins.shapes:
            continue
        if ins.op == "all-to-all":
            use = ins.result_shapes or ins.shapes
            yield ins.op, use[0][0], float(sum(b for _, b in use))
        else:
            dtype, nbytes = max(ins.shapes, key=lambda t: t[1])
            yield ins.op, dtype, float(nbytes)


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-collective-type payload bytes from optimized HLO (the
    ring-transfer approximation)."""
    out = {k: 0.0 for k in COLLECTIVE_OPS}
    counts = {k: 0 for k in COLLECTIVE_OPS}
    for op, _, nbytes in _collective_instructions(hlo_text):
        out[op] += nbytes
        counts[op] += 1
    out["counts"] = counts
    return out


def collective_wire_bytes(hlo_text: str) -> Dict[str, object]:
    """Ring-model wire bytes per (op, dtype) plus totals.

    Returns ``{"by_op_dtype": {op: {dtype: bytes}}, "total": float,
    "by_dtype": {dtype: bytes}}`` where every instruction contributes
    ``ring_factor(op) × payload bytes`` (see
    :func:`_collective_instructions`) under its payload dtype.
    """
    by_op: Dict[str, Dict[str, float]] = {}
    by_dtype: Dict[str, float] = {}
    total = 0.0
    for op, dtype, nbytes in _collective_instructions(hlo_text):
        wire = _RING_FACTOR[op] * nbytes
        by_op.setdefault(op, {})
        by_op[op][dtype] = by_op[op].get(dtype, 0.0) + wire
        by_dtype[dtype] = by_dtype.get(dtype, 0.0) + wire
        total += wire
    return {"by_op_dtype": by_op, "by_dtype": by_dtype, "total": total}


def op_bytes(hlo_text: str, op_name: str) -> Dict[str, object]:
    """Result bytes of every ``op_name`` instruction, split by dtype.

    Parses optimized HLO (fusion bodies included) for lines of the form
    ``%x = <dtype>[dims] <op_name>(...)`` and sums the result-shape bytes
    per dtype.  Returns ``{"by_dtype": {dtype: bytes}, "total": float,
    "count": int}``.  The headline consumer is the no-fp32-flat-concat
    guarantee of the rebuilt ``dps_allreduce_mean_tree``: a compiled tree
    all-reduce must show (near-)zero ``f32`` ``concatenate`` bytes — the
    leaves are encoded straight into the preallocated int8 wire buffer.
    """
    by_dtype: Dict[str, float] = {}
    count = 0
    for ins in _instructions(hlo_text, (op_name,)):
        if not ins.result_shapes:
            continue
        dtype, nbytes = ins.result_shapes[0]
        by_dtype[dtype] = by_dtype.get(dtype, 0.0) + nbytes
        count += 1
    return {"by_dtype": by_dtype,
            "total": float(sum(by_dtype.values())), "count": count}


def concat_bytes(hlo_text: str) -> Dict[str, object]:
    """:func:`op_bytes` for ``concatenate`` — the fp32 flat-concat probe."""
    return op_bytes(hlo_text, "concatenate")


def wire_bytes_summary(hlo_text: str) -> Dict[str, float]:
    """Compact int8-vs-fp32 view of :func:`collective_wire_bytes`.

    The headline accounting for the compressed collective schedules
    (``grad_allreduce_bits`` / ``zero_opt_shards``): how many ring-model
    wire bytes ride the int8 payload vs fp32, and the int8 fraction of the
    total.  Used by the dry-run's per-cell JSON and ``benchmarks/bench_zero``.
    """
    w = collective_wire_bytes(hlo_text)
    int8 = w["by_dtype"].get("s8", 0.0) + w["by_dtype"].get("u8", 0.0)
    fp32 = w["by_dtype"].get("f32", 0.0)
    total = w["total"]
    return {"total": total, "int8": int8, "fp32": fp32,
            "int8_fraction": (int8 / total) if total else 0.0}
