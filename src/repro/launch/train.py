"""Training driver: quantized (DPS) training with fault tolerance.

Production behaviors implemented here:
  * auto-resume from the newest complete checkpoint (``--resume``) —
    ``latest_step`` digest-verifies and walks past torn/corrupt step dirs,
  * atomic async checkpointing every ``--ckpt-every`` steps,
  * elastic restart — the checkpoint is mesh-agnostic, restore re-shards
    onto whatever mesh this invocation builds (different device count OK),
  * graceful pre-emption: SIGTERM/SIGINT checkpoints on the way down and
    exits 0 (a scheduler eviction is not a failure),
  * numeric health guards (``--guards``: repro.resilience in-step monitor,
    skip gate, fp32 wire degradation) plus a host-side loss-spike rollback
    ring (``--rollback-ring K``): the last K healthy train states are kept
    in host memory and a median-filtered loss spike rolls back to the
    newest one and forces the wire into its fp32 fallback for a cooldown,
  * failure injection (``--fail-at N`` crash, ``--inject-*-at N`` numeric
    faults, ``--sigterm-at N`` pre-emption) to exercise every recovery
    path in CI,
  * straggler/step watchdog: a step exceeding ``--step-timeout`` seconds
    raises, the driver checkpoints on the way down.

Smoke scale (CPU container):
  PYTHONPATH=src python -m repro.launch.train --arch llama3_2_3b --smoke \
      --steps 20 --batch 4 --seq 64
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import signal
import time
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import AsyncCheckpointer, latest_step, restore
from repro.configs.base import get_config, smoke as smoke_cfg
from repro.core import qtrain
from repro.data import TokenStream, TokenStreamConfig
from repro.dist.sharding import DEFAULT_RULES, LogicalRules, axis_rules
from repro.launch import specs as specs_lib
from repro.models import registry
from repro.models.common import init_params
from repro.optim import AdamWConfig, SGDConfig, make_optimizer


def _to_host(x):
    """Rollback-ring entry leaf: host numpy (PRNG keys via key_data)."""
    if (hasattr(x, "dtype")
            and jax.dtypes.issubdtype(x.dtype, jax.dtypes.prng_key)):
        return np.asarray(jax.random.key_data(x))
    return np.asarray(x)


def _from_host(arr, like):
    """Inverse of :func:`_to_host` against a template leaf.  Plain arrays
    stay host-side/uncommitted — the jitted step's ``in_shardings`` place
    them, so a rolled-back state reshards exactly like a restore."""
    if jax.dtypes.issubdtype(like.dtype, jax.dtypes.prng_key):
        return jax.random.wrap_key_data(jnp.asarray(arr))
    return np.asarray(arr, like.dtype)


def build(cfg, qcfg, opt_cfg, mesh=None, faults=None):
    opt = make_optimizer(opt_cfg)
    step_fn = specs_lib.build_train_step(cfg, qcfg, opt, mesh=mesh,
                                         faults=faults)
    if mesh is not None:
        if (getattr(step_fn, "wire_sync_active", False)
                or getattr(step_fn, "zero_opt_active", False)):
            # compressed all-reduce / ZeRO-1 = classic data parallelism:
            # params replicate across the data axis (the shard_map pins them
            # to P()); binding "fsdp" would re-gather every leaf per step.
            # Under ZeRO the *optimizer state* shards instead, via the flat
            # P("data") layout in train_state_shardings.
            rules = LogicalRules(rules=tuple(
                r for r in DEFAULT_RULES if r[0] != "fsdp"))
        else:
            rules = LogicalRules()
        state_sh = specs_lib.train_state_shardings(cfg, mesh, rules, opt, qcfg)
        jitted = jax.jit(step_fn, in_shardings=(state_sh, None),
                         out_shardings=(state_sh, None), donate_argnums=(0,))
    else:
        jitted = jax.jit(step_fn, donate_argnums=(0,))
    return opt, jitted


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--optimizer", choices=("sgd", "adamw"), default="adamw")
    ap.add_argument("--controller", default="paper",
                    help="DPS controller (paper|courbariaux|na_mukhopadhyay|"
                         "static|flexpoint) or 'off'")
    ap.add_argument("--grad-allreduce-bits", type=int, default=None,
                    help="compress the gradient all-reduce to an int8 wire "
                         "of this many grid bits (2-8); builds a data-axis "
                         "mesh over all local devices and feeds the wire "
                         "QuantStats into the dedicated wire_grads DPS "
                         "domain")
    ap.add_argument("--wire-controller",
                    default=os.environ.get("REPRO_WIRE_CONTROLLER")
                    or "flexpoint",
                    help="DPS controller kind for the wire precision "
                         "domains (wire_grads/wire_params); 'flexpoint' "
                         "(default) drives the wire radix from max|x|, "
                         "immune to the hair-trigger r_max IL ratchet "
                         "(see dist/README.md)")
    ap.add_argument("--wire-groups", choices=("per-layer", "global"),
                    default=os.environ.get("REPRO_WIRE_GROUPS")
                    or "per-layer",
                    help="granularity of the wire_grads ⟨IL, FL⟩: "
                         "'per-layer' (default) runs one format per "
                         "gradient leaf through the group-aligned "
                         "collectives ([G, 2] kernel format table); "
                         "'global' keeps the single shared wire format. "
                         "Composes with --zero-opt: the flat optimizer "
                         "layout switches to the group-aligned "
                         "partitioner, so per-leaf formats survive the "
                         "flatten and both sharded legs run the grouped "
                         "codec.  Resume with the same choice — the "
                         "wire_grads (and under ZeRO wire_params) ckpt "
                         "state is [G]-shaped under per-layer")
    ap.add_argument("--wire-overlap", choices=("on", "off"),
                    default=os.environ.get("REPRO_WIRE_OVERLAP") or "off",
                    help="backward-overlapped bucketed wire: split the "
                         "gradient tree into buckets and run one "
                         "compressed collective pair per bucket in "
                         "backward ready order (repro.dist.overlap), "
                         "instead of one monolithic pair after the full "
                         "backward.  Needs --grad-allreduce-bits.  "
                         "Composes with --zero-opt: the group-aligned "
                         "layout runs one int8 reduce-scatter per bucket "
                         "in the same backward-ready order")
    ap.add_argument("--wire-auto-slack", action="store_true",
                    default=bool(os.environ.get("REPRO_WIRE_AUTO_SLACK")),
                    help="derive each wire domain's radix headroom from "
                         "its measured abs_sum/nonzero tail quantile "
                         "(dps.wire_hyper(auto_slack=True)) instead of "
                         "the hand-tuned per-tensor-class slack "
                         "constants")
    ap.add_argument("--zero-opt", action="store_true",
                    help="ZeRO-1: shard the optimizer state across the "
                         "data axis (flat padded layout, 1/n state bytes "
                         "per device); combined with --grad-allreduce-bits "
                         "both the gradient reduce-scatter and the param "
                         "all-gather ride the int8 wire")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--guards", action="store_true",
                    help="arm the repro.resilience health guards: in-step "
                         "NaN/overflow/spike detection, skip gate, and "
                         "graceful int8-wire -> fp32 degradation with "
                         "cooldown re-arm")
    ap.add_argument("--guard-cooldown", type=int, default=16,
                    help="clean steps before a degraded wire domain "
                         "re-arms its int8 codec")
    ap.add_argument("--rollback-ring", type=int, default=0,
                    help="keep the last K healthy train states in host "
                         "memory (snapshotted at log points) and roll "
                         "back to the newest one on a median-filtered "
                         "loss spike; 0 disables")
    ap.add_argument("--rollback-spike", type=float, default=10.0,
                    help="drained loss > this factor times the median of "
                         "the recent drained losses triggers a rollback")
    ap.add_argument("--fail-at", type=int, default=0,
                    help="inject a crash after N steps (restart test)")
    ap.add_argument("--sigterm-at", type=int, default=0,
                    help="send SIGTERM to this process after N steps "
                         "(pre-emption test: checkpoint + exit 0)")
    ap.add_argument("--inject-nan-at", type=int, default=-1,
                    help="fault injection: NaN gradients at this step")
    ap.add_argument("--inject-storm-at", type=int, default=-1,
                    help="fault injection: overflow-storm gradient scale "
                         "starting at this step")
    ap.add_argument("--inject-wire-flip-at", type=int, default=-1,
                    help="fault injection: XOR a bit into the int8 wire "
                         "payload at this step")
    ap.add_argument("--step-timeout", type=float, default=0.0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_cfg(cfg)
    n_dev = jax.device_count()
    zero_shards = n_dev if (args.zero_opt and n_dev > 1) else None
    guards = None
    if args.guards:
        from repro.resilience import GuardConfig
        guards = GuardConfig(cooldown=args.guard_cooldown)
    faults = None
    if (args.inject_nan_at >= 0 or args.inject_storm_at >= 0
            or args.inject_wire_flip_at >= 0):
        from repro.resilience import FaultPlan
        faults = FaultPlan(nan_grads_at=args.inject_nan_at,
                           overflow_storm_at=args.inject_storm_at,
                           wire_flip_at=args.inject_wire_flip_at)
    qcfg = qtrain.QuantConfig(enabled=args.controller != "off",
                              controller=args.controller
                              if args.controller != "off" else "paper",
                              grad_allreduce_bits=args.grad_allreduce_bits,
                              zero_opt_shards=zero_shards,
                              wire_controller=args.wire_controller,
                              wire_overlap=args.wire_overlap == "on",
                              wire_auto_slack=args.wire_auto_slack,
                              guards=guards)
    if args.wire_groups == "per-layer":
        # one wire ⟨IL, FL⟩ per gradient leaf; the group count derives
        # from the abstract param tree so the plan (and with it the DPS
        # checkpoint layout) is fixed before any tensor exists.  Under
        # --zero-opt this selects the group-aligned flat layout too.
        qcfg = specs_lib.per_layer_wire_qcfg(cfg, qcfg)
    opt_cfg = (AdamWConfig(total_steps=args.steps) if args.optimizer == "adamw"
               else SGDConfig())
    mesh = None
    if (args.grad_allreduce_bits is not None or zero_shards) and n_dev > 1:
        # a pure data-parallel mesh over every local device — the regime the
        # compressed all-reduce and ZeRO-1 target.  On one device qtrain
        # degrades both paths to the replicated step, so no mesh is built.
        mesh = jax.make_mesh((n_dev,), ("data",))
    opt, jitted = build(cfg, qcfg, opt_cfg, mesh=mesh, faults=faults)

    mod = registry(cfg.family)
    data = TokenStream(TokenStreamConfig(vocab=cfg.vocab, seq_len=args.seq,
                                         global_batch=args.batch,
                                         seed=args.seed))

    start = 0
    ckpt = None
    if args.ckpt_dir:
        ckpt = AsyncCheckpointer(args.ckpt_dir)
    if args.resume and args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
        start = latest_step(args.ckpt_dir)
        template = specs_lib.abstract_train_state(cfg, opt, qcfg, mesh=mesh)
        # legacy checkpoints carry only the three-key compute DPS bundle;
        # domains the plan adds (e.g. wire_grads/wire_params) and the
        # guard subtree init fresh when the checkpoint predates them.
        defaults = qtrain.dps_restore_defaults(qcfg)
        defaults.update(qtrain.guard_restore_defaults(qcfg))
        state, meta = restore(args.ckpt_dir, start, template,
                              defaults=defaults)
        print(f"resumed from step {start} (data cursor {meta.get('cursor')})")
    else:
        params = init_params(jax.random.key(args.seed), mod.model_defs(cfg))
        if qtrain.zero_opt_engaged(qcfg, mesh):
            opt_state = qtrain.zero_opt_state(opt, params, zero_shards,
                                              qcfg=qcfg)
        else:
            opt_state = opt.init(params)
        state = qtrain.TrainState.create(params, opt_state, qcfg,
                                         jax.random.key(args.seed + 1))

    extras = {}
    if cfg.family == "encdec":
        extras["frames"] = jnp.zeros((args.batch, cfg.enc_seq, cfg.d_model),
                                     jnp.float32)
    if cfg.family == "vlm":
        extras["vision_embeds"] = jnp.zeros(
            (args.batch, cfg.n_patches, cfg.d_model), jnp.float32)

    history = []
    pending = []   # device-side metrics, fetched in batch at the log points

    def _drain():
        """One host sync for the whole pending window.  The step loop
        never blocks on metrics per step (the fetch/format transfer used
        to dominate small-step walltime); everything since the last log
        point converts to floats here in a single transfer burst."""
        for m in pending:
            history.append({k: float(v) for k, v in m.items()})
        pending.clear()

    # graceful pre-emption: the handler only sets a flag; the loop
    # checkpoints on the way down and exits 0 (eviction is not a failure)
    stop = {"sig": None}
    old_handlers = {
        s: signal.signal(s, lambda signum, frame: stop.update(sig=signum))
        for s in (signal.SIGTERM, signal.SIGINT)}

    # rollback ring: (step, host snapshot) of the last K healthy states,
    # refreshed at log points — the only places the host looks at metrics
    # anyway, so the ring adds no extra device syncs
    ring = deque(maxlen=max(args.rollback_ring, 1))
    loss_hist = deque(maxlen=256)   # healthy drained losses (median filter)
    rollbacks = 0

    def _force_degrade(st):
        """Post-rollback: hold every wire domain in its fp32 fallback for
        a full cooldown so the replayed window cannot re-trip on the same
        storm (the rollback+degrade response)."""
        if getattr(st, "guard", None) is None or st.guard.degraded.size == 0:
            return st
        g = dataclasses.replace(
            st.guard, degraded=jnp.ones_like(st.guard.degraded),
            cooldown=jnp.full_like(st.guard.cooldown, args.guard_cooldown))
        return dataclasses.replace(st, guard=g)

    try:
        step = start
        while step < args.steps:
            if stop["sig"] is not None:
                if ckpt:
                    ckpt.save(step, state, meta=data.state(step))
                    ckpt.wait()
                _drain()
                print(f"PREEMPTED: signal {stop['sig']} "
                      f"(checkpointed at step {step}); exiting cleanly",
                      flush=True)
                return history
            batch = {**data.batch(step), **extras}
            t0 = time.time()
            state, metrics = jitted(state, batch)
            if args.step_timeout:
                # the straggler watchdog needs the REAL step walltime, so
                # it opts back into the per-step device sync the deferred
                # metrics path exists to avoid
                jax.block_until_ready(metrics)
            dt = time.time() - t0
            if args.step_timeout and dt > args.step_timeout and step > start:
                raise TimeoutError(
                    f"step {step} took {dt:.1f}s > {args.step_timeout}s "
                    "(straggler watchdog)")
            pending.append(metrics)
            if step % args.log_every == 0 or step == args.steps - 1:
                window_at = len(history)
                _drain()
                window = history[window_at:]
                metrics = history[-1]
                # wire precision domains log alongside the compute triple;
                # per-layer (grouped) wire domains show mean(min-max) so
                # the per-group spread is visible in the train log
                def _wfmt(dom):
                    il, fl = metrics[f"il_{dom}"], metrics[f"fl_{dom}"]
                    if f"il_{dom}_min" in metrics:
                        return (f"<{il:.1f}({metrics[f'il_{dom}_min']:.0f}-"
                                f"{metrics[f'il_{dom}_max']:.0f}),"
                                f"{fl:.1f}({metrics[f'fl_{dom}_min']:.0f}-"
                                f"{metrics[f'fl_{dom}_max']:.0f})> ")
                    return f"<{il:.0f},{fl:.0f}> "

                wire = "".join(
                    tag + _wfmt(dom)
                    for tag, dom in (("wg", "wire_grads"),
                                     ("wp", "wire_params"))
                    if f"il_{dom}" in metrics)
                health = ""
                if metrics.get("health"):
                    from repro.resilience import health_flags
                    health = " !" + ",".join(
                        health_flags(int(metrics["health"])))
                print(f"step {step:5d} loss {metrics['loss']:8.4f} "
                      f"w<{metrics['il_w']:.0f},{metrics['fl_w']:.0f}> "
                      f"a<{metrics['il_a']:.0f},{metrics['fl_a']:.0f}> "
                      f"g<{metrics['il_g']:.0f},{metrics['fl_g']:.0f}> "
                      f"{wire}"
                      f"E_a {metrics['E_a']:.2e} R_a {metrics['R_a']:.2e}"
                      f"{health}", flush=True)
                if args.rollback_ring:
                    losses = [h["loss"] for h in window]
                    bad = any(not np.isfinite(l) for l in losses)
                    med = (float(np.median(loss_hist))
                           if len(loss_hist) >= 4 else None)
                    spiked = bad or (
                        med is not None and med > 0
                        and max(losses) > args.rollback_spike * med)
                    if spiked and ring and rollbacks < 8:
                        snap_step, snap = ring[-1]
                        state = _force_degrade(
                            jax.tree.map(_from_host, snap, state))
                        rollbacks += 1
                        print(f"ROLLBACK: loss spike at step {step} "
                              f"(median {med}), resuming from step "
                              f"{snap_step} with wire degraded", flush=True)
                        step = snap_step
                        continue
                    if not spiked:
                        loss_hist.extend(losses)
                        ring.append(
                            (step + 1, jax.tree.map(_to_host, state)))
            if ckpt and (step + 1) % args.ckpt_every == 0:
                ckpt.save(step + 1, state, meta=data.state(step + 1))
            if args.fail_at and step + 1 >= args.fail_at:
                raise RuntimeError(f"injected failure at step {step + 1}")
            if (args.sigterm_at and step + 1 >= args.sigterm_at
                    and stop["sig"] is None):
                # pre-emption drill: deliver a real SIGTERM to ourselves;
                # the handler + loop-top path take it from here
                os.kill(os.getpid(), signal.SIGTERM)
            step += 1
    except (TimeoutError, RuntimeError) as e:
        # crash path: persist progress before going down (exit 17 tells
        # the harness this was a FAILURE, unlike the pre-emption exit 0)
        if ckpt:
            ckpt.save(step + 1, state, meta=data.state(step + 1))
            ckpt.wait()
        print(f"ABORT: {e} (checkpointed at step {step + 1})")
        raise SystemExit(17)
    finally:
        for s, h in old_handlers.items():
            signal.signal(s, h)
        if ckpt:
            ckpt.wait()

    if ckpt:
        ckpt.save(args.steps, state, meta=data.state(args.steps))
        ckpt.wait()
    _drain()
    out = {"final_loss": history[-1]["loss"] if history else None,
           "history_tail": history[-5:]}
    print(json.dumps(out, indent=1))
    return history


if __name__ == "__main__":
    main()
