"""Serving driver: batched prefill + greedy decode with a quantized KV cache.

Implements the inference side of the framework: continuous batches of
requests are prefillled once, then decoded step-by-step with the KV cache
donated through each step (no reallocation).  With ``--quant-kv`` the cache
values are snapped to the DPS activation grid at write time — the paper's
quantizer applied to serving state (beyond-paper; halves cache HBM at
⟨8,8⟩).

Smoke scale (CPU container):
  PYTHONPATH=src python -m repro.launch.serve --arch llama3_2_3b --smoke \
      --batch 4 --prompt-len 16 --gen 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, smoke as smoke_cfg
from repro.core import fixed_point as fxp
from repro.core.dps import DomainSpec, DPSHyper, PrecisionPlan
from repro.launch import specs as specs_lib
from repro.models import registry
from repro.models.common import init_params


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--quant-kv", action="store_true")
    ap.add_argument("--kv-format", default="8,8",
                    help="IL,FL of the kv_cache precision domain used by "
                         "--quant-kv (static controller; <8,8> halves "
                         "cache HBM)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_cfg(cfg)
    mod = registry(cfg.family)
    params = init_params(jax.random.key(args.seed), mod.model_defs(cfg))
    max_seq = args.prompt_len + args.gen

    key = jax.random.key(args.seed + 1)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab)
    extras = {}
    if cfg.family == "encdec":
        extras["frames"] = jax.random.normal(
            jax.random.fold_in(key, 1), (args.batch, cfg.enc_seq, cfg.d_model))
    if cfg.family == "vlm":
        extras["vision_embeds"] = jax.random.normal(
            jax.random.fold_in(key, 2),
            (args.batch, cfg.n_patches, cfg.d_model))

    t0 = time.time()
    logits, cache, pos = jax.jit(
        lambda p, t: mod.prefill(cfg, p, t, max_seq, **extras))(params, prompts)
    t_prefill = time.time() - t0

    # serving-side precision domain: the KV cache runs its own registry
    # entry (static by default — serving has no train-step feedback loop to
    # drive a dynamic controller; swap the kind here if one appears).
    kv_il, kv_fl = (int(t) for t in args.kv_format.split(","))
    plan = PrecisionPlan.of(kv_cache=DomainSpec(
        "static", DPSHyper(il_init=kv_il, fl_init=kv_fl)))
    kv_bundle = plan.init()
    qfmt = plan.formats(kv_bundle)["kv_cache"]
    if args.quant_kv:
        print(f"kv_cache domain: {plan.spec('kv_cache').controller} "
              f"<{kv_il},{kv_fl}>")

    @jax.jit
    def step(params, tok, cache, pos, key):
        logits, cache = mod.decode_step(cfg, params, tok, cache, pos)
        if args.quant_kv:
            cache = jax.tree.map(
                lambda c: fxp.quantize(c, qfmt, mode="stochastic",
                                       key=key, compute_stats=False)[0]
                if c.ndim >= 3 and c.dtype != jnp.int32 else c, cache)
        return jnp.argmax(logits, -1).astype(jnp.int32)[:, None], cache, pos + 1

    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    out_toks = [tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        tok, cache, pos = step(params, tok, cache, pos,
                               jax.random.fold_in(key, 100 + i))
        out_toks.append(tok)
    toks = jnp.concatenate(out_toks, axis=1)
    t_decode = time.time() - t0
    tput = args.batch * (args.gen - 1) / max(t_decode, 1e-9)

    print(f"prefill {args.batch}×{args.prompt_len} in {t_prefill:.3f}s")
    print(f"decode  {args.gen - 1} steps: {t_decode:.3f}s "
          f"({tput:.1f} tok/s{' quant-kv' if args.quant_kv else ''})")
    print("sample:", np.asarray(toks[0])[:16].tolist())
    return toks


if __name__ == "__main__":
    main()
