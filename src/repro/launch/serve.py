"""Serving driver: the continuous-batching engine on a synthetic user trace.

Drives :mod:`repro.serve` — prefill/decode split, strict-FCFS admission
into free batch slots, paged int8 KV cache under per-page ⟨IL, FL⟩ from
the ``kv_cache`` precision domain, fused paged decode attention.  The
trace is many users with mixed prompt/generation lengths and Poisson
arrivals, so slots churn: the engine retires finished rows and admits new
requests without recompiling (page tables and positions are step inputs).

Smoke scale (CPU container):
  PYTHONPATH=src python -m repro.launch.serve --arch llama3_2_3b --smoke \
      --requests 8 --slots 4 --page-size 4
"""

from __future__ import annotations

import argparse
from collections import Counter

import numpy as np
import jax

from repro.configs.base import get_config, smoke as smoke_cfg
from repro.models import registry
from repro.models.common import init_params
from repro.serve import (Engine, EngineConfig, PagedLayout, supports_paging,
                         synthetic_trace)


def build_layout(args) -> PagedLayout:
    ps = args.page_size
    max_prompt = -(-args.max_prompt // ps) * ps     # round up to a page
    prompt_pages = max_prompt // ps
    pages_per_seq = max(prompt_pages + -(-args.max_new // ps) + 1,
                        args.pages_per_seq)
    n_pages = args.pages or max(args.slots * pages_per_seq,
                                2 * prompt_pages)
    return PagedLayout(page_size=ps, n_pages=n_pages,
                       batch_slots=args.slots,
                       max_pages_per_seq=pages_per_seq,
                       max_prompt=max_prompt)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8,
                    help="synthetic trace length (distinct users)")
    ap.add_argument("--slots", type=int, default=4,
                    help="concurrent decode rows (compiled batch)")
    ap.add_argument("--page-size", type=int, default=4,
                    help="tokens per KV page (one page = one <IL,FL> group)")
    ap.add_argument("--pages", type=int, default=0,
                    help="pool size in pages (0 = derive from slots)")
    ap.add_argument("--pages-per-seq", type=int, default=0,
                    help="page-table width floor per row")
    ap.add_argument("--max-prompt", type=int, default=16,
                    help="compiled prompt length ceiling")
    ap.add_argument("--max-new", type=int, default=16,
                    help="trace generation-length ceiling")
    ap.add_argument("--min-prompt", type=int, default=4)
    ap.add_argument("--min-new", type=int, default=4)
    ap.add_argument("--mean-gap", type=float, default=0.5,
                    help="mean inter-arrival gap in engine steps")
    ap.add_argument("--kv-bits", default="8",
                    help="8 = int8 DPS pages; none = fp32 pages (parity "
                         "baseline)")
    ap.add_argument("--serial", action="store_true",
                    help="one request at a time (continuous batching off)")
    ap.add_argument("--attn-backend", default="auto",
                    choices=["auto", "kernel", "jnp"])
    ap.add_argument("--encode-backend", default="auto",
                    choices=["auto", "kernel", "jnp"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_cfg(cfg)
    if not supports_paging(cfg):
        raise SystemExit(f"{cfg.name}: family {cfg.family!r} has no paged "
                         f"decode path (GQA models only)")
    mod = registry(cfg.family)
    params = init_params(jax.random.key(args.seed), mod.model_defs(cfg))

    layout = build_layout(args)
    kv_bits = None if args.kv_bits.lower() in ("none", "0", "32") else \
        int(args.kv_bits)
    eng = Engine(cfg, params, EngineConfig(
        layout=layout, kv_bits=kv_bits, attn_backend=args.attn_backend,
        encode_backend=args.encode_backend,
        max_concurrency=1 if args.serial else None))

    reqs = synthetic_trace(
        args.requests, cfg.vocab,
        prompt_lens=(args.min_prompt, min(args.max_prompt,
                                          layout.max_prompt)),
        new_tokens=(args.min_new, args.max_new),
        mean_gap=args.mean_gap, seed=args.seed + 1)
    report = eng.run(reqs)

    m = report.metrics
    bits = "int8" if kv_bits == 8 else "fp32"
    mode = "serial" if args.serial else "continuous"
    print(f"layout: {layout.n_pages} pages × {layout.page_size} tok, "
          f"{layout.batch_slots} slots ({mode}, {bits} pages, "
          f"attn={eng._attn_backend}, encode={eng._enc_backend})")
    print(f"served {len(reqs)} requests, {int(m['total_tokens'])} tokens "
          f"in {m['wall_s']:.3f}s -> {m['tokens_per_s']:.1f} tok/s")
    print(f"decode: {int(m['decode_steps'])} steps, mean occupancy "
          f"{m['mean_occupancy']:.2f}/{layout.batch_slots}, per-token "
          f"p50 {m['p50_ms_per_token']:.2f}ms p95 {m['p95_ms_per_token']:.2f}ms")
    if report.format_spread:
        spread = Counter(report.format_spread)
        total = sum(spread.values())
        pretty = ", ".join(f"{k}:{v}" for k, v in spread.most_common())
        print(f"per-page <IL,FL> spread over {total} live page-rows: "
              f"{pretty}")
    sample = report.tokens[reqs[0].rid]
    print("sample:", np.asarray(sample)[:16].tolist())
    return report


if __name__ == "__main__":
    main()
