"""nemotron-4-340b [dense] — 96L d=18432 96H (GQA kv=8) ff=73728 V=256000.
Squared-ReLU, non-gated MLP. [arXiv:2402.16819; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b", family="dense",
    n_layers=96, d_model=18432, n_heads=96, n_kv_heads=8, head_dim=192,
    d_ff=73728, vocab=256000, act="relu2", gated_mlp=False,
    rope_theta=10000.0, tie_embed=False,
    train_accum=8,
)
