"""internvl2-26b [vlm] — internlm2-20b backbone: 48L d=6144 48H (GQA kv=8)
ff=16384 V=92553; InternViT frontend STUBBED (patch embeddings arrive
precomputed, 256 vision tokens). [arXiv:2404.16821; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b", family="vlm",
    n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=16384, vocab=92553, act="silu", gated_mlp=True,
    rope_theta=1000000.0, tie_embed=False,
    n_patches=256,
    train_accum=2,
)
