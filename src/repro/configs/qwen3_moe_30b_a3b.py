"""qwen3-moe-30b-a3b [moe] — 48L d=2048 32H (GQA kv=4) V=151936,
128 experts top-8, expert ff=768. [hf:Qwen/Qwen3-30B-A3B; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4, head_dim=128,
    d_ff=0, vocab=151936, act="silu", gated_mlp=True,
    rope_theta=1000000.0, tie_embed=True,
    n_experts=128, top_k=8, moe_d_ff=768, capacity_factor=1.25,
)
