"""deepseek-v2-236b [moe] — 60L d=5120 128H MLA (kv_lora=512, rope=64),
2 shared + 160 routed experts top-6, expert ff=1536, V=102400.
[arXiv:2405.04434; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b", family="moe",
    n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128, head_dim=128,
    d_ff=0, vocab=102400, act="silu", gated_mlp=True,
    rope_theta=10000.0, tie_embed=False,
    n_experts=160, n_shared_experts=2, top_k=6, moe_d_ff=1536,
    capacity_factor=1.25,
    mla=True, q_lora_rank=1536, kv_lora_rank=512,
    qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
    train_accum=4,
)
