"""mamba2-1.3b [ssm] — 48L d=2048 attn-free, SSD state=128, V=50280.
[arXiv:2405.21060; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=0, n_kv_heads=0, head_dim=0,
    d_ff=0, vocab=50280, act="silu",
    rope_theta=0.0, tie_embed=True,
    ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_chunk=256,
    supports_long=True,
    train_accum=2,
)
