"""gemma-7b [dense] — 28L d=3072 16H (MHA kv=16) ff=24576 V=256000.
GeGLU, head_dim=256. [arXiv:2403.08295; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b", family="dense",
    n_layers=28, d_model=3072, n_heads=16, n_kv_heads=16, head_dim=256,
    d_ff=24576, vocab=256000, act="gelu", gated_mlp=True,
    rope_theta=10000.0, tie_embed=True,
    train_accum=2,
)
