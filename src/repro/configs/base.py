"""Config schema: model architecture + input shapes + parallelism + quant.

Every assigned architecture is one ``ModelConfig`` in its own module under
``repro.configs``; ``get_config(name)`` is the registry entry point and
``smoke()`` derives the reduced-size variant used by CPU tests.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    act: str = "silu"
    gated_mlp: bool = True
    attn_bias: bool = False
    rope_theta: float = 10000.0
    tie_embed: bool = True
    norm: str = "rms"              # rms | layer
    # --- MoE ---
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # --- MLA (deepseek-v2) ---
    mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    # --- SSM (mamba2 / zamba2) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    ssm_conv: int = 4
    hybrid_period: int = 0         # shared attn block after every k SSM blocks
    # --- encoder-decoder (whisper) ---
    n_enc_layers: int = 0
    enc_seq: int = 0               # encoder context (frame embeddings)
    # --- VLM (internvl2) ---
    n_patches: int = 0             # vision embeds prepended to the sequence
    # --- numerics / training ---
    dtype: str = "bfloat16"        # compute dtype for LM-scale runs
    param_dtype: str = "float32"
    remat: str = "full"            # full | dots | none
    train_accum: int = 1           # gradient-accumulation microbatches
    kv_cache_bits: int = 16        # 16 = bf16 cache; 8 = int8 DPS-grid cache
    probe_unroll: bool = False     # dry-run FLOP probes: unroll all scans so
                                   # cost_analysis counts every iteration
    attn_batch2d: bool = False     # non-divisible-heads attention: shard the
                                   # batch over (data × model) instead of
                                   # replicating K/V on the model axis
    moe_a2a_bits: int = 16         # 8 = int8 DPS-grid MoE dispatch payload
    # --- shape applicability ---
    supports_long: bool = False    # sub-quadratic path exists (ssm / hybrid)

    @property
    def d_head_q(self) -> int:
        return self.head_dim

    def activation_dtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32

    def n_params(self) -> float:
        """Analytic parameter count (for 6ND roofline math)."""
        from repro.models import registry
        return registry(self.family).count_params(self)

    def n_active_params(self) -> float:
        from repro.models import registry
        mod = registry(self.family)
        if hasattr(mod, "count_active_params"):
            return mod.count_active_params(self)
        return self.n_params()


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str          # train | prefill | decode
    seq: int
    batch: int


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}

ARCH_NAMES = (
    "llama3_2_3b", "mistral_large_123b", "nemotron_4_340b", "gemma_7b",
    "zamba2_7b", "internvl2_26b", "whisper_medium", "qwen3_moe_30b_a3b",
    "deepseek_v2_236b", "mamba2_1_3b",
)


def get_config(name: str) -> ModelConfig:
    name = name.replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{name}")
    return mod.CONFIG


def applicable_shapes(cfg: ModelConfig) -> Tuple[str, ...]:
    """The assigned shape cells for this architecture (see DESIGN §4)."""
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.supports_long:
        out.append("long_500k")
    return tuple(out)


def smoke(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    return dataclasses.replace(
        cfg,
        n_layers=min(cfg.n_layers, 2),
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        head_dim=16,
        d_ff=96 if cfg.d_ff else 0,
        vocab=256,
        n_experts=min(cfg.n_experts, 8),
        n_shared_experts=min(cfg.n_shared_experts, 1),
        top_k=min(cfg.top_k, 2),
        moe_d_ff=32 if cfg.moe_d_ff else 0,
        q_lora_rank=16 if cfg.q_lora_rank else 0,
        kv_lora_rank=16 if cfg.kv_lora_rank else 0,
        qk_nope_dim=16 if cfg.qk_nope_dim else 0,
        qk_rope_dim=8 if cfg.qk_rope_dim else 0,
        v_head_dim=16 if cfg.v_head_dim else 0,
        ssm_state=16 if cfg.ssm_state else 0,
        ssm_head_dim=16 if cfg.ssm_head_dim else 0,
        ssm_chunk=8,
        hybrid_period=2 if cfg.hybrid_period else 0,
        n_enc_layers=min(cfg.n_enc_layers, 2),
        enc_seq=16 if cfg.enc_seq else 0,
        n_patches=4 if cfg.n_patches else 0,
        dtype="float32",
        remat="none",
        train_accum=1,
    )
