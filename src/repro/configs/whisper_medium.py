"""whisper-medium [audio] — enc-dec 24L+24L d=1024 16H (MHA) ff=4096
V=51865; conv frontend STUBBED (frame embeddings arrive precomputed,
enc_seq=1500). LayerNorm, GELU, biases, sinusoidal positions.
[arXiv:2212.04356; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium", family="encdec",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, head_dim=64,
    d_ff=4096, vocab=51865, act="gelu", gated_mlp=False, attn_bias=True,
    norm="layer", rope_theta=0.0, tie_embed=True,
    n_enc_layers=24, enc_seq=1500,
)
