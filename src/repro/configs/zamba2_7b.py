"""zamba2-7b [hybrid] — 81 Mamba2 blocks d=3584, shared attn block (32H
MHA, ff=14336) every 6 blocks, ssm_state=64, V=32000.
[arXiv:2411.15242; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32, head_dim=112,
    d_ff=14336, vocab=32000, act="silu", gated_mlp=True,
    rope_theta=10000.0, tie_embed=True,
    ssm_state=64, ssm_expand=2, ssm_head_dim=64, ssm_chunk=256,
    hybrid_period=6, supports_long=True,
    train_accum=2,
)
