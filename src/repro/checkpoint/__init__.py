from repro.checkpoint.ckpt import (AsyncCheckpointer, flatten_tree,
                                   latest_step, restore, save, prune,
                                   verify_step)
