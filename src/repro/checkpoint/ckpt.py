"""Atomic, versioned, elastic checkpointing.

Layout:  <dir>/step_<N>/arrays.npz + manifest.json, written to a ``.tmp``
sibling and ``os.rename``d into place — a crash mid-write never corrupts
the latest checkpoint.  The manifest carries a per-array SHA-256 digest;
``latest_step`` verifies the newest checkpoint end-to-end (npz readable,
every key present, digests match) and walks back to the newest GOOD step
past torn or bit-rotted directories, and ``restore`` re-verifies every
array it actually reads — a corrupt checkpoint is detected, never silently
loaded.

Elastic restore: arrays are saved device-agnostic (host numpy) and restored
via ``jax.device_put`` against the *target* sharding, so a run checkpointed
on one mesh resumes on a different mesh (or device count) — the reshard is
the device_put.  ``restore`` validates shapes/dtypes against the template
and fails loudly on architecture drift.

``AsyncCheckpointer`` overlaps serialization with training (one in-flight
save, back-pressure on the next) and keeps the newest ``keep`` checkpoints.
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _leaf_to_np(leaf) -> np.ndarray:
    """Portable host representation: PRNG keys -> raw key data (uint32),
    bf16 -> fp32 (lossless widening; restore re-narrows per the template)."""
    if hasattr(leaf, "dtype"):
        if jax.dtypes.issubdtype(leaf.dtype, jax.dtypes.prng_key):
            return np.asarray(jax.random.key_data(leaf))
        if leaf.dtype == jnp_bf16():
            return np.asarray(leaf, dtype=np.float32)
    return np.asarray(leaf)


def jnp_bf16():
    import jax.numpy as jnp
    return jnp.bfloat16


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    flat = {}
    for path, leaf in leaves:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        flat[key] = _leaf_to_np(leaf)
    return flat


# the checkpoint's flat key-path addressing, public for callers building
# ``restore(defaults=...)`` dicts (e.g. qtrain.dps_restore_defaults)
flatten_tree = _flatten


def _digest(arr: np.ndarray) -> str:
    return hashlib.sha256(np.ascontiguousarray(arr).tobytes()).hexdigest()


def save(ckpt_dir: str, step: int, tree: Any, meta: Optional[dict] = None):
    """Atomic synchronous save (per-array SHA-256 digests in the manifest)."""
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten(tree)
    np.savez(os.path.join(tmp, "arrays.npz"), **flat)
    manifest = {"step": step, "keys": sorted(flat),
                "digests": {k: _digest(v) for k, v in flat.items()},
                "meta": meta or {}, "version": 2}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def verify_step(ckpt_dir: str, step: int) -> bool:
    """True iff ``step_<N>`` is a complete, uncorrupted checkpoint: the
    manifest parses, the npz opens, every manifest key is present, and
    (version >= 2) every array matches its recorded SHA-256.  Any failure
    — torn npz, flipped bytes, missing files — reads as False, never
    raises: this is the probe ``latest_step`` uses to walk back to the
    newest good step."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    try:
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        digests = manifest.get("digests")
        with np.load(os.path.join(path, "arrays.npz")) as data:
            for key in manifest["keys"]:
                arr = data[key]           # raises on truncated members
                if digests is not None and _digest(arr) != digests[key]:
                    return False
        return True
    except Exception:
        return False


def latest_step(ckpt_dir: str, verify: bool = True) -> Optional[int]:
    """Newest restorable step.  With ``verify`` (the default) each
    candidate is integrity-checked newest-first and corrupt/torn step
    dirs are skipped — a host crash mid-write or disk corruption of the
    newest checkpoint falls back to the previous good one instead of
    poisoning the resume."""
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
                steps.append(int(name.split("_")[1]))
    for s in sorted(steps, reverse=True):
        if not verify or verify_step(ckpt_dir, s):
            return s
    return None


def restore(ckpt_dir: str, step: int, template: Any,
            shardings: Any = None, defaults: Optional[dict] = None) -> Any:
    """Restore into the structure of ``template`` (elastic re-shard via
    ``shardings`` — a matching pytree of NamedSharding or None).

    ``defaults`` maps flat key paths (``"dps/wire_grads/il"``) to host
    arrays used when the checkpoint lacks that array — the schema-upgrade
    hook.  The concrete case: checkpoints written before the precision-
    domain registry carry only the legacy three-key DPS bundle
    (``dps/weights|acts|grads/...``); restoring into a plan that also
    declares wire domains finds those keys missing and initializes them
    fresh from the defaults (see ``qtrain.dps_restore_defaults``).  Keys
    absent from both the checkpoint and ``defaults`` still fail loudly.
    """
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    digests = manifest.get("digests")  # absent on version-1 checkpoints

    leaves, treedef = jax.tree_util.tree_flatten_with_path(template)
    shard_leaves = (jax.tree_util.tree_leaves(shardings)
                    if shardings is not None else [None] * len(leaves))
    out = []
    for (p, leaf), shard in zip(leaves, shard_leaves):
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in p)
        if key not in data:
            if defaults is not None and key in defaults:
                arr = np.asarray(defaults[key])
            else:
                raise KeyError(f"checkpoint missing array {key!r}")
        else:
            arr = data[key]
            if digests is not None and _digest(arr) != digests.get(key):
                raise ValueError(
                    f"checkpoint array {key!r} fails its SHA-256 digest "
                    f"(step {step} is corrupt — see ckpt.verify_step)")
        if (hasattr(leaf, "dtype")
                and jax.dtypes.issubdtype(leaf.dtype, jax.dtypes.prng_key)):
            out.append(jax.random.wrap_key_data(jax.numpy.asarray(arr)))
            continue
        want = getattr(leaf, "shape", None)
        if want is not None and tuple(arr.shape) != tuple(want):
            raise ValueError(f"shape mismatch for {key}: ckpt {arr.shape} "
                             f"vs template {want}")
        arr = arr.astype(leaf.dtype) if hasattr(leaf, "dtype") else arr
        out.append(jax.device_put(arr, shard) if shard is not None
                   else jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef, out), manifest["meta"]


def prune(ckpt_dir: str, keep: int):
    if not os.path.isdir(ckpt_dir):
        return
    steps = sorted(s for s in (latest_steps(ckpt_dir)))
    for s in steps[:-keep] if keep else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"), ignore_errors=True)


def latest_steps(ckpt_dir: str):
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
                yield int(name.split("_")[1])


class AsyncCheckpointer:
    """One in-flight background save; ``wait()`` before exit."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, tree: Any, meta: Optional[dict] = None):
        self.wait()
        # materialize on host *before* handing to the thread so the training
        # step can donate/overwrite device buffers immediately
        host_tree = jax.tree.map(_leaf_to_np, tree)

        def work():
            try:
                save(self.dir, step, host_tree, meta)
                prune(self.dir, self.keep)
            except BaseException as e:   # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
