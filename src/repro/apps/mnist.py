"""Paper-faithful LeNet/MNIST-class DPS training (§4 of the paper).

Hyper-parameters follow the paper exactly: batch 64, SGD momentum 0.9,
lr 0.01 with inverse decay (γ=1e-4, pow=0.75), weight decay 5e-4,
E_max = R_max = 0.01%, precision updated once per iteration, stats taken on
the last layer's activations/gradients (``stat_scope="last_layer"``).

``train_mnist`` powers examples/train_mnist_dps.py, the convergence /
rounding / scheme benchmarks (paper Figs. 3–4, Table 1) and the integration
tests.
"""

from __future__ import annotations

from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import qtrain
from repro.core.dps import DPSHyper
from repro.data import MNISTLike
from repro.models import lenet
from repro.optim import SGDConfig, make_optimizer


def paper_quant_config(controller: str = "paper",
                       rounding: str = "stochastic",
                       il_init: int = 8, fl_init: int = 12,
                       static_bits: Optional[int] = None,
                       static_scope: str = "all",
                       na_window: int = 30) -> qtrain.QuantConfig:
    """Quantization config for the paper's evaluation.

    ``static_bits`` reproduces the fixed-width ablations (paper's 13-bit
    run, Gupta's 16-bit runs): per-attribute radix placement — weights get
    resolution (⟨2, n-2⟩), activations get range (⟨6, n-6⟩) — with the
    paper's own carve-out that GRADIENT width stays high ("requires the
    most precision in order for training to converge", §4)."""
    if static_bits is not None:
        # Gupta-style IL-heavy activations (logits reach ±100 mid-training;
        # ⟨6,·⟩ overflows at 17% and training explodes — measured).  The
        # static width applies to ALL THREE attributes — that's the paper's
        # "naive fixed 13-bit" ablation; the DPS runs are what keep
        # gradients wide adaptively.
        hw = DPSHyper(il_init=2, fl_init=static_bits - 2)
        if static_scope == "weights":
            # Gupta-style: narrow WEIGHTS only — stochastic rounding's claim
            # is that sub-half-grid weight updates survive in expectation
            ha = DPSHyper(il_init=8, fl_init=8)
            hg = DPSHyper(il_init=6, fl_init=18)
        else:
            ha = DPSHyper(il_init=8, fl_init=static_bits - 8)
            hg = DPSHyper(il_init=6, fl_init=static_bits - 6)
        return qtrain.QuantConfig(
            enabled=True, controller="static", rounding=rounding,
            hyper_weights=hw, hyper_acts=ha, hyper_grads=hg,
            stat_scope="last_layer")
    kw = dict(r_max=1e-4, e_max=1e-4, na_window=na_window)
    h = DPSHyper(il_init=il_init, fl_init=fl_init, **kw)
    hg = DPSHyper(il_init=il_init, fl_init=16, **kw)
    return qtrain.QuantConfig(
        enabled=True, controller=controller, rounding=rounding,
        hyper_weights=h, hyper_acts=h, hyper_grads=hg,
        stat_scope="last_layer")


def train_mnist(qcfg: Optional[qtrain.QuantConfig], steps: int = 2000,
                batch: int = 64, seed: int = 0, eval_every: int = 0,
                data: Optional[MNISTLike] = None) -> Dict:
    """Train LeNet; ``qcfg=None`` is the fp32 baseline.  Returns history."""
    data = data or MNISTLike(batch=batch, seed=seed)
    params = lenet.init(jax.random.key(seed))
    opt = make_optimizer(SGDConfig())            # paper defaults
    if qcfg is None:
        qcfg = qtrain.QuantConfig(enabled=False)
    step_fn = jax.jit(qtrain.make_train_step(lenet.loss_fn, opt, qcfg))
    state = qtrain.TrainState.create(params, opt.init(params), qcfg,
                                     jax.random.key(seed + 1))

    hist: Dict[str, List] = {k: [] for k in
                             ("loss", "acc", "il_w", "fl_w", "il_a", "fl_a",
                              "il_g", "fl_g", "E_a", "R_a", "test_acc")}
    test = data.test_set()

    @jax.jit
    def test_acc(params):
        logits, _, _ = lenet.forward(params, jnp.asarray(test["images"]))
        return jnp.mean((jnp.argmax(logits, -1)
                         == jnp.asarray(test["labels"])).astype(jnp.float32))

    for i in range(steps):
        state, m = step_fn(state, data.train_batch(i))
        for k in ("loss", "il_w", "fl_w", "il_a", "fl_a", "il_g", "fl_g",
                  "E_a", "R_a"):
            hist[k].append(float(m[k]))
        if eval_every and (i + 1) % eval_every == 0:
            hist["test_acc"].append((i + 1, float(test_acc(state.params))))

    hist["final_test_acc"] = float(test_acc(state.params))
    hist["avg_bits_w"] = float(np.mean(np.add(hist["il_w"], hist["fl_w"])))
    hist["avg_bits_a"] = float(np.mean(np.add(hist["il_a"], hist["fl_a"])))
    hist["avg_bits_g"] = float(np.mean(np.add(hist["il_g"], hist["fl_g"])))
    hist["diverged"] = bool(not np.isfinite(hist["loss"][-1])
                            or hist["loss"][-1] > 2.0)
    return hist
