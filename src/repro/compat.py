"""JAX version-compat aliases.

The codebase targets the current JAX API surface; this module backfills the
pieces the pinned jaxlib spells differently so one source tree runs on both:

  * ``jax.shard_map`` — older releases only ship
    ``jax.experimental.shard_map.shard_map``, whose replication-check kwarg
    is ``check_rep`` (newer: ``check_vma``).
  * ``jax.lax.axis_size`` — the classic spelling is ``lax.psum(1, axis)``,
    which constant-folds to the (static) axis size.

(The Pallas ``pltpu.CompilerParams`` / ``TPUCompilerParams`` rename is
handled locally in :mod:`repro.kernels.dps_quant`.)
"""

from __future__ import annotations

import functools

import jax


def _shard_map_backport(f=None, *, mesh=None, in_specs=None, out_specs=None,
                        check_vma=None, check_rep=None, **kwargs):
    from jax.experimental.shard_map import shard_map

    if f is None:
        return functools.partial(
            _shard_map_backport, mesh=mesh, in_specs=in_specs,
            out_specs=out_specs, check_vma=check_vma, check_rep=check_rep,
            **kwargs)
    if check_rep is None:
        check_rep = True if check_vma is None else bool(check_vma)
    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=check_rep, **kwargs)


def install() -> None:
    """Idempotently install the aliases onto the ``jax`` namespace."""
    try:
        jax.shard_map  # noqa: B018  — probes the (possibly deprecated) attr
    except AttributeError:
        jax.shard_map = _shard_map_backport
    if not hasattr(jax.lax, "axis_size"):
        jax.lax.axis_size = lambda axis_name: jax.lax.psum(1, axis_name)
