"""Request queue + strict-FCFS admission for the continuous-batching loop.

Admission policy is deliberately head-of-line only: a request is admitted
iff it is the *oldest* pending request, it has arrived, a batch slot is
free, and the allocator can cover its whole lifetime
(:meth:`PagedLayout.pages_needed`) up front.  No skip-ahead means a
request's admission step — and hence its decode trajectory — never
depends on requests behind it in the queue, which keeps the
solo-equivalence property (``tests/test_serve.py``) unconditional.
Reserving all pages at admission makes the loop deadlock-free: an
admitted request can always run to completion without waiting on pages
held by anyone else.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, List, Optional, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class Request:
    """One user request: a prompt and a fixed decode budget."""

    rid: int
    prompt: np.ndarray          # int32 [prompt_len], prompt_len >= 1
    max_new: int                # tokens to return (>= 1), first from prefill
    arrival: int = 0            # engine step at which the request exists

    def __post_init__(self):
        object.__setattr__(self, "prompt",
                           np.asarray(self.prompt, np.int32).reshape(-1))
        if self.prompt.size < 1:
            raise ValueError(f"request {self.rid}: empty prompt")
        if self.max_new < 1:
            raise ValueError(f"request {self.rid}: max_new must be >= 1")


def synthetic_trace(n_requests: int, vocab: int, *,
                    prompt_lens=(4, 16), new_tokens=(4, 16),
                    mean_gap: float = 0.5, seed: int = 0) -> List[Request]:
    """A many-user trace: random prompts, mixed lengths, Poisson arrivals.

    ``prompt_lens`` / ``new_tokens`` are inclusive [lo, hi] ranges;
    ``mean_gap`` is the mean inter-arrival gap in engine *steps* (0 =
    everything arrives at step 0).  Deterministic in ``seed``.
    """
    rng = np.random.default_rng(seed)
    gaps = rng.poisson(mean_gap, n_requests) if mean_gap > 0 else \
        np.zeros(n_requests, np.int64)
    arrivals = np.cumsum(gaps) - gaps[0] if n_requests else gaps
    out = []
    for i in range(n_requests):
        plen = int(rng.integers(prompt_lens[0], prompt_lens[1] + 1))
        out.append(Request(
            rid=i,
            prompt=rng.integers(1, vocab, plen).astype(np.int32),
            max_new=int(rng.integers(new_tokens[0], new_tokens[1] + 1)),
            arrival=int(arrivals[i])))
    return out


class Scheduler:
    """Strict-FCFS pending queue (ordered by arrival, then rid)."""

    def __init__(self, requests: Sequence[Request]):
        self.pending = deque(
            sorted(requests, key=lambda r: (r.arrival, r.rid)))

    def __len__(self) -> int:
        return len(self.pending)

    def next_arrival(self) -> Optional[int]:
        return self.pending[0].arrival if self.pending else None

    def pop_admissible(self, step: int,
                       can_admit: Callable[[Request], bool]
                       ) -> Optional[Request]:
        """Head of queue, iff arrived and ``can_admit`` (slot + pages) holds."""
        if (self.pending and self.pending[0].arrival <= step
                and can_admit(self.pending[0])):
            return self.pending.popleft()
        return None

    def requeue(self, req: Request):
        """Put a popped request back at the HEAD of the queue (it stays the
        oldest pending request, so strict FCFS is preserved).  The
        backpressure path: admission popped it but the page allocator
        could not actually cover it — hold it and retry after frees
        instead of dropping it or crashing the engine."""
        self.pending.appendleft(req)
