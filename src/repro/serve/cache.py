"""Paged int8 KV pool + page codec under the ``kv_cache`` precision domain.

One page = one ⟨IL, FL⟩ group.  The ``kv_cache`` domain (PR 4's registry)
gets a per-group flexpoint controller with ``2 · n_layers ·
n_pages_total`` rows — one row per (kind ∈ {K, V}, layer, physical page),
laid out by :func:`repro.serve.page_table.page_rows` — and the page encode
is exactly the grouped wire codec of PR 5: ``fixed_point.wire_quantize``
with a ``[G]``-leading format (the jnp grouped reference) or the
``[G, 2]`` SMEM-table Pallas kernel via ``ops.dps_quantize_wire_grouped``
when the page element count meets the kernel's 4096-element tile quantum.

Format placement is **content-driven and history-free**: when a prompt is
encoded into freshly allocated pages, each written row's format comes from
one controller update over a *fresh-init* state fed that page's measured
stats (max|x| et al.), and rows reset to init when their page is freed.
A page's ⟨IL, FL⟩ is therefore a pure function of its content — which is
what makes continuous batching safe: a request's decode trajectory cannot
depend on which physical pages it got or on its neighbors in the batch
(the solo-equivalence property ``tests/test_serve.py`` pins).  Feeding
the shared ``plan.update`` stream instead would decay every untouched
row's EMA toward ``il_min`` on each admission — history leaking across
requests.  Pages allocated for *generated* tokens keep the init format
(⟨il_init, 8 − il_init⟩) for their lifetime; re-placing them from
decode-time stats is the ROADMAP follow-up.
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import fixed_point as fxp
from repro.core import tagging
from repro.core.dps import DomainSpec, PrecisionPlan, wire_hyper
from repro.core.fixed_point import FixedPointFormat, QuantStats
from repro.kernels import ops
from repro.serve.page_table import PagedLayout

KV_DOMAIN = "kv_cache"
WIRE_BITS = 8
# init (and generated-page) format: ⟨2, 6⟩ — range ±2, step 1/64
DEFAULT_IL_INIT = 2


class PagedKV(NamedTuple):
    """The page pools, stacked over layers (scan xs/ys like the contiguous
    cache): ``(n_layers, n_pages_total, page_size, KV, Dh)`` each, int8
    grid integers at ``bits=8``, fp32 at ``bits=None``."""

    k_pages: jax.Array
    v_pages: jax.Array


def n_rows(cfg: ModelConfig, layout: PagedLayout) -> int:
    return 2 * cfg.n_layers * layout.n_pages_total


def kv_plan(cfg: ModelConfig, layout: PagedLayout,
            il_init: int = DEFAULT_IL_INIT) -> PrecisionPlan:
    """The serving precision plan: one wire domain, one row per page view.

    ``slack=0.0``: the radix covers exactly the measured page max — at 8
    bits the KV grid is too narrow for headroom, and unlike gradients the
    page content is already known when the format is placed (encode
    happens after measurement), so only the exact max element can clip (by
    one step).
    """
    return PrecisionPlan.of(**{KV_DOMAIN: DomainSpec(
        "flexpoint", wire_hyper(WIRE_BITS, il_init, slack=0.0),
        groups=n_rows(cfg, layout), wire=True)})


def init_pool(cfg: ModelConfig, layout: PagedLayout, bits) -> PagedKV:
    dt = jnp.int8 if bits == 8 else jnp.float32
    shp = (cfg.n_layers, layout.n_pages_total, layout.page_size,
           cfg.n_kv_heads, cfg.head_dim)
    return PagedKV(jnp.zeros(shp, dt), jnp.zeros(shp, dt))


def fmt_tables(state, cfg: ModelConfig,
               layout: PagedLayout) -> Tuple[jax.Array, jax.Array]:
    """Controller rows → the decode step's per-layer (n_pages_total, 2)
    [IL, FL] tables for K and V (leading L for the layer scan)."""
    L, n_tot = cfg.n_layers, layout.n_pages_total
    il = state.il.reshape(2, L, n_tot)
    fl = state.fl.reshape(2, L, n_tot)
    k_fmt = jnp.stack([il[0], fl[0]], axis=-1).astype(jnp.int32)
    v_fmt = jnp.stack([il[1], fl[1]], axis=-1).astype(jnp.int32)
    return k_fmt, v_fmt


def zero_fmt_tables(cfg: ModelConfig,
                    layout: PagedLayout) -> Tuple[jax.Array, jax.Array]:
    """``bits=None`` tables: FL = 0 decodes fp32 pool values by ×1.0 exactly."""
    z = jnp.zeros((cfg.n_layers, layout.n_pages_total, 2), jnp.int32)
    return z, z


def encode_pages(xg: jax.Array, fmt: FixedPointFormat, mask: jax.Array, *,
                 backend: str, quantum: int) -> jax.Array:
    """The page codec: (G_w, page_elems) fp32 → int8 grid integers.

    ``backend="kernel"`` runs the PR 5 grouped SMEM-table kernel (one tile
    per page; requires ``quantum % 4096 == 0``); ``"jnp"`` is the bit-exact
    grouped reference (``wire_quantize`` with a [G]-leading format).
    ``mask`` zeroes padding out of the wire in both.
    """
    if backend == "kernel":
        tg = jnp.arange(xg.shape[0], dtype=jnp.int32)
        wire, _ = ops.dps_quantize_wire_grouped(
            xg.reshape(-1), fmt, tg, mask=mask.reshape(-1),
            stochastic=False, quantum=quantum, compute_stats=False)
        return wire.reshape(xg.shape)
    if backend != "jnp":
        raise ValueError(f"unknown page-encode backend {backend!r}")
    wire, _ = fxp.wire_quantize(xg, fmt, mode=fxp.ROUND_NEAREST,
                                mask=mask, compute_stats=False)
    return wire


def _page_stats(xg: jax.Array, mask: jax.Array) -> QuantStats:
    """Pre-encode per-page stats the flexpoint placement consumes."""
    absx = jnp.abs(xg) * mask
    z = jnp.zeros(xg.shape[:1], jnp.float32)
    return QuantStats(
        count=jnp.sum(mask, axis=1),
        nonzero=jnp.sum((absx > 0.0).astype(jnp.float32), axis=1),
        overflow=z, abs_err_sum=z, rel_err_sum=z,
        abs_sum=jnp.sum(absx, axis=1),
        max_abs=jnp.max(absx, axis=1))


def _row_index(cfg: ModelConfig, layout: PagedLayout,
               phys: jax.Array) -> jax.Array:
    """(2, L, len(phys)) → flat (G_w,) domain rows (traced page_rows)."""
    L, n_tot = cfg.n_layers, layout.n_pages_total
    kinds = jnp.arange(2, dtype=jnp.int32)[:, None, None]
    layers = jnp.arange(L, dtype=jnp.int32)[None, :, None]
    return ((kinds * L + layers) * n_tot
            + phys[None, None, :].astype(jnp.int32)).reshape(-1)


def write_prompt_pages(cfg: ModelConfig, layout: PagedLayout, plan,
                       pools: PagedKV, state, ck: jax.Array, cv: jax.Array,
                       phys: jax.Array, plen: jax.Array, *,
                       bits, encode_backend: str):
    """Encode one prefilled (B=1) contiguous fp32 cache into its pages.

    ``ck``/``cv``: (L, 1, max_prompt, KV, Dh) from the prefill forward.
    ``phys``: (prompt_pages,) physical destinations for logical page slots
    0..prompt_pages-1 — entries past the request's allocation point at the
    trash page and carry no valid tokens.  ``plen``: traced prompt length.

    Per written page (any page with a token < ``plen``): measure stats →
    one fresh-init controller update → merge ONLY the written rows into
    ``state`` → encode on the placed grid → scatter int8 wire into the
    pools.  Pages without valid tokens (trash entries, generation-region
    pages) contribute zero stats and keep their existing rows.

    Returns ``(pools', state')``; ``state`` passes through at ``bits=None``.
    """
    L, ps = cfg.n_layers, layout.page_size
    KV, Dh = cfg.n_kv_heads, cfg.head_dim
    Pp, n_tot = layout.prompt_pages, layout.n_pages_total
    E = ps * KV * Dh
    S = layout.max_prompt

    x = jnp.stack([ck[:, 0], cv[:, 0]])                  # (2, L, S, KV, Dh)
    tmask = (jnp.arange(S) < plen).astype(jnp.float32)
    x = x.astype(jnp.float32) * tmask[None, None, :, None, None]
    xg = x.reshape(2 * L * Pp, E)                        # (G_w, E)
    mg = jnp.broadcast_to(
        tmask.reshape(Pp, ps, 1),
        (Pp, ps, KV * Dh)).reshape(Pp, E)
    mg = jnp.broadcast_to(mg[None, None], (2, L, Pp, E)).reshape(2 * L * Pp, E)

    if bits is None:
        w = xg.reshape(2, L, Pp, ps, KV, Dh)
        w = tagging.tag(w, "kv_page", domain=KV_DOMAIN, stage="write", bits=0)
        return PagedKV(
            pools.k_pages.at[:, phys].set(w[0].astype(pools.k_pages.dtype)),
            pools.v_pages.at[:, phys].set(w[1].astype(pools.v_pages.dtype)),
        ), state

    rows = _row_index(cfg, layout, phys)                 # (G_w,)
    G_tot = n_rows(cfg, layout)
    zeros = jnp.zeros((G_tot,), jnp.float32)
    st = _page_stats(xg, mg)
    stream = QuantStats(
        count=zeros.at[rows].add(st.count),
        nonzero=zeros.at[rows].add(st.nonzero),
        overflow=zeros, abs_err_sum=zeros, rel_err_sum=zeros,
        abs_sum=zeros.at[rows].add(st.abs_sum),
        max_abs=zeros.at[rows].max(st.max_abs))
    stream = tagging.tag_tree(stream, "stats_sink", domain=KV_DOMAIN,
                              wire=True, stream=KV_DOMAIN)

    ctrl = plan.spec(KV_DOMAIN).make()
    placed = ctrl.update(ctrl.init((G_tot,)), stream)
    # a page is written iff it covers a token < plen (static per slot j)
    live = (jnp.arange(Pp) * ps < plen).astype(jnp.float32)
    written = zeros.at[rows].max(
        jnp.broadcast_to(live[None, None], (2, L, Pp)).reshape(-1)) > 0.0
    state = jax.tree.map(lambda s, n: jnp.where(written, n, s),
                         state, placed)

    fmt = FixedPointFormat(state.il[rows], state.fl[rows])
    xin = tagging.tag(xg, "encode_in", domain=KV_DOMAIN)
    wire = encode_pages(xin, fmt, mg, backend=encode_backend, quantum=E)
    wire = tagging.tag(wire, "kv_page", domain=KV_DOMAIN, stage="write",
                       bits=WIRE_BITS)
    w = wire.reshape(2, L, Pp, ps, KV, Dh)
    return PagedKV(pools.k_pages.at[:, phys].set(w[0]),
                   pools.v_pages.at[:, phys].set(w[1])), state


def reset_rows(plan, state, row_mask: jax.Array):
    """Reset masked controller rows to init (page freed → history cleared)."""
    ctrl = plan.spec(KV_DOMAIN).make()
    fresh = ctrl.init(row_mask.shape)
    return jax.tree.map(lambda f, s: jnp.where(row_mask, f, s), fresh, state)
