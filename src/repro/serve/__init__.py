"""repro.serve — continuous-batching LM inference on a paged int8 KV cache.

The serving-side consumer of the DPS machinery: pages are int8 grid
integers under per-page ⟨IL, FL⟩ formats owned by the ``kv_cache``
precision domain, encoded by the PR 5 grouped wire codec and dequantized
in-register by the fused paged decode-attention kernel
(:mod:`repro.kernels.paged_attn`).  See ``README.md`` in this package.
"""

from repro.serve.cache import (DEFAULT_IL_INIT, KV_DOMAIN, PagedKV,
                               fmt_tables, init_pool, kv_plan,
                               write_prompt_pages)
from repro.serve.engine import (Engine, EngineConfig, ServeReport,
                                analysis_decode, supports_paging)
from repro.serve.page_table import PageAllocator, PagedLayout, page_rows
from repro.serve.scheduler import Request, Scheduler, synthetic_trace

__all__ = [
    "DEFAULT_IL_INIT", "KV_DOMAIN", "PagedKV", "fmt_tables", "init_pool",
    "kv_plan", "write_prompt_pages", "Engine", "EngineConfig",
    "ServeReport", "analysis_decode", "supports_paging", "PageAllocator",
    "PagedLayout", "page_rows", "Request", "Scheduler", "synthetic_trace",
]
