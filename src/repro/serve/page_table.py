"""Paged KV-cache geometry: layout, page table, host-side allocator.

The pool holds ``n_pages`` real pages plus ONE reserved **trash page**
(physical index ``n_pages``).  Every page-table entry that does not map a
live logical page — empty slots of inactive batch rows, entries past a
request's last page — points at the trash page, so compiled scatters and
gathers always hit a valid pool row and need no bounds branches; the
sequence-length mask keeps whatever lands there out of every output.

Physical page ids are shared across layers and across K/V (vLLM-style):
one allocation covers the token range in every layer's pool, and the
per-page precision rows for all ``2 · n_layers`` (kind, layer) views of a
page are derived from the single physical id via :func:`page_rows`.

Allocation is host-side and LIFO — page tables and lengths are plain step
*inputs* to the compiled decode, so admission/retirement never recompiles.
"""

from __future__ import annotations

import dataclasses
from typing import List, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class PagedLayout:
    """Static geometry of one paged engine instance (jit-stable)."""

    page_size: int           # tokens per page
    n_pages: int             # real pages in the pool (trash page excluded)
    batch_slots: int         # concurrent decode rows (B)
    max_pages_per_seq: int   # page-table width per row (P)
    max_prompt: int          # compiled prompt length (page_size multiple)

    def __post_init__(self):
        if self.max_prompt % self.page_size:
            raise ValueError(
                f"max_prompt {self.max_prompt} must be a multiple of the "
                f"page size {self.page_size}")
        if self.prompt_pages > self.max_pages_per_seq:
            raise ValueError(
                f"max_prompt spans {self.prompt_pages} pages but rows hold "
                f"only {self.max_pages_per_seq}")
        if self.n_pages < self.prompt_pages:
            raise ValueError("pool smaller than one prompt")

    @property
    def n_pages_total(self) -> int:
        return self.n_pages + 1

    @property
    def trash_page(self) -> int:
        return self.n_pages

    @property
    def prompt_pages(self) -> int:
        return self.max_prompt // self.page_size

    def pages_needed(self, prompt_len: int, max_new: int) -> int:
        """Pages a request occupies for its whole lifetime.

        Tokens written to the cache: the prompt plus every decode step's
        consumed token — the last generated token is returned but never
        written, hence ``max_new - 1``.
        """
        tokens = prompt_len + max(max_new - 1, 0)
        return -(-tokens // self.page_size)

    def fits(self, prompt_len: int, max_new: int) -> bool:
        return (prompt_len <= self.max_prompt
                and self.pages_needed(prompt_len, max_new)
                <= self.max_pages_per_seq)


def page_rows(n_layers: int, n_pages_total: int, pages) -> np.ndarray:
    """Precision-domain rows for physical ``pages``: shape (2, L, len(pages)).

    Row layout of the ``kv_cache`` domain: ``((kind · L) + layer) ·
    n_pages_total + page`` with kind 0 = K, 1 = V — so a page's K rows for
    every layer are ``out[0, :, i]`` and its V rows ``out[1, :, i]``.
    """
    pages = np.asarray(pages, np.int64)
    kinds = np.arange(2)[:, None, None]
    layers = np.arange(n_layers)[None, :, None]
    return (kinds * n_layers + layers) * n_pages_total + pages[None, None, :]


class PageAllocator:
    """LIFO free-list over the real pages (the trash page is never free)."""

    def __init__(self, n_pages: int):
        self._free: List[int] = list(range(n_pages))

    @property
    def n_free(self) -> int:
        return len(self._free)

    def can(self, n: int) -> bool:
        return len(self._free) >= n

    def alloc(self, n: int) -> List[int]:
        # independent of the advisory can() pre-check: alloc enforces its
        # own invariant so a stale/optimistic admission decision can never
        # hand out pages the pool does not have
        if len(self._free) < n:
            raise RuntimeError(f"allocator has {len(self._free)} free pages, "
                               f"need {n}")
        out, self._free = self._free[-n:], self._free[:-n]
        return out

    def release(self, pages: Sequence[int]) -> None:
        self._free.extend(int(p) for p in pages)
