"""Continuous-batching inference engine over the paged DPS KV cache.

Prefill/decode split: each admission runs the prompt once at batch 1
(compiled at the layout's fixed ``max_prompt``), encodes the resulting
contiguous fp32 cache into int8 pages (``cache.write_prompt_pages``), and
drops the request into a free decode row.  Decode is one jointly-batched
compiled step over all ``batch_slots`` rows — inactive rows ride along
pointed at the trash page — so admissions and retirements only rewrite
*inputs* (page table, positions, last tokens) and never recompile.

Exactly three compiled shapes exist for a layout: prefill, encode, decode.
"""

from __future__ import annotations

import dataclasses
import time
from collections import Counter
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import registry
from repro.models.common import init_params, unembed
from repro.kernels.paged_attn import _on_tpu
from repro.serve import cache as kvc
from repro.serve.page_table import PageAllocator, PagedLayout, page_rows
from repro.serve.scheduler import Request, Scheduler


def supports_paging(cfg: ModelConfig) -> bool:
    """Paged serving needs the GQA decode path (no MLA latent cache, no
    SSM state, no encoder context)."""
    return cfg.family in ("dense", "moe") and not cfg.mla


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    layout: PagedLayout
    kv_bits: Optional[int] = 8     # 8 = int8 DPS pages; None = fp32 pages
    attn_backend: str = "auto"     # fused decode attention: kernel | jnp
    encode_backend: str = "auto"   # page codec: kernel | jnp
    il_init: int = kvc.DEFAULT_IL_INIT
    max_concurrency: Optional[int] = None  # 1 = serial-serving baseline


@dataclasses.dataclass
class ServeReport:
    tokens: Dict[int, List[int]]   # rid -> generated token ids (greedy)
    metrics: Dict[str, float]
    format_spread: Dict[str, int]  # "<il,fl>" -> live prompt pages placed


class Engine:
    """Holds the compiled step functions; :meth:`run` drives a trace."""

    def __init__(self, cfg: ModelConfig, params, ecfg: EngineConfig):
        if not supports_paging(cfg):
            raise ValueError(f"{cfg.name}: family {cfg.family!r} (mla="
                             f"{cfg.mla}) has no paged decode path")
        if ecfg.kv_bits not in (None, 8):
            raise ValueError(f"kv_bits must be 8 or None, got {ecfg.kv_bits}")
        # the engine owns KV quantization at page granularity; the model's
        # own contiguous int8-cache mode must not double-quantize prefill
        if cfg.kv_cache_bits == 8:
            cfg = dataclasses.replace(cfg, kv_cache_bits=16)
        self.cfg = cfg
        self.ecfg = ecfg
        self.layout = ecfg.layout
        self.bits = ecfg.kv_bits
        self.params = params
        self.mod = registry(cfg.family)

        lay = self.layout
        page_elems = lay.page_size * cfg.n_kv_heads * cfg.head_dim
        self._attn_backend = (ecfg.attn_backend if ecfg.attn_backend != "auto"
                              else ("kernel" if _on_tpu() else "jnp"))
        eb = ecfg.encode_backend
        if eb == "auto":
            eb = "kernel" if _on_tpu() and page_elems % 4096 == 0 else "jnp"
        if eb == "kernel" and page_elems % 4096:
            raise ValueError(
                f"page holds {page_elems} elements — the grouped wire "
                f"kernel needs a multiple of 4096; use encode_backend='jnp' "
                f"or a larger page")
        self._enc_backend = eb

        self.plan = (kvc.kv_plan(cfg, lay, ecfg.il_init)
                     if self.bits == 8 else None)

        def prefill_impl(params, tokens, plen):
            hidden, cache2, _, _ = self.mod.forward(
                cfg, params, tokens, mode="prefill", hidden_only=True)
            last = jax.lax.dynamic_index_in_dim(hidden, plen - 1, axis=1)
            logits = unembed(last, params["embed"], cfg.vocab)
            return logits[0, -1].astype(jnp.float32), cache2[0], cache2[1]

        def encode_impl(pools, state, ck, cv, phys, plen):
            return kvc.write_prompt_pages(
                cfg, lay, self.plan, pools, state, ck, cv, phys, plen,
                bits=self.bits, encode_backend=self._enc_backend)

        self._prefill = jax.jit(prefill_impl)
        self._encode = jax.jit(encode_impl)
        self._decode = jax.jit(self.decode_impl)
        if self.bits == 8:
            self._reset = jax.jit(
                lambda state, mask: kvc.reset_rows(self.plan, state, mask))

    def decode_impl(self, params, tokens, pools, state, ptab, pos):
        """One batched decode step (also the analysis entry point).

        ``state`` is the kv_cache FlexState at ``kv_bits=8`` and ``None``
        at ``kv_bits=None`` (fp32 pages, zero-FL tables → ×1.0 dequant).
        """
        if self.bits == 8:
            k_fmt, v_fmt = kvc.fmt_tables(state, self.cfg, self.layout)
        else:
            k_fmt, v_fmt = kvc.zero_fmt_tables(self.cfg, self.layout)
        cache = (pools.k_pages, pools.v_pages, k_fmt, v_fmt)
        logits, new_cache = self.mod.decode_step_paged(
            self.cfg, params, tokens, cache, ptab, pos,
            backend=self._attn_backend)
        return (logits.astype(jnp.float32),
                kvc.PagedKV(new_cache[0], new_cache[1]))

    # ------------------------------------------------------------------
    # the serving loop
    # ------------------------------------------------------------------

    def run(self, requests: Sequence[Request], *,
            max_steps: Optional[int] = None) -> ServeReport:
        if self.params is None:
            raise ValueError("engine built without params (analysis-only)")
        lay, B = self.layout, self.layout.batch_slots
        for r in requests:
            need = lay.pages_needed(r.prompt.size, r.max_new)
            if not lay.fits(r.prompt.size, r.max_new) or need > lay.n_pages:
                raise ValueError(
                    f"request {r.rid} (prompt {r.prompt.size}, max_new "
                    f"{r.max_new} -> {need} pages) can never fit layout "
                    f"{lay}")

        sched = Scheduler(requests)
        alloc = PageAllocator(lay.n_pages)
        pools = kvc.init_pool(self.cfg, lay, self.bits)
        state = self.plan.init()[kvc.KV_DOMAIN] if self.bits == 8 else None

        ptab = np.full((B, lay.max_pages_per_seq), lay.trash_page, np.int32)
        pos = np.zeros(B, np.int32)
        last = np.zeros(B, np.int32)
        slots: List[Optional[dict]] = [None] * B
        tokens_out: Dict[int, List[int]] = {r.rid: [] for r in requests}
        lat: List[float] = []
        prefill_s: List[float] = []
        occ: List[int] = []
        spread: Counter = Counter()
        cap = min(self.ecfg.max_concurrency or B, B)
        guard = max_steps if max_steps is not None else (
            sum(r.max_new for r in requests)
            + max((r.arrival for r in requests), default=0)
            + len(requests) + 16)

        L, n_tot = self.cfg.n_layers, lay.n_pages_total
        step = 0
        bp_steps = 0   # steps an arrived request was held for page frees
        t0 = time.perf_counter()
        while sched.pending or any(s is not None for s in slots):
            if step > guard:
                raise RuntimeError(f"serving loop exceeded {guard} steps")

            # retire finished rows: free pages, clear precision history
            for b, s in enumerate(slots):
                if s is not None and s["produced"] >= s["req"].max_new:
                    alloc.release(s["pages"])
                    if self.bits == 8:
                        rows = page_rows(L, n_tot, s["pages"]).reshape(-1)
                        mask = np.zeros(kvc.n_rows(self.cfg, lay), bool)
                        mask[rows] = True
                        state = self._reset(state, jnp.asarray(mask))
                    ptab[b] = lay.trash_page
                    pos[b] = 0
                    last[b] = 0
                    slots[b] = None

            # admit (strict FCFS) while a slot is free and pages cover the
            # head request's whole lifetime
            while sum(s is not None for s in slots) < cap:
                req = sched.pop_admissible(
                    step, lambda r: alloc.can(
                        lay.pages_needed(r.prompt.size, r.max_new)))
                if req is None:
                    # head arrived but can't start -> pool backpressure:
                    # the request waits in the queue for frees, it is
                    # never dropped
                    if (sched.pending
                            and sched.pending[0].arrival <= step):
                        bp_steps += 1
                    break
                b = next(i for i, s in enumerate(slots) if s is None)
                try:
                    pools, state = self._admit(
                        b, req, alloc, pools, state, ptab, pos, last,
                        slots, tokens_out, prefill_s, spread)
                except RuntimeError:
                    # allocator exhaustion despite the can() pre-check
                    # (accounting drift): hold the request at the queue
                    # head and retry after the next retire frees pages —
                    # backpressure, not a crash
                    sched.requeue(req)
                    bp_steps += 1
                    break

            act = [b for b, s in enumerate(slots) if s is not None]
            if act:
                occ.append(len(act))
                t_d = time.perf_counter()
                logits, pools = self._decode_call(pools, state, ptab, pos,
                                                  last)
                nxt = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
                dt = time.perf_counter() - t_d
                for b in act:
                    s = slots[b]
                    tokens_out[s["req"].rid].append(int(nxt[b]))
                    s["produced"] += 1
                    pos[b] += 1
                    last[b] = nxt[b]
                    lat.append(dt)
            elif sched.pending:
                nxt_arr = sched.next_arrival()
                if nxt_arr is not None and nxt_arr > step + 1:
                    step = nxt_arr - 1          # fast-forward idle gaps
            step += 1

        wall = time.perf_counter() - t0
        total = sum(len(v) for v in tokens_out.values())
        metrics = {
            "wall_s": wall,
            "total_tokens": float(total),
            "tokens_per_s": total / wall if wall > 0 else 0.0,
            "decode_steps": float(len(occ)),
            "decoded_tokens": float(len(lat)),
            "p50_ms_per_token": float(np.percentile(lat, 50) * 1e3)
            if lat else 0.0,
            "p95_ms_per_token": float(np.percentile(lat, 95) * 1e3)
            if lat else 0.0,
            "mean_occupancy": float(np.mean(occ)) if occ else 0.0,
            "prefill_s_total": float(np.sum(prefill_s)) if prefill_s else 0.0,
            "backpressure_steps": float(bp_steps),
        }
        return ServeReport(tokens_out, metrics, dict(spread))

    def _admit(self, b, req, alloc, pools, state, ptab, pos, last, slots,
               tokens_out, prefill_s, spread):
        lay = self.layout
        plen = int(req.prompt.size)
        need = lay.pages_needed(plen, req.max_new)
        pages = alloc.alloc(need)

        t_a = time.perf_counter()
        toks = np.zeros(lay.max_prompt, np.int32)
        toks[:plen] = req.prompt
        logits, ck, cv = self._prefill(self.params, jnp.asarray(toks)[None],
                                       jnp.int32(plen))
        phys = np.full(lay.prompt_pages, lay.trash_page, np.int32)
        npp = min(need, lay.prompt_pages)
        phys[:npp] = pages[:npp]
        pools, state = self._encode(pools, state, ck, cv, jnp.asarray(phys),
                                    jnp.int32(plen))
        first = int(jnp.argmax(logits))
        prefill_s.append(time.perf_counter() - t_a)

        row = np.full(lay.max_pages_per_seq, lay.trash_page, np.int32)
        row[:need] = pages
        ptab[b] = row
        pos[b] = plen
        last[b] = first
        slots[b] = {"req": req, "pages": pages, "produced": 1}
        tokens_out[req.rid].append(first)

        if self.bits == 8:
            live = -(-plen // lay.page_size)
            rows = page_rows(self.cfg.n_layers, lay.n_pages_total,
                             pages[:live]).reshape(-1)
            il = np.asarray(state.il)[rows]
            fl = np.asarray(state.fl)[rows]
            spread.update(f"<{int(a)},{int(f)}>" for a, f in zip(il, fl))
        return pools, state

    def _decode_call(self, pools, state, ptab, pos, last):
        toks = jnp.asarray(last[:, None])
        return self._decode(self.params, toks, pools, state,
                            jnp.asarray(ptab), jnp.asarray(pos))


def analysis_decode(cfg: ModelConfig, ecfg: EngineConfig):
    """(fn, abstract_args) for the verifier/HLO audit — no weights touched.

    ``fn`` is the un-jitted decode step; ``abstract_args`` are
    ShapeDtypeStructs at the layout's production shapes, so
    ``jax.make_jaxpr(fn)(*args)`` / ``jax.jit(fn).lower(*args)`` cost no
    pool memory.
    """
    eng = Engine(cfg, None, ecfg)
    lay = ecfg.layout
    defs = eng.mod.model_defs(eng.cfg)
    params = jax.eval_shape(lambda k: init_params(k, defs),
                            jax.random.key(0))
    pools = jax.eval_shape(lambda: kvc.init_pool(eng.cfg, lay, eng.bits))
    state = (jax.eval_shape(lambda: eng.plan.init()[kvc.KV_DOMAIN])
             if eng.bits == 8 else None)
    B = lay.batch_slots
    i32 = jnp.int32
    abstract_args = (
        params,
        jax.ShapeDtypeStruct((B, 1), i32),
        pools,
        state,
        jax.ShapeDtypeStruct((B, lay.max_pages_per_seq), i32),
        jax.ShapeDtypeStruct((B,), i32),
    )
    return eng.decode_impl, abstract_args
