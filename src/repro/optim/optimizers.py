"""Optimizers: SGD+momentum (the paper's recipe) and AdamW.

Interface (used by ``qtrain.make_train_step``):

    opt = make_optimizer(cfg)
    state = opt.init(params)
    updates, state = opt.update(grads, state, params, count=step)

Beyond-paper: optimizer state can be held in bf16 with **stochastic
rounding** on the state update (``state_dtype="bfloat16"``).  This is the
paper's own Gupta-et-al. insight applied to the optimizer — tiny moment
updates survive in expectation — and halves optimizer HBM, which is what
lets the 340B config fit a single 256-chip pod (see DESIGN §5).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp


def inv_decay(lr0: float, gamma: float, power: float):
    """The paper's schedule: lr = lr0 · (1 + γ·iter)^-pow (§4)."""
    def f(step):
        return lr0 * (1.0 + gamma * step.astype(jnp.float32)) ** (-power)
    return f


def cosine_schedule(lr0: float, warmup: int, total: int, floor: float = 0.1):
    def f(step):
        s = step.astype(jnp.float32)
        warm = s / max(warmup, 1)
        prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return lr0 * jnp.where(s < warmup, warm, cos)
    return f


def _sr_cast(x: jax.Array, dtype, key) -> jax.Array:
    """Stochastically-rounded downcast (unbiased, Gupta et al.)."""
    if x.dtype == dtype or dtype == jnp.float32:
        return x.astype(dtype)
    # bf16: round fp32 mantissa bits 0..15 stochastically
    bits = jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.uint32)
    noise = jax.random.bits(key, shape=x.shape, dtype=jnp.uint32) & 0xFFFF
    rounded = (bits + noise) & jnp.uint32(0xFFFF0000)
    return jax.lax.bitcast_convert_type(rounded, jnp.float32).astype(dtype)


def _layered(one, g, *rest, key):
    """Apply the per-leaf update ``one(g, *rest, key) -> tuple`` with bounded
    temporaries: layer-stacked leaves (ndim ≥ 3, unsharded leading dim) run
    under ``lax.map`` over the layer axis so the fp32 working copies are one
    layer-slice instead of one full stack each (at 100B+ scale those
    co-scheduled full-stack temporaries dominate step memory)."""
    if g.ndim >= 3 and g.shape[0] > 1 and g.size > (1 << 22):
        keys = jax.random.split(key, g.shape[0])
        return jax.lax.map(lambda xs: one(*xs), (g, *rest, keys))
    return one(g, *rest, key)


def _global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def _clip_by_norm(tree, max_norm: float):
    n = _global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(n, 1e-9))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), tree), n


def _clip_by_norm_shard(g: jax.Array, max_norm: float, axis_name):
    """Shard-local clip against the CROSS-SHARD global norm.

    A ZeRO rank holds one flat slice of the gradient, so the norm that the
    replicated :func:`_clip_by_norm` computes over the whole tree is
    recovered by psum-ing per-shard sums of squares over the data axis
    (zero padding contributes nothing).  ``axis_name=None`` (single shard)
    degrades to the local norm.
    """
    sq = jnp.sum(jnp.square(g.astype(jnp.float32)))
    if axis_name is not None:
        sq = jax.lax.psum(sq, axis_name)
    n = jnp.sqrt(sq)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(n, 1e-9))
    return (g * scale).astype(g.dtype), n


def _shard_key(base: int, count, axis_name):
    """Per-step (and per-rank, under ZeRO) RNG for the stochastic state cast.

    The sharded path folds in ``axis_index`` so bf16 state updates draw
    distinct bits per rank; with fp32 state (``_sr_cast`` is the identity)
    the replicated and sharded paths are bit-identical regardless.
    """
    key = jax.random.fold_in(jax.random.key(base), count)
    if axis_name is not None:
        key = jax.random.fold_in(key, jax.lax.axis_index(axis_name))
    return key


@dataclasses.dataclass(frozen=True)
class SGDConfig:
    lr: float = 0.01
    momentum: float = 0.9
    weight_decay: float = 5e-4
    schedule: str = "inv"          # inv | const
    gamma: float = 1e-4            # paper: 0.0001
    power: float = 0.75            # paper: 0.75
    clip_norm: float = 0.0
    state_dtype: str = "float32"   # float32 | bfloat16 (stochastic-rounded)


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup: int = 100
    total_steps: int = 10_000
    clip_norm: float = 1.0
    state_dtype: str = "float32"


class SGD:
    # precision domain whose ⟨IL, FL⟩ quantizes this optimizer's input
    # gradients (Alg. 1 line 17); the train step looks the format up in its
    # PrecisionPlan registry, so an optimizer wanting a dedicated
    # optimizer-input domain only has to name one here.
    grad_domain = "grads"

    def __init__(self, cfg: SGDConfig):
        self.cfg = cfg
        self.sched = (inv_decay(cfg.lr, cfg.gamma, cfg.power)
                      if cfg.schedule == "inv" else lambda s: cfg.lr)

    def init(self, params):
        dt = jnp.bfloat16 if self.cfg.state_dtype == "bfloat16" else jnp.float32
        return {"mu": jax.tree.map(lambda p: jnp.zeros(p.shape, dt), params)}

    def _state_dtype(self):
        return (jnp.bfloat16 if self.cfg.state_dtype == "bfloat16"
                else jnp.float32)

    def _leaf(self, lr, dt, g, mu, p, k):
        # Shared by update (per-leaf) and update_shard (flat ZeRO slice).
        # Whether LLVM contracts a product-feeding-an-add into an FMA
        # depends on the fused kernel's codegen, i.e. on tensor layout —
        # so the two layouts agree bit-exactly exactly when the scalar
        # products (wd·p, momentum·mu, lr·mu) are exact in f32, e.g. for
        # power-of-two lr/momentum/weight_decay; otherwise they may drift
        # by 1 ULP per step (measured on the CPU backend; no HLO-level
        # construct prevents the contraction).
        cfg = self.cfg
        gf = g.astype(jnp.float32) + cfg.weight_decay * p.astype(jnp.float32)
        mu_new = cfg.momentum * mu.astype(jnp.float32) + gf
        return (-lr * mu_new).astype(p.dtype), _sr_cast(mu_new, dt, k)

    def update(self, grads, state, params, count):
        cfg = self.cfg
        if cfg.clip_norm:
            grads, _ = _clip_by_norm(grads, cfg.clip_norm)
        lr = self.sched(count)
        dt = self._state_dtype()
        key = jax.random.fold_in(jax.random.key(17), count)
        leaves, treedef = jax.tree_util.tree_flatten(state["mu"])
        keys = jax.random.split(key, len(leaves))
        keys = jax.tree_util.tree_unflatten(treedef, list(keys))

        one = lambda g, mu, p, k: self._leaf(lr, dt, g, mu, p, k)
        out = jax.tree.map(one, grads, state["mu"], params, keys)
        updates = jax.tree.map(lambda t: t[0], out,
                               is_leaf=lambda x: isinstance(x, tuple))
        mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda x: isinstance(x, tuple))
        return updates, {"mu": mu}

    # --- ZeRO-1 shard-local interface (see repro.dist.sharding) ---

    def init_shard(self, flat: jax.Array):
        """State for one flat slice (or the whole padded flat vector) of the
        flat ZeRO layout — :class:`~repro.dist.sharding.ZeroPartitioner` or
        the group-aligned :class:`~repro.dist.sharding.GroupAlignedPartitioner`
        (the math is layout-agnostic: padding slots carry zero gradients, so
        their state stays zero)."""
        return {"mu": jnp.zeros(flat.shape, self._state_dtype())}

    def update_shard(self, grads, state, params, count, axis_name=None):
        """One optimizer step on this rank's flat parameter slice.

        Works unchanged over either flat layout (plain or group-aligned —
        the slice is just a 1-D fp32 vector either way).
        Identical element-wise math to :meth:`update` (same ``_leaf``), so
        with fp32 state — and ``clip_norm`` off — the concatenation of
        per-shard updates is bit-exact with the replicated step.
        ``clip_norm`` uses the cross-shard global norm (psum over
        ``axis_name``), which sums squares in a different order than the
        per-leaf :func:`_global_norm`, so the clip scale (and hence the
        update) may differ from the replicated step in the last ULP.
        """
        cfg = self.cfg
        if cfg.clip_norm:
            grads, _ = _clip_by_norm_shard(grads, cfg.clip_norm, axis_name)
        upd, mu = self._leaf(self.sched(count), self._state_dtype(),
                             grads, state["mu"], params,
                             _shard_key(17, count, axis_name))
        return upd, {"mu": mu}


class AdamW:
    grad_domain = "grads"   # see SGD.grad_domain

    def __init__(self, cfg: AdamWConfig):
        self.cfg = cfg
        self.sched = cosine_schedule(cfg.lr, cfg.warmup, cfg.total_steps)

    def init(self, params):
        dt = jnp.bfloat16 if self.cfg.state_dtype == "bfloat16" else jnp.float32
        z = lambda p: jnp.zeros(p.shape, dt)
        return {"m": jax.tree.map(z, params), "v": jax.tree.map(z, params)}

    def _state_dtype(self):
        return (jnp.bfloat16 if self.cfg.state_dtype == "bfloat16"
                else jnp.float32)

    def _bias_corrections(self, count):
        cfg = self.cfg
        t = count.astype(jnp.float32) + 1.0
        return 1.0 - cfg.b1 ** t, 1.0 - cfg.b2 ** t

    def _leaf(self, lr, bc1, bc2, dt, g, m, v, p, k):
        # shared by update (per-leaf) and update_shard (flat ZeRO slice);
        # see SGD._leaf for the FMA-contraction caveat on cross-layout
        # bit-exactness.
        cfg = self.cfg
        gf = g.astype(jnp.float32)
        m_new = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * gf
        v_new = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * gf * gf
        step = m_new / bc1 / (jnp.sqrt(v_new / bc2) + cfg.eps)
        step = step + cfg.weight_decay * p.astype(jnp.float32)
        k1, k2 = jax.random.split(k)
        return ((-lr * step).astype(p.dtype),
                _sr_cast(m_new, dt, k1), _sr_cast(v_new, dt, k2))

    def update(self, grads, state, params, count):
        cfg = self.cfg
        if cfg.clip_norm:
            grads, _ = _clip_by_norm(grads, cfg.clip_norm)
        lr = self.sched(count)
        bc1, bc2 = self._bias_corrections(count)
        dt = self._state_dtype()
        key = jax.random.fold_in(jax.random.key(23), count)
        leaves, treedef = jax.tree_util.tree_flatten(state["m"])
        keys = jax.random.split(key, len(leaves))
        keys = jax.tree_util.tree_unflatten(treedef, list(keys))

        one = lambda g, m, v, p, k: self._leaf(lr, bc1, bc2, dt, g, m, v, p, k)
        out = jax.tree.map(one, grads, state["m"], state["v"], params, keys)
        pick = lambda i: jax.tree.map(lambda t: t[i], out,
                                      is_leaf=lambda x: isinstance(x, tuple))
        return pick(0), {"m": pick(1), "v": pick(2)}

    # --- ZeRO-1 shard-local interface (see repro.dist.sharding) ---

    def init_shard(self, flat: jax.Array):
        """State for one flat slice (or the whole padded flat vector) of the
        flat ZeRO layout (:class:`~repro.dist.sharding.ZeroPartitioner` or
        :class:`~repro.dist.sharding.GroupAlignedPartitioner`).

        ``m`` and ``v`` are distinct buffers on purpose: aliased leaves
        crash buffer donation ("Attempt to donate the same buffer twice")
        under ``jit(..., donate_argnums=...)`` without a resharding copy.
        """
        dt = self._state_dtype()
        return {"m": jnp.zeros(flat.shape, dt), "v": jnp.zeros(flat.shape, dt)}

    def update_shard(self, grads, state, params, count, axis_name=None):
        """One optimizer step on this rank's flat parameter slice.

        Same element-wise math as :meth:`update`; ``clip_norm`` uses the
        cross-shard global norm (psum over ``axis_name``).
        """
        cfg = self.cfg
        if cfg.clip_norm:
            grads, _ = _clip_by_norm_shard(grads, cfg.clip_norm, axis_name)
        bc1, bc2 = self._bias_corrections(count)
        upd, m, v = self._leaf(self.sched(count), bc1, bc2,
                               self._state_dtype(), grads, state["m"],
                               state["v"], params,
                               _shard_key(23, count, axis_name))
        return upd, {"m": m, "v": v}


def make_optimizer(cfg):
    if isinstance(cfg, SGDConfig):
        return SGD(cfg)
    if isinstance(cfg, AdamWConfig):
        return AdamW(cfg)
    raise TypeError(f"unknown optimizer config {type(cfg)}")
