from repro.optim.optimizers import (AdamWConfig, SGDConfig, make_optimizer,
                                    inv_decay, cosine_schedule)
