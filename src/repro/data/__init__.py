from repro.data.mnist import MNISTLike, make_split
from repro.data.synthetic import TokenStream, TokenStreamConfig
