"""Deterministic synthetic token stream (no network access in-container).

Sequences follow a learnable affine-recurrence pattern over the vocab
(`tok_{t+1} = (a·tok_t + c) mod V` with per-sequence (a, c) and flip noise),
so training loss actually falls — convergence dynamics, not just shapes.

The stream is a pure function of ``(seed, step)``: the data-pipeline
checkpoint is the integer step cursor, restart-safe by construction, and
every data-parallel shard slices the same global batch (host-sharded
loading would slice by process index; single-process here).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenStreamConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    noise: float = 0.05     # fraction of positions replaced with uniform noise


class TokenStream:
    """``batch(step) -> {"tokens": (B, S+1) int32}`` — stateless."""

    def __init__(self, cfg: TokenStreamConfig):
        self.cfg = cfg

    def batch(self, step: int):
        cfg = self.cfg
        rng = np.random.RandomState((cfg.seed * 1_000_003 + step) % (2**31))
        B, S, V = cfg.global_batch, cfg.seq_len + 1, cfg.vocab
        a = rng.randint(1, 8, size=(B, 1)).astype(np.int64)
        c = rng.randint(0, V, size=(B, 1)).astype(np.int64)
        t0 = rng.randint(0, V, size=(B, 1)).astype(np.int64)
        toks = np.empty((B, S), np.int64)
        toks[:, :1] = t0
        for t in range(1, S):
            toks[:, t:t + 1] = (a * toks[:, t - 1:t] + c) % V
        flip = rng.rand(B, S) < cfg.noise
        toks[flip] = rng.randint(0, V, size=int(flip.sum()))
        return {"tokens": jnp.asarray(toks, jnp.int32)}

    # checkpointable cursor: the step number itself
    def state(self, step: int) -> dict:
        return {"cursor": step, "seed": self.cfg.seed}

    @staticmethod
    def resume_step(state: dict) -> int:
        return int(state["cursor"])
