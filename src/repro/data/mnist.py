"""Procedural MNIST-class dataset (the container has no network access).

Ten 28×28 digit prototypes are rendered from 7-segment-style strokes, then
augmented per sample with sub-pixel shifts, stroke-thickness jitter and
Gaussian noise.  Deterministic per (split, index).  LeNet reaches >98% test
accuracy on it with the paper's hyper-parameters, so the paper's
convergence *dynamics* (DPS vs fp32 vs fixed-13-bit) reproduce; see
DESIGN §3 for the dataset-substitution note.
"""

from __future__ import annotations

import numpy as np

# 7-segment layout on a 28x28 canvas:
#   A: top bar, B: upper-right, C: lower-right, D: bottom bar,
#   E: lower-left, F: upper-left, G: middle bar
_SEGMENTS = {
    "A": (3, 6, 7, 21),      # (r0, r1, c0, c1) filled rectangle
    "B": (6, 14, 18, 21),
    "C": (14, 22, 18, 21),
    "D": (22, 25, 7, 21),
    "E": (14, 22, 7, 10),
    "F": (6, 14, 7, 10),
    "G": (12, 15, 7, 21),
}
_DIGIT_SEGMENTS = {
    0: "ABCDEF", 1: "BC", 2: "ABGED", 3: "ABGCD", 4: "FGBC",
    5: "AFGCD", 6: "AFGECD", 7: "ABC", 8: "ABCDEFG", 9: "ABCDFG",
}


def _prototype(digit: int) -> np.ndarray:
    img = np.zeros((28, 28), np.float32)
    for s in _DIGIT_SEGMENTS[digit]:
        r0, r1, c0, c1 = _SEGMENTS[s]
        img[r0:r1, c0:c1] = 1.0
    return img


_PROTOS = np.stack([_prototype(d) for d in range(10)])


def _augment(img: np.ndarray, rng: np.random.RandomState) -> np.ndarray:
    dr, dc = rng.randint(-2, 3, size=2)
    out = np.roll(np.roll(img, dr, axis=0), dc, axis=1)
    if rng.rand() < 0.5:                      # thickness jitter (dilate)
        out = np.maximum(out, np.roll(out, 1, axis=rng.randint(2)))
    out = out * (0.75 + 0.5 * rng.rand())     # contrast
    out = out + rng.randn(28, 28).astype(np.float32) * 0.15
    return np.clip(out, 0.0, 1.0)


def make_split(n: int, seed: int):
    """Returns (images (n,28,28,1) f32, labels (n,) i32), deterministic."""
    rng = np.random.RandomState(seed)
    labels = rng.randint(0, 10, size=n).astype(np.int32)
    images = np.stack([_augment(_PROTOS[l], rng) for l in labels])
    return images[..., None].astype(np.float32), labels


class MNISTLike:
    def __init__(self, batch: int = 64, seed: int = 0,
                 n_train: int = 16384, n_test: int = 2048):
        self.batch = batch
        self.train_x, self.train_y = make_split(n_train, seed)
        self.test_x, self.test_y = make_split(n_test, seed + 1)

    def train_batch(self, step: int):
        n = self.train_x.shape[0]
        idx = np.random.RandomState(step).randint(0, n, size=self.batch)
        return {"images": self.train_x[idx], "labels": self.train_y[idx]}

    def test_set(self):
        return {"images": self.test_x, "labels": self.test_y}
