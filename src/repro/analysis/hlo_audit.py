"""Compiled-HLO rule engine: machine-checkable wire claims per config.

Where :mod:`repro.analysis.flow` proves invariants on the traced jaxpr,
this pass proves them on what XLA actually compiled — the two can drift
(fusion, constant folding, collective rewriting), and the wire-byte
contract only exists post-compilation.  It generalizes the one-off
assertions of ``tests/test_train_allreduce.py`` / ``tests/test_zero.py``
into a reusable engine over :class:`AuditClaims`:

``HA-PAYLOAD-DTYPE``
    Wire legs carry s8, never f32: in a step with any engaged wire
    domain, every ``all-to-all`` payload byte must be int8 (the
    all-to-all exists only as the compressed dispatch leg), and an
    engaged ``wire_params`` / two-leg ``wire_grads`` schedule must show
    nonzero s8 ``all-gather`` bytes.

``HA-F32-RESIDUAL``
    With the gradient wire engaged, residual fp32 collective traffic
    (loss/stats syncs) must stay under ``f32_residual_frac`` of the
    ring-model fp32 all-reduce a wire-less step would pay
    (``2 × 4 × n_wire_elems`` bytes) — the compiled-HLO form of the
    ``f32_ar8 < 0.01 · f32_ar`` regression pin.

``HA-F32-CONCAT``
    Grouped/tree schedules encode leaves straight into the int8 buffer:
    fp32 ``concatenate`` bytes must stay under ``f32_concat_budget``.

``HA-WIRE-RATIO``
    Total int8 wire bytes must sit inside declared bounds around the
    ideal two-leg cost (≈ ``2 × n_wire_elems`` bytes for an all-reduce:
    one byte per element per leg) — catches both a missing leg and
    padding blow-ups from a mis-sized quantum.

``HA-DOMAIN-COVERAGE``
    Every *engaged* wire domain must have a matching s8 payload in the
    compiled HLO (``wire_grads`` → all-to-all, ``wire_params`` →
    all-gather).  A domain the config declares and the runtime engages
    but the HLO never serves is exactly the dryrun drift this PR closes.

Serving-side rules (:func:`audit_decode_hlo`, the compiled paged decode
step of :mod:`repro.serve` — the first non-training consumer):

``HA-KV-DTYPE``
    At ``kv_bits=8`` the compiled decode step must carry the KV page pool
    as int8: some s8/u8 tensor at least as large as the stacked pool must
    exist in the HLO (the pool threads the step as a loop carry).

``HA-KV-F32-CACHE``
    No f32 tensor as large as the pool may appear: the fused attention
    dequantizes gathered pages in-register, so a pool-sized f32 array in
    the compiled step means the int8 pages are being expanded into a
    materialized fp32 cache in HBM — the exact cost the paged design
    exists to avoid.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional, Tuple

from repro.analysis.report import Report
from repro.launch.hlo_stats import collective_wire_bytes, concat_bytes

# which collective op serves each wire domain's payload
DOMAIN_PAYLOAD_OPS: Dict[str, Tuple[str, ...]] = {
    "wire_grads": ("all-to-all",),
    "wire_params": ("all-gather",),
}


@dataclasses.dataclass(frozen=True)
class AuditClaims:
    """What a given config promises its compiled HLO looks like.

    ``engaged`` lists the wire domains the runtime will actually drive on
    this mesh (declaration alone is not a claim: a config can declare
    ``wire_grads`` and compile on a mesh where the sync is skipped —
    see ``repro.core.qtrain.wire_sync_engaged``).  ``two_leg`` marks the
    all-reduce schedule (dispatch + gather) as opposed to the ZeRO
    half-collectives.  ``n_wire_elems`` sizes the ratio/residual bounds;
    ``None`` skips them.
    """

    engaged: Tuple[str, ...] = ()
    two_leg: bool = True
    grouped: bool = False
    n_wire_elems: Optional[int] = None
    wire_ratio_bounds: Tuple[float, float] = (0.5, 3.0)
    f32_residual_frac: float = 0.02
    # fp32 collective bytes the config DECLARES (e.g. the ZeRO param
    # all-gather falls back to fp32 when the policy excludes leaves — see
    # qtrain.wire_params_engaged); added on top of the residual budget.
    f32_declared_bytes: float = 0.0
    f32_concat_budget: float = 0.0


def audit_hlo(hlo_text: str, claims: AuditClaims,
              name: str = "hlo") -> Report:
    """Evaluate every HA rule the claims make checkable; returns a Report."""
    report = Report(name=name)
    wire = collective_wire_bytes(hlo_text)
    by_op = wire["by_op_dtype"]
    by_dtype = wire["by_dtype"]

    def op_dtype(op: str, dtype: str) -> float:
        return by_op.get(op, {}).get(dtype, 0.0)

    def op_total(op: str, *dtypes: str) -> float:
        d = by_op.get(op, {})
        return sum(v for k, v in d.items() if not dtypes or k in dtypes)

    int8_total = by_dtype.get("s8", 0.0) + by_dtype.get("u8", 0.0)

    if claims.engaged:
        report.mark_checked("HA-PAYLOAD-DTYPE", "HA-DOMAIN-COVERAGE")
        bad_a2a = op_total("all-to-all") - op_total("all-to-all", "s8", "u8")
        if bad_a2a > 0:
            report.add(
                "HA-PAYLOAD-DTYPE",
                f"{bad_a2a:.0f} non-int8 all-to-all bytes "
                f"({by_op.get('all-to-all')}) — the dispatch leg must ship "
                f"s8 grid integers only", name)
        if claims.two_leg and "wire_grads" in claims.engaged \
                and op_dtype("all-gather", "s8") == 0.0:
            report.add(
                "HA-PAYLOAD-DTYPE",
                "two-leg gradient wire engaged but no s8 all-gather bytes "
                "in the compiled HLO — the gather leg is missing or fp32",
                name)
        for dom in claims.engaged:
            ops = DOMAIN_PAYLOAD_OPS.get(dom)
            if ops is None:
                report.add("HA-DOMAIN-COVERAGE",
                           f"unknown wire domain {dom!r} has no payload-op "
                           f"mapping", name)
                continue
            served = sum(op_dtype(op, "s8") + op_dtype(op, "u8")
                         for op in ops)
            if served == 0.0:
                report.add(
                    "HA-DOMAIN-COVERAGE",
                    f"domain {dom!r} is engaged but the compiled HLO has "
                    f"no int8 {'/'.join(ops)} payload — the declared wire "
                    f"never materialized", name)

    if claims.engaged and claims.n_wire_elems:
        report.mark_checked("HA-F32-RESIDUAL", "HA-WIRE-RATIO")
        f32_ref = 2.0 * 4.0 * claims.n_wire_elems
        f32 = by_dtype.get("f32", 0.0)
        budget = claims.f32_declared_bytes \
            + claims.f32_residual_frac * f32_ref
        if f32 > budget:
            report.add(
                "HA-F32-RESIDUAL",
                f"{f32:.0f} fp32 collective bytes vs a "
                f"{claims.f32_residual_frac:.0%}-of-{f32_ref:.0f}-B "
                f"residual budget (+ {claims.f32_declared_bytes:.0f} B "
                f"declared) — an uncompressed tensor is riding the "
                f"interconnect", name)
        legs = 2.0 if claims.two_leg else 1.0
        ideal = legs * claims.n_wire_elems
        lo, hi = claims.wire_ratio_bounds
        if not (lo * ideal <= int8_total <= hi * ideal):
            report.add(
                "HA-WIRE-RATIO",
                f"{int8_total:.0f} int8 wire bytes outside "
                f"[{lo:.2g}, {hi:.2g}] × ideal {ideal:.0f} B "
                f"({legs:.0f} leg(s) × {claims.n_wire_elems} elems) — a "
                f"missing leg or a padding blow-up", name)

    if claims.grouped:
        report.mark_checked("HA-F32-CONCAT")
        cat = concat_bytes(hlo_text)
        f32_cat = cat["by_dtype"].get("f32", 0.0)
        if f32_cat > claims.f32_concat_budget:
            report.add(
                "HA-F32-CONCAT",
                f"{f32_cat:.0f} fp32 concatenate bytes (budget "
                f"{claims.f32_concat_budget:.0f}) — leaves are being "
                f"flattened through an fp32 intermediate instead of "
                f"encoding straight into the int8 buffer", name)

    return report


_SHAPE_RE = re.compile(r"\b(f32|s8|u8)\[([0-9,]*)\]")


def audit_decode_hlo(hlo_text: str, *, pool_elems: int, bits,
                     name: str = "serve-decode") -> Report:
    """Serving-side claims on a compiled paged decode step.

    ``pool_elems`` is the element count of ONE stacked page pool (K or V:
    ``n_layers · n_pages_total · page_size · kv_heads · head_dim``) — the
    size scale that separates the cache from everything else in the step,
    so tensor-size thresholds need no per-instruction attribution.
    ``bits`` is the engine's ``kv_bits`` (8 or None); at ``None`` only the
    vacuous dtype rule is skipped.
    """
    report = Report(name=name)
    sizes: Dict[str, int] = {"f32": 0, "s8": 0, "u8": 0}
    for m in _SHAPE_RE.finditer(hlo_text):
        dt, dims = m.groups()
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        sizes[dt] = max(sizes[dt], n)

    if bits == 8:
        report.mark_checked("HA-KV-DTYPE", "HA-KV-F32-CACHE")
        big_i8 = max(sizes["s8"], sizes["u8"])
        if big_i8 < pool_elems:
            report.add(
                "HA-KV-DTYPE",
                f"pool holds {pool_elems} elements but the largest int8 "
                f"tensor in the compiled decode step has {big_i8} — the "
                f"paged KV cache is not stored as int8 grid integers", name)
        if sizes["f32"] >= pool_elems:
            report.add(
                "HA-KV-F32-CACHE",
                f"a {sizes['f32']}-element f32 tensor (>= the "
                f"{pool_elems}-element pool) appears in the compiled decode "
                f"step — int8 pages are being dequantized into a "
                f"materialized fp32 cache instead of in-register", name)
    return report
