"""Precision-flow lint: run all three analysis passes over a config grid.

    PYTHONPATH=src python -m repro.analysis.lint                  # full grid
    PYTHONPATH=src python -m repro.analysis.lint --config lenet --zero-opt
    PYTHONPATH=src python -m repro.analysis.lint --config llama3_2_3b \
        --wire-groups per-layer

Each cell builds a REAL train step (the same constructors the launch and
test code use), traces it, compiles it, and proves the wire invariants
three ways: jaxpr dataflow (:mod:`repro.analysis.flow`), compiled-HLO
byte audit (:mod:`repro.analysis.hlo_audit`), and static Pallas call-site
geometry (:mod:`repro.analysis.kernel_checks`).  Exits nonzero on any
violation.

The mesh is one pure data-parallel axis over every visible device
(``xla_force_host_platform_device_count=8`` in CI) — the topology where
the compressed wire paths actually engage, mirroring the dist test legs.
Arch configs (``--config llama3_2_3b``) compile with two probe-sized
layers and a short sequence: the wire schedule per step is
depth-independent (one collective pair regardless of leaf count), so the
shrunk cell proves the same invariants at a fraction of the compile cost.

The mode grid:

* ``baseline``       — no wire: flow rules must pass vacuously-clean.
* ``tree``           — global-format compressed gradient all-reduce
                       (``grad_allreduce_bits=8``, one tree collective
                       pair).
* ``per-layer``      — one wire ⟨IL, FL⟩ per param leaf (grouped tree +
                       group-aligned kernel schedule).
* ``zero``           — ZeRO-1: int8 reduce-scatter + parameter
                       all-gather over the plain flat layout.
* ``zero-per-layer`` — ZeRO-1 + per-layer wire formats: both sharded
                       halves run the grouped codec over the
                       group-aligned flat layout.
* ``zero-overlap``   — ZeRO-1 + the backward-overlapped bucketed wire:
                       one int8 reduce-scatter per bucket in backward
                       ready order over the bucketed aligned layout.
* ``serve-decode``   — the serving engine's paged decode step
                       (:mod:`repro.serve`): flow proves the kv_page
                       wire contract (PF-KV-WIRE), the HLO audit proves
                       the pool stays int8 with no materialized fp32
                       cache (HA-KV-DTYPE / HA-KV-F32-CACHE), and the
                       kernel pass checks the fused paged-attention and
                       page-encode launches at production dims.

``--wire-overlap on`` rebuilds the ``tree`` and ``per-layer`` cells with
the backward-overlapped bucketed wire (:mod:`repro.dist.overlap`) — the
flow pass then additionally proves PF-BUCKET-ENCODE / PF-BUCKET-DECODE
(every bucket encoded exactly once and decoded before the optimizer
consumes it); the same rules are proven on the sharded reduce-scatter
half by the ``zero-overlap`` cell, which carries the overlap intrinsically.
``baseline`` is unaffected.
"""

from __future__ import annotations

import argparse
import dataclasses
import sys
from typing import List, Optional

import jax
import jax.numpy as jnp

import repro.compat  # noqa: F401  (installs the jax.shard_map shim)
from repro.analysis import flow, hlo_audit, kernel_checks
from repro.analysis.report import Report
from repro.core import qtrain
from repro.dist import collectives

MODES = ("baseline", "tree", "per-layer", "zero", "zero-per-layer",
         "zero-overlap", "serve-decode")


def _data_mesh():
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",))


def _mode_qcfg(mode: str, n_ranks: int, wire_controller: str,
               wire_overlap: bool = False,
               guards: bool = False) -> qtrain.QuantConfig:
    kw = dict(enabled=True, controller="paper",
              wire_controller=wire_controller)
    if mode in ("tree", "per-layer"):
        kw["grad_allreduce_bits"] = 8
        kw["wire_overlap"] = wire_overlap
    elif mode in ("zero", "zero-per-layer", "zero-overlap"):
        kw["grad_allreduce_bits"] = 8
        kw["zero_opt_shards"] = n_ranks
        kw["wire_overlap"] = mode == "zero-overlap"
    if guards:
        from repro.resilience import GuardConfig
        kw["guards"] = GuardConfig()
    return qtrain.QuantConfig(**kw)


def _claims(qcfg: qtrain.QuantConfig, mesh, params,
            n_params: int) -> hlo_audit.AuditClaims:
    engaged: List[str] = []
    two_leg = True
    declared_f32 = 0.0
    n_wire = n_params
    if qtrain.wire_sync_engaged(qcfg, mesh):
        engaged.append("wire_grads")
    if qtrain.zero_opt_engaged(qcfg, mesh):
        engaged.append("wire_grads")
        # both sharded legs ship the flat layout's padded element count —
        # under the group-aligned partitioner that exceeds the raw param
        # count (every leaf slot is padded to the wire quantum)
        part = qtrain.zero_partitioner(qcfg, params, qcfg.zero_opt_shards)
        n_wire = part.padded_size
        if qtrain.wire_params_engaged(qcfg, params, mesh):
            engaged.append("wire_params")
        else:
            # the policy excludes leaves: the param all-gather falls back
            # to fp32 BY DESIGN — one declared fp32 gather, one s8 leg
            two_leg = False
            declared_f32 = 4.0 * part.padded_size * 1.25
    if qcfg.guards is not None and engaged:
        # a guarded step compiles the fp32 fallback branch of every wire
        # cond ALONGSIDE the int8 branch (graceful degradation, see
        # repro.resilience + dist/README.md): those bytes are declared
        # capacity, not residual leakage.  Ring model: the non-ZeRO
        # fallback all-reduce counts 2x its payload; the ZeRO fallback
        # pair (reduce-scatter + all-gather) is 1x + 1x over the padded
        # flat layout — both are 2 x 4 B x n_wire (x1.25 padding fudge).
        declared_f32 += 2.0 * 4.0 * n_wire * 1.25
    # grouped (zero-f32-concat) is NOT claimed on the full step: model
    # activations legitimately concatenate in fp32.  The strict concat
    # claim runs on the isolated wire pipeline (_wire_pipeline_report).
    return hlo_audit.AuditClaims(
        engaged=tuple(dict.fromkeys(engaged)),
        two_leg=two_leg,
        grouped=False,
        f32_declared_bytes=declared_f32,
        n_wire_elems=n_wire if engaged else None)


def _kernel_reports(mode: str, leaf_sizes, n_ranks: int,
                    name: str) -> List[Report]:
    """Static geometry of the Pallas launches this cell WOULD run on the
    kernel backend (the TPU tiling is checkable anywhere)."""
    from repro.kernels import ops
    total = sum(leaf_sizes)
    if "per-layer" in mode:
        sizes, groups = tuple(leaf_sizes), len(leaf_sizes)
    else:
        sizes, groups = (total,), 1
    q = collectives.default_wire_quantum(total, groups, "kernel")
    layout = collectives.group_layout(sizes, n_chunks=n_ranks, quantum=q)
    return [
        kernel_checks.check_layout(layout, name=f"{name}/layout"),
        kernel_checks.check_call(
            ops.group_wire_call_geometry(layout.total, groups, q),
            expected_groups=groups, name=f"{name}/encode"),
        kernel_checks.check_call(
            ops.wire_reduce_call_geometry(n_ranks, layout.chunk, groups, q),
            expected_groups=groups, name=f"{name}/reduce"),
    ]


def _wire_pipeline_report(mode: str, leaf_sizes, mesh, name: str,
                          wire_overlap: bool = False) -> Report:
    """Audit the wire pipeline compiled in ISOLATION (the
    ``bench_collectives`` idiom): a shard_map'ed tree all-reduce over
    grad-shaped leaves.  Only here is the zero-f32-concatenate claim
    checkable — a full model step concatenates fp32 activations freely."""
    from jax.sharding import PartitionSpec as P
    from repro.core.fixed_point import FixedPointFormat

    per_layer = mode == "per-layer"
    groups = len(leaf_sizes) if per_layer else 1
    if per_layer:
        fmt = FixedPointFormat(jnp.full((groups,), 3, jnp.int32),
                               jnp.full((groups,), 5, jnp.int32))
    else:
        fmt = FixedPointFormat.create(3, 5)
    tree = {f"leaf{i}": jax.ShapeDtypeStruct((s,), jnp.float32)
            for i, s in enumerate(leaf_sizes)}
    key = jax.eval_shape(lambda: jax.random.key(1))

    def body(tr, k):
        if wire_overlap:
            from repro.dist import overlap as overlap_lib
            mean, _ = overlap_lib.bucketed_allreduce_mean_tree(
                tr, fmt, "data", k)
        else:
            mean, _ = collectives.dps_allreduce_mean_tree(tr, fmt, "data", k)
        return mean

    fn = jax.jit(jax.shard_map(
        body, mesh=mesh,
        in_specs=({k: P() for k in tree}, P()), out_specs=P(),
        check_vma=False))
    hlo = fn.lower(tree, key).compile().as_text()
    claims = hlo_audit.AuditClaims(
        engaged=("wire_grads",), two_leg=True, grouped=True,
        f32_concat_budget=64.0 * groups,
        n_wire_elems=sum(leaf_sizes))
    return hlo_audit.audit_hlo(hlo, claims, name=name)


def _lenet_cell(mode: str, mesh, wire_controller: str,
                wire_overlap: bool = False,
                guards: bool = False) -> List[Report]:
    from repro.models import lenet
    from repro.optim import SGDConfig, make_optimizer

    n = mesh.devices.size
    qcfg = _mode_qcfg(mode, n, wire_controller, wire_overlap, guards)
    params = lenet.init(jax.random.key(0))
    if "per-layer" in mode:
        qcfg = qcfg.with_per_layer_wire(params)
    opt = make_optimizer(SGDConfig())
    # qcfg rides along so ZeRO cells init whichever flat layout the step
    # will use (group-aligned under per-layer wire / overlap)
    opt_state = (qtrain.zero_opt_state(opt, params, n, qcfg=qcfg)
                 if mode.startswith("zero") else opt.init(params))
    state = qtrain.TrainState.create(params, opt_state, qcfg,
                                     jax.random.key(1))
    batch = {"images": jnp.zeros((2 * n, 28, 28, 1), jnp.float32),
             "labels": jnp.zeros((2 * n,), jnp.int32)}
    step = qtrain.make_train_step(lenet.loss_fn, opt, qcfg, mesh=mesh)
    name = f"lenet/{mode}"
    leaf_sizes = [l.size for l in jax.tree.leaves(params)]
    return _step_reports(step, (state, batch), qcfg, mesh, mode,
                         params, leaf_sizes, name, wire_overlap)


def _arch_cell(arch: str, mode: str, mesh, wire_controller: str,
               seq: int, wire_overlap: bool = False,
               guards: bool = False) -> List[Report]:
    from repro.configs.base import ShapeConfig, get_config, smoke
    from repro.launch import specs as specs_lib
    from repro.optim import SGDConfig, make_optimizer

    # the wire schedule is depth/width-independent (one collective pair,
    # G = leaf count), so the smoke-sized config proves the same invariants
    cfg = dataclasses.replace(smoke(get_config(arch)), probe_unroll=True)

    n = mesh.devices.size
    shape = ShapeConfig("lint_train", "train", seq=seq, batch=n)
    qcfg = _mode_qcfg(mode, n, wire_controller, wire_overlap, guards)
    if "per-layer" in mode:
        qcfg = specs_lib.per_layer_wire_qcfg(cfg, qcfg)
    opt = make_optimizer(SGDConfig())
    step = specs_lib.build_train_step(cfg, qcfg, opt, mesh=mesh)
    astate = specs_lib.abstract_train_state(cfg, opt, qcfg, mesh=mesh)
    abatch = specs_lib.train_batch_specs(cfg, shape)
    name = f"{arch}/{mode}"
    leaf_sizes = [l.size for l in jax.tree.leaves(astate.params)]
    return _step_reports(step, (astate, abatch), qcfg, mesh, mode,
                         astate.params, leaf_sizes, name, wire_overlap)


def _step_reports(step, abstract_args, qcfg, mesh, mode: str, params,
                  leaf_sizes, name: str,
                  wire_overlap: bool = False) -> List[Report]:
    n_params = sum(leaf_sizes)
    reports = [flow.analyze_jaxpr(jax.make_jaxpr(step)(*abstract_args),
                                  name=f"{name}/flow")]
    claims = _claims(qcfg, mesh, params, n_params)
    hlo = jax.jit(step).lower(*abstract_args).compile().as_text()
    reports.append(hlo_audit.audit_hlo(hlo, claims, name=f"{name}/hlo"))
    if claims.engaged:
        if mode in ("tree", "per-layer"):
            reports.append(_wire_pipeline_report(mode, leaf_sizes, mesh,
                                                 f"{name}/pipeline",
                                                 wire_overlap))
        reports.extend(_kernel_reports(mode, leaf_sizes, mesh.devices.size,
                                       f"{name}/kernel"))
    return reports


def _serve_cell(config: str) -> List[Report]:
    """The serving decode step: flow + HLO at smoke scale (the wire
    contract is size-independent), kernel geometry at production dims
    (the TPU tiling is what production would launch)."""
    from repro.configs.base import get_config, smoke
    from repro.kernels import ops
    from repro.serve import EngineConfig, PagedLayout, analysis_decode

    arch = "llama3_2_3b" if config == "lenet" else config
    cfg = smoke(get_config(arch))
    # pool sized so one stacked page pool out-counts every legit f32
    # tensor in the smoke step (the 32k-element embed table is largest) —
    # the F32-CACHE threshold then cleanly separates a dequantized pool
    # from model weights
    lay = PagedLayout(page_size=4, n_pages=192, batch_slots=4,
                      max_pages_per_seq=8, max_prompt=16)
    ecfg = EngineConfig(layout=lay, kv_bits=8, attn_backend="jnp",
                        encode_backend="jnp")
    fn, args = analysis_decode(cfg, ecfg)
    name = f"{arch}/serve-decode"

    flow_rep = flow.analyze_jaxpr(jax.make_jaxpr(fn)(*args),
                                  name=f"{name}/flow")
    if "PF-KV-WIRE" not in flow_rep.checked:
        flow_rep.add("PF-KV-WIRE",
                     "decode step never tags its KV pages (kv_page "
                     "landmarks absent) — the page wire contract is "
                     "unverifiable", name)
    reports = [flow_rep]

    pool_elems = (cfg.n_layers * lay.n_pages_total * lay.page_size
                  * cfg.n_kv_heads * cfg.head_dim)
    hlo = jax.jit(fn).lower(*args).compile().as_text()
    reports.append(hlo_audit.audit_decode_hlo(
        hlo, pool_elems=pool_elems, bits=8, name=f"{name}/hlo"))

    prod = get_config(arch)
    B, P, ps, n_pages = 8, 16, 128, 512
    page_elems = ps * prod.n_kv_heads * prod.head_dim
    reports.append(kernel_checks.check_call(
        ops.paged_attn_call_geometry(B, P, n_pages + 1, ps,
                                     prod.n_kv_heads, prod.head_dim),
        expected_groups=n_pages + 1, name=f"{name}/attn-kernel"))
    groups = 2 * prod.n_layers * (P // 2)   # one admission's page encode
    reports.append(kernel_checks.check_call(
        ops.group_wire_call_geometry(groups * page_elems, groups,
                                     page_elems),
        expected_groups=groups, name=f"{name}/encode-kernel"))
    return reports


def lint_cell(config: str, mode: str, mesh=None,
              wire_controller: str = "flexpoint",
              seq: int = 128, wire_overlap: bool = False,
              guards: bool = False) -> List[Report]:
    """All three passes over one (config, mode) cell; returns Reports."""
    if mode == "serve-decode":
        return _serve_cell(config)
    mesh = mesh or _data_mesh()
    if config == "lenet":
        return _lenet_cell(mode, mesh, wire_controller, wire_overlap, guards)
    return _arch_cell(config, mode, mesh, wire_controller, seq, wire_overlap,
                      guards)


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="Statically verify the wire invariants of compiled "
                    "steps (see src/repro/analysis/README.md).")
    ap.add_argument("--config", action="append", default=None,
                    help="config to lint: 'lenet' (default) or an arch "
                         "name from repro.configs.base (repeatable)")
    ap.add_argument("--zero-opt", action="store_true",
                    help="lint only the ZeRO-1 cell (composes with "
                         "--wire-groups per-layer / --wire-overlap on to "
                         "select the group-aligned cells)")
    ap.add_argument("--wire-groups", choices=("global", "per-layer"),
                    default=None,
                    help="lint only the tree (global) or per-layer cell")
    ap.add_argument("--modes", default=None,
                    help=f"comma-separated subset of {MODES}")
    ap.add_argument("--wire-controller", default="flexpoint")
    ap.add_argument("--wire-overlap", choices=("on", "off"), default="off",
                    help="rebuild the tree/per-layer cells with the "
                         "backward-overlapped bucketed wire (the "
                         "zero-overlap cell carries it intrinsically; "
                         "combined with --zero-opt this selects that cell)")
    ap.add_argument("--guards", action="store_true",
                    help="arm the repro.resilience health guards in every "
                         "train cell: the flow pass then proves "
                         "PF-GUARD-TAINT (degradation signals descend "
                         "from wire-leg stats) and the HLO audit admits "
                         "the compiled fp32 fallback branches as declared "
                         "bytes under HA-F32-RESIDUAL")
    ap.add_argument("--seq", type=int, default=128,
                    help="sequence length for arch train cells")
    args = ap.parse_args(argv)

    if args.zero_opt:
        if args.wire_groups == "per-layer":
            modes = ["zero-per-layer"]
        elif args.wire_overlap == "on":
            modes = ["zero-overlap"]
        else:
            modes = ["zero"]
    elif args.wire_groups is not None:
        modes = ["per-layer" if args.wire_groups == "per-layer" else "tree"]
    elif args.modes:
        modes = [m.strip() for m in args.modes.split(",")]
    else:
        modes = list(MODES)
    for m in modes:
        if m not in MODES:
            ap.error(f"unknown mode {m!r} (choose from {MODES})")
    wire_overlap = args.wire_overlap == "on"
    configs = args.config or ["lenet"]

    mesh = _data_mesh()
    print(f"precision-flow lint: {len(jax.devices())} device(s), "
          f"configs={configs}, modes={modes}", flush=True)
    n_viol = 0
    for config in configs:
        for mode in modes:
            try:
                reports = lint_cell(config, mode, mesh,
                                    args.wire_controller, args.seq,
                                    wire_overlap, args.guards)
            except Exception as e:          # a cell that cannot build IS a
                n_viol += 1                 # lint failure, not a skip
                print(f"ERROR {config}/{mode}: {e!r}", flush=True)
                continue
            for r in reports:
                print(f"  {r.summary()}", flush=True)
                n_viol += len(r.violations)
    print(f"precision-flow lint: "
          f"{'CLEAN' if not n_viol else f'{n_viol} violation(s)'}",
          flush=True)
    return 1 if n_viol else 0


if __name__ == "__main__":
    sys.exit(main())
