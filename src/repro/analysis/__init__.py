"""Static precision-flow verifier for the DPS wire pipeline.

Three passes prove — without running a training step — that a compiled
configuration honors the numerical contract the runtime tests sample:

* :mod:`repro.analysis.flow` — jaxpr dataflow: taint-propagates quantized
  values from their declared encode sites (``repro.core.tagging``) and
  flags fp32 on the wire, dequant→requant round-trips, wire stats routed
  to non-wire controllers, and seedless stochastic-rounding paths.
* :mod:`repro.analysis.hlo_audit` — compiled-HLO rule engine: collective
  payload dtype budgets per domain, zero-f32-concatenate in grouped/tree
  steps, two-leg wire-byte ratios, declared-domain coverage.
* :mod:`repro.analysis.kernel_checks` — Pallas call-site geometry:
  SMEM format-table bounds, tile/group alignment, int8 tile minimums,
  scalar-prefetch arity.

``python -m repro.analysis.lint`` runs all three over the launchable
config grid; see ``src/repro/analysis/README.md`` for the rule catalogue.
"""

from repro.analysis.report import Report, Violation  # noqa: F401
