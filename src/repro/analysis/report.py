"""Structured violation reporting shared by the three analysis passes."""

from __future__ import annotations

import dataclasses
from typing import List, Tuple


@dataclasses.dataclass(frozen=True)
class Violation:
    """One broken invariant: a stable rule ID plus human-readable context.

    ``rule`` is the catalogue key (``PF-*`` flow, ``HA-*`` HLO audit,
    ``KG-*`` kernel geometry — see ``src/repro/analysis/README.md``);
    ``where`` points at the offending equation / HLO instruction /
    call site.
    """

    rule: str
    message: str
    where: str = ""

    def __str__(self) -> str:
        loc = f" [{self.where}]" if self.where else ""
        return f"{self.rule}{loc}: {self.message}"


@dataclasses.dataclass
class Report:
    """The outcome of one pass over one subject (a step, an HLO, a site).

    ``checked`` records every rule the pass evaluated, so a clean report
    is evidence the rules RAN, not that the pass silently skipped them.
    """

    name: str
    violations: List[Violation] = dataclasses.field(default_factory=list)
    checked: List[str] = dataclasses.field(default_factory=list)

    @property
    def ok(self) -> bool:
        return not self.violations

    def add(self, rule: str, message: str, where: str = "") -> None:
        self.violations.append(Violation(rule, message, where))

    def mark_checked(self, *rules: str) -> None:
        for r in rules:
            if r not in self.checked:
                self.checked.append(r)

    def merge(self, other: "Report") -> "Report":
        self.violations.extend(other.violations)
        self.mark_checked(*other.checked)
        return self

    def rules_fired(self) -> Tuple[str, ...]:
        return tuple(sorted({v.rule for v in self.violations}))

    def summary(self) -> str:
        head = (f"{self.name}: OK ({len(self.checked)} rules)" if self.ok
                else f"{self.name}: {len(self.violations)} violation(s)")
        body = "".join(f"\n  {v}" for v in self.violations)
        return head + body
