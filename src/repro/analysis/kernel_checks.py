"""Kernel geometry pass: validate Pallas call sites without executing.

Every grouped-wire Pallas launch is fully determined by static Python
ints — the :class:`~repro.dist.collectives.GroupLayout` and the
:class:`~repro.kernels.ops.KernelCallGeometry` the wrappers would build.
This pass re-derives those for a config and proves the tiling contract
instead of waiting for a Mosaic lowering error (or worse, silent wrong
formats) at runtime:

``KG-SMEM-TABLE``
    The SMEM ⟨IL, FL⟩ format table must have exactly G rows for a
    G-group domain, the tile→group map exactly one entry per grid tile,
    and all scalar-prefetch operands together must fit the declared SMEM
    budget (``dps_quant.SMEM_TABLE_BUDGET_BYTES``).

``KG-TILE-STRADDLE``
    The group-aligned layout must keep every grid tile inside one group:
    offsets are the cumulative padded sizes, each padded slot is a
    quantum multiple covering its payload, rank chunks are tile-aligned,
    and the tile→group map is constant within each tile.

``KG-TILE-MIN``
    int8 wire tiles must meet the (32, 128) TPU minimum
    (``dps_quant.INT8_MIN_TILE``) and grouped quanta must be multiples
    of ``MIN_GROUP_QUANTUM`` (= 32·128).

``KG-PREFETCH-ARITY``
    The call site's scalar-prefetch operand count must match the kernel
    body's signature (``dps_quant.KERNEL_SIGNATURES``) — a drifted
    signature shows up here as a named rule, not as an opaque Mosaic
    arity error three layers down.
"""

from __future__ import annotations

from typing import Optional

from repro.analysis.report import Report
from repro.kernels.dps_quant import (INT8_MIN_TILE, KERNEL_SIGNATURES,
                                     MIN_GROUP_QUANTUM,
                                     SMEM_TABLE_BUDGET_BYTES)
from repro.kernels.ops import KernelCallGeometry


def check_call(geom: KernelCallGeometry,
               expected_groups: Optional[int] = None,
               name: str = "kernel-call") -> Report:
    """Validate one prospective launch against the signature registry."""
    report = Report(name=name)
    where = f"{geom.kernel} grid={geom.grid} block={geom.block}"

    report.mark_checked("KG-PREFETCH-ARITY")
    sig = KERNEL_SIGNATURES.get(geom.kernel)
    if sig is None:
        report.add("KG-PREFETCH-ARITY",
                   f"unknown kernel body {geom.kernel!r} — not in "
                   f"dps_quant.KERNEL_SIGNATURES", where)
        return report
    if geom.num_scalar_prefetch != sig.num_scalar_prefetch:
        report.add(
            "KG-PREFETCH-ARITY",
            f"call site prefetches {geom.num_scalar_prefetch} scalar "
            f"operand(s), kernel signature takes {sig.num_scalar_prefetch} "
            f"({', '.join(sig.scalar_operands)})", where)
    if len(geom.scalar_shapes) != sig.num_scalar_prefetch:
        report.add(
            "KG-PREFETCH-ARITY",
            f"{len(geom.scalar_shapes)} scalar operand shape(s) declared "
            f"for a {sig.num_scalar_prefetch}-operand signature", where)

    report.mark_checked("KG-SMEM-TABLE")
    if sig.grouped and geom.table_rows is not None:
        if expected_groups is not None and geom.table_rows != expected_groups:
            report.add(
                "KG-SMEM-TABLE",
                f"format table has {geom.table_rows} rows for "
                f"{expected_groups} group(s) — tiles would resolve formats "
                f"out of the wrong row (or read past the table)", where)
        tiles = 1
        for g in geom.grid:
            tiles *= g
        if geom.tile_group_len is not None and geom.tile_group_len != tiles:
            report.add(
                "KG-SMEM-TABLE",
                f"tile→group map has {geom.tile_group_len} entries for "
                f"{tiles} grid tile(s)", where)
    if geom.smem_table_bytes > SMEM_TABLE_BUDGET_BYTES:
        report.add(
            "KG-SMEM-TABLE",
            f"scalar-prefetch operands take {geom.smem_table_bytes} B of "
            f"SMEM (budget {SMEM_TABLE_BUDGET_BYTES} B) — an over-tall "
            f"format table signals a mis-built layout", where)

    report.mark_checked("KG-TILE-MIN")
    if sig.grouped and geom.quantum is not None \
            and geom.quantum % MIN_GROUP_QUANTUM:
        report.add(
            "KG-TILE-MIN",
            f"grouped quantum {geom.quantum} is not a multiple of "
            f"{MIN_GROUP_QUANTUM} (the 32×128 int8 tile)", where)
    if geom.out_dtype in ("int8", "uint8") or sig.grouped:
        bm, bn = geom.block
        min_m, min_n = INT8_MIN_TILE
        if bm < min_m or bn < min_n or bm % min_m or bn % min_n:
            report.add(
                "KG-TILE-MIN",
                f"block {geom.block} violates the int8 minimum tile "
                f"{INT8_MIN_TILE} (must be a componentwise multiple)",
                where)
    return report


def check_layout(layout, name: str = "group-layout") -> Report:
    """Prove the :class:`GroupLayout` tiling contract on a built layout.

    Accepts anything with the GroupLayout fields (``group_sizes``,
    ``quantum``, ``n_chunks``, ``padded``, ``offsets``, ``chunk``,
    ``total``) so the oracle tests can hand-break individual invariants.
    """
    report = Report(name=name)
    report.mark_checked("KG-TILE-STRADDLE")
    q = layout.quantum
    where = (f"groups={len(layout.group_sizes)} quantum={q} "
             f"chunks={layout.n_chunks}")

    off = 0
    for g, (size, padded, offset) in enumerate(
            zip(layout.group_sizes, layout.padded, layout.offsets)):
        if offset != off:
            report.add(
                "KG-TILE-STRADDLE",
                f"group {g} starts at offset {offset}, expected the "
                f"cumulative padded offset {off} — its first tile would "
                f"straddle the previous group", where)
        if offset % q:
            report.add(
                "KG-TILE-STRADDLE",
                f"group {g} offset {offset} is not tile-aligned "
                f"(quantum {q})", where)
        if padded < size:
            report.add(
                "KG-TILE-STRADDLE",
                f"group {g} padded slot {padded} is smaller than its "
                f"{size}-element payload", where)
        if padded % q:
            report.add(
                "KG-TILE-STRADDLE",
                f"group {g} padded slot {padded} is not a quantum "
                f"multiple — the group's last tile would straddle into "
                f"group {g + 1}", where)
        off = offset + padded

    if layout.chunk % q:
        report.add(
            "KG-TILE-STRADDLE",
            f"rank chunk {layout.chunk} is not a quantum multiple — an "
            f"all_to_all boundary would split a tile across ranks", where)
    if layout.total != layout.n_chunks * layout.chunk:
        report.add(
            "KG-TILE-STRADDLE",
            f"total {layout.total} ≠ n_chunks {layout.n_chunks} × chunk "
            f"{layout.chunk}", where)
    if layout.total < off:
        report.add(
            "KG-TILE-STRADDLE",
            f"total {layout.total} cannot hold the {off} aligned payload "
            f"elements", where)
    return report
