"""Jaxpr dataflow pass: taint-propagation over declared wire tag sites.

The wire pipeline declares its own landmarks at trace time via the
``dps_tag`` identity primitive (:mod:`repro.core.tagging`): encode
entries, decode exits, collective payloads, stats streams, SR bits.  This
pass walks the ClosedJaxpr of any step — train, ZeRO, tree, serve — and
propagates taint labels from those landmarks to prove four invariants:

``PF-WIRE-F32``
    A wire-payload value must reach its collective as int8.  Fires when a
    ``wire_payload``-tainted operand of a collective primitive has a
    non-int8 dtype, and when any ``all_to_all`` carries non-int8 data in
    a step that uses the wire machinery at all (the all-to-all exists in
    this codebase only as the compressed dispatch leg, so fp32 there
    means an encode was skipped).

``PF-REQUANT``
    A decode output feeding an encode input with no intervening compute
    is a pure dequant→requant round-trip: wire bytes and rounding noise
    spent to reproduce (at best) the same payload.  ``decode_out`` taint
    survives only *structural* ops (reshape/slice/transpose/...); any
    arithmetic kills it.

``PF-STATS-ROUTE``
    Wire-leg statistics must steer wire controllers.  Fires when
    ``wire_stats`` taint reaches a ``stats_sink`` tag whose domain is
    declared ``wire=False`` — the PR-4 bug class where compressed-grad
    stats starved the compute-grads controller.

``PF-SR-SEED``
    A stochastic encode's ``sr_bits`` operand must descend from a PRNG
    (threefry/random primitives).  Fires when the bits are constants or
    otherwise PRNG-free — silently deterministic "stochastic" rounding.

``PF-BUCKET-ENCODE`` / ``PF-BUCKET-DECODE``
    The bucketed-wire invariants (:mod:`repro.dist.overlap`).  Every
    leaf the scheduler tags ``wire_bucket stage="ready"`` must reach a
    wire encode at **exactly one** site — zero sites is a dropped leaf
    (its gradient never syncs), two is a double-encoded payload (wire
    bytes and rounding noise spent twice, and under stochastic rounding
    the copies disagree) — and the declared bucket count ``n`` must be
    fully covered by ready tags.  Every ``stage="mean"`` tag must carry
    ``decode_out`` taint (the optimizer consumes a *decoded* bucket, not
    raw wire bytes) and every ready bucket must have one.  Encode sites
    are identified by jaxpr path, so fixpoint re-walks of ``while``
    bodies do not double-count.  Both rules are vacuous (still marked
    checked only when bucket tags exist) on un-bucketed steps.

``PF-GUARD-TAINT``
    The resilience invariant (:mod:`repro.resilience`).  A health-guard
    degradation signal (tagged ``guard_sink``) must descend from
    ``wire_stats`` taint in any wire-enabled step: a guard fed from
    post-fallback values (zero stats fabricated after the fp32 branch)
    or from constants would latch permanently or never trip.  Vacuous in
    steps that never put payload on the wire (the tag sites only mark
    engaged legs).

``PF-KV-WIRE``
    The serving-side invariant (:mod:`repro.serve`).  A paged-KV step
    tags the page-pool writes and reads ``kv_page`` with the configured
    wire width; at ``bits=8`` the tagged value must be int8 grid
    integers — an fp32 page write/read means the decode step silently
    fell back to an uncompressed cache while claiming int8 paging.

Taint crosses ``pjit`` / ``shard_map`` / ``scan`` / ``while`` / ``cond``
/ custom-derivative sub-jaxprs.  ``wire_stats`` and ``prng`` survive all
ops (stats get stacked and reduced; keys get folded); ``wire_payload``,
``decode_out`` and the per-leaf ``bucket_ready:<b>:<g>`` labels survive
only structural ops.
"""

from __future__ import annotations

from typing import Dict, Iterable, Optional, Set, Tuple

import jax
from jax import core as jax_core

from repro.analysis.report import Report
from repro.core import tagging

# primitives that move bytes across ranks
COLLECTIVE_PRIMS = frozenset({
    "psum", "pmax", "pmin", "all_to_all", "all_gather", "ppermute",
    "psum_scatter", "reduce_scatter", "pgather", "all_gather_invariant",
})

# shape/layout-only ops: values pass through unchanged (taint survives)
STRUCTURAL_PRIMS = frozenset({
    "reshape", "transpose", "broadcast_in_dim", "squeeze", "slice",
    "dynamic_slice", "dynamic_update_slice", "concatenate",
    "convert_element_type", "copy", "pad", "rev", "gather", "expand_dims",
    "select_n", "bitcast_convert_type",
})

# taints that die at the first non-structural op
_STRUCTURAL_ONLY = frozenset({"wire_payload", "decode_out"})

# structural-only taint family for bucketed-wire readiness: one label per
# (bucket, leaf), "bucket_ready:<b>:<g>"
_BUCKET_READY = "bucket_ready:"

_INT8 = ("int8", "uint8")


def _is_prng_prim(name: str) -> bool:
    return ("threefry" in name or "prng" in name or name.startswith("random_")
            or name == "rng_bit_generator")


def _aval_dtype(v) -> Optional[str]:
    aval = getattr(v, "aval", None)
    dtype = getattr(aval, "dtype", None)
    return None if dtype is None else str(dtype)


class _Walker:
    """One taint walk over a jaxpr and all of its sub-jaxprs."""

    def __init__(self, report: Report):
        self.report = report
        self.taints: Dict[jax_core.Var, Set[str]] = {}
        self.uses_wire = False          # any wire_payload tag seen anywhere
        # bucketed-wire bookkeeping (repro.dist.overlap): ready-tagged
        # (bucket, leaf) -> set of encode-site jaxpr paths; bucket ->
        # list of (where, descends-from-decode) mean tags; declared
        # bucket count; stage="grad" readiness-tap bucket ids.
        self.bucket_sites: Dict[Tuple[int, int], Set[str]] = {}
        self.bucket_means: Dict[int, list] = {}
        self.bucket_n: int = 0
        self.grad_buckets: Set[int] = set()

    # -- taint bookkeeping -------------------------------------------------

    def t(self, v) -> Set[str]:
        if isinstance(v, jax_core.Literal):
            return set()
        return self.taints.get(v, set())

    def set_t(self, v, labels: Set[str]) -> bool:
        """Union ``labels`` into v's taints; True when anything was new."""
        if isinstance(v, jax_core.Literal) or not labels:
            return False
        cur = self.taints.setdefault(v, set())
        before = len(cur)
        cur |= labels
        return len(cur) != before

    # -- the walk ----------------------------------------------------------

    def walk(self, jaxpr: jax_core.Jaxpr, path: str = "") -> None:
        for i, eqn in enumerate(jaxpr.eqns):
            self.eqn(eqn, f"{path}eqn{i}:{eqn.primitive.name}")

    def eqn(self, eqn, where: str) -> None:
        name = eqn.primitive.name
        in_taints: Set[str] = set()
        for v in eqn.invars:
            in_taints |= self.t(v)

        if name == tagging.TAG_PRIMITIVE_NAME:
            self.tag_eqn(eqn, in_taints, where)
            return

        if self.descend(eqn, where):
            return

        if name in COLLECTIVE_PRIMS:
            self.collective_eqn(eqn, where)

        if _is_prng_prim(name):
            in_taints = in_taints | {"prng"}
        if name not in STRUCTURAL_PRIMS:
            in_taints = {t for t in in_taints
                         if t not in _STRUCTURAL_ONLY
                         and not t.startswith(_BUCKET_READY)}
        for o in eqn.outvars:
            self.set_t(o, in_taints)

    def tag_eqn(self, eqn, in_taints: Set[str], where: str) -> None:
        params = tagging.tag_params(eqn.params) or {}
        kind = params.get("kind", "?")
        dom = params.get("domain")
        out_taints = set(in_taints)

        if kind == "encode_in":
            self.report.mark_checked("PF-REQUANT")
            for t in in_taints:
                if t.startswith(_BUCKET_READY):
                    b, g = (int(p) for p in t[len(_BUCKET_READY):].split(":"))
                    self.bucket_sites.setdefault((b, g), set()).add(where)
            if "decode_out" in in_taints:
                self.report.add(
                    "PF-REQUANT",
                    f"decode output re-enters an encode with no intervening "
                    f"compute (domain {dom!r}): a pure dequant→requant "
                    f"round-trip burning wire bytes and rounding noise",
                    where)
        elif kind == "decode_out":
            out_taints.add("decode_out")
        elif kind == "wire_payload":
            self.uses_wire = True
            out_taints.add("wire_payload")
        elif kind == "wire_stats":
            out_taints.add("wire_stats")
        elif kind == "sr_bits":
            self.report.mark_checked("PF-SR-SEED")
            if "prng" not in in_taints:
                self.report.add(
                    "PF-SR-SEED",
                    f"stochastic-rounding bits (domain {dom!r}) do not "
                    f"descend from any PRNG primitive — the 'stochastic' "
                    f"path is silently deterministic",
                    where)
        elif kind == "wire_bucket":
            stage = params.get("stage")
            b = int(params.get("bucket", -1))
            self.bucket_n = max(self.bucket_n, int(params.get("n", 0)))
            if stage == "ready":
                g = int(params.get("leaf", -1))
                self.bucket_sites.setdefault((b, g), set())
                out_taints.add(f"{_BUCKET_READY}{b}:{g}")
            elif stage == "mean":
                self.bucket_means.setdefault(b, []).append(
                    (where, "decode_out" in in_taints))
            elif stage == "grad":
                self.grad_buckets.add(b)
        elif kind == "kv_page":
            self.report.mark_checked("PF-KV-WIRE")
            bits = int(params.get("bits", 0) or 0)
            dtype = _aval_dtype(eqn.invars[0])
            if bits == 8 and dtype is not None and dtype not in _INT8:
                self.report.add(
                    "PF-KV-WIRE",
                    f"paged KV cache {params.get('stage', '?')} (domain "
                    f"{dom!r}) claims {bits}-bit pages but carries {dtype} "
                    f"— the page pool contract is int8 grid integers",
                    where)
        elif kind == "guard_sink":
            self.report.mark_checked("PF-GUARD-TAINT")
            if self.uses_wire and "wire_stats" not in in_taints:
                self.report.add(
                    "PF-GUARD-TAINT",
                    f"the health-guard signal for domain {dom!r} does not "
                    f"descend from wire-leg statistics — a degradation "
                    f"decision fed by post-fallback (or fabricated) values "
                    f"can never see the storm it exists to detect",
                    where)
        elif kind == "stats_sink":
            self.report.mark_checked("PF-STATS-ROUTE")
            if not params.get("wire", False) and "wire_stats" in in_taints:
                self.report.add(
                    "PF-STATS-ROUTE",
                    f"wire-leg statistics reach the non-wire controller of "
                    f"domain {dom!r} (stream {params.get('stream')!r}) — "
                    f"compressed-wire error/overflow would steer a compute "
                    f"format",
                    where)
        for o in eqn.outvars:
            self.set_t(o, out_taints)

    def collective_eqn(self, eqn, where: str) -> None:
        self.report.mark_checked("PF-WIRE-F32")
        name = eqn.primitive.name
        for v in eqn.invars:
            dtype = _aval_dtype(v)
            if dtype is None or dtype in _INT8:
                continue
            tainted = "wire_payload" in self.t(v)
            if tainted or (name == "all_to_all" and self.uses_wire):
                why = ("a wire-payload value" if tainted else
                       "an all-to-all operand in a wire-enabled step")
                self.report.add(
                    "PF-WIRE-F32",
                    f"{why} reaches collective {name!r} as {dtype} — the "
                    f"wire contract is int8 grid integers only",
                    where)

    def finalize_buckets(self) -> None:
        """Post-walk bucket accounting: PF-BUCKET-ENCODE (every ready
        leaf encoded at exactly one site, declared bucket count covered)
        and PF-BUCKET-DECODE (every ready bucket has a mean tag that
        descends from a wire decode).  Vacuous when the step carries no
        ``wire_bucket`` tags."""
        if not (self.bucket_sites or self.bucket_means or self.grad_buckets):
            return
        self.report.mark_checked("PF-BUCKET-ENCODE", "PF-BUCKET-DECODE")
        ready = {b for b, _ in self.bucket_sites}
        for (b, g), sites in sorted(self.bucket_sites.items()):
            if not sites:
                self.report.add(
                    "PF-BUCKET-ENCODE",
                    f"bucket {b} leaf {g} is tagged ready but never "
                    f"reaches a wire encode — the leaf's gradient would "
                    f"be dropped from the synced mean",
                    "<bucket-finalize>")
            elif len(sites) > 1:
                self.report.add(
                    "PF-BUCKET-ENCODE",
                    f"bucket {b} leaf {g} reaches {len(sites)} distinct "
                    f"wire encodes — a double-encoded payload (wire bytes "
                    f"spent twice; stochastic copies disagree)",
                    sorted(sites)[0])
        if self.bucket_n and ready and ready != set(range(self.bucket_n)):
            missing = sorted(set(range(self.bucket_n)) - ready)
            self.report.add(
                "PF-BUCKET-ENCODE",
                f"the schedule declares {self.bucket_n} buckets but ready "
                f"tags cover only {sorted(ready)} (missing {missing})",
                "<bucket-finalize>")
        if self.grad_buckets and ready and self.grad_buckets != ready:
            self.report.add(
                "PF-BUCKET-ENCODE",
                f"gradient-readiness taps mark buckets "
                f"{sorted(self.grad_buckets)} but the wire consumes "
                f"{sorted(ready)} — scheduler and collective disagree on "
                f"the plan",
                "<bucket-finalize>")
        for b in sorted(ready):
            if b not in self.bucket_means:
                self.report.add(
                    "PF-BUCKET-DECODE",
                    f"bucket {b} has no decoded-mean tag — the optimizer "
                    f"would consume an unsynced (or undecoded) bucket",
                    "<bucket-finalize>")
        for b, entries in sorted(self.bucket_means.items()):
            if not any(ok for _, ok in entries):
                self.report.add(
                    "PF-BUCKET-DECODE",
                    f"bucket {b}'s mean tag does not descend from a wire "
                    f"decode — raw or re-encoded wire bytes would reach "
                    f"the optimizer",
                    entries[0][0])

    # -- sub-jaxpr descent -------------------------------------------------

    def descend(self, eqn, where: str) -> bool:
        """Propagate taint through an eqn's sub-jaxprs.  True when the eqn
        was fully handled here."""
        name = eqn.primitive.name
        params = eqn.params

        if name == "while":
            cn = params.get("cond_nconsts", 0)
            bn = params.get("body_nconsts", 0)
            body = _as_jaxpr(params["body_jaxpr"])
            cond = _as_jaxpr(params["cond_jaxpr"])
            carry = eqn.invars[cn + bn:]
            body_in = list(eqn.invars[cn:cn + bn]) + list(carry)
            # loop-carried taint: iterate the body to a fixpoint
            for _ in range(len(carry) + 2):
                changed = self.run_sub(body, body_in, eqn.outvars,
                                       f"{where}/body/")
                for o, c in zip(eqn.outvars, carry):
                    self.set_t(o, self.t(c))
                body_in = list(eqn.invars[cn:cn + bn]) + list(eqn.outvars)
                if not changed:
                    break
            self.run_sub(cond, list(eqn.invars[:cn]) + list(body_in[bn:]),
                         [], f"{where}/cond/")
            return True

        if name == "cond":
            for b, branch in enumerate(params.get("branches", ())):
                self.run_sub(_as_jaxpr(branch), eqn.invars[1:], eqn.outvars,
                             f"{where}/branch{b}/")
            return True

        for key in ("jaxpr", "call_jaxpr", "fun_jaxpr"):
            sub = params.get(key)
            if sub is None:
                continue
            sub = _as_jaxpr(sub)
            if not isinstance(sub, jax_core.Jaxpr):
                continue
            if len(sub.invars) == len(eqn.invars):
                self.run_sub(sub, eqn.invars, eqn.outvars, f"{where}/")
            else:
                # unknown operand convention: smear every input taint over
                # every invar (conservative, never misses a flow)
                smear: Set[str] = set()
                for v in eqn.invars:
                    smear |= self.t(v)
                for iv in sub.invars:
                    self.set_t(iv, smear)
                self.walk(sub, f"{where}/")
                out: Set[str] = set()
                for ov in sub.outvars:
                    out |= self.t(ov)
                for o in eqn.outvars:
                    self.set_t(o, out)
            return True
        return False

    def run_sub(self, sub: jax_core.Jaxpr, invals, outvals,
                path: str) -> bool:
        """Positionally map taint across a sub-jaxpr boundary; True when
        any outer outval gained taint."""
        for iv, v in zip(sub.invars, invals):
            self.set_t(iv, self.t(v))
        self.walk(sub, path)
        changed = False
        for o, ov in zip(outvals, sub.outvars):
            changed |= self.set_t(o, self.t(ov))
        return changed


def _as_jaxpr(j) -> jax_core.Jaxpr:
    return j.jaxpr if isinstance(j, jax_core.ClosedJaxpr) else j


def analyze_jaxpr(jaxpr, name: str = "step") -> Report:
    """Run the dataflow pass over a (Closed)Jaxpr; returns a Report."""
    report = Report(name=name)
    report.mark_checked("PF-WIRE-F32", "PF-REQUANT",
                        "PF-STATS-ROUTE", "PF-SR-SEED")
    walker = _Walker(report)
    # two passes: the first discovers whether the step uses the wire
    # machinery at all (the all-to-all purity clause of PF-WIRE-F32 only
    # applies then); the second applies it from the start of the jaxpr.
    walker.walk(_as_jaxpr(jaxpr))
    if walker.uses_wire:
        second = _Walker(Report(name=name))
        second.uses_wire = True
        second.walk(_as_jaxpr(jaxpr))
        second.finalize_buckets()
        report.violations = second.report.violations
        report.mark_checked(*second.report.checked)
    else:
        walker.finalize_buckets()
    return report


def analyze_fn(fn, *args, name: str = "step",
               axis_env: Optional[Iterable[Tuple[str, int]]] = None,
               **kwargs) -> Report:
    """Trace ``fn(*args, **kwargs)`` to a jaxpr and analyze it.

    ``axis_env`` (e.g. ``[("data", 8)]``) lets collectives trace outside
    ``shard_map`` — used by the oracle tests; real steps trace as-is.
    """
    mk = jax.make_jaxpr(fn)
    if axis_env is not None:
        mk = jax.make_jaxpr(fn, axis_env=list(axis_env))
    return analyze_jaxpr(mk(*args, **kwargs), name=name)
