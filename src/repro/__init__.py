"""repro: DPS (dynamic precision scaling) training system in JAX.

Importing the package installs small version-compat aliases so the same
source runs on the pinned jaxlib and on newer JAX releases (see
:mod:`repro.compat`).
"""

from repro import compat as _compat

_compat.install()
