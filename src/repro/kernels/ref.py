"""Pure-jnp oracle for the fused DPS quantization kernel.

Semantics contract shared with ``dps_quant.py``: given the same input tensor,
format and uint32 random bits, the kernel must reproduce this function
bit-exactly (fp32 grid math, IL-1+FL <= 24).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.fixed_point import (FixedPointFormat, QuantStats, exp2_int,
                                    quantize, wire_quantize, ROUND_STOCHASTIC)


def dps_quant_ref(x: jax.Array, il: jax.Array, fl: jax.Array,
                  bits: jax.Array, mode: str = ROUND_STOCHASTIC):
    """Returns ``(q, stats_vector)``.

    ``stats_vector`` is the kernel's raw accumulator layout, shape (6,):
    [count, nonzero, overflow, abs_err_sum, rel_err_sum, abs_sum]
    (``max_abs`` is tracked separately as element 7 via max-combine in the
    QuantStats adapter below — the raw kernel returns 7 floats).
    """
    fmt = FixedPointFormat(jnp.asarray(il, jnp.int32), jnp.asarray(fl, jnp.int32))
    q, s = quantize(x, fmt, mode=mode, bits=bits, compute_stats=True)
    vec = jnp.stack([s.count, s.nonzero, s.overflow, s.abs_err_sum,
                     s.rel_err_sum, s.abs_sum, s.max_abs])
    return q, vec


def dps_quant_wire_ref(x: jax.Array, il: jax.Array, fl: jax.Array,
                       bits: jax.Array, mode: str = ROUND_STOCHASTIC):
    """Oracle for the fused *wire* kernel: ``(wire int8, stats_vector[7])``.

    Same accumulator layout as :func:`dps_quant_ref`, but the tensor output
    is the int8 grid-integer payload and int8 saturation is folded into the
    overflow count (see :func:`repro.core.fixed_point.wire_quantize`)."""
    fmt = FixedPointFormat(jnp.asarray(il, jnp.int32), jnp.asarray(fl, jnp.int32))
    wire, s = wire_quantize(x, fmt, mode=mode, bits=bits, compute_stats=True)
    vec = jnp.stack([s.count, s.nonzero, s.overflow, s.abs_err_sum,
                     s.rel_err_sum, s.abs_sum, s.max_abs])
    return wire, vec


def dps_quant_group_wire_ref(x: jax.Array, il: jax.Array, fl: jax.Array,
                             tile_group: jax.Array, bits, mask: jax.Array,
                             quantum: int, mode: str = ROUND_STOCHASTIC):
    """Oracle for the grouped wire kernel: ``(wire [L], stats [G, 7])``.

    ``x``/``bits``/``mask``: flat group-aligned buffers of ``T · quantum``
    elements; ``il``/``fl``: int32 ``[G]`` format table; ``tile_group``:
    int32 ``[T]``.  Per-tile formats come straight from the table rows, so
    this is ``wire_quantize`` with a ``[T]``-shaped leading format followed
    by a segment reduction of the per-tile stats into the group rows —
    exactly what the kernel accumulates on-chip.
    """
    tiles = x.size // quantum
    tg = jnp.asarray(tile_group, jnp.int32)
    fmt = FixedPointFormat(jnp.asarray(il, jnp.int32)[tg],
                           jnp.asarray(fl, jnp.int32)[tg])
    x2 = x.reshape(tiles, quantum)
    b2 = bits.reshape(tiles, quantum) if bits is not None else None
    m2 = mask.reshape(tiles, quantum)
    wire, s = wire_quantize(x2, fmt, mode=mode, bits=b2, compute_stats=True,
                            mask=m2)
    groups = jnp.asarray(il).shape[0]
    seg = lambda v: jax.ops.segment_sum(v, tg, num_segments=groups)
    mx = jnp.maximum(jax.ops.segment_max(s.max_abs, tg, num_segments=groups),
                     0.0)
    stats = jnp.stack([seg(s.count), seg(s.nonzero), seg(s.overflow),
                       seg(s.abs_err_sum), seg(s.rel_err_sum),
                       seg(s.abs_sum), mx], axis=1)
    return wire.reshape(-1), stats


def dps_wire_reduce_ref(wire: jax.Array, fl: jax.Array,
                        tile_group: jax.Array, quantum: int) -> jax.Array:
    """Oracle for the fused decode-reduce kernel: ``(n, chunk)`` int8 →
    fp32 ``[chunk]`` mean, with per-tile FL from the ``[G]`` table."""
    n, chunk = wire.shape
    tiles = chunk // quantum
    inv = exp2_int(-jnp.asarray(fl, jnp.int32))[jnp.asarray(tile_group)]
    dec = wire.reshape(n, tiles, quantum).astype(jnp.float32) * inv[None, :,
                                                                    None]
    return (dec.sum(axis=0) / n).reshape(chunk)


def paged_decode_attn_ref(q: jax.Array, k_pages: jax.Array,
                          v_pages: jax.Array, fmt: jax.Array,
                          ptab: jax.Array, lens: jax.Array,
                          *, scale: float) -> jax.Array:
    """Oracle for the fused paged decode-attention kernel.

    (B, H, Dh) fp32 out of int8 (or fp32 at ``bits=None``) KV page pools,
    a (B, P) page table and per-page FL rows — one page dequantized per
    scan step (the fp32 cache never materializes), online softmax with the
    SAME shared page-step math as the kernel grid, hence bit-exact against
    ``paged_attn_pallas`` in interpret mode.
    """
    from repro.kernels.paged_attn import _paged_attn_jnp
    return _paged_attn_jnp(q, k_pages, v_pages, fmt, ptab, lens, scale=scale)


def stats_from_vector(vec: jax.Array) -> QuantStats:
    return QuantStats(count=vec[0], nonzero=vec[1], overflow=vec[2],
                      abs_err_sum=vec[3], rel_err_sum=vec[4], abs_sum=vec[5],
                      max_abs=vec[6])


def stats_from_matrix(mat: jax.Array) -> QuantStats:
    """``[G, 7]`` grouped-kernel accumulator → ``[G]``-shaped QuantStats."""
    return QuantStats(count=mat[:, 0], nonzero=mat[:, 1], overflow=mat[:, 2],
                      abs_err_sum=mat[:, 3], rel_err_sum=mat[:, 4],
                      abs_sum=mat[:, 5], max_abs=mat[:, 6])
