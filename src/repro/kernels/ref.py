"""Pure-jnp oracle for the fused DPS quantization kernel.

Semantics contract shared with ``dps_quant.py``: given the same input tensor,
format and uint32 random bits, the kernel must reproduce this function
bit-exactly (fp32 grid math, IL-1+FL <= 24).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.fixed_point import (FixedPointFormat, QuantStats, quantize,
                                    wire_quantize, ROUND_STOCHASTIC)


def dps_quant_ref(x: jax.Array, il: jax.Array, fl: jax.Array,
                  bits: jax.Array, mode: str = ROUND_STOCHASTIC):
    """Returns ``(q, stats_vector)``.

    ``stats_vector`` is the kernel's raw accumulator layout, shape (6,):
    [count, nonzero, overflow, abs_err_sum, rel_err_sum, abs_sum]
    (``max_abs`` is tracked separately as element 7 via max-combine in the
    QuantStats adapter below — the raw kernel returns 7 floats).
    """
    fmt = FixedPointFormat(jnp.asarray(il, jnp.int32), jnp.asarray(fl, jnp.int32))
    q, s = quantize(x, fmt, mode=mode, bits=bits, compute_stats=True)
    vec = jnp.stack([s.count, s.nonzero, s.overflow, s.abs_err_sum,
                     s.rel_err_sum, s.abs_sum, s.max_abs])
    return q, vec


def dps_quant_wire_ref(x: jax.Array, il: jax.Array, fl: jax.Array,
                       bits: jax.Array, mode: str = ROUND_STOCHASTIC):
    """Oracle for the fused *wire* kernel: ``(wire int8, stats_vector[7])``.

    Same accumulator layout as :func:`dps_quant_ref`, but the tensor output
    is the int8 grid-integer payload and int8 saturation is folded into the
    overflow count (see :func:`repro.core.fixed_point.wire_quantize`)."""
    fmt = FixedPointFormat(jnp.asarray(il, jnp.int32), jnp.asarray(fl, jnp.int32))
    wire, s = wire_quantize(x, fmt, mode=mode, bits=bits, compute_stats=True)
    vec = jnp.stack([s.count, s.nonzero, s.overflow, s.abs_err_sum,
                     s.rel_err_sum, s.abs_sum, s.max_abs])
    return wire, vec


def stats_from_vector(vec: jax.Array) -> QuantStats:
    return QuantStats(count=vec[0], nonzero=vec[1], overflow=vec[2],
                      abs_err_sum=vec[3], rel_err_sum=vec[4], abs_sum=vec[5],
                      max_abs=vec[6])
