"""jit'd public wrapper around the fused DPS quantization kernel.

``dps_quantize`` accepts any-rank tensors and a dynamic
:class:`~repro.core.fixed_point.FixedPointFormat`, reshapes to the kernel's
2-D tiling, and adapts the raw stats vector back into ``QuantStats``.

On this (CPU) container the kernel runs in Pallas interpret mode; on TPU the
same call lowers to Mosaic.  ``onchip_prng=True`` selects the PRNG-in-kernel
variant (TPU only — see kernel docstring).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.fixed_point import FixedPointFormat, QuantStats
from repro.kernels import ref as ref_lib
from repro.kernels.dps_quant import (DEFAULT_BLOCK, DEFAULT_GROUP_QUANTUM,
                                     dps_quant_pallas,
                                     dps_quant_group_wire_pallas,
                                     dps_quant_wire_pallas,
                                     dps_wire_reduce_pallas, group_block)

_ON_TPU = None


def _on_tpu() -> bool:
    global _ON_TPU
    if _ON_TPU is None:
        _ON_TPU = jax.default_backend() == "tpu"
    return _ON_TPU


# ---------------------------------------------------------------------------
# Static call-site geometry — what each wrapper WOULD launch, computed
# without tracing or executing anything.  ``repro.analysis.kernel_checks``
# builds one of these per Pallas call site reachable from a config and
# validates the tiling/SMEM invariants against
# ``dps_quant.KERNEL_SIGNATURES``.  The builders replicate the exact shape
# arithmetic of the wrappers below; keeping them in this module means a
# wrapper tiling change and its declared geometry are one diff.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class KernelCallGeometry:
    """One prospective Pallas launch, statically described."""

    kernel: str                       # KERNEL_SIGNATURES key
    grid: Tuple[int, ...]
    block: Tuple[int, int]            # (bm, bn) VMEM tile
    out_dtype: str
    num_scalar_prefetch: int          # arity at THIS call site
    scalar_shapes: Tuple[Tuple[int, ...], ...]   # prefetch operand shapes
    table_rows: Optional[int] = None  # G of the [G, 2] SMEM format table
    tile_group_len: Optional[int] = None         # T entries passed
    quantum: Optional[int] = None

    @property
    def smem_table_bytes(self) -> int:
        """int32 bytes of all scalar-prefetch operands at this site."""
        n = 0
        for shp in self.scalar_shapes:
            k = 1
            for d in shp:
                k *= d
            n += 4 * k
        return n


def quantize_call_geometry(size: int, *, block=None,
                           wire: bool = False) -> KernelCallGeometry:
    """Geometry of a :func:`dps_quantize` / :func:`dps_quantize_wire` call
    on a ``size``-element tensor (mirrors ``_fold_and_call`` +
    ``_pallas_quant``)."""
    block = block or DEFAULT_BLOCK
    minor = 1024 if size >= 1024 else max(size, 1)
    major = -(-size // minor)
    bm = min(block[0], major) if major % block[0] else block[0]
    bn = min(block[1], minor) if minor % block[1] else block[1]
    grid = (-(-major // bm), -(-minor // bn))
    return KernelCallGeometry(
        kernel="_kernel", grid=grid, block=(bm, bn),
        out_dtype="int8" if wire else "float32",
        num_scalar_prefetch=1, scalar_shapes=((3,),))


def group_wire_call_geometry(total: int, n_groups: int,
                             quantum: int = DEFAULT_GROUP_QUANTUM
                             ) -> KernelCallGeometry:
    """Geometry of a :func:`dps_quantize_wire_grouped` call on a
    group-aligned ``total``-element buffer with a ``[G, 2]`` table."""
    bm, bn = group_block(quantum)
    tiles = total // quantum
    return KernelCallGeometry(
        kernel="_group_kernel", grid=(tiles,), block=(bm, bn),
        out_dtype="int8", num_scalar_prefetch=3,
        scalar_shapes=((n_groups, 2), (tiles,), (1,)),
        table_rows=n_groups, tile_group_len=tiles, quantum=quantum)


def wire_reduce_call_geometry(n_ranks: int, chunk: int, n_groups: int,
                              quantum: int = DEFAULT_GROUP_QUANTUM
                              ) -> KernelCallGeometry:
    """Geometry of a :func:`dps_wire_reduce` call on an
    ``[n_ranks, chunk]`` payload (includes the internal tail pad)."""
    bm, bn = group_block(quantum)
    tiles = -(-chunk // quantum)
    return KernelCallGeometry(
        kernel="_wire_reduce_kernel", grid=(tiles,), block=(bm, bn),
        out_dtype="float32", num_scalar_prefetch=2,
        scalar_shapes=((n_groups, 2), (tiles,)),
        table_rows=n_groups, tile_group_len=tiles, quantum=quantum)


def paged_attn_call_geometry(batch_slots: int, pages_per_seq: int,
                             n_pages: int, page_size: int, kv_heads: int,
                             head_dim: int) -> KernelCallGeometry:
    """Geometry of a ``paged_attn_pallas`` decode launch (repro.serve).

    Grid is (batch slot, logical page slot); the VMEM tile is one gathered
    int8 KV page viewed as ``(page_size, kv_heads · head_dim)``, which must
    respect the (32, 128) int8 minimum; the SMEM residents are the (B, P)
    page table, the (n_pages, 2) per-page FL table and the (B,) lengths.
    ``quantum`` is the page's element count — also the grouped page-encode
    codec's quantum, so one declaration covers both launches' tiling.
    """
    return KernelCallGeometry(
        kernel="_paged_attn_kernel",
        grid=(batch_slots, pages_per_seq),
        block=(page_size, kv_heads * head_dim),
        out_dtype="float32",
        num_scalar_prefetch=3,
        scalar_shapes=((batch_slots, pages_per_seq), (n_pages, 2),
                       (batch_slots,)),
        table_rows=n_pages,
        tile_group_len=batch_slots * pages_per_seq,
        quantum=page_size * kv_heads * head_dim)


def bucketed_wire_call_geometries(bucket_leaf_sizes, n_ranks: int,
                                  quantum: int = DEFAULT_GROUP_QUANTUM
                                  ) -> Tuple[KernelCallGeometry, ...]:
    """Geometries of the kernel-backend launches ONE bucket of the
    backward-overlapped wire (``repro.dist.overlap``) would run: the
    grouped encode over the bucket's group-aligned buffer plus the fused
    decode-reduce on its ``(n_ranks, chunk)`` payload.  Mirrors the
    per-bucket ``group_layout`` arithmetic (each leaf padded to a quantum
    multiple, the total rounded up to ``n_ranks`` quantum-sized chunks),
    so a bucketed step's kernel schedule is checkable statically — G is
    the bucket's leaf count, not the whole tree's."""
    sizes = tuple(int(s) for s in bucket_leaf_sizes)
    padded = sum(-(-s // quantum) * quantum for s in sizes)
    chunk = (quantum * -(-padded // (n_ranks * quantum)) if padded
             else quantum)
    total = chunk * n_ranks
    return (group_wire_call_geometry(total, len(sizes), quantum),
            wire_reduce_call_geometry(n_ranks, chunk, len(sizes), quantum))


def _fold_and_call(pallas_fn, x, fmt, *, key, bits, stochastic, onchip_prng,
                   block, interpret):
    """Shared any-rank → 2-D tiling adapter around a dps_quant kernel."""
    if interpret is None:
        interpret = not _on_tpu()
    orig_shape = x.shape
    n = x.size
    # fold to 2-D with a 128-lane-friendly minor dim; zero-pad the tail (the
    # kernel's mask operand keeps padded lanes out of the statistics)
    minor = 1024 if n >= 1024 else max(n, 1)
    major = -(-n // minor)
    pad = major * minor - n

    def _fold(v, dtype):
        # an already-aligned size needs no tail: skip the no-op concat copy
        if not pad:
            return v.reshape(major, minor)
        return jnp.concatenate(
            [v.reshape(-1), jnp.zeros((pad,), dtype)]).reshape(major, minor)

    x2 = _fold(x, x.dtype)

    if stochastic and not onchip_prng:
        if bits is None:
            if key is None:
                raise ValueError("stochastic path needs `key` or `bits`")
            bits = jax.random.bits(key, shape=(n,), dtype=jnp.uint32)
        bits2 = _fold(bits, jnp.uint32)
    else:
        bits2 = jnp.zeros((major, minor), jnp.uint32)

    seed = jnp.zeros((), jnp.int32)
    if key is not None:
        seed = jax.random.randint(key, (), 0, 2**31 - 1, jnp.int32)
    fmt3 = jnp.stack([fmt.il.astype(jnp.int32), fmt.fl.astype(jnp.int32), seed])

    mask2 = (None if not pad else
             _fold(jnp.ones((n,), jnp.float32), jnp.float32))

    kwargs = dict(stochastic=stochastic, use_onchip_prng=onchip_prng,
                  interpret=interpret)
    if block is not None:
        kwargs["block"] = block
    q2, vec = pallas_fn(x2, fmt3, bits2, mask2, **kwargs)

    q = q2.reshape(-1)[:n].reshape(orig_shape)
    return q, ref_lib.stats_from_vector(vec)


def dps_quantize(x: jax.Array, fmt: FixedPointFormat, *,
                 key: jax.Array | None = None,
                 bits: jax.Array | None = None,
                 stochastic: bool = True,
                 onchip_prng: bool = False,
                 block=None, interpret: bool | None = None):
    """Fused quantize+stats for an arbitrary-rank tensor.

    Returns ``(q, QuantStats)``.  Exactly matches
    ``repro.kernels.ref.dps_quant_ref`` for the bits-operand path.
    """
    return _fold_and_call(dps_quant_pallas, x, fmt, key=key, bits=bits,
                          stochastic=stochastic, onchip_prng=onchip_prng,
                          block=block, interpret=interpret)


def dps_quantize_wire(x: jax.Array, fmt: FixedPointFormat, *,
                      key: jax.Array | None = None,
                      bits: jax.Array | None = None,
                      stochastic: bool = True,
                      onchip_prng: bool = False,
                      block=None, interpret: bool | None = None):
    """Fused quantize → int8 wire payload + stats for an arbitrary-rank
    tensor, in one read-x/write-wire HBM pass.

    Returns ``(wire int8 with x's shape, QuantStats)``.  Exactly matches
    ``repro.kernels.ref.dps_quant_wire_ref`` (and therefore the jnp codec in
    ``repro.dist.collectives``) for the bits-operand path; int8 saturation
    of over-wide formats is counted into ``stats.overflow``.
    """
    return _fold_and_call(dps_quant_wire_pallas, x, fmt, key=key, bits=bits,
                          stochastic=stochastic, onchip_prng=onchip_prng,
                          block=block, interpret=interpret)


def dps_quantize_wire_grouped(x: jax.Array, fmt: FixedPointFormat,
                              tile_group: jax.Array, *,
                              key: jax.Array | None = None,
                              bits: jax.Array | None = None,
                              mask: jax.Array | None = None,
                              stochastic: bool = True,
                              onchip_prng: bool = False,
                              quantum: int = DEFAULT_GROUP_QUANTUM,
                              interpret: bool | None = None,
                              compute_stats: bool = True):
    """Fused per-group wire encode of a group-aligned flat buffer.

    ``x`` is the group-aligned layout (size = ``len(tile_group) ·
    quantum``; see ``repro.dist.collectives.GroupLayout``), ``fmt`` a
    ``[G]``-shaped format whose rows the tiles index via ``tile_group``.
    ``mask`` (1/0 float32, same size) excludes alignment padding from the
    wire and the stats.  Returns ``(wire int8 with x's size,
    [G]-shaped QuantStats)`` in ONE read-x/write-wire HBM pass;
    ``compute_stats=False`` skips the stats accumulation in the kernel
    and returns ``None``.
    """
    if interpret is None:
        interpret = not _on_tpu()
    n = x.size
    if stochastic and not onchip_prng:
        if bits is None:
            if key is None:
                raise ValueError("stochastic path needs `key` or `bits`")
            bits = jax.random.bits(key, shape=(n,), dtype=jnp.uint32)
        bits = bits.reshape(-1)
    else:
        bits = jnp.zeros((n,), jnp.uint32)
    seed = jnp.zeros((1,), jnp.int32)
    if key is not None:
        seed = jax.random.randint(key, (1,), 0, 2**31 - 1, jnp.int32)
    if mask is None:
        mask = jnp.ones((n,), jnp.float32)
    fmt_tab = jnp.stack([fmt.il.astype(jnp.int32),
                         fmt.fl.astype(jnp.int32)], axis=1)
    wire, mat = dps_quant_group_wire_pallas(
        x.reshape(-1), fmt_tab, jnp.asarray(tile_group, jnp.int32), seed,
        bits, mask.reshape(-1), stochastic=stochastic,
        use_onchip_prng=onchip_prng, quantum=quantum, interpret=interpret,
        emit_stats=compute_stats)
    return wire, (ref_lib.stats_from_matrix(mat) if compute_stats else None)


def dps_wire_reduce(wire: jax.Array, fmt: FixedPointFormat,
                    tile_group: jax.Array | None = None, *,
                    quantum: int = DEFAULT_GROUP_QUANTUM,
                    interpret: bool | None = None) -> jax.Array:
    """Fused int8 decode → mean over the rank axis (the receive leg).

    ``wire``: ``[n_ranks, chunk]`` int8.  A scalar ``fmt`` decodes every
    tile with one FL (``tile_group`` ignored); a ``[G]`` format needs
    ``tile_group`` (``ceil(chunk / quantum)`` entries) mapping this chunk's
    tiles into the table.  Pads the chunk to a quantum multiple internally
    (zero int8 bytes decode to zero and are sliced back off).  Returns the
    fp32 ``[chunk]`` mean without materializing the decoded ``(n, chunk)``
    fp32 intermediate in HBM.
    """
    if interpret is None:
        interpret = not _on_tpu()
    n, chunk = wire.shape
    tiles = -(-chunk // quantum)
    pad = tiles * quantum - chunk
    if pad:
        wire = jnp.pad(wire, ((0, 0), (0, pad)))
    if fmt.il.ndim == 0:
        fmt_tab = jnp.stack([fmt.il, fmt.fl]).astype(jnp.int32)[None, :]
        tile_group = jnp.zeros((tiles,), jnp.int32)
    else:
        if tile_group is None:
            raise ValueError("[G]-shaped formats need a tile_group map")
        fmt_tab = jnp.stack([fmt.il.astype(jnp.int32),
                             fmt.fl.astype(jnp.int32)], axis=1)
        tile_group = jnp.asarray(tile_group, jnp.int32)
        if tile_group.shape[0] != tiles:
            raise ValueError(f"tile_group has {tile_group.shape[0]} entries "
                             f"for {tiles} chunk tiles")
    out = dps_wire_reduce_pallas(wire, fmt_tab, tile_group,
                                 quantum=quantum, interpret=interpret)
    return out[:chunk]
