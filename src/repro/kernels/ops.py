"""jit'd public wrapper around the fused DPS quantization kernel.

``dps_quantize`` accepts any-rank tensors and a dynamic
:class:`~repro.core.fixed_point.FixedPointFormat`, reshapes to the kernel's
2-D tiling, and adapts the raw stats vector back into ``QuantStats``.

On this (CPU) container the kernel runs in Pallas interpret mode; on TPU the
same call lowers to Mosaic.  ``onchip_prng=True`` selects the PRNG-in-kernel
variant (TPU only — see kernel docstring).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.fixed_point import FixedPointFormat, QuantStats
from repro.kernels import ref as ref_lib
from repro.kernels.dps_quant import dps_quant_pallas, dps_quant_wire_pallas

_ON_TPU = None


def _on_tpu() -> bool:
    global _ON_TPU
    if _ON_TPU is None:
        _ON_TPU = jax.default_backend() == "tpu"
    return _ON_TPU


def _fold_and_call(pallas_fn, x, fmt, *, key, bits, stochastic, onchip_prng,
                   block, interpret):
    """Shared any-rank → 2-D tiling adapter around a dps_quant kernel."""
    if interpret is None:
        interpret = not _on_tpu()
    orig_shape = x.shape
    n = x.size
    # fold to 2-D with a 128-lane-friendly minor dim; zero-pad the tail (the
    # kernel's mask operand keeps padded lanes out of the statistics)
    minor = 1024 if n >= 1024 else max(n, 1)
    major = -(-n // minor)
    pad = major * minor - n
    x2 = jnp.concatenate(
        [x.reshape(-1), jnp.zeros((pad,), x.dtype)]).reshape(major, minor)

    if stochastic and not onchip_prng:
        if bits is None:
            if key is None:
                raise ValueError("stochastic path needs `key` or `bits`")
            bits = jax.random.bits(key, shape=(n,), dtype=jnp.uint32)
        bits2 = jnp.concatenate(
            [bits.reshape(-1), jnp.zeros((pad,), jnp.uint32)]).reshape(major, minor)
    else:
        bits2 = jnp.zeros((major, minor), jnp.uint32)

    seed = jnp.zeros((), jnp.int32)
    if key is not None:
        seed = jax.random.randint(key, (), 0, 2**31 - 1, jnp.int32)
    fmt3 = jnp.stack([fmt.il.astype(jnp.int32), fmt.fl.astype(jnp.int32), seed])

    mask2 = jnp.concatenate(
        [jnp.ones((n,), jnp.float32), jnp.zeros((pad,), jnp.float32)]
    ).reshape(major, minor)

    kwargs = dict(stochastic=stochastic, use_onchip_prng=onchip_prng,
                  interpret=interpret)
    if block is not None:
        kwargs["block"] = block
    q2, vec = pallas_fn(x2, fmt3, bits2, mask2, **kwargs)

    q = q2.reshape(-1)[:n].reshape(orig_shape)
    return q, ref_lib.stats_from_vector(vec)


def dps_quantize(x: jax.Array, fmt: FixedPointFormat, *,
                 key: jax.Array | None = None,
                 bits: jax.Array | None = None,
                 stochastic: bool = True,
                 onchip_prng: bool = False,
                 block=None, interpret: bool | None = None):
    """Fused quantize+stats for an arbitrary-rank tensor.

    Returns ``(q, QuantStats)``.  Exactly matches
    ``repro.kernels.ref.dps_quant_ref`` for the bits-operand path.
    """
    return _fold_and_call(dps_quant_pallas, x, fmt, key=key, bits=bits,
                          stochastic=stochastic, onchip_prng=onchip_prng,
                          block=block, interpret=interpret)


def dps_quantize_wire(x: jax.Array, fmt: FixedPointFormat, *,
                      key: jax.Array | None = None,
                      bits: jax.Array | None = None,
                      stochastic: bool = True,
                      onchip_prng: bool = False,
                      block=None, interpret: bool | None = None):
    """Fused quantize → int8 wire payload + stats for an arbitrary-rank
    tensor, in one read-x/write-wire HBM pass.

    Returns ``(wire int8 with x's shape, QuantStats)``.  Exactly matches
    ``repro.kernels.ref.dps_quant_wire_ref`` (and therefore the jnp codec in
    ``repro.dist.collectives``) for the bits-operand path; int8 saturation
    of over-wide formats is counted into ``stats.overflow``.
    """
    return _fold_and_call(dps_quant_wire_pallas, x, fmt, key=key, bits=bits,
                          stochastic=stochastic, onchip_prng=onchip_prng,
                          block=block, interpret=interpret)
