"""Pallas TPU kernel: fused dynamic fixed-point quantize + statistics.

The paper's per-step hot spot is quantizing *every* weight / activation /
gradient tensor and measuring overflow rate R and quantization error E.
Done naively (as in the paper's Caffe layers) that is four passes over HBM:
read x, write q, read both back for the error reduction.  On TPU we fuse the
whole event into one kernel:

    HBM traffic:  read x (+ random bits on the portable path), write q,
                  plus 7 floats of statistics per grid tile.
    VMEM:         one (block_m, block_n) tile at a time; stats are reduced
                  on-tile to scalars and accumulated into a tiny SMEM-resident
                  accumulator that lives across the grid (dimension_semantics
                  = 'arbitrary' keeps the accumulation race-free).

Two tensor-output flavours share one kernel body:

  * ``dps_quant_pallas`` — emulation: write the dequantized grid value q.
  * ``dps_quant_wire_pallas`` — the collectives' **int8 wire**: write the
    grid integer ``round(q·2^FL)`` saturated at [-128, 127] (saturation
    counts into the overflow stat).  The int8 tile is 4× smaller than the
    input tile, so the wire payload costs one read-x/write-wire pass and
    never exists as an fp32 intermediate in HBM.

Two more kernels give the **per-group** wire pipeline the same one-pass
traffic profile (see ``repro.dist.collectives`` for the layout contract):

  * ``dps_quant_group_wire_pallas`` — the wire variant with a ``[G, 2]``
    ⟨IL, FL⟩ **format table** in SMEM plus a tile→group index map: the
    input is a *group-aligned* flat buffer (every group zero-padded to a
    multiple of the ``quantum`` = one grid tile, so a tile never straddles
    groups), each grid tile resolves its own format out of the table, and
    statistics accumulate into a ``[G, N_STATS]`` VMEM accumulator — G
    per-layer formats in ONE kernel launch, same HBM traffic as the
    global-format wire kernel (read x + bits, write int8 wire).
  * ``dps_wire_reduce_pallas`` — the receive leg: reads the post-all_to_all
    ``(n_ranks, chunk)`` int8 payload and emits the fp32 **mean** chunk
    directly (decode → sum over ranks → ÷n on-tile), so the decoded fp32
    ``(n, chunk)`` intermediate never touches HBM: traffic is n·chunk int8
    in + chunk fp32 out, vs 4·n·chunk fp32 write + (4·n+4)·chunk read for
    the naive decode-then-reduce.

HBM traffic accounting per leg (E = elements, n = ranks):

    naive jnp grouped encode   read 4E (fp32 pad/concat) + write 4E + read
                               4E + write E (int8)     ≈ 13E bytes
    grouped wire kernel        read 4E (+4E bits, portable path) + write E
                                                       ≈ 5E (9E) bytes
    naive decode-reduce        read nE, write 4nE, read 4nE + 4E chunk out
    fused dps_wire_reduce      read nE + write 4E·(1/n per rank)

Bucketed wire (``repro.dist.overlap``): the backward-overlapped schedule
runs the SAME two kernels once per bucket instead of once per tree, so
the per-element traffic is unchanged — but the working set of each
launch shrinks from the whole packed tree to one bucket (default 2^16
elements = 256 KiB fp32 in + 64 KiB int8 out), which fits last-level
cache on the CPU emulation path and one VMEM residency on TPU, and each
bucket's group-aligned layout resolves its own size-aware quantum, so a
bucket of small leaves no longer pays the whole tree's per-group
padding.  ``ops.bucketed_wire_call_geometries`` declares the per-bucket
launch pair statically.

Two variants of the stochastic-rounding noise source:

  * ``use_onchip_prng=False`` (default; CPU-validatable): uniform bits enter
    as a second operand.  Bit-exact against ``ref.dps_quant_ref`` — this is
    what the test sweep asserts.
  * ``use_onchip_prng=True`` (TPU fast path): bits come from the per-core
    hardware PRNG (``pltpu.prng_seed``/``prng_random_bits``), halving HBM
    reads.  This container's interpreter cannot execute the PRNG primitive
    (verified: returns zeros), so this path is lowering-validated only and
    is selected by ``ops.dps_quantize(..., onchip_prng=True)`` on real TPUs.

⟨IL, FL⟩ arrive as an SMEM scalar-prefetch operand, so precision changes at
every training step re-use the same compiled kernel.

Block shape: (256, 1024) fp32 tiles = 1 MiB in / 1 MiB out — comfortably
inside the ~16 MiB v5e VMEM budget together with the bits operand (1 MiB)
and double buffering (6 MiB total), MXU-aligned (multiples of (8, 128)).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# JAX renamed pltpu.TPUCompilerParams -> pltpu.CompilerParams; support both so
# the kernel compiles against the pinned jaxlib and newer releases alike.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams")

# stats accumulator layout (must match ref.dps_quant_ref)
N_STATS = 7
_IDX_COUNT, _IDX_NZ, _IDX_OVER, _IDX_AERR, _IDX_RERR, _IDX_ASUM, _IDX_MAX = range(7)

DEFAULT_BLOCK = (256, 1024)
_U_BITS = 24
_U_SCALE = 1.0 / (1 << _U_BITS)

# Group-aligned layout quantum: elements covered by one grid tile of the
# grouped kernels.  32×128 is the minimum int8 tile (sublane × lane), so any
# multiple of 4096 lowers cleanly; larger quanta trade per-group padding for
# fewer grid steps (repro.dist.collectives picks the layout).
MIN_GROUP_QUANTUM = 32 * 128
DEFAULT_GROUP_QUANTUM = MIN_GROUP_QUANTUM


def group_block(quantum: int):
    """(bm, bn) tile shape for a grouped-kernel quantum.

    ``quantum`` must be a multiple of 4096 so the int8 wire tile respects
    the (32, 128) minimum; quanta ≥ 32768 widen to 1024 lanes."""
    if quantum % MIN_GROUP_QUANTUM:
        raise ValueError(f"group quantum must be a multiple of "
                         f"{MIN_GROUP_QUANTUM} (32x128 int8 tile), "
                         f"got {quantum}")
    bn = 1024 if quantum % 1024 == 0 and quantum // 1024 >= 32 else 128
    return quantum // bn, bn


# minimum int8 tile (sublane, lane) — every grouped wire tile must be a
# multiple of this shape (see the TPU tiling rules for 1-byte elements)
INT8_MIN_TILE = (32, 128)

# Per-core SMEM budget the scalar-prefetch operands (format table +
# tile→group map + seed) must fit into.  Real v5e SMEM is far larger, but
# the tables are meant to stay tiny — a [G, 2] int32 table with thousands
# of rows signals a mis-built layout, which is exactly what the analyzer
# flags (rule KG-SMEM-TABLE in repro.analysis.kernel_checks).
SMEM_TABLE_BUDGET_BYTES = 64 * 1024


class KernelSignature:
    """Static facts about one Pallas kernel body, declared beside it.

    ``repro.analysis.kernel_checks`` validates call-site geometry against
    these without executing anything — a signature drift (say a new
    scalar-prefetch operand added to the kernel but not its call sites)
    becomes rule KG-PREFETCH-ARITY instead of a Mosaic lowering error
    three layers deep.
    """

    def __init__(self, num_scalar_prefetch: int, scalar_operands: tuple,
                 grouped: bool):
        self.num_scalar_prefetch = num_scalar_prefetch
        self.scalar_operands = scalar_operands
        self.grouped = grouped


# keyed by kernel-body name; scalar_operands lists the SMEM prefetch refs
# in kernel-signature order
KERNEL_SIGNATURES = {
    "_kernel": KernelSignature(
        num_scalar_prefetch=1, scalar_operands=("fmt3[3]",), grouped=False),
    "_group_kernel": KernelSignature(
        num_scalar_prefetch=3,
        scalar_operands=("fmt_tab[G,2]", "tile_group[T]", "seed[1]"),
        grouped=True),
    "_wire_reduce_kernel": KernelSignature(
        num_scalar_prefetch=2,
        scalar_operands=("fmt_tab[G,2]", "tile_group[T]"),
        grouped=True),
    # body lives in repro.kernels.paged_attn (the serving decode step);
    # declared here so kernel_checks sees every kernel in one registry
    "_paged_attn_kernel": KernelSignature(
        num_scalar_prefetch=3,
        scalar_operands=("page_tab[B,P]", "fmt_tab[n_pages,2]",
                         "seq_lens[B]"),
        grouped=True),
}


def _exp2i(n):
    """Bit-exact 2^n inside the kernel (jnp.exp2 is inexact on some
    backends; matches fixed_point.exp2_int)."""
    n = jnp.clip(n, -126, 127)
    return jax.lax.bitcast_convert_type((n + 127) << 23, jnp.float32)


def _kernel(fmt_ref,            # SMEM: (3,) int32 [il, fl, seed]
            x_ref,              # VMEM: (bm, bn) input tile
            bits_ref,           # VMEM: (bm, bn) uint32 tile (portable path)
            mask_ref,           # VMEM: (bm, bn) float32 1/0 validity tile
            q_ref,              # VMEM out: (bm, bn); int8 wire if emit_wire
            stats_ref,          # SMEM out: (N_STATS,) float32 accumulator
            *, stochastic: bool, use_onchip_prng: bool,
            emit_wire: bool = False):
    i = pl.program_id(0)
    j = pl.program_id(1)

    il = fmt_ref[0]
    fl = fmt_ref[1]
    scale = _exp2i(fl)
    inv_scale = _exp2i(-fl)
    span = _exp2i(il - 1 + fl)
    qmax = span - 1.0
    qmin = -span

    x = x_ref[...].astype(jnp.float32)
    m = mask_ref[...]

    y = x * scale
    over = ((y > qmax) | (y < qmin)).astype(jnp.float32) * m
    yc = jnp.clip(y, qmin, qmax)

    if stochastic:
        if use_onchip_prng:
            # TPU fast path: no bits operand traffic.  Seed is decorrelated
            # per grid tile so every tile draws an independent stream.
            pltpu.prng_seed(fmt_ref[2] + i * pl.num_programs(1) + j)
            bits = pltpu.prng_random_bits(x.shape).astype(jnp.uint32)
        else:
            bits = bits_ref[...]
        u = (bits >> (32 - _U_BITS)).astype(jnp.float32) * _U_SCALE
        q_int = jnp.floor(yc + u)
    else:
        q_int = jnp.floor(yc + 0.5)
    q_int = jnp.clip(q_int, qmin, qmax)
    if emit_wire:
        # wire variant: emit int8 grid integers, saturated at int8 capacity.
        # Saturated elements count as overflow (wire clipping IS overflow
        # from the receiver's point of view) and the error is measured
        # against the decoded wire value, matching fixed_point.wire_quantize.
        sat = jnp.clip(q_int, -128.0, 127.0)
        over = (((y > qmax) | (y < qmin) | (q_int != sat))
                .astype(jnp.float32) * m)
        q_ref[...] = (sat * m).astype(q_ref.dtype)
        q = sat * inv_scale
    else:
        q = q_int * inv_scale
        q_ref[...] = (q * m).astype(q_ref.dtype)

    # --- on-tile stats reduction (rounding error vs clipped reference) ---
    x_ref_val = yc * inv_scale
    abs_err = jnp.abs(q - x_ref_val) * m
    abs_ref = jnp.abs(x_ref_val) * m
    nz = (abs_ref > 0.0).astype(jnp.float32)
    rel = jnp.where(abs_ref > 0.0, abs_err / jnp.where(abs_ref > 0.0, abs_ref, 1.0), 0.0)

    @pl.when((i == 0) & (j == 0))
    def _init():
        for k in range(N_STATS):
            stats_ref[k] = 0.0

    stats_ref[_IDX_COUNT] += jnp.sum(m)
    stats_ref[_IDX_NZ] += jnp.sum(nz)
    stats_ref[_IDX_OVER] += jnp.sum(over)
    stats_ref[_IDX_AERR] += jnp.sum(abs_err)
    stats_ref[_IDX_RERR] += jnp.sum(rel)
    stats_ref[_IDX_ASUM] += jnp.sum(abs_ref)
    stats_ref[_IDX_MAX] = jnp.maximum(stats_ref[_IDX_MAX], jnp.max(jnp.abs(x) * m))


def _pallas_quant(x: jax.Array, fmt3: jax.Array, bits: jax.Array,
                  mask: jax.Array | None,
                  *, stochastic: bool, use_onchip_prng: bool,
                  block, interpret: bool, emit_wire: bool):
    M, N = x.shape
    bm = min(block[0], M) if M % block[0] else block[0]
    bn = min(block[1], N) if N % block[1] else block[1]
    # pad to the tile grid; mask marks the valid region.  When the shape is
    # already tile-aligned the pads would be no-ops that still cost an HBM
    # copy each (x, bits, mask) — skip them.
    Mp = pl.cdiv(M, bm) * bm
    Np = pl.cdiv(N, bn) * bn
    if (Mp, Np) == (M, N):
        xp, bp = x, bits
        if mask is None:
            mask = jnp.ones((M, N), jnp.float32)
    else:
        xp = jnp.pad(x, ((0, Mp - M), (0, Np - N)))
        bp = jnp.pad(bits, ((0, Mp - M), (0, Np - N)))
        if mask is None:
            mask = jnp.pad(jnp.ones((M, N), jnp.float32),
                           ((0, Mp - M), (0, Np - N)))
        else:
            mask = jnp.pad(mask, ((0, Mp - M), (0, Np - N)))

    grid = (Mp // bm, Np // bn)
    out_dtype = jnp.int8 if emit_wire else x.dtype
    kernel = functools.partial(_kernel, stochastic=stochastic,
                               use_onchip_prng=use_onchip_prng,
                               emit_wire=emit_wire)
    q, stats = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                # index maps receive the scalar-prefetch refs as trailing args
                pl.BlockSpec((bm, bn), lambda i, j, *_: (i, j)),
                pl.BlockSpec((bm, bn), lambda i, j, *_: (i, j)),
                pl.BlockSpec((bm, bn), lambda i, j, *_: (i, j)),
            ],
            out_specs=[
                pl.BlockSpec((bm, bn), lambda i, j, *_: (i, j)),
                pl.BlockSpec(memory_space=pltpu.SMEM),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((Mp, Np), out_dtype),
            jax.ShapeDtypeStruct((N_STATS,), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary"),
        ),
        interpret=interpret,
    )(fmt3, xp, bp, mask)
    return q[:M, :N], stats


@functools.partial(jax.jit, static_argnames=("stochastic", "use_onchip_prng",
                                             "block", "interpret"))
def dps_quant_pallas(x: jax.Array, fmt3: jax.Array, bits: jax.Array,
                     mask: jax.Array | None = None,
                     *, stochastic: bool = True, use_onchip_prng: bool = False,
                     block=DEFAULT_BLOCK, interpret: bool = True):
    """Run the fused kernel on a 2-D fp32/bf16 array.

    ``fmt3`` = int32[3] = [il, fl, seed].  ``bits`` uint32, same shape as x
    (ignored when ``use_onchip_prng``).  ``mask`` (float32 1/0, same shape)
    marks elements that belong in the statistics; grid padding added here is
    masked automatically.  Returns ``(q, stats_vec[7])``.
    """
    return _pallas_quant(x, fmt3, bits, mask, stochastic=stochastic,
                         use_onchip_prng=use_onchip_prng, block=block,
                         interpret=interpret, emit_wire=False)


@functools.partial(jax.jit, static_argnames=("stochastic", "use_onchip_prng",
                                             "block", "interpret"))
def dps_quant_wire_pallas(x: jax.Array, fmt3: jax.Array, bits: jax.Array,
                          mask: jax.Array | None = None,
                          *, stochastic: bool = True,
                          use_onchip_prng: bool = False,
                          block=DEFAULT_BLOCK, interpret: bool = True):
    """Fused quantize → **int8 wire** + stats in one read-x/write-wire pass.

    Same contract as :func:`dps_quant_pallas` except the tensor output is
    the int8 grid-integer wire payload (what the collectives ship), with
    int8 saturation folded into the overflow count.  Bit-exact against
    ``ref.dps_quant_wire_ref`` on the portable (bits-operand) path.  The
    int8 tile is 4× smaller than the fp32 input tile, so HBM traffic is
    read-x + write-wire (+ bits on the portable path) — the wire payload
    never exists as an fp32 intermediate in HBM.
    """
    return _pallas_quant(x, fmt3, bits, mask, stochastic=stochastic,
                         use_onchip_prng=use_onchip_prng, block=block,
                         interpret=interpret, emit_wire=True)


# ---------------------------------------------------------------------------
# Grouped wire kernel: [G, 2] SMEM format table, one format per grid tile.
# ---------------------------------------------------------------------------

def _group_kernel(fmt_ref,           # SMEM: (G, 2) int32 [[il, fl], ...]
                  tgrp_ref,          # SMEM: (T,) int32 tile -> group index
                  seed_ref,          # SMEM: (1,) int32 PRNG seed
                  x_ref,             # VMEM: (bm, bn) input tile
                  bits_ref,          # VMEM: (bm, bn) uint32 (portable path)
                  mask_ref,          # VMEM: (bm, bn) float32 validity
                  wire_ref,          # VMEM out: (bm, bn) int8 grid integers
                  stats_ref=None,    # VMEM out: (G, N_STATS); None when the
                                     # caller asked for wire only
                  *, stochastic: bool, use_onchip_prng: bool):
    t = pl.program_id(0)
    g = tgrp_ref[t]
    il = fmt_ref[g, 0]
    fl = fmt_ref[g, 1]

    scale = _exp2i(fl)
    inv_scale = _exp2i(-fl)
    span = _exp2i(il - 1 + fl)
    qmax = span - 1.0
    qmin = -span

    x = x_ref[...].astype(jnp.float32)
    m = mask_ref[...]

    y = x * scale
    yc = jnp.clip(y, qmin, qmax)
    if stochastic:
        if use_onchip_prng:
            pltpu.prng_seed(seed_ref[0] + t)
            bits = pltpu.prng_random_bits(x.shape).astype(jnp.uint32)
        else:
            bits = bits_ref[...]
        u = (bits >> (32 - _U_BITS)).astype(jnp.float32) * _U_SCALE
        q_int = jnp.floor(yc + u)
    else:
        q_int = jnp.floor(yc + 0.5)
    q_int = jnp.clip(q_int, qmin, qmax)
    sat = jnp.clip(q_int, -128.0, 127.0)
    over = (((y > qmax) | (y < qmin) | (q_int != sat))
            .astype(jnp.float32) * m)
    wire_ref[...] = (sat * m).astype(wire_ref.dtype)
    if stats_ref is None:        # wire-only launch (e.g. the receive-side
        return                   # re-encode leg, whose stats nobody reads)
    q = sat * inv_scale

    # --- on-tile stats, accumulated into this tile's group row ---
    x_ref_val = yc * inv_scale
    abs_err = jnp.abs(q - x_ref_val) * m
    abs_ref = jnp.abs(x_ref_val) * m
    nz = (abs_ref > 0.0).astype(jnp.float32)
    rel = jnp.where(abs_ref > 0.0,
                    abs_err / jnp.where(abs_ref > 0.0, abs_ref, 1.0), 0.0)

    @pl.when(t == 0)
    def _init():
        stats_ref[...] = jnp.zeros_like(stats_ref)

    zero = jnp.float32(0)
    row_add = jnp.stack([jnp.sum(m), jnp.sum(nz), jnp.sum(over),
                         jnp.sum(abs_err), jnp.sum(rel), jnp.sum(abs_ref),
                         zero])                       # (N_STATS,), max col 0
    row_max = jnp.stack([zero] * (N_STATS - 1)
                        + [jnp.max(jnp.abs(x) * m)])  # max col only
    G = stats_ref.shape[0]
    onehot = (jax.lax.broadcasted_iota(jnp.int32, (G, 1), 0) == g
              ).astype(jnp.float32)
    cur = stats_ref[...]
    # every stat is >= 0, so one fused update covers both combine rules:
    # sums add their (one-hot-masked) row, the max column maxes against it.
    stats_ref[...] = jnp.maximum(cur + onehot * row_add[None, :],
                                 onehot * row_max[None, :])


@functools.partial(jax.jit, static_argnames=("stochastic", "use_onchip_prng",
                                             "quantum", "interpret",
                                             "emit_stats"))
def dps_quant_group_wire_pallas(x: jax.Array, fmt_tab: jax.Array,
                                tile_group: jax.Array, seed: jax.Array,
                                bits: jax.Array, mask: jax.Array,
                                *, stochastic: bool = True,
                                use_onchip_prng: bool = False,
                                quantum: int = DEFAULT_GROUP_QUANTUM,
                                interpret: bool = True,
                                emit_stats: bool = True):
    """Per-group ⟨IL, FL⟩ wire encode of a group-aligned flat buffer.

    ``x``: flat fp32/bf16 buffer whose size is ``T · quantum`` — the
    group-aligned layout (each group padded to a quantum multiple, so a
    tile never straddles groups; ``mask`` zeroes the padding out of both
    the wire and the statistics).  ``fmt_tab``: int32 ``[G, 2]`` rows of
    ``[IL, FL]`` — the SMEM-prefetched format table.  ``tile_group``:
    int32 ``[T]`` mapping grid tile → table row.  ``bits``/``mask``: same
    size as ``x`` (bits ignored under ``use_onchip_prng``); ``seed``:
    int32 ``[1]`` for the on-chip PRNG.

    Returns ``(wire int8 [T·quantum], stats float32 [G, N_STATS])`` —
    bit-exact against ``ref.dps_quant_group_wire_ref`` on the portable
    path, and against G independent ``dps_quant_wire_pallas`` calls on the
    per-group slices.  One read-x/write-wire HBM pass for all G formats.
    ``emit_stats=False`` drops the accumulator entirely (no per-tile stat
    reductions, no [G, N_STATS] output; stats come back ``None``) — the
    receive-side re-encode leg runs wire-only.
    """
    n = x.size
    if n % quantum:
        raise ValueError(f"group-aligned buffer size {n} is not a multiple "
                         f"of the quantum {quantum}")
    bm, bn = group_block(quantum)
    tiles = n // quantum
    x2 = x.reshape(tiles * bm, bn)
    b2 = bits.reshape(tiles * bm, bn)
    m2 = mask.reshape(tiles * bm, bn)
    G = fmt_tab.shape[0]
    kernel = functools.partial(_group_kernel, stochastic=stochastic,
                               use_onchip_prng=use_onchip_prng)
    out_specs = [pl.BlockSpec((bm, bn), lambda t, *_: (t, 0))]
    out_shape = [jax.ShapeDtypeStruct((tiles * bm, bn), jnp.int8)]
    if emit_stats:
        # the [G, N_STATS] accumulator revisits one block across the
        # whole grid ('arbitrary' semantics keep it race-free)
        out_specs.append(pl.BlockSpec((G, N_STATS), lambda t, *_: (0, 0)))
        out_shape.append(jax.ShapeDtypeStruct((G, N_STATS), jnp.float32))
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=(tiles,),
            in_specs=[
                pl.BlockSpec((bm, bn), lambda t, *_: (t, 0)),
                pl.BlockSpec((bm, bn), lambda t, *_: (t, 0)),
                pl.BlockSpec((bm, bn), lambda t, *_: (t, 0)),
            ],
            out_specs=out_specs,
        ),
        out_shape=out_shape,
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary",),
        ),
        interpret=interpret,
    )(fmt_tab, tile_group, seed, x2, b2, m2)
    wire = out[0].reshape(n)
    return wire, (out[1] if emit_stats else None)


# ---------------------------------------------------------------------------
# Fused int8 decode-reduce: (n_ranks, chunk) wire -> fp32 mean chunk.
# ---------------------------------------------------------------------------

def _wire_reduce_kernel(fmt_ref,     # SMEM: (G, 2) int32 format table
                        tgrp_ref,    # SMEM: (T,) int32 tile -> group
                        w_ref,       # VMEM: (n, bm, bn) int8 wire stack
                        out_ref):    # VMEM out: (bm, bn) fp32 mean tile
    t = pl.program_id(0)
    g = tgrp_ref[t]
    inv_scale = _exp2i(-fmt_ref[g, 1])
    n = w_ref.shape[0]
    dec = w_ref[...].astype(jnp.float32) * inv_scale
    # every decoded value is a multiple of 2^-FL with |w| <= 127, so the
    # fp32 sum is exact for any practical rank count (n·127 < 2^24) and the
    # single ÷n rounds identically to the jnp decode-then-mean path.
    out_ref[...] = jnp.sum(dec, axis=0) / jnp.float32(n)


@functools.partial(jax.jit, static_argnames=("quantum", "interpret"))
def dps_wire_reduce_pallas(wire: jax.Array, fmt_tab: jax.Array,
                           tile_group: jax.Array,
                           *, quantum: int = DEFAULT_GROUP_QUANTUM,
                           interpret: bool = True):
    """Fused decode → sum → mean over the rank axis of an int8 payload.

    ``wire``: int8 ``[n_ranks, chunk]`` (chunk a quantum multiple) — the
    post-``all_to_all`` stack where row i is rank i's contribution to this
    rank's chunk.  ``fmt_tab``/``tile_group``: as in
    :func:`dps_quant_group_wire_pallas`, indexed by this chunk's tiles (a
    global format is the G=1 table).  Returns the fp32 ``[chunk]`` mean —
    the decoded ``(n, chunk)`` fp32 intermediate never exists in HBM.
    """
    n, chunk = wire.shape
    if chunk % quantum:
        raise ValueError(f"chunk {chunk} is not a multiple of the "
                         f"quantum {quantum}")
    bm, bn = group_block(quantum)
    tiles = chunk // quantum
    w3 = wire.reshape(n, tiles * bm, bn)
    out = pl.pallas_call(
        _wire_reduce_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(tiles,),
            in_specs=[
                pl.BlockSpec((n, bm, bn), lambda t, *_: (0, t, 0)),
            ],
            out_specs=pl.BlockSpec((bm, bn), lambda t, *_: (t, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((tiles * bm, bn), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary",),
        ),
        interpret=interpret,
    )(fmt_tab, tile_group, w3)
    return out.reshape(chunk)
