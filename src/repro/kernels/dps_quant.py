"""Pallas TPU kernel: fused dynamic fixed-point quantize + statistics.

The paper's per-step hot spot is quantizing *every* weight / activation /
gradient tensor and measuring overflow rate R and quantization error E.
Done naively (as in the paper's Caffe layers) that is four passes over HBM:
read x, write q, read both back for the error reduction.  On TPU we fuse the
whole event into one kernel:

    HBM traffic:  read x (+ random bits on the portable path), write q,
                  plus 7 floats of statistics per grid tile.
    VMEM:         one (block_m, block_n) tile at a time; stats are reduced
                  on-tile to scalars and accumulated into a tiny SMEM-resident
                  accumulator that lives across the grid (dimension_semantics
                  = 'arbitrary' keeps the accumulation race-free).

Two tensor-output flavours share one kernel body:

  * ``dps_quant_pallas`` — emulation: write the dequantized grid value q.
  * ``dps_quant_wire_pallas`` — the collectives' **int8 wire**: write the
    grid integer ``round(q·2^FL)`` saturated at [-128, 127] (saturation
    counts into the overflow stat).  The int8 tile is 4× smaller than the
    input tile, so the wire payload costs one read-x/write-wire pass and
    never exists as an fp32 intermediate in HBM.

Two variants of the stochastic-rounding noise source:

  * ``use_onchip_prng=False`` (default; CPU-validatable): uniform bits enter
    as a second operand.  Bit-exact against ``ref.dps_quant_ref`` — this is
    what the test sweep asserts.
  * ``use_onchip_prng=True`` (TPU fast path): bits come from the per-core
    hardware PRNG (``pltpu.prng_seed``/``prng_random_bits``), halving HBM
    reads.  This container's interpreter cannot execute the PRNG primitive
    (verified: returns zeros), so this path is lowering-validated only and
    is selected by ``ops.dps_quantize(..., onchip_prng=True)`` on real TPUs.

⟨IL, FL⟩ arrive as an SMEM scalar-prefetch operand, so precision changes at
every training step re-use the same compiled kernel.

Block shape: (256, 1024) fp32 tiles = 1 MiB in / 1 MiB out — comfortably
inside the ~16 MiB v5e VMEM budget together with the bits operand (1 MiB)
and double buffering (6 MiB total), MXU-aligned (multiples of (8, 128)).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# JAX renamed pltpu.TPUCompilerParams -> pltpu.CompilerParams; support both so
# the kernel compiles against the pinned jaxlib and newer releases alike.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams")

# stats accumulator layout (must match ref.dps_quant_ref)
N_STATS = 7
_IDX_COUNT, _IDX_NZ, _IDX_OVER, _IDX_AERR, _IDX_RERR, _IDX_ASUM, _IDX_MAX = range(7)

DEFAULT_BLOCK = (256, 1024)
_U_BITS = 24
_U_SCALE = 1.0 / (1 << _U_BITS)


def _kernel(fmt_ref,            # SMEM: (3,) int32 [il, fl, seed]
            x_ref,              # VMEM: (bm, bn) input tile
            bits_ref,           # VMEM: (bm, bn) uint32 tile (portable path)
            mask_ref,           # VMEM: (bm, bn) float32 1/0 validity tile
            q_ref,              # VMEM out: (bm, bn); int8 wire if emit_wire
            stats_ref,          # SMEM out: (N_STATS,) float32 accumulator
            *, stochastic: bool, use_onchip_prng: bool,
            emit_wire: bool = False):
    i = pl.program_id(0)
    j = pl.program_id(1)

    il = fmt_ref[0]
    fl = fmt_ref[1]
    # bit-exact 2^n (jnp.exp2 is inexact on some backends; matches
    # fixed_point.exp2_int)
    def _exp2i(n):
        n = jnp.clip(n, -126, 127)
        return jax.lax.bitcast_convert_type((n + 127) << 23, jnp.float32)

    scale = _exp2i(fl)
    inv_scale = _exp2i(-fl)
    span = _exp2i(il - 1 + fl)
    qmax = span - 1.0
    qmin = -span

    x = x_ref[...].astype(jnp.float32)
    m = mask_ref[...]

    y = x * scale
    over = ((y > qmax) | (y < qmin)).astype(jnp.float32) * m
    yc = jnp.clip(y, qmin, qmax)

    if stochastic:
        if use_onchip_prng:
            # TPU fast path: no bits operand traffic.  Seed is decorrelated
            # per grid tile so every tile draws an independent stream.
            pltpu.prng_seed(fmt_ref[2] + i * pl.num_programs(1) + j)
            bits = pltpu.prng_random_bits(x.shape).astype(jnp.uint32)
        else:
            bits = bits_ref[...]
        u = (bits >> (32 - _U_BITS)).astype(jnp.float32) * _U_SCALE
        q_int = jnp.floor(yc + u)
    else:
        q_int = jnp.floor(yc + 0.5)
    q_int = jnp.clip(q_int, qmin, qmax)
    if emit_wire:
        # wire variant: emit int8 grid integers, saturated at int8 capacity.
        # Saturated elements count as overflow (wire clipping IS overflow
        # from the receiver's point of view) and the error is measured
        # against the decoded wire value, matching fixed_point.wire_quantize.
        sat = jnp.clip(q_int, -128.0, 127.0)
        over = (((y > qmax) | (y < qmin) | (q_int != sat))
                .astype(jnp.float32) * m)
        q_ref[...] = (sat * m).astype(q_ref.dtype)
        q = sat * inv_scale
    else:
        q = q_int * inv_scale
        q_ref[...] = (q * m).astype(q_ref.dtype)

    # --- on-tile stats reduction (rounding error vs clipped reference) ---
    x_ref_val = yc * inv_scale
    abs_err = jnp.abs(q - x_ref_val) * m
    abs_ref = jnp.abs(x_ref_val) * m
    nz = (abs_ref > 0.0).astype(jnp.float32)
    rel = jnp.where(abs_ref > 0.0, abs_err / jnp.where(abs_ref > 0.0, abs_ref, 1.0), 0.0)

    @pl.when((i == 0) & (j == 0))
    def _init():
        for k in range(N_STATS):
            stats_ref[k] = 0.0

    stats_ref[_IDX_COUNT] += jnp.sum(m)
    stats_ref[_IDX_NZ] += jnp.sum(nz)
    stats_ref[_IDX_OVER] += jnp.sum(over)
    stats_ref[_IDX_AERR] += jnp.sum(abs_err)
    stats_ref[_IDX_RERR] += jnp.sum(rel)
    stats_ref[_IDX_ASUM] += jnp.sum(abs_ref)
    stats_ref[_IDX_MAX] = jnp.maximum(stats_ref[_IDX_MAX], jnp.max(jnp.abs(x) * m))


def _pallas_quant(x: jax.Array, fmt3: jax.Array, bits: jax.Array,
                  mask: jax.Array | None,
                  *, stochastic: bool, use_onchip_prng: bool,
                  block, interpret: bool, emit_wire: bool):
    M, N = x.shape
    if mask is None:
        mask = jnp.ones((M, N), jnp.float32)
    bm = min(block[0], M) if M % block[0] else block[0]
    bn = min(block[1], N) if N % block[1] else block[1]
    # pad to the tile grid; mask marks the valid region
    Mp = pl.cdiv(M, bm) * bm
    Np = pl.cdiv(N, bn) * bn
    xp = jnp.pad(x, ((0, Mp - M), (0, Np - N)))
    bp = jnp.pad(bits, ((0, Mp - M), (0, Np - N)))
    mask = jnp.pad(mask, ((0, Mp - M), (0, Np - N)))

    grid = (Mp // bm, Np // bn)
    out_dtype = jnp.int8 if emit_wire else x.dtype
    kernel = functools.partial(_kernel, stochastic=stochastic,
                               use_onchip_prng=use_onchip_prng,
                               emit_wire=emit_wire)
    q, stats = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                # index maps receive the scalar-prefetch refs as trailing args
                pl.BlockSpec((bm, bn), lambda i, j, *_: (i, j)),
                pl.BlockSpec((bm, bn), lambda i, j, *_: (i, j)),
                pl.BlockSpec((bm, bn), lambda i, j, *_: (i, j)),
            ],
            out_specs=[
                pl.BlockSpec((bm, bn), lambda i, j, *_: (i, j)),
                pl.BlockSpec(memory_space=pltpu.SMEM),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((Mp, Np), out_dtype),
            jax.ShapeDtypeStruct((N_STATS,), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary"),
        ),
        interpret=interpret,
    )(fmt3, xp, bp, mask)
    return q[:M, :N], stats


@functools.partial(jax.jit, static_argnames=("stochastic", "use_onchip_prng",
                                             "block", "interpret"))
def dps_quant_pallas(x: jax.Array, fmt3: jax.Array, bits: jax.Array,
                     mask: jax.Array | None = None,
                     *, stochastic: bool = True, use_onchip_prng: bool = False,
                     block=DEFAULT_BLOCK, interpret: bool = True):
    """Run the fused kernel on a 2-D fp32/bf16 array.

    ``fmt3`` = int32[3] = [il, fl, seed].  ``bits`` uint32, same shape as x
    (ignored when ``use_onchip_prng``).  ``mask`` (float32 1/0, same shape)
    marks elements that belong in the statistics; grid padding added here is
    masked automatically.  Returns ``(q, stats_vec[7])``.
    """
    return _pallas_quant(x, fmt3, bits, mask, stochastic=stochastic,
                         use_onchip_prng=use_onchip_prng, block=block,
                         interpret=interpret, emit_wire=False)


@functools.partial(jax.jit, static_argnames=("stochastic", "use_onchip_prng",
                                             "block", "interpret"))
def dps_quant_wire_pallas(x: jax.Array, fmt3: jax.Array, bits: jax.Array,
                          mask: jax.Array | None = None,
                          *, stochastic: bool = True,
                          use_onchip_prng: bool = False,
                          block=DEFAULT_BLOCK, interpret: bool = True):
    """Fused quantize → **int8 wire** + stats in one read-x/write-wire pass.

    Same contract as :func:`dps_quant_pallas` except the tensor output is
    the int8 grid-integer wire payload (what the collectives ship), with
    int8 saturation folded into the overflow count.  Bit-exact against
    ``ref.dps_quant_wire_ref`` on the portable (bits-operand) path.  The
    int8 tile is 4× smaller than the fp32 input tile, so HBM traffic is
    read-x + write-wire (+ bits on the portable path) — the wire payload
    never exists as an fp32 intermediate in HBM.
    """
    return _pallas_quant(x, fmt3, bits, mask, stochastic=stochastic,
                         use_onchip_prng=use_onchip_prng, block=block,
                         interpret=interpret, emit_wire=True)
