"""Pallas TPU kernel: fused paged decode-attention over an int8 KV cache.

The serving-side hot loop (``repro.serve``) keeps the KV cache as **int8
grid integers** in a paged pool — one page = one ⟨IL, FL⟩ group under the
``kv_cache`` precision domain, encoded by the grouped wire codec
(``dps_quant_group_wire_pallas`` / ``fixed_point.wire_quantize``).  The
naive decode step would dequantize the whole pool to fp32 in HBM before
attending (4× the pool bytes written + read back).  This kernel fuses the
dequantize into the attention read:

    grid = (batch_slot, page_slot); each step gathers ONE physical page of
    K and V straight from the int8 pool (the page table is an SMEM
    scalar-prefetch operand, so the gather is a BlockSpec index_map —
    ``ptab[b, p]`` — and changing page assignments never recompiles),
    multiplies by 2^-FL **in-register** (per-page FL from a second SMEM
    table), and folds the page into an online-softmax accumulator held in
    VMEM scratch.  HBM traffic per decoded token: the int8 pages of the
    sequence + the (tiny) fp32 q/out — the fp32 cache never exists in HBM.

Out-of-range page-table entries simply must point at a valid pool row (the
serve layer reserves a trash page); correctness comes from the sequence-
length mask, which zeroes every position ≥ ``lens[b]`` regardless of what
the gathered page contains.

``_paged_attn_jnp`` is the bit-exact portable reference (same math, same
op order, a ``lax.scan`` over page slots instead of the grid) — it is what
CPU serving runs, re-exported as ``kernels.ref.paged_decode_attn_ref``.
The kernel body is registered in ``dps_quant.KERNEL_SIGNATURES`` and its
call geometry is declared by ``ops.paged_attn_call_geometry`` so
``repro.analysis.kernel_checks`` covers it statically.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.dps_quant import _CompilerParams, _exp2i

# matches models.attention.NEG_INF: finite, so masked-row softmax math
# stays NaN-free (exp(NEG_INF - m) underflows to exactly 0.0)
NEG_INF = -1e30


def _on_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except Exception:  # pragma: no cover - no devices configured
        return False


def _page_attn_step(q, kw, vw, fl_k, fl_v, base, seq_len, m, l, acc, *,
                    scale: float):
    """Fold one KV page into the online-softmax accumulator.

    Shared verbatim by the kernel body and the jnp reference so the two are
    bit-exact: identical op sequence on identical shapes.

    q: (H, Dh) fp32 — the decode-step query for one batch row.
    kw/vw: (page, KV, Dh) int8 grid integers (or fp32 when paging runs at
        ``bits=None``; then FL = 0 and the dequant multiply is exact ×1.0).
    fl_k/fl_v: scalar int32 — this page's FL (per-page grid exponent).
    base: scalar int32 — first absolute position covered by this page.
    seq_len: scalar int32 — valid length of this row (positions ≥ len mask
        to NEG_INF, so trash-page garbage never reaches the output).
    m/l/acc: (H, 1)/(H, 1)/(H, Dh) fp32 running max / normalizer / value.
    """
    ps, KV, Dh = kw.shape
    H = q.shape[0]
    G = H // KV

    k = kw.astype(jnp.float32) * _exp2i(-fl_k)
    v = vw.astype(jnp.float32) * _exp2i(-fl_v)
    # GQA: each KV head serves H/KV query heads (broadcast, not repeat —
    # broadcast_to lowers to a no-copy view on TPU)
    kh = jnp.broadcast_to(k[:, :, None, :], (ps, KV, G, Dh)).reshape(ps, H, Dh)
    vh = jnp.broadcast_to(v[:, :, None, :], (ps, KV, G, Dh)).reshape(ps, H, Dh)

    # scores (H, ps): contract Dh, batch over H
    s = jax.lax.dot_general(q, kh, (((1,), (2,)), ((0,), (1,))),
                            preferred_element_type=jnp.float32)
    idx = base + jax.lax.broadcasted_iota(jnp.int32, (1, ps), 1)
    valid = (idx < seq_len).astype(jnp.float32)
    s = s * scale + jnp.where(valid > 0.0, 0.0, NEG_INF)

    bm = jnp.max(s, axis=1, keepdims=True)
    new_m = jnp.maximum(m, bm)
    p = jnp.exp(s - new_m) * valid
    corr = jnp.exp(m - new_m)
    new_l = l * corr + jnp.sum(p, axis=1, keepdims=True)
    # pv (H, Dh): contract ps, batch over H
    pv = jax.lax.dot_general(p, vh, (((1,), (0,)), ((0,), (1,))),
                             preferred_element_type=jnp.float32)
    new_acc = acc * corr + pv
    return new_m, new_l, new_acc


def _finalize(m, l, acc):
    # fully-masked rows (inactive batch slots) have l == 0 → output 0, not NaN
    return acc / jnp.maximum(l, 1e-30)


def _paged_attn_kernel(ptab_ref,    # SMEM: (B, P) int32 page table
                       fmt_ref,     # SMEM: (n_pages, 2) int32 [fl_k, fl_v]
                       lens_ref,    # SMEM: (B,) int32 valid sequence lengths
                       q_ref,       # VMEM: (1, H, Dh) query block
                       k_ref,       # VMEM: (1, page, KV, Dh) gathered K page
                       v_ref,       # VMEM: (1, page, KV, Dh) gathered V page
                       out_ref,     # VMEM out: (1, H, Dh) fp32
                       m_ref, l_ref, acc_ref,   # VMEM scratch accumulators
                       *, page_size: int, scale: float):
    b = pl.program_id(0)
    p = pl.program_id(1)

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    phys = ptab_ref[b, p]
    m, l, acc = _page_attn_step(
        q_ref[0], k_ref[0], v_ref[0], fmt_ref[phys, 0], fmt_ref[phys, 1],
        p * page_size, lens_ref[b], m_ref[...], l_ref[...], acc_ref[...],
        scale=scale)
    m_ref[...] = m
    l_ref[...] = l
    acc_ref[...] = acc

    @pl.when(p == pl.num_programs(1) - 1)
    def _fin():
        out_ref[0] = _finalize(m_ref[...], l_ref[...],
                               acc_ref[...]).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def paged_attn_pallas(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                      fmt: jax.Array, ptab: jax.Array, lens: jax.Array,
                      *, scale: float, interpret: bool = True):
    """Fused paged decode attention; one launch per decode step.

    ``q``: fp32 (B, H, Dh) single-token queries.  ``k_pages``/``v_pages``:
    (n_pages, page, KV, Dh) int8 pools (fp32 at ``bits=None``).  ``fmt``:
    int32 (n_pages, 2) per-page [FL_k, FL_v].  ``ptab``: int32 (B, P)
    logical→physical page table (entries past a row's last page must point
    at a valid pool row — masked by ``lens``).  ``lens``: int32 (B).
    Returns fp32 (B, H, Dh).
    """
    B, H, Dh = q.shape
    n_pages, ps, KV, _ = k_pages.shape
    P = ptab.shape[1]
    kernel = functools.partial(_paged_attn_kernel, page_size=ps, scale=scale)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=(B, P),
            in_specs=[
                pl.BlockSpec((1, H, Dh), lambda b, p, *_: (b, 0, 0)),
                # the page gather: scalar-prefetch refs arrive as trailing
                # index_map args, so the block index is ptab[b, p]
                pl.BlockSpec((1, ps, KV, Dh),
                             lambda b, p, ptab, fmt, lens: (ptab[b, p], 0, 0, 0)),
                pl.BlockSpec((1, ps, KV, Dh),
                             lambda b, p, ptab, fmt, lens: (ptab[b, p], 0, 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, H, Dh), lambda b, p, *_: (b, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((H, 1), jnp.float32),
                pltpu.VMEM((H, 1), jnp.float32),
                pltpu.VMEM((H, Dh), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, H, Dh), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary"),
        ),
        interpret=interpret,
    )(ptab, fmt, lens, q, k_pages, v_pages)


def _paged_attn_jnp(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                    fmt: jax.Array, ptab: jax.Array, lens: jax.Array,
                    *, scale: float):
    """Bit-exact portable reference (and the CPU serving path).

    Python loop over batch rows + ``lax.scan`` over page slots, calling the
    SAME ``_page_attn_step`` on the same shapes as the kernel grid — so the
    interpret-mode kernel and this function agree bitwise.  Never
    materializes the dequantized pool: one page is decoded per scan step.
    """
    B, H, Dh = q.shape
    ps = k_pages.shape[1]
    P = ptab.shape[1]

    def one_row(qb, ptab_b, len_b):
        def body(carry, p):
            m, l, acc = carry
            phys = ptab_b[p]
            kw = jax.lax.dynamic_index_in_dim(k_pages, phys, keepdims=False)
            vw = jax.lax.dynamic_index_in_dim(v_pages, phys, keepdims=False)
            m, l, acc = _page_attn_step(qb, kw, vw, fmt[phys, 0], fmt[phys, 1],
                                        p * ps, len_b, m, l, acc, scale=scale)
            return (m, l, acc), None

        init = (jnp.full((H, 1), NEG_INF, jnp.float32),
                jnp.zeros((H, 1), jnp.float32),
                jnp.zeros((H, Dh), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(body, init, jnp.arange(P, dtype=jnp.int32))
        return _finalize(m, l, acc)

    # unrolled over B (small at serving batch sizes) rather than vmapped:
    # vmap batches the dot_generals into different contraction shapes, which
    # need not round identically to the kernel's per-row grid steps.
    return jnp.stack([one_row(q[b], ptab[b], lens[b]) for b in range(B)])


def paged_decode_attn(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                      fmt: jax.Array, ptab: jax.Array, lens: jax.Array,
                      *, scale: float, backend: str = "auto",
                      interpret: bool | None = None):
    """Backend-dispatching entry point (same contract as the kernel).

    ``backend``: "kernel" (Pallas; interpret off-TPU), "jnp" (the scan
    reference), or "auto" (kernel on TPU, jnp elsewhere — interpret-mode
    Pallas inside the serving loop would pay a per-step lowering tax).
    """
    if backend == "auto":
        backend = "kernel" if _on_tpu() else "jnp"
    if backend == "kernel":
        if interpret is None:
            interpret = not _on_tpu()
        return paged_attn_pallas(q, k_pages, v_pages, fmt, ptab, lens,
                                 scale=scale, interpret=interpret)
    if backend != "jnp":
        raise ValueError(f"unknown paged-attention backend {backend!r}")
    return _paged_attn_jnp(q, k_pages, v_pages, fmt, ptab, lens, scale=scale)
