"""Paper Fig. 3: bit-width trajectories for weights / activations / grads.

Validates: widths are greatly reduced from the 32-bit baseline, and
gradients keep the most bits ("requires the most precision" — §4).
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import save_result, steps
from repro.apps.mnist import paper_quant_config, train_mnist


def run():
    n = steps(300, 2000)
    h = train_mnist(paper_quant_config(), steps=n)
    stride = max(1, n // 100)
    bits = {a: list(np.add(h[f"il_{a}"], h[f"fl_{a}"])[::stride].astype(float))
            for a in ("w", "a", "g")}
    out = {
        "steps": n,
        "trajectory": bits,
        "avg_bits": {a: h[f"avg_bits_{a}"] for a in ("w", "a", "g")},
        "claims": {
            "all_below_32": bool(max(max(b) for b in bits.values()) < 32),
            "grads_widest": bool(h["avg_bits_g"] >= h["avg_bits_w"]
                                 and h["avg_bits_g"] >= h["avg_bits_a"]),
        },
    }
    save_result("bitwidths", out)
    return out


if __name__ == "__main__":
    import json
    r = run()
    print(json.dumps({"avg_bits": r["avg_bits"], "claims": r["claims"]},
                     indent=1))
