"""Gupta et al. comparison (§3): stochastic vs round-to-nearest on
low-width WEIGHTS (activations 16-bit, gradients wide).

Gupta's claim is about the weight update: below half a grid step, RTN
always rounds the update away while stochastic rounding preserves it in
expectation.  At ⟨2,8⟩ (10-bit weights, grid 2^-8) typical LeNet updates
sit under the half-grid and the separation is visible.

We also record the reverse regime found during reproduction (documented in
EXPERIMENTS.md): quantizing the raw GRADIENTS coarsely favors RTN —
stochastic kicks tiny gradients to ±grid with correct mean but huge
variance, which destabilizes SGD+momentum; see bench_convergence's
all-static 13-bit run (fails under both roundings, the paper's Fig. 4)."""

from __future__ import annotations

from benchmarks.common import save_result, steps
from repro.apps.mnist import paper_quant_config, train_mnist


def run():
    n = steps(300, 2000)
    out = {"steps": n}
    for bits in (12, 10):
        for mode in ("stochastic", "nearest"):
            q = paper_quant_config(rounding=mode, static_bits=bits,
                                   static_scope="weights")
            h = train_mnist(q, steps=n)
            out[f"w{bits}_{mode}"] = {
                "test_acc": h["final_test_acc"],
                "final_loss": h["loss"][-1],
                "diverged": h["diverged"],
            }
    out["claims"] = {
        "stochastic_beats_nearest_w10": bool(
            out["w10_stochastic"]["test_acc"]
            >= out["w10_nearest"]["test_acc"] - 1e-6),
        "stochastic_w12_converges": bool(
            not out["w12_stochastic"]["diverged"]),
    }
    save_result("rounding", out)
    return out


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=1))
