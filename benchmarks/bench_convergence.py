"""Paper Fig. 4: DPS vs fp32 baseline vs fixed 13-bit on LeNet/MNIST-class.

Claims validated:
  * DPS reaches baseline accuracy within a small margin,
  * fixed 13-bit (no DPS) fails to converge,
  * DPS average bit-width lands far below 32.
"""

from __future__ import annotations

from benchmarks.common import save_result, steps
from repro.apps.mnist import paper_quant_config, train_mnist
from repro.data import MNISTLike


def run():
    n = steps(300, 2000)
    data = MNISTLike(batch=64, seed=0)
    out = {}
    out["fp32_baseline"] = _summ(train_mnist(None, steps=n, data=data))
    out["dps_paper"] = _summ(train_mnist(paper_quant_config(), steps=n,
                                         data=data))
    out["fixed_13bit"] = _summ(train_mnist(
        paper_quant_config(static_bits=13), steps=n, data=data))
    out["steps"] = n

    gap = out["fp32_baseline"]["test_acc"] - out["dps_paper"]["test_acc"]
    out["claims"] = {
        "dps_matches_baseline(<1.5% gap)": bool(gap < 0.015),
        "fixed13_degrades": bool(out["fixed_13bit"]["test_acc"]
                                 < out["dps_paper"]["test_acc"] - 0.01
                                 or out["fixed_13bit"]["diverged"]),
        "dps_avg_bits_below_24": bool(out["dps_paper"]["avg_bits_w"] < 24
                                      and out["dps_paper"]["avg_bits_a"] < 24),
    }
    save_result("convergence", out)
    return out


def _summ(h):
    return {"test_acc": h["final_test_acc"], "final_loss": h["loss"][-1],
            "diverged": h["diverged"], "avg_bits_w": h["avg_bits_w"],
            "avg_bits_a": h["avg_bits_a"], "avg_bits_g": h["avg_bits_g"],
            "loss_curve_sample": h["loss"][:: max(1, len(h["loss"]) // 40)]}


if __name__ == "__main__":
    import json
    print(json.dumps(run()["claims"], indent=1))
