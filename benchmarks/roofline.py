"""Roofline derivation from the dry-run artifacts (deliverable g).

For every (arch × shape × mesh) JSON under results/dryrun/ compute

    compute    = HLO_FLOPs_per_device / 197e12        (bf16 peak, TPU v5e)
    memory     = HLO_bytes_per_device / 819e9         (HBM bandwidth)
    collective = Σ collective_bytes_per_device / 50e9 (ICI link)

using the scan-corrected per-device numbers (the L1/L2 probe reconstruction
— XLA counts a while body once regardless of trip count), identify the
dominant term, and report MODEL_FLOPS = 6·N·D (train) / 2·N·D (prefill) /
2·N_active·B (decode) against compiled FLOPs as the useful-compute ratio.
"""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, Optional

from benchmarks.common import save_result

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
LINK_BW = 50e9               # bytes/s / link

DRYRUN = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def model_flops_per_device(arch: str, shape_name: str, kind: str,
                           n_devices: int) -> float:
    from repro.configs.base import SHAPES, get_config
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n_active = cfg.n_active_params()
    n_total = cfg.n_params()
    if kind == "train":
        total = 6.0 * n_active * shape.batch * shape.seq
    elif kind == "prefill":
        total = 2.0 * n_active * shape.batch * shape.seq
    else:  # decode: one token per row
        total = 2.0 * n_active * shape.batch
    return total / n_devices


def analyze_cell(js: Dict) -> Dict:
    corr = js.get("corrected", {})
    flops = corr.get("flops", js["flops"])
    hbytes = corr.get("bytes_accessed", js["bytes_accessed"])
    coll = sum(v for k, v in corr.items() if k.startswith("cb_")) if corr \
        else sum(js["collective_bytes"].values())
    coll = max(coll, 0.0)

    t_c = flops / PEAK_FLOPS
    t_m = hbytes / HBM_BW
    t_x = coll / LINK_BW
    terms = {"compute_s": t_c, "memory_s": t_m, "collective_s": t_x}
    bottleneck = max(terms, key=terms.get)
    mf = model_flops_per_device(js["arch"], js["shape"], js["kind"],
                                js["n_devices"])
    step_time = max(t_c, t_m, t_x)
    return {
        "arch": js["arch"], "shape": js["shape"], "mesh": js["mesh"],
        "kind": js["kind"],
        "probe_corrected": bool(corr),
        **{k: round(v, 6) for k, v in terms.items()},
        "bottleneck": bottleneck.replace("_s", ""),
        "model_flops_per_dev": mf,
        "useful_ratio": round(mf / flops, 4) if flops > 0 else None,
        "roofline_fraction": round((mf / PEAK_FLOPS) / step_time, 4)
        if step_time > 0 else None,
        "temp_gib": round(js.get("temp_size_in_bytes", 0) / 2**30, 2),
        "arg_gib": round(js.get("argument_size_in_bytes", 0) / 2**30, 2),
        "collective_bytes": coll,
        "hbm_fits": bool((js.get("temp_size_in_bytes", 0)
                          + js.get("argument_size_in_bytes", 0)) / 2**30 < 16),
    }


def run(pattern: str = "*.json") -> Dict:
    rows = []
    for path in sorted(glob.glob(os.path.join(DRYRUN, pattern))):
        with open(path) as f:
            js = json.load(f)
        try:
            rows.append(analyze_cell(js))
        except Exception as e:  # pragma: no cover
            rows.append({"arch": js.get("arch"), "shape": js.get("shape"),
                         "error": repr(e)})
    out = {"cells": rows, "constants": {
        "peak_flops": PEAK_FLOPS, "hbm_bw": HBM_BW, "link_bw": LINK_BW}}
    save_result("roofline", out)
    return out


def table(rows, corrected_only: bool = True) -> str:
    """Markdown table.  Multi-pod cells compile without probes (they exist
    to prove the pod axis shards), so their FLOP/byte numbers carry the
    while-counted-once distortion — excluded from the table by default;
    the roofline analysis is single-pod per the assignment."""
    hdr = ("| arch | shape | mesh | compute s | memory s | coll s | "
           "bottleneck | useful | roofline | temp GiB |\n"
           "|---|---|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for r in rows:
        if "error" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | — | ERROR {r['error'][:40]} |")
            continue
        if corrected_only and not r.get("probe_corrected", True):
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['compute_s']:.2e} | {r['memory_s']:.2e} "
            f"| {r['collective_s']:.2e} | {r['bottleneck']} "
            f"| {r['useful_ratio']} | {r['roofline_fraction']} "
            f"| {r['temp_gib']} |")
    return "\n".join(lines)


if __name__ == "__main__":
    out = run()
    print(table(out["cells"]))
