"""Quantizer hot-spot benchmark (§2): the fused Pallas kernel vs the
unfused jnp path.

On this CPU container the kernel runs in interpret mode, so wall-clock is
meaningless; what we CAN measure honestly is the memory traffic of the two
lowerings (bytes accessed from cost_analysis) plus the op/pass structure —
the fused kernel's one-read-one-write contract vs the multi-pass jnp chain.
Wall-clock of the jnp path is also reported as the emulation-layer cost.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import save_result
from repro.core.fixed_point import FixedPointFormat, quantize
from repro.kernels import ops


def run():
    fmt = FixedPointFormat.create(6, 10)
    key = jax.random.key(0)
    shape = (2048, 4096)
    x = jax.random.normal(key, shape)
    bits = jax.random.bits(jax.random.fold_in(key, 1), shape=shape,
                           dtype=jnp.uint32)

    # --- structural comparison via cost_analysis on the jnp path ---
    jnp_fn = jax.jit(lambda x, bits: quantize(x, fmt, bits=bits))
    c = jnp_fn.lower(x, bits).compile()
    ca = c.cost_analysis()
    naive_bytes = float(ca.get("bytes accessed", -1))
    io_floor = x.size * 4 * 2 + bits.size * 4       # read x+bits, write q

    t0 = time.time()
    q1, s1 = jnp_fn(x, bits)
    jax.block_until_ready(q1)
    n_iter = 5
    t0 = time.time()
    for _ in range(n_iter):
        q1, s1 = jnp_fn(x, bits)
    jax.block_until_ready(q1)
    jnp_ms = (time.time() - t0) / n_iter * 1e3

    # kernel path (interpret mode: correctness-equivalent, not timed)
    q2, s2 = ops.dps_quantize(x, fmt, bits=bits.reshape(-1))
    exact = bool(jnp.array_equal(q1, q2))

    out = {
        "tensor": list(shape),
        "jnp_path_ms_cpu": jnp_ms,
        "jnp_bytes_accessed": naive_bytes,
        "io_floor_bytes": io_floor,
        "jnp_traffic_multiplier": naive_bytes / io_floor,
        "kernel_traffic_multiplier": 1.0,   # by construction: 1 read + 1 write
        "kernel_matches_jnp_bitexact": exact,
        "note": "kernel timed on TPU only; interpret mode validates "
                "numerics (see tests/test_kernels.py sweep)",
    }
    save_result("quant", out)
    return out


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=1))
