"""Serving benchmark: continuous batching + the paged int8 KV cache.

Measures the two serving claims on a synthetic many-user trace:

* **Throughput** — continuous batching (admit into free slots as finished
  rows retire) vs one-request-at-a-time serving on the SAME engine and
  layout: aggregate tokens/s and per-token latency p50/p95.
* **Cache HBM per decoded token** — the int8-paged read cost (per decode
  step a row reads its populated pages: ``2 · L · ceil(len/ps) · ps · KV
  · Dh`` bytes) against what a contiguous fp32 cache pays for the same
  trace, both the populated-length read (``2 · L · len · KV · Dh · 4`` —
  the conservative baseline: a masked contiguous kernel that reads only
  written rows) and the padded full-``max_seq`` read a naive preallocated
  cache does.  Byte counts are exact functions of the trace (prompt and
  generation lengths), independent of scheduling.

Emits ``BENCH_serve.json`` at the repo root (via ``benchmarks.run_all``)
with a stable flat schema; raw run metrics stay inside the payload.

  PYTHONPATH=src python -m benchmarks.bench_serve          # quick
  BENCH_QUICK=0 PYTHONPATH=src python -m benchmarks.bench_serve
"""

from __future__ import annotations

import json
import os
import sys

SCHEMA_VERSION = 1


def _trace_cache_bytes(reqs, lay, cfg):
    """Exact per-trace cache-read byte totals (see module docstring)."""
    L, ps = cfg.n_layers, lay.page_size
    kvdh = cfg.n_kv_heads * cfg.head_dim
    int8 = fp32_pop = fp32_pad = 0
    max_seq = lay.max_prompt + max(r.max_new for r in reqs)
    ntok = 0
    for r in reqs:
        for i in range(1, r.max_new):
            ln = int(r.prompt.size) + i          # tokens visible this step
            pages = -(-ln // ps)
            int8 += 2 * L * pages * ps * kvdh          # 1 B/elem
            fp32_pop += 2 * L * ln * kvdh * 4
            fp32_pad += 2 * L * max_seq * kvdh * 4
            ntok += 1
    return {
        "decoded_tokens": ntok,
        "int8_paged_bytes_per_token": int8 / max(ntok, 1),
        "fp32_contiguous_populated_bytes_per_token": fp32_pop / max(ntok, 1),
        "fp32_contiguous_padded_bytes_per_token": fp32_pad / max(ntok, 1),
        "int8_cache_hbm_reduction": fp32_pop / max(int8, 1),
        "int8_cache_hbm_reduction_vs_padded": fp32_pad / max(int8, 1),
    }


def _variant_metrics(report):
    m = report.metrics
    return {k: m[k] for k in ("tokens_per_s", "p50_ms_per_token",
                              "p95_ms_per_token", "mean_occupancy",
                              "decode_steps", "wall_s")}


def run() -> dict:
    import jax
    from repro.configs.base import get_config, smoke
    from repro.models import registry
    from repro.models.common import init_params
    from repro.serve import (Engine, EngineConfig, PagedLayout,
                             synthetic_trace)

    quick = os.environ.get("BENCH_QUICK", "1") != "0"
    arch = "llama3_2_3b"
    cfg = smoke(get_config(arch))
    mod = registry(cfg.family)
    params = init_params(jax.random.key(0), mod.model_defs(cfg))

    n_requests = 10 if quick else 32
    lay = PagedLayout(page_size=4, n_pages=48, batch_slots=4,
                      max_pages_per_seq=10, max_prompt=16)
    trace_kw = dict(prompt_lens=(4, 16), new_tokens=(4, 16),
                    mean_gap=0.0, seed=7)
    reqs = synthetic_trace(n_requests, cfg.vocab, **trace_kw)
    warm = synthetic_trace(2, cfg.vocab, **trace_kw)

    engines = {
        "paged_int8_continuous": Engine(cfg, params, EngineConfig(
            layout=lay, kv_bits=8)),
        "paged_fp32_continuous": Engine(cfg, params, EngineConfig(
            layout=lay, kv_bits=None)),
        "paged_int8_serial": Engine(cfg, params, EngineConfig(
            layout=lay, kv_bits=8, max_concurrency=1)),
    }
    variants, spreads, complete = {}, {}, True
    for name, eng in engines.items():
        eng.run(warm)                      # compile outside the clock
        rep = eng.run(reqs)
        variants[name] = _variant_metrics(rep)
        if rep.format_spread:
            spreads[name] = rep.format_spread
        complete &= all(len(rep.tokens[r.rid]) == r.max_new for r in reqs)

    hbm = _trace_cache_bytes(reqs, lay, cfg)
    cont = variants["paged_int8_continuous"]["tokens_per_s"]
    serial = variants["paged_int8_serial"]["tokens_per_s"]
    claims = {
        "int8_cache_hbm_reduction_ge_1.8":
            hbm["int8_cache_hbm_reduction"] >= 1.8,
        "continuous_faster_than_serial": cont > serial,
        "all_requests_served_to_completion": complete,
    }
    return {
        "schema_version": SCHEMA_VERSION,
        "quick_mode": quick,
        "arch": f"{arch}(smoke)",
        "n_requests": n_requests,
        "layout": {"page_size": lay.page_size, "n_pages": lay.n_pages,
                   "batch_slots": lay.batch_slots,
                   "max_pages_per_seq": lay.max_pages_per_seq,
                   "max_prompt": lay.max_prompt},
        "variants": variants,
        "cache_hbm": hbm,
        "format_spread": spreads.get("paged_int8_continuous", {}),
        "continuous_speedup_over_serial": cont / max(serial, 1e-9),
        "claims": claims,
    }


def main():
    res = run()
    print(json.dumps(res, indent=1, default=float, sort_keys=True))
    return 0 if all(res["claims"].values()) else 1


if __name__ == "__main__":
    sys.exit(main())
