"""Benchmark driver that persists a repo-root perf artifact per PR.

Runs the benchmark suites (all of them, or ``--collectives-only`` for the
wire-pipeline subset) and emits ``BENCH_collectives.json`` at the repo
root with a **stable schema** — a small, flat summary of the collective
wire pipeline's perf counters, meant to be committed so the trajectory
(wire ratios, grouped-kernel overhead, fused-receive traffic model, tree
flat-concat bytes) is diffable across PRs.  The full raw payloads stay in
``results/bench/*.json`` as before; this file only carries the numbers a
reviewer should watch, under keys that do not churn.

``BENCH_serve.json`` rides the same mechanism for the serving engine
(:mod:`benchmarks.bench_serve`): tokens/s and per-token latency for
continuous vs serial batching, and cache-HBM bytes per decoded token for
int8-paged vs fp32-contiguous — ``--serve-only`` emits just that file.

  PYTHONPATH=src python -m benchmarks.run_all --collectives-only
  PYTHONPATH=src python -m benchmarks.run_all --serve-only
  BENCH_QUICK=0 PYTHONPATH=src python -m benchmarks.run_all   # full scale
"""

from __future__ import annotations

import os

# standalone entry point: force the 8-way host platform before JAX
# initializes, exactly like benchmarks.bench_collectives standalone.
if __name__ == "__main__" and "jax" not in __import__("sys").modules:
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                               + os.environ.get("XLA_FLAGS", ""))

import argparse
import json
import sys

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
ARTIFACT = os.path.join(REPO_ROOT, "BENCH_collectives.json")
SERVE_ARTIFACT = os.path.join(REPO_ROOT, "BENCH_serve.json")

# bump ONLY when a key is renamed/removed; adding keys is schema-compatible
# v2: adds the overlap walltime block (overlap_ms_per_step,
# overlap_improvement_over_serial, metrics_fetch) — all v1 keys kept
SCHEMA_VERSION = 2


def collectives_summary(res: dict) -> dict:
    """The stable cross-PR schema, derived from bench_collectives' payload."""
    per = res.get("per_variant", {})
    tree = res.get("tree_allreduce", {})
    return {
        "schema_version": SCHEMA_VERSION,
        "quick_mode": os.environ.get("BENCH_QUICK", "1") != "0",
        "n_devices": res.get("n_devices"),
        "elements_per_rank": res.get("elements_per_rank"),
        "wire_groups": res.get("wire_groups"),
        "group_quantum": res.get("group_quantum"),
        "wire_ratio_int8_over_fp32": res.get("wire_ratio_int8_over_fp32"),
        "grouped_wire_ratio_int8_over_fp32":
            res.get("grouped_wire_ratio_int8_over_fp32"),
        "grouped_kernel_walltime_over_global_kernel":
            res.get("grouped_kernel_walltime_over_global_kernel"),
        "ms_per_step": {k: v.get("ms_per_step") for k, v in per.items()},
        "hbm_model_bytes_per_rank": {
            k: v.get("hbm_model_bytes_per_rank") for k, v in per.items()},
        "tree_f32_concat_bytes": {
            k: v.get("f32_concat_bytes") for k, v in tree.items()},
        "codecs_bitexact": res.get("codecs_bitexact"),
        "grouped_codecs_bitexact": res.get("grouped_codecs_bitexact"),
        "overlap_ms_per_step": {
            k: v.get("ms_per_step")
            for k, v in res.get("overlap", {}).get("per_variant", {}).items()},
        "overlap_improvement_over_serial":
            res.get("overlap", {}).get("overlap_improvement_over_serial"),
        "overlap_n_buckets": res.get("overlap", {}).get("n_buckets"),
        "zero_groupaligned": {
            "wire_ratio_int8_over_fp32":
                res.get("zero_groupaligned", {})
                   .get("wire_ratio_int8_over_fp32"),
            "padded_elems": res.get("zero_groupaligned", {})
                               .get("padded_elems"),
            "n_buckets": res.get("zero_groupaligned", {}).get("n_buckets"),
            "ms_per_step": {
                k: v.get("ms_per_step")
                for k, v in res.get("zero_groupaligned", {})
                               .get("per_variant", {}).items()},
        },
        "metrics_fetch": {
            k: res.get("metrics_fetch", {}).get(k)
            for k in ("synced_ms_per_step", "deferred_ms_per_step",
                      "deferred_improvement")},
        "claims": res.get("claims", {}),
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--collectives-only", action="store_true",
                    help="run only the wire-pipeline benchmark (the one "
                         "that feeds BENCH_collectives.json)")
    ap.add_argument("--serve-only", action="store_true",
                    help="run only the serving benchmark (the one that "
                         "feeds BENCH_serve.json)")
    ap.add_argument("--out", default=ARTIFACT)
    ap.add_argument("--serve-out", default=SERVE_ARTIFACT)
    args = ap.parse_args(argv)

    import jax  # noqa: F401  (device count fixed by the XLA flag above)

    failures = []
    if not args.serve_only:
        from benchmarks import bench_collectives
        res = bench_collectives.run()
        if res.get("skipped"):
            print("collectives benchmark skipped:", res.get("note"))
            return 1
        claims = res.get("claims", {})
        if not all(claims.values()):
            failures.append(("collectives", claims))

        with open(args.out, "w") as f:
            json.dump(collectives_summary(res), f, indent=1, default=float,
                      sort_keys=True)
            f.write("\n")
        print(f"wrote {args.out}")

    if not args.collectives_only:
        from benchmarks import bench_serve
        sres = bench_serve.run()
        sclaims = sres.get("claims", {})
        if not all(sclaims.values()):
            failures.append(("serve", sclaims))
        with open(args.serve_out, "w") as f:
            json.dump(sres, f, indent=1, default=float, sort_keys=True)
            f.write("\n")
        print(f"wrote {args.serve_out}")

    if not (args.collectives_only or args.serve_only):
        # the remaining suites keep their own results/bench artifacts
        from benchmarks import run as run_mod
        try:
            run_mod.main()
        except SystemExit as e:
            if e.code:
                failures.append(("benchmarks.run", e.code))

    if failures:
        print("\nFAILED CLAIMS/SUITES:")
        for n, c in failures:
            print(" -", n, c)
        return 1
    print("\nall benchmark claims hold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
