"""Benchmark aggregator: one bench per paper artifact + the roofline table.

  PYTHONPATH=src python -m benchmarks.run            # quick mode
  BENCH_QUICK=0 PYTHONPATH=src python -m benchmarks.run   # paper-scale

The MNIST-class benches reproduce the paper's own evaluation (Figs. 3-4,
Table 1, the Gupta rounding comparison); bench_quant covers the kernel
hot-spot; the roofline table is derived from results/dryrun/ (run
``python -m repro.launch.dryrun --all --mesh both`` first for all cells).
"""

from __future__ import annotations

import json
import sys
import time
import traceback


def main():
    from benchmarks import (bench_bitwidths, bench_collectives,
                            bench_convergence, bench_quant, bench_rounding,
                            bench_schemes, bench_zero, roofline)
    suites = [
        ("convergence (paper Fig. 4)", bench_convergence.run),
        ("bitwidths (paper Fig. 3)", bench_bitwidths.run),
        ("rounding (Gupta comparison)", bench_rounding.run),
        ("schemes (paper Table 1)", bench_schemes.run),
        ("quantizer hot-spot", bench_quant.run),
        ("collectives (int8 gradient wire)", bench_collectives.run),
        ("ZeRO-1 (sharded optimizer + int8 wire)", bench_zero.run),
        ("roofline (dry-run artifacts)", roofline.run),
    ]
    failures = []
    for name, fn in suites:
        t0 = time.time()
        print(f"=== {name} ===", flush=True)
        try:
            out = fn()
            claims = out.get("claims")
            if claims is not None:
                print(json.dumps(claims, indent=1))
                if not all(claims.values()):
                    failures.append((name, claims))
            if name.startswith("roofline"):
                print(roofline.table(out["cells"]))
            print(f"  ({time.time() - t0:.1f}s)", flush=True)
        except Exception:
            traceback.print_exc()
            failures.append((name, "exception"))
    if failures:
        print("\nFAILED CLAIMS/SUITES:")
        for n, c in failures:
            print(" -", n, c)
        sys.exit(1)
    print("\nall benchmark claims hold")


if __name__ == "__main__":
    main()
