"""Shared benchmark plumbing: result I/O and quick/full mode."""

from __future__ import annotations

import json
import os
import time

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "bench")


def save_result(name: str, payload: dict):
    os.makedirs(RESULTS, exist_ok=True)
    path = os.path.join(RESULTS, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=float)
    return path


def is_quick() -> bool:
    return os.environ.get("BENCH_QUICK", "1") != "0"


def steps(quick: int, full: int) -> int:
    return quick if is_quick() else full
