"""ZeRO-1 sharded-optimizer benchmark: replicated vs ZeRO, fp32 vs int8 wire.

Four LeNet train-step variants on a host data mesh:

  * ``replicated_fp32``  — stock data parallelism: implicit fp32 gradient
    all-reduce, optimizer state fully replicated,
  * ``replicated_int8``  — ``grad_allreduce_bits=8``: int8 two-leg gradient
    all-reduce, state still replicated,
  * ``zero_fp32``        — ``zero_opt_shards``: optimizer state sharded
    over the data axis (flat padded layout), exact collective legs,
  * ``zero_int8``        — both: int8 reduce-scatter of gradients + int8
    all-gather of updated parameter shards.

Reported per variant: ring-model wire bytes split int8/fp32 (parsed from
the compiled HLO via ``repro.launch.hlo_stats``), optimizer-state bytes
per device, and walltime per step.  Headline claims: ZeRO cuts per-device
optimizer state to ~1/n, and its int8 schedule moves ≤ ~1/4 the wire bytes
of the fp32 reduce-scatter + all-gather (the ISSUE-3 criterion).

Run standalone (multi-device): ``PYTHONPATH=src python -m
benchmarks.bench_zero`` — the module forces an 8-way host platform before
JAX initializes.  Under ``benchmarks.run`` (JAX already live with one
device) it degrades to a note.
"""

from __future__ import annotations

import os

# only the standalone entry point may mutate process-global XLA flags, and
# only before JAX initializes (see bench_collectives).
if __name__ == "__main__" and "jax" not in __import__("sys").modules:
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                               + os.environ.get("XLA_FLAGS", ""))

import time

import jax
import jax.numpy as jnp

from benchmarks.common import is_quick, save_result
from repro.core import qtrain
from repro.core.dps import DPSHyper
from repro.launch.hlo_stats import wire_bytes_summary
from repro.models import lenet
from repro.optim import SGDConfig, make_optimizer


def _state_bytes_per_device(state, n_dev: int, zero: bool) -> int:
    """Optimizer-state bytes one device holds (flat ZeRO leaves shard 1/n)."""
    total = sum(l.size * l.dtype.itemsize
                for l in jax.tree.leaves(state.opt_state))
    return total // n_dev if zero else total


def run():
    n_dev = jax.device_count()
    if n_dev < 2:
        out = {"skipped": True,
               "note": "needs a multi-device mesh; run standalone "
                       "(python -m benchmarks.bench_zero)"}
        save_result("zero", out)
        return out

    mesh = jax.make_mesh((n_dev,), ("data",))
    opt = make_optimizer(SGDConfig())
    params = lenet.init(jax.random.key(0))
    batch_n = 64 if is_quick() else 512
    iters = 3 if is_quick() else 20
    batch = {"images": jax.random.normal(jax.random.key(2),
                                         (batch_n, 28, 28, 1)) * 0.5,
             "labels": jax.random.randint(jax.random.key(3), (batch_n,),
                                          0, 10)}
    # static formats sized to the init stats so the int8 legs don't clip
    base = dict(enabled=False, controller="static",
                hyper_grads=DPSHyper(il_init=6, fl_init=2),
                hyper_weights=DPSHyper(il_init=2, fl_init=14))

    variants = {
        "replicated_fp32": qtrain.QuantConfig(**base),
        "replicated_int8": qtrain.QuantConfig(**base, grad_allreduce_bits=8),
        "zero_fp32": qtrain.QuantConfig(**base, zero_opt_shards=n_dev),
        "zero_int8": qtrain.QuantConfig(**base, grad_allreduce_bits=8,
                                        zero_opt_shards=n_dev),
    }

    results = {}
    for name, qcfg in variants.items():
        zero = qtrain.zero_opt_engaged(qcfg, mesh)
        step = qtrain.make_train_step(lenet.loss_fn, opt, qcfg, mesh=mesh)
        opt_state = (qtrain.zero_opt_state(opt, params, n_dev) if zero
                     else opt.init(params))
        state = qtrain.TrainState.create(params, opt_state, qcfg,
                                         jax.random.key(1))
        if name == "replicated_fp32":
            # stock DP needs the batch sharded for the implicit all-reduce
            # to appear in HLO; the shard_map variants pin specs themselves
            from jax.sharding import NamedSharding, PartitionSpec as P
            repl = jax.tree.map(lambda _: NamedSharding(mesh, P()), state)
            bsh = {k: NamedSharding(mesh, P("data")) for k in batch}
            jitted = jax.jit(step, in_shardings=(repl, bsh),
                             out_shardings=None)
        else:
            jitted = jax.jit(step)
        wire = wire_bytes_summary(
            jitted.lower(state, batch).compile().as_text())

        s, _ = jitted(state, batch)             # compile + warm
        jax.block_until_ready(s)
        t0 = time.time()
        for _ in range(iters):
            s, _ = jitted(s, batch)
        jax.block_until_ready(s)
        results[name] = {
            "wire_bytes": wire,
            "opt_state_bytes_per_device":
                _state_bytes_per_device(state, n_dev, zero),
            "ms_per_step": (time.time() - t0) / iters * 1e3,
            "wire_sync_active": bool(step.wire_sync_active),
            "zero_opt_active": bool(step.zero_opt_active),
        }

    # fp32 baseline for the headline ratio: the same reduce-scatter +
    # all-gather schedule without the codec, over the same padded flat size
    # (zero_fp32's own gradient leg is GSPMD's implicit all-reduce, a
    # different schedule — see dist/README.md).
    from jax.sharding import PartitionSpec as P
    from repro.dist.sharding import ZeroPartitioner
    part = ZeroPartitioner.create(params, n_dev)

    def _fp32_ref(x):
        s = jax.lax.psum_scatter(x.reshape(n_dev, part.shard_size), "data",
                                 scatter_dimension=0, tiled=True)
        return jax.lax.all_gather(s, "data", axis=0, tiled=True)

    ref = jax.jit(jax.shard_map(_fp32_ref, mesh=mesh, in_specs=P(),
                                out_specs=P(), check_vma=False))
    fp32_ref = wire_bytes_summary(
        ref.lower(jax.ShapeDtypeStruct((part.padded_size,), jnp.float32)
                  ).compile().as_text())["fp32"]

    zi, zf = results["zero_int8"], results["zero_fp32"]
    rep = results["replicated_fp32"]
    wire_ratio = (zi["wire_bytes"]["int8"] / fp32_ref) if fp32_ref else None
    out = {
        "n_devices": n_dev,
        "per_variant": results,
        "fp32_reduce_scatter_allgather_wire_bytes": fp32_ref,
        "zero_int8_over_fp32_schedule_wire_ratio": wire_ratio,
        "opt_state_shrink":
            rep["opt_state_bytes_per_device"]
            / max(zi["opt_state_bytes_per_device"], 1),
        "note": "CPU container: walltime is emulation cost, not a fabric "
                "measurement; wire bytes are ring-model from compiled HLO",
        "claims": {
            "zero_int8_wire_le_quarter_fp32":
                wire_ratio is not None and wire_ratio <= 0.26,
            "zero_shards_opt_state":
                zi["opt_state_bytes_per_device"]
                <= rep["opt_state_bytes_per_device"] // n_dev + 8,
            "all_paths_engaged":
                zi["zero_opt_active"] and zi["wire_sync_active"]
                and zf["zero_opt_active"]
                and results["replicated_int8"]["wire_sync_active"],
        },
    }
    save_result("zero", out)
    return out


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=1, default=float))
