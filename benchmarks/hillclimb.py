"""§Perf hillclimb driver: baseline vs optimized variants for the three
selected cells (worst roofline fraction / most collective-bound / most
paper-representative), each optimization DPS-flavored:

  gemma_7b × decode_32k       int8 ⟨3,5⟩-grid KV cache  (memory-bound)
  llama3_2_3b × train_4k      batch-2D attention sharding (collective-bound)
  deepseek_v2_236b × train_4k int8 ⟨4,4⟩-grid MoE all-to-all payload
                              (collective-bound + the paper's quantizer on
                              the expert-parallel wire)

Each variant re-lowers + re-compiles the cell on the single-pod mesh and
records the three roofline terms; the before/after log lands in
results/hillclimb/ and EXPERIMENTS.md §Perf.

  PYTHONPATH=src python -m benchmarks.hillclimb
"""

from __future__ import annotations

import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import json
import time
import traceback

CELLS = [
    # (arch, shape, variant-name, overrides)
    ("gemma_7b", "decode_32k", "baseline", {}),
    ("gemma_7b", "decode_32k", "int8_kv", {"kv_cache_bits": 8}),
    ("llama3_2_3b", "train_4k", "baseline", {}),
    ("llama3_2_3b", "train_4k", "batch2d_attn", {"attn_batch2d": True}),
    ("deepseek_v2_236b", "train_4k", "baseline", {}),
    ("deepseek_v2_236b", "train_4k", "int8_a2a", {"moe_a2a_bits": 8}),
    ("deepseek_v2_236b", "train_4k", "int8_a2a+accum8",
     {"moe_a2a_bits": 8, "train_accum": 8}),
    # bonus: the other over-budget decode cell gets the int8 cache too
    ("nemotron_4_340b", "decode_32k", "baseline", {}),
    ("nemotron_4_340b", "decode_32k", "int8_kv", {"kv_cache_bits": 8}),
]

OUT = os.path.join(os.path.dirname(__file__), "..", "results", "hillclimb")


def main(cells=None):
    from benchmarks.roofline import analyze_cell
    from repro.launch.dryrun import run_cell
    os.makedirs(OUT, exist_ok=True)
    rows = []
    for arch, shape, name, over in (cells or CELLS):
        tag = f"{arch}__{shape}__{name}"
        t0 = time.time()
        print(f"=== {tag} ===", flush=True)
        try:
            stats = run_cell(arch, shape, multi_pod=False, probes=True,
                             overrides=over)
            stats["variant"] = name
            with open(os.path.join(OUT, tag + ".json"), "w") as f:
                json.dump(stats, f, indent=1)
            r = analyze_cell(stats)
            r["variant"] = name
            rows.append(r)
            print(f"  compute {r['compute_s']:.3e}s  memory {r['memory_s']:.3e}s"
                  f"  collective {r['collective_s']:.3e}s  "
                  f"bottleneck={r['bottleneck']}  temp={r['temp_gib']}GiB  "
                  f"roofline={r['roofline_fraction']}  "
                  f"({time.time() - t0:.0f}s)", flush=True)
        except Exception:
            traceback.print_exc()
    with open(os.path.join(OUT, "summary.json"), "w") as f:
        json.dump(rows, f, indent=1)
    return rows


if __name__ == "__main__":
    main()
