"""Paper Table 1: every DPS scheme in the related-work comparison, run
head-to-head on the same task — the paper's scheme vs Courbariaux
(fixed-width overflow-driven), Na & Mukhopadhyay (convergence-driven,
round-to-nearest), Gupta (static), FlexPoint-like (predictive max)."""

from __future__ import annotations

import dataclasses

from benchmarks.common import save_result, steps
from repro.apps.mnist import paper_quant_config, train_mnist
from repro.core.dps import DPSHyper
from repro.core import qtrain


def run():
    n = steps(250, 2000)
    out = {"steps": n}
    schemes = {
        "paper": paper_quant_config("paper"),
        "courbariaux": paper_quant_config("courbariaux", il_init=4),
        "na_mukhopadhyay": paper_quant_config("na_mukhopadhyay",
                                              rounding="nearest"),
        "gupta_static_16": paper_quant_config(static_bits=16,
                                              static_scope="weights"),
        "flexpoint": paper_quant_config("flexpoint", il_init=4),
    }
    for name, q in schemes.items():
        h = train_mnist(q, steps=n)
        out[name] = {
            "test_acc": h["final_test_acc"],
            "diverged": h["diverged"],
            "avg_bits_w": h["avg_bits_w"],
            "avg_bits_a": h["avg_bits_a"],
            "avg_bits_g": h["avg_bits_g"],
        }
    # paper §6: its scheme converges (at adaptive width) where Na's
    # convergence-triggered ramp-up is still far from converged
    out["claims"] = {
        "paper_converges": bool(not out["paper"]["diverged"]),
        "paper_acc_beats_na": bool(out["paper"]["test_acc"]
                                   >= out["na_mukhopadhyay"]["test_acc"]),
    }
    save_result("schemes", out)
    return out


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=1))
