"""Gradient all-reduce wire benchmark: fp32 vs the int8 DPS codec (§dist).

Compares three ways to average a gradient-sized tensor across a host-device
data mesh:

  * ``fp32``    — ``lax.pmean``: XLA's stock all-reduce,
  * ``int8_jnp``    — ``dps_allreduce_mean`` with the jnp wire codec,
  * ``int8_kernel`` — the same collective with the fused Pallas
    ``dps_quant_wire`` codec (interpret mode on CPU — numerics-identical,
    walltime is emulation cost only; honest kernel timing needs a TPU).

Reported per variant: ring-model wire bytes parsed from the compiled HLO
(see ``repro.launch.hlo_stats``) and walltime per step.  The headline
claim is the ISSUE/ROADMAP one: the int8 two-leg path moves ≤ ~1/4 the
wire bytes of the fp32 all-reduce.

Second artifact (``results/bench/wire_controller.json``): LeNet/MNIST-tiny
loss trajectories under the paper's hair-trigger ``r_max = 1e-4`` at 8
wire bits, comparing **wire-domain controller kinds** — the shared-IL-style
threshold-driven ``paper`` wire (⟨IL, 8−IL⟩ with IL ratcheting on stray
wire clips, the dynamics the pre-registry derived-format design exhibited),
``courbariaux`` (overflow-driven radix with a decay path), and the default
dedicated ``flexpoint`` wire (max-abs-driven radix).  This is the measured
basis for "choosing a wire controller" in dist/README.md.

Run standalone (multi-device): ``PYTHONPATH=src python -m
benchmarks.bench_collectives`` — the module forces an 8-way host platform
before JAX initializes.  Under ``benchmarks.run`` (JAX already live with
one device) it degrades to a note.
"""

from __future__ import annotations

import os

# only the standalone entry point (python -m benchmarks.bench_collectives)
# may mutate process-global XLA flags, and only before JAX initializes; a
# plain import (benchmarks.run, pytest collection) must stay side-effect
# free.
if __name__ == "__main__" and "jax" not in __import__("sys").modules:
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                               + os.environ.get("XLA_FLAGS", ""))

import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from benchmarks.common import is_quick, save_result
from repro.core.fixed_point import FixedPointFormat
from repro.dist.collectives import dps_allreduce_mean
from repro.launch.hlo_stats import collective_wire_bytes


def run_wire_controllers(mesh, steps: int):
    """Train LeNet/MNIST-tiny at hair-trigger ``r_max`` per wire controller.

    The ``paper`` variant is the shared-IL-style baseline: a threshold-
    driven wire domain whose IL moves on every step with > 0.01% wire
    clipping and whose FL is pinned to the remaining bits — the ⟨IL, 8−IL⟩
    ratchet dynamics the pre-registry design derived from the grads
    controller.  ``flexpoint`` is the registry default (radix from the
    running max|g|, two octaves of bulk bias — ``dps.wire_hyper``).
    """
    from jax.sharding import NamedSharding
    from repro.core import qtrain
    from repro.core.dps import DPSHyper, wire_hyper
    from repro.data import MNISTLike
    from repro.models import lenet
    from repro.optim import SGDConfig, make_optimizer

    opt = make_optimizer(SGDConfig())
    data = MNISTLike(batch=64, seed=0)
    params = lenet.init(jax.random.key(0))
    hg = DPSHyper(il_init=6, fl_init=12, e_max=5e-2, r_max=1e-4)
    batch_sh = {"images": NamedSharding(mesh, P("data")),
                "labels": NamedSharding(mesh, P("data"))}

    def run_one(wire_controller):
        qcfg = qtrain.QuantConfig(
            enabled=True, hyper_grads=hg, grad_allreduce_bits=8,
            wire_controller=wire_controller,
            # same initial placement for every kind; flexpoint's slack is
            # what wire_hyper would default anyway
            hyper_wire_grads=wire_hyper(8, il_init=6, slack=-2.0))
        state = qtrain.TrainState.create(params, opt.init(params), qcfg,
                                         jax.random.key(1))
        repl = jax.tree.map(lambda _: NamedSharding(mesh, P()), state)
        step = qtrain.make_train_step(lenet.loss_fn, opt, qcfg, mesh=mesh)
        jitted = jax.jit(step, in_shardings=(repl, batch_sh),
                         out_shardings=None)
        hist = {"loss": [], "il_wire": [], "fl_wire": [], "fl_g": [],
                "R_wire": []}
        for i in range(steps):
            state, m = jitted(state, data.train_batch(i))
            hist["loss"].append(float(m["loss"]))
            hist["il_wire"].append(float(m["il_wire_grads"]))
            hist["fl_wire"].append(float(m["fl_wire_grads"]))
            hist["fl_g"].append(float(m["fl_g"]))
            hist["R_wire"].append(float(m["R_wire"]))
        tail = float(np.mean(hist["loss"][-max(5, steps // 4):]))
        il = hist["il_wire"]
        return {
            "history": hist,
            "loss_start": hist["loss"][0],
            "loss_tail_mean": tail,
            "loss_peak": max(hist["loss"]),
            "wire_il_up_events": sum(1 for a, b in zip(il, il[1:]) if b > a),
            "wire_il_final": il[-1],
            "compute_fl_max": max(hist["fl_g"]),
            "converged": bool(np.isfinite(hist["loss"]).all()
                              and tail < 0.6 * hist["loss"][0]),
        }

    variants = {k: run_one(k) for k in ("paper", "courbariaux", "flexpoint")}
    flex = variants["flexpoint"]
    out = {
        "n_devices": mesh.devices.size,
        "steps": steps,
        "scenario": "LeNet/MNIST-tiny, r_max=1e-4 (hair-trigger), "
                    "8 wire bits, grads hyper <6,12> e_max=5e-2",
        "per_controller": variants,
        "claims": {
            # the redesign's guarantee: the default dedicated wire
            # controller trains stably where the shared-IL-style ratchet
            # was pinned as unstable (the paper/courbariaux rows document
            # whatever the threshold-driven kinds do — reported, not
            # asserted)
            "flexpoint_converges": flex["converged"],
            "flexpoint_compute_fl_off_rail":
                flex["compute_fl_max"] < hg.fl_max,
        },
    }
    save_result("wire_controller", out)
    return out


def _time_steps(fn, args, iters: int) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters * 1e3


def run():
    n_dev = jax.device_count()
    if n_dev < 2:
        out = {"skipped": True,
               "note": "needs a multi-device mesh; run standalone "
                       "(python -m benchmarks.bench_collectives)"}
        save_result("collectives", out)
        return out

    mesh = jax.make_mesh((n_dev,), ("data",))
    size = (1 << 20) if is_quick() else (1 << 24)     # fp32 elements per rank
    iters = 3 if is_quick() else 20
    fmt = FixedPointFormat.create(3, 5)
    x = jax.random.normal(jax.random.key(0), (n_dev, size)) * 0.5
    key = jax.random.key(1)

    def fp32_body(xs, key):
        return jax.lax.pmean(xs[0], "data")

    def int8_body(backend):
        def body(xs, key):
            m, _ = dps_allreduce_mean(xs[0], fmt, "data", key,
                                      backend=backend)
            return m
        return body

    variants = {}
    results = {}
    for name, body in (("fp32", fp32_body),
                       ("int8_jnp", int8_body("jnp")),
                       ("int8_kernel", int8_body("kernel"))):
        fn = jax.jit(jax.shard_map(body, mesh=mesh,
                                   in_specs=(P("data", None), P()),
                                   out_specs=P(), check_vma=False))
        hlo = fn.lower(x, key).compile().as_text()
        wire = collective_wire_bytes(hlo)
        ms = _time_steps(fn, (x, key), iters)
        variants[name] = fn
        results[name] = {"wire_bytes": wire["total"],
                         "wire_bytes_by_dtype": wire["by_dtype"],
                         "ms_per_step": ms}

    # the two codecs draw identical rounding bits from the same key, so the
    # collective's result must be bit-identical across backends.
    m_jnp = variants["int8_jnp"](x, key)
    m_ker = variants["int8_kernel"](x, key)
    codecs_bitexact = bool(jnp.array_equal(m_jnp, m_ker))

    ratio = results["int8_jnp"]["wire_bytes"] / results["fp32"]["wire_bytes"]

    # wire-domain controller comparison (shared-IL-style vs dedicated)
    wire_ctrl = run_wire_controllers(mesh, steps=25 if is_quick() else 60)

    out = {
        "n_devices": n_dev,
        "elements_per_rank": size,
        "fp32_wire_bytes": results["fp32"]["wire_bytes"],
        "int8_wire_bytes": results["int8_jnp"]["wire_bytes"],
        "wire_ratio_int8_over_fp32": ratio,
        "per_variant": results,
        "codecs_bitexact": codecs_bitexact,
        "wire_controller": wire_ctrl,
        "note": "CPU container: int8_kernel runs the Pallas codec in "
                "interpret mode (numerics only; walltime not a kernel "
                "measurement)",
        "claims": {
            "int8_wire_le_quarter_fp32": ratio <= 0.26,
            "codec_backends_bitexact": codecs_bitexact,
            **wire_ctrl["claims"],
        },
    }
    save_result("collectives", out)
    return out


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=1, default=float))
