"""Gradient all-reduce wire benchmark: fp32 vs the int8 DPS codec (§dist).

Compares ways to average a gradient-sized tensor across a host-device
data mesh:

  * ``fp32``    — ``lax.pmean``: XLA's stock all-reduce,
  * ``int8_jnp``    — ``dps_allreduce_mean`` with the jnp wire codec,
  * ``int8_kernel`` — the same collective with the fused Pallas
    ``dps_quant_wire`` codec (interpret mode on CPU — numerics-identical,
    walltime is emulation cost only; honest kernel timing needs a TPU),
  * ``int8_jnp_grouped`` / ``int8_kernel_grouped`` — per-group ⟨IL, FL⟩
    (a [G] format table, one row per layer-sized group) through BOTH legs
    via the group-aligned layout; the kernel variant runs the [G, 2]
    SMEM-table grouped encode + the fused ``dps_wire_reduce`` receive.

Reported per variant: ring-model wire bytes parsed from the compiled HLO
(see ``repro.launch.hlo_stats``), walltime per step, and an **HBM-traffic
model** column (modeled bytes each rank moves through HBM per collective,
separating the fused one-pass pipeline from the naive multi-pass path).
Headline claims: the int8 two-leg path moves ≤ ~1/4 the wire bytes of the
fp32 all-reduce, the grouped-kernel path stays within 1.35× of the
global-format kernel walltime (interpret-mode emulation cost is host-
dependent — the bound guards against the [G, 2]-table machinery grossly
blowing up the kernel, not against per-host constant factors), and the rebuilt tree all-reduce compiles
with NO fp32 flat-concatenate (verified via ``hlo_stats.concat_bytes``).

Two more sections feed the ``overlap_*`` keys of the repo-root
``BENCH_collectives.json`` (schema v2): ``run_overlap_wire`` pits the
serial monolithic tree pipeline against the backward-overlapped bucketed
wire (``repro.dist.overlap``) on a layer-spectrum tree — claim: bucketed
beats serial outright and by ≥ 25% — and ``run_metrics_fetch`` measures
the before/after of killing the driver's per-step host metrics sync
(``launch/train.py`` now drains at log points only).
``run_zero_groupaligned`` adds the sharded schedule: the group-aligned
ZeRO two-leg pipeline (per-bucket int8 ``zero_bucketed_reduce_scatter``
+ one int8 ``zero_allgather_params``) against the fp32 reduce-scatter +
all-gather over the SAME flat layout — claim: the int8 two-leg wire
moves ≤ 0.26× the fp32 bytes, alignment padding included.

Second artifact (``results/bench/wire_controller.json``): LeNet/MNIST-tiny
loss trajectories under the paper's hair-trigger ``r_max = 1e-4`` at 8
wire bits, comparing **wire-domain controller kinds** — the shared-IL-style
threshold-driven ``paper`` wire (⟨IL, 8−IL⟩ with IL ratcheting on stray
wire clips, the dynamics the pre-registry derived-format design exhibited),
``courbariaux`` (overflow-driven radix with a decay path), and the default
dedicated ``flexpoint`` wire (max-abs-driven radix).  This is the measured
basis for "choosing a wire controller" in dist/README.md.

Run standalone (multi-device): ``PYTHONPATH=src python -m
benchmarks.bench_collectives`` — the module forces an 8-way host platform
before JAX initializes.  Under ``benchmarks.run`` (JAX already live with
one device) it degrades to a note.
"""

from __future__ import annotations

import os

# only the standalone entry point (python -m benchmarks.bench_collectives)
# may mutate process-global XLA flags, and only before JAX initializes; a
# plain import (benchmarks.run, pytest collection) must stay side-effect
# free.
if __name__ == "__main__" and "jax" not in __import__("sys").modules:
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                               + os.environ.get("XLA_FLAGS", ""))

import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from benchmarks.common import is_quick, save_result
from repro.core.fixed_point import FixedPointFormat
from repro.dist.collectives import (dps_allreduce_mean,
                                    dps_allreduce_mean_tree)
from repro.launch.hlo_stats import collective_wire_bytes, concat_bytes


def hbm_traffic_model(size: int, n_dev: int, variant: str) -> float:
    """Modeled HBM bytes ONE rank moves per all-reduce (both legs).

    E = local elements, c = E / n (the owned chunk).  The model counts
    tensor-sized reads/writes only (stats and scalars are noise):

      fp32          read 4E + write 4E (the stock all-reduce's copy in/out)
      int8 fused    encode read 4E (+4E rounding bits) + write E int8;
                    receive read E int8 + write 4c fp32 mean (the fused
                    decode-reduce never materializes the (n, c) fp32
                    stack); leg-2 encode read 4c + write c int8; gather
                    decode read E + write 4E
      int8 jnp      the same, plus the receive leg's 4E fp32 write + 4E
                    read for the decoded (n, c) stack (and, for layouts
                    that are not already group-aligned, an 8E fp32
                    align/scatter pass the benchmark's exact layout
                    skips)
    """
    E = float(size)
    c = E / n_dev
    if variant == "fp32":
        return 8 * E
    fused = (4 * E + 4 * E + E) + (E + 4 * c) + (4 * c + c) + (E + 4 * E)
    if variant.startswith("int8_kernel"):
        return fused
    naive_receive = 4 * E + 4 * E          # fp32 (n, c) stack write + read
    return fused + naive_receive


def run_wire_controllers(mesh, steps: int):
    """Train LeNet/MNIST-tiny at hair-trigger ``r_max`` per wire controller.

    The ``paper`` variant is the shared-IL-style baseline: a threshold-
    driven wire domain whose IL moves on every step with > 0.01% wire
    clipping and whose FL is pinned to the remaining bits — the ⟨IL, 8−IL⟩
    ratchet dynamics the pre-registry design derived from the grads
    controller.  ``flexpoint`` is the registry default (radix from the
    running max|g|, two octaves of bulk bias — ``dps.wire_hyper``).
    """
    from jax.sharding import NamedSharding
    from repro.core import qtrain
    from repro.core.dps import DPSHyper, wire_hyper
    from repro.data import MNISTLike
    from repro.models import lenet
    from repro.optim import SGDConfig, make_optimizer

    opt = make_optimizer(SGDConfig())
    data = MNISTLike(batch=64, seed=0)
    params = lenet.init(jax.random.key(0))
    hg = DPSHyper(il_init=6, fl_init=12, e_max=5e-2, r_max=1e-4)
    batch_sh = {"images": NamedSharding(mesh, P("data")),
                "labels": NamedSharding(mesh, P("data"))}

    def run_one(wire_controller):
        qcfg = qtrain.QuantConfig(
            enabled=True, hyper_grads=hg, grad_allreduce_bits=8,
            wire_controller=wire_controller,
            # same initial placement for every kind; flexpoint's slack is
            # what wire_hyper would default anyway
            hyper_wire_grads=wire_hyper(8, il_init=6, slack=-2.0))
        state = qtrain.TrainState.create(params, opt.init(params), qcfg,
                                         jax.random.key(1))
        repl = jax.tree.map(lambda _: NamedSharding(mesh, P()), state)
        step = qtrain.make_train_step(lenet.loss_fn, opt, qcfg, mesh=mesh)
        jitted = jax.jit(step, in_shardings=(repl, batch_sh),
                         out_shardings=None)
        hist = {"loss": [], "il_wire": [], "fl_wire": [], "fl_g": [],
                "R_wire": []}
        for i in range(steps):
            state, m = jitted(state, data.train_batch(i))
            hist["loss"].append(float(m["loss"]))
            hist["il_wire"].append(float(m["il_wire_grads"]))
            hist["fl_wire"].append(float(m["fl_wire_grads"]))
            hist["fl_g"].append(float(m["fl_g"]))
            hist["R_wire"].append(float(m["R_wire"]))
        tail = float(np.mean(hist["loss"][-max(5, steps // 4):]))
        il = hist["il_wire"]
        return {
            "history": hist,
            "loss_start": hist["loss"][0],
            "loss_tail_mean": tail,
            "loss_peak": max(hist["loss"]),
            "wire_il_up_events": sum(1 for a, b in zip(il, il[1:]) if b > a),
            "wire_il_final": il[-1],
            "compute_fl_max": max(hist["fl_g"]),
            "converged": bool(np.isfinite(hist["loss"]).all()
                              and tail < 0.6 * hist["loss"][0]),
        }

    variants = {k: run_one(k) for k in ("paper", "courbariaux", "flexpoint")}
    flex = variants["flexpoint"]
    out = {
        "n_devices": mesh.devices.size,
        "steps": steps,
        "scenario": "LeNet/MNIST-tiny, r_max=1e-4 (hair-trigger), "
                    "8 wire bits, grads hyper <6,12> e_max=5e-2",
        "per_controller": variants,
        "claims": {
            # the redesign's guarantee: the default dedicated wire
            # controller trains stably where the shared-IL-style ratchet
            # was pinned as unstable (the paper/courbariaux rows document
            # whatever the threshold-driven kinds do — reported, not
            # asserted)
            "flexpoint_converges": flex["converged"],
            "flexpoint_compute_fl_off_rail":
                flex["compute_fl_max"] < hg.fl_max,
        },
    }
    save_result("wire_controller", out)
    return out


def _time_variants(fns: dict, args, iters: int) -> dict:
    """Best-of-``iters`` ms per step for every variant, measured
    ROUND-ROBIN: one step of each variant per round, so slow phases of a
    shared CPU box hit all variants alike and the walltime-RATIO claims
    compare like with like.  Min-of-rounds is robust to scheduler noise.

    Timing honesty rule: every variant's ``fn`` must return (and we block
    on) the FINAL DECODED OUTPUT only — the fp32 mean a training step
    would consume next.  Stats, intermediates, and per-bucket partial
    results are dropped inside the jit, for every variant alike; a
    variant must never pay a sync another variant skips.
    """
    for fn in fns.values():                     # compile + warm
        jax.block_until_ready(fn(*args))
    best = {name: float("inf") for name in fns}
    for _ in range(iters):
        for name, fn in fns.items():
            t0 = time.time()
            jax.block_until_ready(fn(*args))
            best[name] = min(best[name], time.time() - t0)
    return {name: t * 1e3 for name, t in best.items()}


def _time_steps(fn, args, iters: int) -> float:
    return _time_variants({"_": fn}, args, iters)["_"]


def run_overlap_wire(mesh, iters: int, total: int):
    """Serial-monolithic vs bucketed wire on a layer-spectrum tree.

    Both variants compress the SAME gradient-shaped tree with the same
    per-leaf [G] format table and run the same two-leg int8 schedule; the
    serial variant is the monolithic ``dps_allreduce_mean_tree`` (one
    collective pair over one packed buffer), the overlap variant is
    ``repro.dist.overlap.bucketed_allreduce_mean_tree`` (one pair per
    bucket, backward ready order, per-bucket size-aware quanta).  On this
    single-core CPU box there is no compute to hide the collectives
    behind, so the measured gap is the overlap schedule's OTHER wins —
    cache locality of bucket-sized working sets and tighter per-bucket
    alignment padding — which is what the ≥25% claim pins.
    """
    from repro.dist import overlap as overlap_lib

    n_dev = mesh.devices.size
    # layer-like spectrum: a few big tensors + a tail of small ones,
    # deliberately not quantum-divisible
    sizes = [total // 2, total // 4, total // 8, total // 16, total // 32]
    sizes.append(total - sum(sizes))
    sizes = tuple(sizes)
    G = len(sizes)
    fmt = FixedPointFormat(
        jnp.array([[3, 2, 4, 3][g % 4] for g in range(G)], jnp.int32),
        jnp.array([[5, 6, 4, 5][g % 4] for g in range(G)], jnp.int32))
    key = jax.random.key(2)
    tree = {f"layer{i}": jax.random.normal(jax.random.fold_in(key, 100 + i),
                                           (n_dev, s)) * 0.5
            for i, s in enumerate(sizes)}
    target = max(total // 8, 1)

    def serial_body(tr, k):
        m, _ = dps_allreduce_mean_tree(tr, fmt, "data", k)
        return m

    def overlap_body(tr, k):
        from repro.dist.overlap import bucketed_allreduce_mean_tree
        m, _ = bucketed_allreduce_mean_tree(tr, fmt, "data", k,
                                            target_elems=target)
        return m

    plan = overlap_lib.plan_buckets(sizes, target)
    fns, stats = {}, {}
    for name, body in (("serial", serial_body), ("overlap", overlap_body)):
        fn = jax.jit(jax.shard_map(
            body, mesh=mesh,
            in_specs=({k: P("data", None) for k in tree}, P()),
            out_specs=P(), check_vma=False))
        hlo = fn.lower(tree, key).compile().as_text()
        wire = collective_wire_bytes(hlo)
        fns[name] = fn
        stats[name] = {"wire_bytes": wire["total"],
                       "wire_bytes_by_dtype": wire["by_dtype"]}
    # both bodies return the decoded mean tree only (the timing honesty
    # rule _time_variants documents): neither variant syncs on stats
    times = _time_variants(fns, (tree, key), iters)
    for name, ms in times.items():
        stats[name]["ms_per_step"] = ms
    improvement = 1.0 - times["overlap"] / times["serial"]
    return {
        "leaf_sizes": list(sizes),
        "total_elems": total,
        "bucket_target_elems": target,
        "n_buckets": plan.n_buckets,
        "per_variant": stats,
        "overlap_improvement_over_serial": improvement,
    }


def run_zero_groupaligned(mesh, iters: int, total: int):
    """Group-aligned ZeRO two-leg wire vs fp32 over the SAME flat layout.

    Both variants move one gradient-sized tree through a reduce-scatter
    and bring the full flat vector back with an all-gather, over the
    identical :class:`~repro.dist.sharding.GroupAlignedPartitioner`
    layout (same buckets, same alignment padding) — so the wire-byte
    ratio isolates the codec, not the layout.  The int8 variant is the
    sharded train-step pipeline itself: per-bucket
    ``zero_bucketed_reduce_scatter`` in backward-ready order (per-leaf
    [G] formats) + one concatenated ``zero_allgather_params``.  Walltime
    is reported for completeness but the claim is bytes-only: the jnp
    codec's emulation cost on CPU is not a wire measurement.
    """
    from repro.dist import overlap as overlap_lib
    from repro.dist.sharding import GroupAlignedPartitioner

    n_dev = mesh.devices.size
    sizes = [total // 2, total // 4, total // 8, total // 16, total // 32]
    sizes.append(total - sum(sizes))
    sizes = tuple(sizes)
    G = len(sizes)
    fmt_g = FixedPointFormat(
        jnp.array([[3, 2, 4, 3][g % 4] for g in range(G)], jnp.int32),
        jnp.array([[5, 6, 4, 5][g % 4] for g in range(G)], jnp.int32))
    key = jax.random.key(3)
    tree = {f"layer{i}": jax.random.normal(jax.random.fold_in(key, 200 + i),
                                           (n_dev, s)) * 0.5
            for i, s in enumerate(sizes)}
    target = max(total // 8, 1)
    plan = overlap_lib.plan_buckets(sizes, target)
    abstract = {n: jax.ShapeDtypeStruct((s,), jnp.float32)
                for n, s in zip(tree, sizes)}
    # flatten-order buckets, exactly like qtrain.zero_partitioner
    part = GroupAlignedPartitioner.create(
        abstract, n_dev, backend="jnp",
        buckets=tuple(sorted(plan.buckets, key=lambda r: r[0])))

    def local_tree(tr):
        return {n: v.reshape(-1) for n, v in tr.items()}

    def zero_body(tr, k):
        # same key to both legs, like the train step (the internal fold
        # constants keep the two draw streams disjoint)
        gshard, _ = overlap_lib.zero_bucketed_reduce_scatter(
            local_tree(tr), fmt_g, "data", k, part=part, backend="jnp")
        flat, _ = overlap_lib.zero_allgather_params(
            gshard, fmt_g, "data", k, part=part, backend="jnp")
        return flat

    def fp32_body(tr, k):
        flat = part.flatten(local_tree(tr))
        gshard = jax.lax.psum_scatter(flat, "data", scatter_dimension=0,
                                      tiled=True) / n_dev
        return jax.lax.all_gather(gshard, "data", axis=0, tiled=True)

    fns, stats = {}, {}
    for name, body in (("fp32", fp32_body), ("zero_groupaligned", zero_body)):
        fn = jax.jit(jax.shard_map(
            body, mesh=mesh,
            in_specs=({k: P("data", None) for k in tree}, P()),
            out_specs=P(), check_vma=False))
        hlo = fn.lower(tree, key).compile().as_text()
        wire = collective_wire_bytes(hlo)
        fns[name] = fn
        stats[name] = {"wire_bytes": wire["total"],
                       "wire_bytes_by_dtype": wire["by_dtype"]}
    times = _time_variants(fns, (tree, key), iters)
    for name, ms in times.items():
        stats[name]["ms_per_step"] = ms
    ratio = (stats["zero_groupaligned"]["wire_bytes"]
             / stats["fp32"]["wire_bytes"])
    return {
        "leaf_sizes": list(sizes),
        "total_elems": total,
        "padded_elems": part.padded_size,
        "n_buckets": part.n_buckets,
        "per_variant": stats,
        "wire_ratio_int8_over_fp32": ratio,
    }


def run_metrics_fetch(mesh, steps: int):
    """Per-step host sync vs deferred metrics fetch on a compressed step.

    The serial driver fetched every step's metrics to Python floats
    before issuing the next step — a host round-trip on the critical path
    that also fences the overlap schedule (nothing can stay in flight
    across a blocking fetch).  The overlap-aware driver
    (``repro.launch.train``) keeps metrics on device and drains them at
    log points only.  Both loops run the SAME jitted compressed step and
    block on the final state at the end, so the difference is purely the
    per-step host sync.
    """
    from jax.sharding import NamedSharding
    from repro.core import qtrain
    from repro.data import MNISTLike
    from repro.models import lenet
    from repro.optim import SGDConfig, make_optimizer

    opt = make_optimizer(SGDConfig())
    data = MNISTLike(batch=64, seed=0)
    params = lenet.init(jax.random.key(0))
    qcfg = qtrain.QuantConfig(enabled=True, grad_allreduce_bits=8)
    state0 = qtrain.TrainState.create(params, opt.init(params), qcfg,
                                      jax.random.key(1))
    batch_sh = {"images": NamedSharding(mesh, P("data")),
                "labels": NamedSharding(mesh, P("data"))}
    repl = jax.tree.map(lambda _: NamedSharding(mesh, P()), state0)
    step = jax.jit(qtrain.make_train_step(lenet.loss_fn, opt, qcfg,
                                          mesh=mesh),
                   in_shardings=(repl, batch_sh), out_shardings=None)
    batches = [data.train_batch(i) for i in range(steps)]
    state, m = step(state0, batches[0])            # compile + warm
    jax.block_until_ready((state, m))

    def synced():
        st, out = state0, []
        for b in batches:
            st, m = step(st, b)
            out.append(float(m["loss"]))           # host sync per step
        jax.block_until_ready(st)
        return out

    def deferred():
        st, pending = state0, []
        for b in batches:
            st, m = step(st, b)
            pending.append(m)                      # stays on device
        jax.block_until_ready(st)
        return [float(m["loss"]) for m in pending]

    # warm both loops, then time them ROUND-ROBIN min-of-rounds like
    # _time_variants — a single back-to-back pair is at the mercy of
    # whatever else the box is doing for those few seconds
    assert synced() == deferred()                  # fetch mode is metadata
    best = {"synced": float("inf"), "deferred": float("inf")}
    for _ in range(4):
        for name, loop in (("synced", synced), ("deferred", deferred)):
            t0 = time.time()
            loop()
            best[name] = min(best[name], time.time() - t0)
    return {
        "steps": steps,
        "synced_ms_per_step": best["synced"] / steps * 1e3,
        "deferred_ms_per_step": best["deferred"] / steps * 1e3,
        "deferred_improvement": 1.0 - best["deferred"] / best["synced"],
    }


def run():
    n_dev = jax.device_count()
    if n_dev < 2:
        out = {"skipped": True,
               "note": "needs a multi-device mesh; run standalone "
                       "(python -m benchmarks.bench_collectives)"}
        save_result("collectives", out)
        return out

    mesh = jax.make_mesh((n_dev,), ("data",))
    size = (1 << 21) if is_quick() else (1 << 24)     # fp32 elements per rank
    iters = 3 if is_quick() else 20
    fmt = FixedPointFormat.create(3, 5)
    # per-group table: one ⟨IL, FL⟩ per layer-sized group, radices spread
    # over 3 octaves like real per-layer gradient ranges.  The quantum is
    # one (256, 1024) kernel tile and every group size is a multiple of
    # it, so the grouped grid matches the global kernel's tile geometry
    # EXACTLY (same tile count, same tile shape, identity align): the
    # walltime ratio isolates the [G, 2]-table machinery — the honest
    # apples-to-apples comparison, and the right real-HW configuration
    # for multi-MiB layers (the 4096 default quantum is sized for trees
    # of many small leaves instead)
    quantum = 1 << 18                      # = one (256, 1024) kernel tile
    G = 8
    fmt_g = FixedPointFormat(
        jnp.array([[3, 2, 4, 3][g % 4] for g in range(G)], jnp.int32),
        jnp.array([[5, 6, 4, 5][g % 4] for g in range(G)], jnp.int32))
    group_sizes = tuple([size // G] * G)
    x = jax.random.normal(jax.random.key(0), (n_dev, size)) * 0.5
    key = jax.random.key(1)

    def fp32_body(xs, key):
        return jax.lax.pmean(xs[0], "data")

    def int8_body(backend, grouped=False):
        def body(xs, key):
            m, _ = dps_allreduce_mean(
                xs[0], fmt_g if grouped else fmt, "data", key,
                backend=backend,
                group_sizes=group_sizes if grouped else None,
                quantum=quantum)
            return m
        return body

    variants = {}
    results = {}
    for name, body in (("fp32", fp32_body),
                       ("int8_jnp", int8_body("jnp")),
                       ("int8_kernel", int8_body("kernel")),
                       ("int8_jnp_grouped", int8_body("jnp", grouped=True)),
                       ("int8_kernel_grouped",
                        int8_body("kernel", grouped=True))):
        fn = jax.jit(jax.shard_map(body, mesh=mesh,
                                   in_specs=(P("data", None), P()),
                                   out_specs=P(), check_vma=False))
        hlo = fn.lower(x, key).compile().as_text()
        wire = collective_wire_bytes(hlo)
        variants[name] = fn
        results[name] = {"wire_bytes": wire["total"],
                         "wire_bytes_by_dtype": wire["by_dtype"],
                         "hbm_model_bytes_per_rank":
                             hbm_traffic_model(size, n_dev, name)}
    # interleaved timing: the grouped-vs-global kernel ratio claim needs
    # both sides measured under the same machine conditions
    times = _time_variants(variants, (x, key), max(iters, 5))
    for name, ms in times.items():
        results[name]["ms_per_step"] = ms

    # the codecs draw identical rounding bits from the same key, so the
    # collective's result must be bit-identical across backends — for the
    # global AND the grouped format table.
    codecs_bitexact = bool(jnp.array_equal(variants["int8_jnp"](x, key),
                                           variants["int8_kernel"](x, key)))
    grouped_bitexact = bool(jnp.array_equal(
        variants["int8_jnp_grouped"](x, key),
        variants["int8_kernel_grouped"](x, key)))

    ratio = results["int8_jnp"]["wire_bytes"] / results["fp32"]["wire_bytes"]
    grouped_wall_ratio = (results["int8_kernel_grouped"]["ms_per_step"]
                          / results["int8_kernel"]["ms_per_step"])
    grouped_wire_ratio = (results["int8_kernel_grouped"]["wire_bytes"]
                          / results["fp32"]["wire_bytes"])

    # --- rebuilt tree all-reduce: no fp32 flat-concat in the HLO ---
    tree = {f"layer{i}": jax.random.normal(jax.random.fold_in(key, i),
                                           (n_dev, s)) * 0.5
            for i, s in enumerate((48000, 1200, 30720, 120, 840, 10))}
    tree_elems = sum(v.shape[1] for v in tree.values())
    fmt_tree = FixedPointFormat(
        jnp.array([3, 2, 4, 3, 2, 3], jnp.int32),
        jnp.array([5, 6, 4, 5, 6, 5], jnp.int32))
    tree_stats = {}
    for tname, tfmt in (("global", fmt), ("per_layer", fmt_tree)):
        def tree_body(tr, key, _f=tfmt):
            m, _ = dps_allreduce_mean_tree(tr, _f, "data", key)
            return m
        fn = jax.jit(jax.shard_map(
            tree_body, mesh=mesh,
            in_specs=({k: P("data", None) for k in tree}, P()),
            out_specs=P(), check_vma=False))
        hlo = fn.lower(tree, key).compile().as_text()
        cat = concat_bytes(hlo)
        wire = collective_wire_bytes(hlo)
        ms = _time_steps(fn, (tree, key), iters)
        tree_stats[tname] = {
            "f32_concat_bytes": cat["by_dtype"].get("f32", 0.0),
            "concat_bytes_by_dtype": cat["by_dtype"],
            "wire_bytes": wire["total"],
            "ms_per_step": ms,
        }
    tree_f32_concat = max(t["f32_concat_bytes"]
                          for t in tree_stats.values())
    # threshold: anything tree-sized would mean the flat-concat came back;
    # stats-stacking noise is a few hundred bytes
    tree_no_f32_concat = tree_f32_concat < 0.01 * 4 * tree_elems

    # the x-sized buffers are dead past this point; release them before
    # the overlap phase allocates its own tree at the same scale
    del variants, x

    # backward-overlapped bucketed wire vs the serial monolithic pipeline
    # the 25%-improvement claim needs a converged min-of-rounds on
    # a noisy 1-core box: 16 rounds (~13 s) instead of quick's 3
    overlap = run_overlap_wire(mesh, max(iters, 16), size)
    zero_ga = run_zero_groupaligned(mesh, iters, size)
    fetch = run_metrics_fetch(mesh, steps=12 if is_quick() else 30)

    # wire-domain controller comparison (shared-IL-style vs dedicated);
    # 40+ steps like the pinned stability test — the hair-trigger scenario
    # needs the post-transient window for an honest tail mean
    wire_ctrl = run_wire_controllers(mesh, steps=40 if is_quick() else 60)

    out = {
        "n_devices": n_dev,
        "elements_per_rank": size,
        "wire_groups": G,
        "group_quantum": quantum,
        "fp32_wire_bytes": results["fp32"]["wire_bytes"],
        "int8_wire_bytes": results["int8_jnp"]["wire_bytes"],
        "wire_ratio_int8_over_fp32": ratio,
        "grouped_wire_ratio_int8_over_fp32": grouped_wire_ratio,
        "grouped_kernel_walltime_over_global_kernel": grouped_wall_ratio,
        "per_variant": results,
        "tree_allreduce": tree_stats,
        "overlap": overlap,
        "zero_groupaligned": zero_ga,
        "metrics_fetch": fetch,
        "codecs_bitexact": codecs_bitexact,
        "grouped_codecs_bitexact": grouped_bitexact,
        "wire_controller": wire_ctrl,
        "note": "CPU container: int8_kernel runs the Pallas codec in "
                "interpret mode (numerics only; walltime not a kernel "
                "measurement)",
        "claims": {
            "int8_wire_le_quarter_fp32": ratio <= 0.26,
            "codec_backends_bitexact": codecs_bitexact,
            "grouped_codec_backends_bitexact": grouped_bitexact,
            # grouped wire overhead = group/chunk alignment padding only
            "grouped_wire_le_quarter_fp32": grouped_wire_ratio <= 0.26,
            # interpret-mode walltime is emulation cost (see module
            # docstring) and its grouped/global ratio moves with the host
            # CPU — measured 1.01 and 1.21 on two different boxes for the
            # SAME code.  The bound catches the failure mode that matters
            # (a mis-tiled [G, 2]-table path runs 20-30x, not 1.2x).
            "grouped_kernel_within_1p35x_of_global":
                grouped_wall_ratio <= 1.35,
            "tree_allreduce_no_f32_flat_concat": tree_no_f32_concat,
            # the overlapped bucketed wire must beat the serial monolithic
            # pipeline outright, and by >= 25% (cache locality + per-bucket
            # quanta on this box; on real hardware the collective also
            # hides behind backward compute)
            "overlap_faster_than_serial":
                overlap["per_variant"]["overlap"]["ms_per_step"]
                < overlap["per_variant"]["serial"]["ms_per_step"],
            "overlap_ge_25pct_over_serial":
                overlap["overlap_improvement_over_serial"] >= 0.25,
            # the sharded two-leg pipeline ships int8 both ways over the
            # group-aligned layout; the bound includes alignment padding
            "zero_groupaligned_wire_le_quarter_fp32":
                zero_ga["wire_ratio_int8_over_fp32"] <= 0.26,
            # on this 1-core emulation the step executes serially either
            # way, so deferring the host fetch is a wash (measured: 1-6%
            # slower from the deeper async dispatch queue) — the claim
            # bounds it at noise level; the actual win needs hardware
            # where a blocked host thread stalls the dispatch pipeline
            "deferred_fetch_within_noise":
                fetch["deferred_ms_per_step"]
                <= 1.10 * fetch["synced_ms_per_step"],
            **wire_ctrl["claims"],
        },
    }
    save_result("collectives", out)
    return out


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=1, default=float))
