"""Gradient all-reduce wire benchmark: fp32 vs the int8 DPS codec (§dist).

Compares three ways to average a gradient-sized tensor across a host-device
data mesh:

  * ``fp32``    — ``lax.pmean``: XLA's stock all-reduce,
  * ``int8_jnp``    — ``dps_allreduce_mean`` with the jnp wire codec,
  * ``int8_kernel`` — the same collective with the fused Pallas
    ``dps_quant_wire`` codec (interpret mode on CPU — numerics-identical,
    walltime is emulation cost only; honest kernel timing needs a TPU).

Reported per variant: ring-model wire bytes parsed from the compiled HLO
(see ``repro.launch.hlo_stats``) and walltime per step.  The headline
claim is the ISSUE/ROADMAP one: the int8 two-leg path moves ≤ ~1/4 the
wire bytes of the fp32 all-reduce.

Run standalone (multi-device): ``PYTHONPATH=src python -m
benchmarks.bench_collectives`` — the module forces an 8-way host platform
before JAX initializes.  Under ``benchmarks.run`` (JAX already live with
one device) it degrades to a note.
"""

from __future__ import annotations

import os

# only the standalone entry point (python -m benchmarks.bench_collectives)
# may mutate process-global XLA flags, and only before JAX initializes; a
# plain import (benchmarks.run, pytest collection) must stay side-effect
# free.
if __name__ == "__main__" and "jax" not in __import__("sys").modules:
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                               + os.environ.get("XLA_FLAGS", ""))

import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from benchmarks.common import is_quick, save_result
from repro.core.fixed_point import FixedPointFormat
from repro.dist.collectives import dps_allreduce_mean
from repro.launch.hlo_stats import collective_wire_bytes


def _time_steps(fn, args, iters: int) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters * 1e3


def run():
    n_dev = jax.device_count()
    if n_dev < 2:
        out = {"skipped": True,
               "note": "needs a multi-device mesh; run standalone "
                       "(python -m benchmarks.bench_collectives)"}
        save_result("collectives", out)
        return out

    mesh = jax.make_mesh((n_dev,), ("data",))
    size = (1 << 20) if is_quick() else (1 << 24)     # fp32 elements per rank
    iters = 3 if is_quick() else 20
    fmt = FixedPointFormat.create(3, 5)
    x = jax.random.normal(jax.random.key(0), (n_dev, size)) * 0.5
    key = jax.random.key(1)

    def fp32_body(xs, key):
        return jax.lax.pmean(xs[0], "data")

    def int8_body(backend):
        def body(xs, key):
            m, _ = dps_allreduce_mean(xs[0], fmt, "data", key,
                                      backend=backend)
            return m
        return body

    variants = {}
    results = {}
    for name, body in (("fp32", fp32_body),
                       ("int8_jnp", int8_body("jnp")),
                       ("int8_kernel", int8_body("kernel"))):
        fn = jax.jit(jax.shard_map(body, mesh=mesh,
                                   in_specs=(P("data", None), P()),
                                   out_specs=P(), check_vma=False))
        hlo = fn.lower(x, key).compile().as_text()
        wire = collective_wire_bytes(hlo)
        ms = _time_steps(fn, (x, key), iters)
        variants[name] = fn
        results[name] = {"wire_bytes": wire["total"],
                         "wire_bytes_by_dtype": wire["by_dtype"],
                         "ms_per_step": ms}

    # the two codecs draw identical rounding bits from the same key, so the
    # collective's result must be bit-identical across backends.
    m_jnp = variants["int8_jnp"](x, key)
    m_ker = variants["int8_kernel"](x, key)
    codecs_bitexact = bool(jnp.array_equal(m_jnp, m_ker))

    ratio = results["int8_jnp"]["wire_bytes"] / results["fp32"]["wire_bytes"]
    out = {
        "n_devices": n_dev,
        "elements_per_rank": size,
        "fp32_wire_bytes": results["fp32"]["wire_bytes"],
        "int8_wire_bytes": results["int8_jnp"]["wire_bytes"],
        "wire_ratio_int8_over_fp32": ratio,
        "per_variant": results,
        "codecs_bitexact": codecs_bitexact,
        "note": "CPU container: int8_kernel runs the Pallas codec in "
                "interpret mode (numerics only; walltime not a kernel "
                "measurement)",
        "claims": {
            "int8_wire_le_quarter_fp32": ratio <= 0.26,
            "codec_backends_bitexact": codecs_bitexact,
        },
    }
    save_result("collectives", out)
    return out


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=1, default=float))
