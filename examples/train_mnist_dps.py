"""End-to-end driver: the paper's evaluation (§4), LeNet on MNIST-class data.

Trains three runs — fp32 baseline, DPS (the paper's Algorithm 2), and the
fixed-13-bit ablation — and prints the Fig. 3/4 artifacts: convergence and
bit-width trajectories.

  PYTHONPATH=src python examples/train_mnist_dps.py --steps 400
  PYTHONPATH=src python examples/train_mnist_dps.py --steps 10000  # paper
"""

import argparse

import numpy as np

from repro.apps.mnist import paper_quant_config, train_mnist
from repro.data import MNISTLike


def sparkline(vals, width=48):
    bars = "▁▂▃▄▅▆▇█"
    v = np.asarray(vals, dtype=float)
    v = v[np.isfinite(v)]
    if not len(v):
        return "(no data)"
    idx = np.linspace(0, len(v) - 1, width).astype(int)
    v = v[idx]
    lo, hi = v.min(), v.max()
    span = (hi - lo) or 1.0
    return "".join(bars[int(7 * (x - lo) / span)] for x in v)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    data = MNISTLike(batch=64, seed=args.seed)
    runs = {
        "fp32 baseline": train_mnist(None, steps=args.steps, data=data),
        "DPS (paper)": train_mnist(paper_quant_config(), steps=args.steps,
                                   data=data),
        "fixed 13-bit": train_mnist(paper_quant_config(static_bits=13),
                                    steps=args.steps, data=data),
    }

    print(f"\n{'run':16s} {'test acc':>9s} {'avg bits w/a/g':>18s}  loss curve")
    for name, h in runs.items():
        bits = (f"{h['avg_bits_w']:.1f}/{h['avg_bits_a']:.1f}/"
                f"{h['avg_bits_g']:.1f}" if name != "fp32 baseline"
                else "32/32/32")
        print(f"{name:16s} {h['final_test_acc']:9.4f} {bits:>18s}  "
              f"{sparkline(h['loss'])}")

    h = runs["DPS (paper)"]
    print("\nbit-width trajectories (paper Fig. 3):")
    for attr in ("w", "a", "g"):
        tot = np.add(h[f"il_{attr}"], h[f"fl_{attr}"])
        print(f"  {attr}: {sparkline(tot)}  "
              f"(start {tot[0]:.0f} -> end {tot[-1]:.0f}, avg {tot.mean():.1f})")


if __name__ == "__main__":
    main()
