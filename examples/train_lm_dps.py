"""Beyond-paper: DPS-quantized LM training for a few hundred steps on the
synthetic token stream, with checkpoint/auto-resume.

This is the LM-scale variant of the paper's loop: weights/activations/
gradients snap to the ⟨IL, FL⟩ grid every step, one Algorithm-2 controller
per attribute, loss on the learnable affine-recurrence stream goes down.
Interrupt it (Ctrl-C) and re-run: it resumes from the newest checkpoint.

  PYTHONPATH=src python examples/train_lm_dps.py --steps 200
  PYTHONPATH=src python examples/train_lm_dps.py --arch qwen3_moe_30b_a3b
"""

import argparse

from repro.launch import train as train_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="llama3_2_3b",
                    help="architecture family (reduced smoke-size config)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    history = train_mod.main([
        "--arch", args.arch, "--smoke", "--steps", str(args.steps),
        "--batch", "8", "--seq", "128", "--optimizer", "adamw",
        "--controller", "paper", "--ckpt-dir", args.ckpt_dir,
        "--ckpt-every", "50", "--log-every", "20", "--resume",
    ])
    if history:
        first, last = history[0]["loss"], history[-1]["loss"]
        print(f"\nloss {first:.3f} -> {last:.3f} over {len(history)} steps "
              f"({'LEARNING' if last < first - 0.3 else 'resumed near end'})")


if __name__ == "__main__":
    main()
