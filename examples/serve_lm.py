"""Serving example: continuous batching on a paged int8 KV cache.

Runs the repro.serve engine on a synthetic many-user trace — requests
with mixed prompt/generation lengths arrive over time, get admitted into
free batch slots, and decode against int8 KV pages whose per-page
⟨IL, FL⟩ formats are placed by the ``kv_cache`` precision domain.  The
printed spread line shows the DPS signal at work: pages holding different
content land on different grids.

  PYTHONPATH=src python examples/serve_lm.py
  PYTHONPATH=src python examples/serve_lm.py --kv-bits none  # fp32 pages
"""

import sys

from repro.launch import serve


def main():
    argv = sys.argv[1:]
    if "--arch" not in argv:
        argv = ["--arch", "llama3_2_3b"] + argv
    if "--smoke" not in argv:
        argv.append("--smoke")
    serve.main(argv + ["--requests", "8", "--slots", "4", "--page-size", "4",
                       "--max-prompt", "16", "--max-new", "12"])


if __name__ == "__main__":
    main()
