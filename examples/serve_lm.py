"""Serving example: batched prefill + greedy decode with quantized KV cache.

Demonstrates the inference side of the framework — the paper's quantizer
applied to serving state.  With --quant-kv the cache is snapped to ⟨8,8⟩
(int8-equivalent payload), halving KV HBM versus bf16.

  PYTHONPATH=src python examples/serve_lm.py --arch llama3_2_3b
  PYTHONPATH=src python examples/serve_lm.py --arch mamba2_1_3b  # O(1) state
"""

import sys

from repro.launch import serve


def main():
    argv = sys.argv[1:]
    if "--arch" not in argv:
        argv = ["--arch", "llama3_2_3b"] + argv
    if "--smoke" not in argv:
        argv.append("--smoke")
    serve.main(argv + ["--batch", "4", "--prompt-len", "16", "--gen", "12",
                       "--quant-kv"])


if __name__ == "__main__":
    main()
