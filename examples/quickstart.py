"""Quickstart: the paper's technique in 40 lines.

Quantize a tensor onto a dynamic fixed-point grid, watch Algorithm 2 adapt
⟨IL, FL⟩ from overflow rate and quantization error, and run one quantized
training step on a tiny llama-family model.

  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.core.dps import DPSHyper, make_controller
from repro.core.fixed_point import FixedPointFormat, quantize

# --- 1. fixed-point quantization with fused statistics -------------------
x = jax.random.normal(jax.random.key(0), (4096,)) * 3.0
fmt = FixedPointFormat.create(il=4, fl=4)          # range ±8, grid 1/16
q, stats = quantize(x, fmt, mode="stochastic", key=jax.random.key(1))
print(f"⟨4,4⟩: overflow rate R={float(stats.overflow_rate()):.4f} "
      f"quant error E={float(stats.quant_error()):.4f}")

# --- 2. the paper's controller reacts: R>R_max -> IL+1; E>E_max -> FL+1 --
ctrl = make_controller("paper", DPSHyper(r_max=1e-4, e_max=1e-4))
state = ctrl.init()
for step in range(6):
    fmt = ctrl.fmt(state)
    q, stats = quantize(x, fmt, mode="stochastic",
                        key=jax.random.fold_in(jax.random.key(2), step))
    state = ctrl.update(state, stats)
    print(f"step {step}: ⟨{int(fmt.il)},{int(fmt.fl)}⟩ "
          f"R={float(stats.overflow_rate()):.2e} "
          f"E={float(stats.quant_error()):.2e}")

# --- 3. one quantized train step on a reduced llama3.2 -------------------
from repro.configs.base import get_config, smoke
from repro.core import qtrain
from repro.models import registry
from repro.models.common import init_params
from repro.optim import SGDConfig, make_optimizer

cfg = smoke(get_config("llama3_2_3b"))
mod = registry(cfg.family)
params = init_params(jax.random.key(3), mod.model_defs(cfg))
opt = make_optimizer(SGDConfig())
qcfg = qtrain.QuantConfig(enabled=True, controller="paper")
step_fn = jax.jit(qtrain.make_train_step(mod.loss_fn(cfg), opt, qcfg))
tstate = qtrain.TrainState.create(params, opt.init(params), qcfg,
                                  jax.random.key(4))
batch = {"tokens": jax.random.randint(jax.random.key(5), (4, 33), 0,
                                      cfg.vocab)}
tstate, metrics = step_fn(tstate, batch)
print(f"\nquantized llama train step: loss={float(metrics['loss']):.3f} "
      f"weights ⟨{int(metrics['il_w'])},{int(metrics['fl_w'])}⟩ "
      f"acts ⟨{int(metrics['il_a'])},{int(metrics['fl_a'])}⟩")
