"""Fault-injection suite for ``repro.resilience`` (ISSUE-10).

Every guard is proven by firing its fault and watching the recovery:

  (a) transparency — guards armed with no fault are BIT-EXACT with the
      guard-free step (loss, params, DPS trajectory) at ``bits=None``,
      nearest@8 and stochastic@8;
  (b) NaN gradients — detected pre-encode (the int8 codec clips NaN
      silently), update skipped bit-exactly, wire degrades to fp32,
      int8 re-arms after the cooldown;
  (c) overflow storm — per-domain overflow EWMA trips, wire degrades,
      training recovers into the un-faulted loss envelope;
  (d) wire payload bit-flip — the gradient-norm spike guard catches the
      decoded offset, the poisoned step is skipped;
  (e) torn/corrupt checkpoints — SHA-256 digests make ``latest_step``
      walk back to the newest good step and ``restore`` fail loudly;
  (f) pre-emption — a REAL ``SIGTERM`` mid-run checkpoints and exits 0,
      and ``--resume`` continues (even after the newest checkpoint is
      corrupted on top);
  (g) loss-spike rollback — the host-side snapshot ring restores a
      healthy state after divergence the in-step guards can't see;
  (h) serve backpressure — page-pool exhaustion holds requests in the
      queue instead of crashing; every request completes;
  (i) the flow verifier's ``PF-GUARD-TAINT`` rule — degradation signals
      must descend from wire-leg stats (positive + negative oracle).

Multi-device pieces run in subprocesses under
``xla_force_host_platform_device_count=8`` (the repo-wide idiom).
"""

import dataclasses
import os
import signal
import subprocess
import sys
import textwrap

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))


def run_with_devices(code: str, n: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


# ---------------------------------------------------------------------------
# Health word plumbing (host-side, no devices).
# ---------------------------------------------------------------------------

def test_health_flags_decode():
    from repro.resilience import (HEALTH_DEGRADED, HEALTH_GRADS_NONFINITE,
                                  HEALTH_SKIPPED, health_flags)
    word = HEALTH_GRADS_NONFINITE | HEALTH_DEGRADED | HEALTH_SKIPPED
    assert health_flags(word) == ("grads-nonfinite", "degraded", "skipped")
    assert health_flags(0) == ()


# ---------------------------------------------------------------------------
# (a) transparency: armed guards with no fault are bit-exact.
# ---------------------------------------------------------------------------

def test_guards_transparent_across_rounding_modes():
    run_with_devices("""
        import jax, jax.numpy as jnp
        from repro.core import fixed_point as fxp
        from repro.core import qtrain
        from repro.models import lenet
        from repro.optim import SGDConfig, make_optimizer
        from repro.resilience import GuardConfig

        mesh = jax.make_mesh((8,), ("data",))
        opt = make_optimizer(SGDConfig())
        params = lenet.init(jax.random.key(0))
        batch = {"images": jax.random.normal(jax.random.key(2),
                                             (64, 28, 28, 1)),
                 "labels": jax.random.randint(jax.random.key(3), (64,),
                                              0, 10)}

        variants = [
            ("bits=None", dict(enabled=True), None),
            ("nearest@8", dict(enabled=True, grad_allreduce_bits=8,
                               rounding=fxp.ROUND_NEAREST), mesh),
            ("stochastic@8", dict(enabled=True, grad_allreduce_bits=8), mesh),
        ]
        for name, kw, m in variants:
            q0 = qtrain.QuantConfig(**kw)
            qg = qtrain.QuantConfig(**kw, guards=GuardConfig())
            s0 = qtrain.TrainState.create(params, opt.init(params), q0,
                                          jax.random.key(1))
            sg = qtrain.TrainState.create(params, opt.init(params), qg,
                                          jax.random.key(1))
            f0 = jax.jit(qtrain.make_train_step(lenet.loss_fn, opt, q0,
                                                mesh=m))
            fg = jax.jit(qtrain.make_train_step(lenet.loss_fn, opt, qg,
                                                mesh=m))
            for i in range(3):
                s0, m0 = f0(s0, batch)
                sg, mg = fg(sg, batch)
                assert float(m0["loss"]) == float(mg["loss"]), (name, i)
            for a, b in zip(jax.tree.leaves(s0.params),
                            jax.tree.leaves(sg.params)):
                assert jnp.array_equal(a, b), name
            for a, b in zip(jax.tree.leaves(s0.dps),
                            jax.tree.leaves(sg.dps)):
                assert jnp.array_equal(a, b), name
            assert int(sg.guard.health) == 0, name
            assert int(sg.guard.skipped) == 0, name
            assert int(sg.guard.trips) == 0, name
            print(name, "transparent")
    """)


# ---------------------------------------------------------------------------
# (b) NaN gradients: detect -> skip -> degrade -> cooldown -> re-arm.
# ---------------------------------------------------------------------------

def test_nan_fault_skip_degrade_rearm():
    run_with_devices("""
        import jax, jax.numpy as jnp
        from repro.core import qtrain
        from repro.models import lenet
        from repro.optim import SGDConfig, make_optimizer
        from repro.resilience import (FaultPlan, GuardConfig,
                                      HEALTH_GRADS_NONFINITE, HEALTH_SKIPPED)

        mesh = jax.make_mesh((8,), ("data",))
        opt = make_optimizer(SGDConfig())
        params = lenet.init(jax.random.key(0))
        batch = {"images": jax.random.normal(jax.random.key(2),
                                             (64, 28, 28, 1)),
                 "labels": jax.random.randint(jax.random.key(3), (64,),
                                              0, 10)}
        qcfg = qtrain.QuantConfig(enabled=True, grad_allreduce_bits=8,
                                  guards=GuardConfig(cooldown=3))
        s = qtrain.TrainState.create(params, opt.init(params), qcfg,
                                     jax.random.key(1))
        step = jax.jit(qtrain.make_train_step(
            lenet.loss_fn, opt, qcfg, mesh=mesh,
            faults=FaultPlan(nan_grads_at=2)))
        hist = []
        for i in range(8):
            prev = s.params
            s, m = step(s, batch)
            hist.append((int(m["health"]), int(m["degraded"]),
                         int(m["skipped"])))
            if i == 2:
                # the poisoned update is skipped BIT-EXACTLY
                for a, b in zip(jax.tree.leaves(prev),
                                jax.tree.leaves(s.params)):
                    assert jnp.array_equal(a, b)
        h2 = hist[2][0]
        assert h2 & HEALTH_GRADS_NONFINITE and h2 & HEALTH_SKIPPED, hist
        assert hist[2][2] == 1 and hist[7][2] == 1, hist   # exactly one skip
        assert hist[3][1] == 1, hist       # degraded right after the trip
        assert hist[7][1] == 0, hist       # int8 re-armed after cooldown
        assert int(s.guard.trips) == 1
        assert all(bool(jnp.isfinite(l).all())
                   for l in jax.tree.leaves(s.params))
        print("nan recovery OK", hist)
    """)


# ---------------------------------------------------------------------------
# (c) overflow storm: EWMA trip -> degrade -> recover into the envelope.
# ---------------------------------------------------------------------------

def test_overflow_storm_degrade_and_recover():
    run_with_devices("""
        import jax, jax.numpy as jnp
        from repro.core import qtrain
        from repro.models import lenet
        from repro.optim import SGDConfig, make_optimizer
        from repro.resilience import (FaultPlan, GuardConfig,
                                      HEALTH_OVERFLOW_STORM)

        mesh = jax.make_mesh((8,), ("data",))
        opt = make_optimizer(SGDConfig())
        params = lenet.init(jax.random.key(0))
        batch = {"images": jax.random.normal(jax.random.key(2),
                                             (64, 28, 28, 1)),
                 "labels": jax.random.randint(jax.random.key(3), (64,),
                                              0, 10)}

        def run(faults, steps):
            qcfg = qtrain.QuantConfig(enabled=True, grad_allreduce_bits=8,
                                      guards=GuardConfig(cooldown=3))
            s = qtrain.TrainState.create(params, opt.init(params), qcfg,
                                         jax.random.key(1))
            fn = jax.jit(qtrain.make_train_step(lenet.loss_fn, opt, qcfg,
                                                mesh=mesh, faults=faults))
            hist = []
            for i in range(steps):
                s, m = fn(s, batch)
                hist.append((int(m["health"]), int(m["degraded"]),
                             float(m["loss"])))
            return s, hist

        s0, clean = run(None, 12)
        sf, hist = run(FaultPlan(overflow_storm_at=2, storm_steps=2,
                                 storm_scale=float(2 ** 12)), 12)
        # detection within the storm window
        assert any(h[0] & HEALTH_OVERFLOW_STORM for h in hist[2:5]), hist
        # degradation engaged, then re-armed by the end
        assert any(h[1] for h in hist[2:8]), hist
        assert hist[-1][1] == 0, hist
        assert int(sf.guard.trips) >= 1
        # recovery: params finite, final loss inside the un-faulted
        # envelope (generous: the storm steps still moved the params)
        assert all(bool(jnp.isfinite(l).all())
                   for l in jax.tree.leaves(sf.params))
        lf, l0 = hist[-1][2], clean[-1][2]
        import math
        assert math.isfinite(lf), hist
        assert lf < 2.0 * l0 + 1.0, (lf, l0)
        print("storm recovery OK", hist)
    """)


# ---------------------------------------------------------------------------
# (d) wire payload bit-flip: spike guard catches transport corruption.
# ---------------------------------------------------------------------------

def test_wire_bitflip_spike_detected_and_skipped():
    run_with_devices("""
        import jax, jax.numpy as jnp
        from repro.core import qtrain
        from repro.models import lenet
        from repro.optim import SGDConfig, make_optimizer
        from repro.resilience import (FaultPlan, GuardConfig,
                                      HEALTH_GRAD_SPIKE, HEALTH_SKIPPED)

        mesh = jax.make_mesh((8,), ("data",))
        opt = make_optimizer(SGDConfig())
        params = lenet.init(jax.random.key(0))
        batch = {"images": jax.random.normal(jax.random.key(2),
                                             (64, 28, 28, 1)),
                 "labels": jax.random.randint(jax.random.key(3), (64,),
                                              0, 10)}
        qcfg = qtrain.QuantConfig(enabled=True, grad_allreduce_bits=8,
                                  guards=GuardConfig(cooldown=2))
        s = qtrain.TrainState.create(params, opt.init(params), qcfg,
                                     jax.random.key(1))
        step = jax.jit(qtrain.make_train_step(
            lenet.loss_fn, opt, qcfg, mesh=mesh,
            faults=FaultPlan(wire_flip_at=3)))
        hist = []
        for i in range(8):
            prev = s.params
            s, m = step(s, batch)
            hist.append((int(m["health"]), int(m["degraded"])))
            if i == 3:
                for a, b in zip(jax.tree.leaves(prev),
                                jax.tree.leaves(s.params)):
                    assert jnp.array_equal(a, b)   # poisoned sync skipped
        h3 = hist[3][0]
        assert h3 & HEALTH_GRAD_SPIKE and h3 & HEALTH_SKIPPED, hist
        assert hist[4][1] == 1, hist   # degraded after the flip
        assert hist[7][1] == 0, hist   # re-armed
        assert all(bool(jnp.isfinite(l).all())
                   for l in jax.tree.leaves(s.params))
        print("bit-flip detection OK", hist)
    """)


# ---------------------------------------------------------------------------
# (e) checkpoint integrity: digests, walk-back, loud restore failure.
# ---------------------------------------------------------------------------

def _small_tree():
    import jax
    import jax.numpy as jnp
    return {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "n": {"b": jnp.ones((5,), jnp.float32)},
            "k": jax.random.key(7),
            "s": jnp.int32(3)}


def test_ckpt_digests_walk_back_past_corruption(tmp_path):
    import jax
    from repro.checkpoint import latest_step, restore, save, verify_step
    from repro.resilience import corrupt_checkpoint

    t = _small_tree()
    for s in (1, 2, 3):
        save(str(tmp_path), s, t)
    assert latest_step(str(tmp_path)) == 3
    assert verify_step(str(tmp_path), 3)

    # torn npz (truncated write that survived the rename)
    corrupt_checkpoint(str(tmp_path), 3, mode="truncate")
    assert not verify_step(str(tmp_path), 3)
    assert latest_step(str(tmp_path)) == 2          # walked back
    # silent bit-rot: npz still opens, digest must catch it
    corrupt_checkpoint(str(tmp_path), 2, mode="bitflip")
    assert latest_step(str(tmp_path)) == 1
    # unverified scan still sees the newest dir (the old hole, explicit)
    assert latest_step(str(tmp_path), verify=False) == 3

    # restore of the good step round-trips
    template = jax.eval_shape(lambda: _small_tree())
    restored, _ = restore(str(tmp_path), 1, template)
    for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(t)):
        if jax.dtypes.issubdtype(a.dtype, jax.dtypes.prng_key):
            a, b = jax.random.key_data(a), jax.random.key_data(b)
        assert np.array_equal(np.asarray(a), np.asarray(b))
    # restore of corrupted steps fails LOUDLY, never silently
    with pytest.raises(Exception):
        restore(str(tmp_path), 3, template)
    with pytest.raises(ValueError, match="SHA-256"):
        restore(str(tmp_path), 2, template)


# ---------------------------------------------------------------------------
# (f) pre-emption: SIGTERM checkpoints + exits 0; resume survives a
#     corrupted newest checkpoint on top.
# ---------------------------------------------------------------------------

def _train_cli(extra, tmp_path, n_dev=2):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    args = [sys.executable, "-m", "repro.launch.train",
            "--arch", "llama3_2_3b", "--smoke", "--steps", "8",
            "--batch", "2", "--seq", "16", "--optimizer", "sgd",
            "--grad-allreduce-bits", "8", "--guards",
            "--ckpt-dir", str(tmp_path), "--ckpt-every", "2",
            "--log-every", "2"] + extra
    return subprocess.run(args, capture_output=True, text=True, env=env,
                          timeout=600)


def test_sigterm_preemption_checkpoints_and_resumes(tmp_path):
    from repro.checkpoint import latest_step
    from repro.resilience import corrupt_checkpoint

    out = _train_cli(["--sigterm-at", "5"], tmp_path)
    assert out.returncode == 0, f"{out.stdout}\n{out.stderr}"
    assert "PREEMPTED" in out.stdout, out.stdout
    pre = latest_step(str(tmp_path))
    assert pre is not None and pre >= 5, out.stdout

    # disk rot on top of the pre-emption: resume must fall back to the
    # newest GOOD checkpoint and still finish
    corrupt_checkpoint(str(tmp_path), pre, mode="truncate")
    good = latest_step(str(tmp_path))
    assert good is not None and good < pre

    out2 = _train_cli(["--resume"], tmp_path)
    assert out2.returncode == 0, f"{out2.stdout}\n{out2.stderr}"
    assert f"resumed from step {good}" in out2.stdout, out2.stdout
    assert "final_loss" in out2.stdout


# ---------------------------------------------------------------------------
# (g) loss-spike rollback ring (host side).
# ---------------------------------------------------------------------------

def test_rollback_ring_restores_healthy_state(capsys):
    """NaN gradients at step 5 with NO in-step guards: params go NaN,
    the drained window turns nonfinite, the ring rolls back to the
    step-5 snapshot and replays.  The fault is step-keyed, so every
    deterministic replay re-fires it — which is exactly what proves the
    restore: each replayed window's step-5 FORWARD loss is finite again
    (computed on the restored params, before the NaN grads re-poison
    them).  The rollback cap bounds the livelock and the driver still
    completes instead of crashing."""
    from repro.launch import train as train_mod
    hist = train_mod.main([
        "--arch", "llama3_2_3b", "--smoke", "--steps", "10",
        "--batch", "2", "--seq", "16", "--optimizer", "sgd",
        "--inject-nan-at", "5", "--rollback-ring", "2",
        "--log-every", "2"])
    out = capsys.readouterr().out
    n_rb = out.count("ROLLBACK")
    assert 1 <= n_rb <= 8, out
    assert "resuming from step 5 with wire degraded" in out, out
    # every rollback restored HEALTHY params: each replayed window
    # re-runs step 5's forward on the restored snapshot and drains a
    # finite loss before the re-fired fault poisons step 6 again
    losses = [h["loss"] for h in hist]
    first_bad = next(i for i, l in enumerate(losses) if not np.isfinite(l))
    finite_after = sum(1 for l in losses[first_bad:] if np.isfinite(l))
    assert finite_after >= n_rb, (n_rb, losses)
    # the run pushed through after the cap instead of looping forever
    assert len(hist) > 0 and not np.isfinite(losses[-1])


# ---------------------------------------------------------------------------
# (h) serve backpressure: pool exhaustion holds, drains, loses nothing.
# ---------------------------------------------------------------------------

def test_scheduler_requeue_preserves_fcfs():
    from repro.serve import Request, Scheduler
    reqs = [Request(rid=i, prompt=np.ones(4, np.int32), max_new=2,
                    arrival=0) for i in range(3)]
    s = Scheduler(reqs)
    head = s.pop_admissible(0, lambda r: True)
    assert head.rid == 0
    s.requeue(head)
    assert len(s) == 3
    assert s.pop_admissible(0, lambda r: True).rid == 0   # still the head


def test_serve_backpressure_exhaustion_then_drain():
    """More lifetime-page demand than the pool holds: requests are held
    in the queue under backpressure and every one of them completes —
    none dropped, no crash."""
    import jax
    from repro.configs.base import get_config, smoke
    from repro.models import registry
    from repro.models.common import init_params
    from repro.serve import Engine, EngineConfig, PagedLayout, Request

    cfg = smoke(get_config("llama3_2_3b"))
    params = init_params(jax.random.key(0), registry(cfg.family).model_defs(cfg))
    # 12 pages; each request needs ceil((8 prompt + 8 new)/4) = 4 pages
    # -> at most 3 of the 4 batch slots can ever be live; the rest queue
    lay = PagedLayout(page_size=4, n_pages=12, batch_slots=4,
                      max_pages_per_seq=8, max_prompt=16)
    eng = Engine(cfg, params, EngineConfig(layout=lay, kv_bits=None))
    reqs = [Request(rid=i,
                    prompt=np.full(8, 3 + i, np.int32), max_new=8,
                    arrival=0) for i in range(6)]
    rep = eng.run(reqs)
    assert all(len(rep.tokens[r.rid]) == r.max_new for r in reqs)
    assert rep.metrics["backpressure_steps"] > 0


def test_serve_alloc_failure_requeues_instead_of_crashing(monkeypatch):
    """Force the defensive path: the admission pre-check lies (can()
    always True) so ``alloc.alloc`` raises mid-admit — the engine must
    requeue the request and finish the trace regardless."""
    import jax
    from repro.configs.base import get_config, smoke
    from repro.models import registry
    from repro.models.common import init_params
    from repro.serve import (Engine, EngineConfig, PageAllocator,
                             PagedLayout, Request)

    # keep the real alloc (it raises on exhaustion); lying in the
    # pre-check makes the mid-admit exhaustion path actually execute
    monkeypatch.setattr(PageAllocator, "can", lambda self, n: True)

    cfg = smoke(get_config("llama3_2_3b"))
    params = init_params(jax.random.key(0), registry(cfg.family).model_defs(cfg))
    lay = PagedLayout(page_size=4, n_pages=12, batch_slots=4,
                      max_pages_per_seq=8, max_prompt=16)
    eng = Engine(cfg, params, EngineConfig(layout=lay, kv_bits=None))
    reqs = [Request(rid=i, prompt=np.full(8, 3 + i, np.int32), max_new=8,
                    arrival=0) for i in range(5)]
    rep = eng.run(reqs)
    assert all(len(rep.tokens[r.rid]) == r.max_new for r in reqs)
    assert rep.metrics["backpressure_steps"] > 0


# ---------------------------------------------------------------------------
# (i) PF-GUARD-TAINT: degradation signals must descend from wire stats.
# ---------------------------------------------------------------------------

def _taint_jaxpr(make_signal):
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.core.fixed_point import FixedPointFormat
    from repro.dist import collectives

    fmt = FixedPointFormat.create(3, 5)
    tree = {"leaf0": jnp.ones((64,), jnp.float32)}
    mesh = jax.make_mesh((1,), ("data",))

    def body(tr, k):
        mean, stats = collectives.dps_allreduce_mean_tree(tr, fmt, "data", k)
        return mean, make_signal(stats)

    fn = jax.shard_map(body, mesh=mesh,
                       in_specs=({"leaf0": P()}, P()),
                       out_specs=({"leaf0": P()}, P()),
                       check_vma=False)
    return jax.make_jaxpr(fn)(tree, jax.random.key(0))


def test_flow_guard_taint_positive_and_negative():
    import jax.numpy as jnp
    from repro.analysis import flow
    from repro.core import tagging

    # a signal genuinely derived from the wire-leg stats: clean
    def good(stats):
        rate = jnp.sum(stats.overflow) / jnp.maximum(jnp.sum(stats.count), 1.0)
        return tagging.tag(rate, "guard_sink", domain="wire_grads")

    rep = flow.analyze_jaxpr(_taint_jaxpr(good), name="guard-taint-good")
    assert "PF-GUARD-TAINT" in rep.checked
    assert not [v for v in rep.violations if v.rule == "PF-GUARD-TAINT"], \
        rep.summary()

    # a constant masquerading as a health signal in a wire step: flagged
    def bad(stats):
        return tagging.tag(jnp.float32(0.0), "guard_sink",
                           domain="wire_grads")

    rep = flow.analyze_jaxpr(_taint_jaxpr(bad), name="guard-taint-bad")
    bad_v = [v for v in rep.violations if v.rule == "PF-GUARD-TAINT"]
    assert bad_v, rep.summary()


def test_lint_guarded_cell_clean():
    """The full guarded train cell passes flow + HLO audit: the compiled
    fp32 fallback branches are declared bytes, not residual leakage."""
    run_with_devices("""
        from repro.analysis import lint
        reports = lint.lint_cell("lenet", "tree", guards=True)
        flow_rep = reports[0]
        assert "PF-GUARD-TAINT" in flow_rep.checked, flow_rep.checked
        for r in reports:
            assert not r.violations, r.summary()
        print("guarded lint cell clean")
    """)
