"""Group-aligned ZeRO layout (``repro.dist.sharding.GroupAlignedPartitioner``)
and its composition with per-layer wire formats and the overlapped bucketed
pipeline (ISSUE-8).

Covers the acceptance criteria:
  (a) partitioner edge cases — non-divisible leaves (the 37/8 case), leaves
      smaller than one quantum, a single-leaf tree — every leaf slot starts
      on a quantum boundary, rank chunks never straddle a leaf, and
      flatten → shard → assemble → unflatten round-trips bit-exactly;
  (b) ``zero_opt_shards`` + per-layer ``wire_grads`` + ``wire_overlap``
      runs end-to-end on an 8-device host mesh with no rejection branch,
      and is bit-exact vs the replicated per-layer step over 3 steps with
      live DPS controllers — at ``bits=None`` (pure layout change) and at
      8 wire bits under BOTH nearest and stochastic rounding (every wire
      rounding-bit draw is keyed by global leaf index, so the sharded and
      replicated schedules consume identical bit streams);
  (c) engagement policy — mismatched ``zero_opt_shards`` warns and falls
      back (no raise), and the chosen paths surface as ``train_step``
      attributes including ``zero_groupaligned_active``.

The parity tests run with a policy-excluded norm-scale leaf: the flat wire
legs cannot honor per-leaf carve-outs, so the params all-gather stays fp32
(``full_quant=False``) — the regime where the replicated and sharded steps
are defined to coincide exactly (the params-leg int8 snap is an extra
quantization the replicated step never performs).  Power-of-two SGD hypers
keep the shard-local optimizer math FMA-contraction-proof (see
``SGD._leaf``).
"""

import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))


def run_with_devices(code: str, n: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


# ---------------------------------------------------------------------------
# Partitioner geometry + round-trips (in-process, no mesh needed).
# ---------------------------------------------------------------------------

def _roundtrip(tree, n_shards, **kw):
    import jax.numpy as jnp
    import numpy as np
    from repro.dist.sharding import GroupAlignedPartitioner

    part = GroupAlignedPartitioner.create(tree, n_shards, **kw)
    # geometry invariants: aligned leaf slots, whole-quantum rank chunks
    assert part.padded_size == n_shards * part.shard_size
    for b, lay in enumerate(part.layouts):
        assert lay.chunk % lay.quantum == 0
        assert part.bucket_offset(b) % lay.quantum == 0
    import jax
    leaves = jax.tree_util.tree_leaves(tree)
    for g in range(len(leaves)):
        # every leaf slot starts on its bucket's quantum boundary
        b = next(i for i, r in enumerate(part.buckets) if g in r)
        off = part.leaf_offset(g) - part.bucket_offset(b)
        assert off % part.layouts[b].quantum == 0, (g, off)

    flat = part.flatten(tree)
    assert flat.shape == (part.padded_size,) and flat.dtype == jnp.float32
    back = part.unflatten(flat)
    for a, c in zip(leaves, jax.tree_util.tree_leaves(back)):
        assert c.dtype == a.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(c, np.float32))
    # shard/assemble round-trip: rank chunks tile the flat layout exactly
    gathered = jnp.stack([part.shard(flat, r) for r in range(n_shards)])
    np.testing.assert_array_equal(np.asarray(part.assemble(gathered)),
                                  np.asarray(flat))
    return part


def test_groupaligned_non_divisible_37_over_8():
    import jax.numpy as jnp

    tree = {"w": jnp.arange(37.0) / 64}
    part = _roundtrip(tree, 8)
    assert part.size == 37
    # one leaf, one bucket; the slot pads to the quantum and the chunk
    # divides it evenly across 8 ranks
    assert part.n_buckets == 1
    assert part.padded_size >= 40          # at least the plain layout's pad


def test_groupaligned_leaves_smaller_than_quantum():
    import jax.numpy as jnp

    # every leaf far below one quantum: each still gets its own aligned
    # slot, so per-leaf formats survive and chunks never straddle leaves
    tree = {"a": jnp.ones((3,)), "b": jnp.ones((5, 1)),
            "c": jnp.ones((7,)), "d": jnp.ones(()) * 2}
    part = _roundtrip(tree, 8)
    assert part.size == 3 + 5 + 7 + 1
    offs = [part.leaf_offset(g) for g in range(4)]
    assert offs == sorted(offs) and len(set(offs)) == 4


def test_groupaligned_single_leaf_tree():
    import jax.numpy as jnp

    part = _roundtrip({"only": jnp.arange(1000.0).reshape(10, 100)}, 8)
    assert part.n_buckets == 1 and part.size == 1000


def test_groupaligned_bucketed_runs():
    import jax.numpy as jnp

    tree = {f"l{i}": jnp.ones((s,)) * i
            for i, s in enumerate((640, 96, 32, 7))}
    part = _roundtrip(tree, 8, buckets=((0,), (1, 2), (3,)))
    assert part.n_buckets == 3
    assert part.leaf_range(1) == (1, 3)
    # bucket offsets are whole quanta and shard offsets tile the chunk
    assert part.shard_offset(0) == 0
    assert part.shard_offset(2) == sum(l.chunk for l in part.layouts[:2])


def test_groupaligned_rejects_malformed_buckets():
    import jax.numpy as jnp
    import pytest
    from repro.dist.sharding import GroupAlignedPartitioner

    tree = {"a": jnp.ones((4,)), "b": jnp.ones((4,))}
    with pytest.raises(ValueError):     # leaf 1 dropped
        GroupAlignedPartitioner.create(tree, 4, buckets=((0,),))
    with pytest.raises(ValueError):     # duplicate leaf
        GroupAlignedPartitioner.create(tree, 4, buckets=((0,), (0, 1)))


# ---------------------------------------------------------------------------
# Train-step parity on 8 host devices.
# ---------------------------------------------------------------------------

_PARITY_PRELUDE = """
    import warnings
    import jax, repro.compat
    import jax.numpy as jnp
    from repro.core import qtrain
    from repro.models.common import rms_norm
    from repro.optim import SGDConfig, make_optimizer

    def loss_fn(params, batch, qctx=None):
        h = rms_norm(batch["x"] @ params["w1"], params["norm_scale"])
        return jnp.mean((h @ params["w2"] - batch["y"]) ** 2), {}

    # norm_scale is policy-excluded -> the params all-gather stays fp32
    # (full_quant=False), the regime where sharded == replicated exactly;
    # w1 is 16x37 so the flat slot is the non-divisible 592/8 case
    params = {"w1": jax.random.normal(jax.random.key(0), (16, 37)) * 0.3,
              "norm_scale": jnp.ones((37,)),
              "w2": jax.random.normal(jax.random.key(4), (37, 8)) * 0.3}
    batch = {"x": jax.random.normal(jax.random.key(1), (32, 16)),
             "y": jax.random.normal(jax.random.key(2), (32, 8))}
    mesh = jax.make_mesh((8,), ("data",))
    # power-of-two hypers: shard-local SGD math is FMA-contraction-proof
    opt = make_optimizer(SGDConfig(lr=0.0078125, momentum=0.5,
                                   weight_decay=0.00048828125,
                                   schedule="const"))

    def run_pair(qr, qz, steps=3):
        step_r = qtrain.make_train_step(loss_fn, opt, qr, mesh=mesh)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            step_z = qtrain.make_train_step(loss_fn, opt, qz, mesh=mesh)
        s_r = qtrain.TrainState.create(params, opt.init(params), qr,
                                       jax.random.key(3))
        s_z = qtrain.TrainState.create(
            params, qtrain.zero_opt_state(opt, params, 8, qcfg=qz), qz,
            jax.random.key(3))
        jr, jz = jax.jit(step_r), jax.jit(step_z)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            for i in range(steps):
                s_r, m_r = jr(s_r, batch)
                s_z, m_z = jz(s_z, batch)
                assert float(m_r["loss"]) == float(m_z["loss"]), i
        for k in params:
            assert jnp.array_equal(s_r.params[k], s_z.params[k]), k
        # live DPS controllers must have seen identical stats streams
        for a, b in zip(jax.tree.leaves(s_r.dps), jax.tree.leaves(s_z.dps)):
            assert jnp.array_equal(a, b), "DPS trajectories must match"
        return step_z
"""


def test_zero_groupalign_parity_bits_none():
    """bits=None: ZeRO + overlap flags degrade to the plain layout and the
    step is a pure layout change — bit-exact with the replicated step."""
    run_with_devices(_PARITY_PRELUDE + """
    qr = qtrain.QuantConfig(enabled=True)
    qz = qtrain.QuantConfig(enabled=True, zero_opt_shards=8,
                            wire_overlap=True)
    step_z = run_pair(qr, qz)
    assert step_z.zero_opt_active
    assert not step_z.wire_sync_active
    assert not step_z.zero_groupaligned_active   # no wire, plain layout
    print("OK")
    """)


def test_zero_groupalign_parity_wire8_both_modes():
    """8 wire bits, ZeRO + per-layer + overlap vs replicated per-layer:
    bit-exact over 3 steps with live DPS controllers under nearest AND
    stochastic rounding (global-leaf-indexed wire bit draws)."""
    run_with_devices(_PARITY_PRELUDE + """
    for mode in ("nearest", "stochastic"):
        base = dict(enabled=True, rounding=mode, grad_allreduce_bits=8)
        qr = qtrain.QuantConfig(**base).with_per_layer_wire(params)
        qz = qtrain.QuantConfig(**base, zero_opt_shards=8,
                                wire_overlap=True).with_per_layer_wire(params)
        step_z = run_pair(qr, qz)
        assert step_z.zero_opt_active and step_z.wire_sync_active
        assert step_z.wire_overlap_active and step_z.zero_groupaligned_active
        print("OK", mode)
    """)


def test_zero_groupalign_per_layer_without_overlap():
    """Per-layer wire under ZeRO without bucketing: the single-bucket
    aligned layout still routes both halves through the grouped codec."""
    run_with_devices(_PARITY_PRELUDE + """
    base = dict(enabled=True, rounding="nearest", grad_allreduce_bits=8)
    qr = qtrain.QuantConfig(**base).with_per_layer_wire(params)
    qz = qtrain.QuantConfig(**base,
                            zero_opt_shards=8).with_per_layer_wire(params)
    step_z = run_pair(qr, qz)
    assert step_z.zero_groupaligned_active
    assert not step_z.wire_overlap_active
    print("OK")
    """)


def test_zero_shards_mismatch_warns_and_falls_back():
    """Engagement-mismatch policy: zero_opt_shards != the mesh's data axis
    warns and runs the replicated optimizer state (no raise)."""
    run_with_devices("""
        import warnings
        import jax, repro.compat
        import jax.numpy as jnp
        from repro.core import qtrain
        from repro.models import lenet
        from repro.optim import SGDConfig, make_optimizer

        mesh = jax.make_mesh((8,), ("data",))
        qcfg = qtrain.QuantConfig(enabled=True, zero_opt_shards=4)
        assert not qtrain.zero_opt_engaged(qcfg, mesh)
        opt = make_optimizer(SGDConfig())
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            step = qtrain.make_train_step(lenet.loss_fn, opt, qcfg,
                                          mesh=mesh)
        assert any("does not match" in str(x.message) for x in w)
        assert not step.zero_opt_active
        assert not step.zero_groupaligned_active
        params = lenet.init(jax.random.key(0))
        batch = {"images": jnp.zeros((64, 28, 28, 1)),
                 "labels": jnp.zeros((64,), jnp.int32)}
        st = qtrain.TrainState.create(params, opt.init(params), qcfg,
                                      jax.random.key(1))
        jax.jit(step)(st, batch)      # replicated fallback runs
        print("OK")
        """)


def test_zero_groupalign_opt_state_layout_matches_step():
    """zero_opt_state(qcfg=...) sizes the flat state for the SAME layout
    the step shards over — the aligned padded size, not the plain one."""
    run_with_devices("""
        import jax, repro.compat
        import jax.numpy as jnp
        from repro.core import qtrain
        from repro.dist.sharding import GroupAlignedPartitioner, \\
            ZeroPartitioner
        from repro.models import lenet
        from repro.optim import SGDConfig, make_optimizer

        opt = make_optimizer(SGDConfig())
        params = lenet.init(jax.random.key(0))
        qz = qtrain.QuantConfig(enabled=True, grad_allreduce_bits=8,
                                zero_opt_shards=8,
                                wire_overlap=True).with_per_layer_wire(params)
        part = qtrain.zero_partitioner(qz, params, 8)
        assert isinstance(part, GroupAlignedPartitioner)
        st = qtrain.zero_opt_state(opt, params, 8, qcfg=qz)
        assert st["mu"].shape == (part.padded_size,)
        # legacy default (no qcfg): the plain layout, unchanged
        plain = ZeroPartitioner.create(params, 8)
        st0 = qtrain.zero_opt_state(opt, params, 8)
        assert st0["mu"].shape == (plain.padded_size,)
        # scalar wire without overlap keeps the plain layout too
        qs = qtrain.QuantConfig(enabled=True, grad_allreduce_bits=8,
                                zero_opt_shards=8)
        assert isinstance(qtrain.zero_partitioner(qs, params, 8),
                          ZeroPartitioner)
        print("OK")
        """)
