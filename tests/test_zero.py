"""ZeRO-1 sharded-optimizer regression tests (``QuantConfig.zero_opt_shards``):
run in a subprocess under ``xla_force_host_platform_device_count=8`` like
tests/test_dist.py.

Covers the ISSUE-3 acceptance criteria:
  (a) ``zero_opt_shards=8`` + ``bits=None`` is bit-exact with the replicated
      ``make_train_step`` over multiple steps (params, optimizer state, loss
      and DPS trajectories) — with power-of-two SGD hypers, the regime where
      the shard-local optimizer math is FMA-contraction-proof (see
      ``SGD._leaf``),
  (b) the fused ZeRO+int8-wire step's single SGD update stays within the
      two wire grid steps the two compressed legs can add,
  (c) the int8 reduce-scatter + all-gather schedule moves ≤ ~1/4 the wire
      bytes of an fp32 reduce-scatter + all-gather (ring model, both sides
      parsed from compiled HLO via ``hlo_stats.collective_wire_bytes``),
  (d) the ZeroPartitioner's padded flat layout round-trips non-divisible
      leaves through a real scatter/step/gather cycle on an 8-rank mesh.

``REPRO_WIRE_CONTROLLER`` pins the wire domains' controller kind for the
fused wire tests (CI's dist-wire-ctrl leg sets ``flexpoint``); the wire
formats they assert on are initial-step formats fixed by ``wire_hyper``'s
``il_init``, so any kind satisfies them.
"""

import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_with_devices(code: str, n: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_zero_bits_none_bitexact_with_replicated_step():
    """(a): the flat-sharded optimizer is a pure layout change at bits=None."""
    run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import qtrain
        from repro.dist.sharding import ZeroPartitioner
        from repro.models import lenet
        from repro.optim import SGDConfig, make_optimizer

        mesh = jax.make_mesh((8,), ("data",))
        # power-of-two lr/momentum/weight_decay: every scalar product in
        # the SGD leaf is exact in f32, so LLVM's layout-dependent FMA
        # contraction cannot make the per-leaf and flat-shard updates
        # differ (the documented bit-exactness regime).
        cfg = SGDConfig(lr=0.0078125, momentum=0.5,
                        weight_decay=0.00048828125, schedule="const")
        opt = make_optimizer(cfg)
        qcfg0 = qtrain.QuantConfig(enabled=True)
        qcfgz = qtrain.QuantConfig(enabled=True, zero_opt_shards=8)
        params = lenet.init(jax.random.key(0))
        batch = {"images": jax.random.normal(jax.random.key(2),
                                             (64, 28, 28, 1)),
                 "labels": jax.random.randint(jax.random.key(3), (64,),
                                              0, 10)}

        step_ref = qtrain.make_train_step(lenet.loss_fn, opt, qcfg0)
        step_zero = qtrain.make_train_step(lenet.loss_fn, opt, qcfgz,
                                           mesh=mesh)
        assert step_zero.zero_opt_active and not step_zero.wire_sync_active
        s_r = qtrain.TrainState.create(params, opt.init(params), qcfg0,
                                       jax.random.key(1))
        s_z = qtrain.TrainState.create(
            params, qtrain.zero_opt_state(opt, params, 8), qcfgz,
            jax.random.key(1))
        # the ZeRO state is 1/8 per device: one flat padded vector
        part = ZeroPartitioner.create(params, 8)
        assert s_z.opt_state["mu"].shape == (part.padded_size,)

        jr, jz = jax.jit(step_ref), jax.jit(step_zero)
        for i in range(3):
            s_r, m_r = jr(s_r, batch)
            s_z, m_z = jz(s_z, batch)
            assert float(m_r["loss"]) == float(m_z["loss"]), i
        for a, b in zip(jax.tree.leaves(s_r.params),
                        jax.tree.leaves(s_z.params)):
            assert jnp.array_equal(a, b), "params must be bit-exact"
        np.testing.assert_array_equal(
            np.asarray(part.flatten(s_r.opt_state["mu"])),
            np.asarray(s_z.opt_state["mu"]))
        for a, b in zip(jax.tree.leaves(s_r.dps), jax.tree.leaves(s_z.dps)):
            assert jnp.array_equal(a, b), "DPS trajectories must match"
        print("OK")
    """)


def test_zero_wire8_update_within_two_grid_steps():
    """(b): fp32 training + int8 wire only — the fused step's two wire legs
    (grads reduce-scatter on the ⟨6,2⟩ grid, params all-gather on the ⟨2,6⟩
    grid) bound the parameter perturbation element-wise."""
    run_with_devices("""
        import os
        import jax, jax.numpy as jnp
        from repro.core import qtrain
        from repro.core.dps import DPSHyper
        from repro.models import lenet
        from repro.optim import SGDConfig, make_optimizer

        mesh = jax.make_mesh((8,), ("data",))
        # static compute formats: grads <6,2> (range +-32 covers init
        # grads), weights <2,14>; the wire domains' initial formats are
        # <6,2> / <2,6> from wire_hyper's il_init regardless of kind
        # (the subprocess inherits REPRO_WIRE_CONTROLLER from CI)
        base = dict(enabled=False, controller="static",
                    hyper_grads=DPSHyper(il_init=6, fl_init=2),
                    hyper_weights=DPSHyper(il_init=2, fl_init=14),
                    wire_controller=os.environ.get("REPRO_WIRE_CONTROLLER")
                    or "flexpoint")
        qcfg0 = qtrain.QuantConfig(**base)
        qcfgz = qtrain.QuantConfig(**base, grad_allreduce_bits=8,
                                   zero_opt_shards=8)
        opt = make_optimizer(SGDConfig())
        params = lenet.init(jax.random.key(0))
        batch = {"images": jax.random.normal(jax.random.key(2),
                                             (64, 28, 28, 1)) * 0.5,
                 "labels": jax.random.randint(jax.random.key(3), (64,),
                                              0, 10)}

        s0, _ = jax.jit(qtrain.make_train_step(lenet.loss_fn, opt, qcfg0))(
            qtrain.TrainState.create(params, opt.init(params), qcfg0,
                                     jax.random.key(1)), batch)
        stepz = qtrain.make_train_step(lenet.loss_fn, opt, qcfgz, mesh=mesh)
        assert stepz.zero_opt_active and stepz.wire_sync_active
        sz = qtrain.TrainState.create(
            params, qtrain.zero_opt_state(opt, params, 8), qcfgz,
            jax.random.key(1))
        sz, mz = jax.jit(stepz)(sz, batch)

        assert float(mz["R_wire"]) == 0.0, "both legs must fit their ranges"
        assert float(mz["E_wire"]) > 0.0, "wire stats must be live"
        # one stochastic encode per leg: < 1 grads grid step through the
        # reduce-scatter mean (lr-scaled by the optimizer) + < 1 params
        # grid step through the all-gather.
        lr = 0.01                  # SGDConfig default, momentum step 1
        bound = lr * 2 * 2.0 ** -2 + 2 * 2.0 ** -6
        diff = max(float(jnp.abs(a - b).max()) for a, b in
                   zip(jax.tree.leaves(s0.params), jax.tree.leaves(sz.params)))
        assert diff <= bound, (diff, bound)
        print("OK diff", diff, "bound", bound)
    """)


def test_zero_wire_bytes_le_quarter_fp32_reduce_scatter():
    """(c): the acceptance wire-byte criterion, measured HLO vs measured HLO."""
    run_with_devices("""
        import os
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.core import qtrain
        from repro.core.dps import DPSHyper
        from repro.launch.hlo_stats import collective_wire_bytes
        from repro.models import lenet
        from repro.optim import SGDConfig, make_optimizer

        mesh = jax.make_mesh((8,), ("data",))
        qcfgz = qtrain.QuantConfig(enabled=False, controller="static",
                                   hyper_grads=DPSHyper(il_init=6, fl_init=2),
                                   grad_allreduce_bits=8, zero_opt_shards=8,
                                   wire_controller=os.environ.get(
                                       "REPRO_WIRE_CONTROLLER")
                                   or "flexpoint")
        opt = make_optimizer(SGDConfig())
        params = lenet.init(jax.random.key(0))
        batch = {"images": jnp.zeros((64, 28, 28, 1)),
                 "labels": jnp.zeros((64,), jnp.int32)}
        sz = qtrain.TrainState.create(
            params, qtrain.zero_opt_state(opt, params, 8), qcfgz,
            jax.random.key(1))
        jz = jax.jit(qtrain.make_train_step(lenet.loss_fn, opt, qcfgz,
                                            mesh=mesh))
        wz = collective_wire_bytes(jz.lower(sz, batch).compile().as_text())

        # fp32 baseline: the same two-leg schedule (reduce-scatter +
        # all-gather) without the codec, over the same padded flat size.
        n_params = sum(p.size for p in jax.tree.leaves(params))
        chunk = -(-n_params // 8)
        def ref(x):
            s = jax.lax.psum_scatter(x.reshape(8, chunk), "data",
                                     scatter_dimension=0, tiled=True)
            return jax.lax.all_gather(s, "data", axis=0, tiled=True)
        fr = jax.jit(jax.shard_map(ref, mesh=mesh, in_specs=P(),
                                   out_specs=P(), check_vma=False))
        wr = collective_wire_bytes(
            fr.lower(jax.ShapeDtypeStruct((8 * chunk,), jnp.float32)
                     ).compile().as_text())

        f32_ref = wr["total"]
        # both fp32 legs must be present and full-sized (2 x 4 x padded)
        assert f32_ref >= 2 * 4 * 8 * chunk * 0.9, wr
        s8 = wz["by_dtype"].get("s8", 0.0)
        assert s8 > 0.0, wz
        assert s8 <= 0.26 * f32_ref, (s8, f32_ref)
        # residual f32 collectives in the ZeRO step are stats/loss scalars
        assert wz["by_dtype"].get("f32", 0.0) < 0.01 * f32_ref, wz
        print("OK ratio", s8 / f32_ref)
    """)


def test_zero_wire_respects_policy_excluded_leaves():
    """The flat layout can't skip policy-excluded leaves per-element, so a
    tree containing one (e.g. a norm scale) must warn, gather params in
    fp32, and never snap the excluded leaf's VALUE onto the coarse wire
    grid — while the gradient scatter leg stays int8."""
    run_with_devices("""
        import warnings
        import jax, jax.numpy as jnp
        from repro.core import qtrain
        from repro.core.dps import DPSHyper
        from repro.models.common import rms_norm
        from repro.optim import SGDConfig, make_optimizer

        def loss_fn(params, batch, qctx=None):
            h = rms_norm(batch["x"] @ params["w"], params["out_norm_scale"])
            return jnp.mean((h - batch["y"]) ** 2), {}

        params = {"w": jax.random.normal(jax.random.key(0), (16, 16)) * 0.3,
                  "out_norm_scale": jnp.ones((16,))}
        batch = {"x": jax.random.normal(jax.random.key(1), (32, 16)),
                 "y": jax.random.normal(jax.random.key(2), (32, 16))}

        mesh = jax.make_mesh((8,), ("data",))
        qcfg = qtrain.QuantConfig(enabled=True,
                                  hyper_weights=DPSHyper(il_init=2,
                                                         fl_init=14),
                                  grad_allreduce_bits=8, zero_opt_shards=8)
        opt = make_optimizer(SGDConfig())
        step = qtrain.make_train_step(loss_fn, opt, qcfg, mesh=mesh)
        state = qtrain.TrainState.create(
            params, qtrain.zero_opt_state(opt, params, 8), qcfg,
            jax.random.key(3))
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            jitted = jax.jit(step)
            s1, m = jitted(state, batch)
        assert any("cannot skip them per-leaf" in str(x.message) for x in w)
        # params leg fp32 => zero params-leg wire stats merged in; the
        # grads scatter leg is still live int8
        assert float(m["E_wire"]) > 0.0
        hlo = jitted.lower(state, batch).compile().as_text()
        lines = hlo.splitlines()
        assert any("all-to-all" in l and "s8[" in l for l in lines)
        assert not any("all-gather" in l and "s8[" in l for l in lines)
        # the norm scale moved by an SGD update, not by wire-grid snapping:
        # vs the replicated step it may differ only through the gradient
        # wire (grads grid <7,1> -> update diff <= lr * 0.5), never by a
        # <2,6> params-grid snap of its ~1.0 value
        qcfg_ref = qtrain.QuantConfig(enabled=True,
                                      hyper_weights=DPSHyper(il_init=2,
                                                             fl_init=14))
        s_ref, _ = jax.jit(qtrain.make_train_step(loss_fn, opt, qcfg_ref))(
            qtrain.TrainState.create(params, opt.init(params), qcfg_ref,
                                     jax.random.key(3)), batch)
        diff = jnp.abs(s1.params["out_norm_scale"]
                       - s_ref.params["out_norm_scale"])
        assert float(diff.max()) <= 0.01 * 0.5 + 1e-6, diff
        print("OK")
    """)


def test_zero_partitioner_non_divisible_roundtrip():
    """(d): 37 elements over 8 ranks (pad 3) survive flatten -> slice-per-
    rank -> shard-local SGD step -> all-gather -> unflatten, and the pad
    region stays zero."""
    run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.dist.sharding import ZeroPartitioner
        from repro.optim import SGDConfig, make_optimizer

        tree = {"a": jnp.arange(15.0).reshape(3, 5) / 16,
                "b": jnp.arange(7.0)[::-1] / 8,
                "c": jnp.arange(15.0).reshape(5, 3).astype(jnp.bfloat16)}
        part = ZeroPartitioner.create(tree, 8)
        assert part.size == 37 and part.shard_size == 5
        assert part.padded_size == 40

        flat = part.flatten(tree)
        assert flat.shape == (40,) and flat.dtype == jnp.float32
        assert float(jnp.abs(flat[37:]).max()) == 0.0, "pad must be zero"
        back = part.unflatten(flat)
        for k in tree:
            assert back[k].dtype == tree[k].dtype
            np.testing.assert_array_equal(np.asarray(tree[k], np.float32),
                                          np.asarray(back[k], np.float32))

        # scatter / shard-local step / gather on a real 8-rank mesh
        mesh = jax.make_mesh((8,), ("data",))
        opt = make_optimizer(SGDConfig(lr=0.5, momentum=0.0,
                                       weight_decay=0.0, schedule="const"))
        g = part.flatten(jax.tree.map(jnp.ones_like, tree))

        def body(gf, pf, mu):
            r = jax.lax.axis_index("data")
            upd, st = opt.update_shard(part.shard(gf, r), {"mu": mu},
                                       part.shard(pf, r),
                                       jnp.zeros((), jnp.int32),
                                       axis_name="data")
            return jax.lax.all_gather(part.shard(pf, r) + upd, "data",
                                      axis=0, tiled=True), st["mu"]

        fn = jax.jit(jax.shard_map(body, mesh=mesh,
                                   in_specs=(P(), P(), P("data")),
                                   out_specs=(P(), P("data")),
                                   check_vma=False))
        new_flat, mu = fn(g, flat, jnp.zeros((40,)))
        assert mu.shape == (40,)
        new_tree = part.unflatten(new_flat)
        for k in tree:
            np.testing.assert_allclose(
                np.asarray(new_tree[k], np.float32),
                np.asarray(tree[k], np.float32) - 0.5, atol=1e-6)
        # gradient 1.0 in the pad region would move it; the pad gradient is
        # zero by construction so the pad stays zero
        assert float(jnp.abs(new_flat[37:]).max()) == 0.0
        print("OK")
    """)
