"""Oracle suite for the precision-flow verifier (``repro.analysis``).

Every rule in the catalogue (src/repro/analysis/README.md) is demonstrated
to FIRE on a deliberately broken input — a construct with the bug class
the rule exists for — and to stay quiet on the closest correct variant.
The clean-pass sweep then runs the real lint CLI over the lenet mode grid
under 8 devices, pinning that every shipped step verifies clean.

Flow oracles trace in-process with an ``axis_env`` (collectives outside
shard_map); HLO oracles feed handwritten HLO text to the rule engine (it
is a text engine — synthetic modules make the firing conditions exact);
kernel oracles break real geometries/layouts field-by-field with
``dataclasses.replace``.
"""

import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import flow, hlo_audit, kernel_checks
from repro.core import tagging
from repro.dist import collectives
from repro.kernels import ops
from repro.launch import hlo_stats

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
AXIS = [("data", 8)]


def run_with_devices(code: str, n: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


# ---------------------------------------------------------------- flow pass

def test_pf_wire_f32_fires_on_f32_payload():
    def bad(x):
        p = tagging.tag(x, "wire_payload", leg="dispatch")   # still f32!
        return jax.lax.all_to_all(p, "data", split_axis=0, concat_axis=0,
                                  tiled=True)
    r = flow.analyze_fn(bad, jnp.zeros((8, 32)), axis_env=AXIS)
    assert "PF-WIRE-F32" in r.rules_fired()


def test_pf_wire_f32_fires_on_untagged_a2a_in_wire_step():
    # an all-to-all that never went through an encode, in a step that
    # uses the wire machinery elsewhere: the purity clause must catch it
    def bad(x, y):
        p = tagging.tag(x.astype(jnp.int8), "wire_payload", leg="dispatch")
        w = jax.lax.all_to_all(p, "data", split_axis=0, concat_axis=0,
                               tiled=True)
        forgot = jax.lax.all_to_all(y, "data", split_axis=0, concat_axis=0,
                                    tiled=True)
        return w, forgot
    r = flow.analyze_fn(bad, jnp.zeros((8, 32)), jnp.zeros((8, 32)),
                        axis_env=AXIS)
    assert "PF-WIRE-F32" in r.rules_fired()


def test_pf_wire_f32_clean_on_int8_payload_and_f32_stats_psum():
    def good(x, s):
        p = tagging.tag(x.astype(jnp.int8), "wire_payload", leg="dispatch")
        w = jax.lax.all_to_all(p, "data", split_axis=0, concat_axis=0,
                               tiled=True)
        return w, jax.lax.psum(s, "data")    # untainted f32 psum is fine
    r = flow.analyze_fn(good, jnp.zeros((8, 32)), jnp.zeros(()),
                        axis_env=AXIS)
    assert r.ok and "PF-WIRE-F32" in r.checked


def test_pf_requant_fires_through_structural_ops_only():
    def bad(x):
        d = tagging.tag(x, "decode_out")
        d = d.reshape(-1)[:16]               # structural: taint survives
        return tagging.tag(d, "encode_in", domain="wire_grads")
    r = flow.analyze_fn(bad, jnp.zeros((4, 8)))
    assert "PF-REQUANT" in r.rules_fired()

    def good(x):
        d = tagging.tag(x, "decode_out")
        d = d * 0.5                          # genuine compute kills taint
        return tagging.tag(d, "encode_in", domain="wire_grads")
    r = flow.analyze_fn(good, jnp.zeros((4, 8)))
    assert r.ok and "PF-REQUANT" in r.checked


def test_pf_stats_route_fires_on_wire_stats_into_compute_sink():
    def bad(x):
        s = tagging.tag(x, "wire_stats")
        return tagging.tag(s + 1.0, "stats_sink", domain="grads",
                           wire=False, stream="E")
    r = flow.analyze_fn(bad, jnp.zeros(()))
    assert "PF-STATS-ROUTE" in r.rules_fired()

    def good(x):
        s = tagging.tag(x, "wire_stats")
        return tagging.tag(s + 1.0, "stats_sink", domain="wire_grads",
                           wire=True, stream="E")
    r = flow.analyze_fn(good, jnp.zeros(()))
    assert r.ok and "PF-STATS-ROUTE" in r.checked


def test_pf_sr_seed_fires_on_prng_free_bits():
    def bad(x):
        bits = tagging.tag(jnp.zeros(x.shape, jnp.uint32), "sr_bits",
                           domain="wire_grads")
        return x + bits.astype(jnp.float32)
    r = flow.analyze_fn(bad, jnp.zeros((16,)))
    assert "PF-SR-SEED" in r.rules_fired()

    def good(x, key):
        raw = jax.random.bits(key, (16,), jnp.uint32)
        bits = tagging.tag(raw, "sr_bits", domain="wire_grads")
        return x + bits.astype(jnp.float32)
    r = flow.analyze_fn(good, jnp.zeros((16,)), jax.random.key(0))
    assert r.ok and "PF-SR-SEED" in r.checked


def test_flow_descends_into_jit_subjaxprs():
    @jax.jit
    def inner(d):
        return tagging.tag(d.reshape(-1), "encode_in", domain="wire_grads")

    def bad(x):
        return inner(tagging.tag(x, "decode_out"))
    r = flow.analyze_fn(bad, jnp.zeros((4, 8)))
    assert "PF-REQUANT" in r.rules_fired()


# ----------------------------------------------------------- HLO audit pass

def _hlo(*body: str) -> str:
    return "ENTRY main {\n" + "\n".join(f"  {b}" for b in body) + "\n}\n"


_CLAIMS_2LEG = hlo_audit.AuditClaims(engaged=("wire_grads",), two_leg=True,
                                     n_wire_elems=4096)


def test_ha_payload_dtype_fires_on_f32_all_to_all():
    hlo = _hlo("%p = f32[4096]{0} parameter(0)",
               "%a = f32[4096]{0} all-to-all(f32[4096]{0} %p)",
               "%g = s8[4096]{0} all-gather(s8[512]{0} %q)")
    r = hlo_audit.audit_hlo(hlo, _CLAIMS_2LEG)
    assert "HA-PAYLOAD-DTYPE" in r.rules_fired()


def test_ha_payload_dtype_fires_on_missing_gather_leg():
    hlo = _hlo("%a = s8[4096]{0} all-to-all(s8[4096]{0} %p)")
    r = hlo_audit.audit_hlo(hlo, _CLAIMS_2LEG)
    assert "HA-PAYLOAD-DTYPE" in r.rules_fired()


def test_ha_domain_coverage_fires_on_unserved_domain():
    hlo = _hlo("%a = s8[4096]{0} all-to-all(s8[4096]{0} %p)",
               "%g = s8[4096]{0} all-gather(s8[512]{0} %q)")
    claims = dataclasses.replace(_CLAIMS_2LEG,
                                 engaged=("wire_grads", "wire_params"))
    # wire_params maps to all-gather and one exists -> covered; drop it:
    hlo2 = _hlo("%a = s8[4096]{0} all-to-all(s8[4096]{0} %p)")
    r = hlo_audit.audit_hlo(hlo2, dataclasses.replace(claims, two_leg=False))
    assert "HA-DOMAIN-COVERAGE" in r.rules_fired()
    assert hlo_audit.audit_hlo(hlo, claims).ok


def test_ha_wire_ratio_fires_on_padding_blowup_and_missing_leg():
    fat = _hlo("%a = s8[65536]{0} all-to-all(s8[65536]{0} %p)",
               "%g = s8[65536]{0} all-gather(s8[8192]{0} %q)")
    r = hlo_audit.audit_hlo(fat, _CLAIMS_2LEG)
    assert "HA-WIRE-RATIO" in r.rules_fired()
    thin = _hlo("%a = s8[512]{0} all-to-all(s8[512]{0} %p)",
                "%g = s8[512]{0} all-gather(s8[64]{0} %q)")
    r = hlo_audit.audit_hlo(thin, _CLAIMS_2LEG)
    assert "HA-WIRE-RATIO" in r.rules_fired()


def test_ha_f32_residual_fires_on_uncompressed_allreduce():
    hlo = _hlo("%a = s8[4096]{0} all-to-all(s8[4096]{0} %p)",
               "%g = s8[4096]{0} all-gather(s8[512]{0} %q)",
               "%r = f32[4096]{0} all-reduce(f32[4096]{0} %x)")
    r = hlo_audit.audit_hlo(hlo, _CLAIMS_2LEG)
    assert "HA-F32-RESIDUAL" in r.rules_fired()


def test_ha_f32_concat_fires_on_grouped_flatten():
    hlo = _hlo("%c = f32[4096]{0} concatenate(f32[2048]{0} %a, "
               "f32[2048]{0} %b)",
               "%a2 = s8[4096]{0} all-to-all(s8[4096]{0} %p)",
               "%g = s8[4096]{0} all-gather(s8[512]{0} %q)")
    claims = dataclasses.replace(_CLAIMS_2LEG, grouped=True)
    r = hlo_audit.audit_hlo(hlo, claims)
    assert "HA-F32-CONCAT" in r.rules_fired()


def test_ha_clean_on_two_leg_int8_schedule():
    hlo = _hlo("%a = s8[4096]{0} all-to-all(s8[4096]{0} %p)",
               "%g = s8[4096]{0} all-gather(s8[512]{0} %q)")
    r = hlo_audit.audit_hlo(hlo, dataclasses.replace(_CLAIMS_2LEG,
                                                     grouped=True))
    assert r.ok, r.summary()
    assert set(r.checked) >= {"HA-PAYLOAD-DTYPE", "HA-DOMAIN-COVERAGE",
                              "HA-WIRE-RATIO", "HA-F32-RESIDUAL",
                              "HA-F32-CONCAT"}


# ------------------------------------------------------- kernel geometry

def _geom():
    return ops.group_wire_call_geometry(8 * 4096, 4, 4096)


def test_kg_clean_on_real_builders():
    assert kernel_checks.check_call(_geom(), expected_groups=4).ok
    assert kernel_checks.check_call(
        ops.wire_reduce_call_geometry(8, 4096, 4, 4096),
        expected_groups=4).ok
    assert kernel_checks.check_call(
        ops.quantize_call_geometry(1 << 16)).ok


def test_kg_smem_table_fires_on_wrong_height():
    bad = dataclasses.replace(_geom(), table_rows=5)
    r = kernel_checks.check_call(bad, expected_groups=4)
    assert "KG-SMEM-TABLE" in r.rules_fired()


def test_kg_smem_table_fires_on_overbudget_table():
    g = _geom()
    bad = dataclasses.replace(
        g, table_rows=20000,
        scalar_shapes=((20000, 2),) + g.scalar_shapes[1:])
    r = kernel_checks.check_call(bad, expected_groups=20000)
    assert "KG-SMEM-TABLE" in r.rules_fired()


def test_kg_prefetch_arity_fires_on_signature_drift():
    bad = dataclasses.replace(_geom(), num_scalar_prefetch=1)
    r = kernel_checks.check_call(bad, expected_groups=4)
    assert "KG-PREFETCH-ARITY" in r.rules_fired()


def test_kg_tile_min_fires_on_subminimal_block_and_quantum():
    r = kernel_checks.check_call(
        dataclasses.replace(_geom(), block=(8, 128)), expected_groups=4)
    assert "KG-TILE-MIN" in r.rules_fired()
    r = kernel_checks.check_call(
        dataclasses.replace(_geom(), quantum=4096 + 128), expected_groups=4)
    assert "KG-TILE-MIN" in r.rules_fired()


def test_kg_tile_straddle_fires_on_broken_layout():
    lay = collectives.group_layout((5000, 3000), n_chunks=8, quantum=4096)
    assert kernel_checks.check_layout(lay).ok

    r = kernel_checks.check_layout(
        dataclasses.replace(lay, offsets=(0, 5000)))
    assert "KG-TILE-STRADDLE" in r.rules_fired()

    r = kernel_checks.check_layout(
        dataclasses.replace(lay, padded=(4096, 4096)))
    assert "KG-TILE-STRADDLE" in r.rules_fired()

    r = kernel_checks.check_layout(
        dataclasses.replace(lay, chunk=lay.chunk + 1))
    assert "KG-TILE-STRADDLE" in r.rules_fired()


# --------------------------------------------- satellites: quantum + stats

def test_default_wire_quantum_size_aware():
    q = collectives.default_wire_quantum
    # jnp backend: ~size/G rounded up to the 128-lane tile, 4096 cap
    assert q(1000, 4, "jnp") == 256
    assert q(100, 1, "jnp") == 128
    assert q(100000, 4, "jnp") == 4096
    # kernel backend: the 32x128 grouped tile is the floor
    assert q(1000, 4, "kernel") == 4096
    assert q(10 ** 7, 1, "kernel") == 4096


def test_shape_bytes_raises_on_unknown_dtype():
    with pytest.raises(ValueError, match="unknown HLO dtype"):
        hlo_stats.collective_bytes("%a = q3[64]{0} all-reduce(q3[64] %p)")


def test_hlo_walker_shared_by_all_consumers():
    hlo = _hlo("%c = f32[256]{0} concatenate(f32[128]{0} %a, "
               "f32[128]{0} %b)",
               "%r = f32[64]{0} all-reduce(f32[64]{0} %p)",
               "%d = f32[32]{0} dot(f32[32]{0} %x, f32[32]{0} %y)")
    assert hlo_stats.concat_bytes(hlo)["by_dtype"]["f32"] == 1024.0
    assert hlo_stats.collective_bytes(hlo)["all-reduce"] == 256
    assert hlo_stats.op_bytes(hlo, "dot")["total"] == 128
    # ring model: an all-reduce traverses ~2x its payload
    assert hlo_stats.collective_wire_bytes(hlo)["by_dtype"]["f32"] == 512.0


# ------------------------------------------------------- clean-pass sweep

def test_lint_clean_sweep_lenet_grid():
    """The shipped steps verify clean: the real CLI over the full lenet
    mode grid (baseline / tree / per-layer / zero) must exit 0."""
    out = run_with_devices("""
        import sys
        from repro.analysis import lint
        rc = lint.main(["--config", "lenet"])
        assert rc == 0, "lint reported violations on shipped configs"
        print("SWEEP-OK")
    """)
    assert "SWEEP-OK" in out


def test_lint_cli_mode_selection():
    out = run_with_devices("""
        from repro.analysis import lint
        assert lint.main(["--zero-opt"]) == 0
        print("ZERO-OK")
    """)
    assert "ZERO-OK" in out
