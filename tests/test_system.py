"""End-to-end behaviour tests: every assigned architecture (reduced config)
runs one forward + one quantized train step + a prefill/decode round trip on
CPU, asserting output shapes and finiteness — deliverable (f)'s smoke gate."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import (ARCH_NAMES, applicable_shapes, get_config,
                                smoke)
from repro.core import qtrain
from repro.models import registry
from repro.models.common import init_params
from repro.optim import SGDConfig, make_optimizer


def _extras(cfg, B, key):
    out = {}
    if cfg.family == "encdec":
        out["frames"] = jax.random.normal(key, (B, cfg.enc_seq, cfg.d_model))
    if cfg.family == "vlm":
        out["vision_embeds"] = jax.random.normal(
            key, (B, cfg.n_patches, cfg.d_model))
    return out


@pytest.fixture(scope="module", params=ARCH_NAMES)
def arch_setup(request):
    cfg = smoke(get_config(request.param))
    mod = registry(cfg.family)
    params = init_params(jax.random.key(0), mod.model_defs(cfg))
    return request.param, cfg, mod, params


def test_forward_shapes_and_finite(arch_setup):
    name, cfg, mod, params = arch_setup
    B, S = 2, 16
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab)
    kw = _extras(cfg, B, jax.random.key(2))
    logits, _, _, _ = mod.forward(cfg, params, toks, **kw)
    S_out = S + (cfg.n_patches if cfg.family == "vlm" else 0)
    from repro.models.common import padded_vocab
    assert logits.shape == (B, S_out, padded_vocab(cfg.vocab))
    assert bool(jnp.isfinite(logits[..., :cfg.vocab]).all())


def test_quantized_train_step_runs_and_updates(arch_setup):
    name, cfg, mod, params = arch_setup
    B, S = 2, 16
    qcfg = qtrain.QuantConfig(enabled=True, controller="paper")
    opt = make_optimizer(SGDConfig())
    step = qtrain.make_train_step(mod.loss_fn(cfg), opt, qcfg)
    state = qtrain.TrainState.create(params, opt.init(params), qcfg,
                                     jax.random.key(3))
    batch = {"tokens": jax.random.randint(jax.random.key(4), (B, S + 1), 0,
                                          cfg.vocab),
             **_extras(cfg, B, jax.random.key(5))}
    state2, metrics = jax.jit(step)(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert int(state2.step) == 1
    # params actually moved
    delta = sum(float(jnp.abs(a - b).sum()) for a, b in
                zip(jax.tree.leaves(params), jax.tree.leaves(state2.params)))
    assert delta > 0.0
    # DPS state advanced to legal widths
    assert 2 <= int(state2.dps["weights"].il) <= 16
    assert 0 <= int(state2.dps["weights"].fl) <= 23


def test_prefill_decode_consistency(arch_setup):
    """Greedy decode from a cache matches teacher-forced logits."""
    name, cfg, mod, params = arch_setup
    if cfg.n_experts:
        pytest.skip("MoE capacity dropping makes TF vs decode inexact "
                    "(verified equal at capacity_factor=8 elsewhere)")
    B, S = 2, 12
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab)
    kw = _extras(cfg, B, jax.random.key(2))
    full, _, _, _ = mod.forward(cfg, params, toks, **kw)
    lp, cache, pos = mod.prefill(cfg, params, toks[:, :S - 1], 24, **kw)
    ld, _ = mod.decode_step(cfg, params, toks[:, S - 1:S], cache, pos)
    off = cfg.n_patches if cfg.family == "vlm" else 0
    np.testing.assert_allclose(np.asarray(lp), np.asarray(full[:, off + S - 2]),
                               atol=2e-3)
    np.testing.assert_allclose(np.asarray(ld), np.asarray(full[:, off + S - 1]),
                               atol=2e-3)


def test_decode_positions_are_per_row(arch_setup):
    """Rows with different cache positions decode independently."""
    name, cfg, mod, params = arch_setup
    if cfg.family in ("ssm",):
        pytest.skip("ssm cache has no positional dimension")
    B, S = 2, 8
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab)
    kw = _extras(cfg, B, jax.random.key(2))
    _, cache, pos = mod.prefill(cfg, params, toks, 16, **kw)
    tok = toks[:, -1:]
    l1, _ = mod.decode_step(cfg, params, tok, cache, pos)
    # shifting row 1's position changes only row 1's output
    pos2 = pos.at[1].add(2)
    l2, _ = mod.decode_step(cfg, params, tok, cache, pos2)
    assert float(jnp.abs(l1[0] - l2[0]).max()) < 1e-5


def test_applicable_shapes_contract():
    """long_500k only for sub-quadratic archs; all archs list 3+ shapes."""
    for name in ARCH_NAMES:
        cfg = get_config(name)
        shapes = applicable_shapes(cfg)
        assert {"train_4k", "prefill_32k", "decode_32k"} <= set(shapes)
        assert ("long_500k" in shapes) == (cfg.family in ("ssm", "hybrid"))


def test_param_counts_match_declared_scale():
    """Analytic param counts sit near the advertised model sizes."""
    expected = {
        "llama3_2_3b": (2.5e9, 4.5e9),
        "mistral_large_123b": (1.1e11, 1.35e11),
        "nemotron_4_340b": (3.0e11, 3.7e11),
        "gemma_7b": (7e9, 1.0e10),
        "qwen3_moe_30b_a3b": (2.6e10, 3.4e10),
        "deepseek_v2_236b": (2.0e11, 2.6e11),
        "mamba2_1_3b": (1.0e9, 1.6e9),
        "zamba2_7b": (6e9, 9e9),
        "whisper_medium": (2.5e8, 1.2e9),
        "internvl2_26b": (1.7e10, 2.4e10),
    }
    for name, (lo, hi) in expected.items():
        cfg = get_config(name)
        n = cfg.n_params()
        assert lo <= n <= hi, f"{name}: {n:.3e} outside [{lo:.1e}, {hi:.1e}]"


def test_moe_active_params_smaller():
    for name in ("qwen3_moe_30b_a3b", "deepseek_v2_236b"):
        cfg = get_config(name)
        assert cfg.n_active_params() < 0.35 * cfg.n_params()


def test_int8_kv_cache_decode_close_to_bf16():
    """kv_cache_bits=8: decode output within grid-quantization error."""
    import dataclasses
    cfg = smoke(get_config("gemma_7b"))
    mod = registry(cfg.family)
    params = init_params(jax.random.key(0), mod.model_defs(cfg))
    B, S = 2, 10
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab)
    out = {}
    for bits in (16, 8):
        c = dataclasses.replace(cfg, kv_cache_bits=bits)
        _, cache, pos = mod.prefill(c, params, toks[:, :S - 1], 16)
        ld, _ = mod.decode_step(c, params, toks[:, S - 1:S], cache, pos)
        out[bits] = ld
    assert out[8].dtype == out[16].dtype
    err = float(jnp.abs(out[8] - out[16]).max())
    assert err < 0.3, err          # coarse cache, bounded logit drift
    assert bool(jnp.isfinite(out[8]).all())


def test_moe_int8_a2a_close_to_bf16():
    """moe_a2a_bits=8 wire quantization stays near the bf16 path."""
    import dataclasses
    from repro.dist.sharding import axis_rules, LogicalRules
    from repro.models import moe as moe_lib
    cfg = dataclasses.replace(smoke(get_config("qwen3_moe_30b_a3b")),
                              capacity_factor=8.0)
    p = init_params(jax.random.key(0), moe_lib.moe_defs(cfg, jnp.float32))
    x = jax.random.normal(jax.random.key(1), (2, 8, cfg.d_model)) * 0.3
    ref, _ = moe_lib.moe_apply(cfg, p, x)
    # int8 wire only engages on the a2a path (needs a real mesh); on one
    # device it must leave the einsum path untouched:
    cfg8 = dataclasses.replace(cfg, moe_a2a_bits=8)
    out, _ = moe_lib.moe_apply(cfg8, p, x)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(out), atol=1e-6)
