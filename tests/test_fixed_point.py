"""Unit + property tests for the ⟨IL, FL⟩ emulation grid."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.fixed_point import (FixedPointFormat, QuantStats, quantize,
                                    quantize_tree, ROUND_NEAREST,
                                    ROUND_STOCHASTIC)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYP = True
except ImportError:  # pragma: no cover
    HAVE_HYP = False


def fmt(il, fl):
    return FixedPointFormat.create(il, fl)


def test_grid_snap_nearest():
    f = fmt(4, 2)  # grid 0.25, range [-8, 7.75]
    x = jnp.array([0.0, 0.1, 0.125, 0.30, 1.0, -0.30, 7.9, 100.0, -100.0])
    q, s = quantize(x, f, mode=ROUND_NEAREST)
    np.testing.assert_allclose(
        np.asarray(q),
        [0.0, 0.0, 0.25, 0.25, 1.0, -0.25, 7.75, 7.75, -8.0], rtol=0, atol=0)
    # 7.9 (31.6 grid units > qmax=31), 100 and -100 all clip:
    assert int(s.overflow) == 3


def test_overflow_boundary_semantics():
    f = fmt(4, 2)
    x = jnp.array([7.75, 7.76, -8.0, -8.01])
    _, s = quantize(x, f, mode=ROUND_NEAREST)
    assert int(s.overflow) == 2      # only values strictly outside the grid


def test_round_half_up_matches_paper_eq1():
    f = fmt(8, 0)  # integer grid
    x = jnp.array([0.5, 1.5, 2.5, -0.5, -1.5])
    q, _ = quantize(x, f, mode=ROUND_NEAREST)
    # floor(y + 0.5): 0.5->1, 1.5->2, 2.5->3, -0.5->0, -1.5->-1
    np.testing.assert_array_equal(np.asarray(q), [1.0, 2.0, 3.0, 0.0, -1.0])


def test_stochastic_unbiased():
    f = fmt(4, 4)  # grid 1/16
    key = jax.random.key(0)
    x = jnp.full((200_000,), 0.4)   # 6.4 grid units
    q, _ = quantize(x, f, mode=ROUND_STOCHASTIC, key=key)
    # E[q] = x; with 200k samples the mean is within ~4 sigma
    sigma = (1 / 16) * 0.5 / np.sqrt(200_000)
    assert abs(float(q.mean()) - 0.4) < 4 * sigma
    # only the two adjacent grid points appear
    assert set(np.unique(np.asarray(q))) <= {6 / 16, 7 / 16}


def test_stochastic_preserves_grid_values():
    f = fmt(6, 6)
    key = jax.random.key(1)
    x = jnp.arange(-32, 32) / 64.0 * 32  # exact grid values
    q, s = quantize(x, f, mode=ROUND_STOCHASTIC, key=key)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(x))
    assert float(s.abs_err_sum) == 0.0
    assert float(s.quant_error()) == 0.0


def test_dynamic_fmt_no_recompile():
    """IL/FL are traced: one compilation serves every precision."""
    traces = []

    @jax.jit
    def f(x, il, fl):
        traces.append(1)
        q, s = quantize(x, FixedPointFormat(il, fl), mode=ROUND_NEAREST)
        return q, s.overflow

    x = jnp.linspace(-4, 4, 64)
    f(x, jnp.int32(4), jnp.int32(2))
    f(x, jnp.int32(8), jnp.int32(8))
    f(x, jnp.int32(2), jnp.int32(12))
    assert len(traces) == 1


def test_stats_merge_matches_whole():
    key = jax.random.key(2)
    x = jax.random.normal(key, (4096,))
    f = fmt(4, 8)
    _, s_all = quantize(x, f, mode=ROUND_NEAREST)
    _, s_a = quantize(x[:1000], f, mode=ROUND_NEAREST)
    _, s_b = quantize(x[1000:], f, mode=ROUND_NEAREST)
    merged = s_a.merge(s_b)
    for field in ("count", "nonzero", "overflow", "abs_err_sum", "abs_sum"):
        np.testing.assert_allclose(float(getattr(merged, field)),
                                   float(getattr(s_all, field)), rtol=1e-6)
    np.testing.assert_allclose(float(merged.max_abs), float(s_all.max_abs))


def test_quantize_tree_predicate():
    tree = {"w": jnp.ones((8, 8)) * 0.3, "norm_scale": jnp.ones((8,)) * 0.3}
    f = fmt(4, 1)  # grid 0.5 -> 0.3 rounds to 0.5 or 0.0

    def pred(path, leaf):
        name = "/".join(str(getattr(k, "key", k)) for k in path)
        return "norm" not in name

    qt, stats = quantize_tree(tree, f, mode=ROUND_NEAREST, predicate=pred)
    assert float(stats.count) == 64          # only w counted
    np.testing.assert_array_equal(np.asarray(qt["norm_scale"]),
                                  np.asarray(tree["norm_scale"]))
    assert set(np.unique(np.asarray(qt["w"]))) == {0.5}


def test_bf16_roundtrip_dtype():
    f = fmt(4, 4)
    x = jnp.array([0.37, -1.12], jnp.bfloat16)
    q, _ = quantize(x, f, mode=ROUND_NEAREST)
    assert q.dtype == jnp.bfloat16


if HAVE_HYP:

    @settings(max_examples=50, deadline=None)
    @given(il=st.integers(2, 10), fl=st.integers(0, 14),
           seed=st.integers(0, 2**31 - 1))
    def test_property_grid_and_range(il, fl, seed):
        """Outputs always lie on the 2^-FL grid inside the signed range."""
        key = jax.random.key(seed)
        x = jax.random.normal(key, (257,)) * (2.0 ** (il - 1))
        q, s = quantize(x, fmt(il, fl), mode=ROUND_STOCHASTIC,
                        key=jax.random.fold_in(key, 7))
        qn = np.asarray(q, np.float64)
        grid = qn * (2.0 ** fl)
        np.testing.assert_allclose(grid, np.round(grid), atol=1e-6)
        assert qn.max() <= 2.0 ** (il - 1) - 2.0 ** (-fl) + 1e-9
        assert qn.min() >= -(2.0 ** (il - 1)) - 1e-9
        # error never exceeds one grid step (for non-overflowed values)
        assert float(s.quant_error("ratio")) >= 0.0

    @settings(max_examples=30, deadline=None)
    @given(il=st.integers(2, 8), fl=st.integers(1, 12),
           seed=st.integers(0, 2**31 - 1))
    def test_property_rtn_error_bound(il, fl, seed):
        """RTN error <= half a grid step for in-range values."""
        key = jax.random.key(seed)
        x = jax.random.uniform(key, (311,), minval=-(2.0 ** (il - 2)),
                               maxval=2.0 ** (il - 2))
        q, _ = quantize(x, fmt(il, fl), mode=ROUND_NEAREST)
        err = np.abs(np.asarray(q, np.float64) - np.asarray(x, np.float64))
        assert err.max() <= 0.5 * 2.0 ** (-fl) + 1e-9


if HAVE_HYP:

    @settings(max_examples=30, deadline=None)
    @given(il=st.integers(2, 8), fl=st.integers(0, 12),
           seed=st.integers(0, 2**31 - 1))
    def test_property_rtn_idempotent(il, fl, seed):
        """Grid values are fixed points of the quantizer."""
        key = jax.random.key(seed)
        x = jax.random.normal(key, (129,)) * (2.0 ** (il - 2))
        q1, _ = quantize(x, fmt(il, fl), mode=ROUND_NEAREST)
        q2, s2 = quantize(q1, fmt(il, fl), mode=ROUND_NEAREST)
        np.testing.assert_array_equal(np.asarray(q1), np.asarray(q2))
        assert float(s2.abs_err_sum) == 0.0

    @settings(max_examples=20, deadline=None)
    @given(il=st.integers(2, 6), fl=st.integers(1, 10),
           seed=st.integers(0, 2**31 - 1))
    def test_property_finer_grid_never_worse(il, fl, seed):
        """RTN error is monotone non-increasing in FL (same range)."""
        key = jax.random.key(seed)
        x = jax.random.uniform(key, (257,), minval=-(2.0 ** (il - 2)),
                               maxval=2.0 ** (il - 2))
        _, s1 = quantize(x, fmt(il, fl), mode=ROUND_NEAREST)
        _, s2 = quantize(x, fmt(il, fl + 1), mode=ROUND_NEAREST)
        assert float(s2.abs_err_sum) <= float(s1.abs_err_sum) + 1e-6

    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_property_stochastic_error_bounded_by_step(seed):
        """|q - x| < 2^-FL for in-range values, any rounding draw."""
        key = jax.random.key(seed)
        x = jax.random.uniform(key, (311,), minval=-3.0, maxval=3.0)
        q, _ = quantize(x, fmt(4, 9), mode=ROUND_STOCHASTIC,
                        key=jax.random.fold_in(key, 3))
        err = np.abs(np.asarray(q, np.float64) - np.asarray(x, np.float64))
        assert err.max() < 2.0 ** -9 + 1e-9
