"""Precision-domain registry tests (ISSUE-4): PrecisionPlan / DpsBundle.

Property tests: ANY plan — random domain names, controller kinds, group
counts, hypers — must (a) build a DpsBundle that round-trips through
``jit`` and ``shard_map`` as a pytree with stable structure, (b) update
under partial stats streams (absent streams read as zero), and (c) leave
the training step bit-exact at ``bits=None``: domains nobody feeds or
reads cannot perturb the parameter trajectory.

Plus the checkpoint schema upgrade: a legacy checkpoint carrying only the
three-key compute DPS bundle restores into a five-domain registry with
the wire domains initialized fresh (``ckpt.restore(defaults=...)``).
"""

import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import qtrain
from repro.core.dps import (CONTROLLERS, DomainSpec, DpsBundle, DPSHyper,
                            PrecisionPlan, wire_hyper)
from repro.core.fixed_point import QuantStats


def random_plan(rng: random.Random, max_domains: int = 5) -> PrecisionPlan:
    names = rng.sample(["weights", "acts", "grads", "wire_grads",
                        "wire_params", "kv_cache", "moe_router", "opt_state"],
                       rng.randint(1, max_domains))
    groups = {n: rng.choice([0, 0, 1, 3, 4]) for n in names}
    domains = []
    for n in names:
        kind = rng.choice(sorted(CONTROLLERS))
        hyper = DPSHyper(il_init=rng.randint(2, 10),
                         fl_init=rng.randint(1, 14),
                         total_bits=rng.choice([8, 12, 16]),
                         r_max=rng.choice([1e-4, 5e-3]),
                         e_max=rng.choice([1e-4, 5e-2]))
        # routed streams must be scalar or match the domain's group count
        # (PrecisionPlan.update enforces this; pinned below) — route only
        # to shape-compatible targets, plus absent streams
        targets = [m for m in names
                   if groups[m] == groups[n] or groups[m] == 0]
        domains.append((n, DomainSpec(
            controller=kind, hyper=hyper,
            stats=rng.choice(["", n, rng.choice(targets), "absent_stream"]),
            groups=groups[n])))
    return PrecisionPlan(tuple(domains))


def random_stats(rng: random.Random, shape=()) -> QuantStats:
    full = lambda v: jnp.full(shape, v, jnp.float32)
    n = rng.randint(100, 10_000)
    return QuantStats(count=full(n), nonzero=full(n * 0.9),
                      overflow=full(rng.randint(0, 50)),
                      abs_err_sum=full(rng.uniform(0, 10)),
                      rel_err_sum=full(rng.uniform(0, 100)),
                      abs_sum=full(rng.uniform(1, 100)),
                      max_abs=full(rng.uniform(0.1, 64.0)))


def test_random_plans_roundtrip_jit_and_shard_map_as_pytrees():
    rng = random.Random(0)
    mesh = jax.make_mesh((jax.device_count(),), ("data",))
    for trial in range(12):
        plan = random_plan(rng)
        bundle = plan.init()
        assert isinstance(bundle, DpsBundle)
        assert bundle.names() == plan.names
        # formats honor the declared group count
        fmts = plan.formats(bundle)
        for name, spec in plan.domains:
            assert fmts[name].il.shape == spec.state_shape(), (trial, name)

        # streams for a random subset of domains (others read zero stats)
        streams = {n: random_stats(rng, s.state_shape())
                   for n, s in plan.domains if rng.random() < 0.7}
        aux = {"loss": jnp.float32(rng.uniform(0.1, 10.0))}

        # jit round-trip: structure stable, updatable, formats extractable
        upd = jax.jit(lambda b: plan.update(b, streams, aux))
        b1 = upd(bundle)
        assert jax.tree.structure(b1) == jax.tree.structure(bundle), trial
        b2 = upd(b1)
        assert jax.tree.structure(b2) == jax.tree.structure(bundle), trial

        # flatten/unflatten identity (checkpoint + donation path)
        leaves, treedef = jax.tree_util.tree_flatten(b2)
        b3 = jax.tree_util.tree_unflatten(treedef, leaves)
        for a, b in zip(jax.tree.leaves(b2), jax.tree.leaves(b3)):
            assert jnp.array_equal(a, b)

        # shard_map round-trip: the bundle is replicated controller state
        body = jax.shard_map(lambda b: plan.update(b, streams, aux),
                             mesh=mesh, in_specs=P(), out_specs=P(),
                             check_vma=False)
        b4 = jax.jit(body)(bundle)
        assert jax.tree.structure(b4) == jax.tree.structure(bundle), trial
        for a, b in zip(jax.tree.leaves(b1), jax.tree.leaves(b4)):
            assert jnp.array_equal(a, b), (trial, "shard_map != jit")


def test_plan_validation_rejects_bad_declarations():
    with pytest.raises(ValueError, match="duplicate"):
        PrecisionPlan((("a", DomainSpec()), ("a", DomainSpec())))
    with pytest.raises(ValueError, match="unknown controller"):
        PrecisionPlan((("a", DomainSpec(controller="nope")),))
    with pytest.raises(ValueError, match="groups"):
        PrecisionPlan((("a", DomainSpec(groups=-1)),))
    plan = PrecisionPlan((("a", DomainSpec()),))
    with pytest.raises(KeyError):
        plan.spec("missing")
    # a routed stream whose [G] shape mismatches the consumer fails loudly
    # instead of silently reshaping the domain's controller state
    bad = PrecisionPlan((
        ("grads", DomainSpec(groups=4)),
        ("scalar_consumer", DomainSpec(stats="grads", groups=0)),
    ))
    rng = random.Random(3)
    with pytest.raises(ValueError, match="scalar or match"):
        bad.update(bad.init(), {"grads": random_stats(rng, (4,))},
                   {"loss": jnp.float32(1.0)})
    off_by_one = PrecisionPlan((
        ("grads", DomainSpec(groups=4)),
        ("grouped_consumer", DomainSpec(stats="grads", groups=3)),
    ))
    with pytest.raises(ValueError, match="scalar or match"):
        off_by_one.update(off_by_one.init(),
                          {"grads": random_stats(rng, (4,))},
                          {"loss": jnp.float32(1.0)})


def test_stats_routing_and_scalar_broadcast_to_groups():
    rng = random.Random(7)
    plan = PrecisionPlan((
        ("grads", DomainSpec("paper", DPSHyper())),
        # routed: consumes the grads stream despite its own name
        ("shadow", DomainSpec("paper", DPSHyper(), stats="grads")),
        # per-group domain fed by the (scalar) grads stream -> broadcast
        ("grouped", DomainSpec("paper", DPSHyper(), stats="grads", groups=3)),
    ))
    bundle = plan.init()
    st = random_stats(rng)
    out = plan.update(bundle, {"grads": st}, {"loss": jnp.float32(1.0)})
    # same controller, same hyper, same stats -> identical moves
    assert jnp.array_equal(out["grads"].il, out["shadow"].il)
    assert out["grouped"].il.shape == (3,)
    np.testing.assert_array_equal(np.asarray(out["grouped"].il),
                                  np.full((3,), int(out["grads"].il)))


def test_group_stream_routes_group_wise_into_grouped_domain():
    """A [G] stats stream drives each group's controller row independently
    (the per-layer wire regime: group g's wire stats move only group g's
    ⟨IL, FL⟩), and a shape-mismatched stream still raises."""
    plan = PrecisionPlan((
        ("wire_grads", DomainSpec("flexpoint",
                                  DPSHyper(total_bits=8, il_min=1,
                                           il_init=4), groups=3)),
    ))
    bundle = plan.init()
    zero = jnp.zeros((3,), jnp.float32)
    # only group 1 observes a large max |g|
    st = QuantStats(count=jnp.full((3,), 100.0), nonzero=jnp.full((3,), 90.0),
                    overflow=zero, abs_err_sum=zero, rel_err_sum=zero,
                    abs_sum=zero,
                    max_abs=jnp.asarray([0.01, 40.0, 0.01], jnp.float32))
    out = plan.update(bundle, {"wire_grads": st}, None)
    il = np.asarray(out["wire_grads"].il)
    assert il[1] > il[0] and il[1] > il[2], il  # radix follows ITS group
    assert il[0] == il[2], il
    with pytest.raises(ValueError, match="scalar or match"):
        bad = jax.tree.map(lambda x: jnp.broadcast_to(x[:2], (2,)), st)
        plan.update(bundle, {"wire_grads": bad}, None)


def test_with_per_layer_wire_sets_groups_from_leaf_count():
    params = {"a": jnp.zeros((3, 4)), "b": {"w": jnp.zeros((5,)),
                                            "s": jnp.zeros(())}}
    base = qtrain.QuantConfig(enabled=True)
    assert base.with_per_layer_wire(params) is base    # no wire -> no-op
    qcfg = qtrain.QuantConfig(enabled=True, grad_allreduce_bits=8
                              ).with_per_layer_wire(params)
    assert qcfg.wire_grads_groups == 3
    assert qcfg.plan().spec("wire_grads").groups == 3
    bundle = qtrain.init_dps_bundle(qcfg)
    assert bundle["wire_grads"].il.shape == (3,)
    # the [G] formats surface in bundle_formats for the collectives' table
    fmts = qtrain.bundle_formats(qcfg, bundle)
    assert fmts["wire_grads"].il.shape == (3,)


def test_per_layer_wire_with_zero_opt_raises():
    from repro.models import lenet
    from repro.optim import SGDConfig, make_optimizer
    params = lenet.init(jax.random.key(0))
    qcfg = qtrain.QuantConfig(enabled=True, grad_allreduce_bits=8,
                              zero_opt_shards=1
                              ).with_per_layer_wire(params)
    mesh = jax.make_mesh((1,), ("data",))
    opt = make_optimizer(SGDConfig())
    # single-device mesh: neither path engages, so the build succeeds ...
    qtrain.make_train_step(lenet.loss_fn, opt, qcfg, mesh=mesh)
    # ... but an engaging ZeRO mesh must reject per-layer wire groups
    # (the flat partitioner layout erases leaf boundaries).  Exercised
    # through the validation directly: fake an engaged config check via
    # a 1-axis mesh of the real device count when >1 devices exist.
    if jax.device_count() > 1:
        n = jax.device_count()
        mesh_n = jax.make_mesh((n,), ("data",))
        qcfg_n = qtrain.QuantConfig(enabled=True, grad_allreduce_bits=8,
                                    zero_opt_shards=n
                                    ).with_per_layer_wire(params)
        with pytest.raises(ValueError, match="per-layer wire"):
            qtrain.make_train_step(lenet.loss_fn, opt, qcfg_n, mesh=mesh_n)


def test_bits_none_step_bitexact_under_extra_domains():
    """Domains nobody feeds or reads cannot perturb training: a plan with
    wire + custom domains produces the identical parameter trajectory to
    the standard three-domain plan at ``bits=None``."""
    from repro.models import lenet
    from repro.optim import SGDConfig, make_optimizer

    opt = make_optimizer(SGDConfig())
    params = lenet.init(jax.random.key(0))
    batch = {"images": jax.random.normal(jax.random.key(2), (16, 28, 28, 1)),
             "labels": jax.random.randint(jax.random.key(3), (16,), 0, 10)}

    qcfg_std = qtrain.QuantConfig(enabled=True)
    base = qcfg_std.plan()
    qcfg_ext = qtrain.QuantConfig(enabled=True, precision_plan=PrecisionPlan(
        base.domains + (
            ("wire_grads", DomainSpec("flexpoint", wire_hyper(8, 6, -2.0))),
            ("wire_params", DomainSpec("flexpoint", wire_hyper(8, 2, 1.0))),
            ("kv_cache", DomainSpec("static", DPSHyper(il_init=8,
                                                       fl_init=8))),
        )))

    def run(qcfg, steps=3):
        state = qtrain.TrainState.create(params, opt.init(params), qcfg,
                                         jax.random.key(1))
        step = jax.jit(qtrain.make_train_step(lenet.loss_fn, opt, qcfg))
        losses = []
        for _ in range(steps):
            state, m = step(state, batch)
            losses.append(float(m["loss"]))
        return state, losses

    s_std, l_std = run(qcfg_std)
    s_ext, l_ext = run(qcfg_ext)
    assert l_std == l_ext
    for a, b in zip(jax.tree.leaves(s_std.params),
                    jax.tree.leaves(s_ext.params)):
        assert jnp.array_equal(a, b), "extra domains perturbed the params"
    # the compute-domain trajectories match too
    for k in ("weights", "acts", "grads"):
        for a, b in zip(jax.tree.leaves(s_std.dps[k]),
                        jax.tree.leaves(s_ext.dps[k])):
            assert jnp.array_equal(a, b)


def test_ckpt_legacy_three_key_bundle_upgrades_to_registry(tmp_path):
    """Round-trip: a checkpoint written with the legacy dict-of-three DPS
    bundle restores into a wire-domain registry — compute domains carry
    their checkpointed trajectories, wire domains initialize fresh."""
    from repro.checkpoint import restore, save
    from repro.models import lenet
    from repro.optim import SGDConfig, make_optimizer

    opt = make_optimizer(SGDConfig())
    params = lenet.init(jax.random.key(0))
    qcfg_new = qtrain.QuantConfig(enabled=True, grad_allreduce_bits=8,
                                  zero_opt_shards=8)

    # a legacy state: plain {attr: controller state} dict, with visibly
    # non-initial trajectories so the restore is distinguishable
    legacy_dps = {
        "weights": qcfg_new.plan().controller("weights").init(),
        "acts": qcfg_new.plan().controller("acts").init(),
        "grads": qcfg_new.plan().controller("grads").init(),
    }
    legacy_dps["grads"] = jax.tree.map(lambda x: x + 3, legacy_dps["grads"])
    legacy_state = qtrain.TrainState(
        step=jnp.asarray(17, jnp.int32), params=params,
        opt_state=opt.init(params), dps=legacy_dps,
        rng=jax.random.key(5), last_loss=jnp.float32(1.25))
    save(str(tmp_path), 17, legacy_state, meta={"cursor": 17})

    # restore into the registry template (five domains)
    template = jax.eval_shape(
        lambda: qtrain.TrainState.create(params, opt.init(params), qcfg_new,
                                         jax.random.key(1)))
    with pytest.raises(KeyError):
        restore(str(tmp_path), 17, template)   # without defaults: loud
    restored, meta = restore(str(tmp_path), 17, template,
                             defaults=qtrain.dps_restore_defaults(qcfg_new))
    assert meta["cursor"] == 17
    assert restored.dps.names() == ("weights", "acts", "grads",
                                    "wire_grads", "wire_params")
    # compute domains: checkpointed values (grads trajectory +3)
    for a, b in zip(jax.tree.leaves(restored.dps["grads"]),
                    jax.tree.leaves(legacy_dps["grads"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # wire domains: fresh init
    fresh = qtrain.init_dps_bundle(qcfg_new)
    for dom in ("wire_grads", "wire_params"):
        for a, b in zip(jax.tree.leaves(restored.dps[dom]),
                        jax.tree.leaves(fresh[dom])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # params restored exactly
    for a, b in zip(jax.tree.leaves(restored.params),
                    jax.tree.leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
