"""Substrate tests: optimizers, data pipeline, checkpoint/restore (incl.
failure injection + elastic restore), sharding rules, fused loss."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import AsyncCheckpointer, latest_step, restore, save
from repro.data import MNISTLike, TokenStream, TokenStreamConfig
from repro.dist.sharding import LogicalRules
from repro.models.common import fused_unembed_xent, softmax_xent, unembed
from repro.optim import AdamWConfig, SGDConfig, inv_decay, make_optimizer


# ---------------------------------------------------------------------------
# Optimizers.
# ---------------------------------------------------------------------------

def test_sgd_matches_reference_momentum():
    cfg = SGDConfig(lr=0.1, momentum=0.9, weight_decay=0.0, schedule="const")
    opt = make_optimizer(cfg)
    p = {"w": jnp.array([1.0, -2.0])}
    s = opt.init(p)
    g = {"w": jnp.array([0.5, 0.5])}
    upd, s = opt.update(g, s, p, count=jnp.int32(0))
    np.testing.assert_allclose(np.asarray(upd["w"]), [-0.05, -0.05], rtol=1e-6)
    upd, s = opt.update(g, s, p, count=jnp.int32(1))
    # mu = 0.9*0.5 + 0.5 = 0.95
    np.testing.assert_allclose(np.asarray(upd["w"]), [-0.095, -0.095], rtol=1e-6)


def test_paper_inv_decay_schedule():
    f = inv_decay(0.01, 1e-4, 0.75)
    assert abs(float(f(jnp.int32(0))) - 0.01) < 1e-9
    # lr(10000) = 0.01 * 2^-0.75
    np.testing.assert_allclose(float(f(jnp.int32(10000))),
                               0.01 * 2 ** -0.75, rtol=1e-5)


def test_adamw_converges_quadratic():
    opt = make_optimizer(AdamWConfig(lr=0.05, weight_decay=0.0, warmup=0,
                                     total_steps=300, clip_norm=0))
    p = {"w": jnp.array([3.0, -2.0])}
    s = opt.init(p)
    for i in range(300):
        g = {"w": 2 * p["w"]}
        upd, s = opt.update(g, s, p, count=jnp.int32(i))
        p = jax.tree.map(lambda a, b: a + b, p, upd)
    assert float(jnp.abs(p["w"]).max()) < 0.05


def test_bf16_sr_momentum_unbiased():
    """bf16 momentum with stochastic rounding keeps tiny updates alive in
    expectation (Gupta et al.) — the mean over many steps tracks fp32."""
    cfg = SGDConfig(lr=1.0, momentum=0.0, weight_decay=0.0,
                    schedule="const", state_dtype="bfloat16")
    opt = make_optimizer(cfg)
    p = {"w": jnp.ones((2048,))}
    s = opt.init(p)
    g = {"w": jnp.full((2048,), 1e-4)}   # far below bf16 ulp at 1.0... of mu
    acc = jnp.zeros((2048,))
    for i in range(64):
        upd, s2 = opt.update(g, s, p, count=jnp.int32(i))
        acc = acc + s2["mu"]["w"].astype(jnp.float32)
    # E[mu] = 1e-4; mean over steps*elements within 10%
    assert abs(float(acc.mean()) / 64 - 1e-4) < 1e-5


# ---------------------------------------------------------------------------
# Data.
# ---------------------------------------------------------------------------

def test_token_stream_deterministic_and_learnable():
    ts = TokenStream(TokenStreamConfig(vocab=97, seq_len=32, global_batch=4,
                                       seed=7))
    b1, b2 = ts.batch(5), ts.batch(5)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    assert b1["tokens"].shape == (4, 33)
    # affine recurrence: majority of transitions satisfy t+1 = a*t+c mod V
    toks = np.asarray(ts.batch(0)["tokens"])[0]
    hits = 0
    for a in range(1, 8):
        for c0 in range(97):
            if ((a * toks[:-1] + c0) % 97 == toks[1:]).mean() > 0.8:
                hits += 1
    assert hits >= 1


def test_mnist_like_shapes_and_classes():
    d = MNISTLike(batch=16, n_train=256, n_test=64)
    b = d.train_batch(0)
    assert b["images"].shape == (16, 28, 28, 1)
    assert b["images"].min() >= 0.0 and b["images"].max() <= 1.0
    assert set(np.unique(d.train_y)) <= set(range(10))
    # prototypes are distinguishable: nearest-prototype classifier beats 60%
    from repro.data.mnist import _PROTOS
    flat = d.test_x.reshape(len(d.test_x), -1)
    pf = _PROTOS.reshape(10, -1)
    pred = np.argmin(((flat[:, None] - pf[None]) ** 2).sum(-1), axis=1)
    assert (pred == d.test_y).mean() > 0.6


# ---------------------------------------------------------------------------
# Checkpointing / fault tolerance.
# ---------------------------------------------------------------------------

def _tree():
    return {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "nested": {"b": jnp.ones((4,), jnp.bfloat16)},
            "s": jnp.int32(7)}


def test_checkpoint_roundtrip(tmp_path):
    t = _tree()
    save(str(tmp_path), 3, t, meta={"cursor": 3})
    assert latest_step(str(tmp_path)) == 3
    restored, meta = restore(str(tmp_path), 3, jax.eval_shape(lambda: t))
    assert meta == {"cursor": 3}
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_atomicity_partial_write(tmp_path):
    """A stale .tmp dir never shadows a complete checkpoint."""
    t = _tree()
    save(str(tmp_path), 1, t)
    os.makedirs(tmp_path / "step_00000002.tmp")   # simulated crash mid-write
    assert latest_step(str(tmp_path)) == 1


def test_checkpoint_shape_mismatch_fails_loud(tmp_path):
    save(str(tmp_path), 1, _tree())
    bad = jax.eval_shape(lambda: {"a": jnp.zeros((3, 3)),
                                  "nested": {"b": jnp.ones((4,), jnp.bfloat16)},
                                  "s": jnp.int32(0)})
    with pytest.raises(ValueError, match="shape mismatch"):
        restore(str(tmp_path), 1, bad)


def test_async_checkpointer_and_prune(tmp_path):
    ck = AsyncCheckpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3):
        ck.save(s, _tree())
    ck.wait()
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path))
    assert steps == [2, 3]


def test_train_failure_injection_and_resume(tmp_path):
    """Driver crashes at step 6, checkpoints, resumes, and finishes."""
    from repro.launch import train as train_mod
    args = ["--arch", "llama3_2_3b", "--smoke", "--steps", "10",
            "--batch", "2", "--seq", "16", "--ckpt-dir", str(tmp_path),
            "--ckpt-every", "4", "--log-every", "100"]
    with pytest.raises(SystemExit) as e:
        train_mod.main(args + ["--fail-at", "6"])
    assert e.value.code == 17
    assert latest_step(str(tmp_path)) == 6
    history = train_mod.main(args + ["--resume"])
    assert len(history) == 4            # steps 6..9 after resume
    assert np.isfinite(history[-1]["loss"])


# ---------------------------------------------------------------------------
# Sharding rules.
# ---------------------------------------------------------------------------

def test_logical_rules_divisibility_fallback():
    import os as _os
    # a tiny fake mesh via the public API on 1 device: rules logic is pure
    rules = LogicalRules()

    class FakeMesh:
        axis_names = ("data", "model")
        class devices:
            shape = (4, 8)
            size = 32

    # 30 does not divide model=8 -> falls through to replicated
    assert rules.resolve_dim("tp", 30, FakeMesh, set()) is None
    assert rules.resolve_dim("tp", 32, FakeMesh, set()) == "model"
    # batch binds the data axis when divisible
    assert rules.resolve_dim("batch", 8, FakeMesh, set()) == "data"
    assert rules.resolve_dim("batch", 2, FakeMesh, set()) is None
    # one mesh axis never used twice in a tensor
    taken = set()
    assert rules.resolve_dim("tp", 32, FakeMesh, taken) == "model"
    assert rules.resolve_dim("kv", 32, FakeMesh, taken) is None


# ---------------------------------------------------------------------------
# Fused loss.
# ---------------------------------------------------------------------------

def test_fused_unembed_xent_matches_reference():
    key = jax.random.key(0)
    B, S, D, V = 2, 13, 8, 37
    x = jax.random.normal(key, (B, S, D))
    emb = {"tok": jax.random.normal(jax.random.fold_in(key, 1), (64, D))}
    labels = jax.random.randint(jax.random.fold_in(key, 2), (B, S), 0, V)
    ref = softmax_xent(unembed(x, emb, V), labels)
    fused = fused_unembed_xent(x, emb, V, labels, chunk=5)
    np.testing.assert_allclose(float(fused), float(ref), rtol=1e-6)
    # gradients agree too
    g1 = jax.grad(lambda x: softmax_xent(unembed(x, emb, V), labels))(x)
    g2 = jax.grad(lambda x: fused_unembed_xent(x, emb, V, labels, chunk=5))(x)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-5)
