"""Make ``python -m pytest -q`` work from the repo root without an explicit
``PYTHONPATH=src``: put ``src`` at the front of ``sys.path`` for this test
session (and for subprocess-based tests, which set PYTHONPATH themselves)."""

import os
import sys

_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                    "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)
