"""Backward-overlapped bucketed wire (``repro.dist.overlap``).

Covers the ISSUE-7 acceptance criteria:
  (a) ``plan_buckets`` is a deterministic partition: contiguous
      leaf-index runs in backward (reverse-flatten) order, every leaf in
      exactly one bucket, non-divisible sizes included, and malformed
      plans are rejected at construction;
  (b) the bucketed collective is bit-exact against the monolithic
      ``dps_allreduce_mean_tree`` under round-to-nearest at pinned
      ⟨IL, FL⟩ — scalar AND per-leaf grouped formats — and its
      dispatch-leg stats are bit-exact under stochastic rounding too;
  (c) the overlapped train step (``QuantConfig(wire_overlap=True)``)
      matches the monolithic step bit-exactly at nearest, is a pure
      no-op without ``grad_allreduce_bits``, and composes with ZeRO-1
      through the group-aligned layout (the flow verifier proves the
      bucket schedule on the sharded halves too);
  (d) the precision-flow verifier proves PF-BUCKET-ENCODE /
      PF-BUCKET-DECODE on the real overlapped step and fires both on
      deliberately broken bucket schedules (double-encode, dropped
      leaf, mean-without-decode).

Multi-device tests run in a subprocess under
``xla_force_host_platform_device_count=8`` like tests/test_dist.py; the
plan units and flow oracles run in-process (no mesh needed).
"""

import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))


def run_with_devices(code: str, n: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


# ---------------------------------------------------------------------------
# BucketPlan units (pure Python — no devices).
# ---------------------------------------------------------------------------

LENET_SIZES = (48000, 1200, 30720, 120, 840, 10)


def test_plan_buckets_lenet_shape_and_determinism():
    from repro.dist import overlap

    plan = overlap.plan_buckets(LENET_SIZES, 1 << 16)
    # backward order: the tail leaves (materialized first) share a
    # bucket, the big first-layer leaf gets its own
    assert plan.buckets == ((1, 2, 3, 4, 5), (0,))
    assert plan.n_buckets == 2 and plan.n_leaves == len(LENET_SIZES)
    # deterministic: a static function of (sizes, target)
    assert overlap.plan_buckets(LENET_SIZES, 1 << 16) == plan
    assert plan.bucket_elems(0) == sum(LENET_SIZES) - 48000
    assert plan.bucket_elems(1) == 48000


def test_plan_buckets_partition_no_drops_no_dups():
    from repro.dist import overlap

    # awkward, non-divisible sizes (primes, singleton leaves)
    sizes = (7, 4097, 13, 1, 65536, 251, 3, 1023)
    for target in (1, 1000, 1 << 16, 1 << 30):
        plan = overlap.plan_buckets(sizes, target)
        seen = [g for b in plan.buckets for g in b]
        assert sorted(seen) == list(range(len(sizes)))   # partition
        assert len(seen) == len(set(seen))               # no dups
        for b, leaves in enumerate(plan.buckets):
            for g in leaves:
                assert plan.bucket_of(g) == b
    # a huge target degenerates to one bucket, a tiny one to per-leaf
    assert overlap.plan_buckets(sizes, 1 << 30).n_buckets == 1
    assert overlap.plan_buckets(sizes, 1).n_buckets == len(sizes)


def test_plan_validation_rejects_malformed():
    from repro.dist import overlap

    # not a partition (leaf 0 dropped)
    with pytest.raises(ValueError):
        overlap.BucketPlan(sizes=(4, 4), buckets=((1,),), target=8)
    # duplicate leaf
    with pytest.raises(ValueError):
        overlap.BucketPlan(sizes=(4, 4), buckets=((1,), (1, 0)), target=8)
    # forward (non-reverse) bucket order
    with pytest.raises(ValueError):
        overlap.BucketPlan(sizes=(4, 4), buckets=((0,), (1,)), target=8)


# ---------------------------------------------------------------------------
# Collective-level bit-exactness vs the monolithic pipeline (8 devices).
# ---------------------------------------------------------------------------

def test_bucketed_collective_bitexact_vs_monolithic():
    run_with_devices("""
        import jax, repro.compat
        import jax.numpy as jnp
        import numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.core.fixed_point import FixedPointFormat
        from repro.dist import collectives, overlap

        sizes = (48000, 1200, 30720, 120, 840, 10)
        plan = overlap.plan_buckets(sizes, 1 << 16)
        assert plan.n_buckets >= 2
        mesh = jax.make_mesh((8,), ("data",))
        tree = {f"l{i}": jax.random.normal(
                    jax.random.fold_in(jax.random.key(0), i), (s,)) * 0.5
                for i, s in enumerate(sizes)}
        key = jax.random.key(7)
        sm = lambda f: jax.jit(jax.shard_map(
            f, mesh=mesh, in_specs=({k: P() for k in tree}, P()),
            out_specs=(P(), P()), check_vma=False))

        stat_fields = ("count", "nonzero", "overflow", "abs_err_sum",
                       "rel_err_sum", "abs_sum", "max_abs")
        for fmt, label in [
                (FixedPointFormat.create(3, 5), "scalar"),
                (FixedPointFormat(jnp.array([3, 2, 4, 3, 2, 3]),
                                  jnp.array([5, 6, 4, 5, 6, 5])), "grouped")]:
            def mono(tr, k, _f=fmt):
                return collectives.dps_allreduce_mean_tree(
                    tr, _f, "data", k, mode="nearest")
            def buck(tr, k, _f=fmt):
                return overlap.bucketed_allreduce_mean_tree(
                    tr, _f, "data", k, mode="nearest", plan=plan)
            m1, s1 = sm(mono)(tree, key)
            m2, s2 = sm(buck)(tree, key)
            for k2 in tree:
                assert np.array_equal(np.asarray(m1[k2]),
                                      np.asarray(m2[k2])), (label, k2)
            for f in stat_fields:
                assert np.array_equal(np.asarray(getattr(s1, f)),
                                      np.asarray(getattr(s2, f))), (label, f)

        # stochastic rounding: the dispatch-leg stats (what steers the
        # wire controller) stay bit-exact — leg-1 rounding bits are keyed
        # per GLOBAL leaf index, identically to the monolithic pipeline
        fmt = FixedPointFormat.create(3, 5)
        def monoS(tr, k):
            return collectives.dps_allreduce_mean_tree(
                tr, fmt, "data", k, mode="stochastic")
        def buckS(tr, k):
            return overlap.bucketed_allreduce_mean_tree(
                tr, fmt, "data", k, mode="stochastic", plan=plan)
        _, s1 = sm(monoS)(tree, key)
        _, s2 = sm(buckS)(tree, key)
        for f in ("count", "nonzero", "overflow", "abs_err_sum",
                  "abs_sum", "max_abs"):
            assert np.array_equal(np.asarray(getattr(s1, f)),
                                  np.asarray(getattr(s2, f))), f
        print("OK")
        """)


# ---------------------------------------------------------------------------
# Train-step parity + flow verification + ZeRO rejection (8 devices).
# ---------------------------------------------------------------------------

def test_overlap_step_bitexact_and_flow_clean():
    run_with_devices("""
        import dataclasses
        import jax, repro.compat
        import jax.numpy as jnp
        from repro.analysis import flow
        from repro.core import qtrain
        from repro.core.dps import DPSHyper
        from repro.models import lenet
        from repro.optim import SGDConfig, make_optimizer

        mesh = jax.make_mesh((8,), ("data",))
        base = dict(enabled=False, controller="static",
                    hyper_grads=DPSHyper(il_init=6, fl_init=2),
                    rounding="nearest", grad_allreduce_bits=8)
        qA = qtrain.QuantConfig(**base)
        qB = qtrain.QuantConfig(**base, wire_overlap=True,
                                wire_bucket_elems=1 << 15)
        opt = make_optimizer(SGDConfig())
        params = lenet.init(jax.random.key(0))
        batch = {"images": jax.random.normal(jax.random.key(2),
                                             (64, 28, 28, 1)) * 0.5,
                 "labels": jax.random.randint(jax.random.key(3), (64,),
                                              0, 10)}

        def run(q):
            st = qtrain.TrainState.create(params, opt.init(params), q,
                                          jax.random.key(1))
            step = qtrain.make_train_step(lenet.loss_fn, opt, q, mesh=mesh)
            return step, jax.jit(step)(st, batch)

        # scalar wire format: overlapped step bit-exact vs monolithic
        stepA, (sA, mA) = run(qA)
        stepB, (sB, mB) = run(qB)
        assert stepA.wire_sync_active and not stepA.wire_overlap_active
        assert stepB.wire_sync_active and stepB.wire_overlap_active
        assert float(mA["loss"]) == float(mB["loss"])
        assert float(mA["E_wire"]) == float(mB["E_wire"])
        for a, b in zip(jax.tree.leaves(sA.params), jax.tree.leaves(sB.params)):
            assert jnp.array_equal(a, b), "overlap must be bit-exact"

        # per-layer grouped wire formats too
        qAg, qBg = qA.with_per_layer_wire(params), qB.with_per_layer_wire(params)
        _, (sA, mA) = run(qAg)
        _, (sB, mB) = run(qBg)
        for a, b in zip(jax.tree.leaves(sA.params), jax.tree.leaves(sB.params)):
            assert jnp.array_equal(a, b), "grouped overlap must be bit-exact"

        # the flow verifier proves the bucket schedule on the REAL step
        st = qtrain.TrainState.create(params, opt.init(params), qBg,
                                      jax.random.key(1))
        step = qtrain.make_train_step(lenet.loss_fn, opt, qBg, mesh=mesh)
        r = flow.analyze_fn(step, st, batch, name="overlap-step")
        assert r.ok, r.summary()
        assert "PF-BUCKET-ENCODE" in r.checked
        assert "PF-BUCKET-DECODE" in r.checked

        # ZeRO-1 composes: the group-aligned layout keeps the leaf
        # boundaries buckets are made of, and the verifier proves the
        # same bucket schedule on the SHARDED reduce-scatter half
        qZ = dataclasses.replace(qBg, zero_opt_shards=8)
        stepZ = qtrain.make_train_step(lenet.loss_fn, opt, qZ, mesh=mesh)
        assert stepZ.zero_opt_active and stepZ.wire_overlap_active
        assert stepZ.zero_groupaligned_active
        stZ = qtrain.TrainState.create(
            params, qtrain.zero_opt_state(opt, params, 8, qcfg=qZ), qZ,
            jax.random.key(1))
        _, mZ = jax.jit(stepZ)(stZ, batch)
        assert float(mZ["loss"]) == float(mA["loss"])
        r = flow.analyze_fn(stepZ, stZ, batch, name="zero-overlap-step")
        assert r.ok, r.summary()
        assert "PF-BUCKET-ENCODE" in r.checked
        assert "PF-BUCKET-DECODE" in r.checked
        print("OK")
        """)


def test_bucketed_bitexact_both_modes():
    """The PR-7 SR caveat is gone: bucketed decoded means AND stats are
    bit-exact vs the monolithic collective under BOTH rounding modes —
    every rounding-bit draw (dispatch and gather leg) is keyed by global
    leaf index, so the bucket partition cannot move it."""
    run_with_devices("""
        import jax, repro.compat
        import jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.core.fixed_point import FixedPointFormat
        from repro.dist import collectives, overlap

        mesh = jax.make_mesh((8,), ("data",))
        tree = {"a": jax.random.normal(jax.random.key(0), (8, 37, 5)) * .2,
                "b": jax.random.normal(jax.random.key(1), (8, 3)) * .1,
                "c": jax.random.normal(jax.random.key(2), (8, 300)) * .3,
                "d": jax.random.normal(jax.random.key(3), (8, 1000)) * .05}
        fmts = {
            "grouped": FixedPointFormat(jnp.full((4,), 3, jnp.int32),
                                        jnp.full((4,), 5, jnp.int32)),
            "scalar": FixedPointFormat.create(3, 5)}
        key = jax.random.key(7)
        for label, fmt in fmts.items():
            for mode in ("nearest", "stochastic"):
                def mono(t, _f=fmt, _m=mode):
                    return collectives.dps_allreduce_mean_tree(
                        t, _f, "data", key, mode=_m)
                def buck(t, _f=fmt, _m=mode):
                    return overlap.bucketed_allreduce_mean_tree(
                        t, _f, "data", key, mode=_m, target_elems=512)
                sm = lambda f: jax.jit(jax.shard_map(
                    f, mesh=mesh, in_specs=(P("data"),),
                    out_specs=(P(), P()), check_vma=False))
                m, s1 = sm(mono)(tree)
                b, s2 = sm(buck)(tree)
                for x, y in zip(jax.tree.leaves(m), jax.tree.leaves(b)):
                    assert jnp.array_equal(x, y), (label, mode)
                for x, y in zip(jax.tree.leaves(s1), jax.tree.leaves(s2)):
                    assert jnp.array_equal(x, y), (label, mode, "stats")
        print("OK")
        """)


def test_wire_overlap_without_bits_is_noop():
    run_with_devices("""
        import jax, repro.compat
        import jax.numpy as jnp
        from repro.core import qtrain
        from repro.models import lenet
        from repro.optim import SGDConfig, make_optimizer

        mesh = jax.make_mesh((8,), ("data",))
        # wire_overlap without grad_allreduce_bits: no wire, no buckets —
        # the step must match the meshless reference bit-exactly
        qcfg = qtrain.QuantConfig(enabled=True, wire_overlap=True)
        opt = make_optimizer(SGDConfig())
        params = lenet.init(jax.random.key(0))
        batch = {"images": jax.random.normal(jax.random.key(2),
                                             (64, 28, 28, 1)),
                 "labels": jax.random.randint(jax.random.key(3), (64,),
                                              0, 10)}
        st = qtrain.TrainState.create(params, opt.init(params), qcfg,
                                      jax.random.key(1))
        step_ref = qtrain.make_train_step(lenet.loss_fn, opt, qcfg)
        step_mesh = qtrain.make_train_step(lenet.loss_fn, opt, qcfg,
                                           mesh=mesh)
        assert not step_mesh.wire_sync_active
        assert not step_mesh.wire_overlap_active
        s1, m1 = jax.jit(step_ref)(st, batch)
        s2, m2 = jax.jit(step_mesh)(st, batch)
        assert float(m1["loss"]) == float(m2["loss"])
        for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params)):
            assert jnp.array_equal(a, b)
        print("OK")
        """)


# ---------------------------------------------------------------------------
# Flow oracles: the PF-BUCKET rules fire on deliberately broken schedules
# (in-process; the analyzer traces, nothing executes on a mesh).
# ---------------------------------------------------------------------------

def _fmt():
    from repro.core.fixed_point import FixedPointFormat
    return FixedPointFormat.create(3, 5)


def test_oracle_double_encoded_bucket_fires():
    import jax
    import jax.numpy as jnp
    from repro.analysis import flow
    from repro.core import tagging
    from repro.dist import collectives

    fmt = _fmt()

    def double_encode(x, k):
        r = tagging.tag(x, "wire_bucket", stage="ready", bucket=0, leaf=0,
                        n=1)
        w1, _ = collectives.wire_encode(r.reshape(-1), fmt, key=k,
                                        mode="nearest")
        w2, _ = collectives.wire_encode(r.reshape(-1), fmt, key=k,
                                        mode="nearest")
        return w1, w2

    r = flow.analyze_fn(double_encode, jnp.zeros((64,)), jax.random.key(0))
    assert "PF-BUCKET-ENCODE" in r.rules_fired()


def test_oracle_dropped_bucket_fires():
    import jax.numpy as jnp
    from repro.analysis import flow
    from repro.core import tagging
    from repro.dist import collectives

    fmt = _fmt()

    def dropped(x):
        # declares n=2 buckets but only bucket 0 ever reaches the wire
        r0 = tagging.tag(x, "wire_bucket", stage="ready", bucket=0, leaf=0,
                         n=2)
        w, _ = collectives.wire_encode(r0.reshape(-1), fmt, key=None,
                                       mode="nearest")
        return tagging.tag(collectives.wire_decode(w, fmt), "wire_bucket",
                           stage="mean", bucket=0, n=2)

    r = flow.analyze_fn(dropped, jnp.zeros((64,)))
    assert "PF-BUCKET-ENCODE" in r.rules_fired()


def test_oracle_mean_without_decode_fires():
    import jax.numpy as jnp
    from repro.analysis import flow
    from repro.core import tagging
    from repro.dist import collectives

    fmt = _fmt()

    def no_decode(x):
        r0 = tagging.tag(x, "wire_bucket", stage="ready", bucket=0, leaf=0,
                         n=1)
        w, _ = collectives.wire_encode(r0.reshape(-1), fmt, key=None,
                                       mode="nearest")
        # arithmetic between decode and the mean tag kills the taint
        return tagging.tag(w.astype(jnp.float32) * 2.0, "wire_bucket",
                           stage="mean", bucket=0, n=1)

    r = flow.analyze_fn(no_decode, jnp.zeros((64,)))
    assert "PF-BUCKET-DECODE" in r.rules_fired()


def test_oracle_clean_bucketed_pipeline_checks_rules():
    """The closest correct variant stays quiet — and marks both bucket
    rules checked (not vacuous) on a genuinely bucketed pipeline."""
    import jax
    import jax.numpy as jnp
    from repro.analysis import flow
    from repro.dist import overlap

    sizes = (640, 96, 32)
    plan = overlap.plan_buckets(sizes, 128)
    tree = {f"l{i}": jnp.ones((s,)) for i, s in enumerate(sizes)}

    def step(tr, k):
        return overlap.bucketed_allreduce_mean_tree(
            tr, _fmt(), "data", k, mode="nearest", plan=plan)

    r = flow.analyze_fn(step, tree, jax.random.key(0),
                        axis_env=[("data", 8)])
    assert r.ok, r.summary()
    assert "PF-BUCKET-ENCODE" in r.checked
    assert "PF-BUCKET-DECODE" in r.checked
