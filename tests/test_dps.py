"""Tests for the DPS controllers (paper Alg. 2 + the Table-1 baselines)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dps import (DPSHyper, CONTROLLERS, make_controller,
                            PaperController)
from repro.core.fixed_point import FixedPointFormat, QuantStats, quantize


def stats(count=1000, overflow=0, rel_err=0.0, nonzero=None, max_abs=1.0):
    nz = count if nonzero is None else nonzero
    return QuantStats(
        count=jnp.float32(count), nonzero=jnp.float32(nz),
        overflow=jnp.float32(overflow),
        abs_err_sum=jnp.float32(rel_err * nz), rel_err_sum=jnp.float32(rel_err * nz),
        abs_sum=jnp.float32(nz), max_abs=jnp.float32(max_abs))


def test_paper_alg2_all_four_branches():
    h = DPSHyper(r_max=1e-4, e_max=1e-4, il_init=8, fl_init=8)
    c = PaperController(h)
    s0 = c.init()

    # R high, E high -> both grow
    s = c.update(s0, stats(overflow=10, rel_err=0.5))
    assert (int(s.il), int(s.fl)) == (9, 9)
    # R high, E low -> IL grows, FL shrinks
    s = c.update(s0, stats(overflow=10, rel_err=0.0))
    assert (int(s.il), int(s.fl)) == (9, 7)
    # R low, E high -> IL shrinks, FL grows
    s = c.update(s0, stats(overflow=0, rel_err=0.5))
    assert (int(s.il), int(s.fl)) == (7, 9)
    # R low, E low -> both shrink (the paper's "aggressive" property)
    s = c.update(s0, stats(overflow=0, rel_err=0.0))
    assert (int(s.il), int(s.fl)) == (7, 7)


def test_paper_threshold_is_percent_scale():
    """E_max = R_max = 0.01% = 1e-4 (paper §4)."""
    h = DPSHyper()
    assert h.r_max == 1e-4 and h.e_max == 1e-4
    c = PaperController(h)
    s0 = c.init()
    # overflow rate 2e-4 > 1e-4 -> grow
    s = c.update(s0, stats(count=10000, overflow=2, rel_err=0.0))
    assert int(s.il) == h.il_init + 1


def test_clamping_keeps_grid_exact():
    """IL - 1 + FL never exceeds 24 (fp32-exact emulation)."""
    h = DPSHyper(il_init=16, fl_init=23, il_max=16, fl_max=23)
    c = PaperController(h)
    s = c.init()
    for _ in range(5):
        s = c.update(s, stats(overflow=100, rel_err=1.0))  # push both up
    assert int(s.il) - 1 + int(s.fl) <= 24
    assert int(s.il) <= h.il_max


def test_paper_converges_to_narrow_format_on_easy_tensor():
    """Closed loop: quantize a well-scaled tensor, feed stats back; widths
    should fall until E crosses threshold, then stabilize (paper Fig. 3)."""
    key = jax.random.key(0)
    x = jax.random.normal(key, (4096,)) * 0.5
    h = DPSHyper(il_init=10, fl_init=16, e_max=1e-3)
    c = PaperController(h)
    s = c.init()
    widths = []
    for i in range(40):
        q, st = quantize(x, c.fmt(s), key=jax.random.fold_in(key, i))
        s = c.update(s, st)
        widths.append(int(s.il) + int(s.fl))
    assert widths[-1] < widths[0]           # shrank
    assert min(widths) >= 3                 # did not collapse to nothing
    # stabilized: last 10 widths within +-2 bits of each other
    assert max(widths[-10:]) - min(widths[-10:]) <= 4


def test_courbariaux_fixed_width_invariant():
    c = make_controller("courbariaux", DPSHyper(total_bits=16))
    s = c.init()
    for ov in (0, 500, 0, 0, 500):
        s = c.update(s, stats(overflow=ov))
        assert int(s.il) + int(s.fl) == 16
    # overflow pushes radix right
    s2 = c.update(s, stats(overflow=500))
    assert int(s2.il) == min(int(s.il) + 1, 16 - 0)


def test_courbariaux_headroom_moves_radix_left():
    c = make_controller("courbariaux", DPSHyper(total_bits=16, r_max=1e-2))
    s0 = c.init()
    s = c.update(s0, stats(count=10000, overflow=0))       # 0 <= Rmax/2
    assert int(s.il) == int(s0.il) - 1
    s = c.update(s0, stats(count=10000, overflow=60))      # Rmax/2 < R <= ... no wait 6e-3 > 5e-3, <=1e-2 -> hold
    assert int(s.il) == int(s0.il)


def test_na_width_grows_on_stall():
    h = DPSHyper(na_window=5, na_tl_init=8, na_ml=24)
    c = make_controller("na_mukhopadhyay", h)
    s = c.init()
    # constant loss -> stall after window steps -> width bump
    tl0 = int(s.tl)
    for _ in range(2 * h.na_window + 2):
        s = c.update(s, stats(), {"loss": 1.0})
    assert int(s.tl) > tl0
    assert int(s.il) + int(s.fl) == int(s.tl)
    assert c.rounding == "nearest"          # Na uses RTN (Table 1)


def test_na_no_growth_while_improving():
    h = DPSHyper(na_window=5)
    c = make_controller("na_mukhopadhyay", h)
    s = c.init()
    loss = 10.0
    for _ in range(20):
        s = c.update(s, stats(), {"loss": loss})
        loss *= 0.8
    assert int(s.tl) == h.na_tl_init


def test_static_never_moves():
    c = make_controller("static", DPSHyper(il_init=3, fl_init=10))
    s = c.init()
    s2 = c.update(s, stats(overflow=999, rel_err=1.0))
    assert (int(s2.il), int(s2.fl)) == (3, 10)


def test_flexpoint_tracks_max():
    c = make_controller("flexpoint", DPSHyper(total_bits=16, flex_slack=1.0))
    s = c.init()
    s = c.update(s, stats(max_abs=100.0))      # needs ~2^8 range + slack
    # 2^(IL-1) must cover 200 -> IL >= 9 (ceil(log2(200))+1 = 9)
    assert int(s.il) >= 9
    assert int(s.il) + int(s.fl) == 16
    # decays back down when maxima shrink
    for _ in range(40):
        s = c.update(s, stats(max_abs=0.1))
    assert int(s.il) < 9


def test_flexpoint_auto_slack_places_radix_from_measured_bulk():
    from repro.core.dps import wire_hyper

    def st(bulk, mx, nz=1000.0):
        return QuantStats(
            count=jnp.float32(1000), nonzero=jnp.float32(nz),
            overflow=jnp.float32(0), abs_err_sum=jnp.float32(0),
            rel_err_sum=jnp.float32(0), abs_sum=jnp.float32(bulk * nz),
            max_abs=jnp.float32(mx))

    c_s = make_controller("flexpoint", wire_hyper(8, il_init=6, slack=0.0))
    c_a = make_controller("flexpoint", wire_hyper(8, il_init=6, slack=0.0,
                                                  auto_slack=True))
    # heavy tail (bulk 0.01, max 100): the static slack covers the max;
    # the measured placement covers the r_max tail quantile of the bulk
    # (0.01 · ln(1e4) ≈ 0.09), spending the 8-bit grid on the signal
    s_s = c_s.update(c_s.init(), st(0.01, 100.0))
    s_a = c_a.update(c_a.init(), st(0.01, 100.0))
    assert int(s_a.il) < int(s_s.il)
    # concentrated tensor (bulk ~ max): the tail quantile overshoots the
    # max, so the placement caps at the max component — never wider
    s_a2 = c_a.update(c_a.init(), st(50.0, 100.0))
    s_s2 = c_s.update(c_s.init(), st(50.0, 100.0))
    assert int(s_a2.il) <= int(s_s2.il)
    # an empty stream (wire not engaged this step) falls back to the
    # static-slack path bit-for-bit
    s_a3 = c_a.update(c_a.init(), st(0.0, 0.0, nz=0.0))
    s_s3 = c_s.update(c_s.init(), st(0.0, 0.0, nz=0.0))
    assert (int(s_a3.il), int(s_a3.fl)) == (int(s_s3.il), int(s_s3.fl))


def test_all_controllers_jittable_and_stable_shape():
    for name in CONTROLLERS:
        c = make_controller(name)
        s = c.init()
        upd = jax.jit(lambda s, st: c.update(s, st, {"loss": jnp.float32(1.0)}))
        s2 = upd(s, stats(overflow=5, rel_err=0.2))
        assert jax.tree.structure(s) == jax.tree.structure(s2)
        f = c.fmt(s2)
        assert f.il.dtype == jnp.int32 and f.fl.dtype == jnp.int32


def test_controllers_support_per_group_granularity():
    c = PaperController(DPSHyper())
    s = c.init(shape=(4,))
    st = QuantStats(
        count=jnp.full((4,), 100.0), nonzero=jnp.full((4,), 100.0),
        overflow=jnp.array([0.0, 50.0, 0.0, 50.0]),
        abs_err_sum=jnp.zeros((4,)), rel_err_sum=jnp.array([0.0, 0.0, 50.0, 50.0]),
        abs_sum=jnp.full((4,), 100.0), max_abs=jnp.ones((4,)))
    s2 = c.update(s, st)
    np.testing.assert_array_equal(np.asarray(s2.il) - np.asarray(s.il),
                                  [-1, 1, -1, 1])
    np.testing.assert_array_equal(np.asarray(s2.fl) - np.asarray(s.fl),
                                  [-1, -1, 1, 1])
