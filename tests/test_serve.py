"""repro.serve: page codec, fused paged attention, engine equivalences.

The load-bearing properties:

* the page-encode kernel path is bit-exact against the grouped jnp codec
  (one page = one group — PR 5's contract applied to the cache);
* the fused paged decode-attention kernel is bitwise equal to its jnp
  reference (``repro.kernels.ref.paged_decode_attn_ref``);
* at ``kv_bits=None`` the paged engine is token-identical to the plain
  contiguous fp32 prefill+decode loop (paging is pure bookkeeping);
* continuous batching is invisible to any single request: every admitted
  request decodes to exactly the tokens a solo run produces, regardless
  of neighbors, arrival order, or which physical pages it was handed.
"""

import dataclasses
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, smoke
from repro.core import fixed_point as fxp
from repro.core.fixed_point import FixedPointFormat
from repro.models import registry
from repro.models.common import init_params
from repro.serve import (Engine, EngineConfig, PageAllocator, PagedLayout,
                         Request, Scheduler, page_rows, synthetic_trace)
from repro.serve import cache as kvc

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

CFG = smoke(get_config("llama3_2_3b"))
MOD = registry(CFG.family)
PARAMS = init_params(jax.random.key(0), MOD.model_defs(CFG))
LAY = PagedLayout(page_size=4, n_pages=24, batch_slots=4,
                  max_pages_per_seq=8, max_prompt=16)


def _engine(**kw):
    return Engine(CFG, PARAMS, EngineConfig(layout=LAY, **kw))


# ---------------------------------------------------------------------------
# geometry / allocator
# ---------------------------------------------------------------------------

def test_layout_pages_needed():
    lay = LAY
    assert lay.pages_needed(4, 1) == 1          # last token never written
    assert lay.pages_needed(4, 2) == 2
    assert lay.pages_needed(8, 5) == 3
    assert lay.trash_page == lay.n_pages
    assert lay.prompt_pages == 4


def test_allocator_lifo_and_release():
    a = PageAllocator(6)
    p1 = a.alloc(4)
    assert a.n_free == 2 and len(set(p1)) == 4
    with pytest.raises(RuntimeError):
        a.alloc(3)
    a.release(p1)
    assert a.n_free == 6


def test_page_rows_layout():
    rows = page_rows(3, 10, [7, 2])
    assert rows.shape == (2, 3, 2)
    # K rows of page 7: layer-l row = l*10 + 7; V rows offset by 3*10
    assert list(rows[0, :, 0]) == [7, 17, 27]
    assert list(rows[1, :, 0]) == [37, 47, 57]
    assert rows.max() < 2 * 3 * 10


# ---------------------------------------------------------------------------
# page codec: kernel path bit-exact vs the grouped jnp reference
# ---------------------------------------------------------------------------

def test_page_encode_kernel_matches_jnp():
    G, E = 6, 4096                     # E meets the kernel's tile quantum
    key = jax.random.key(3)
    x = jax.random.normal(key, (G, E)) * \
        (2.0 ** jax.random.randint(jax.random.fold_in(key, 1),
                                   (G, 1), -3, 4))
    mask = (jax.random.uniform(jax.random.fold_in(key, 2), (G, E))
            > 0.1).astype(jnp.float32)
    fmt = FixedPointFormat(
        jax.random.randint(jax.random.fold_in(key, 3), (G,), 1, 5),
        8 - jax.random.randint(jax.random.fold_in(key, 3), (G,), 1, 5))
    w_jnp = kvc.encode_pages(x, fmt, mask, backend="jnp", quantum=E)
    w_ker = kvc.encode_pages(x, fmt, mask, backend="kernel", quantum=E)
    assert w_jnp.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(w_jnp), np.asarray(w_ker))
    # masked elements carry no wire payload
    assert not np.any(np.asarray(w_jnp)[np.asarray(mask) == 0.0])


def test_page_roundtrip_error_bounded():
    """Decode (wire · 2^-FL) of an in-range page is within half a step."""
    E = 64
    key = jax.random.key(4)
    x = jax.random.uniform(key, (1, E), minval=-1.9, maxval=1.9)
    fmt = FixedPointFormat(jnp.array([2]), jnp.array([6]))
    w = kvc.encode_pages(x, fmt, jnp.ones((1, E)), backend="jnp", quantum=E)
    back = np.asarray(w, np.float32) * 2.0 ** -6
    assert np.max(np.abs(back - np.asarray(x))) <= 2.0 ** -7 + 1e-7


# ---------------------------------------------------------------------------
# fused paged attention: kernel vs jnp oracle
# ---------------------------------------------------------------------------

def test_paged_attn_kernel_bitexact_vs_ref():
    from repro.kernels.paged_attn import paged_attn_pallas
    from repro.kernels.ref import paged_decode_attn_ref

    B, P, ps, KV, Dh, H = 3, 4, 4, 2, 16, 4
    n_pages = 8
    key = jax.random.key(11)
    q = jax.random.normal(key, (B, H, Dh), jnp.float32)
    kp = jax.random.randint(jax.random.fold_in(key, 1),
                            (n_pages + 1, ps, KV, Dh), -128, 128, jnp.int32
                            ).astype(jnp.int8)
    vp = jax.random.randint(jax.random.fold_in(key, 2),
                            (n_pages + 1, ps, KV, Dh), -128, 128, jnp.int32
                            ).astype(jnp.int8)
    fmt = jax.random.randint(jax.random.fold_in(key, 3),
                             (n_pages + 1, 2), 4, 9, jnp.int32)
    ptab = jax.random.randint(jax.random.fold_in(key, 4), (B, P), 0,
                              n_pages, jnp.int32)
    lens = jnp.array([1, 7, 16], jnp.int32)
    scale = Dh ** -0.5
    ref = paged_decode_attn_ref(q, kp, vp, fmt, ptab, lens, scale=scale)
    ker = paged_attn_pallas(q, kp, vp, fmt, ptab, lens, scale=scale,
                            interpret=True)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(ker))


def test_paged_attn_zero_len_rows_are_zero():
    from repro.kernels.ref import paged_decode_attn_ref
    B, P, ps, KV, Dh, H = 2, 2, 4, 2, 8, 2
    q = jnp.ones((B, H, Dh))
    kp = jnp.ones((5, ps, KV, Dh), jnp.int8) * 7
    vp = jnp.ones((5, ps, KV, Dh), jnp.int8) * 7
    fmt = jnp.full((5, 2), 4, jnp.int32)
    ptab = jnp.zeros((B, P), jnp.int32)
    out = paged_decode_attn_ref(q, kp, vp, fmt, ptab,
                                jnp.array([0, 3]), scale=1.0)
    assert np.all(np.asarray(out[0]) == 0.0)
    assert np.all(np.isfinite(np.asarray(out)))


def test_paged_attn_geometry_rules():
    """Production dims pass; a sub-tile page trips KG-TILE-MIN."""
    from repro.analysis import kernel_checks
    from repro.kernels import ops
    good = kernel_checks.check_call(
        ops.paged_attn_call_geometry(8, 16, 513, 128, 8, 128),
        expected_groups=513)
    assert good.ok, good.summary()
    bad = kernel_checks.check_call(
        ops.paged_attn_call_geometry(8, 16, 513, 4, 2, 16),
        expected_groups=513)
    assert any(v.rule == "KG-TILE-MIN" for v in bad.violations)


# ---------------------------------------------------------------------------
# engine equivalences
# ---------------------------------------------------------------------------

def test_paged_fp32_matches_contiguous_decode():
    """kv_bits=None: paging is bookkeeping — token-identical to the plain
    contiguous fp32 loop (full-length prompt keeps summation orders
    aligned between the two prefill shapes)."""
    eng = _engine(kv_bits=None)
    prompt = np.asarray(
        jax.random.randint(jax.random.key(7), (LAY.max_prompt,), 1,
                           CFG.vocab), np.int32)
    n_new = 8
    paged = eng.run([Request(rid=0, prompt=prompt, max_new=n_new)]).tokens[0]

    cfg16 = dataclasses.replace(CFG, kv_cache_bits=16)
    logits, cache, pos = MOD.prefill(cfg16, PARAMS,
                                     jnp.asarray(prompt)[None],
                                     LAY.max_prompt + n_new)
    toks = [int(jnp.argmax(logits[0]))]
    for _ in range(n_new - 1):
        lg, cache = MOD.decode_step(cfg16, PARAMS,
                                    jnp.asarray([[toks[-1]]]), cache, pos)
        pos = pos + 1
        toks.append(int(jnp.argmax(lg[0])))
    assert paged == toks


def test_continuous_batching_matches_solo_runs():
    """Every admitted request decodes to the tokens of a solo run: per-page
    formats are content-pure, trash writes are masked out, and physical
    page ids never enter the math."""
    eng = _engine(kv_bits=8)
    reqs = synthetic_trace(6, CFG.vocab, prompt_lens=(3, 12),
                          new_tokens=(2, 8), mean_gap=0.5, seed=1)
    batched = eng.run(reqs)
    for r in reqs:
        solo = eng.run([dataclasses.replace(r, arrival=0)])
        assert solo.tokens[r.rid] == batched.tokens[r.rid], r.rid
    # churn really happened: more requests than slots, all served fully
    assert all(len(batched.tokens[r.rid]) == r.max_new for r in reqs)
    assert batched.metrics["mean_occupancy"] > 1.0


def test_int8_close_to_fp32_tokens():
    """The int8 page grid is lossy but must stay close on greedy tokens —
    first tokens (pure prefill, no cache read) are exactly equal."""
    prompt = np.asarray(
        jax.random.randint(jax.random.key(9), (8,), 1, CFG.vocab), np.int32)
    r = Request(rid=0, prompt=prompt, max_new=6)
    t8 = _engine(kv_bits=8).run([r]).tokens[0]
    t32 = _engine(kv_bits=None).run([r]).tokens[0]
    assert t8[0] == t32[0]
    assert len(t8) == len(t32) == 6


def test_scheduler_strict_fcfs():
    reqs = [Request(rid=i, prompt=np.ones(4, np.int32), max_new=2,
                    arrival=a) for i, a in enumerate([5, 0, 0])]
    s = Scheduler(reqs)
    assert s.pop_admissible(0, lambda r: True).rid == 1
    # head-of-line blocks even when later requests would fit
    assert s.pop_admissible(0, lambda r: r.rid != 2) is None
    assert s.pop_admissible(0, lambda r: True).rid == 2
    assert s.pop_admissible(0, lambda r: True) is None   # rid 0 not arrived
    assert s.pop_admissible(5, lambda r: True).rid == 0


def test_format_spread_and_state_reset():
    """Pages holding different content land on different grids, and a
    retired request's rows return to the init format."""
    eng = _engine(kv_bits=8)
    reqs = synthetic_trace(4, CFG.vocab, prompt_lens=(4, 12),
                          new_tokens=(2, 4), mean_gap=0.0, seed=5)
    rep = eng.run(reqs)
    assert sum(rep.format_spread.values()) > 0
    # the decode-flow verifier saw the page tags
    from repro.analysis import flow
    from repro.serve import analysis_decode
    fn, args = analysis_decode(CFG, EngineConfig(layout=LAY, kv_bits=8,
                                                 attn_backend="jnp",
                                                 encode_backend="jnp"))
    r = flow.analyze_jaxpr(jax.make_jaxpr(fn)(*args), name="decode")
    assert "PF-KV-WIRE" in r.checked
    assert r.ok, r.summary()


# ---------------------------------------------------------------------------
# CLI smoke
# ---------------------------------------------------------------------------

def test_serve_cli_smoke():
    env = dict(os.environ,
               PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch",
         "llama3_2_3b", "--smoke", "--requests", "4", "--slots", "2",
         "--page-size", "4", "--max-prompt", "8", "--max-new", "6"],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "tok/s" in out.stdout
    assert "<IL,FL> spread" in out.stdout
