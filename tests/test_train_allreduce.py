"""Multi-device regression tests for the compressed gradient all-reduce
(``QuantConfig.grad_allreduce_bits``): run in a subprocess under
``xla_force_host_platform_device_count=8`` like tests/test_dist.py.

Covers the ISSUE-2 acceptance criteria (updated for the ISSUE-4
precision-domain registry):
  (a) ``grad_allreduce_bits=None`` with a mesh matches the meshless step
      bit-exactly (the flag is a pure opt-in),
  (b) ``=8`` keeps the synced gradient within two wire grid steps of the
      fp32 mean (asserted through the SGD update) and trains MNIST-tiny
      with the same loss trend,
  (c) the dedicated ``wire_grads`` domain's ⟨IL, FL⟩ responds to the wire
      QuantStats while the compute controllers stay decoupled from them,
  (d) the int8 path moves ≤ ~1/4 the gradient wire bytes of the fp32
      all-reduce (ring model, parsed from compiled HLO),
plus the ISSUE-4 stability guarantee: the hair-trigger ``r_max = 1e-4``
scenario — formerly pinned as an instability — trains stably now that the
wire format is owned by its own flexpoint domain.

``REPRO_WIRE_CONTROLLER`` selects the wire domain's controller kind for
the stability test (CI's dist-wire-ctrl leg pins ``flexpoint``).
"""

import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_with_devices(code: str, n: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_grad_allreduce_off_matches_meshless_step_bitexact():
    run_with_devices("""
        import jax, jax.numpy as jnp
        from repro.core import qtrain
        from repro.models import lenet
        from repro.optim import SGDConfig, make_optimizer

        mesh = jax.make_mesh((8,), ("data",))
        qcfg = qtrain.QuantConfig(enabled=True)   # grad_allreduce_bits=None
        opt = make_optimizer(SGDConfig())
        params = lenet.init(jax.random.key(0))
        state = qtrain.TrainState.create(params, opt.init(params), qcfg,
                                         jax.random.key(1))
        batch = {"images": jax.random.normal(jax.random.key(2), (64, 28, 28, 1)),
                 "labels": jax.random.randint(jax.random.key(3), (64,), 0, 10)}

        step_ref = qtrain.make_train_step(lenet.loss_fn, opt, qcfg)
        step_mesh = qtrain.make_train_step(lenet.loss_fn, opt, qcfg, mesh=mesh)
        assert not step_mesh.wire_sync_active
        s1, m1 = jax.jit(step_ref)(state, batch)
        s2, m2 = jax.jit(step_mesh)(state, batch)
        assert float(m1["loss"]) == float(m2["loss"])
        for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params)):
            assert jnp.array_equal(a, b), "bits=None must be a pure no-op"
        print("OK")
    """)


def test_grad_allreduce8_update_within_two_grid_steps():
    """fp32 training + int8 wire only: the one perturbation is the
    all-reduce codec, so a single SGD update must stay within
    lr · 2·2^-FL of the uncompressed step, element-wise."""
    run_with_devices("""
        import jax, jax.numpy as jnp
        from repro.core import qtrain
        from repro.core.dps import DPSHyper
        from repro.models import lenet
        from repro.optim import SGDConfig, make_optimizer

        mesh = jax.make_mesh((8,), ("data",))
        # wire format derives from the grads controller: static <6,2>
        # (range +-32 covers the per-shard init grads, max |g| ~ 26)
        hg = DPSHyper(il_init=6, fl_init=2)
        base = dict(enabled=False, controller="static", hyper_grads=hg)
        qcfg0 = qtrain.QuantConfig(**base)
        qcfg8 = qtrain.QuantConfig(**base, grad_allreduce_bits=8)
        opt = make_optimizer(SGDConfig())
        params = lenet.init(jax.random.key(0))
        # one state per config: the qcfg8 registry carries the wire domains
        # (same compute-domain states, same RNG -> still comparable)
        state0 = qtrain.TrainState.create(params, opt.init(params), qcfg0,
                                          jax.random.key(1))
        state8 = qtrain.TrainState.create(params, opt.init(params), qcfg8,
                                          jax.random.key(1))
        batch = {"images": jax.random.normal(jax.random.key(2),
                                             (64, 28, 28, 1)) * 0.5,
                 "labels": jax.random.randint(jax.random.key(3), (64,), 0, 10)}

        s0, _ = jax.jit(qtrain.make_train_step(lenet.loss_fn, opt, qcfg0))(
            state0, batch)
        step8 = qtrain.make_train_step(lenet.loss_fn, opt, qcfg8, mesh=mesh)
        assert step8.wire_sync_active
        s8, m8 = jax.jit(step8)(state8, batch)

        assert float(m8["R_wire"]) == 0.0, "grads must fit the <6,2> range"
        assert float(m8["E_wire"]) > 0.0, "wire stats must be live"
        lr = 0.01                       # SGDConfig default, momentum step 1
        bound = lr * 2 * 2.0 ** -2 + 1e-6
        diff = max(float(jnp.abs(a - b).max()) for a, b in
                   zip(jax.tree.leaves(s0.params), jax.tree.leaves(s8.params)))
        assert diff <= bound, (diff, bound)
        print("OK diff", diff, "bound", bound)
    """)


def test_wire_dps_hair_trigger_rmax_stability():
    """FLIPPED regression pin (was ``..._instability_pin``): with the
    paper's hair-trigger ``r_max = 1e-4`` at 8 wire bits, the pre-registry
    design derived the wire grid ⟨IL, 8−IL⟩ from the grads controller and
    merged wire stats back into it — a few clipped wire elements ratcheted
    IL up, the wire grid coarsened, and the compute FL railed at its cap
    chasing wire error it could not fix.

    The precision-domain registry decouples the wire: a dedicated
    ``wire_grads`` flexpoint domain owns the int8 format (radix from the
    running max|g|, two octaves under it — see ``dps.wire_hyper``) and
    consumes the wire stats, while the grads controller sees only
    compute-grid stats measured on the raw gradients.  This test asserts
    the *stability guarantee* the old pin was flipped into: under the
    identical hair-trigger threshold the compressed run now tracks the
    uncompressed baseline — no wire-induced IL ratchet, compute FL far
    from the rail, no wire-induced early-loss spike, convergence."""
    wire_ctrl = os.environ.get("REPRO_WIRE_CONTROLLER") or "flexpoint"
    run_with_devices(f"""
        import numpy as np
        import jax
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core import qtrain
        from repro.core.dps import DPSHyper
        from repro.data import MNISTLike
        from repro.models import lenet
        from repro.optim import SGDConfig, make_optimizer

        mesh = jax.make_mesh((8,), ("data",))
        # the paper's hair-trigger threshold: 0.01% — >43 of 431080
        # gradient elements clipping anywhere used to bump IL that step
        hg = DPSHyper(il_init=6, fl_init=12, e_max=5e-2, r_max=1e-4)
        qcfg0 = qtrain.QuantConfig(enabled=True, hyper_grads=hg)
        qcfg8 = qtrain.QuantConfig(enabled=True, hyper_grads=hg,
                                   grad_allreduce_bits=8,
                                   wire_controller={wire_ctrl!r})
        opt = make_optimizer(SGDConfig())
        data = MNISTLike(batch=64, seed=0)
        params = lenet.init(jax.random.key(0))

        batch_sh = {{"images": NamedSharding(mesh, P("data")),
                     "labels": NamedSharding(mesh, P("data"))}}

        def run(qcfg, steps=40):
            state = qtrain.TrainState.create(params, opt.init(params), qcfg,
                                             jax.random.key(1))
            repl = jax.tree.map(lambda _: NamedSharding(mesh, P()), state)
            step = qtrain.make_train_step(lenet.loss_fn, opt, qcfg,
                                          mesh=mesh)
            jitted = jax.jit(step, in_shardings=(repl, batch_sh),
                             out_shardings=None)
            hist = {{"loss": [], "il_g": [], "fl_g": [], "il_wg": [],
                     "R_wire": []}}
            for i in range(steps):
                state, m = jitted(state, data.train_batch(i))
                hist["loss"].append(float(m["loss"]))
                hist["il_g"].append(float(m["il_g"]))
                hist["fl_g"].append(float(m["fl_g"]))
                if "il_wire_grads" in m:
                    hist["il_wg"].append(float(m["il_wire_grads"]))
                    hist["R_wire"].append(float(m["R_wire"]))
            return hist

        h0 = run(qcfg0)
        h8 = run(qcfg8)
        ups = lambda xs: sum(1 for a, b in zip(xs, xs[1:]) if b > a)

        # (1) no wire-induced IL ratchet: the compressed run's IL-up count
        # stays in family with the uncompressed baseline's own moves.
        assert ups(h8["il_g"]) <= ups(h0["il_g"]) + 3, (
            ups(h8["il_g"]), ups(h0["il_g"]), h8["il_g"])
        # (2) compute FL stays far off the hyper cap (the old failure
        # railed it at fl_max chasing irreducible wire error).
        assert max(h8["fl_g"]) < hg.fl_max, h8["fl_g"]
        # (3) no wire-induced early-loss spike beyond the baseline's own
        # startup transient.
        assert max(h8["loss"][:10]) <= 1.5 * max(h0["loss"][:10]), (
            h8["loss"][:10], h0["loss"][:10])
        # (4) training converges under the hair-trigger threshold.
        assert np.isfinite(h8["loss"]).all()
        assert np.mean(h8["loss"][-10:]) < 0.5 * h8["loss"][0], h8["loss"]
        # (5) the wire domain is live and absorbs the range motion the
        # compute IL used to ratchet over: clipping stays rare and the
        # wire radix follows the shrinking gradients down.
        assert max(h8["R_wire"]) < 1e-2, h8["R_wire"]
        assert h8["il_wg"][-1] < h8["il_wg"][0], h8["il_wg"]
        print("OK il_ups", ups(h8["il_g"]), "vs", ups(h0["il_g"]),
              "max_fl", max(h8["fl_g"]),
              "spike", max(h8["loss"][:10]) / max(h0["loss"][:10]),
              "tail", np.mean(h8["loss"][-10:]))
    """)


def test_per_layer_wire_static_formats_match_global_trajectory():
    """Satellite train-parity pin: per-layer wire formats whose [G] table
    rows all equal the global format must produce a BIT-IDENTICAL
    two-step training trajectory under round-to-nearest (no rounding
    noise, so the group-aligned layout and the per-leaf encode order are
    pure implementation detail) — the per-layer machinery adds zero
    numerics of its own."""
    run_with_devices("""
        import jax, jax.numpy as jnp
        from repro.core import qtrain
        from repro.core.dps import DPSHyper
        from repro.models import lenet
        from repro.optim import SGDConfig, make_optimizer

        mesh = jax.make_mesh((8,), ("data",))
        base = dict(enabled=False, controller="static",
                    rounding="nearest", wire_controller="static",
                    grad_allreduce_bits=8)
        qcfg_g = qtrain.QuantConfig(**base)
        params = lenet.init(jax.random.key(0))
        qcfg_p = qtrain.QuantConfig(**base).with_per_layer_wire(params)
        G = len(jax.tree.leaves(params))
        assert qcfg_p.wire_grads_groups == G, qcfg_p.wire_grads_groups
        opt = make_optimizer(SGDConfig())

        def run(qcfg, steps=2):
            state = qtrain.TrainState.create(params, opt.init(params), qcfg,
                                             jax.random.key(1))
            step = qtrain.make_train_step(lenet.loss_fn, opt, qcfg,
                                          mesh=mesh)
            assert step.wire_sync_active
            jitted = jax.jit(step)
            for i in range(steps):
                batch = {"images": jax.random.normal(
                             jax.random.fold_in(jax.random.key(2), i),
                             (64, 28, 28, 1)) * 0.5,
                         "labels": jax.random.randint(
                             jax.random.fold_in(jax.random.key(3), i),
                             (64,), 0, 10)}
                state, m = jitted(state, batch)
            return state, m

        s_g, m_g = run(qcfg_g)
        s_p, m_p = run(qcfg_p)
        # the per-layer state really is [G]-shaped and static
        assert s_p.dps["wire_grads"].il.shape == (G,)
        assert float(m_g["loss"]) == float(m_p["loss"])
        for a, b in zip(jax.tree.leaves(s_g.params),
                        jax.tree.leaves(s_p.params)):
            assert jnp.array_equal(a, b), \\
                "equal per-layer formats must reproduce the global run"
        print("OK G =", G)
    """)


def test_per_layer_wire_flexpoint_trains_and_formats_diverge():
    """Per-layer wire formats end-to-end: LeNet/MNIST-tiny with the
    standard per-layer flexpoint wire domain converges, the [G] radix
    table diverges across layers (the point of per-layer formats — conv
    vs fc gradient ranges differ by octaves), wire clipping stays rare,
    and the per-group min/max metrics are live."""
    run_with_devices("""
        import numpy as np
        import jax
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core import qtrain
        from repro.core.dps import DPSHyper
        from repro.data import MNISTLike
        from repro.models import lenet
        from repro.optim import SGDConfig, make_optimizer

        mesh = jax.make_mesh((8,), ("data",))
        hg = DPSHyper(il_init=6, fl_init=12, e_max=5e-2, r_max=5e-3)
        params = lenet.init(jax.random.key(0))
        qcfg = qtrain.QuantConfig(enabled=True, hyper_grads=hg,
                                  grad_allreduce_bits=8
                                  ).with_per_layer_wire(params)
        opt = make_optimizer(SGDConfig())
        data = MNISTLike(batch=64, seed=0)
        state = qtrain.TrainState.create(params, opt.init(params), qcfg,
                                         jax.random.key(1))
        step = qtrain.make_train_step(lenet.loss_fn, opt, qcfg, mesh=mesh)
        assert step.wire_sync_active
        repl = jax.tree.map(lambda _: NamedSharding(mesh, P()), state)
        batch_sh = {"images": NamedSharding(mesh, P("data")),
                    "labels": NamedSharding(mesh, P("data"))}
        jitted = jax.jit(step, in_shardings=(repl, batch_sh),
                         out_shardings=None)
        hist = {"loss": [], "R_wire": [], "spread": []}
        for i in range(40):
            state, m = jitted(state, data.train_batch(i))
            hist["loss"].append(float(m["loss"]))
            hist["R_wire"].append(float(m["R_wire"]))
            hist["spread"].append(float(m["il_wire_grads_max"])
                                  - float(m["il_wire_grads_min"]))
        il = np.asarray(state.dps["wire_grads"].il)
        assert il.shape == (qcfg.wire_grads_groups,)
        # per-layer radices actually diverge (>= 2 distinct ILs in use)
        assert len(set(il.tolist())) > 1, il
        assert max(hist["spread"][-10:]) >= 1.0, hist["spread"]
        # training converges and wire clipping stays mild: the per-layer
        # bulk-biased radix (wire_hyper slack=-2) clips each layer's rare
        # tail by design, so the bound is "mild gradient clipping", not
        # the global domain's near-zero rate
        assert np.isfinite(hist["loss"]).all()
        assert np.mean(hist["loss"][-10:]) < 0.6 * hist["loss"][0]
        assert max(hist["R_wire"][5:]) < 5e-2, max(hist["R_wire"][5:])
        print("OK ils", il, "tail", np.mean(hist["loss"][-10:]))
    """)


def test_grad_allreduce8_trend_controller_and_wire_bytes():
    run_with_devices("""
        import numpy as np
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core import qtrain
        from repro.core.dps import DPSHyper
        from repro.data import MNISTLike
        from repro.launch.hlo_stats import collective_wire_bytes
        from repro.models import lenet
        from repro.optim import SGDConfig, make_optimizer

        mesh = jax.make_mesh((8,), ("data",))
        # e_max=5% lets the grads controller equilibrate FL around its
        # start (raw grads at grid 2^-12 round with ~1% relative error).
        # Under the registry the wire runs its own flexpoint domain and
        # the grads controller sees only compute-grid stats measured on
        # the raw gradients, so both runs' ⟨IL, FL⟩ follow the *same*
        # dynamics — the signals under test are (c) the wire domain
        # tracking the gradient range while the compute format stays in
        # family with the uncompressed run, and (b)/(d) unchanged.
        hg = DPSHyper(il_init=6, fl_init=12, e_max=5e-2, r_max=5e-3)
        qcfg0 = qtrain.QuantConfig(enabled=True, hyper_grads=hg)
        qcfg8 = qtrain.QuantConfig(enabled=True, hyper_grads=hg,
                                   grad_allreduce_bits=8)
        opt = make_optimizer(SGDConfig())
        data = MNISTLike(batch=64, seed=0)
        params = lenet.init(jax.random.key(0))

        batch_sh = {"images": NamedSharding(mesh, P("data")),
                    "labels": NamedSharding(mesh, P("data"))}

        def run(qcfg, steps=40):
            step = qtrain.make_train_step(lenet.loss_fn, opt, qcfg, mesh=mesh)
            state = qtrain.TrainState.create(params, opt.init(params), qcfg,
                                             jax.random.key(1))
            # per-config replication specs: the qcfg8 registry carries two
            # extra wire domains, so the state pytrees differ in structure
            repl = jax.tree.map(lambda _: NamedSharding(mesh, P()), state)
            jitted = jax.jit(step, in_shardings=(repl, batch_sh),
                             out_shardings=None)
            hist = {"loss": [], "fl_g": [], "il_g": [], "il_wg": [],
                    "E_wire": []}
            for i in range(steps):
                state, m = jitted(state, data.train_batch(i))
                hist["loss"].append(float(m["loss"]))
                hist["fl_g"].append(float(m["fl_g"]))
                hist["il_g"].append(float(m["il_g"]))
                if "il_wire_grads" in m:
                    hist["il_wg"].append(float(m["il_wire_grads"]))
                    hist["E_wire"].append(float(m["E_wire"]))
            hlo = jitted.lower(state, data.train_batch(0)).compile().as_text()
            return hist, hlo

        h0, hlo0 = run(qcfg0)
        h8, hlo8 = run(qcfg8)

        # (b) same loss trend: both converge on MNIST-tiny, and the
        # compressed run ends no worse than the uncompressed one (the
        # wire's tail clipping may even land it slightly better)
        assert np.isfinite(h8["loss"]).all()
        assert np.mean(h8["loss"][-10:]) < 0.6 * h8["loss"][0], h8["loss"]
        assert np.mean(h0["loss"][-10:]) < 0.6 * h0["loss"][0], h0["loss"]
        assert (np.mean(h8["loss"][-10:])
                < np.mean(h0["loss"][-10:]) + 0.8), (h0["loss"][-10:],
                                                     h8["loss"][-10:])

        # (c) the wire_grads domain visibly responds to wire stats — its
        # flexpoint radix follows the shrinking gradient range down while
        # the wire rounding error stays live — and the *compute* format is
        # decoupled: FL stays in family with the uncompressed run instead
        # of railing over wire error it cannot fix.
        assert len(set(h8["il_wg"])) > 1, h8["il_wg"]
        assert h8["il_wg"][-1] < h8["il_wg"][0], h8["il_wg"]
        assert max(h8["E_wire"]) > 0.0
        assert max(h8["fl_g"]) <= max(h0["fl_g"]) + 2, (h8["fl_g"],
                                                        h0["fl_g"])

        # (d) wire bytes: int8 grad sync <= ~1/4 of the fp32 all-reduce
        w0 = collective_wire_bytes(hlo0)
        w8 = collective_wire_bytes(hlo8)
        f32_ar = w0["by_op_dtype"].get("all-reduce", {}).get("f32", 0.0)
        s8_wire = w8["by_dtype"].get("s8", 0.0)
        n_params = sum(p.size for p in jax.tree.leaves(params))
        assert f32_ar >= 8 * n_params * 0.9, (f32_ar, n_params)
        assert s8_wire > 0.0
        assert s8_wire <= 0.26 * f32_ar, (s8_wire, f32_ar)
        # residual f32 all-reduces in the compressed step are stats/loss
        # scalars, not gradient payloads
        f32_ar8 = w8["by_op_dtype"].get("all-reduce", {}).get("f32", 0.0)
        assert f32_ar8 < 0.01 * f32_ar, (f32_ar8, f32_ar)
        print("OK", s8_wire / f32_ar)
    """)
