"""Multi-device regression tests for the compressed gradient all-reduce
(``QuantConfig.grad_allreduce_bits``): run in a subprocess under
``xla_force_host_platform_device_count=8`` like tests/test_dist.py.

Covers the ISSUE-2 acceptance criteria:
  (a) ``grad_allreduce_bits=None`` with a mesh matches the meshless step
      bit-exactly (the flag is a pure opt-in),
  (b) ``=8`` keeps the synced gradient within two wire grid steps of the
      fp32 mean (asserted through the SGD update) and trains MNIST-tiny
      with the same loss trend,
  (c) the grads DPS controller's ⟨IL, FL⟩ trajectory visibly responds to
      the wire QuantStats,
  (d) the int8 path moves ≤ ~1/4 the gradient wire bytes of the fp32
      all-reduce (ring model, parsed from compiled HLO).
"""

import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_with_devices(code: str, n: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_grad_allreduce_off_matches_meshless_step_bitexact():
    run_with_devices("""
        import jax, jax.numpy as jnp
        from repro.core import qtrain
        from repro.models import lenet
        from repro.optim import SGDConfig, make_optimizer

        mesh = jax.make_mesh((8,), ("data",))
        qcfg = qtrain.QuantConfig(enabled=True)   # grad_allreduce_bits=None
        opt = make_optimizer(SGDConfig())
        params = lenet.init(jax.random.key(0))
        state = qtrain.TrainState.create(params, opt.init(params), qcfg,
                                         jax.random.key(1))
        batch = {"images": jax.random.normal(jax.random.key(2), (64, 28, 28, 1)),
                 "labels": jax.random.randint(jax.random.key(3), (64,), 0, 10)}

        step_ref = qtrain.make_train_step(lenet.loss_fn, opt, qcfg)
        step_mesh = qtrain.make_train_step(lenet.loss_fn, opt, qcfg, mesh=mesh)
        assert not step_mesh.wire_sync_active
        s1, m1 = jax.jit(step_ref)(state, batch)
        s2, m2 = jax.jit(step_mesh)(state, batch)
        assert float(m1["loss"]) == float(m2["loss"])
        for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params)):
            assert jnp.array_equal(a, b), "bits=None must be a pure no-op"
        print("OK")
    """)


def test_grad_allreduce8_update_within_two_grid_steps():
    """fp32 training + int8 wire only: the one perturbation is the
    all-reduce codec, so a single SGD update must stay within
    lr · 2·2^-FL of the uncompressed step, element-wise."""
    run_with_devices("""
        import jax, jax.numpy as jnp
        from repro.core import qtrain
        from repro.core.dps import DPSHyper
        from repro.models import lenet
        from repro.optim import SGDConfig, make_optimizer

        mesh = jax.make_mesh((8,), ("data",))
        # wire format derives from the grads controller: static <6,2>
        # (range +-32 covers the per-shard init grads, max |g| ~ 26)
        hg = DPSHyper(il_init=6, fl_init=2)
        base = dict(enabled=False, controller="static", hyper_grads=hg)
        qcfg0 = qtrain.QuantConfig(**base)
        qcfg8 = qtrain.QuantConfig(**base, grad_allreduce_bits=8)
        opt = make_optimizer(SGDConfig())
        params = lenet.init(jax.random.key(0))
        state = qtrain.TrainState.create(params, opt.init(params), qcfg0,
                                         jax.random.key(1))
        batch = {"images": jax.random.normal(jax.random.key(2),
                                             (64, 28, 28, 1)) * 0.5,
                 "labels": jax.random.randint(jax.random.key(3), (64,), 0, 10)}

        s0, _ = jax.jit(qtrain.make_train_step(lenet.loss_fn, opt, qcfg0))(
            state, batch)
        step8 = qtrain.make_train_step(lenet.loss_fn, opt, qcfg8, mesh=mesh)
        assert step8.wire_sync_active
        s8, m8 = jax.jit(step8)(state, batch)

        assert float(m8["R_wire"]) == 0.0, "grads must fit the <6,2> range"
        assert float(m8["E_wire"]) > 0.0, "wire stats must be live"
        lr = 0.01                       # SGDConfig default, momentum step 1
        bound = lr * 2 * 2.0 ** -2 + 1e-6
        diff = max(float(jnp.abs(a - b).max()) for a, b in
                   zip(jax.tree.leaves(s0.params), jax.tree.leaves(s8.params)))
        assert diff <= bound, (diff, bound)
        print("OK diff", diff, "bound", bound)
    """)


def test_wire_dps_hair_trigger_rmax_instability_pin():
    """REGRESSION PIN for the ROADMAP's wire-DPS instability (not a feature
    test): with the paper's hair-trigger ``r_max = 1e-4`` at 8 wire bits, a
    few clipped wire elements repeatedly ratchet IL up, the derived wire
    grid ⟨IL, 8−IL⟩ coarsens, and the grads controller rails its *compute*
    FL at the cap chasing wire error it cannot fix — destabilizing early
    training vs the tolerant-``r_max`` regime pinned by the trend test
    below.

    A future dedicated wire controller (e.g. FlexPoint-style max_abs-driven
    wire radix, see ROADMAP) should decouple the wire format from the grads
    IL; when it lands, these assertions are EXPECTED TO FAIL — flip them to
    assert the fixed behavior instead of deleting the test."""
    run_with_devices("""
        import numpy as np
        import jax
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core import qtrain
        from repro.core.dps import DPSHyper
        from repro.data import MNISTLike
        from repro.models import lenet
        from repro.optim import SGDConfig, make_optimizer

        mesh = jax.make_mesh((8,), ("data",))
        # identical to the tolerant trend test below except r_max: the
        # paper's 0.01% means >43 of 431080 gradient elements clipping on
        # the wire bumps IL (and thereby coarsens the wire grid) that step.
        hg = DPSHyper(il_init=4, fl_init=12, e_max=5e-2, r_max=1e-4)
        qcfg = qtrain.QuantConfig(enabled=True, hyper_grads=hg,
                                  grad_allreduce_bits=8)
        opt = make_optimizer(SGDConfig())
        data = MNISTLike(batch=64, seed=0)
        params = lenet.init(jax.random.key(0))
        state = qtrain.TrainState.create(params, opt.init(params), qcfg,
                                         jax.random.key(1))
        repl = jax.tree.map(lambda _: NamedSharding(mesh, P()), state)
        batch_sh = {"images": NamedSharding(mesh, P("data")),
                    "labels": NamedSharding(mesh, P("data"))}
        step = qtrain.make_train_step(lenet.loss_fn, opt, qcfg, mesh=mesh)
        jitted = jax.jit(step, in_shardings=(repl, batch_sh),
                         out_shardings=None)

        il, fl, loss = [], [], []
        for i in range(25):
            state, m = jitted(state, data.train_batch(i))
            il.append(float(m["il_g"]))
            fl.append(float(m["fl_g"]))
            loss.append(float(m["loss"]))

        # (1) the ratchet: several distinct IL-up events fire from stray
        # wire clips (a decoupled wire controller would absorb these).
        il_ups = sum(1 for a, b in zip(il, il[1:]) if b > a)
        assert il_ups >= 3, (il_ups, il)
        # (2) the compute-FL rails at the hyper cap chasing the irreducible
        # coarse-wire error E_wire ~ O(1) >> e_max.
        assert max(fl) >= hg.fl_max, fl
        # (3) early training destabilizes: the loss spikes well above its
        # starting point before recovering (the tolerant-r_max run below
        # never leaves its downward trend this violently).
        assert max(loss[:10]) > 2.5 * loss[0], loss[:10]
        print("OK il_ups", il_ups, "fl_max", max(fl),
              "spike", max(loss[:10]) / loss[0])
    """)


def test_grad_allreduce8_trend_controller_and_wire_bytes():
    run_with_devices("""
        import numpy as np
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core import qtrain
        from repro.core.dps import DPSHyper
        from repro.data import MNISTLike
        from repro.launch.hlo_stats import collective_wire_bytes
        from repro.models import lenet
        from repro.optim import SGDConfig, make_optimizer

        mesh = jax.make_mesh((8,), ("data",))
        # e_max=5% lets the uncompressed run equilibrate FL below its
        # start (grads at grid 2^-12 round with ~1% relative error), while
        # the int8 wire (grid 2^-4) rounds most gradient elements to zero
        # -> E ~ 1 >> e_max -> FL must climb.  That asymmetry is the
        # "controller responds to wire stats" signal under test.  r_max
        # is loosened to 0.5%: with the paper's hair-trigger 0.01% every
        # stray clip ratchets IL up and the derived wire grid (2^-(8-IL))
        # coarsens until training destabilizes — a real dynamic of wire-
        # fed DPS worth pinning, but not the subject of this test.
        hg = DPSHyper(il_init=4, fl_init=12, e_max=5e-2, r_max=5e-3)
        qcfg0 = qtrain.QuantConfig(enabled=True, hyper_grads=hg)
        qcfg8 = qtrain.QuantConfig(enabled=True, hyper_grads=hg,
                                   grad_allreduce_bits=8)
        opt = make_optimizer(SGDConfig())
        data = MNISTLike(batch=64, seed=0)
        params = lenet.init(jax.random.key(0))

        repl = jax.tree.map(
            lambda _: NamedSharding(mesh, P()),
            qtrain.TrainState.create(params, opt.init(params), qcfg0,
                                     jax.random.key(1)))
        batch_sh = {"images": NamedSharding(mesh, P("data")),
                    "labels": NamedSharding(mesh, P("data"))}

        def run(qcfg, steps=40):
            step = qtrain.make_train_step(lenet.loss_fn, opt, qcfg, mesh=mesh)
            jitted = jax.jit(step, in_shardings=(repl, batch_sh),
                             out_shardings=None)
            state = qtrain.TrainState.create(params, opt.init(params), qcfg,
                                             jax.random.key(1))
            hist = {"loss": [], "fl_g": [], "il_g": []}
            for i in range(steps):
                state, m = jitted(state, data.train_batch(i))
                hist["loss"].append(float(m["loss"]))
                hist["fl_g"].append(float(m["fl_g"]))
                hist["il_g"].append(float(m["il_g"]))
            hlo = jitted.lower(state, data.train_batch(0)).compile().as_text()
            return hist, hlo

        h0, hlo0 = run(qcfg0)
        h8, hlo8 = run(qcfg8)

        # (b) same loss trend: both converge on MNIST-tiny
        assert np.isfinite(h8["loss"]).all()
        assert np.mean(h8["loss"][-10:]) < 0.6 * h8["loss"][0], h8["loss"]
        assert np.mean(h0["loss"][-10:]) < 0.6 * h0["loss"][0], h0["loss"]
        gap = abs(np.mean(h8["loss"][-10:]) - np.mean(h0["loss"][-10:]))
        assert gap < 0.8, (gap, h0["loss"][-10:], h8["loss"][-10:])

        # (c) the grads controller visibly responds to wire stats: the
        # coarse int8 wire keeps E above threshold, so FL climbs instead
        # of decaying toward fl_min as in the uncompressed run.
        assert h8["fl_g"] != h0["fl_g"], "wire stats had no effect on <IL,FL>"
        assert h8["fl_g"][-1] > h0["fl_g"][-1], (h8["fl_g"], h0["fl_g"])

        # (d) wire bytes: int8 grad sync <= ~1/4 of the fp32 all-reduce
        w0 = collective_wire_bytes(hlo0)
        w8 = collective_wire_bytes(hlo8)
        f32_ar = w0["by_op_dtype"].get("all-reduce", {}).get("f32", 0.0)
        s8_wire = w8["by_dtype"].get("s8", 0.0)
        n_params = sum(p.size for p in jax.tree.leaves(params))
        assert f32_ar >= 8 * n_params * 0.9, (f32_ar, n_params)
        assert s8_wire > 0.0
        assert s8_wire <= 0.26 * f32_ar, (s8_wire, f32_ar)
        # residual f32 all-reduces in the compressed step are stats/loss
        # scalars, not gradient payloads
        f32_ar8 = w8["by_op_dtype"].get("all-reduce", {}).get("f32", 0.0)
        assert f32_ar8 < 0.01 * f32_ar, (f32_ar8, f32_ar)
        print("OK", s8_wire / f32_ar)
    """)
